// Fixture tree with zero findings — the CLI must exit 0 here.
pub fn add(a: u32, b: u32) -> u32 {
    a + b
}
