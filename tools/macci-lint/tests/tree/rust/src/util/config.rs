// Fixture tree: the one sanctioned env read, behind a reviewed pragma.
pub fn raw(key: &str) -> Option<String> {
    // lint: allow(env-config) — latch-once read point
    std::env::var(key).ok()
}
