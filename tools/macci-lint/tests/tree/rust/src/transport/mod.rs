// Fixture tree: mod.rs collapses onto its directory (`transport`),
// which is itself an exact R1 zone — the index below must be caught.
pub fn frame_len(buf: &[u8]) -> usize {
    usize::from(buf[0])
}
