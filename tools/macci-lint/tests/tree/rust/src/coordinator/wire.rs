// Fixture tree: seeded R1 violation in a no-panic zone.
pub fn decode(buf: &[u8]) -> u8 {
    *buf.first().unwrap()
}
