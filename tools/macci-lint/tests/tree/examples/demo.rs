// Fixture tree: a clean example — nothing to report.
fn main() {
    println!("demo");
}
