//! Rule-level self-tests: every rule catches its seeded bad fixture,
//! the pragma-suppressed variant passes with exactly one suppression,
//! and the clean variant is silent. Plus zone scoping, `#[cfg(test)]`
//! masking, pragma grammar, tree walking, and the CLI/JSON contract.

use std::path::Path;
use std::process::Command;

use macci_lint::{lint_source, lint_tree};

const R1_BAD: &str = include_str!("fixtures/r1_bad.rs");
const R1_SUPPRESSED: &str = include_str!("fixtures/r1_suppressed.rs");
const R1_CLEAN: &str = include_str!("fixtures/r1_clean.rs");
const R2_BAD: &str = include_str!("fixtures/r2_bad.rs");
const R2_SUPPRESSED: &str = include_str!("fixtures/r2_suppressed.rs");
const R2_CLEAN: &str = include_str!("fixtures/r2_clean.rs");
const R3_BAD: &str = include_str!("fixtures/r3_bad.rs");
const R3_SUPPRESSED: &str = include_str!("fixtures/r3_suppressed.rs");
const R3_CLEAN: &str = include_str!("fixtures/r3_clean.rs");
const R4_BAD: &str = include_str!("fixtures/r4_bad.rs");
const R4_SUPPRESSED: &str = include_str!("fixtures/r4_suppressed.rs");
const R4_CLEAN: &str = include_str!("fixtures/r4_clean.rs");
const R5_BAD: &str = include_str!("fixtures/r5_bad.rs");
const R5_SUPPRESSED: &str = include_str!("fixtures/r5_suppressed.rs");
const R5_CLEAN: &str = include_str!("fixtures/r5_clean.rs");
const R6_BAD: &str = include_str!("fixtures/r6_bad.rs");
const R6_SUPPRESSED: &str = include_str!("fixtures/r6_suppressed.rs");
const R6_CLEAN: &str = include_str!("fixtures/r6_clean.rs");

fn rules_of(module: &str, src: &str) -> Vec<String> {
    lint_source(module, "fixture.rs", src).findings.iter().map(|f| f.rule.clone()).collect()
}

#[test]
fn r1_catches_unwrap_panic_and_indexing() {
    assert_eq!(rules_of("coordinator::wire", R1_BAD), ["R1", "R1", "R1"]);
}

#[test]
fn r2_catches_hashmap_and_mul_add() {
    assert_eq!(rules_of("runtime::native::gemm", R2_BAD), ["R2", "R2"]);
}

#[test]
fn r3_catches_direct_and_turbofish_channel() {
    assert_eq!(rules_of("coordinator::executor", R3_BAD), ["R3", "R3"]);
}

#[test]
fn r4_catches_raw_env_reads() {
    assert_eq!(rules_of("runtime::backend", R4_BAD), ["R4"]);
}

#[test]
fn r5_catches_unjustified_unsafe() {
    assert_eq!(rules_of("runtime::native::simd", R5_BAD), ["R5"]);
}

#[test]
fn r6_catches_anonymous_spawn() {
    assert_eq!(rules_of("coordinator::supervisor", R6_BAD), ["R6"]);
}

#[test]
fn update_engine_module_is_patrolled_by_r2_and_r6() {
    // the sharded PPO update engine lives in the R2 bit-exactness zone
    // (prefix match under runtime::native) and, like every module, in the
    // R6 named-threads zone — it must stay clean with zero pragmas, so
    // both rules have to actually fire there
    assert_eq!(rules_of("runtime::native::update", R2_BAD), ["R2", "R2"]);
    assert_eq!(rules_of("runtime::native::update", R6_BAD), ["R6"]);
}

#[test]
fn offload_cache_module_is_a_no_panic_zone() {
    // the content-addressed result cache sits on the serving hot path and
    // digests request-supplied payload bytes, so it carries the same
    // no-panic contract as the wire codec and the server loop — R1 must
    // fire there, with zero pragmas in the real module
    assert_eq!(rules_of("coordinator::offload_cache", R1_BAD), ["R1", "R1", "R1"]);
}

#[test]
fn pragmas_suppress_each_rule_and_record_the_reason() {
    let cases = [
        ("coordinator::wire", R1_SUPPRESSED, "R1"),
        ("runtime::native::gemm", R2_SUPPRESSED, "R2"),
        ("coordinator::executor", R3_SUPPRESSED, "R3"),
        ("util::config", R4_SUPPRESSED, "R4"),
        ("runtime::native::simd", R5_SUPPRESSED, "R5"),
        ("coordinator::supervisor", R6_SUPPRESSED, "R6"),
    ];
    for (module, src, rule) in cases {
        let r = lint_source(module, "fixture.rs", src);
        assert!(r.findings.is_empty(), "{rule}: {:?}", r.findings);
        assert_eq!(r.suppressed.len(), 1, "{rule}");
        assert_eq!(r.suppressed[0].rule, rule);
        assert!(!r.suppressed[0].reason.is_empty(), "{rule}");
    }
}

#[test]
fn clean_fixtures_are_silent() {
    let cases = [
        ("coordinator::wire", R1_CLEAN),
        ("runtime::native::gemm", R2_CLEAN),
        ("coordinator::executor", R3_CLEAN),
        ("main", R4_CLEAN),
        ("runtime::native::simd", R5_CLEAN),
        ("coordinator::supervisor", R6_CLEAN),
    ];
    for (module, src) in cases {
        let r = lint_source(module, "fixture.rs", src);
        assert!(r.findings.is_empty(), "{module}: {:?}", r.findings);
        assert!(r.suppressed.is_empty(), "{module}");
    }
}

#[test]
fn rules_stay_inside_their_zones() {
    // R1's panics/indexing are fine outside its zones; same for R2's
    // fused math outside the kernels and the RL stack.
    assert!(rules_of("rl::rollout", R1_BAD).is_empty());
    assert!(rules_of("coordinator::wire", R2_BAD).is_empty());
}

#[test]
fn cfg_test_modules_are_exempt() {
    let src = r#"
pub fn f() -> u8 {
    0
}

#[cfg(test)]
mod tests {
    #[test]
    fn indexing_is_fine_in_tests() {
        let v = [1u8, 2];
        assert_eq!(v[0], super::f() + 1);
    }
}
"#;
    assert!(rules_of("coordinator::wire", src).is_empty());
}

#[test]
fn pragma_without_a_reason_is_itself_a_finding() {
    let src = "// lint: allow(no-panic)\npub fn f() {}\n";
    let r = lint_source("coordinator::wire", "fixture.rs", src);
    assert_eq!(r.findings.len(), 1);
    assert_eq!(r.findings[0].rule, "pragma");
}

#[test]
fn pragma_matches_by_rule_id_too() {
    let src = r#"
pub fn f(xs: &[u8]) -> u8 {
    // lint: allow(R1) -- bound checked by the caller
    xs[0]
}
"#;
    let r = lint_source("transport::tcp", "fixture.rs", src);
    assert!(r.findings.is_empty(), "{:?}", r.findings);
    assert_eq!(r.suppressed.len(), 1);
    assert_eq!(r.suppressed[0].rule, "R1");
}

#[test]
fn lint_tree_walks_and_labels_the_fixture_tree() {
    let root = Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/tests/tree"));
    let r = lint_tree(root).expect("scan fixture tree");
    assert_eq!(r.files_scanned, 4);
    assert_eq!(r.findings.len(), 2);
    assert_eq!(r.findings[0].rule, "R1");
    assert_eq!(r.findings[0].file, "rust/src/coordinator/wire.rs");
    assert_eq!(r.findings[1].file, "rust/src/transport/mod.rs");
    assert_eq!(r.suppressed.len(), 1);
    assert_eq!(r.suppressed[0].file, "rust/src/util/config.rs");
}

#[test]
fn cli_reports_findings_and_writes_schema_conformant_json() {
    let root = concat!(env!("CARGO_MANIFEST_DIR"), "/tests/tree");
    let json = std::env::temp_dir().join("macci-lint-selftest.json");
    let out = Command::new(env!("CARGO_BIN_EXE_macci-lint"))
        .args(["--root", root, "--json"])
        .arg(&json)
        .output()
        .expect("run macci-lint");
    assert_eq!(out.status.code(), Some(1));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("R1(no-panic)"), "{stdout}");
    let text = std::fs::read_to_string(&json).expect("read LINT.json");
    let keys = ["\"version\": 1", "\"files_scanned\": 4", "\"rules\":", "\"findings\":"];
    for key in keys {
        assert!(text.contains(key), "missing {key} in {text}");
    }
    assert!(text.contains("\"suppressed\":"), "{text}");
    assert!(text.contains("\"rule\": \"R1\""), "{text}");
    assert_balanced(&text);
}

#[test]
fn cli_exits_zero_on_a_clean_tree() {
    let root = concat!(env!("CARGO_MANIFEST_DIR"), "/tests/tree_clean");
    let out = Command::new(env!("CARGO_BIN_EXE_macci-lint"))
        .args(["--root", root])
        .output()
        .expect("run macci-lint");
    assert_eq!(out.status.code(), Some(0));
}

#[test]
fn cli_rejects_unknown_arguments() {
    let out = Command::new(env!("CARGO_BIN_EXE_macci-lint"))
        .arg("--bogus")
        .output()
        .expect("run macci-lint");
    assert_eq!(out.status.code(), Some(2));
}

/// Structural JSON check without a parser: braces/brackets balance and
/// never go negative, and every string closes — string-aware so escaped
/// quotes and braces inside messages don't confuse the count.
fn assert_balanced(text: &str) {
    let mut depth = 0i32;
    let mut in_str = false;
    let mut escaped = false;
    for ch in text.chars() {
        if escaped {
            escaped = false;
            continue;
        }
        match ch {
            '\\' if in_str => escaped = true,
            '"' => in_str = !in_str,
            '{' | '[' if !in_str => depth += 1,
            '}' | ']' if !in_str => depth -= 1,
            _ => {}
        }
        assert!(depth >= 0, "bracket depth went negative");
    }
    assert_eq!(depth, 0, "unbalanced brackets");
    assert!(!in_str, "unterminated string");
}
