// R1 clean fixture: slice patterns and Option instead of panics.
pub fn decode(buf: &[u8]) -> Option<u16> {
    match buf {
        [hi, lo, ..] => Some((u16::from(*hi) << 8) | u16::from(*lo)),
        _ => None,
    }
}
