// R3 suppressed fixture: the unbounded queue is pragma'd with a reason.
use std::sync::mpsc;

pub fn drain_queue() -> (mpsc::Sender<u32>, mpsc::Receiver<u32>) {
    // lint: allow(bounded-channels) — drained synchronously before senders can outrun it
    mpsc::channel()
}
