// R4 suppressed fixture: the single latch-once read point.
pub fn raw(key: &str) -> Option<String> {
    // lint: allow(env-config) — this is the one place env is read, behind a latch
    std::env::var(key).ok()
}
