// R1 suppressed fixture: the index is pragma'd with a reason.
pub fn checksum(data: &[u8]) -> u8 {
    let mut acc = 0u8;
    for i in 0..data.len() {
        // lint: allow(no-panic) — i < data.len() by the loop bound
        acc ^= data[i];
    }
    acc
}
