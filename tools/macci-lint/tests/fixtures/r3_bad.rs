// R3 bad fixture: linted as module `coordinator::executor`. Two hits —
// a direct `channel()` call and the turbofish form.
use std::sync::mpsc;

pub fn queues() -> (mpsc::Sender<u32>, mpsc::Receiver<u32>) {
    let (_tx, _rx) = mpsc::channel::<u8>();
    mpsc::channel()
}
