// R1 bad fixture: linted as module `coordinator::wire`. Three hits —
// an unwrap, a panic! macro, and a direct slice index.
pub fn decode(buf: &[u8]) -> u16 {
    let hi = buf.first().unwrap();
    if buf.len() < 2 {
        panic!("short frame");
    }
    (u16::from(*hi) << 8) | u16::from(buf[1])
}
