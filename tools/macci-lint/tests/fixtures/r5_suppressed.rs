// R5 suppressed fixture: justification deferred via pragma.
pub fn head(xs: &[f32]) -> f32 {
    // lint: allow(unsafe-safety) — soundness argument lives at the single call site
    unsafe { *xs.as_ptr() }
}
