// R5 clean fixture: both comment placements the rule accepts — same
// line / directly above, and above an attribute stack.
pub fn head(xs: &[f32]) -> f32 {
    // SAFETY: callers pass the non-empty row slices built in new()
    unsafe { *xs.as_ptr() }
}

// SAFETY: caller must ensure AVX2 is available on the executing CPU
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
pub unsafe fn head_avx2(xs: &[f32]) -> f32 {
    *xs.as_ptr()
}
