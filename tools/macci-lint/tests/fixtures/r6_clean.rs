// R6 clean fixture: spawned through Builder with a name.
pub fn start() -> std::io::Result<std::thread::JoinHandle<()>> {
    std::thread::Builder::new().name("worker".into()).spawn(|| {})
}
