// R3 clean fixture: a bounded queue with an explicit depth.
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};

pub fn queue() -> (SyncSender<u32>, Receiver<u32>) {
    sync_channel(8)
}
