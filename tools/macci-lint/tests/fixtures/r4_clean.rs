// R4 clean fixture: knobs come from accessors, not raw env reads.
pub fn backend(configured: &str) -> bool {
    configured == "native"
}
