// R2 clean fixture: separate multiply/add rounding, ordered map.
use std::collections::BTreeMap;

pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    let mut acc = 0.0f32;
    for (x, y) in a.iter().zip(b) {
        acc += x * y;
    }
    acc
}

pub fn histogram(xs: &[u8]) -> BTreeMap<u8, usize> {
    let mut h = BTreeMap::new();
    for &x in xs {
        *h.entry(x).or_insert(0) += 1;
    }
    h
}
