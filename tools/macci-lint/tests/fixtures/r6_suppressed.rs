// R6 suppressed fixture: anonymity justified via pragma.
pub fn start() -> std::thread::JoinHandle<()> {
    // lint: allow(named-threads) — short-lived probe thread, a name adds no signal
    std::thread::spawn(|| {})
}
