// R5 bad fixture: linted as module `runtime::native::simd`. One hit —
// an `unsafe` block with no `// SAFETY:` justification anywhere near it.
pub fn head(xs: &[f32]) -> f32 {
    unsafe { *xs.as_ptr() }
}
