// R4 bad fixture: a raw env read outside util::config.
pub fn backend() -> String {
    std::env::var("MACCI_BACKEND").unwrap_or_default()
}
