// R2 suppressed fixture: the fused path is pragma'd with a reason.
pub fn fast_dot(a: &[f32], b: &[f32]) -> f32 {
    let mut acc = 0.0f32;
    for (x, y) in a.iter().zip(b) {
        // lint: allow(determinism) — reference path, never feeds bit-exact checkpoints
        acc = x.mul_add(*y, acc);
    }
    acc
}
