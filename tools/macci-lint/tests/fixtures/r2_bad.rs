// R2 bad fixture: linted as module `runtime::native::gemm`. Two hits —
// a HashMap import and a fused mul_add.
use std::collections::HashMap;

pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    let mut acc = 0.0f32;
    for (x, y) in a.iter().zip(b) {
        acc = x.mul_add(*y, acc);
    }
    acc
}
