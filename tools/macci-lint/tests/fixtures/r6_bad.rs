// R6 bad fixture: an anonymous thread::spawn.
pub fn start() -> std::thread::JoinHandle<()> {
    std::thread::spawn(|| {})
}
