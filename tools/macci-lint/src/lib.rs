//! macci-lint: the workspace's invariant linter (DESIGN.md §Static-Analysis).
//!
//! Six module-scoped rules guard invariants `clippy` cannot see because
//! they are repo policy, not Rust policy: no-panic zones on the serving
//! path (R1), bit-exact determinism in the kernels (R2), bounded queues
//! in the coordinator/transport (R3), latch-once env discipline (R4),
//! `// SAFETY:` audits on `unsafe` (R5), and named threads (R6).
//!
//! Violations are silenced only by an inline pragma with a mandatory
//! reason: `// lint: allow(<rule>) — <why>`. A pragma without a reason
//! is itself a finding.

pub mod engine;
pub mod lexer;

pub use engine::{lint_source, lint_tree, Finding, LintReport, RuleInfo, Suppressed, RULES};
