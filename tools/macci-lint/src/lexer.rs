//! A minimal Rust lexer: just enough token structure for module-scoped
//! pattern rules, with exact comment/string/char-literal handling so a
//! rule can never fire on text inside a literal or a comment.
//!
//! Not a full grammar — no keyword/ident distinction (rules match ident
//! text directly), no operator gluing (`::` is two `:` tokens). What it
//! does get right, because the rules depend on it: line comments, nested
//! block comments, string escapes, raw strings with arbitrary `#`
//! fences, byte/raw-byte strings, char literals vs lifetimes, and raw
//! identifiers (`r#match`).

/// What a token is, as far as the rules care.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kind {
    /// Identifier or keyword (`unwrap`, `unsafe`, `match`, ...).
    Ident,
    /// A single punctuation character (`[`, `:`, `!`, ...).
    Punct,
    /// Numeric literal.
    Num,
    /// String / raw string / byte string / char literal.
    Literal,
    /// Lifetime (`'a`, `'static`).
    Lifetime,
    /// `// ...` comment, text without the slashes.
    LineComment,
    /// `/* ... */` comment (nesting folded), full inner text.
    BlockComment,
}

/// One token with its source position (1-based line and column).
#[derive(Debug, Clone)]
pub struct Tok {
    pub kind: Kind,
    pub text: String,
    pub line: u32,
    pub col: u32,
}

impl Tok {
    /// The single character of a `Punct` token.
    pub fn ch(&self) -> char {
        self.text.chars().next().unwrap_or('\0')
    }
}

fn tok(kind: Kind, text: String, line: u32, col: u32) -> Tok {
    Tok { kind, text, line, col }
}

struct Cursor<'a> {
    src: &'a [u8],
    pos: usize,
    line: u32,
    col: u32,
}

impl<'a> Cursor<'a> {
    fn peek(&self, ahead: usize) -> u8 {
        self.src.get(self.pos + ahead).copied().unwrap_or(0)
    }

    fn bump(&mut self) -> u8 {
        let b = self.peek(0);
        self.pos += 1;
        if b == b'\n' {
            self.line += 1;
            self.col = 1;
        } else {
            self.col += 1;
        }
        b
    }

    fn eof(&self) -> bool {
        self.pos >= self.src.len()
    }

    fn take_while(&mut self, pred: fn(u8) -> bool) -> String {
        let mut text = String::new();
        while !self.eof() && pred(self.peek(0)) {
            text.push(self.bump() as char);
        }
        text
    }
}

fn is_ident_start(b: u8) -> bool {
    b.is_ascii_alphabetic() || b == b'_'
}

fn is_ident_cont(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// How many `#` fence characters a raw string opener has at offset `at`,
/// or `None` if the cursor is not looking at a raw string opener.
fn raw_fence(c: &Cursor, mut at: usize) -> Option<usize> {
    let mut hashes = 0usize;
    while c.peek(at) == b'#' {
        hashes += 1;
        at += 1;
    }
    (c.peek(at) == b'"').then_some(hashes)
}

/// Does the cursor sit on `r"`, `r#"`, `b"`, `b'`, `br"`, or `br#"`?
fn raw_or_byte_literal_start(c: &Cursor) -> bool {
    match c.peek(0) {
        b'r' => raw_fence(c, 1).is_some(),
        b'b' => match c.peek(1) {
            b'"' | b'\'' => true,
            b'r' => raw_fence(c, 2).is_some(),
            _ => false,
        },
        _ => false,
    }
}

/// Tokenize `src`. Unterminated literals/comments simply end at EOF —
/// the linter reads real, compiling source, so error recovery is moot.
pub fn lex(src: &str) -> Vec<Tok> {
    let mut c = Cursor { src: src.as_bytes(), pos: 0, line: 1, col: 1 };
    let mut out = Vec::new();
    while !c.eof() {
        let (line, col) = (c.line, c.col);
        let b = c.peek(0);
        if b == b' ' || b == b'\t' || b == b'\r' || b == b'\n' {
            c.bump();
        } else if b == b'/' && c.peek(1) == b'/' {
            c.bump();
            c.bump();
            let mut text = String::new();
            while !c.eof() && c.peek(0) != b'\n' {
                text.push(c.bump() as char);
            }
            out.push(tok(Kind::LineComment, text, line, col));
        } else if b == b'/' && c.peek(1) == b'*' {
            out.push(tok(Kind::BlockComment, lex_block_comment(&mut c), line, col));
        } else if raw_or_byte_literal_start(&c) {
            out.push(tok(Kind::Literal, lex_raw_or_byte_literal(&mut c), line, col));
        } else if b == b'r' && c.peek(1) == b'#' && is_ident_start(c.peek(2)) {
            c.bump();
            c.bump();
            out.push(tok(Kind::Ident, c.take_while(is_ident_cont), line, col));
        } else if is_ident_start(b) {
            out.push(tok(Kind::Ident, c.take_while(is_ident_cont), line, col));
        } else if b.is_ascii_digit() {
            let mut text = c.take_while(is_ident_cont);
            // fractional part — but not the `..` of a range like `1..n`
            if c.peek(0) == b'.' && c.peek(1).is_ascii_digit() {
                text.push(c.bump() as char);
                text.push_str(&c.take_while(is_ident_cont));
            }
            out.push(tok(Kind::Num, text, line, col));
        } else if b == b'"' {
            out.push(tok(Kind::Literal, lex_quoted(&mut c, b'"'), line, col));
        } else if b == b'\'' {
            // lifetime ('a, 'static) vs char literal ('x', '\n', '\'')
            if is_ident_start(c.peek(1)) && c.peek(2) != b'\'' {
                c.bump();
                let text = format!("'{}", c.take_while(is_ident_cont));
                out.push(tok(Kind::Lifetime, text, line, col));
            } else {
                out.push(tok(Kind::Literal, lex_quoted(&mut c, b'\''), line, col));
            }
        } else {
            c.bump();
            out.push(tok(Kind::Punct, (b as char).to_string(), line, col));
        }
    }
    out
}

/// Lex a (possibly nested) `/* ... */` comment, delimiters consumed.
fn lex_block_comment(c: &mut Cursor) -> String {
    c.bump();
    c.bump();
    let mut depth = 1usize;
    let mut text = String::new();
    while !c.eof() && depth > 0 {
        if c.peek(0) == b'/' && c.peek(1) == b'*' {
            depth += 1;
            c.bump();
            c.bump();
            text.push_str("/*");
        } else if c.peek(0) == b'*' && c.peek(1) == b'/' {
            depth -= 1;
            c.bump();
            c.bump();
            if depth > 0 {
                text.push_str("*/");
            }
        } else {
            text.push(c.bump() as char);
        }
    }
    text
}

/// Lex `r"..."`, `r#"..."#`, `b"..."`, `b'.'`, `br"..."`, `br#"..."#`.
fn lex_raw_or_byte_literal(c: &mut Cursor) -> String {
    if c.peek(0) == b'b' {
        c.bump();
        match c.peek(0) {
            b'"' => return lex_quoted(c, b'"'),
            b'\'' => return lex_quoted(c, b'\''),
            _ => {} // br... falls through to the raw case
        }
    }
    c.bump(); // the r
    let mut fence = 0usize;
    while c.peek(0) == b'#' {
        fence += 1;
        c.bump();
    }
    c.bump(); // opening quote
    let mut text = String::new();
    loop {
        if c.eof() {
            return text;
        }
        if c.peek(0) == b'"' {
            let mut close = 0usize;
            while close < fence && c.peek(1 + close) == b'#' {
                close += 1;
            }
            if close == fence {
                c.bump();
                for _ in 0..fence {
                    c.bump();
                }
                return text;
            }
        }
        text.push(c.bump() as char);
    }
}

/// Lex an escaped quoted literal (string or char), quotes consumed.
/// Escapes are unwrapped (`\"` keeps the quote, `\n` keeps the `n`) —
/// rules only ever substring-match literal text, never re-parse it.
fn lex_quoted(c: &mut Cursor, quote: u8) -> String {
    c.bump();
    let mut text = String::new();
    while !c.eof() {
        let b = c.peek(0);
        if b == b'\\' {
            c.bump();
            if !c.eof() {
                text.push(c.bump() as char);
            }
        } else if b == quote {
            c.bump();
            break;
        } else {
            text.push(c.bump() as char);
        }
    }
    text
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<(Kind, String)> {
        lex(src).into_iter().map(|t| (t.kind, t.text)).collect()
    }

    fn has(toks: &[(Kind, String)], kind: Kind, text: &str) -> bool {
        toks.iter().any(|(k, t)| *k == kind && t == text)
    }

    #[test]
    fn strings_hide_their_contents_from_rules() {
        let toks = kinds(r#"let s = "unwrap() // not a comment";"#);
        assert!(has(&toks, Kind::Literal, "unwrap() // not a comment"));
        assert!(!toks.iter().any(|(k, _)| *k == Kind::LineComment));
        assert!(!has(&toks, Kind::Ident, "unwrap"));
    }

    #[test]
    fn raw_strings_with_fences_and_embedded_quotes() {
        let src = "let s = r#\"a \"quoted\" panic!()\"#; let t = 1;";
        let toks = kinds(src);
        assert!(has(&toks, Kind::Literal, "a \"quoted\" panic!()"));
        // the lexer resumes cleanly after the closing fence
        assert!(has(&toks, Kind::Ident, "t"));
    }

    #[test]
    fn nested_block_comments_fold_into_one_token() {
        let toks = kinds("a /* outer /* inner */ still outer */ b");
        assert_eq!(toks[0], (Kind::Ident, "a".into()));
        assert_eq!(toks[1].0, Kind::BlockComment);
        assert!(toks[1].1.contains("inner"));
        assert_eq!(toks[2], (Kind::Ident, "b".into()));
    }

    #[test]
    fn char_literals_are_not_lifetimes() {
        let toks = kinds("let c = 'a'; let l: &'static str = x; let e = '\\n';");
        assert!(has(&toks, Kind::Literal, "a"));
        assert!(has(&toks, Kind::Lifetime, "'static"));
        assert!(has(&toks, Kind::Literal, "n"));
    }

    #[test]
    fn line_positions_are_one_based_and_accurate() {
        let toks = lex("ab\n  cd");
        assert_eq!((toks[0].line, toks[0].col), (1, 1));
        assert_eq!((toks[1].line, toks[1].col), (2, 3));
    }

    #[test]
    fn numbers_do_not_swallow_range_dots() {
        let toks = kinds("for i in 0..256 { x(1.5); }");
        assert!(has(&toks, Kind::Num, "0"));
        assert!(has(&toks, Kind::Num, "256"));
        assert!(has(&toks, Kind::Num, "1.5"));
    }

    #[test]
    fn raw_identifiers_lex_as_idents() {
        let toks = kinds("let r#type = 1;");
        assert!(has(&toks, Kind::Ident, "type"));
    }

    #[test]
    fn byte_and_raw_byte_literals() {
        let toks = kinds(r##"let a = b"unwrap"; let b2 = b'x'; let c = br#"todo!()"#;"##);
        assert!(has(&toks, Kind::Literal, "unwrap"));
        assert!(has(&toks, Kind::Literal, "x"));
        assert!(has(&toks, Kind::Literal, "todo!()"));
        assert!(!has(&toks, Kind::Ident, "unwrap"));
    }
}
