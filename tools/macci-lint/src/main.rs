//! CLI: `cargo run -p macci-lint -- [--root <dir>] [--json <path>]`.
//!
//! Exit codes: 0 = clean (suppressions are fine), 1 = unsuppressed
//! findings, 2 = bad usage or I/O failure. CI treats 1 as a hard stop.

use std::path::{Path, PathBuf};
use std::process::ExitCode;

use macci_lint::{lint_tree, LintReport, RULES};

fn main() -> ExitCode {
    let mut root = PathBuf::from(".");
    let mut json: Option<PathBuf> = None;
    let mut args = std::env::args().skip(1);
    while let Some(arg) = args.next() {
        match arg.as_str() {
            "--root" => match args.next() {
                Some(v) => root = PathBuf::from(v),
                None => return usage("--root needs a directory"),
            },
            "--json" => match args.next() {
                Some(v) => json = Some(PathBuf::from(v)),
                None => return usage("--json needs a file path"),
            },
            other => return usage(&format!("unknown argument `{other}`")),
        }
    }

    let report = match lint_tree(&root) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("macci-lint: cannot scan {}: {e}", root.display());
            return ExitCode::from(2);
        }
    };

    for f in &report.findings {
        println!("{}:{}:{}: {}({}): {}", f.file, f.line, f.col, f.rule, f.name, f.message);
    }
    let (nf, ns) = (report.findings.len(), report.suppressed.len());
    println!("macci-lint: {} files, {nf} finding(s), {ns} suppressed", report.files_scanned);

    if let Some(path) = &json {
        if let Err(e) = std::fs::write(path, render_json(&root, &report)) {
            eprintln!("macci-lint: cannot write {}: {e}", path.display());
            return ExitCode::from(2);
        }
    }
    if report.findings.is_empty() {
        ExitCode::SUCCESS
    } else {
        ExitCode::from(1)
    }
}

fn usage(err: &str) -> ExitCode {
    eprintln!("macci-lint: {err}");
    eprintln!("usage: macci-lint [--root <dir>] [--json <path>]");
    ExitCode::from(2)
}

/// Render the machine-readable report (`LINT.json`). Hand-rolled writer
/// — the offline policy rules out a JSON dependency, and the schema is
/// flat enough that escaping strings is the only subtlety.
fn render_json(root: &Path, report: &LintReport) -> String {
    let mut rules = Vec::new();
    for r in RULES {
        let zones: Vec<String> = r.zones.iter().map(|z| format!("\"{}\"", esc(z))).collect();
        rules.push(format!(
            "    {{\"id\": \"{}\", \"name\": \"{}\", \"zones\": [{}]}}",
            r.id,
            r.name,
            zones.join(", ")
        ));
    }
    let mut findings = Vec::new();
    for f in &report.findings {
        findings.push(format!(
            "    {{\"rule\": \"{}\", \"name\": \"{}\", \"file\": \"{}\", \"line\": {}, \
             \"col\": {}, \"message\": \"{}\"}}",
            esc(&f.rule),
            esc(&f.name),
            esc(&f.file),
            f.line,
            f.col,
            esc(&f.message)
        ));
    }
    let mut suppressed = Vec::new();
    for s in &report.suppressed {
        suppressed.push(format!(
            "    {{\"rule\": \"{}\", \"file\": \"{}\", \"line\": {}, \"reason\": \"{}\"}}",
            esc(&s.rule),
            esc(&s.file),
            s.line,
            esc(&s.reason)
        ));
    }
    format!(
        "{{\n  \"version\": 1,\n  \"root\": \"{}\",\n  \"files_scanned\": {},\n  \
         \"rules\": {},\n  \"findings\": {},\n  \"suppressed\": {}\n}}\n",
        esc(&root.display().to_string()),
        report.files_scanned,
        json_array(&rules),
        json_array(&findings),
        json_array(&suppressed)
    )
}

fn json_array(items: &[String]) -> String {
    if items.is_empty() {
        "[]".into()
    } else {
        format!("[\n{}\n  ]", items.join(",\n"))
    }
}

/// Minimal JSON string escaping — paths, reasons, and rule messages.
fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}
