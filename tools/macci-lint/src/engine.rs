//! The module-scoped rule engine: maps files to module paths, masks
//! `#[cfg(test)]` regions, applies suppression pragmas, and runs the six
//! repo rules over the token stream (see DESIGN.md §Static-Analysis).

use std::path::{Path, PathBuf};

use crate::lexer::{lex, Kind, Tok};

/// A rule's identity and the module zones it patrols. `"*"` means every
/// walked module (minus `#[cfg(test)]` regions, which no rule scans).
pub struct RuleInfo {
    pub id: &'static str,
    pub name: &'static str,
    pub zones: &'static [&'static str],
    /// One-line statement of the invariant the rule protects.
    pub invariant: &'static str,
}

const R1_ZONES: &[&str] = &[
    "coordinator::wire",
    "coordinator::server",
    "coordinator::executor",
    "coordinator::shard",
    "coordinator::offload_cache",
    "loadgen",
    "transport",
];
const R5_ZONES: &[&str] =
    &["runtime::native::simd", "runtime::native::gemm", "runtime::native::quant8"];

/// The rule set, in report order.
pub const RULES: &[RuleInfo] = &[
    RuleInfo {
        id: "R1",
        name: "no-panic",
        zones: R1_ZONES,
        invariant: "hostile or truncated input can never panic the serving path",
    },
    RuleInfo {
        id: "R2",
        name: "determinism",
        zones: &["runtime::native", "rl"],
        invariant: "bit-exact kernels: no FMA/mul_add, no unordered map iteration",
    },
    RuleInfo {
        id: "R3",
        name: "bounded-channels",
        zones: &["coordinator", "loadgen", "transport"],
        invariant: "every queue has a depth bound (or a reviewed pragma)",
    },
    RuleInfo {
        id: "R4",
        name: "env-config",
        zones: &["*"],
        invariant: "env knobs latch once, in util::config only",
    },
    RuleInfo {
        id: "R5",
        name: "unsafe-safety",
        zones: R5_ZONES,
        invariant: "every unsafe site documents why it is sound",
    },
    RuleInfo {
        id: "R6",
        name: "named-threads",
        zones: &["*"],
        invariant: "every thread has a name for debuggable supervision",
    },
];

/// One unsuppressed violation.
#[derive(Debug, Clone)]
pub struct Finding {
    /// Rule id (`"R1"`) — or `"pragma"` for a malformed pragma itself.
    pub rule: String,
    /// Rule kebab name (`"no-panic"`).
    pub name: String,
    pub file: String,
    pub line: u32,
    pub col: u32,
    pub message: String,
}

/// A violation silenced by a `// lint: allow(<rule>) — <reason>` pragma.
#[derive(Debug, Clone)]
pub struct Suppressed {
    pub rule: String,
    pub file: String,
    pub line: u32,
    pub reason: String,
}

/// Everything one lint run produced.
#[derive(Debug, Default)]
pub struct LintReport {
    pub findings: Vec<Finding>,
    pub suppressed: Vec<Suppressed>,
    pub files_scanned: usize,
}

struct Pragma {
    rule: String,
    reason: String,
    /// Lines this pragma covers: its own, and the next line with code.
    covers: (u32, u32),
}

/// A rule hit before suppression/test-mask filtering.
type Raw = (&'static RuleInfo, u32, u32, String);

/// Lint one file's source, attributed to `module` (e.g.
/// `"coordinator::wire"`, `"tests::proptests"`). Exposed so the fixture
/// tests can claim zone membership for synthetic sources.
pub fn lint_source(module: &str, file: &str, src: &str) -> LintReport {
    let toks = lex(src);
    let code: Vec<&Tok> = toks.iter().filter(|t| is_code(t)).collect();
    let test_spans = test_mod_spans(&code);
    let in_tests = |line: u32| test_spans.iter().any(|&(a, b)| a <= line && line <= b);

    let mut report = LintReport { files_scanned: 1, ..Default::default() };
    let mut pragmas = Vec::new();
    for t in toks.iter().filter(|t| !is_code(t)) {
        match parse_pragma(t, &code) {
            Ok(Some(p)) => pragmas.push(p),
            Ok(None) => {}
            Err(msg) => report.findings.push(Finding {
                rule: "pragma".into(),
                name: "pragma".into(),
                file: file.into(),
                line: t.line,
                col: t.col,
                message: msg,
            }),
        }
    }

    let mut raw: Vec<Raw> = Vec::new();
    rule_no_panic(module, &code, &mut raw);
    rule_determinism(module, &code, &mut raw);
    rule_bounded_channels(module, &code, &mut raw);
    rule_env_config(&code, &mut raw);
    rule_unsafe_safety(module, &toks, &code, &mut raw);
    rule_named_threads(module, &code, &mut raw);

    for (rule, line, col, message) in raw {
        if in_tests(line) {
            continue;
        }
        let pragma = pragmas
            .iter()
            .find(|p| (p.rule == rule.id || p.rule == rule.name) && covers(p, line));
        match pragma {
            Some(p) => report.suppressed.push(Suppressed {
                rule: rule.id.into(),
                file: file.into(),
                line,
                reason: p.reason.clone(),
            }),
            None => report.findings.push(Finding {
                rule: rule.id.into(),
                name: rule.name.into(),
                file: file.into(),
                line,
                col,
                message,
            }),
        }
    }
    report
}

/// The directories the linter walks, with the module-path prefix each
/// one contributes.
const ROOTS: [(&str, &str); 4] = [
    ("rust/src", ""),
    ("rust/tests", "tests"),
    ("rust/benches", "benches"),
    ("examples", "examples"),
];

/// Lint the whole repo at `root`.
pub fn lint_tree(root: &Path) -> std::io::Result<LintReport> {
    let mut report = LintReport::default();
    for (dir, prefix) in ROOTS {
        let base = root.join(dir);
        if !base.is_dir() {
            continue;
        }
        let mut files = Vec::new();
        collect_rs(&base, &mut files)?;
        files.sort();
        for f in files {
            let src = std::fs::read_to_string(&f)?;
            let module = module_path(&base, prefix, &f);
            let rel = f.strip_prefix(root).unwrap_or(&f);
            let label = rel.to_string_lossy().replace('\\', "/");
            let one = lint_source(&module, &label, &src);
            report.findings.extend(one.findings);
            report.suppressed.extend(one.suppressed);
            report.files_scanned += 1;
        }
    }
    Ok(report)
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let path = entry?.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// `rust/src/coordinator/wire.rs` → `coordinator::wire`;
/// `rust/src/lib.rs` → `` (crate root); `rust/tests/proptests.rs` →
/// `tests::proptests`; `mod.rs` files collapse onto their directory.
fn module_path(base: &Path, prefix: &str, file: &Path) -> String {
    let rel = file.strip_prefix(base).unwrap_or(file);
    let mut parts: Vec<String> = Vec::new();
    if !prefix.is_empty() {
        parts.push(prefix.to_string());
    }
    for comp in rel.components() {
        parts.push(comp.as_os_str().to_string_lossy().to_string());
    }
    if let Some(last) = parts.last_mut() {
        *last = last.trim_end_matches(".rs").to_string();
        if *last == "mod" || *last == "lib" {
            parts.pop();
        }
    }
    parts.join("::")
}

fn is_code(t: &Tok) -> bool {
    t.kind != Kind::LineComment && t.kind != Kind::BlockComment
}

fn zone_match(module: &str, zones: &[&str]) -> bool {
    let sub_of = |z: &str| module == z || module.starts_with(&format!("{z}::"));
    zones.iter().any(|z| *z == "*" || sub_of(z))
}

fn covers(p: &Pragma, line: u32) -> bool {
    line == p.covers.0 || line == p.covers.1
}

/// Parse `lint: allow(<rule>) — <reason>` out of a comment token.
/// `Ok(None)`: not a pragma at all. `Err`: a pragma with no reason —
/// itself a finding, since unreviewable suppressions are exactly what
/// the mandatory-reason policy exists to prevent.
fn parse_pragma(t: &Tok, code: &[&Tok]) -> Result<Option<Pragma>, String> {
    let Some(at) = t.text.find("lint: allow(") else {
        return Ok(None);
    };
    let rest = &t.text[at + "lint: allow(".len()..];
    let Some(close) = rest.find(')') else {
        return Err("malformed pragma: missing `)` after the rule name".into());
    };
    let rule = rest[..close].trim().to_string();
    let mut reason = rest[close + 1..].trim_start();
    let mut separated = false;
    for sep in ["—", "--", "-"] {
        if let Some(r) = reason.strip_prefix(sep) {
            reason = r.trim_start();
            separated = true;
            break;
        }
    }
    if !separated || reason.is_empty() {
        return Err(format!(
            "pragma for `{rule}` has no reason: write `// lint: allow({rule}) — <why>`"
        ));
    }
    let next_code = code.iter().map(|c| c.line).find(|&l| l > t.line);
    Ok(Some(Pragma {
        rule,
        reason: reason.to_string(),
        covers: (t.line, next_code.unwrap_or(t.line)),
    }))
}

/// Line spans (inclusive) of every `#[cfg(test)] mod <name> { ... }` —
/// no rule fires inside them: tests may unwrap, panic, and index freely.
fn test_mod_spans(code: &[&Tok]) -> Vec<(u32, u32)> {
    let mut spans = Vec::new();
    let mut i = 0;
    while i < code.len() {
        if !is_cfg_test_attr(code, i) {
            i += 1;
            continue;
        }
        // skip this and any stacked attributes, then expect `mod name {`
        let mut j = i;
        while punct(code, j) == Some('#') && punct(code, j + 1) == Some('[') {
            match skip_attr(code, j) {
                Some(next) => j = next,
                None => return spans,
            }
        }
        if ident(code, j) != Some("mod") || punct(code, j + 2) != Some('{') {
            i += 1;
            continue;
        }
        let start = code[i].line;
        let mut end = code.last().map(|t| t.line).unwrap_or(start);
        let mut depth = 0usize;
        let mut k = j + 2;
        while k < code.len() {
            match punct(code, k) {
                Some('{') => depth += 1,
                Some('}') => {
                    depth -= 1;
                    if depth == 0 {
                        end = code[k].line;
                        break;
                    }
                }
                _ => {}
            }
            k += 1;
        }
        spans.push((start, end));
        i = k + 1;
    }
    spans
}

/// Given `code[at] == '#'` starting an attribute, return the index just
/// past its closing `]`, or `None` at EOF.
fn skip_attr(code: &[&Tok], at: usize) -> Option<usize> {
    let mut depth = 0usize;
    let mut j = at + 1;
    while j < code.len() {
        match punct(code, j) {
            Some('[') => depth += 1,
            Some(']') => {
                depth -= 1;
                if depth == 0 {
                    return Some(j + 1);
                }
            }
            _ => {}
        }
        j += 1;
    }
    None
}

/// Is `code[i..]` exactly `# [ cfg ( test ) ]`?
fn is_cfg_test_attr(code: &[&Tok], i: usize) -> bool {
    punct(code, i) == Some('#')
        && punct(code, i + 1) == Some('[')
        && ident(code, i + 2) == Some("cfg")
        && punct(code, i + 3) == Some('(')
        && ident(code, i + 4) == Some("test")
        && punct(code, i + 5) == Some(')')
        && punct(code, i + 6) == Some(']')
}

fn punct(code: &[&Tok], i: usize) -> Option<char> {
    code.get(i).filter(|t| t.kind == Kind::Punct).map(|t| t.ch())
}

fn ident<'a>(code: &[&'a Tok], i: usize) -> Option<&'a str> {
    code.get(i).filter(|t| t.kind == Kind::Ident).map(|t| t.text.as_str())
}

/// Keywords that may legitimately precede `[` (slice patterns, array
/// types/literals) — everything else before `[` reads as an index.
fn bracket_keyword(s: &str) -> bool {
    let kws = "as await box break const dyn else for if impl in let loop match \
               mod move mut pub ref return static unsafe use where while yield";
    kws.split_whitespace().any(|k| k == s)
}

fn rule_no_panic(module: &str, code: &[&Tok], out: &mut Vec<Raw>) {
    let rule = &RULES[0];
    if !zone_match(module, rule.zones) {
        return;
    }
    for (i, t) in code.iter().enumerate() {
        if t.kind == Kind::Ident {
            let panicky_method = matches!(
                t.text.as_str(),
                "unwrap" | "expect" | "unwrap_err" | "expect_err" | "unwrap_unchecked"
            );
            // only as a method call (`.unwrap()`), so a struct field or
            // enum variant named `expect` doesn't trip the rule
            if panicky_method && i > 0 && punct(code, i - 1) == Some('.') {
                let msg = format!("`{}()` in a no-panic zone — return a typed error", t.text);
                out.push((rule, t.line, t.col, msg));
            }
            let panicky_macro =
                matches!(t.text.as_str(), "panic" | "unreachable" | "todo" | "unimplemented");
            if panicky_macro && punct(code, i + 1) == Some('!') {
                let msg = format!("`{}!` in a no-panic zone — return a typed error", t.text);
                out.push((rule, t.line, t.col, msg));
            }
        }
        if t.kind == Kind::Punct && t.ch() == '[' && i > 0 {
            let p = code[i - 1];
            let indexes = match p.kind {
                Kind::Ident => !bracket_keyword(&p.text),
                Kind::Punct => matches!(p.ch(), ')' | ']' | '?'),
                _ => false,
            };
            if indexes {
                let msg = "direct indexing in a no-panic zone — use `.get()` or patterns".into();
                out.push((rule, t.line, t.col, msg));
            }
        }
    }
}

fn rule_determinism(module: &str, code: &[&Tok], out: &mut Vec<Raw>) {
    let rule = &RULES[1];
    if !zone_match(module, rule.zones) {
        return;
    }
    for t in code {
        if t.kind != Kind::Ident {
            continue;
        }
        let name = t.text.as_str();
        let fma_intrinsic =
            name.starts_with("_mm") && (name.contains("fmadd") || name.contains("fmsub"));
        if name == "mul_add" || fma_intrinsic {
            let msg = format!("`{name}` fuses the mul-add rounding step — breaks bit-exactness");
            out.push((rule, t.line, t.col, msg));
        }
        if name == "HashMap" || name == "HashSet" {
            let msg = format!("`{name}` iterates in nondeterministic order — use a BTree map/set");
            out.push((rule, t.line, t.col, msg));
        }
    }
}

fn rule_bounded_channels(module: &str, code: &[&Tok], out: &mut Vec<Raw>) {
    let rule = &RULES[2];
    if !zone_match(module, rule.zones) {
        return;
    }
    for (i, t) in code.iter().enumerate() {
        if t.kind != Kind::Ident || t.text != "channel" {
            continue;
        }
        let direct_call = punct(code, i + 1) == Some('(');
        let turbofish = punct(code, i + 1) == Some(':')
            && punct(code, i + 2) == Some(':')
            && punct(code, i + 3) == Some('<');
        if direct_call || turbofish {
            let msg = "unbounded `mpsc::channel()` — use `sync_channel` or a pragma".to_string();
            out.push((rule, t.line, t.col, msg));
        }
    }
}

fn rule_env_config(code: &[&Tok], out: &mut Vec<Raw>) {
    let rule = &RULES[3];
    for (i, t) in code.iter().enumerate() {
        if t.kind != Kind::Ident || (t.text != "var" && t.text != "var_os") {
            continue;
        }
        let env_path = i >= 3
            && punct(code, i - 1) == Some(':')
            && punct(code, i - 2) == Some(':')
            && ident(code, i - 3) == Some("env");
        if env_path {
            let msg = format!("raw `env::{}` — go through util::config accessors", t.text);
            out.push((rule, t.line, t.col, msg));
        }
    }
}

fn rule_unsafe_safety(module: &str, toks: &[Tok], code: &[&Tok], out: &mut Vec<Raw>) {
    let rule = &RULES[4];
    if !zone_match(module, rule.zones) {
        return;
    }
    for t in code {
        if t.kind != Kind::Ident || t.text != "unsafe" {
            continue;
        }
        if !has_safety_comment(toks, code, t.line) {
            let msg = "`unsafe` without a `// SAFETY:` comment for why it is sound".into();
            out.push((rule, t.line, t.col, msg));
        }
    }
}

/// A `// SAFETY:` comment justifies an `unsafe` on `line` if it sits on
/// the same line, or in the contiguous comment/attribute block above it.
fn has_safety_comment(toks: &[Tok], code: &[&Tok], line: u32) -> bool {
    let comment_on =
        |l: u32| toks.iter().any(|t| !is_code(t) && t.line == l && t.text.contains("SAFETY:"));
    if comment_on(line) {
        return true;
    }
    let mut ln = line.saturating_sub(1);
    while ln >= 1 {
        if comment_on(ln) {
            return true;
        }
        // a real code line (not an attribute) ends the block above;
        // attribute, blank, and plain comment lines keep the scan going
        if let Some(t) = code.iter().find(|t| t.line == ln) {
            if t.ch() != '#' {
                return false;
            }
        }
        ln -= 1;
    }
    false
}

fn rule_named_threads(module: &str, code: &[&Tok], out: &mut Vec<Raw>) {
    let rule = &RULES[5];
    let head = module.split("::").next().unwrap_or(module);
    if head == "tests" || head == "benches" {
        return;
    }
    for (i, t) in code.iter().enumerate() {
        let spawn = t.kind == Kind::Ident
            && t.text == "spawn"
            && i >= 3
            && punct(code, i - 1) == Some(':')
            && punct(code, i - 2) == Some(':')
            && ident(code, i - 3) == Some("thread");
        if spawn {
            let msg = "anonymous `thread::spawn` — name it via `Builder::new().name(..)`".into();
            out.push((rule, t.line, t.col, msg));
        }
    }
}
