//! Minimal in-tree stand-in for the `anyhow` crate (offline build — no
//! crates.io; see DESIGN.md §Substitutions).
//!
//! Implements the subset this workspace uses: [`Error`] with a context
//! chain, the [`anyhow!`]/[`bail!`]/[`ensure!`] macros, the [`Context`]
//! extension trait, and `Result<T>` defaulting its error type. Formatting
//! matches the real crate where it matters: `{e}` prints the outermost
//! message, `{e:#}` the full `a: b: c` chain, `{e:?}` a "Caused by" list.

use std::fmt;

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// An error message plus a chain of underlying causes.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

impl Error {
    /// Create an error from a printable message.
    pub fn msg(msg: impl fmt::Display) -> Error {
        Error {
            msg: msg.to_string(),
            source: None,
        }
    }

    /// Wrap this error with an outer context message.
    pub fn context(self, msg: impl fmt::Display) -> Error {
        Error {
            msg: msg.to_string(),
            source: Some(Box::new(self)),
        }
    }

    /// The outermost message (without causes).
    pub fn root_message(&self) -> &str {
        &self.msg
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if f.alternate() {
            let mut cur = self.source.as_deref();
            while let Some(e) = cur {
                write!(f, ": {}", e.msg)?;
                cur = e.source.as_deref();
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if let Some(first) = self.source.as_deref() {
            write!(f, "\n\nCaused by:")?;
            let mut cur = Some(first);
            while let Some(e) = cur {
                write!(f, "\n    {}", e.msg)?;
                cur = e.source.as_deref();
            }
        }
        Ok(())
    }
}

// The same blanket conversion the real crate provides; `Error` deliberately
// does NOT implement `std::error::Error`, which keeps this impl coherent.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut msgs = vec![e.to_string()];
        let mut cur = e.source();
        while let Some(c) = cur {
            msgs.push(c.to_string());
            cur = c.source();
        }
        let mut err = Error::msg(msgs.pop().expect("at least one message"));
        while let Some(m) = msgs.pop() {
            err = err.context(m);
        }
        err
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to results
/// and options.
pub trait Context<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T, E> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("inner {}", 42)
    }

    #[test]
    fn chain_formatting() {
        let e = fails().context("outer").unwrap_err();
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: inner 42");
        assert!(format!("{e:?}").contains("Caused by"));
    }

    #[test]
    fn std_error_conversion() {
        let r: Result<i32> = "zzz".parse::<i32>().map_err(Into::into);
        assert!(r.is_err());
    }

    #[test]
    fn option_context() {
        let x: Option<i32> = None;
        let e = x.context("missing").unwrap_err();
        assert_eq!(format!("{e}"), "missing");
    }

    #[test]
    fn ensure_passes_and_fails() {
        fn check(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            Ok(x)
        }
        assert!(check(1).is_ok());
        assert!(check(-1).is_err());
    }
}
