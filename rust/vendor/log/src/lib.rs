//! Minimal in-tree stand-in for the `log` facade crate (offline build — no
//! crates.io; see DESIGN.md §Substitutions).
//!
//! Provides the `error!`/`warn!`/`info!`/`debug!`/`trace!` macros, the
//! [`Log`] trait, and the global logger/max-level plumbing the `macci`
//! binary's tiny logger uses. Level filtering happens at the call site, so
//! disabled levels cost one atomic load.

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Log levels, in decreasing severity.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Level {
    Error = 1,
    Warn,
    Info,
    Debug,
    Trace,
}

impl fmt::Display for Level {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        write!(f, "{s}")
    }
}

/// Level filter: `Off` plus every [`Level`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LevelFilter {
    Off = 0,
    Error,
    Warn,
    Info,
    Debug,
    Trace,
}

impl PartialEq<LevelFilter> for Level {
    fn eq(&self, other: &LevelFilter) -> bool {
        *self as usize == *other as usize
    }
}

impl PartialOrd<LevelFilter> for Level {
    fn partial_cmp(&self, other: &LevelFilter) -> Option<std::cmp::Ordering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

/// Metadata about a log record (just the level here).
pub struct Metadata {
    level: Level,
}

impl Metadata {
    pub fn level(&self) -> Level {
        self.level
    }
}

/// One log record: level + preformatted arguments.
pub struct Record<'a> {
    metadata: Metadata,
    args: fmt::Arguments<'a>,
}

impl<'a> Record<'a> {
    pub fn level(&self) -> Level {
        self.metadata.level
    }

    pub fn metadata(&self) -> &Metadata {
        &self.metadata
    }

    pub fn args(&self) -> &fmt::Arguments<'a> {
        &self.args
    }
}

/// A log sink.
pub trait Log: Send + Sync {
    fn enabled(&self, metadata: &Metadata) -> bool;
    fn log(&self, record: &Record);
    fn flush(&self);
}

struct NopLogger;

impl Log for NopLogger {
    fn enabled(&self, _m: &Metadata) -> bool {
        false
    }
    fn log(&self, _r: &Record) {}
    fn flush(&self) {}
}

static NOP: NopLogger = NopLogger;
static LOGGER: OnceLock<&'static dyn Log> = OnceLock::new();
static MAX_LEVEL: AtomicUsize = AtomicUsize::new(LevelFilter::Off as usize);

/// Error returned when a logger was already installed.
#[derive(Debug)]
pub struct SetLoggerError(());

impl fmt::Display for SetLoggerError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "a logger is already installed")
    }
}

/// Install the global logger (first caller wins).
pub fn set_logger(logger: &'static dyn Log) -> Result<(), SetLoggerError> {
    LOGGER.set(logger).map_err(|_| SetLoggerError(()))
}

/// The installed logger, or a no-op sink.
pub fn logger() -> &'static dyn Log {
    LOGGER.get().copied().unwrap_or(&NOP)
}

pub fn set_max_level(filter: LevelFilter) {
    MAX_LEVEL.store(filter as usize, Ordering::Relaxed);
}

pub fn max_level() -> LevelFilter {
    match MAX_LEVEL.load(Ordering::Relaxed) {
        0 => LevelFilter::Off,
        1 => LevelFilter::Error,
        2 => LevelFilter::Warn,
        3 => LevelFilter::Info,
        4 => LevelFilter::Debug,
        _ => LevelFilter::Trace,
    }
}

#[doc(hidden)]
pub fn __log(level: Level, args: fmt::Arguments) {
    if level <= max_level() {
        let record = Record {
            metadata: Metadata { level },
            args,
        };
        logger().log(&record);
    }
}

#[macro_export]
macro_rules! log {
    ($lvl:expr, $($arg:tt)+) => {
        $crate::__log($lvl, format_args!($($arg)+))
    };
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Error, $($arg)+) };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Warn, $($arg)+) };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Info, $($arg)+) };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Debug, $($arg)+) };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Trace, $($arg)+) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_vs_filter_ordering() {
        assert!(Level::Error <= LevelFilter::Info);
        assert!(Level::Debug > LevelFilter::Info);
        assert!(Level::Info <= LevelFilter::Trace);
    }

    #[test]
    fn default_filter_is_off() {
        // level filtering happens before the logger is consulted, so with
        // the default Off filter this is a no-op regardless of sink
        __log(Level::Error, format_args!("dropped"));
    }
}
