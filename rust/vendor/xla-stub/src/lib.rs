//! API-compatible stub of the `xla` crate (PJRT bindings).
//!
//! The offline build has no crates.io and no `xla_extension` shared library,
//! but the `xla-pjrt` cargo feature must stay *compilable* so the PJRT
//! execution path in `runtime::client` does not rot. This stub mirrors the
//! slice of the real crate's API that path uses; host-side [`Literal`]
//! handling is implemented for real, while every PJRT entry point
//! (`PjRtClient::cpu`, `compile`, `execute`, …) returns an error at
//! runtime. Deployments with the real `xla` crate point the workspace's
//! `xla` path dependency at it instead (see DESIGN.md §Substitutions).

use std::borrow::Borrow;
use std::path::Path;

/// Error type matching the real crate's `xla::Error` role.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what}: PJRT is unavailable in the offline build (in-tree `xla` stub; point the \
         workspace's `xla` path dependency at the real crate to execute HLO artifacts)"
    )))
}

/// Element types the runtime boundary uses (plus enough of the rest of the
/// real crate's enum that exhaustive matches need a catch-all, as they do
/// against the real API).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S8,
    S16,
    S32,
    S64,
    U8,
    U16,
    U32,
    U64,
    F16,
    F32,
    F64,
    Bf16,
    C64,
    C128,
}

#[derive(Clone, Debug)]
enum Payload {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

/// A host literal: dense f32/i32 data plus dimensions, or a tuple.
#[derive(Clone, Debug)]
pub struct Literal {
    payload: Payload,
    dims: Vec<i64>,
}

/// Shape of an array literal.
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

/// Rust scalar types that map onto XLA element types.
pub trait NativeType: Copy {
    const TY: ElementType;
    fn wrap(v: Vec<Self>) -> Payload;
    fn unwrap(lit: &Literal) -> Result<Vec<Self>>;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;

    fn wrap(v: Vec<f32>) -> Payload {
        Payload::F32(v)
    }

    fn unwrap(lit: &Literal) -> Result<Vec<f32>> {
        match &lit.payload {
            Payload::F32(v) => Ok(v.clone()),
            _ => Err(Error("literal is not f32".into())),
        }
    }
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;

    fn wrap(v: Vec<i32>) -> Payload {
        Payload::I32(v)
    }

    fn unwrap(lit: &Literal) -> Result<Vec<i32>> {
        match &lit.payload {
            Payload::I32(v) => Ok(v.clone()),
            _ => Err(Error("literal is not i32".into())),
        }
    }
}

impl Literal {
    /// 1-d literal from a slice.
    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        Literal {
            dims: vec![v.len() as i64],
            payload: T::wrap(v.to_vec()),
        }
    }

    /// 0-d f32 literal.
    pub fn scalar(x: f32) -> Literal {
        Literal {
            dims: Vec::new(),
            payload: Payload::F32(vec![x]),
        }
    }

    fn element_count(&self) -> usize {
        match &self.payload {
            Payload::F32(v) => v.len(),
            Payload::I32(v) => v.len(),
            Payload::Tuple(_) => 0,
        }
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let count: i64 = dims.iter().product();
        if count as usize != self.element_count() {
            return Err(Error(format!(
                "cannot reshape {} elements to {dims:?}",
                self.element_count()
            )));
        }
        Ok(Literal {
            payload: self.payload.clone(),
            dims: dims.to_vec(),
        })
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        let ty = match &self.payload {
            Payload::F32(_) => ElementType::F32,
            Payload::I32(_) => ElementType::S32,
            Payload::Tuple(_) => return Err(Error("tuple literal has no array shape".into())),
        };
        Ok(ArrayShape {
            dims: self.dims.clone(),
            ty,
        })
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(self)
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.payload {
            Payload::Tuple(v) => Ok(v),
            _ => Err(Error("literal is not a tuple".into())),
        }
    }
}

/// Stub of the PJRT CPU client.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "xla-stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

/// Stub of a parsed HLO module.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(path: impl AsRef<Path>) -> Result<HloModuleProto> {
        unavailable(&format!(
            "HloModuleProto::from_text_file({})",
            path.as_ref().display()
        ))
    }
}

/// Stub of an XLA computation.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Stub of a loaded executable.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T: Borrow<Literal>>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// Stub of a device buffer.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let lit = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let lit = lit.reshape(&[2, 2]).unwrap();
        let shape = lit.array_shape().unwrap();
        assert_eq!(shape.dims(), &[2, 2]);
        assert_eq!(shape.ty(), ElementType::F32);
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(lit.to_vec::<i32>().is_err());
        assert!(lit.reshape(&[3]).is_err());
    }

    #[test]
    fn pjrt_entry_points_error() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("/nope").is_err());
    }
}
