//! Minimal in-tree stand-in for the `once_cell` crate (offline build — no
//! crates.io; see DESIGN.md §Substitutions). Backed by `std::sync::OnceLock`.

pub mod sync {
    use std::ops::Deref;
    use std::sync::OnceLock;

    /// A value initialized on first access, usable in `static`s.
    pub struct Lazy<T, F = fn() -> T> {
        cell: OnceLock<T>,
        init: F,
    }

    impl<T, F> Lazy<T, F> {
        pub const fn new(init: F) -> Lazy<T, F> {
            Lazy {
                cell: OnceLock::new(),
                init,
            }
        }
    }

    impl<T, F: Fn() -> T> Lazy<T, F> {
        pub fn force(this: &Lazy<T, F>) -> &T {
            this.cell.get_or_init(|| (this.init)())
        }
    }

    impl<T, F: Fn() -> T> Deref for Lazy<T, F> {
        type Target = T;

        fn deref(&self) -> &T {
            Lazy::force(self)
        }
    }

    #[cfg(test)]
    mod tests {
        use super::*;

        static COUNTER: Lazy<u64> = Lazy::new(|| 41 + 1);

        #[test]
        fn initializes_once() {
            assert_eq!(*COUNTER, 42);
            assert_eq!(*COUNTER, 42);
        }
    }
}
