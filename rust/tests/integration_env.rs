//! Environment integration over the real paper-scale device profiles.
//! Requires artifacts/profiles (run `make artifacts-rl` at minimum).

use macci::env::mdp::MultiAgentEnv;
use macci::env::scenario::ScenarioConfig;
use macci::env::{Action, HybridAction};
use macci::profiles::DeviceProfile;
use macci::rl::baselines::{evaluate_policy, BaselinePolicy, PolicyKind};
use macci::util::check::forall;
use macci::util::rng::Rng;

fn profile() -> Option<DeviceProfile> {
    let p = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts/profiles/resnet18.json");
    if !p.exists() {
        eprintln!("skipping: no profiles");
        return None;
    }
    Some(DeviceProfile::load(p).unwrap())
}

#[test]
fn local_policy_reproduces_profile_anchors() {
    let Some(profile) = profile() else { return };
    let cfg = ScenarioConfig {
        n_ues: 3,
        eval_mode: true,
        eval_tasks: 20,
        ..Default::default()
    };
    let full_t = profile.full_local_t;
    let full_e = profile.full_local_e;
    let mut env = MultiAgentEnv::new(profile, cfg, 1).unwrap();
    let mut p = BaselinePolicy::new(PolicyKind::Local, 0);
    let stats = evaluate_policy(&mut p, &mut env, 1).unwrap();
    assert!((stats.avg_latency - full_t).abs() < 1e-9);
    assert!((stats.avg_energy - full_e).abs() < 1e-9);
}

#[test]
fn energy_accounting_conserved_under_random_policies() {
    // frame-level E_t sums must equal the per-task totals at episode end
    // (no energy is lost or double-counted), for arbitrary action streams
    let Some(profile) = profile() else { return };
    forall(
        7,
        12,
        |g| g.rng.next_u64(),
        |&seed| {
            let cfg = ScenarioConfig {
                n_ues: 3,
                lambda_tasks: 8.0,
                ..Default::default()
            };
            let mut env = MultiAgentEnv::new(profile.clone(), cfg, seed).unwrap();
            let mut rng = Rng::new(seed ^ 0xabc);
            let mut frame_energy_sum = 0.0;
            let mut frames = 0;
            while !env.done() && frames < 5000 {
                let a: Action = (0..3)
                    .map(|_| {
                        HybridAction::new(
                            rng.below(env.profile.n_choices),
                            rng.below(2),
                            rng.normal() as f32,
                            1.0,
                        )
                    })
                    .collect();
                let r = env.step(&a);
                frame_energy_sum += r.info.energy;
                frames += 1;
            }
            let totals = env.totals();
            // all tasks completed => per-task energy sum == frame energy sum
            if env.done() && frames < 5000 {
                let diff = (totals.energy_sum - frame_energy_sum).abs();
                if diff > 1e-6 * frame_energy_sum.max(1.0) {
                    return Err(format!(
                        "energy mismatch: tasks {} vs frames {}",
                        totals.energy_sum, frame_energy_sum
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn latency_lower_bound_is_profile_compute_time() {
    // no task can finish faster than its decision's compute time
    let Some(profile) = profile() else { return };
    let cfg = ScenarioConfig {
        n_ues: 2,
        eval_mode: true,
        eval_tasks: 10,
        ..Default::default()
    };
    let min_t = profile.entry(1).t_f + profile.entry(1).t_c;
    let mut env = MultiAgentEnv::new(profile, cfg, 5).unwrap();
    let acts: Action = (0..2).map(|i| HybridAction::new(1, i, 3.0, 1.0)).collect();
    let mut frames = 0;
    while !env.done() && frames < 10_000 {
        env.step(&acts);
        frames += 1;
    }
    let t = env.totals();
    assert!(t.completed > 0);
    assert!(
        t.avg_latency() >= min_t,
        "avg latency {} below compute floor {min_t}",
        t.avg_latency()
    );
}

#[test]
fn more_ues_same_channels_is_never_faster() {
    // fixed-split offloading with more co-channel UEs must not reduce
    // average latency (monotone interference)
    let Some(profile) = profile() else { return };
    let avg = |n: usize| {
        let cfg = ScenarioConfig {
            n_ues: n,
            eval_mode: true,
            eval_tasks: 20,
            ..Default::default()
        };
        let mut env = MultiAgentEnv::new(profile.clone(), cfg, 3).unwrap();
        let acts: Action = (0..n).map(|_| HybridAction::new(1, 0, 2.0, 1.0)).collect();
        let mut frames = 0;
        while !env.done() && frames < 20_000 {
            env.step(&acts);
            frames += 1;
        }
        env.totals().avg_latency()
    };
    let a2 = avg(2);
    let a5 = avg(5);
    assert!(
        a5 >= a2 * 0.99,
        "5 UEs ({a5}) should not beat 2 UEs ({a2}) on one channel"
    );
}

#[test]
fn beta_zero_reward_counts_only_time() {
    let Some(profile) = profile() else { return };
    let cfg = ScenarioConfig {
        n_ues: 2,
        beta: 0.0,
        lambda_tasks: 5.0,
        ..Default::default()
    };
    let mut env = MultiAgentEnv::new(profile.clone(), cfg, 9).unwrap();
    let acts: Action = (0..2)
        .map(|_| HybridAction::new(profile.local_choice(), 0, 0.0, 1.0))
        .collect();
    let r = env.step(&acts);
    let k = r.info.completed.max(1) as f64;
    assert!((r.reward - (-0.5 / k)).abs() < 1e-12);
}
