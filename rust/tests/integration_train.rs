//! End-to-end MAHPPO training through the artifact executables: short runs
//! that verify learning actually happens (reward improves over the
//! random-init policy) and that the full Algorithm-1 loop holds together.
//!
//! Runs on whatever backend `ArtifactStore::open` resolves — the native
//! interpreter with the built-in demo manifest on a fresh offline checkout,
//! the compiled artifacts when they exist.

use macci::env::scenario::ScenarioConfig;
use macci::profiles::DeviceProfile;
use macci::rl::mahppo::{MahppoTrainer, TrainConfig};
use macci::runtime::artifacts::ArtifactStore;

fn setup() -> Option<(ArtifactStore, DeviceProfile)> {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let store = ArtifactStore::open(&root).unwrap();
    let prof_path = root.join("profiles/resnet18.json");
    let profile = if prof_path.exists() {
        DeviceProfile::load(prof_path).unwrap()
    } else {
        DeviceProfile::synthetic()
    };
    Some((store, profile))
}

#[test]
fn short_training_run_completes_and_logs() {
    let Some((store, profile)) = setup() else { return };
    let scenario = ScenarioConfig {
        n_ues: 3,
        lambda_tasks: 20.0,
        ..Default::default()
    };
    let cfg = TrainConfig {
        buffer_size: 128,
        minibatch: 256, // falls back? no — must exist: use 256-batch artifacts
        ..Default::default()
    };
    // minibatch must match an AOT update artifact; 256 > buffer 128 is
    // invalid, so use 256/256
    let cfg = TrainConfig {
        buffer_size: 256,
        minibatch: 256,
        reuse: 2,
        ..cfg
    };
    let mut t = MahppoTrainer::new(&store, &profile, scenario, cfg).unwrap();
    let report = t.train(600).unwrap();
    assert!(report.frames >= 600);
    assert!(report.episodes > 0);
    assert!(!report.value_losses.ys.is_empty());
    assert!(report.value_losses.ys.iter().all(|v| v.is_finite()));
    assert!(report.entropies.ys.iter().all(|e| e.is_finite() && *e > 0.0));
}

#[test]
fn training_improves_over_initial_policy() {
    let Some((store, profile)) = setup() else { return };
    let scenario = ScenarioConfig {
        n_ues: 3,
        lambda_tasks: 30.0,
        ..Default::default()
    };
    let cfg = TrainConfig {
        buffer_size: 512,
        minibatch: 256,
        reuse: 6,
        lr: 3e-4,
        seed: 5,
        ..Default::default()
    };
    let mut t = MahppoTrainer::new(&store, &profile, scenario, cfg).unwrap();
    let report = t.train(2500).unwrap();
    let ys = &report.episode_rewards.ys;
    assert!(ys.len() >= 10, "need enough episodes, got {}", ys.len());
    let head: f64 = ys[..5].iter().sum::<f64>() / 5.0;
    let tail: f64 = ys[ys.len() - 5..].iter().sum::<f64>() / 5.0;
    assert!(
        tail > head,
        "reward should improve: first5 {head:.2} -> last5 {tail:.2}"
    );
}

#[test]
fn greedy_eval_runs_and_is_deterministic() {
    let Some((store, profile)) = setup() else { return };
    let scenario = ScenarioConfig {
        n_ues: 3,
        lambda_tasks: 15.0,
        eval_mode: true,
        eval_tasks: 15,
        ..Default::default()
    };
    let cfg = TrainConfig {
        buffer_size: 256,
        minibatch: 256,
        reuse: 2,
        ..Default::default()
    };
    let mut t = MahppoTrainer::new(&store, &profile, scenario, cfg).unwrap();
    let a = t.evaluate(1).unwrap();
    let b = t.evaluate(1).unwrap();
    assert!((a.avg_latency - b.avg_latency).abs() < 1e-12);
    assert!((a.avg_energy - b.avg_energy).abs() < 1e-12);
    assert!(a.avg_latency > 0.0 && a.avg_energy > 0.0);
}

#[test]
fn fig9_batch_matrix_artifacts_usable() {
    // |M| in {512, 1024, 2048} with B = |M|/4 must all train one round
    let Some((store, profile)) = setup() else { return };
    for mem in [512usize, 2048] {
        let scenario = ScenarioConfig {
            n_ues: 5,
            lambda_tasks: 20.0,
            ..Default::default()
        };
        let cfg = TrainConfig {
            buffer_size: mem,
            minibatch: mem / 4,
            reuse: 1,
            ..Default::default()
        };
        let mut t = MahppoTrainer::new(&store, &profile, scenario, cfg).unwrap();
        let report = t.train(mem).unwrap();
        assert!(
            !report.value_losses.ys.is_empty(),
            "|M|={mem} should complete a PPO round"
        );
    }
}
