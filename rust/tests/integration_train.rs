//! End-to-end MAHPPO training through the artifact executables: short runs
//! that verify learning actually happens (reward improves over the
//! random-init policy) and that the full Algorithm-1 loop holds together.
//!
//! Runs on whatever backend `ArtifactStore::open` resolves — the native
//! interpreter with the built-in demo manifest on a fresh offline checkout,
//! the compiled artifacts when they exist.

use macci::env::mdp::MultiAgentEnv;
use macci::env::scenario::{ScenarioConfig, ScenarioDistribution};
use macci::env::{Action, HybridAction};
use macci::metrics::Series;
use macci::profiles::DeviceProfile;
use macci::rl::buffer::{TrajectoryBuffer, Transition};
use macci::rl::mahppo::{MahppoTrainer, TrainConfig, TrainReport};
use macci::rl::sampling;
use macci::runtime::artifacts::ArtifactStore;
use macci::runtime::nets::{ActorNet, CriticNet};
use macci::util::rng::Rng;

fn setup() -> Option<(ArtifactStore, DeviceProfile)> {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let store = ArtifactStore::open(&root).unwrap();
    let prof_path = root.join("profiles/resnet18.json");
    let profile = if prof_path.exists() {
        DeviceProfile::load(prof_path).unwrap()
    } else {
        DeviceProfile::synthetic()
    };
    Some((store, profile))
}

#[test]
fn short_training_run_completes_and_logs() {
    let Some((store, profile)) = setup() else { return };
    let scenario = ScenarioConfig {
        n_ues: 3,
        lambda_tasks: 20.0,
        ..Default::default()
    };
    let cfg = TrainConfig {
        buffer_size: 128,
        minibatch: 256, // falls back? no — must exist: use 256-batch artifacts
        ..Default::default()
    };
    // minibatch must match an AOT update artifact; 256 > buffer 128 is
    // invalid, so use 256/256
    let cfg = TrainConfig {
        buffer_size: 256,
        minibatch: 256,
        reuse: 2,
        ..cfg
    };
    let mut t = MahppoTrainer::new(&store, &profile, scenario, cfg).unwrap();
    let report = t.train(600).unwrap();
    assert!(report.frames >= 600);
    assert!(report.episodes > 0);
    assert!(!report.value_losses.ys.is_empty());
    assert!(report.value_losses.ys.iter().all(|v| v.is_finite()));
    assert!(report.entropies.ys.iter().all(|e| e.is_finite() && *e > 0.0));
}

#[test]
fn training_improves_over_initial_policy() {
    let Some((store, profile)) = setup() else { return };
    let scenario = ScenarioConfig {
        n_ues: 3,
        lambda_tasks: 30.0,
        ..Default::default()
    };
    let cfg = TrainConfig {
        buffer_size: 512,
        minibatch: 256,
        reuse: 6,
        lr: 3e-4,
        seed: 5,
        ..Default::default()
    };
    let mut t = MahppoTrainer::new(&store, &profile, scenario, cfg).unwrap();
    let report = t.train(2500).unwrap();
    let ys = &report.episode_rewards.ys;
    assert!(ys.len() >= 10, "need enough episodes, got {}", ys.len());
    let head: f64 = ys[..5].iter().sum::<f64>() / 5.0;
    let tail: f64 = ys[ys.len() - 5..].iter().sum::<f64>() / 5.0;
    assert!(
        tail > head,
        "reward should improve: first5 {head:.2} -> last5 {tail:.2}"
    );
}

#[test]
fn greedy_eval_runs_and_is_deterministic() {
    let Some((store, profile)) = setup() else { return };
    let scenario = ScenarioConfig {
        n_ues: 3,
        lambda_tasks: 15.0,
        eval_mode: true,
        eval_tasks: 15,
        ..Default::default()
    };
    let cfg = TrainConfig {
        buffer_size: 256,
        minibatch: 256,
        reuse: 2,
        ..Default::default()
    };
    let mut t = MahppoTrainer::new(&store, &profile, scenario, cfg).unwrap();
    let a = t.evaluate(1).unwrap();
    let b = t.evaluate(1).unwrap();
    assert!((a.avg_latency - b.avg_latency).abs() < 1e-12);
    assert!((a.avg_energy - b.avg_energy).abs() < 1e-12);
    assert!(a.avg_latency > 0.0 && a.avg_energy > 0.0);
}

/// The PRE-REFACTOR serial MAHPPO loop, reproduced verbatim from the old
/// `MahppoTrainer::train` against the public API. The vectorized trainer
/// at `n_envs = 1` with a fixed scenario must match it bit-for-bit.
fn reference_serial_train(
    store: &ArtifactStore,
    profile: &DeviceProfile,
    scenario: ScenarioConfig,
    cfg: &TrainConfig,
    total_frames: usize,
) -> TrainReport {
    let n = scenario.n_ues;
    let mut env = MultiAgentEnv::new(profile.clone(), scenario, cfg.seed).unwrap();
    let mut actors: Vec<ActorNet> = (0..n)
        .map(|i| ActorNet::new(store, n, cfg.actor_seed(i)).unwrap())
        .collect();
    let mut critic = CriticNet::new(store, n, cfg.critic_seed()).unwrap();
    let mut rng = Rng::new(cfg.sampler_seed());
    let mut buf = TrajectoryBuffer::new(cfg.buffer_size, n);

    let mut report = TrainReport::default();
    report.episode_rewards = Series::new("episode_reward");
    report.value_losses = Series::new("value_loss");
    report.entropies = Series::new("entropy");
    report.clip_fracs = Series::new("clip_frac");

    let mut state = env.reset();
    let mut ep_reward = 0.0f64;
    let mut frames = 0usize;
    while frames < total_frames {
        while !buf.is_full() {
            // the old `act`: per-actor B=1 forward, then sample
            let n_choices = env.profile.n_choices;
            let p_max = env.cfg.p_max;
            let mut action: Action = Vec::with_capacity(n);
            let (mut a_b, mut a_c, mut a_p, mut log_prob) =
                (Vec::new(), Vec::new(), Vec::new(), Vec::new());
            for actor in actors.iter_mut() {
                let out = actor.forward(&state).unwrap();
                let s = sampling::sample_hybrid(&out, &mut rng);
                action.push(HybridAction::new(s.b.min(n_choices - 1), s.c, s.p_raw, p_max));
                a_b.push(s.b as i32);
                a_c.push(s.c as i32);
                a_p.push(s.p_raw);
                log_prob.push(s.log_prob);
            }
            let value = critic.value(&state).unwrap();
            let r = env.step(&action);
            ep_reward += r.reward;
            frames += 1;
            buf.push(Transition {
                state: std::mem::take(&mut state),
                a_b,
                a_c,
                a_p,
                log_prob,
                reward: r.reward,
                value,
                done: r.done,
            });
            if r.done {
                report.episode_rewards.push(report.episodes as f64, ep_reward);
                report.episodes += 1;
                ep_reward = 0.0;
                state = env.reset();
            } else {
                state = r.state;
            }
        }
        let bootstrap = critic.value(&state).unwrap() as f64;
        buf.finish(cfg.gamma, cfg.lam, bootstrap, cfg.normalize_adv);
        let rounds = cfg.reuse * (cfg.buffer_size / cfg.minibatch).max(1);
        let (mut vl, mut en, mut cl) = (0.0f64, 0.0f64, 0.0f64);
        for _ in 0..rounds {
            let mb = buf.sample_minibatch(cfg.minibatch, &mut rng);
            vl += critic.update(cfg.lr, &mb.states, &mb.returns).unwrap() as f64;
            // f32 accumulation across actors, as in `update_actors`
            let (mut ent, mut clip) = (0.0f32, 0.0f32);
            for (u, actor) in actors.iter_mut().enumerate() {
                let stats = actor
                    .update(
                        cfg.lr,
                        &mb.states,
                        &mb.a_b[u],
                        &mb.a_c[u],
                        &mb.a_p[u],
                        &mb.old_logp[u],
                        &mb.adv,
                    )
                    .unwrap();
                ent += stats.entropy;
                clip += stats.clip_frac;
            }
            en += (ent / n as f32) as f64;
            cl += (clip / n as f32) as f64;
        }
        let r = rounds as f64;
        report.value_losses.push(frames as f64, vl / r);
        report.entropies.push(frames as f64, en / r);
        report.clip_fracs.push(frames as f64, cl / r);
        buf.clear();
    }
    report.frames = frames;
    report
}

fn assert_reports_identical(a: &TrainReport, b: &TrainReport, what: &str) {
    assert_eq!(a.frames, b.frames, "{what}: frames");
    assert_eq!(a.episodes, b.episodes, "{what}: episodes");
    assert_eq!(a.episode_rewards.xs, b.episode_rewards.xs, "{what}: episode xs");
    assert_eq!(a.episode_rewards.ys, b.episode_rewards.ys, "{what}: episode rewards");
    assert_eq!(a.value_losses.ys, b.value_losses.ys, "{what}: value losses");
    assert_eq!(a.entropies.ys, b.entropies.ys, "{what}: entropies");
    assert_eq!(a.clip_fracs.ys, b.clip_fracs.ys, "{what}: clip fracs");
}

#[test]
fn vectorized_n_envs_1_reproduces_serial_trainer_bit_for_bit() {
    let Some((store, profile)) = setup() else { return };
    let scenario = ScenarioConfig {
        n_ues: 3,
        lambda_tasks: 12.0,
        ..Default::default()
    };
    let cfg = TrainConfig {
        buffer_size: 256,
        minibatch: 256,
        reuse: 2,
        seed: 31,
        ..Default::default()
    };
    let reference = reference_serial_train(&store, &profile, scenario.clone(), &cfg, 512);
    let mut t = MahppoTrainer::new(&store, &profile, scenario, cfg).unwrap();
    let vectorized = t.train(512).unwrap();
    assert!(reference.episodes > 0, "need episodes for a meaningful check");
    assert_reports_identical(&reference, &vectorized, "serial-vs-n_envs=1");
}

#[test]
fn vectorized_training_is_deterministic_and_thread_invariant() {
    // same seed + scenario => identical TrainReport, and the worker-thread
    // count must not change a single value
    let Some((store, profile)) = setup() else { return };
    let scenario = ScenarioConfig {
        n_ues: 3,
        lambda_tasks: 12.0,
        ..Default::default()
    };
    let mk = |threads: usize| {
        let cfg = TrainConfig {
            buffer_size: 256,
            minibatch: 256,
            reuse: 1,
            seed: 77,
            n_envs: 4,
            rollout_threads: threads,
            ..Default::default()
        };
        let mut t = MahppoTrainer::new(&store, &profile, scenario.clone(), cfg).unwrap();
        t.train(512).unwrap()
    };
    let a = mk(2);
    let b = mk(2);
    assert_reports_identical(&a, &b, "same-seed determinism");
    let c = mk(1);
    assert_reports_identical(&a, &c, "thread invariance");
}

#[test]
fn evaluation_does_not_perturb_training_streams() {
    // train -> eval -> train must equal train -> train
    let Some((store, profile)) = setup() else { return };
    let scenario = ScenarioConfig {
        n_ues: 3,
        lambda_tasks: 12.0,
        ..Default::default()
    };
    let cfg = TrainConfig {
        buffer_size: 256,
        minibatch: 256,
        reuse: 1,
        seed: 13,
        ..Default::default()
    };
    let mut with_eval =
        MahppoTrainer::new(&store, &profile, scenario.clone(), cfg.clone()).unwrap();
    let mut without = MahppoTrainer::new(&store, &profile, scenario, cfg).unwrap();
    let a1 = with_eval.train(256).unwrap();
    let b1 = without.train(256).unwrap();
    assert_reports_identical(&a1, &b1, "first leg");
    let ev1 = with_eval.evaluate(2).unwrap();
    let a2 = with_eval.train(256).unwrap();
    let b2 = without.train(256).unwrap();
    assert_reports_identical(&a2, &b2, "post-eval leg");
    // evaluation itself is reproducible (fresh eval-seeded env every call)
    let ev2 = with_eval.evaluate(2).unwrap();
    assert!((ev1.avg_latency - ev2.avg_latency).abs() < 1e-12);
    assert!((ev1.avg_energy - ev2.avg_energy).abs() < 1e-12);
}

#[test]
fn domain_randomized_training_runs_and_is_deterministic() {
    let Some((store, profile)) = setup() else { return };
    let base = ScenarioConfig {
        n_ues: 3,
        lambda_tasks: 12.0,
        ..Default::default()
    };
    let mk = || {
        let cfg = TrainConfig {
            buffer_size: 256,
            minibatch: 256,
            reuse: 1,
            seed: 5,
            n_envs: 2,
            scenario_dist: Some(ScenarioDistribution::around(base.clone())),
            ..Default::default()
        };
        let mut t = MahppoTrainer::new(&store, &profile, base.clone(), cfg).unwrap();
        t.train(512).unwrap()
    };
    let a = mk();
    let b = mk();
    assert!(a.frames >= 512);
    assert!(a.value_losses.ys.iter().all(|v| v.is_finite()));
    assert_reports_identical(&a, &b, "randomized-scenario determinism");
}

#[test]
fn fig9_batch_matrix_artifacts_usable() {
    // |M| in {512, 1024, 2048} with B = |M|/4 must all train one round
    let Some((store, profile)) = setup() else { return };
    for mem in [512usize, 2048] {
        let scenario = ScenarioConfig {
            n_ues: 5,
            lambda_tasks: 20.0,
            ..Default::default()
        };
        let cfg = TrainConfig {
            buffer_size: mem,
            minibatch: mem / 4,
            reuse: 1,
            ..Default::default()
        };
        let mut t = MahppoTrainer::new(&store, &profile, scenario, cfg).unwrap();
        let report = t.train(mem).unwrap();
        assert!(
            !report.value_losses.ys.is_empty(),
            "|M|={mem} should complete a PPO round"
        );
    }
}

/// The policy-lifecycle acceptance bar: `train(2k)` must produce
/// byte-identical state to `train(1k)` → save → load → `train(1k)` under
/// the same seed — the checkpoint seam captures *everything* (params,
/// Adam moments, step counters, sampler/lane/env RNG streams, mid-episode
/// UE task machines). Covers the serial path and the vectorized engine.
#[test]
fn checkpoint_resume_equals_continuous_training() {
    let Some((store, profile)) = setup() else { return };
    for n_envs in [1usize, 2] {
        let scenario = ScenarioConfig {
            n_ues: 3,
            lambda_tasks: 12.0,
            ..Default::default()
        };
        let cfg = TrainConfig {
            buffer_size: 256,
            minibatch: 256,
            reuse: 1,
            seed: 21,
            n_envs,
            ..Default::default()
        };

        let mut continuous =
            MahppoTrainer::new(&store, &profile, scenario.clone(), cfg.clone()).unwrap();
        continuous.train(512).unwrap();

        let mut half = MahppoTrainer::new(&store, &profile, scenario, cfg).unwrap();
        half.train(256).unwrap();
        let dir = std::env::temp_dir().join(format!(
            "macci_resume_test_{}_{n_envs}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trainer.ckpt");
        half.save(&path).unwrap();
        let mut resumed = MahppoTrainer::load(&store, &path).unwrap();
        resumed.train(256).unwrap();

        // params byte-identical (explicit, for a readable failure)...
        for (u, (a, b)) in continuous.actors.iter().zip(&resumed.actors).enumerate() {
            let pa: Vec<u32> = a.params.iter().map(|x| x.to_bits()).collect();
            let pb: Vec<u32> = b.params.iter().map(|x| x.to_bits()).collect();
            assert_eq!(pa, pb, "E={n_envs}: actor {u} params diverged after resume");
        }
        assert_eq!(
            continuous.critic.params.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            resumed.critic.params.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            "E={n_envs}: critic params diverged after resume"
        );
        // ...and the FULL state matches: both trainers checkpoint to
        // byte-identical files (Adam moments, RNG streams, env state)
        assert_eq!(
            macci::rl::checkpoint::encode(&continuous.checkpoint()).unwrap(),
            macci::rl::checkpoint::encode(&resumed.checkpoint()).unwrap(),
            "E={n_envs}: complete trainer state diverged after resume"
        );

        // in-process continuation is the same stream too:
        // train(256); train(256) on the saved trainer ≡ train(512)
        half.train(256).unwrap();
        assert_eq!(
            macci::rl::checkpoint::encode(&continuous.checkpoint()).unwrap(),
            macci::rl::checkpoint::encode(&half.checkpoint()).unwrap(),
            "E={n_envs}: sequential train() calls diverged from one long call"
        );
        std::fs::remove_dir_all(&dir).ok();
    }
}

/// Resuming mid-training on a DIFFERENT update worker count must still be
/// bit-exact: train 512 frames continuously at `update_threads = 4`
/// versus 256 frames at `update_threads = 1` → checkpoint → rewrite the
/// config to 4 workers → resume → 256 more frames. The sharded update
/// engine's fixed partition + shard-ascending reduction make the worker
/// count a pure wall-time knob, so the final states are byte-identical.
#[test]
fn resume_with_different_update_worker_count_is_bit_exact() {
    let Some((store, profile)) = setup() else { return };
    let scenario = ScenarioConfig {
        n_ues: 3,
        lambda_tasks: 12.0,
        ..Default::default()
    };
    let cfg = TrainConfig {
        buffer_size: 256,
        minibatch: 256,
        reuse: 1,
        seed: 21,
        update_threads: 4,
        ..Default::default()
    };

    let mut continuous =
        MahppoTrainer::new(&store, &profile, scenario.clone(), cfg.clone()).unwrap();
    continuous.train(512).unwrap();

    let mut half = MahppoTrainer::new(
        &store,
        &profile,
        scenario,
        TrainConfig {
            update_threads: 1,
            ..cfg
        },
    )
    .unwrap();
    half.train(256).unwrap();
    // checkpoint at the serial worker count, then hand the resumed run a
    // different one — round-tripped through the wire format so the v2
    // config word is exercised too
    let mut cp = half.checkpoint();
    cp.config.update_threads = 4;
    let cp = macci::rl::checkpoint::decode(&macci::rl::checkpoint::encode(&cp).unwrap()).unwrap();
    let mut resumed = MahppoTrainer::resume(&store, cp).unwrap();
    resumed.train(256).unwrap();

    for (u, (a, b)) in continuous.actors.iter().zip(&resumed.actors).enumerate() {
        let pa: Vec<u32> = a.params.iter().map(|x| x.to_bits()).collect();
        let pb: Vec<u32> = b.params.iter().map(|x| x.to_bits()).collect();
        assert_eq!(pa, pb, "actor {u} params diverged across worker counts");
    }
    assert_eq!(
        macci::rl::checkpoint::encode(&continuous.checkpoint()).unwrap(),
        macci::rl::checkpoint::encode(&resumed.checkpoint()).unwrap(),
        "complete trainer state diverged after resuming on 4 update workers"
    );
}

/// A corrupted or truncated checkpoint file must fail `load` with a typed
/// error — never construct a half-restored trainer.
#[test]
fn trainer_load_rejects_damaged_checkpoints() {
    let Some((store, profile)) = setup() else { return };
    let scenario = ScenarioConfig {
        n_ues: 3,
        lambda_tasks: 12.0,
        ..Default::default()
    };
    let cfg = TrainConfig {
        buffer_size: 256,
        minibatch: 256,
        reuse: 1,
        ..Default::default()
    };
    let trainer = MahppoTrainer::new(&store, &profile, scenario, cfg).unwrap();
    let dir = std::env::temp_dir().join(format!("macci_damaged_ckpt_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("trainer.ckpt");
    trainer.save(&path).unwrap();

    let bytes = std::fs::read(&path).unwrap();
    let bad = dir.join("bad.ckpt");

    std::fs::write(&bad, &bytes[..bytes.len() / 2]).unwrap();
    let err = MahppoTrainer::load(&store, &bad).unwrap_err().to_string();
    assert!(err.contains("truncated"), "unexpected error: {err}");

    let mut flipped = bytes.clone();
    let mid = flipped.len() / 2;
    flipped[mid] ^= 0x40;
    std::fs::write(&bad, &flipped).unwrap();
    let err = MahppoTrainer::load(&store, &bad).unwrap_err().to_string();
    assert!(err.contains("crc mismatch"), "unexpected error: {err}");

    std::fs::remove_dir_all(&dir).ok();
}
