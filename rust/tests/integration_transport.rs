//! Remote-UE serving over the TCP transport: real sockets on loopback,
//! the byte-level wire codec, per-UE session handshake, and the full
//! report → decision → offload → result workflow — plus the NACK path
//! for a malformed (calibration-less) feature offload. Runs fully
//! offline on the synthetic offload compute.

use std::sync::Arc;
use std::time::Duration;

use macci::coordinator::decision::{DecisionMaker, StaticDecision};
use macci::coordinator::executor::{OffloadCompute, SyntheticCompute};
use macci::coordinator::protocol::{Downlink, FrameDecision, UeStateReport};
use macci::coordinator::server::{EdgeServer, ServerConfig};
use macci::coordinator::state_pool::{StateNorm, StatePool};
use macci::coordinator::wire::{encode_frame, read_frame, write_frame, Frame, HEADER_LEN};
use macci::env::HybridAction;
use macci::transport::channel::channel_transport;
use macci::transport::reactor::{ReactorConfig, TcpReactor};
use macci::transport::tcp::{TcpClientTransport, TcpServerTransport};
use macci::transport::ue::UeClient;
use macci::transport::{ClientTransport, ServerTransport};

fn pool(n: usize) -> StatePool {
    StatePool::new(
        n,
        StateNorm {
            lambda_tasks: 10.0,
            frame_s: 0.5,
            max_bits: 1e6,
            d_max: 100.0,
        },
    )
}

fn decisions(n: usize) -> DecisionMaker {
    DecisionMaker::new(Box::new(StaticDecision::new(vec![
        HybridAction::new(0, 0, 0.0, 1.0);
        n
    ])))
}

fn report(ue: usize) -> UeStateReport {
    UeStateReport {
        ue_id: ue,
        tasks_left: 4,
        compute_left_s: 0.0,
        offload_left_bits: 0.0,
        distance_m: 40.0,
    }
}

/// The acceptance scenario: two remote UEs drive handshake → state
/// report → decision broadcast → offload → result against a live TCP
/// server, and one calibration-less feature offload comes back as an
/// `Error` NACK while the session keeps serving.
#[test]
fn tcp_loopback_serves_two_remote_ues() {
    let n = 2;
    let tasks = 4u64;
    let compute = Arc::new(SyntheticCompute::new(Duration::from_micros(100)));
    let elems = compute.image_elems;
    let mut cfg = ServerConfig::new(n, Duration::from_millis(10), usize::MAX);
    cfg.exec.workers = 2;
    cfg.exec.max_wait = Duration::from_micros(500);

    let transport = TcpServerTransport::bind("127.0.0.1:0", n).unwrap();
    let addr = transport.local_addr();
    let compute = Some(compute as Arc<dyn OffloadCompute>);
    let server = EdgeServer::spawn_on(cfg, pool(n), decisions(n), compute, transport).unwrap();

    let handles: Vec<_> = (0..n)
        .map(|ue| {
            std::thread::spawn(move || {
                let mut client =
                    UeClient::new(TcpClientTransport::connect(addr, ue).expect("handshake"));
                client.report(report(ue)).expect("report");
                let d = client
                    .await_decision(Duration::from_secs(15))
                    .expect("decision broadcast");
                assert_eq!(d.actions.len(), 2, "joint decision covers every UE");

                // UE 1 exercises the NACK path mid-stream: a feature
                // offload with no calibration is rejected at admission,
                // and the session keeps serving afterwards
                if ue == 1 {
                    client.offload(100, 2, vec![7u8; 8], None).expect("send");
                    let err = client
                        .await_result(100, Duration::from_secs(15))
                        .expect_err("calibration-less feature offload must NACK");
                    let msg = format!("{err:#}");
                    assert!(msg.contains("calibration"), "unexpected NACK: {msg}");
                }

                for task in 0..tasks {
                    client
                        .offload(task, 0, vec![task as u8 + 1; 4 * elems], None)
                        .expect("send offload");
                    let res = client
                        .await_result(task, Duration::from_secs(15))
                        .expect("offload result");
                    assert_eq!(res.ue_id, ue);
                    assert_eq!(res.task_id, task);
                    // synthetic logits are strictly increasing in the
                    // class index, so argmax is always the last class
                    assert_eq!(res.argmax, res.logits.len() - 1);
                }
                client.goodbye().expect("goodbye");
            })
        })
        .collect();

    for h in handles {
        h.join().expect("ue client thread");
    }
    let stats = server.join();
    assert_eq!(stats.reports, n);
    assert_eq!(stats.offloads_served as u64, n as u64 * tasks);
    assert_eq!(stats.raw_offloads as u64, n as u64 * tasks);
    assert_eq!(stats.feature_offloads, 0, "the NACKed offload was never admitted");
    assert_eq!(stats.offload_errors, 1, "exactly the calibration NACK");
    assert!(stats.frames >= 1, "at least the initial decision fired");
}

/// The same server loop runs unchanged on the in-process transport via
/// `spawn_on` — the trait seam, not the TCP stack, is what the
/// coordinator depends on.
#[test]
fn channel_transport_drives_spawn_on() {
    let n = 2;
    let (server_t, clients) = channel_transport(n);
    let cfg = ServerConfig::new(n, Duration::from_millis(5), usize::MAX);
    let server = EdgeServer::spawn_on(cfg, pool(n), decisions(n), None, server_t).unwrap();

    let handles: Vec<_> = clients
        .into_iter()
        .map(|t| {
            std::thread::spawn(move || {
                let mut client = UeClient::new(t);
                let ue = client.ue_id();
                client.report(report(ue)).unwrap();
                let d = client.await_decision(Duration::from_secs(10)).unwrap();
                assert_eq!(d.actions.len(), 2);
                client.goodbye().unwrap();
            })
        })
        .collect();
    for h in handles {
        h.join().expect("ue client thread");
    }
    let stats = server.join();
    assert_eq!(stats.reports, n);
    assert!(stats.frames >= 1);
}

/// A remote UE that vanishes without a `Goodbye` (crash, cable pull)
/// must not wedge the server: the connection thread synthesizes the
/// Goodbye, so a `max_frames = usize::MAX` server still exits and
/// `join()` returns.
#[test]
fn server_exits_when_remote_ue_vanishes() {
    let n = 1;
    let cfg = ServerConfig::new(n, Duration::from_millis(5), usize::MAX);
    let transport = TcpServerTransport::bind("127.0.0.1:0", n).unwrap();
    let addr = transport.local_addr();
    let server = EdgeServer::spawn_on(cfg, pool(n), decisions(n), None, transport).unwrap();

    let mut client = UeClient::new(TcpClientTransport::connect(addr, 0).unwrap());
    client.report(report(0)).unwrap();
    client.await_decision(Duration::from_secs(15)).unwrap();
    drop(client); // vanish without a Goodbye

    let t0 = std::time::Instant::now();
    let stats = server.join();
    assert!(
        t0.elapsed() < Duration::from_secs(10),
        "server must exit once the vanished UE's Goodbye is synthesized"
    );
    assert_eq!(stats.reports, 1);
    assert!(stats.frames >= 1);
}

/// Read one whole frame (header + body) off a raw socket, bytes as sent.
fn read_raw_frame(sock: &mut std::net::TcpStream) -> Vec<u8> {
    use std::io::Read;
    let mut frame = vec![0u8; HEADER_LEN];
    sock.read_exact(&mut frame).expect("frame header");
    let len = u32::from_le_bytes([frame[4], frame[5], frame[6], frame[7]]) as usize;
    frame.resize(HEADER_LEN + len, 0);
    sock.read_exact(&mut frame[HEADER_LEN..]).expect("frame body");
    frame
}

/// The reactor's single-encode broadcast must stay frame-for-frame
/// equivalent to the trait-default per-target `send_to` loop (the
/// contract documented on `ServerTransport::broadcast_decision`).
/// Asserted end to end, for both fan-out shapes: the bytes a multiplexed
/// connection reads off its socket are identical to re-encoding the
/// `DownTo` envelopes around whatever the default loop delivers, and a
/// plain single-UE client decodes the same downlink value.
#[test]
fn reactor_broadcast_matches_the_per_ue_send_loop() {
    let (reactor, mut shards) =
        TcpReactor::bind("127.0.0.1:0", ReactorConfig::new(3, 1)).unwrap();
    let addr = reactor.local_addr();

    // UEs 0 and 1 share one multiplexed socket; UE 2 rides the plain
    // single-UE client transport
    let mut multi = std::net::TcpStream::connect(addr).unwrap();
    multi.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    for ue in [0usize, 1] {
        write_frame(&mut multi, &Frame::Hello { ue_id: ue }).unwrap();
        match read_frame(&mut multi).unwrap() {
            Frame::Welcome { ue_id } => assert_eq!(ue_id, ue),
            other => panic!("expected a welcome, got {other:?}"),
        }
    }
    let mut single = TcpClientTransport::connect(addr, 2).unwrap();

    // an asymmetric action table and a shuffled target → index mapping:
    // any mix-up in addressing or slicing changes some frame's bytes
    let d = FrameDecision {
        frame: 7,
        actions: vec![
            HybridAction::new(1, 0, -0.5, 1.0),
            HybridAction::new(2, 1, 0.25, 1.0),
            HybridAction::new(3, 0, 0.75, 1.0),
        ]
        .into(),
    };
    let targets = [(0usize, 2usize), (1, 0), (2, 1)];

    for per_ue in [false, true] {
        // reference: the default send_to loop on the in-process
        // transport, fed the same decision and targets
        let (mut reference, ref_clients) = channel_transport(3);
        reference.broadcast_decision(&d, &targets, per_ue);
        let expected: Vec<Downlink> = ref_clients
            .into_iter()
            .map(|mut c| {
                c.recv_timeout(Duration::from_secs(5))
                    .unwrap()
                    .expect("reference downlink")
            })
            .collect();

        shards[0].broadcast_decision(&d, &targets, per_ue);

        for ue in [0usize, 1] {
            let got = read_raw_frame(&mut multi);
            let want = encode_frame(&Frame::DownTo {
                ue_id: ue,
                down: expected[ue].clone(),
            });
            assert_eq!(got, want, "frame to UE {ue} (per_ue = {per_ue}) diverged");
        }
        let got = single
            .recv_timeout(Duration::from_secs(10))
            .unwrap()
            .expect("broadcast to UE 2");
        assert_eq!(got, expected[2], "UE 2 (per_ue = {per_ue}) diverged");
    }
    reactor.stop();
}

/// Reconnection after a clean goodbye: the server frees the ue_id slot
/// when the first connection closes, so a UE may come back.
#[test]
fn ue_slot_frees_after_disconnect() {
    let transport = TcpServerTransport::bind("127.0.0.1:0", 1).unwrap();
    let addr = transport.local_addr();
    let first = TcpClientTransport::connect(addr, 0).unwrap();
    drop(first); // close the session
    // the slot frees as soon as the server reaps the closed connection
    let deadline = std::time::Instant::now() + Duration::from_secs(5);
    loop {
        match TcpClientTransport::connect(addr, 0) {
            Ok(_) => break,
            Err(e) if std::time::Instant::now() < deadline => {
                let msg = format!("{e:#}");
                assert!(msg.contains("live session"), "unexpected reject: {msg}");
                std::thread::sleep(Duration::from_millis(10));
            }
            Err(e) => panic!("slot never freed: {e:#}"),
        }
    }
}
