//! Fleet-scale serving integration: the sharded reactor core under a
//! 1k-UE loopback trace with reconnect churn, plus fault-injection and
//! drop-accounting regressions (ISSUE 8 satellites).

use std::io::Write as IoWrite;
use std::net::TcpStream;
use std::sync::mpsc::sync_channel;
use std::time::{Duration, Instant};

use anyhow::Result;
use macci::coordinator::decision::{DecisionMaker, DecisionSource, StaticDecision};
use macci::coordinator::protocol::{
    Downlink, FrameDecision, InferenceResult, UeStateReport, Uplink,
};
use macci::coordinator::server::{EdgeServer, ServerConfig};
use macci::coordinator::shard::{spawn_shards, ShardMap};
use macci::coordinator::state_pool::{StateNorm, StatePool};
use macci::coordinator::wire::{encode_frame, read_frame, write_frame, Frame};
use macci::env::HybridAction;
use macci::loadgen::{run_fleet, ArrivalMode, FleetConfig};
use macci::rl::checkpoint::PolicySnapshot;
use macci::transport::channel::ChannelServerTransport;
use macci::transport::reactor::{ReactorConfig, ReactorShardTransport, TcpReactor};
use macci::transport::tcp::TcpClientTransport;
use macci::transport::{ClientTransport, ServerTransport};

fn pool(n: usize) -> StatePool {
    StatePool::new(
        n,
        StateNorm {
            lambda_tasks: 10.0,
            frame_s: 0.5,
            max_bits: 1e6,
            d_max: 100.0,
        },
    )
}

fn report(ue_id: usize) -> Uplink {
    Uplink::Report(UeStateReport {
        ue_id,
        tasks_left: 3,
        compute_left_s: 0.1,
        offload_left_bits: 1e4,
        distance_m: 40.0,
    })
}

/// A static joint action whose source accepts policy installs — lets the
/// tests counter-verify that a fan-out publish reached a shard (its
/// `ServerStats::policy_swaps` ticks).
struct SwappableStatic {
    actions: std::sync::Arc<[HybridAction]>,
}

impl DecisionSource for SwappableStatic {
    fn decide(&mut self, _state: &[f32]) -> Result<std::sync::Arc<[HybridAction]>> {
        Ok(self.actions.clone())
    }

    fn install(&mut self, _snap: &PolicySnapshot) -> Result<bool> {
        Ok(true)
    }
}

fn fleet_server_cfg(len: usize) -> ServerConfig {
    let mut cfg = ServerConfig::new(len, Duration::from_millis(100), usize::MAX);
    cfg.per_ue_decisions = true;
    cfg.exit_when_empty = false; // churn gaps must not stop the shard
    cfg.decide_on_partial = true;
    cfg.drain_limit = 1024;
    cfg
}

fn poll_uplink(t: &mut ReactorShardTransport, deadline: Instant) -> Option<Uplink> {
    while Instant::now() < deadline {
        if let Ok(Some(u)) = t.try_recv() {
            return Some(u);
        }
        std::thread::sleep(Duration::from_millis(1));
    }
    None
}

/// The tentpole end-to-end: 1000 UEs over 2 shards and 8 multiplexed
/// stations, two of them churning — every UE is served, no downlink is
/// silently lost, both shards keep running, a fanned-out policy publish
/// reaches each shard, and a fresh session on a used ue id still gets
/// decisions afterwards (no wedged shard, no leaked slot).
#[test]
fn sharded_fleet_serves_1k_ues_through_churn() {
    const N_UES: usize = 1000;
    const N_SHARDS: usize = 2;
    let map = ShardMap::new(N_UES, N_SHARDS);
    let (reactor, transports) =
        TcpReactor::bind("127.0.0.1:0", ReactorConfig::new(N_UES, N_SHARDS)).unwrap();
    let addr = reactor.local_addr();

    let shards: Vec<_> = transports
        .into_iter()
        .enumerate()
        .map(|(shard, t)| {
            let len = map.slice_of(shard).unwrap().1;
            let dm = DecisionMaker::new(Box::new(SwappableStatic {
                actions: vec![HybridAction::new(0, 0, 0.0, 1.0); len].into(),
            }));
            (t, pool(len), dm)
        })
        .collect();
    let (handles, policy) =
        spawn_shards(&map, |_s, len| fleet_server_cfg(len), shards, None).unwrap();
    assert_eq!(policy.live_slots(), N_SHARDS);

    // one publish through the fan-out handle must reach every shard
    assert!(policy.publish(PolicySnapshot {
        version: 7,
        actors: Vec::new(),
    }));

    let fleet = FleetConfig {
        addr,
        n_ues: N_UES,
        n_stations: 8,
        mode: ArrivalMode::Open,
        duration: Duration::from_secs(3),
        report_interval: Duration::from_millis(100),
        offload_every: 0,
        churn_period: Some(Duration::from_millis(700)),
        churn_stations: 2,
    };
    let stats = run_fleet(&fleet).unwrap();

    assert!(stats.reports_sent > 0);
    assert!(
        stats.reconnects >= 2,
        "churning stations must have reconnected: {}",
        stats.reconnects
    );
    assert!(
        stats.decisions_after_reconnect > 0,
        "reconnected UEs must keep receiving decisions"
    );
    let starved: Vec<usize> = stats
        .per_ue_decisions
        .iter()
        .enumerate()
        .filter(|(_, &d)| d == 0)
        .map(|(ue, _)| ue)
        .collect();
    assert!(
        starved.is_empty(),
        "{} UEs never saw a decision (first few: {:?})",
        starved.len(),
        starved.iter().take(8).collect::<Vec<_>>()
    );
    assert!(stats.latency.count() > 0, "latency samples were recorded");

    // no wedged shards / leaked slots: a fresh session on a used ue id of
    // each shard still handshakes and receives a decision
    for &ue in &[0usize, N_UES - 1] {
        let deadline = Instant::now() + Duration::from_secs(5);
        let mut client = loop {
            match TcpClientTransport::connect(addr, ue) {
                Ok(c) => break c,
                Err(e) => {
                    assert!(Instant::now() < deadline, "ue {ue} cannot reconnect: {e:#}");
                    std::thread::sleep(Duration::from_millis(10));
                }
            }
        };
        client.send(report(ue)).unwrap();
        let mut got_decision = false;
        while Instant::now() < deadline {
            match client.recv_timeout(Duration::from_millis(200)).unwrap() {
                Some(Downlink::Decision(d)) => {
                    assert_eq!(d.actions.len(), 1, "fleet serving sends slim decisions");
                    got_decision = true;
                    break;
                }
                _ => continue,
            }
        }
        assert!(got_decision, "post-churn session for ue {ue} is starved");
    }

    // tear down: stopping the reactor closes the shard uplinks
    reactor.stop();
    let mut swaps = Vec::new();
    for h in handles {
        let s = h.join();
        assert!(s.frames > 0, "a shard never issued a decision frame");
        assert_eq!(
            s.downlink_drops, 0,
            "decision frames were dropped under backpressure"
        );
        swaps.push(s.policy_swaps);
    }
    assert_eq!(
        swaps,
        vec![1; N_SHARDS],
        "the fan-out publish must reach every shard exactly once"
    );
}

/// Fault injection at the reactor: a corrupt-header peer and a mid-frame
/// disconnect are contained to their own connections — both get their
/// registered UEs deregistered (synthesized Goodbyes), while a
/// well-behaved client keeps being served.
#[test]
fn reactor_survives_corrupt_and_midframe_peers() {
    let (reactor, mut transports) =
        TcpReactor::bind("127.0.0.1:0", ReactorConfig::new(4, 1)).unwrap();
    let addr = reactor.local_addr();
    let shard = transports.get_mut(0).unwrap();

    let mut good = TcpClientTransport::connect(addr, 1).unwrap();
    good.send(report(1)).unwrap();
    let deadline = Instant::now() + Duration::from_secs(5);
    assert_eq!(poll_uplink(shard, deadline), Some(report(1)));

    // -- corrupt-header peer: registers, then poisons its stream --
    let mut corrupt = TcpStream::connect(addr).unwrap();
    corrupt.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    write_frame(&mut corrupt, &Frame::Hello { ue_id: 2 }).unwrap();
    match read_frame(&mut corrupt) {
        Ok(Frame::Welcome { ue_id }) => assert_eq!(ue_id, 2),
        other => panic!("expected a welcome, got {other:?}"),
    }
    corrupt.write_all(&[0xde, 0xad, 0xbe, 0xef, 0, 0, 0, 0, 0, 0, 0, 0]).unwrap();

    // -- mid-frame disconnect: half a report, then gone --
    let mut half = TcpStream::connect(addr).unwrap();
    half.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    write_frame(&mut half, &Frame::Hello { ue_id: 3 }).unwrap();
    match read_frame(&mut half) {
        Ok(Frame::Welcome { ue_id }) => assert_eq!(ue_id, 3),
        other => panic!("expected a welcome, got {other:?}"),
    }
    let bytes = encode_frame(&Frame::Up(report(3)));
    half.write_all(&bytes[..bytes.len() / 2]).unwrap();
    drop(half);

    // both faulty sessions resolve into synthesized Goodbyes
    let deadline = Instant::now() + Duration::from_secs(5);
    let mut goodbyes = Vec::new();
    while goodbyes.len() < 2 {
        match poll_uplink(shard, deadline) {
            Some(Uplink::Goodbye { ue_id }) => goodbyes.push(ue_id),
            Some(other) => panic!("unexpected uplink {other:?}"),
            None => panic!("goodbyes never synthesized (got {goodbyes:?})"),
        }
    }
    goodbyes.sort_unstable();
    assert_eq!(goodbyes, vec![2, 3]);

    // the well-behaved client is unaffected, both directions
    good.send(report(1)).unwrap();
    let deadline = Instant::now() + Duration::from_secs(5);
    assert_eq!(poll_uplink(shard, deadline), Some(report(1)));
    shard.send_to(
        1,
        Downlink::Decision(FrameDecision {
            frame: 0,
            actions: vec![HybridAction::new(0, 0, 0.0, 1.0)].into(),
        }),
    );
    let deadline = Instant::now() + Duration::from_secs(5);
    loop {
        match good.recv_timeout(Duration::from_millis(100)).unwrap() {
            Some(Downlink::Decision(_)) => break,
            Some(other) => panic!("unexpected downlink {other:?}"),
            None => assert!(Instant::now() < deadline, "good client starved"),
        }
    }
    reactor.stop();
}

/// A peer that registers and never drains its socket: once its write
/// buffer budget is blown, frames are dropped *and counted* against the
/// owning shard, and the connection is evicted — while another client
/// keeps being served.
#[test]
fn slow_consumer_is_counted_then_evicted() {
    let mut cfg = ReactorConfig::new(2, 1);
    cfg.write_buf_cap = 4096; // any big result frame overflows it
    cfg.evict_after_drops = 3;
    let (reactor, mut transports) = TcpReactor::bind("127.0.0.1:0", cfg).unwrap();
    let addr = reactor.local_addr();
    let shard = transports.get_mut(0).unwrap();

    // register ue 0 and then stop reading forever
    let mut slow = TcpStream::connect(addr).unwrap();
    slow.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    write_frame(&mut slow, &Frame::Hello { ue_id: 0 }).unwrap();
    match read_frame(&mut slow) {
        Ok(Frame::Welcome { ue_id }) => assert_eq!(ue_id, 0),
        other => panic!("expected a welcome, got {other:?}"),
    }

    let big = Downlink::Result(InferenceResult {
        ue_id: 0,
        task_id: 1,
        logits: vec![0.5; 8192], // ~32 KiB encoded > write_buf_cap
        argmax: 0,
        edge_latency_s: 0.01,
    });
    let deadline = Instant::now() + Duration::from_secs(10);
    let mut dropped = 0usize;
    while dropped < 3 {
        shard.send_to(0, big.clone());
        std::thread::sleep(Duration::from_millis(2));
        dropped += shard.take_drops();
        assert!(Instant::now() < deadline, "drops never surfaced: {dropped}");
    }

    // the eviction deregisters ue 0 (synthesized Goodbye proves it)
    let deadline = Instant::now() + Duration::from_secs(5);
    loop {
        match poll_uplink(shard, deadline) {
            Some(Uplink::Goodbye { ue_id }) => {
                assert_eq!(ue_id, 0);
                break;
            }
            Some(other) => panic!("unexpected uplink {other:?}"),
            None => panic!("slow consumer never evicted"),
        }
    }

    // the reactor still serves a fresh, well-behaved client
    let mut good = TcpClientTransport::connect(addr, 1).unwrap();
    good.send(report(1)).unwrap();
    let deadline = Instant::now() + Duration::from_secs(5);
    assert_eq!(poll_uplink(shard, deadline), Some(report(1)));

    let stats = reactor.stop();
    assert!(stats.evicted >= 1, "eviction must be visible in reactor stats");
}

/// Satellite regression for the PR 7 `try_send` drop policy: decision
/// frames dropped on a flooded UE's bounded downlink must increment
/// `ServerStats::downlink_drops` — they used to vanish with a log line.
#[test]
fn flooded_ue_downlink_drops_are_counted() {
    let (uplink_tx, uplink_rx) = sync_channel::<Uplink>(64);
    // depth-1 downlink that nobody ever drains: the second decision
    // broadcast (and every one after) must be dropped and counted
    let (down_tx, down_rx) = sync_channel::<Downlink>(1);
    let transport = ChannelServerTransport::from_parts(uplink_rx, vec![down_tx]);

    let dm = DecisionMaker::new(Box::new(StaticDecision::new(vec![HybridAction::new(
        0, 0, 0.0, 1.0,
    )])));
    let cfg = ServerConfig::new(1, Duration::from_millis(5), usize::MAX);
    let handle = EdgeServer::spawn_on(cfg, pool(1), dm, None, transport).unwrap();

    // keep reporting so decisions keep broadcasting into the full queue
    for _ in 0..40 {
        uplink_tx.send(report(0)).unwrap();
        std::thread::sleep(Duration::from_millis(5));
    }
    uplink_tx.send(Uplink::Goodbye { ue_id: 0 }).unwrap();
    let stats = handle.join();
    assert!(stats.frames >= 2, "server issued decisions: {}", stats.frames);
    assert!(
        stats.downlink_drops > 0,
        "dropped decision frames must be counted, not vanish \
         (frames = {}, drops = {})",
        stats.frames,
        stats.downlink_drops
    );
    drop(down_rx); // held open so drops were Full, never Disconnected
}
