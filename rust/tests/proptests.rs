//! Property tests over the coordinator invariants: routing/state assembly,
//! batching policy, buffer/GAE math, action-space mapping — pure Rust, no
//! artifacts needed.

use macci::coordinator::protocol::UeStateReport;
use macci::coordinator::state_pool::{StateNorm, StatePool};
use macci::env::mdp::MultiAgentEnv;
use macci::env::scenario::ScenarioConfig;
use macci::env::{Action, HybridAction};
use macci::profiles::DeviceProfile;
use macci::rl::buffer::{TrajectoryBuffer, Transition};
use macci::rl::gae;
use macci::util::check::forall;
use macci::util::rng::Rng;

#[test]
fn state_pool_matches_env_state_encoding() {
    // for arbitrary UE states, the server-side StatePool must assemble the
    // same vector the in-process env produces from identical raw values
    forall(
        1,
        50,
        |g| {
            let n = g.usize_in(1, 10).clamp(1, 10);
            let reports: Vec<UeStateReport> = (0..n)
                .map(|ue_id| UeStateReport {
                    ue_id,
                    tasks_left: g.usize_in(0, 300) as u64,
                    compute_left_s: g.f64_in(0.0, 0.5),
                    offload_left_bits: g.f64_in(0.0, 1.2e6),
                    distance_m: g.f64_in(1.0, 100.0),
                })
                .collect();
            reports
        },
        |reports| {
            let n = reports.len();
            let norm = StateNorm {
                lambda_tasks: 200.0,
                frame_s: 0.5,
                max_bits: 1.2e6,
                d_max: 100.0,
            };
            let mut pool = StatePool::new(n, norm);
            for r in reports {
                pool.ingest(*r);
            }
            let s = pool.assemble();
            if s.len() != 4 * n {
                return Err(format!("bad state length {}", s.len()));
            }
            for (i, r) in reports.iter().enumerate() {
                let checks = [
                    (s[i], r.tasks_left as f64 / 200.0),
                    (s[n + i], r.compute_left_s / 0.5),
                    (s[2 * n + i], r.offload_left_bits / 1.2e6),
                    (s[3 * n + i], r.distance_m / 100.0),
                ];
                for (got, want) in checks {
                    if (got as f64 - want).abs() > 1e-6 {
                        return Err(format!("ue {i}: {got} vs {want}"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn env_step_preserves_task_conservation() {
    // tasks never appear or vanish: completed + remaining + in-flight is
    // constant through arbitrary action sequences
    forall(
        3,
        15,
        |g| g.rng.next_u64(),
        |&seed| {
            let cfg = ScenarioConfig {
                n_ues: 4,
                lambda_tasks: 12.0,
                ..Default::default()
            };
            let mut env =
                MultiAgentEnv::new(DeviceProfile::synthetic(), cfg, seed).unwrap();
            let initial: u64 = env.ues().iter().map(|u| u.tasks_left).sum();
            let mut rng = Rng::new(seed ^ 0x55);
            let mut completed = 0u64;
            for _ in 0..200 {
                if env.done() {
                    break;
                }
                let a: Action = (0..4)
                    .map(|_| {
                        HybridAction::new(rng.below(6), rng.below(2), rng.normal() as f32, 1.0)
                    })
                    .collect();
                let r = env.step(&a);
                completed += r.info.completed;
                let remaining: u64 = env.ues().iter().map(|u| u.tasks_left).sum();
                let in_flight = env
                    .ues()
                    .iter()
                    .filter(|u| u.phase != macci::env::ue::Phase::Idle)
                    .count() as u64;
                let total = completed + remaining + in_flight;
                if total != initial {
                    return Err(format!(
                        "task conservation broken: {completed}+{remaining}+{in_flight} != {initial}"
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn buffer_minibatch_indices_are_consistent() {
    // advantages and returns drawn into a minibatch must correspond to the
    // same transitions as the action columns
    forall(
        5,
        30,
        |g| (g.usize_in(4, 64).max(4), g.rng.next_u64()),
        |&(cap, seed)| {
            let n_ues = 3;
            let mut buf = TrajectoryBuffer::new(cap, n_ues);
            for i in 0..cap {
                buf.push(Transition {
                    // encode the index into the state so we can check joins
                    state: vec![i as f32; 4 * n_ues],
                    a_b: vec![i as i32; n_ues],
                    a_c: vec![0; n_ues],
                    a_p: vec![i as f32; n_ues],
                    log_prob: vec![0.0; n_ues],
                    reward: i as f64,
                    value: 0.0,
                    done: i + 1 == cap,
                })
            }
            buf.finish(0.0, 0.0, 0.0, false); // gamma = 0 => return == reward
            let mut rng = Rng::new(seed);
            let b = (cap / 2).max(1);
            let mb = buf.sample_minibatch(b, &mut rng);
            for k in 0..b {
                let idx = mb.a_b[0][k] as usize;
                if mb.states[k * 4 * n_ues] as usize != idx {
                    return Err("state column misaligned".into());
                }
                if (mb.returns[k] - idx as f32).abs() > 1e-6 {
                    return Err(format!(
                        "return misaligned: {} vs {idx}",
                        mb.returns[k]
                    ));
                }
                if (mb.a_p[2][k] - idx as f32).abs() > 1e-6 {
                    return Err("per-actor column misaligned".into());
                }
            }
            Ok(())
        },
    );
}

#[test]
fn gae_is_shift_invariant_in_rewards_only_through_values() {
    // adding a constant c to all rewards shifts returns by c/(1-gamma) in
    // the infinite-horizon limit; for a single finite episode the *relative
    // ordering* of advantages under identical values must be preserved when
    // rewards are scaled by a positive constant
    forall(
        9,
        40,
        |g| {
            let n = g.usize_in(2, 32).max(2);
            let rewards: Vec<f64> = (0..n).map(|_| g.f64_in(-2.0, 0.0)).collect();
            let values: Vec<f32> = vec![0.0; n];
            (rewards, values, g.f64_in(0.5, 3.0))
        },
        |(rewards, values, scale)| {
            let n = rewards.len();
            let mut dones = vec![false; n];
            dones[n - 1] = true;
            let a1 = gae::gae_advantages(rewards, values, &dones, 0.95, 0.95, 0.0);
            let scaled: Vec<f64> = rewards.iter().map(|r| r * scale).collect();
            let a2 = gae::gae_advantages(&scaled, values, &dones, 0.95, 0.95, 0.0);
            // positive scaling preserves sign and ordering
            for i in 0..n {
                for j in 0..n {
                    if (a1[i] > a1[j]) != (a2[i] > a2[j])
                        && (a1[i] - a1[j]).abs() > 1e-4
                        && (a2[i] - a2[j]).abs() > 1e-4
                    {
                        return Err(format!("ordering flipped at ({i},{j})"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn hybrid_action_power_always_feasible() {
    forall(
        11,
        200,
        |g| (g.f64_in(-50.0, 50.0) as f32, g.f64_in(0.1, 5.0)),
        |&(raw, p_max)| {
            let a = HybridAction::new(0, 0, raw, p_max);
            if a.p_watts <= 0.0 || a.p_watts > p_max {
                return Err(format!("power {} outside (0, {p_max}]", a.p_watts));
            }
            Ok(())
        },
    );
}
