//! Property tests over the coordinator invariants: routing/state assembly,
//! shard ownership/routing, the wire codec (round-trip + corruption),
//! batching policy, buffer/GAE math, action-space mapping — pure Rust, no
//! artifacts needed.

use macci::coordinator::protocol::{
    Downlink, FrameDecision, InferenceResult, OffloadRequest, UeStateReport, Uplink,
};
use macci::coordinator::state_pool::{StateNorm, StatePool};
use macci::coordinator::wire::{decode_frame, encode_frame, Frame};
use macci::env::mdp::{EnvSnapshot, MultiAgentEnv};
use macci::env::scenario::ScenarioConfig;
use macci::env::ue::{Phase, TaskTotals, UeSnapshot};
use macci::env::{Action, HybridAction};
use macci::profiles::DeviceProfile;
use macci::rl::buffer::{TrajectoryBuffer, Transition};
use macci::rl::checkpoint::{self, TrainerCheckpoint};
use macci::rl::gae;
use macci::rl::mahppo::TrainConfig;
use macci::rl::rollout::{EngineSnapshot, LaneSnapshot};
use macci::runtime::artifacts::ArtifactStore;
use macci::runtime::nets::{ActorNet, CriticNet, NetState};
use macci::util::check::forall;
use macci::util::rng::Rng;

#[test]
fn state_pool_matches_env_state_encoding() {
    // for arbitrary UE states, the server-side StatePool must assemble the
    // same vector the in-process env produces from identical raw values
    forall(
        1,
        50,
        |g| {
            let n = g.usize_in(1, 10).clamp(1, 10);
            let reports: Vec<UeStateReport> = (0..n)
                .map(|ue_id| UeStateReport {
                    ue_id,
                    tasks_left: g.usize_in(0, 300) as u64,
                    compute_left_s: g.f64_in(0.0, 0.5),
                    offload_left_bits: g.f64_in(0.0, 1.2e6),
                    distance_m: g.f64_in(1.0, 100.0),
                })
                .collect();
            reports
        },
        |reports| {
            let n = reports.len();
            let norm = StateNorm {
                lambda_tasks: 200.0,
                frame_s: 0.5,
                max_bits: 1.2e6,
                d_max: 100.0,
            };
            let mut pool = StatePool::new(n, norm);
            for r in reports {
                pool.ingest(*r);
            }
            let s = pool.assemble();
            if s.len() != 4 * n {
                return Err(format!("bad state length {}", s.len()));
            }
            for (i, r) in reports.iter().enumerate() {
                let checks = [
                    (s[i], r.tasks_left as f64 / 200.0),
                    (s[n + i], r.compute_left_s / 0.5),
                    (s[2 * n + i], r.offload_left_bits / 1.2e6),
                    (s[3 * n + i], r.distance_m / 100.0),
                ];
                for (got, want) in checks {
                    if (got as f64 - want).abs() > 1e-6 {
                        return Err(format!("ue {i}: {got} vs {want}"));
                    }
                }
            }
            Ok(())
        },
    );
}

/// A random well-formed frame with finite floats (NaN never crosses the
/// wire in practice, and `PartialEq` could not compare it).
fn arbitrary_frame(g: &mut macci::util::check::Gen) -> Frame {
    match g.usize_in(0, 11) {
        0 => Frame::Hello {
            ue_id: g.usize_in(0, 1_000),
        },
        1 => Frame::Welcome {
            ue_id: g.usize_in(0, 1_000),
        },
        2 => Frame::Up(Uplink::Report(UeStateReport {
            ue_id: g.usize_in(0, 64),
            tasks_left: g.rng.next_u64(),
            compute_left_s: g.f64_in(0.0, 1.0),
            offload_left_bits: g.f64_in(0.0, 1e6),
            distance_m: g.f64_in(0.0, 100.0),
        })),
        3 | 4 => {
            let payload_len = g.usize_in(0, 64);
            Frame::Up(Uplink::Offload(OffloadRequest {
                ue_id: g.usize_in(0, 64),
                task_id: g.rng.next_u64(),
                b: g.usize_in(0, 4),
                payload: (0..payload_len).map(|_| (g.rng.next_u64() & 0xFF) as u8).collect(),
                calibration: if g.bool() {
                    Some((g.f64_in(-4.0, 0.0) as f32, g.f64_in(0.0, 4.0) as f32))
                } else {
                    None
                },
            }))
        }
        5 => Frame::Up(Uplink::Goodbye {
            ue_id: g.usize_in(0, 64),
        }),
        6 => {
            let n = g.usize_in(0, 8);
            Frame::Down(Downlink::Decision(FrameDecision {
                frame: g.usize_in(0, 10_000),
                actions: (0..n)
                    .map(|_| {
                        HybridAction::new(
                            g.usize_in(0, 5),
                            g.usize_in(0, 2),
                            g.f64_in(-3.0, 3.0) as f32,
                            1.0,
                        )
                    })
                    .collect(),
            }))
        }
        7 => {
            let n = g.usize_in(0, 16);
            Frame::Down(Downlink::Result(InferenceResult {
                ue_id: g.usize_in(0, 64),
                task_id: g.rng.next_u64(),
                logits: g.vec_f32(n, -5.0, 5.0),
                argmax: g.usize_in(0, 16),
                edge_latency_s: g.f64_in(0.0, 1.0),
            }))
        }
        8 => Frame::Down(Downlink::Error {
            task_id: g.rng.next_u64(),
            // multi-byte utf-8 must survive the trip
            error: "wire ☃ failure".chars().take(g.usize_in(0, 14)).collect(),
        }),
        // the reactor's addressed envelope (multiplexed connections)
        9 => Frame::DownTo {
            ue_id: g.usize_in(0, 10_000),
            down: Downlink::Decision(FrameDecision {
                frame: g.usize_in(0, 10_000),
                actions: vec![HybridAction::new(g.usize_in(0, 5), 0, 0.0, 1.0)].into(),
            }),
        },
        _ => Frame::Down(Downlink::Shutdown),
    }
}

#[test]
fn wire_frames_survive_encode_decode() {
    // every frame type round-trips bit-exactly, and consecutive frames in
    // one buffer decode in sequence (stream framing)
    forall(
        21,
        200,
        |g| (arbitrary_frame(g), arbitrary_frame(g)),
        |(a, b)| {
            let mut buf = encode_frame(a);
            let len_a = buf.len();
            buf.extend_from_slice(&encode_frame(b));
            let (got_a, used_a) = decode_frame(&buf).map_err(|e| format!("first: {e}"))?;
            if got_a != *a || used_a != len_a {
                return Err(format!("first frame mangled: {got_a:?} vs {a:?}"));
            }
            let rest = &buf[used_a..];
            let (got_b, used_b) = decode_frame(rest).map_err(|e| format!("second: {e}"))?;
            if got_b != *b || used_a + used_b != buf.len() {
                return Err(format!("second frame mangled: {got_b:?} vs {b:?}"));
            }
            Ok(())
        },
    );
}

#[test]
fn wire_corruption_is_rejected_never_panics() {
    // any truncation and any single bit-flip of a valid frame decodes to
    // an error — the CRC covers the header prefix and the body, so no
    // damaged frame is ever delivered as data
    forall(
        22,
        200,
        |g| {
            let frame = arbitrary_frame(g);
            let bits = encode_frame(&frame).len() * 8;
            (frame, g.rng.next_u64() as usize % bits, g.rng.next_u64())
        },
        |(frame, flip_bit, trunc_seed)| {
            let buf = encode_frame(frame);
            let trunc = (*trunc_seed as usize) % buf.len();
            if decode_frame(&buf[..trunc]).is_ok() {
                return Err(format!("truncation to {trunc} bytes decoded"));
            }
            let mut flipped = buf.clone();
            flipped[flip_bit / 8] ^= 1 << (flip_bit % 8);
            if decode_frame(&flipped).is_ok() {
                return Err(format!("bit flip at {flip_bit} went undetected"));
            }
            Ok(())
        },
    );
}

/// A random structurally-valid trainer checkpoint (small nets, 1–2 lanes,
/// finite floats) — the starting point for corruption testing.
fn arbitrary_checkpoint(g: &mut macci::util::check::Gen) -> TrainerCheckpoint {
    let n_ues = g.usize_in(1, 4).clamp(1, 3);
    let n_envs = g.usize_in(1, 3).clamp(1, 2);
    let scenario = ScenarioConfig {
        n_ues,
        lambda_tasks: g.f64_in(5.0, 50.0),
        p_max: g.f64_in(0.5, 2.0),
        ..Default::default()
    };
    let config = TrainConfig {
        buffer_size: 8 * n_envs,
        minibatch: 4,
        n_envs,
        seed: g.rng.next_u64(),
        ..Default::default()
    };
    let params = g.usize_in(1, 16).max(1);
    let mut net = |t: u64| NetState {
        params: g.vec_f32(params, -2.0, 2.0),
        m: g.vec_f32(params, -1.0, 1.0),
        v: g.vec_f32(params, 0.0, 1.0),
        t,
    };
    let actors = (0..n_ues).map(|_| net(3)).collect();
    let critic = net(3);
    let mut mk_rng = || Rng::new(g.rng.next_u64()).state();
    let lanes = (0..n_envs)
        .map(|_| LaneSnapshot {
            env: EnvSnapshot {
                cfg: scenario.clone(),
                rng: mk_rng(),
                frame_idx: 5,
                ues: (0..n_ues)
                    .map(|id| UeSnapshot {
                        id,
                        distance: 50.0,
                        gain: 1e-5,
                        tasks_left: 4,
                        phase: match id % 3 {
                            0 => Phase::Idle,
                            1 => Phase::Compute {
                                remaining_s: 0.01,
                                total_s: 0.05,
                                total_energy: 0.1,
                            },
                            _ => Phase::Offload {
                                remaining_bits: 1000.0,
                            },
                        },
                        decision: HybridAction::new(2, 0, 0.1, 1.0),
                        pending: HybridAction::new(1, 1, -0.2, 1.0),
                        cur_latency: 0.01,
                        cur_energy: 0.001,
                        frame_energy: 0.0005,
                        totals: TaskTotals {
                            completed: 2,
                            latency_sum: 0.1,
                            energy_sum: 0.2,
                        },
                    })
                    .collect(),
            },
            rng: mk_rng(),
            scenario_rng: mk_rng(),
            ep_reward: -1.5,
        })
        .collect();
    TrainerCheckpoint {
        config,
        scenario,
        profile: DeviceProfile::synthetic(),
        actors,
        critic,
        sampler_rng: mk_rng(),
        engine: EngineSnapshot {
            started: true,
            lanes,
        },
    }
}

#[test]
fn checkpoint_roundtrips_bit_exactly() {
    forall(
        31,
        40,
        arbitrary_checkpoint,
        |cp| {
            let bytes = checkpoint::encode(cp).map_err(|e| format!("encode: {e}"))?;
            let back = checkpoint::decode(&bytes).map_err(|e| format!("decode: {e}"))?;
            if &back != cp {
                return Err("decoded checkpoint differs from the original".into());
            }
            let again = checkpoint::encode(&back).map_err(|e| format!("re-encode: {e}"))?;
            if again != bytes {
                return Err("encoding is not canonical".into());
            }
            Ok(())
        },
    );
}

#[test]
fn checkpoint_corruption_is_rejected_never_panics() {
    // every truncation and every single bit-flip of a valid checkpoint
    // decodes to a typed error — the CRC covers the header prefix and the
    // whole body, so no damaged checkpoint is ever accepted
    forall(
        32,
        60,
        |g| {
            let cp = arbitrary_checkpoint(g);
            let bits = checkpoint::encode(&cp).unwrap().len() * 8;
            (cp, g.rng.next_u64() as usize % bits, g.rng.next_u64())
        },
        |(cp, flip_bit, trunc_seed)| {
            let buf = checkpoint::encode(cp).map_err(|e| format!("encode: {e}"))?;
            let trunc = (*trunc_seed as usize) % buf.len();
            if checkpoint::decode(&buf[..trunc]).is_ok() {
                return Err(format!("truncation to {trunc} bytes decoded"));
            }
            let mut flipped = buf.clone();
            flipped[flip_bit / 8] ^= 1 << (flip_bit % 8);
            if checkpoint::decode(&flipped).is_ok() {
                return Err(format!("bit flip at {flip_bit} went undetected"));
            }
            Ok(())
        },
    );
}

#[test]
fn env_step_preserves_task_conservation() {
    // tasks never appear or vanish: completed + remaining + in-flight is
    // constant through arbitrary action sequences
    forall(
        3,
        15,
        |g| g.rng.next_u64(),
        |&seed| {
            let cfg = ScenarioConfig {
                n_ues: 4,
                lambda_tasks: 12.0,
                ..Default::default()
            };
            let mut env =
                MultiAgentEnv::new(DeviceProfile::synthetic(), cfg, seed).unwrap();
            let initial: u64 = env.ues().iter().map(|u| u.tasks_left).sum();
            let mut rng = Rng::new(seed ^ 0x55);
            let mut completed = 0u64;
            for _ in 0..200 {
                if env.done() {
                    break;
                }
                let a: Action = (0..4)
                    .map(|_| {
                        HybridAction::new(rng.below(6), rng.below(2), rng.normal() as f32, 1.0)
                    })
                    .collect();
                let r = env.step(&a);
                completed += r.info.completed;
                let remaining: u64 = env.ues().iter().map(|u| u.tasks_left).sum();
                let in_flight = env
                    .ues()
                    .iter()
                    .filter(|u| u.phase != macci::env::ue::Phase::Idle)
                    .count() as u64;
                let total = completed + remaining + in_flight;
                if total != initial {
                    return Err(format!(
                        "task conservation broken: {completed}+{remaining}+{in_flight} != {initial}"
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn buffer_minibatch_indices_are_consistent() {
    // advantages and returns drawn into a minibatch must correspond to the
    // same transitions as the action columns
    forall(
        5,
        30,
        |g| (g.usize_in(4, 64).max(4), g.rng.next_u64()),
        |&(cap, seed)| {
            let n_ues = 3;
            let mut buf = TrajectoryBuffer::new(cap, n_ues);
            for i in 0..cap {
                buf.push(Transition {
                    // encode the index into the state so we can check joins
                    state: vec![i as f32; 4 * n_ues],
                    a_b: vec![i as i32; n_ues],
                    a_c: vec![0; n_ues],
                    a_p: vec![i as f32; n_ues],
                    log_prob: vec![0.0; n_ues],
                    reward: i as f64,
                    value: 0.0,
                    done: i + 1 == cap,
                })
            }
            buf.finish(0.0, 0.0, 0.0, false); // gamma = 0 => return == reward
            let mut rng = Rng::new(seed);
            let b = (cap / 2).max(1);
            let mb = buf.sample_minibatch(b, &mut rng);
            for k in 0..b {
                let idx = mb.a_b[0][k] as usize;
                if mb.states[k * 4 * n_ues] as usize != idx {
                    return Err("state column misaligned".into());
                }
                if (mb.returns[k] - idx as f32).abs() > 1e-6 {
                    return Err(format!(
                        "return misaligned: {} vs {idx}",
                        mb.returns[k]
                    ));
                }
                if (mb.a_p[2][k] - idx as f32).abs() > 1e-6 {
                    return Err("per-actor column misaligned".into());
                }
            }
            Ok(())
        },
    );
}

#[test]
fn gae_is_shift_invariant_in_rewards_only_through_values() {
    // adding a constant c to all rewards shifts returns by c/(1-gamma) in
    // the infinite-horizon limit; for a single finite episode the *relative
    // ordering* of advantages under identical values must be preserved when
    // rewards are scaled by a positive constant
    forall(
        9,
        40,
        |g| {
            let n = g.usize_in(2, 32).max(2);
            let rewards: Vec<f64> = (0..n).map(|_| g.f64_in(-2.0, 0.0)).collect();
            let values: Vec<f32> = vec![0.0; n];
            (rewards, values, g.f64_in(0.5, 3.0))
        },
        |(rewards, values, scale)| {
            let n = rewards.len();
            let mut dones = vec![false; n];
            dones[n - 1] = true;
            let a1 = gae::gae_advantages(rewards, values, &dones, 0.95, 0.95, 0.0);
            let scaled: Vec<f64> = rewards.iter().map(|r| r * scale).collect();
            let a2 = gae::gae_advantages(&scaled, values, &dones, 0.95, 0.95, 0.0);
            // positive scaling preserves sign and ordering
            for i in 0..n {
                for j in 0..n {
                    if (a1[i] > a1[j]) != (a2[i] > a2[j])
                        && (a1[i] - a1[j]).abs() > 1e-4
                        && (a2[i] - a2[j]).abs() > 1e-4
                    {
                        return Err(format!("ordering flipped at ({i},{j})"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn hybrid_action_power_always_feasible() {
    forall(
        11,
        200,
        |g| (g.f64_in(-50.0, 50.0) as f32, g.f64_in(0.1, 5.0)),
        |&(raw, p_max)| {
            let a = HybridAction::new(0, 0, raw, p_max);
            if a.p_watts <= 0.0 || a.p_watts > p_max {
                return Err(format!("power {} outside (0, {p_max}]", a.p_watts));
            }
            Ok(())
        },
    );
}

// ------------------------------------------------------------ native kernels

use macci::runtime::native::gemm::{dense_packed, PackedW};
use macci::runtime::native::kernels::{conv1x1_with, dense_with, matmul_bt_with, Act};
use macci::runtime::native::quant8::{
    conv1x1_q8_error_bound, dense_q8_error_bound, QuantConv, QuantDense,
};
use macci::runtime::native::simd::{self, Isa};

#[test]
fn kernel_simd_dense_is_bit_identical_to_scalar() {
    // every available ISA — plain dispatch AND the packed/blocked GEMM —
    // must reproduce the scalar reference bit-for-bit, including empty
    // batches (rows = 0) and odd, non-multiple-of-8 dims
    forall(
        77,
        80,
        |g| {
            let rows = g.usize_in(0, 32);
            let in_dim = g.usize_in(1, 37);
            let out_dim = g.usize_in(1, 37);
            (
                rows,
                in_dim,
                out_dim,
                g.vec_f32(rows * in_dim, -2.0, 2.0),
                g.vec_f32(in_dim * out_dim, -1.0, 1.0),
                g.vec_f32(out_dim, -1.0, 1.0),
            )
        },
        |(rows, in_dim, out_dim, x, w, b)| {
            let (rows, in_dim, out_dim) = (*rows, *in_dim, *out_dim);
            for act in [Act::Linear, Act::Tanh, Act::Relu] {
                let reference = dense_with(Isa::Scalar, x, rows, in_dim, w, b, out_dim, act);
                let pw = PackedW::pack(w, b, in_dim, out_dim);
                for isa in simd::available() {
                    if dense_with(isa, x, rows, in_dim, w, b, out_dim, act) != reference {
                        return Err(format!(
                            "dense {isa:?} diverged at {rows}x{in_dim}->{out_dim} {act:?}"
                        ));
                    }
                    if dense_packed(isa, x, rows, &pw, act) != reference {
                        return Err(format!(
                            "dense_packed {isa:?} diverged at {rows}x{in_dim}->{out_dim} {act:?}"
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn kernel_simd_matmul_bt_and_conv1x1_are_bit_identical_to_scalar() {
    forall(
        78,
        80,
        |g| {
            let rows = g.usize_in(0, 24);
            let in_dim = g.usize_in(1, 33);
            let out_dim = g.usize_in(1, 33);
            let hw = g.usize_in(1, 19);
            (
                rows,
                in_dim,
                out_dim,
                hw,
                g.vec_f32(rows * out_dim, -2.0, 2.0),
                g.vec_f32(in_dim * out_dim, -1.0, 1.0),
                g.vec_f32(out_dim, -1.0, 1.0),
                g.vec_f32(in_dim * hw, -2.0, 2.0),
            )
        },
        |(rows, in_dim, out_dim, hw, dy, w, b, img)| {
            let (rows, in_dim, out_dim, hw) = (*rows, *in_dim, *out_dim, *hw);
            let dx_ref = matmul_bt_with(Isa::Scalar, dy, rows, out_dim, w, in_dim);
            // conv treats (in_dim, out_dim) as (c_in, c_out) over a 1 x hw map
            let conv_ref = conv1x1_with(Isa::Scalar, img, 1, in_dim, 1, hw, w, b, out_dim);
            for isa in simd::available() {
                if matmul_bt_with(isa, dy, rows, out_dim, w, in_dim) != dx_ref {
                    return Err(format!(
                        "matmul_bt {isa:?} diverged at {rows}x{out_dim}->{in_dim}"
                    ));
                }
                if conv1x1_with(isa, img, 1, in_dim, 1, hw, w, b, out_dim) != conv_ref {
                    return Err(format!(
                        "conv1x1 {isa:?} diverged at c{in_dim}->c{out_dim} hw={hw}"
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn kernel_int8_dense_respects_analytic_error_bound() {
    // randomized calibration ranges: activations drawn from [lo, lo+span]
    // with lo in [-8, 0) and span in [0.1, 16) — the quantized forward must
    // stay inside the per-element analytic bound on every available ISA
    forall(
        79,
        80,
        |g| {
            let rows = g.usize_in(0, 8);
            let in_dim = g.usize_in(1, 40);
            let out_dim = g.usize_in(1, 24);
            let lo = g.f64_in(-8.0, 0.0) as f32;
            let span = g.f64_in(0.1, 16.0) as f32;
            (
                rows,
                in_dim,
                out_dim,
                g.vec_f32(rows * in_dim, lo, lo + span),
                g.vec_f32(in_dim * out_dim, -2.0, 2.0),
                g.vec_f32(out_dim, -1.0, 1.0),
            )
        },
        |(rows, in_dim, out_dim, x, w, b)| {
            let (rows, in_dim, out_dim) = (*rows, *in_dim, *out_dim);
            let bound = dense_q8_error_bound(x, rows, in_dim, w, out_dim);
            let qd = QuantDense::pack(w, b, in_dim, out_dim);
            for act in [Act::Linear, Act::Tanh] {
                let exact = dense_with(Isa::Scalar, x, rows, in_dim, w, b, out_dim, act);
                for isa in simd::available() {
                    let got = qd.forward(isa, x, rows, act);
                    for (i, (&gv, &ev)) in got.iter().zip(&exact).enumerate() {
                        // tanh is 1-Lipschitz, relu too: the pre-activation
                        // bound survives the epilogue
                        if (gv - ev).abs() > bound[i] {
                            return Err(format!(
                                "int8 {isa:?} {act:?} out of bound at {i}: |{gv} - {ev}| > {}",
                                bound[i]
                            ));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn kernel_int8_conv1x1_respects_analytic_error_bound() {
    forall(
        80,
        60,
        |g| {
            let c_in = g.usize_in(1, 12);
            let c_out = g.usize_in(1, 10);
            let hw = g.usize_in(1, 25);
            let lo = g.f64_in(-4.0, 0.0) as f32;
            let span = g.f64_in(0.1, 8.0) as f32;
            (
                c_in,
                c_out,
                hw,
                g.vec_f32(c_in * hw, lo, lo + span),
                g.vec_f32(c_in * c_out, -2.0, 2.0),
                g.vec_f32(c_out, -1.0, 1.0),
            )
        },
        |(c_in, c_out, hw, x, w, b)| {
            let (c_in, c_out, hw) = (*c_in, *c_out, *hw);
            let exact = conv1x1_with(Isa::Scalar, x, 1, c_in, 1, hw, w, b, c_out);
            let bound = conv1x1_q8_error_bound(x, 1, c_in, 1, hw, w, c_out);
            let qc = QuantConv::pack(w, b, c_in, c_out);
            for isa in simd::available() {
                let got = qc.forward(isa, x, 1, 1, hw);
                for (i, (&gv, &ev)) in got.iter().zip(&exact).enumerate() {
                    if (gv - ev).abs() > bound[i] {
                        return Err(format!(
                            "int8 conv {isa:?} out of bound at {i}: |{gv} - {ev}| > {}",
                            bound[i]
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

// ------------------------------------------------------------ shard routing

use std::collections::VecDeque;
use std::sync::{Arc, Mutex};

use macci::coordinator::shard::{ShardMap, ShardView};
use macci::transport::{ServerTransport, TransportError};

#[test]
fn shard_map_assignment_is_total_and_collision_free() {
    // over arbitrary fleet sizes and shard counts (well beyond the Gen's
    // size-capped ranges): slices tile [0, n) exactly and in order, every
    // slice boundary routes back to its shard, lengths are balanced to
    // ±1, arbitrary probes agree with the owning slice, and out-of-range
    // ids are unowned — the assignment is total and collision-free
    forall(
        41,
        120,
        |g| {
            let n_ues = (g.rng.next_u64() % 200_000) as usize;
            let n_shards = 1 + (g.rng.next_u64() % 64) as usize;
            let probes: Vec<usize> = (0..64)
                .map(|_| (g.rng.next_u64() % 250_000) as usize)
                .collect();
            (n_ues, n_shards, probes)
        },
        |(n_ues, n_shards, probes)| {
            let (n, k) = (*n_ues, *n_shards);
            let map = ShardMap::new(n, k);
            if map.n_shards() != k || map.n_ues() != n {
                return Err("map dimensions mangled".into());
            }
            let mut next = 0usize;
            let (mut min_len, mut max_len) = (usize::MAX, 0usize);
            for shard in 0..k {
                let Some((lo, len)) = map.slice_of(shard) else {
                    return Err(format!("shard {shard} has no slice"));
                };
                if lo != next {
                    return Err(format!("shard {shard} starts at {lo}, expected {next}"));
                }
                // both boundary ids of a non-empty slice route back to it
                // (the closed form's off-by-one hot spots)
                if len > 0 {
                    for ue in [lo, lo + len - 1] {
                        if map.shard_of(ue) != Some(shard) {
                            return Err(format!("ue {ue} not owned by its slice {shard}"));
                        }
                    }
                }
                min_len = min_len.min(len);
                max_len = max_len.max(len);
                next = lo + len;
            }
            if next != n {
                return Err(format!("slices cover {next} of {n} UEs"));
            }
            if max_len - min_len > 1 {
                return Err(format!("unbalanced: lens in [{min_len}, {max_len}]"));
            }
            if map.slice_of(k).is_some() {
                return Err("slice for an out-of-range shard".into());
            }
            for &ue in probes {
                match map.shard_of(ue) {
                    Some(s) if ue < n => {
                        let (lo, len) = map.slice_of(s).ok_or("owner without a slice")?;
                        if ue < lo || ue >= lo + len {
                            return Err(format!(
                                "ue {ue} assigned to shard {s} but outside [{lo}, {})",
                                lo + len
                            ));
                        }
                    }
                    None if ue >= n => {}
                    other => return Err(format!("ue {ue} (fleet {n}): {other:?}")),
                }
            }
            Ok(())
        },
    );
}

/// A scripted fleet-wide transport for exercising [`ShardView`] in
/// isolation: uplinks pop from a queue, downlinks are recorded.
struct ScriptedTransport {
    uplinks: VecDeque<Uplink>,
    sent: Arc<Mutex<Vec<(usize, Downlink)>>>,
}

impl ServerTransport for ScriptedTransport {
    fn try_recv(&mut self) -> Result<Option<Uplink>, TransportError> {
        Ok(self.uplinks.pop_front())
    }

    fn send_to(&mut self, ue_id: usize, frame: Downlink) {
        self.sent.lock().unwrap().push((ue_id, frame));
    }
}

#[test]
fn shard_view_isolates_cross_shard_traffic() {
    // a shard's view of the fleet transport delivers exactly the uplinks
    // inside its slice (ids rewritten to local space, order preserved,
    // the rest counted as misrouted) and never lets a downlink escape the
    // slice — cross-shard isolation by construction
    forall(
        42,
        80,
        |g| {
            let n_ues = 1 + (g.rng.next_u64() % 5_000) as usize;
            let n_shards = 1 + (g.rng.next_u64() % 16) as usize;
            let shard = (g.rng.next_u64() % n_shards as u64) as usize;
            // global ids across the whole fleet plus some past the end
            let ids: Vec<usize> = (0..40)
                .map(|_| (g.rng.next_u64() % (n_ues as u64 + 64)) as usize)
                .collect();
            (n_ues, n_shards, shard, ids)
        },
        |(n_ues, n_shards, shard, ids)| {
            let map = ShardMap::new(*n_ues, *n_shards);
            let (lo, len) = map.slice_of(*shard).ok_or("no slice for the shard")?;
            let uplinks: VecDeque<Uplink> = ids
                .iter()
                .enumerate()
                .map(|(i, &gid)| {
                    Uplink::Report(UeStateReport {
                        ue_id: gid,
                        tasks_left: i as u64, // index tag: joins outputs to inputs
                        compute_left_s: 0.0,
                        offload_left_bits: 0.0,
                        distance_m: 1.0,
                    })
                })
                .collect();
            let sent = Arc::new(Mutex::new(Vec::new()));
            let inner = ScriptedTransport {
                uplinks,
                sent: Arc::clone(&sent),
            };
            let mut view = ShardView::new(inner, lo, len);

            let mut got = Vec::new();
            while let Ok(Some(u)) = view.try_recv() {
                match u {
                    Uplink::Report(r) => got.push((r.ue_id, r.tasks_left as usize)),
                    other => return Err(format!("unexpected rewrite: {other:?}")),
                }
            }
            let expected: Vec<(usize, usize)> = ids
                .iter()
                .enumerate()
                .filter(|&(_, &gid)| gid >= lo && gid < lo + len)
                .map(|(i, &gid)| (gid - lo, i))
                .collect();
            if got != expected {
                return Err(format!("uplink rewrite {got:?} != {expected:?}"));
            }
            if view.misrouted() != ids.len() - expected.len() {
                return Err(format!(
                    "misrouted {} != {} out-of-slice frames",
                    view.misrouted(),
                    ids.len() - expected.len()
                ));
            }

            // downlinks: local ids map back into the slice, results get
            // their global id restored, out-of-range locals are dropped
            let want = len.min(8);
            for local in 0..want {
                view.send_to(
                    local,
                    Downlink::Result(InferenceResult {
                        ue_id: local,
                        task_id: local as u64,
                        logits: Vec::new(),
                        argmax: 0,
                        edge_latency_s: 0.0,
                    }),
                );
            }
            view.send_to(len, Downlink::Shutdown);
            view.send_to(len + 17, Downlink::Shutdown);
            let sent = sent.lock().map_err(|_| "recorder poisoned")?;
            if sent.len() != want {
                return Err(format!(
                    "{} downlinks reached the wire, expected {want}",
                    sent.len()
                ));
            }
            for (i, (gid, frame)) in sent.iter().enumerate() {
                if *gid != lo + i {
                    return Err(format!("downlink {i} addressed to {gid}, not {}", lo + i));
                }
                match frame {
                    Downlink::Result(r) if r.ue_id == lo + i && r.task_id == i as u64 => {}
                    other => return Err(format!("downlink {i} mangled: {other:?}")),
                }
            }
            Ok(())
        },
    );
}

/// f32 slices compared as raw bit patterns — "close enough" is not the
/// contract here, byte identity is.
fn f32_bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

fn net_states_identical(a: &NetState, b: &NetState) -> Result<(), String> {
    if f32_bits(&a.params) != f32_bits(&b.params) {
        return Err("params diverged".into());
    }
    if f32_bits(&a.m) != f32_bits(&b.m) || f32_bits(&a.v) != f32_bits(&b.v) {
        return Err("Adam moments diverged".into());
    }
    if a.t != b.t {
        return Err(format!("step counters diverged: {} vs {}", a.t, b.t));
    }
    Ok(())
}

#[test]
fn update_is_thread_count_invariant() {
    // the PR-4 contract, extended to training: for random nets and random
    // minibatches, K epochs of PPO updates produce byte-identical params
    // AND Adam moments whether the sharded update engine runs on 1, 2, or
    // 4 workers — the fixed shard partition and shard-ascending reduction
    // make worker count a pure wall-time knob
    let store = ArtifactStore::native_demo();
    forall(
        61,
        4,
        |g| {
            let n = g.usize_in(3, 10).clamp(3, 10);
            let b = 256usize; // 8 shards of 32 rows, compiled for every N
            let d = 4 * n;
            let states = g.vec_f32(b * d, -1.0, 1.0);
            let a_b: Vec<i32> = (0..b).map(|_| g.usize_in(0, 5) as i32).collect();
            let a_c: Vec<i32> = (0..b).map(|_| g.usize_in(0, 1) as i32).collect();
            let a_p = g.vec_f32(b, 0.05, 0.95);
            let old_logp = g.vec_f32(b, -4.0, 0.0);
            let adv = g.vec_f32(b, -1.5, 1.5);
            let returns = g.vec_f32(b, -2.0, 0.5);
            let epochs = g.usize_in(2, 4).clamp(2, 4);
            let seed = g.rng.next_u64();
            (n, states, a_b, a_c, a_p, old_logp, adv, returns, epochs, seed)
        },
        |(n, states, a_b, a_c, a_p, old_logp, adv, returns, epochs, seed)| {
            let mut runs = Vec::new();
            for w in [1usize, 2, 4] {
                let mut actor =
                    ActorNet::new(&store, *n, *seed).map_err(|e| format!("actor: {e}"))?;
                let mut critic =
                    CriticNet::new(&store, *n, seed ^ 1).map_err(|e| format!("critic: {e}"))?;
                actor.set_update_threads(w);
                critic.set_update_threads(w);
                for _ in 0..*epochs {
                    actor
                        .update(3e-3, states, a_b, a_c, a_p, old_logp, adv)
                        .map_err(|e| format!("actor update (w={w}): {e}"))?;
                    critic
                        .update(1e-2, states, returns)
                        .map_err(|e| format!("critic update (w={w}): {e}"))?;
                }
                runs.push((w, actor.snapshot(), critic.snapshot()));
            }
            let (_, a1, c1) = &runs[0];
            for (w, aw, cw) in &runs[1..] {
                net_states_identical(a1, aw)
                    .map_err(|e| format!("actor n={n} w=1 vs w={w}: {e}"))?;
                net_states_identical(c1, cw)
                    .map_err(|e| format!("critic n={n} w=1 vs w={w}: {e}"))?;
            }
            Ok(())
        },
    );
}

// ------------------------------------------------------------ offload cache

use macci::coordinator::offload_cache::{key_head, OffloadCache};

/// A random offload: partition, optional calibration, random payload.
fn arbitrary_offload(g: &mut macci::util::check::Gen, ue_id: usize) -> OffloadRequest {
    let len = g.usize_in(0, 48);
    OffloadRequest {
        ue_id,
        task_id: g.rng.next_u64(),
        b: g.usize_in(0, 5),
        payload: (0..len).map(|_| (g.rng.next_u64() & 0xFF) as u8).collect(),
        calibration: if g.bool() {
            Some((g.f64_in(-4.0, 0.0) as f32, g.f64_in(0.0, 4.0) as f32))
        } else {
            None
        },
    }
}

/// Calibration compared the way the cache key compares it: exact bits.
fn cal_bits(c: Option<(f32, f32)>) -> Option<(u32, u32)> {
    c.map(|(lo, hi)| (lo.to_bits(), hi.to_bits()))
}

#[test]
fn offload_cache_serves_exactly_byte_identical_requests() {
    // the content-addressed key: a cached result is replayed for a later
    // request iff partition, calibration bits and payload bytes all match
    // — never across any difference, whatever the requester's ids are
    forall(
        51,
        200,
        |g| {
            let first = arbitrary_offload(g, 1);
            // half the probes are exact content clones (forced hit path),
            // half are independent draws (usually a forced miss)
            let probe = if g.bool() {
                OffloadRequest {
                    ue_id: 2,
                    task_id: g.rng.next_u64(),
                    b: first.b,
                    payload: first.payload.clone(),
                    calibration: first.calibration,
                }
            } else {
                arbitrary_offload(g, 2)
            };
            (first, probe)
        },
        |(first, probe)| {
            let mut cache = OffloadCache::new(64);
            let result = InferenceResult {
                ue_id: first.ue_id,
                task_id: first.task_id,
                logits: vec![0.25, -1.5],
                argmax: 0,
                edge_latency_s: 0.125,
            };
            cache.note_pending(first);
            cache.complete(first.ue_id, first.task_id, Some(&result));
            let same = first.b == probe.b
                && cal_bits(first.calibration) == cal_bits(probe.calibration)
                && first.payload == probe.payload;
            match cache.lookup(probe) {
                Some(hit) if same => {
                    if hit.ue_id != probe.ue_id || hit.task_id != probe.task_id {
                        return Err("hit not rebuilt under the requester's ids".into());
                    }
                    if hit.logits != result.logits || hit.argmax != result.argmax {
                        return Err("hit replayed the wrong result".into());
                    }
                    Ok(())
                }
                None if !same => Ok(()),
                Some(_) => Err(format!("cross-served: {probe:?} hit the entry for {first:?}")),
                None => Err("a byte-identical request missed".into()),
            }
        },
    );
}

#[test]
fn offload_cache_forced_head_collision_misses_on_byte_compare() {
    // two different payloads forced onto one KeyHead — a simulated FNV
    // collision, which `lookup` could never produce on its own — must be
    // separated by the full byte compare: the impostor misses, the
    // genuine payload still hits
    forall(
        52,
        200,
        |g| {
            let len = g.usize_in(1, 48);
            let p1: Vec<u8> = (0..len).map(|_| (g.rng.next_u64() & 0xFF) as u8).collect();
            let mut p2 = p1.clone();
            // flip one byte: same length, same forced head, new content
            let at = g.usize_in(0, len);
            if let Some(byte) = p2.get_mut(at) {
                *byte ^= 0x5A;
            }
            (p1, p2, g.usize_in(0, 5))
        },
        |(p1, p2, b)| {
            let mut cache = OffloadCache::new(8);
            let head = key_head(*b, None, p1);
            let result = InferenceResult {
                ue_id: 0,
                task_id: 0,
                logits: vec![1.0],
                argmax: 0,
                edge_latency_s: 0.01,
            };
            cache.insert_keyed(head, p1.clone(), &result);
            if cache.lookup_keyed(head, p2, 9, 9).is_some() {
                return Err("a forced head collision was served across payloads".into());
            }
            if cache.lookup_keyed(head, p1, 9, 9).is_none() {
                return Err("the genuine payload no longer hits".into());
            }
            Ok(())
        },
    );
}
