//! Coordinator integration: real CNN artifacts through the collaborative
//! pipeline, wire-format roundtrips, batching, and the threaded server.
//! Skipped when model artifacts are absent (`make artifacts-models`).

use std::time::Duration;

use macci::compress::ae::AeCompressor;
use macci::coordinator::batcher::{BatchItem, BatchRunner, DynamicBatcher};
use macci::coordinator::inference::CollabPipeline;
use macci::coordinator::protocol::OffloadRequest;
use macci::exp::fig4::smooth_images;
use macci::runtime::artifacts::ArtifactStore;

fn store_with_models() -> Option<ArtifactStore> {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !root.join("manifest.json").exists() {
        eprintln!("skipping: no artifacts");
        return None;
    }
    let store = ArtifactStore::open(&root).unwrap();
    if store.model("resnet18").is_err() {
        eprintln!("skipping: no model artifacts");
        return None;
    }
    if store.backend_name() == "native" {
        // the CNN backbone segments only execute on the PJRT backend
        eprintln!("skipping: model artifacts need the PJRT backend (--features xla-pjrt)");
        return None;
    }
    Some(store)
}

#[test]
fn split_inference_matches_full_model_topk() {
    let Some(store) = store_with_models() else { return };
    let pipeline = CollabPipeline::load(&store, "resnet18").unwrap();
    let images = smooth_images(3, pipeline.meta.input_hw, 11);
    let mut agree = 0;
    let mut total = 0;
    for img in &images {
        let local = pipeline.infer_local(img).unwrap();
        for p in 1..=pipeline.num_points() {
            let (logits, timing) = pipeline.infer_split(img, p).unwrap();
            assert_eq!(logits.len(), pipeline.meta.num_classes);
            assert!(logits.iter().all(|x| x.is_finite()));
            assert!(timing.wire_bits > 0);
            let am = |v: &[f32]| {
                v.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i)
                    .unwrap()
            };
            if am(&logits) == am(&local) {
                agree += 1;
            }
            total += 1;
        }
    }
    // Lossy compression on out-of-distribution probe images (the rust side
    // cannot regenerate the python training set): demand clearly-better-
    // than-chance agreement (chance = 1/16). On real dataset inputs the
    // sweep enforces <= 2% accuracy drop at build time.
    assert!(
        agree * 3 >= total,
        "top-1 agreement too low: {agree}/{total} (chance would be ~{})",
        total / 16
    );
}

#[test]
fn front_feature_roundtrip_error_is_quantization_bounded() {
    let Some(store) = store_with_models() else { return };
    let pipeline = CollabPipeline::load(&store, "resnet18").unwrap();
    let img = &smooth_images(1, pipeline.meta.input_hw, 3)[0];
    for p in 1..=pipeline.num_points() {
        let feature = pipeline.front_feature(img, p).unwrap();
        let (encoded, _t) = pipeline.ue_half(img, p).unwrap();
        let restored = pipeline.decode_feature(&encoded, p).unwrap();
        assert_eq!(feature.len(), restored.len());
        // AE is lossy; sanity: same scale, finite, correlated
        let dot: f64 = feature
            .iter()
            .zip(&restored)
            .map(|(a, b)| (*a as f64) * (*b as f64))
            .sum();
        let n1: f64 = feature.iter().map(|a| (*a as f64).powi(2)).sum::<f64>().sqrt();
        let n2: f64 = restored.iter().map(|a| (*a as f64).powi(2)).sum::<f64>().sqrt();
        let cos = dot / (n1 * n2).max(1e-9);
        assert!(cos > 0.5, "p{p}: reconstruction uncorrelated (cos {cos:.3})");
    }
}

#[test]
fn wire_format_roundtrips_through_serve_offload() {
    let Some(store) = store_with_models() else { return };
    let pipeline = CollabPipeline::load(&store, "resnet18").unwrap();
    let img = &smooth_images(1, pipeline.meta.input_hw, 5)[0];
    let p = 2;
    let (encoded, mut timing) = pipeline.ue_half(img, p).unwrap();
    let direct = pipeline.edge_half(&encoded, p, &mut timing).unwrap();

    let req = OffloadRequest {
        ue_id: 0,
        task_id: 7,
        b: p,
        payload: encoded.to_wire().unwrap(),
        calibration: Some((encoded.lo, encoded.hi)),
    };
    let result = pipeline.serve_offload(&req).unwrap();
    assert_eq!(result.task_id, 7);
    for (a, b) in direct.iter().zip(&result.logits) {
        assert!((a - b).abs() < 1e-4, "wire path must match in-process path");
    }
}

#[test]
fn raw_offload_served_via_full_model() {
    let Some(store) = store_with_models() else { return };
    let pipeline = CollabPipeline::load(&store, "resnet18").unwrap();
    let img = &smooth_images(1, pipeline.meta.input_hw, 8)[0];
    let payload: Vec<u8> = img.iter().flat_map(|v| v.to_le_bytes()).collect();
    let req = OffloadRequest {
        ue_id: 1,
        task_id: 0,
        b: 0,
        payload,
        calibration: None,
    };
    let result = pipeline.serve_offload(&req).unwrap();
    let local = pipeline.infer_local(img).unwrap();
    for (a, b) in local.iter().zip(&result.logits) {
        assert!((a - b).abs() < 1e-5);
    }
}

#[test]
fn ae_compressor_rate_matches_manifest() {
    let Some(store) = store_with_models() else { return };
    let meta = store.model("resnet18").unwrap().clone();
    for pm in &meta.points {
        let comp = AeCompressor::load(&store, "resnet18", pm.point).unwrap();
        let expect = pm.ch as f64 * 32.0 / (pm.ch_r as f64 * pm.bits as f64);
        assert!((comp.rate() - expect).abs() < 1e-9);
    }
}

#[test]
fn dynamic_batcher_flushes_by_size_and_age() {
    let Some(store) = store_with_models() else { return };
    let runner = BatchRunner::from_store(&store, "resnet18").unwrap();
    let mut batcher = DynamicBatcher::new(runner.wire_batch(), Duration::from_millis(10));
    let hw = store.model("resnet18").unwrap().input_hw;
    let images = smooth_images(9, hw, 2);
    let now = std::time::Instant::now();
    for (i, img) in images.iter().enumerate() {
        batcher.push(BatchItem {
            ue_id: i % 3,
            task_id: i as u64,
            image: img.clone(),
            enqueued: now,
        });
    }
    assert!(batcher.should_flush(now), "9 > max_batch triggers flush");
    let out = runner.run(batcher.take_batch()).unwrap();
    assert_eq!(out.len(), 8, "one full batch");
    assert_eq!(batcher.pending(), 1);
    // batched results must match b1 execution
    let pipeline = CollabPipeline::load(&store, "resnet18").unwrap();
    for o in &out {
        let direct = pipeline.infer_local(&images[o.task_id as usize]).unwrap();
        for (a, b) in direct.iter().zip(&o.logits) {
            assert!((a - b).abs() < 1e-3, "batched vs single mismatch");
        }
    }
    // age-based flush for the remainder
    std::thread::sleep(Duration::from_millis(12));
    assert!(batcher.should_flush(std::time::Instant::now()));
    let rest = runner.run(batcher.take_batch()).unwrap();
    assert_eq!(rest.len(), 1);
}
