//! Threaded-server serving integration: the offload executor under load,
//! concurrency between decision broadcasts and offload serving, and
//! graceful drain-on-shutdown. Runs fully offline on the synthetic
//! offload compute (the CNN artifacts need the PJRT backend).

use std::sync::mpsc::Receiver;
use std::sync::Arc;
use std::time::{Duration, Instant};

use macci::coordinator::decision::{DecisionMaker, StaticDecision};
use macci::coordinator::executor::{ExecutorConfig, OffloadCompute, SyntheticCompute};
use macci::coordinator::protocol::{Downlink, OffloadRequest, UeStateReport, Uplink};
use macci::coordinator::server::{EdgeServer, ServerConfig, ServerStats};
use macci::coordinator::state_pool::{StateNorm, StatePool};
use macci::env::HybridAction;

fn pool(n: usize) -> StatePool {
    StatePool::new(
        n,
        StateNorm {
            lambda_tasks: 10.0,
            frame_s: 0.5,
            max_bits: 1e6,
            d_max: 100.0,
        },
    )
}

fn decisions(n: usize) -> DecisionMaker {
    DecisionMaker::new(Box::new(StaticDecision::new(vec![
        HybridAction::new(0, 0, 0.0, 1.0);
        n
    ])))
}

fn report(ue: usize) -> Uplink {
    Uplink::Report(UeStateReport {
        ue_id: ue,
        tasks_left: 5,
        compute_left_s: 0.0,
        offload_left_bits: 0.0,
        distance_m: 40.0,
    })
}

fn raw_offload(ue: usize, task: u64, elems: usize) -> Uplink {
    // payload bytes vary with the task id so logits differ per task
    Uplink::Offload(OffloadRequest {
        ue_id: ue,
        task_id: task,
        b: 0,
        payload: vec![(task % 251) as u8; 4 * elems],
        calibration: None,
    })
}

/// The acceptance scenario: decision frames keep broadcasting while a
/// sustained offload flood is being served concurrently (bounded uplink
/// drain + worker pool — the server thread never blocks on model math).
#[test]
fn decisions_broadcast_while_offloads_flood() {
    let n = 2;
    let compute = Arc::new(SyntheticCompute::new(Duration::from_micros(300)));
    let elems = compute.image_elems;
    let mut cfg = ServerConfig::new(n, Duration::from_millis(10), usize::MAX);
    cfg.drain_limit = 32;
    cfg.exec = ExecutorConfig {
        workers: 2,
        max_batch: 8,
        max_wait: Duration::from_millis(1),
        ..ExecutorConfig::default()
    };
    let compute = Some(compute as Arc<dyn OffloadCompute>);
    let (server, mut downlinks) = EdgeServer::spawn(cfg, pool(n), decisions(n), compute).unwrap();

    for ue in 0..n {
        server.uplink.send(report(ue)).unwrap();
    }

    // UE 1 floods raw offloads from its own thread for the whole test
    let flood_uplink = server.uplink.clone();
    let flood_done = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let flood_done_tx = flood_done.clone();
    let flooder = std::thread::spawn(move || {
        let mut sent = 0u64;
        let t0 = Instant::now();
        // long flood window + generous decision budget below keep this
        // robust on oversubscribed CI machines
        while t0.elapsed() < Duration::from_millis(600) {
            flood_uplink.send(raw_offload(1, sent, elems)).unwrap();
            sent += 1;
            if sent % 2 == 0 {
                // sustained pressure, not an instantaneous burst
                std::thread::sleep(Duration::from_micros(300));
            }
        }
        flood_done_tx.store(true, std::sync::atomic::Ordering::SeqCst);
        sent
    });

    // meanwhile UE 0 must keep hearing decision frames: 3 decisions at a
    // 10 ms cadence need ~30 ms of a 600 ms flood
    let rx0 = &downlinks[0];
    let mut decisions_seen = 0;
    let deadline = Instant::now() + Duration::from_secs(5);
    while decisions_seen < 3 && Instant::now() < deadline {
        match rx0.recv_timeout(Duration::from_millis(500)) {
            Ok(Downlink::Decision(_)) => decisions_seen += 1,
            Ok(_) => {}
            Err(_) => break,
        }
    }
    let still_flooding = !flood_done.load(std::sync::atomic::Ordering::SeqCst);
    let sent = flooder.join().unwrap();
    assert!(
        decisions_seen >= 3,
        "decisions starved under offload flood: saw {decisions_seen} (flood sent {sent})"
    );
    assert!(
        still_flooding,
        "the 3rd decision must arrive while the flood is still running"
    );

    // let the flood finish serving, then wind down
    for ue in 0..n {
        server.uplink.send(Uplink::Goodbye { ue_id: ue }).unwrap();
    }
    let rx1 = downlinks.remove(1);
    let results = count_results_until_shutdown(&rx1);
    let stats = server.join();
    assert_eq!(stats.raw_offloads as u64, sent);
    assert_eq!(
        stats.offloads_served + stats.offload_errors,
        sent as usize,
        "every accepted offload must complete (drain-on-shutdown)"
    );
    assert_eq!(stats.offload_errors, 0);
    assert_eq!(results as u64, sent, "every result reaches the owning UE");
    assert!(stats.frames >= 3);
    assert!(stats.exec.batches > 0, "flood must exercise the batcher");
    assert!(stats.exec.max_queue_depth > 0);
}

fn count_results_until_shutdown(rx: &Receiver<Downlink>) -> usize {
    let mut results = 0;
    loop {
        match rx.recv_timeout(Duration::from_secs(10)) {
            Ok(Downlink::Result(_)) => results += 1,
            Ok(Downlink::Decision(_) | Downlink::Error { .. }) => {}
            Ok(Downlink::Shutdown) | Err(_) => return results,
        }
    }
}

/// Closed-loop pooled serving: every task completes, raw offloads ride
/// batches, and the executor counters land in `ServerStats`.
#[test]
fn pooled_server_serves_all_tasks_and_batches() {
    let n = 4;
    let tasks = 24u64;
    let compute = Arc::new(SyntheticCompute::new(Duration::from_micros(200)));
    let elems = compute.image_elems;
    let mut cfg = ServerConfig::new(n, Duration::from_millis(5), usize::MAX);
    cfg.exec = ExecutorConfig {
        workers: 2,
        max_batch: 4,
        max_wait: Duration::from_micros(500),
        ..ExecutorConfig::default()
    };
    let compute = Some(compute as Arc<dyn OffloadCompute>);
    let (server, downlinks) = EdgeServer::spawn(cfg, pool(n), decisions(n), compute).unwrap();

    let handles: Vec<_> = downlinks
        .into_iter()
        .enumerate()
        .map(|(ue, rx)| {
            let uplink = server.uplink.clone();
            std::thread::spawn(move || {
                uplink.send(report(ue)).unwrap();
                let mut done = 0u64;
                for task in 0..tasks {
                    uplink.send(raw_offload(ue, task, elems)).unwrap();
                    loop {
                        match rx.recv_timeout(Duration::from_secs(10)).unwrap() {
                            Downlink::Result(res) => {
                                assert_eq!(res.ue_id, ue);
                                assert_eq!(res.task_id, task);
                                assert_eq!(res.argmax, res.logits.len() - 1);
                                done += 1;
                                break;
                            }
                            Downlink::Decision(_) => {}
                            Downlink::Error { error, .. } => panic!("offload failed: {error}"),
                            Downlink::Shutdown => panic!("server shut down early"),
                        }
                    }
                }
                uplink.send(Uplink::Goodbye { ue_id: ue }).unwrap();
                done
            })
        })
        .collect();

    let total: u64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
    let stats = server.join();
    assert_eq!(total, n as u64 * tasks);
    assert_eq!(stats.offloads_served as u64, total);
    assert_eq!(stats.offload_errors, 0);
    assert!(stats.exec.batches > 0, "raw offloads must ride the batcher");
    assert!(stats.exec.batched_items as u64 == total);
    assert!(stats.exec.batch_occupancy(4) > 0.0);
    assert!(stats.frames >= 1, "decisions fire alongside serving");
}

/// A malformed raw payload turns into an `Error` NACK on the owner's
/// downlink — the server keeps running and the counter records it.
#[test]
fn malformed_payload_is_counted_not_fatal() {
    let n = 1;
    let compute = Arc::new(SyntheticCompute::new(Duration::from_micros(50)));
    let elems = compute.image_elems;
    let mut cfg = ServerConfig::new(n, Duration::from_millis(5), usize::MAX);
    cfg.exec.workers = 1;
    let compute = Some(compute as Arc<dyn OffloadCompute>);
    let (server, downlinks) = EdgeServer::spawn(cfg, pool(n), decisions(n), compute).unwrap();

    server.uplink.send(report(0)).unwrap();
    server
        .uplink
        .send(Uplink::Offload(OffloadRequest {
            ue_id: 0,
            task_id: 0,
            b: 0,
            payload: vec![0u8; 3], // not 4 * elems
            calibration: None,
        }))
        .unwrap();
    // a healthy offload right after must still be served
    server.uplink.send(raw_offload(0, 1, elems)).unwrap();

    let mut served = 0;
    let mut nacked = 0;
    let deadline = Instant::now() + Duration::from_secs(5);
    while (served == 0 || nacked == 0) && Instant::now() < deadline {
        match downlinks[0].recv_timeout(Duration::from_millis(500)) {
            Ok(Downlink::Result(res)) => {
                assert_eq!(res.task_id, 1);
                served += 1;
            }
            Ok(Downlink::Error { task_id, error }) => {
                assert_eq!(task_id, 0);
                assert!(error.contains("bytes"), "unexpected NACK text: {error}");
                nacked += 1;
            }
            _ => {}
        }
    }
    server.uplink.send(Uplink::Goodbye { ue_id: 0 }).unwrap();
    let stats: ServerStats = server.join();
    assert_eq!(served, 1);
    assert_eq!(nacked, 1, "the owner must hear about the failure");
    assert_eq!(stats.offload_errors, 1);
    assert_eq!(stats.offloads_served, 1);
}

/// Feature offloads (b >= 1) bypass the batcher and dispatch per item.
#[test]
fn feature_offloads_are_served_individually() {
    let n = 1;
    let compute = Arc::new(SyntheticCompute::new(Duration::from_micros(50)));
    let mut cfg = ServerConfig::new(n, Duration::from_millis(5), usize::MAX);
    cfg.exec.workers = 2;
    let compute = Some(compute as Arc<dyn OffloadCompute>);
    let (server, downlinks) = EdgeServer::spawn(cfg, pool(n), decisions(n), compute).unwrap();

    server.uplink.send(report(0)).unwrap();
    for task in 0..6u64 {
        server
            .uplink
            .send(Uplink::Offload(OffloadRequest {
                ue_id: 0,
                task_id: task,
                b: 2,
                payload: vec![7u8; 11],
                calibration: Some((0.0, 1.0)),
            }))
            .unwrap();
    }
    let mut got = 0;
    let deadline = Instant::now() + Duration::from_secs(5);
    while got < 6 && Instant::now() < deadline {
        if let Ok(Downlink::Result(_)) = downlinks[0].recv_timeout(Duration::from_millis(500)) {
            got += 1;
        }
    }
    server.uplink.send(Uplink::Goodbye { ue_id: 0 }).unwrap();
    let stats = server.join();
    assert_eq!(got, 6);
    assert_eq!(stats.feature_offloads, 6);
    assert_eq!(stats.exec.batches, 0, "features must not enter the batcher");
    assert_eq!(stats.offloads_served, 6);
}

/// Tentpole acceptance: a policy published mid-serve is applied between
/// decision frames with ZERO missed broadcasts — every UE receives every
/// frame the server issued, the swap counter records the apply, and the
/// served decisions visibly change policy.
#[test]
fn policy_swap_mid_serve_loses_no_broadcasts() {
    use macci::coordinator::decision::ActorDecision;
    use macci::rl::checkpoint::PolicySnapshot;
    use macci::runtime::artifacts::ArtifactStore;

    let store = ArtifactStore::native_demo();
    let n = 3;
    let max_frames = 20;
    let source = ActorDecision::untrained(&store, n, 1.0, 4).unwrap();
    let dm = DecisionMaker::new(Box::new(source));
    let handle = dm.policy_handle();
    // a roomy interval: the publish below (after ~4 frames) must land well
    // before the last frame, even on a loaded CI machine
    let cfg = ServerConfig::new(n, Duration::from_millis(20), max_frames);
    let (server, downlinks) = EdgeServer::spawn(cfg, pool(n), dm, None).unwrap();
    for ue in 0..n {
        server.uplink.send(report(ue)).unwrap();
    }

    // read a few pre-swap frames from UE 0, then publish a new policy
    let pre_swap = 4;
    let mut first: Option<std::sync::Arc<[HybridAction]>> = None;
    let mut got = vec![0usize; n];
    for _ in 0..pre_swap {
        match downlinks[0].recv_timeout(Duration::from_secs(5)).unwrap() {
            Downlink::Decision(d) => {
                got[0] += 1;
                first.get_or_insert(d.actions);
            }
            other => panic!("expected a decision, got {other:?}"),
        }
    }
    let snap = PolicySnapshot {
        version: 7,
        actors: (0..n)
            .map(|i| {
                macci::runtime::nets::ActorNet::new(&store, n, 888 + i as u64)
                    .unwrap()
                    .params
            })
            .collect(),
    };
    assert!(handle.publish(snap));

    // drain everything until shutdown, counting per-UE broadcasts
    let mut last: Option<std::sync::Arc<[HybridAction]>> = None;
    for (ue, rx) in downlinks.iter().enumerate() {
        loop {
            match rx.recv_timeout(Duration::from_secs(10)).unwrap() {
                Downlink::Decision(d) => {
                    got[ue] += 1;
                    if ue == 0 {
                        last = Some(d.actions);
                    }
                }
                Downlink::Shutdown => break,
                other => panic!("unexpected downlink {other:?}"),
            }
        }
    }
    for ue in 0..n {
        server.uplink.send(Uplink::Goodbye { ue_id: ue }).ok();
    }
    let stats = server.join();

    assert_eq!(stats.frames, max_frames);
    for (ue, &g) in got.iter().enumerate() {
        assert_eq!(
            g, max_frames,
            "UE {ue} missed a broadcast across the swap"
        );
    }
    assert_eq!(stats.policy_swaps, 1, "exactly one swap must be applied");
    assert_ne!(
        first.unwrap(),
        last.unwrap(),
        "the published policy must change served decisions"
    );
}

/// A server serving `from_checkpoint` emits exactly the decisions of one
/// using `from_actors` on the live trainer's nets — deployment through
/// the file format is bit-transparent.
#[test]
fn from_checkpoint_serves_identically_to_from_actors() {
    use macci::coordinator::decision::ActorDecision;
    use macci::env::scenario::ScenarioConfig;
    use macci::profiles::DeviceProfile;
    use macci::rl::mahppo::{MahppoTrainer, TrainConfig};
    use macci::runtime::artifacts::ArtifactStore;
    use macci::util::rng::Rng;

    let store = ArtifactStore::native_demo();
    let scenario = ScenarioConfig {
        n_ues: 3,
        lambda_tasks: 12.0,
        ..Default::default()
    };
    let n = scenario.n_ues;
    let cfg = TrainConfig {
        buffer_size: 256,
        minibatch: 256,
        reuse: 1,
        seed: 33,
        ..Default::default()
    };
    let mut trainer =
        MahppoTrainer::new(&store, &DeviceProfile::synthetic(), scenario.clone(), cfg).unwrap();
    trainer.train(256).unwrap();

    let dir = std::env::temp_dir().join(format!("macci_serve_ckpt_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("policy.ckpt");
    trainer.save(&path).unwrap();

    let p_max = trainer.scenario.p_max;
    let n_choices = store.rl().unwrap().n_partition;
    let live = ActorDecision::from_actors(trainer.actors, p_max, n_choices);
    let mut dm_live = DecisionMaker::new(Box::new(live));
    let mut dm_ckpt =
        DecisionMaker::new(Box::new(ActorDecision::from_checkpoint(&store, &path).unwrap()));

    let mut rng = Rng::new(2);
    for frame in 0..16 {
        let state: Vec<f32> = (0..4 * n).map(|_| rng.f32()).collect();
        let a = dm_live.next_decision(&state).unwrap();
        let b = dm_ckpt.next_decision(&state).unwrap();
        assert_eq!(a.frame, b.frame);
        assert_eq!(a.actions, b.actions, "frame {frame} diverged");
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// The offload cache must be invisible in the data: an identical request
/// stream yields bit-identical per-task logits with the cache on and
/// off, because a hit replays the stored result verbatim (only the
/// requester's ids are rewritten). One closed-loop UE keeps the stream
/// serial, so the hit/miss split is exact: the first occurrence of each
/// distinct payload misses, every repeat hits.
#[test]
fn cached_results_are_bit_identical_to_uncached() {
    let tasks = 24u64;
    let distinct = 4u64;

    let run = |cache_entries: usize| -> (Vec<Vec<u32>>, ServerStats) {
        let compute = Arc::new(SyntheticCompute::new(Duration::from_micros(50)));
        let elems = compute.image_elems;
        let mut cfg = ServerConfig::new(1, Duration::from_millis(10), usize::MAX);
        cfg.offload_cache = cache_entries;
        cfg.exec = ExecutorConfig {
            workers: 2,
            max_wait: Duration::from_micros(100),
            ..ExecutorConfig::default()
        };
        let compute = Some(compute as Arc<dyn OffloadCompute>);
        let (server, mut downlinks) =
            EdgeServer::spawn(cfg, pool(1), decisions(1), compute).unwrap();
        let rx = downlinks.remove(0);
        server.uplink.send(report(0)).unwrap();

        let mut logits: Vec<Vec<u32>> = Vec::new();
        for task in 0..tasks {
            server
                .uplink
                .send(Uplink::Offload(OffloadRequest {
                    ue_id: 0,
                    task_id: task,
                    b: 0,
                    payload: vec![(task % distinct) as u8 + 1; 4 * elems],
                    calibration: None,
                }))
                .unwrap();
            loop {
                match rx.recv_timeout(Duration::from_secs(15)).unwrap() {
                    Downlink::Result(r) => {
                        assert_eq!(r.task_id, task);
                        logits.push(r.logits.iter().map(|l| l.to_bits()).collect());
                        break;
                    }
                    Downlink::Decision(_) => {}
                    other => panic!("unexpected downlink: {other:?}"),
                }
            }
        }
        server.uplink.send(Uplink::Goodbye { ue_id: 0 }).unwrap();
        (logits, server.join())
    };

    let (uncached, off_stats) = run(0);
    let (cached, on_stats) = run(64);

    assert_eq!(uncached, cached, "cache changed some task's logits");
    assert_eq!(
        off_stats.cache.hits + off_stats.cache.misses,
        0,
        "a disabled cache must never be consulted"
    );
    assert_eq!(on_stats.cache.misses, distinct, "one miss per distinct payload");
    assert_eq!(on_stats.cache.hits, tasks - distinct, "every repeat is a hit");
    assert!(on_stats.cache.bytes_saved > 0);
    assert_eq!(off_stats.offloads_served as u64, tasks);
    assert_eq!(on_stats.offloads_served as u64, tasks);
}
