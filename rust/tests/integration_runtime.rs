//! Integration: the runtime executing real artifacts end-to-end.
//!
//! Runs on whatever backend `ArtifactStore::open` resolves — by default the
//! pure-Rust native backend with the built-in RL demo manifest, so these
//! tests run (not skip) on a fresh offline checkout. With compiled
//! artifacts present the same assertions hold against the real manifest.

use macci::runtime::artifacts::ArtifactStore;
use macci::runtime::backend::Executable;
use macci::runtime::nets::{ActorNet, CriticNet};
use macci::runtime::tensor::TensorView;
use macci::util::rng::Rng;

fn store() -> ArtifactStore {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    ArtifactStore::open(root).expect("artifact store")
}

#[test]
fn actor_forward_produces_distributions() {
    let store = store();
    let mut actor = ActorNet::new(&store, 5, 1).unwrap();
    let state = vec![0.25f32; 20];
    let out = actor.forward(&state).unwrap();
    assert_eq!(out.probs_b.len(), 6);
    assert_eq!(out.probs_c.len(), 2);
    let sum_b: f32 = out.probs_b.iter().sum();
    let sum_c: f32 = out.probs_c.iter().sum();
    assert!((sum_b - 1.0).abs() < 1e-4, "probs_b sums to {sum_b}");
    assert!((sum_c - 1.0).abs() < 1e-4, "probs_c sums to {sum_c}");
    assert!(out.probs_b.iter().all(|&p| (0.0..=1.0).contains(&p)));
    assert!(out.log_std <= 1.0 && out.log_std >= -4.0);
}

#[test]
fn actor_forward_is_deterministic() {
    let store = store();
    let mut actor = ActorNet::new(&store, 3, 7).unwrap();
    let state = vec![0.5f32; 12];
    let a = actor.forward(&state).unwrap();
    let b = actor.forward(&state).unwrap();
    assert_eq!(a.probs_b, b.probs_b);
    assert_eq!(a.mu, b.mu);
}

#[test]
fn cached_and_uncached_forward_agree() {
    let store = store();
    let mut actor = ActorNet::new(&store, 4, 3).unwrap();
    let state = vec![0.1f32; 16];
    let cached = actor.forward(&state).unwrap();
    let uncached = actor.forward_uncached(&state).unwrap();
    assert_eq!(cached.probs_b, uncached.probs_b);
    assert_eq!(cached.probs_c, uncached.probs_c);
    assert_eq!(cached.mu, uncached.mu);
}

#[test]
fn critic_value_finite_and_state_sensitive() {
    let store = store();
    let mut critic = CriticNet::new(&store, 5, 3).unwrap();
    let v0 = critic.value(&vec![0.0f32; 20]).unwrap();
    let v1 = critic.value(&vec![1.0f32; 20]).unwrap();
    assert!(v0.is_finite() && v1.is_finite());
    assert_ne!(v0, v1, "critic must react to the state");
}

#[test]
fn actor_update_moves_params_toward_advantage() {
    let store = store();
    let mut actor = ActorNet::new(&store, 5, 11).unwrap();
    let b = 256usize;
    let mut rng = Rng::new(5);
    let states: Vec<f32> = (0..b * 20).map(|_| rng.f32()).collect();
    // pick action (b=2, c=1) everywhere with positive advantage: its
    // probability must increase after a few updates
    let a_b = vec![2i32; b];
    let a_c = vec![1i32; b];
    let a_p = vec![0.3f32; b];
    let probe = vec![0.5f32; 20];
    let before = actor.forward(&probe).unwrap();
    // old_logp from the current policy (ratio starts at ~1)
    let mut old_logp = vec![0.0f32; b];
    for i in 0..b {
        let st = &states[i * 20..(i + 1) * 20];
        let out = actor.forward(st).unwrap();
        old_logp[i] = out.probs_b[2].max(1e-8).ln()
            + out.probs_c[1].max(1e-8).ln()
            + macci::rl::sampling::gaussian_log_prob(0.3, out.mu, out.log_std);
    }
    let adv = vec![1.0f32; b];
    let mut last_stats = Default::default();
    for _ in 0..5 {
        last_stats = actor
            .update(3e-3, &states, &a_b, &a_c, &a_p, &old_logp, &adv)
            .unwrap();
    }
    let after = actor.forward(&probe).unwrap();
    assert!(
        after.probs_b[2] > before.probs_b[2],
        "p(b=2) {} -> {} should increase",
        before.probs_b[2],
        after.probs_b[2]
    );
    assert!(
        after.probs_c[1] > before.probs_c[1],
        "p(c=1) {} -> {} should increase",
        before.probs_c[1],
        after.probs_c[1]
    );
    assert!(last_stats.entropy.is_finite());
    assert_eq!(actor.steps(), 5);
}

#[test]
fn critic_update_reduces_value_loss() {
    let store = store();
    let mut critic = CriticNet::new(&store, 5, 13).unwrap();
    let b = 256usize;
    let mut rng = Rng::new(6);
    let states: Vec<f32> = (0..b * 20).map(|_| rng.f32()).collect();
    let returns: Vec<f32> = (0..b).map(|i| -1.0 - (i % 7) as f32 * 0.1).collect();
    let first = critic.update(1e-2, &states, &returns).unwrap();
    let mut last = first;
    for _ in 0..20 {
        last = critic.update(1e-2, &states, &returns).unwrap();
    }
    assert!(
        last < first * 0.5,
        "value loss should drop: {first} -> {last}"
    );
}

#[test]
fn rl_metadata_covers_paper_range() {
    let store = store();
    let rl = store.rl().unwrap();
    assert_eq!(rl.n_range, (3..=10).collect::<Vec<_>>());
    assert_eq!(rl.n_partition, 6);
    assert_eq!(rl.n_channels, 2);
    // N=5 has the fig9 batch-size matrix
    let batches = store.update_batches(5).unwrap();
    assert!(batches.contains(&128) && batches.contains(&256) && batches.contains(&512));
}

#[test]
fn executable_reports_stats_and_rejects_bad_inputs() {
    let store = store();
    let exe = store.load("critic_fwd_n3_b1").unwrap();
    assert_eq!(exe.stats().calls, 0);
    let size = *store.rl().unwrap().critic_size.get(&3).unwrap();
    let params = TensorView::f32(vec![0.0; size], vec![size]).unwrap();
    let state = TensorView::f32(vec![0.0; 12], vec![1, 12]).unwrap();
    let outs = exe.call_refs(&[&params, &state]).unwrap();
    assert_eq!(outs.len(), 1);
    assert_eq!(exe.stats().calls, 1);
    assert!(exe.stats().total_ns > 0);
    // wrong parameter count must error, not crash
    let bad = TensorView::f32(vec![0.0; 3], vec![3]).unwrap();
    assert!(exe.call_refs(&[&bad, &state]).is_err());
    // wrong dtype must error
    let istate = TensorView::i32(vec![0; 12], vec![1, 12]).unwrap();
    assert!(exe.call_refs(&[&params, &istate]).is_err());
}

#[test]
fn backbone_artifacts_unsupported_natively() {
    // only meaningful when running on the native backend with a real
    // manifest that includes CNN segments; on the demo manifest the
    // artifact simply does not exist — both are errors, never a panic
    let store = store();
    if store.backend_name() == "native" {
        assert!(macci::coordinator::inference::CollabPipeline::load(&store, "resnet18").is_err());
    }
}
