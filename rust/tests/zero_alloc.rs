//! Proof of the data plane's alloc-free steady state (DESIGN.md
//! §Data-Plane): a counting global allocator wraps the system allocator
//! and the single test in this binary (single on purpose — a sibling
//! test running in parallel would pollute the counters) drives the
//! serving hot paths with warmed buffers, asserting the allocation
//! counter does not move:
//!
//! * encode → write: [`encode_frame_into`] / [`encode_frame_append`]
//!   into a reused wire buffer,
//! * decision fan-out: one [`encode_decision_body`] plus per-connection
//!   [`encode_down_to_raw`] stamps,
//! * read → route: [`read_frame_into`] with a reused body scratch,
//! * [`FramePool`] get/put recycling within one size class.
//!
//! ci.sh runs this file as its own step (`cargo test --test zero_alloc`)
//! so a regression fails CI loudly instead of surfacing as a slow drift
//! in bench numbers.

use std::alloc::{GlobalAlloc, Layout, System};
use std::hint::black_box;
use std::io::Cursor;
use std::sync::atomic::{AtomicU64, Ordering};

use macci::coordinator::protocol::{UeStateReport, Uplink};
use macci::coordinator::wire::{
    encode_decision_body, encode_down_to_raw, encode_frame_append, encode_frame_into,
    read_frame_into, Frame, FramePool, TAG_DECISION,
};
use macci::env::HybridAction;

/// Counts every allocator entry point that hands out or regrows memory.
/// Frees are deliberately uncounted: the invariant under test is "no new
/// memory on the steady-state path", and shrinking churn would surface
/// as the matching alloc when the buffer regrows.
struct CountingAlloc;

static ALLOC_CALLS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::SeqCst);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::SeqCst);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::SeqCst);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

/// Allocator calls made while running `f`.
fn allocs_during(f: impl FnOnce()) -> u64 {
    let before = ALLOC_CALLS.load(Ordering::SeqCst);
    f();
    ALLOC_CALLS.load(Ordering::SeqCst) - before
}

#[test]
fn steady_state_serving_paths_do_not_allocate() {
    let report = Frame::Up(Uplink::Report(UeStateReport {
        ue_id: 7,
        tasks_left: 42,
        compute_left_s: 0.25,
        offload_left_bits: 1.5e5,
        distance_m: 63.0,
    }));
    let actions: Vec<HybridAction> = (0..32)
        .map(|i| HybridAction::new(i % 5, i % 4, 0.5, 1.0))
        .collect();

    // warm every reused buffer once: the first touch may grow capacity.
    // `wire` is warmed to its *worst case* — the two-frame batch below —
    // so no measured loop ever outgrows it
    let mut wire = Vec::new();
    encode_frame_into(&report, &mut wire);
    let report_bytes = wire.clone();
    encode_frame_append(&report, &mut wire);
    let mut body = Vec::new();
    let mut conn_buf = Vec::new();
    encode_decision_body(0, &actions, &mut body);
    encode_down_to_raw(0, TAG_DECISION, &body, &mut conn_buf);
    let mut rx_body = Vec::new();
    read_frame_into(&mut Cursor::new(report_bytes.as_slice()), &mut rx_body)
        .expect("warmup read");

    // encode → write: a reused buffer takes frame after frame without
    // touching the allocator
    let n = allocs_during(|| {
        for _ in 0..1000 {
            encode_frame_into(black_box(&report), &mut wire);
            black_box(wire.as_slice());
        }
    });
    assert_eq!(n, 0, "encode_frame_into allocated on the steady state");

    // appended multi-frame batches: same invariant via _append + clear
    let n = allocs_during(|| {
        for _ in 0..1000 {
            wire.clear();
            encode_frame_append(black_box(&report), &mut wire);
            encode_frame_append(black_box(&report), &mut wire);
            black_box(wire.as_slice());
        }
    });
    assert_eq!(n, 0, "encode_frame_append allocated on the steady state");

    // decision fan-out: the body is encoded once per frame, then stamped
    // once per connection — no per-subscriber encode, no per-subscriber
    // allocation
    let n = allocs_during(|| {
        for frame in 0..200usize {
            body.clear();
            let tag = encode_decision_body(black_box(frame), &actions, &mut body);
            for ue in 0..32usize {
                conn_buf.clear();
                encode_down_to_raw(ue, tag, &body, &mut conn_buf);
                black_box(conn_buf.as_slice());
            }
        }
    });
    assert_eq!(n, 0, "decision fan-out allocated on the steady state");

    // read → route: scalar frames decode into a reused body scratch with
    // nothing left on the heap
    let n = allocs_during(|| {
        for _ in 0..1000 {
            let mut r = Cursor::new(report_bytes.as_slice());
            let f = read_frame_into(&mut r, &mut rx_body).expect("read warm frame");
            black_box(&f);
        }
    });
    assert_eq!(n, 0, "read_frame_into allocated on the steady state");

    // pool recycling: after one warmup miss, a get/put cycle inside one
    // size class never allocates
    let mut pool = FramePool::new();
    let warm = pool.get(4096);
    pool.put(warm);
    let n = allocs_during(|| {
        for _ in 0..1000 {
            let mut buf = pool.get(4096);
            buf.extend_from_slice(&[0u8; 64]);
            black_box(buf.as_slice());
            pool.put(buf);
        }
    });
    assert_eq!(n, 0, "FramePool get/put allocated on the steady state");
    let (hits, misses) = pool.stats();
    assert_eq!(misses, 1, "only the warmup get may miss");
    assert_eq!(hits, 1000, "every steady-state get is a recycle");
}
