//! Pluggable transports carrying the UE ⇄ edge-server protocol.
//!
//! The coordinator ([`crate::coordinator::server`]) speaks two small
//! traits instead of concrete channels, so the same `server_loop` serves
//! in-process simulations and real remote UEs:
//!
//! * [`ServerTransport`] — the server's side: poll uplink frames from all
//!   connected UEs, push downlink frames to one UE.
//! * [`ClientTransport`] — one UE's side: send uplinks, receive downlinks.
//!
//! Two implementations ship:
//!
//! * [`channel`] — the original in-process mpsc pair, zero behavior
//!   change for simulations, tests and benches.
//! * [`tcp`] — real sockets over `std::net` + threads (the offline build
//!   has no tokio; see DESIGN.md §Substitutions), speaking the
//!   byte-level codec of [`crate::coordinator::wire`] with a per-UE
//!   session handshake and bounded per-connection write queues
//!   (slow-consumer eviction) for backpressure.
//! * [`reactor`] — the fleet-scale variant: one nonblocking reactor
//!   thread sweeps every socket (no thread per connection), multiplexes
//!   many UEs per connection, and feeds per-shard
//!   [`reactor::ReactorShardTransport`] endpoints (DESIGN.md
//!   §Sharded-Serving).
//!
//! [`ue`] adds [`ue::UeClient`], a client-side convenience wrapper over
//! any [`ClientTransport`] (report / offload / await-result helpers).

pub mod channel;
pub mod reactor;
pub mod tcp;
pub mod ue;

use std::time::Duration;

use crate::coordinator::protocol::{Downlink, FrameDecision, Uplink};
use crate::coordinator::wire::WireError;

/// Why a transport can no longer move frames.
#[derive(Debug)]
pub enum TransportError {
    /// No peer can ever speak again (every client gone, or the socket
    /// closed). Terminal: the server treats this as shutdown.
    Closed,
    /// The byte stream violated the wire protocol.
    Wire(WireError),
}

impl std::fmt::Display for TransportError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TransportError::Closed => write!(f, "transport closed"),
            TransportError::Wire(e) => write!(f, "wire protocol: {e}"),
        }
    }
}

impl std::error::Error for TransportError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            TransportError::Wire(e) => Some(e),
            TransportError::Closed => None,
        }
    }
}

impl From<WireError> for TransportError {
    fn from(e: WireError) -> TransportError {
        TransportError::Wire(e)
    }
}

/// The server's view of the radio link: every connected UE multiplexed
/// into one uplink stream, with per-UE downlink addressing.
///
/// Implementations decode/validate frames internally — `try_recv` only
/// ever yields well-formed [`Uplink`] values, and the only error it
/// reports is [`TransportError::Closed`].
pub trait ServerTransport: Send {
    /// Non-blocking poll for the next uplink frame. `Ok(None)` means
    /// nothing is pending right now; `Err(Closed)` means no client can
    /// ever speak again (the server loop treats it as shutdown).
    fn try_recv(&mut self) -> Result<Option<Uplink>, TransportError>;

    /// Queue `frame` for delivery to `ue_id`. Best-effort and
    /// non-blocking for the caller: frames to unknown or disconnected
    /// UEs are dropped (a vanished client must not crash the server),
    /// and a client whose bounded write queue overflows may be evicted —
    /// the routing thread never stalls on one peer.
    fn send_to(&mut self, ue_id: usize, frame: Downlink);

    /// Fan one frame's decision out to `targets` — pairs of
    /// `(ue_id, action_index)` into `d.actions`. With `per_ue` false
    /// every target receives the full joint decision (sharing the
    /// action table is an `Arc` refcount bump per target); with `per_ue`
    /// true each target receives a slim decision holding only its own
    /// action row. The default is a plain `send_to` loop — transports
    /// with a cheaper fan-out (the reactor's single-encode broadcast)
    /// override it, and must stay frame-for-frame equivalent to this
    /// loop (asserted by `rust/tests/integration_transport.rs`).
    fn broadcast_decision(&mut self, d: &FrameDecision, targets: &[(usize, usize)], per_ue: bool) {
        for &(ue_id, idx) in targets {
            if per_ue {
                let Some(&action) = d.actions.get(idx) else {
                    continue;
                };
                let actions: std::sync::Arc<[_]> = std::sync::Arc::new([action]);
                self.send_to(
                    ue_id,
                    Downlink::Decision(FrameDecision {
                        frame: d.frame,
                        actions,
                    }),
                );
            } else {
                self.send_to(ue_id, Downlink::Decision(d.clone()));
            }
        }
    }

    /// Downlink frames dropped on the floor by backpressure (a bounded
    /// queue or write buffer was full) since the last call — drains the
    /// counter. Frames to unknown/disconnected UEs are *not* counted:
    /// those are expected churn, not silent loss. The server loop folds
    /// this into `ServerStats::downlink_drops` so drops are visible in
    /// stats and benches instead of vanishing into a log line.
    fn take_drops(&mut self) -> usize {
        0
    }
}

/// One UE's view of the radio link.
pub trait ClientTransport: Send {
    /// The UE id this transport was registered under.
    fn ue_id(&self) -> usize;

    /// Send one uplink frame to the server.
    fn send(&mut self, frame: Uplink) -> Result<(), TransportError>;

    /// Wait up to `timeout` for the next downlink frame. `Ok(None)` on
    /// timeout; `Err(Closed)` once the server is gone.
    fn recv_timeout(&mut self, timeout: Duration) -> Result<Option<Downlink>, TransportError>;
}
