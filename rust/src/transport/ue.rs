//! Client-side convenience wrapper: one UE's session over any
//! [`ClientTransport`] (in-process channels or TCP), with the
//! report → decision → offload → result call patterns the examples and
//! integration tests share.

use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use super::{ClientTransport, TransportError};
use crate::coordinator::protocol::{
    Downlink, FrameDecision, InferenceResult, OffloadRequest, SESSION_ERROR_TASK, UeStateReport,
    Uplink,
};

/// One UE's session with the edge server.
pub struct UeClient<T: ClientTransport> {
    transport: T,
}

impl<T: ClientTransport> UeClient<T> {
    pub fn new(transport: T) -> UeClient<T> {
        UeClient { transport }
    }

    pub fn ue_id(&self) -> usize {
        self.transport.ue_id()
    }

    /// Send this frame's state report (stamped with the session's id).
    pub fn report(&mut self, mut report: UeStateReport) -> Result<(), TransportError> {
        report.ue_id = self.transport.ue_id();
        self.transport.send(Uplink::Report(report))
    }

    /// Ship an offload payload to the edge (stamped with the session's
    /// id). `calibration` is required whenever `b >= 1` — the server
    /// NACKs calibration-less feature offloads at admission.
    pub fn offload(
        &mut self,
        task_id: u64,
        b: usize,
        payload: Vec<u8>,
        calibration: Option<(f32, f32)>,
    ) -> Result<(), TransportError> {
        self.transport.send(Uplink::Offload(OffloadRequest {
            ue_id: self.transport.ue_id(),
            task_id,
            b,
            payload,
            calibration,
        }))
    }

    /// Announce that this UE finished all tasks and is leaving.
    pub fn goodbye(&mut self) -> Result<(), TransportError> {
        let ue_id = self.transport.ue_id();
        self.transport.send(Uplink::Goodbye { ue_id })
    }

    /// Next downlink frame, if one arrives within `timeout`.
    pub fn recv(&mut self, timeout: Duration) -> Result<Option<Downlink>, TransportError> {
        self.transport.recv_timeout(timeout)
    }

    /// Wait for the next decision broadcast, skipping results/NACKs for
    /// other exchanges.
    pub fn await_decision(&mut self, timeout: Duration) -> Result<FrameDecision> {
        let deadline = Instant::now() + timeout;
        loop {
            let left = deadline.saturating_duration_since(Instant::now());
            if left.is_zero() {
                return Err(anyhow!("no decision within {timeout:?}"));
            }
            match self.transport.recv_timeout(left)? {
                Some(Downlink::Decision(d)) => return Ok(d),
                Some(Downlink::Shutdown) => return Err(anyhow!("server shut down")),
                Some(Downlink::Error { task_id, error }) if task_id == SESSION_ERROR_TASK => {
                    return Err(anyhow!("session failed: {error}"))
                }
                Some(_) | None => continue,
            }
        }
    }

    /// Wait for `task_id`'s inference result, skipping decision
    /// broadcasts. A `Downlink::Error` NACK for this task becomes an
    /// `Err` carrying the server's message.
    pub fn await_result(&mut self, task_id: u64, timeout: Duration) -> Result<InferenceResult> {
        let deadline = Instant::now() + timeout;
        loop {
            let left = deadline.saturating_duration_since(Instant::now());
            if left.is_zero() {
                return Err(anyhow!("no result for task {task_id} within {timeout:?}"));
            }
            match self.transport.recv_timeout(left)? {
                Some(Downlink::Result(r)) if r.task_id == task_id => return Ok(r),
                Some(Downlink::Error { task_id: t, error }) if t == SESSION_ERROR_TASK => {
                    return Err(anyhow!("session failed: {error}"))
                }
                Some(Downlink::Error { task_id: t, error }) if t == task_id => {
                    return Err(anyhow!("task {task_id} NACKed by the edge: {error}"))
                }
                Some(Downlink::Shutdown) => {
                    return Err(anyhow!("server shut down before task {task_id} completed"))
                }
                Some(_) | None => continue,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transport::channel::channel_transport;
    use crate::transport::ServerTransport;

    #[test]
    fn helpers_stamp_the_session_id_and_match_results() {
        let (mut server, mut clients) = channel_transport(2);
        let mut ue = UeClient::new(clients.remove(1));
        assert_eq!(ue.ue_id(), 1);

        // report/offload are re-stamped with the session id
        ue.report(UeStateReport {
            ue_id: 99,
            tasks_left: 1,
            compute_left_s: 0.0,
            offload_left_bits: 0.0,
            distance_m: 10.0,
        })
        .unwrap();
        ue.offload(5, 0, vec![0u8; 4], None).unwrap();
        match server.try_recv().unwrap() {
            Some(Uplink::Report(r)) => assert_eq!(r.ue_id, 1),
            other => panic!("expected report, got {other:?}"),
        }
        match server.try_recv().unwrap() {
            Some(Uplink::Offload(o)) => {
                assert_eq!((o.ue_id, o.task_id), (1, 5));
            }
            other => panic!("expected offload, got {other:?}"),
        }

        // await_result skips decisions and NACKs for other tasks
        server.send_to(
            1,
            Downlink::Error {
                task_id: 4,
                error: "other task".into(),
            },
        );
        server.send_to(
            1,
            Downlink::Result(InferenceResult {
                ue_id: 1,
                task_id: 5,
                logits: vec![0.0, 1.0],
                argmax: 1,
                edge_latency_s: 0.0,
            }),
        );
        let r = ue.await_result(5, Duration::from_secs(2)).unwrap();
        assert_eq!(r.argmax, 1);

        // a NACK for the awaited task is an error with the server's text
        server.send_to(
            1,
            Downlink::Error {
                task_id: 6,
                error: "no calibration".into(),
            },
        );
        let err = ue.await_result(6, Duration::from_secs(2)).unwrap_err();
        assert!(format!("{err:#}").contains("no calibration"));
    }
}
