//! The in-process transport: the original mpsc channel pair, now behind
//! the [`ServerTransport`]/[`ClientTransport`] traits.
//!
//! Frames move as Rust values — no serialization — so simulations, tests
//! and benches keep their exact pre-transport behavior and cost. The
//! threaded server's public channel API
//! ([`crate::coordinator::server::EdgeServer::spawn`]) is built on
//! [`ChannelServerTransport::from_parts`].

use std::sync::mpsc::{
    sync_channel, Receiver, RecvTimeoutError, SyncSender, TryRecvError, TrySendError,
};
use std::time::Duration;

use super::{ClientTransport, ServerTransport, TransportError};
use crate::coordinator::protocol::{Downlink, Uplink};

/// Uplink frames buffered across all in-process UEs before senders block
/// (global backpressure toward the producers, never unbounded RAM).
pub const UPLINK_QUEUE: usize = 4096;
/// Downlink frames one UE may leave undrained before further frames to it
/// are dropped — the in-process mirror of the TCP slow-consumer policy.
pub const DOWNLINK_QUEUE: usize = 1024;

/// Server side of the in-process transport: one shared uplink receiver
/// plus one downlink sender per UE.
pub struct ChannelServerTransport {
    uplink: Receiver<Uplink>,
    downlinks: Vec<SyncSender<Downlink>>,
    /// Frames dropped because a UE's bounded downlink queue was full —
    /// drained by [`ServerTransport::take_drops`] so the loss is counted
    /// in `ServerStats`, never silent.
    drops: usize,
}

impl ChannelServerTransport {
    /// Wrap raw channel halves (the server keeps handing out the matching
    /// `SyncSender<Uplink>` / `Receiver<Downlink>` ends to in-process UEs).
    pub fn from_parts(
        uplink: Receiver<Uplink>,
        downlinks: Vec<SyncSender<Downlink>>,
    ) -> ChannelServerTransport {
        ChannelServerTransport {
            uplink,
            downlinks,
            drops: 0,
        }
    }
}

impl ServerTransport for ChannelServerTransport {
    fn try_recv(&mut self) -> Result<Option<Uplink>, TransportError> {
        match self.uplink.try_recv() {
            Ok(u) => Ok(Some(u)),
            Err(TryRecvError::Empty) => Ok(None),
            // every sender clone dropped: no client can ever speak again
            Err(TryRecvError::Disconnected) => Err(TransportError::Closed),
        }
    }

    fn send_to(&mut self, ue_id: usize, frame: Downlink) {
        if let Some(tx) = self.downlinks.get(ue_id) {
            match tx.try_send(frame) {
                Ok(()) => {}
                Err(TrySendError::Full(_)) => {
                    // a UE that stopped draining must not stall the server
                    // loop: drop the frame, mirroring the TCP transport's
                    // slow-consumer policy — but count it, so the loss
                    // surfaces in ServerStats instead of vanishing
                    self.drops += 1;
                    log::warn!("UE {ue_id} downlink queue full — frame dropped");
                }
                // a UE that dropped its receiver simply misses the frame
                Err(TrySendError::Disconnected(_)) => {}
            }
        }
    }

    fn take_drops(&mut self) -> usize {
        std::mem::take(&mut self.drops)
    }
}

/// Client side of the in-process transport.
pub struct ChannelClientTransport {
    ue_id: usize,
    uplink: SyncSender<Uplink>,
    downlink: Receiver<Downlink>,
}

impl ChannelClientTransport {
    pub fn new(
        ue_id: usize,
        uplink: SyncSender<Uplink>,
        downlink: Receiver<Downlink>,
    ) -> ChannelClientTransport {
        ChannelClientTransport {
            ue_id,
            uplink,
            downlink,
        }
    }
}

impl ClientTransport for ChannelClientTransport {
    fn ue_id(&self) -> usize {
        self.ue_id
    }

    fn send(&mut self, frame: Uplink) -> Result<(), TransportError> {
        self.uplink.send(frame).map_err(|_| TransportError::Closed)
    }

    fn recv_timeout(&mut self, timeout: Duration) -> Result<Option<Downlink>, TransportError> {
        match self.downlink.recv_timeout(timeout) {
            Ok(d) => Ok(Some(d)),
            Err(RecvTimeoutError::Timeout) => Ok(None),
            Err(RecvTimeoutError::Disconnected) => Err(TransportError::Closed),
        }
    }
}

/// Build a connected in-process transport pair for `n_ues` clients.
pub fn channel_transport(n_ues: usize) -> (ChannelServerTransport, Vec<ChannelClientTransport>) {
    let (uplink_tx, uplink_rx) = sync_channel(UPLINK_QUEUE);
    let mut downlink_txs = Vec::with_capacity(n_ues);
    let mut clients = Vec::with_capacity(n_ues);
    for ue_id in 0..n_ues {
        let (tx, rx) = sync_channel(DOWNLINK_QUEUE);
        downlink_txs.push(tx);
        clients.push(ChannelClientTransport::new(ue_id, uplink_tx.clone(), rx));
    }
    (
        ChannelServerTransport::from_parts(uplink_rx, downlink_txs),
        clients,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::protocol::UeStateReport;

    #[test]
    fn pair_routes_frames_and_reports_closure() {
        let (mut server, mut clients) = channel_transport(2);
        clients[1]
            .send(Uplink::Goodbye { ue_id: 1 })
            .expect("send while server alive");
        match server.try_recv().unwrap() {
            Some(Uplink::Goodbye { ue_id }) => assert_eq!(ue_id, 1),
            other => panic!("expected the goodbye, got {other:?}"),
        }
        assert!(server.try_recv().unwrap().is_none(), "queue drained");

        server.send_to(0, Downlink::Shutdown);
        server.send_to(99, Downlink::Shutdown); // unknown UE: silently dropped
        match clients[0].recv_timeout(Duration::from_secs(1)).unwrap() {
            Some(Downlink::Shutdown) => {}
            other => panic!("expected shutdown, got {other:?}"),
        }

        // dropping every client closes the uplink
        let report = UeStateReport {
            ue_id: 0,
            tasks_left: 1,
            compute_left_s: 0.0,
            offload_left_bits: 0.0,
            distance_m: 10.0,
        };
        clients[0].send(Uplink::Report(report)).unwrap();
        drop(clients);
        assert!(server.try_recv().unwrap().is_some(), "queued frame survives");
        assert!(matches!(server.try_recv(), Err(TransportError::Closed)));
    }
}
