//! TCP transport for remote UEs, over `std::net` + threads (the offline
//! build has no tokio; the thread-per-connection layout mirrors
//! DESIGN.md §Substitutions' stance on `coordinator::server`).
//!
//! Wire format: the length-prefixed, CRC-protected frames of
//! [`crate::coordinator::wire`] (DESIGN.md §Wire-Protocol). Session flow:
//!
//! ```text
//! UE                             edge server
//! ── TcpStream::connect ───────► accept thread ─ spawns conn thread
//! ── Hello { ue_id } ──────────► validate id, register writer queue
//! ◄────────────────── Welcome ── (or Error + close: bad/duplicate id)
//! ── Report / Offload ─────────► reader thread → shared uplink mpsc
//! ◄── Decision / Result / Error─ writer thread ◄ bounded per-UE queue
//! ── Goodbye ──────────────────►
//! ◄───────────────── Shutdown ── writer flushes it, then closes
//! ```
//!
//! * **Backpressure.** Each connection's downlink rides a bounded
//!   [`std::sync::mpsc::sync_channel`]. The server's routing thread
//!   never blocks on a socket: a client that stops draining and fills
//!   its queue is evicted (slow-consumer policy), so one stalled UE can
//!   never stall decisions or results for the others.
//! * **Graceful rejection.** A frame that fails to decode poisons the
//!   byte stream (framing is lost), so the server NACKs best-effort and
//!   closes that one connection; other UEs are unaffected. Uplinks whose
//!   embedded `ue_id` differs from the handshake id are dropped (logged)
//!   — one UE cannot speak for another.
//! * **Lifecycle.** Unlike the channel transport, a TCP server never
//!   reports [`TransportError::Closed`] on `try_recv` — clients may come
//!   and go; the serving loop ends via `Goodbye`s or its frame budget.

use std::collections::hash_map::Entry;
use std::collections::HashMap;
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{anyhow, Context, Result};

use super::{ClientTransport, ServerTransport, TransportError};
use crate::coordinator::protocol::{Downlink, SESSION_ERROR_TASK, Uplink};
use crate::coordinator::wire::{read_frame, write_frame, Frame, WireError};
use crate::util::sync::lock_unpoisoned;

/// Downlink frames a single connection may buffer before the server
/// evicts it as a slow consumer (per-UE backpressure bound).
const WRITE_QUEUE: usize = 256;
/// Decoded uplinks buffered across all connections before reader threads
/// block (global backpressure toward the sockets, never unbounded RAM).
const UPLINK_QUEUE: usize = 4096;
/// Downlinks the client buffers before its reader thread blocks, pushing
/// backpressure onto the socket instead of growing a queue without bound.
const CLIENT_QUEUE: usize = 1024;
/// How long a fresh connection gets to complete the `Hello`/`Welcome`
/// handshake before the server gives up on it.
const HANDSHAKE_TIMEOUT: Duration = Duration::from_secs(5);

/// Process-wide session counter: each registered connection gets a
/// unique token, so a stale connection thread can never deregister (or
/// NACK) a successor session that reused its `ue_id`.
static SESSION_CTR: AtomicU64 = AtomicU64::new(0);

/// One registered connection, as the server loop sees it. The stream
/// clone lets `send_to` forcibly disconnect a slow client.
struct Peer {
    queue: SyncSender<Downlink>,
    stream: TcpStream,
    session: u64,
}

/// A spawned connection thread plus a stream clone to unblock it on drop.
type ConnHandle = (JoinHandle<()>, TcpStream);

/// Server side: an accept thread plus one reader and one writer thread
/// per connection, multiplexing decoded uplinks into a single queue.
pub struct TcpServerTransport {
    local_addr: SocketAddr,
    uplink_rx: Receiver<Uplink>,
    peers: Arc<Mutex<HashMap<usize, Peer>>>,
    conns: Arc<Mutex<Vec<ConnHandle>>>,
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
    /// Downlink frames dropped by the slow-consumer eviction path —
    /// drained by [`ServerTransport::take_drops`] into `ServerStats`.
    drops: usize,
}

impl TcpServerTransport {
    /// Bind and start accepting. `max_ues` bounds valid `ue_id`s — the
    /// handshake rejects ids at or above it, and duplicates of a live
    /// session. Use port 0 for an ephemeral port ([`Self::local_addr`]).
    pub fn bind(addr: impl ToSocketAddrs, max_ues: usize) -> Result<TcpServerTransport> {
        let listener = TcpListener::bind(addr).context("binding the UE listener")?;
        let local_addr = listener.local_addr()?;
        listener.set_nonblocking(true).context("listener nonblocking mode")?;

        let (uplink_tx, uplink_rx) = sync_channel::<Uplink>(UPLINK_QUEUE);
        let peers: Arc<Mutex<HashMap<usize, Peer>>> = Arc::new(Mutex::new(HashMap::new()));
        let conns: Arc<Mutex<Vec<ConnHandle>>> = Arc::new(Mutex::new(Vec::new()));
        let stop = Arc::new(AtomicBool::new(false));

        let accept = {
            let peers = peers.clone();
            let conns = conns.clone();
            let stop = stop.clone();
            std::thread::Builder::new()
                .name("ue-accept".into())
                .spawn(move || {
                    while !stop.load(Ordering::SeqCst) {
                        match listener.accept() {
                            Ok((stream, from)) => {
                                log::debug!("UE connection from {from}");
                                let shut = match stream.try_clone() {
                                    Ok(s) => s,
                                    Err(e) => {
                                        log::error!("cloning UE stream: {e}");
                                        continue;
                                    }
                                };
                                let peers = peers.clone();
                                let tx = uplink_tx.clone();
                                let handle = std::thread::Builder::new()
                                    .name(format!("ue-conn-{from}"))
                                    .spawn(move || serve_connection(stream, peers, tx, max_ues));
                                match handle {
                                    Ok(h) => {
                                        let mut conns = lock_unpoisoned(&conns);
                                        // reap finished connections so churn
                                        // doesn't leak handles and stream fds
                                        conns.retain(|(h, _)| !h.is_finished());
                                        conns.push((h, shut));
                                    }
                                    Err(e) => log::error!("spawning UE connection thread: {e}"),
                                }
                            }
                            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                                std::thread::sleep(Duration::from_millis(1));
                            }
                            Err(e) => {
                                log::error!("accept failed: {e}");
                                std::thread::sleep(Duration::from_millis(10));
                            }
                        }
                    }
                })?
        };

        Ok(TcpServerTransport {
            local_addr,
            uplink_rx,
            peers,
            conns,
            stop,
            accept: Some(accept),
            drops: 0,
        })
    }

    /// The bound address (resolves port 0 to the ephemeral port).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// UEs with a live registered session right now.
    pub fn connected(&self) -> usize {
        lock_unpoisoned(&self.peers).len()
    }
}

impl ServerTransport for TcpServerTransport {
    fn try_recv(&mut self) -> Result<Option<Uplink>, TransportError> {
        // the accept thread keeps an uplink sender alive, so this can
        // only be Empty or a frame while the transport exists
        Ok(self.uplink_rx.try_recv().ok())
    }

    fn send_to(&mut self, ue_id: usize, frame: Downlink) {
        // clone the queue handle out of the lock so connection threads
        // never contend with an in-progress send
        let queue = {
            let peers = lock_unpoisoned(&self.peers);
            peers.get(&ue_id).map(|p| p.queue.clone())
        };
        let Some(queue) = queue else {
            log::debug!("downlink to unconnected UE {ue_id} dropped");
            return;
        };
        match queue.try_send(frame) {
            Ok(()) => {}
            Err(TrySendError::Full(_)) => {
                // a client that stopped draining its socket must not be
                // able to stall the single routing thread (and with it
                // every other UE): evict the slow consumer instead
                self.drops += 1;
                log::warn!("UE {ue_id} write queue full — disconnecting the slow client");
                if let Some(p) = lock_unpoisoned(&self.peers).remove(&ue_id) {
                    let _ = p.stream.shutdown(Shutdown::Both);
                }
            }
            Err(TrySendError::Disconnected(_)) => {
                // writer gone (client hung up): deregister so later
                // sends stop queueing into the void
                lock_unpoisoned(&self.peers).remove(&ue_id);
            }
        }
    }

    fn take_drops(&mut self) -> usize {
        std::mem::take(&mut self.drops)
    }
}

impl Drop for TcpServerTransport {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        // swap the uplink receiver out first: a connection thread parked
        // in a full `send` only unblocks once the receiver drops, and the
        // joins below would otherwise deadlock against it
        let (_tx, drained) = sync_channel::<Uplink>(1);
        self.uplink_rx = drained;
        let conns = std::mem::take(&mut *lock_unpoisoned(&self.conns));
        for (_, stream) in &conns {
            // unblock readers parked in read_frame
            let _ = stream.shutdown(Shutdown::Both);
        }
        for (h, _) in conns {
            let _ = h.join();
        }
    }
}

/// Reject a handshake with a session-level `Downlink::Error` frame
/// (`task_id` = [`SESSION_ERROR_TASK`]) before closing.
fn reject(stream: &mut TcpStream, why: String) {
    log::warn!("rejecting UE connection: {why}");
    let nack = Downlink::Error {
        task_id: SESSION_ERROR_TASK,
        error: why,
    };
    let _ = write_frame(stream, &Frame::Down(nack));
}

/// One connection's lifetime: handshake, then the reader loop; owns and
/// finally joins the connection's writer thread.
fn serve_connection(
    mut stream: TcpStream,
    peers: Arc<Mutex<HashMap<usize, Peer>>>,
    uplink_tx: SyncSender<Uplink>,
    max_ues: usize,
) {
    // the listener is nonblocking and some platforms let accepted
    // sockets inherit that — the frame reader needs blocking reads
    let _ = stream.set_nonblocking(false);
    let _ = stream.set_nodelay(true);

    // -- handshake (deadline-bounded so a silent peer can't pin us) --
    let _ = stream.set_read_timeout(Some(HANDSHAKE_TIMEOUT));
    let ue_id = match read_frame(&mut stream) {
        Ok(Frame::Hello { ue_id }) if ue_id < max_ues => ue_id,
        Ok(Frame::Hello { ue_id }) => {
            return reject(
                &mut stream,
                format!("ue_id {ue_id} out of range (server admits {max_ues} UEs)"),
            )
        }
        Ok(other) => return reject(&mut stream, format!("expected Hello, got {other:?}")),
        Err(e) => return reject(&mut stream, format!("handshake failed: {e}")),
    };
    let _ = stream.set_read_timeout(None);

    // -- register the writer (atomically: duplicate ids are rejected) --
    let writer_stream = match stream.try_clone() {
        Ok(s) => s,
        Err(e) => return reject(&mut stream, format!("stream clone failed: {e}")),
    };
    let peer_stream = match stream.try_clone() {
        Ok(s) => s,
        Err(e) => return reject(&mut stream, format!("stream clone failed: {e}")),
    };
    let (queue_tx, queue_rx) = sync_channel::<Downlink>(WRITE_QUEUE);
    let session = SESSION_CTR.fetch_add(1, Ordering::Relaxed);
    match lock_unpoisoned(&peers).entry(ue_id) {
        Entry::Occupied(_) => {
            return reject(&mut stream, format!("ue_id {ue_id} already has a live session"))
        }
        Entry::Vacant(v) => {
            v.insert(Peer {
                queue: queue_tx,
                stream: peer_stream,
                session,
            });
        }
    }
    // Welcome goes out before the writer thread exists, so the two never
    // interleave bytes on the stream
    if write_frame(&mut stream, &Frame::Welcome { ue_id }).is_err() {
        lock_unpoisoned(&peers).remove(&ue_id);
        return;
    }
    let writer = std::thread::Builder::new()
        .name(format!("ue-writer-{ue_id}"))
        .spawn(move || writer_loop(writer_stream, queue_rx));

    // -- reader loop --
    let mut saw_goodbye = false;
    loop {
        match read_frame(&mut stream) {
            Ok(Frame::Up(up)) => {
                let claimed = match &up {
                    Uplink::Report(r) => r.ue_id,
                    Uplink::Offload(o) => o.ue_id,
                    Uplink::Goodbye { ue_id } => *ue_id,
                };
                if claimed != ue_id {
                    log::warn!("UE {ue_id} sent a frame claiming ue_id {claimed}; dropped");
                    continue;
                }
                let is_goodbye = matches!(up, Uplink::Goodbye { .. });
                if uplink_tx.send(up).is_err() {
                    break; // server loop gone
                }
                if is_goodbye {
                    saw_goodbye = true;
                }
            }
            Ok(other) => {
                log::warn!("UE {ue_id} sent an unexpected {other:?}; dropped");
            }
            Err(WireError::Closed) => break,
            Err(WireError::UnknownTag { got, .. }) => {
                // the frame was fully read and CRC-validated — framing is
                // intact, so a future same-version frame type is skipped
                log::debug!("UE {ue_id} sent unknown frame tag {got:#04x}; skipped");
            }
            Err(e) => {
                // framing is lost: NACK best-effort (only our own
                // session, never a successor's), then drop the session
                log::warn!("UE {ue_id} stream unrecoverable: {e}");
                if let Some(p) = lock_unpoisoned(&peers).get(&ue_id) {
                    if p.session == session {
                        let _ = p.queue.try_send(Downlink::Error {
                            task_id: SESSION_ERROR_TASK,
                            error: format!("wire error, closing session: {e}"),
                        });
                    }
                }
                break;
            }
        }
    }

    // deregister — but only our own session: `send_to` may have already
    // evicted this entry and a reconnected successor may own the slot
    let mut vanished = !saw_goodbye;
    {
        let mut map = lock_unpoisoned(&peers);
        match map.get(&ue_id).map(|p| p.session == session) {
            Some(true) => {
                map.remove(&ue_id);
            }
            Some(false) => vanished = false, // a successor session is live
            None => {}
        }
    }
    // a UE that dropped without a Goodbye must not wedge the server loop
    // (its alive flag would stay true forever): synthesize the Goodbye.
    // A later reconnect + state report re-enters it into the system.
    if vanished {
        log::debug!("UE {ue_id} vanished without Goodbye — synthesizing one");
        let _ = uplink_tx.send(Uplink::Goodbye { ue_id });
    }
    let _ = stream.shutdown(Shutdown::Both);
    if let Ok(w) = writer {
        let _ = w.join();
    }
}

/// Drain one connection's downlink queue onto the socket. Exits when the
/// queue closes (session deregistered), a write fails, or after flushing
/// a `Shutdown` frame — the protocol's end-of-session marker.
fn writer_loop(mut stream: TcpStream, queue: Receiver<Downlink>) {
    while let Ok(frame) = queue.recv() {
        let last = matches!(frame, Downlink::Shutdown);
        if write_frame(&mut stream, &Frame::Down(frame)).is_err() {
            break;
        }
        if last {
            break;
        }
    }
    let _ = stream.shutdown(Shutdown::Write);
}

/// Client side: a blocking writer plus a reader thread feeding a local
/// queue, so [`ClientTransport::recv_timeout`] has channel semantics.
#[derive(Debug)]
pub struct TcpClientTransport {
    ue_id: usize,
    stream: TcpStream,
    rx: Receiver<Downlink>,
    reader: Option<JoinHandle<()>>,
}

impl TcpClientTransport {
    /// Connect and complete the session handshake as `ue_id`. Fails if
    /// the server rejects the id (out of range or already connected).
    pub fn connect(addr: impl ToSocketAddrs, ue_id: usize) -> Result<TcpClientTransport> {
        let mut stream = TcpStream::connect(addr).context("connecting to the edge server")?;
        let _ = stream.set_nodelay(true);
        stream
            .set_read_timeout(Some(HANDSHAKE_TIMEOUT))
            .context("handshake read timeout")?;
        write_frame(&mut stream, &Frame::Hello { ue_id })
            .map_err(|e| anyhow!("sending Hello: {e}"))?;
        match read_frame(&mut stream) {
            Ok(Frame::Welcome { ue_id: got }) if got == ue_id => {}
            Ok(Frame::Welcome { ue_id: got }) => {
                anyhow::bail!("server welcomed us as UE {got}, expected {ue_id}")
            }
            Ok(Frame::Down(Downlink::Error { error, .. })) => {
                anyhow::bail!("server rejected the handshake: {error}")
            }
            Ok(other) => anyhow::bail!("unexpected handshake reply: {other:?}"),
            Err(e) => anyhow::bail!("handshake failed: {e}"),
        }
        stream.set_read_timeout(None).context("clearing read timeout")?;

        let (tx, rx) = sync_channel::<Downlink>(CLIENT_QUEUE);
        let mut reader_stream = stream.try_clone().context("cloning the client stream")?;
        let reader = std::thread::Builder::new()
            .name(format!("ue-{ue_id}-reader"))
            .spawn(move || loop {
                match read_frame(&mut reader_stream) {
                    Ok(Frame::Down(d)) => {
                        let last = matches!(d, Downlink::Shutdown);
                        if tx.send(d).is_err() || last {
                            break;
                        }
                    }
                    // reactor servers address every downlink explicitly
                    // (their sockets may carry many UEs); a single-UE
                    // client just unwraps its own envelopes
                    Ok(Frame::DownTo { ue_id: to, down }) if to == ue_id => {
                        let last = matches!(down, Downlink::Shutdown);
                        if tx.send(down).is_err() || last {
                            break;
                        }
                    }
                    Ok(other) => log::warn!("server sent an unexpected {other:?}; dropped"),
                    Err(WireError::Closed) => break,
                    Err(WireError::UnknownTag { got, .. }) => {
                        log::debug!("server sent unknown frame tag {got:#04x}; skipped");
                    }
                    Err(e) => {
                        log::warn!("downlink stream unrecoverable: {e}");
                        break;
                    }
                }
            })?;

        Ok(TcpClientTransport {
            ue_id,
            stream,
            rx,
            reader: Some(reader),
        })
    }
}

impl ClientTransport for TcpClientTransport {
    fn ue_id(&self) -> usize {
        self.ue_id
    }

    fn send(&mut self, frame: Uplink) -> Result<(), TransportError> {
        write_frame(&mut self.stream, &Frame::Up(frame)).map_err(TransportError::Wire)
    }

    fn recv_timeout(&mut self, timeout: Duration) -> Result<Option<Downlink>, TransportError> {
        match self.rx.recv_timeout(timeout) {
            Ok(d) => Ok(Some(d)),
            Err(RecvTimeoutError::Timeout) => Ok(None),
            Err(RecvTimeoutError::Disconnected) => Err(TransportError::Closed),
        }
    }
}

impl Drop for TcpClientTransport {
    fn drop(&mut self) {
        let _ = self.stream.shutdown(Shutdown::Both);
        // the reader may be parked in a full queue send; dropping the
        // receiver unblocks it so the join below cannot deadlock
        let (_tx, drained) = sync_channel::<Downlink>(1);
        self.rx = drained;
        if let Some(h) = self.reader.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::protocol::UeStateReport;

    fn report(ue_id: usize) -> Uplink {
        Uplink::Report(UeStateReport {
            ue_id,
            tasks_left: 2,
            compute_left_s: 0.1,
            offload_left_bits: 5.0,
            distance_m: 30.0,
        })
    }

    #[test]
    fn loopback_session_roundtrips_frames() {
        let mut server = TcpServerTransport::bind("127.0.0.1:0", 4).unwrap();
        let addr = server.local_addr();
        let mut client = TcpClientTransport::connect(addr, 1).unwrap();
        assert_eq!(client.ue_id(), 1);

        client.send(report(1)).unwrap();
        let got = wait_uplink(&mut server);
        assert_eq!(got, Some(report(1)));

        server.send_to(1, Downlink::Shutdown);
        match client.recv_timeout(Duration::from_secs(5)).unwrap() {
            Some(Downlink::Shutdown) => {}
            other => panic!("expected shutdown, got {other:?}"),
        }
    }

    #[test]
    fn handshake_rejects_bad_and_duplicate_ids() {
        let server = TcpServerTransport::bind("127.0.0.1:0", 2).unwrap();
        let addr = server.local_addr();

        let err = TcpClientTransport::connect(addr, 7).unwrap_err();
        assert!(format!("{err:#}").contains("out of range"), "got: {err:#}");

        let _first = TcpClientTransport::connect(addr, 0).unwrap();
        let err = TcpClientTransport::connect(addr, 0).unwrap_err();
        assert!(format!("{err:#}").contains("already has a live session"), "got: {err:#}");
        assert_eq!(server.connected(), 1);
    }

    #[test]
    fn spoofed_ue_id_is_dropped() {
        let mut server = TcpServerTransport::bind("127.0.0.1:0", 4).unwrap();
        let addr = server.local_addr();
        let mut client = TcpClientTransport::connect(addr, 1).unwrap();
        client.send(report(3)).unwrap(); // claims to be UE 3
        client.send(report(1)).unwrap(); // honest
        // only the honest frame arrives
        assert_eq!(wait_uplink(&mut server), Some(report(1)));
    }

    fn wait_uplink(server: &mut TcpServerTransport) -> Option<Uplink> {
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        while std::time::Instant::now() < deadline {
            if let Some(u) = server.try_recv().unwrap() {
                return Some(u);
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        None
    }
}
