//! The fleet-scale transport: one nonblocking reactor thread sweeping
//! every socket, feeding per-shard server loops (DESIGN.md
//! §Sharded-Serving).
//!
//! [`super::tcp`] spawns a reader and a writer thread per connection —
//! honest at tens of UEs, dead at thousands. Here a single thread owns a
//! nonblocking `TcpListener` plus every accepted `TcpStream` and runs a
//! readiness sweep (the offline build has no epoll binding; the sweep is
//! a poll loop over nonblocking sockets with a short idle sleep):
//!
//! ```text
//!                        ┌── ReactorShardTransport (shard 0) ─ server_loop
//!  sockets ── reactor ───┼── ReactorShardTransport (shard 1) ─ server_loop
//!  (nonblocking sweep)   └── …        bounded sync_channels both ways
//! ```
//!
//! * **Multiplexing.** One connection may carry many UEs (a load-test
//!   station speaks for a whole slice): each UE registers with its own
//!   `Hello`, and every server→UE frame is wrapped in
//!   [`Frame::DownTo`] so the peer can attribute it. Single-UE
//!   [`super::tcp::TcpClientTransport`] clients also work — their reader
//!   unwraps envelopes addressed to them.
//! * **Session takeover.** A `Hello` for an already-registered UE moves
//!   the registration to the new connection (latest wins) — reconnect
//!   churn never races the old socket's EOF.
//! * **Backpressure.** Per-connection write buffers are capped
//!   ([`ReactorConfig::write_buf_cap`]): a frame that does not fit is
//!   dropped and counted against the owning shard (visible via
//!   [`crate::transport::ServerTransport::take_drops`] →
//!   `ServerStats::downlink_drops`), and `evict_after_drops` consecutive
//!   drops evict the connection — one stalled station can never stall
//!   the sweep.
//! * **Fault isolation.** A frame that fails to decode poisons that one
//!   connection: best-effort NACK, close, synthesized `Goodbye`s for its
//!   registered UEs. Unknown-but-well-framed tags are skipped in place.

use std::io::{Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TryRecvError, TrySendError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{Context, Result};

use super::{ServerTransport, TransportError};
use crate::coordinator::protocol::{Downlink, FrameDecision, SESSION_ERROR_TASK, Uplink};
use crate::coordinator::shard::ShardMap;
use crate::coordinator::wire::{
    decode_frame, encode_decision_body, encode_down_to_raw, encode_frame_append, Frame, WireError,
    TAG_DECISION,
};

/// Reactor sweep knobs. `max_ues`/`n_shards` define the [`ShardMap`]
/// used for uplink routing; the rest bound per-connection memory.
#[derive(Debug, Clone, Copy)]
pub struct ReactorConfig {
    /// Valid ue ids are `0..max_ues`; `Hello`s outside are NACKed.
    pub max_ues: usize,
    /// Server shards fed by this reactor (one transport endpoint each).
    pub n_shards: usize,
    /// Bytes one connection may buffer for write before further frames
    /// to it are dropped (and counted) instead of queued.
    pub write_buf_cap: usize,
    /// Consecutive dropped frames after which a connection is evicted
    /// as a slow consumer (any flushed byte resets the streak).
    pub evict_after_drops: usize,
}

impl ReactorConfig {
    pub fn new(max_ues: usize, n_shards: usize) -> ReactorConfig {
        ReactorConfig {
            max_ues,
            n_shards: n_shards.max(1),
            write_buf_cap: 256 * 1024,
            evict_after_drops: 8,
        }
    }
}

/// Reactor-side counters, returned by [`TcpReactor::stop`].
#[derive(Debug, Clone, Copy, Default)]
pub struct ReactorStats {
    /// Connections accepted over the reactor's lifetime.
    pub accepted: usize,
    /// Connections evicted as slow consumers.
    pub evicted: usize,
    /// Uplink frames dropped because a shard's bounded queue was full.
    pub uplink_drops: usize,
    /// `Goodbye`s synthesized for UEs whose connection vanished.
    pub goodbyes_synthesized: usize,
}

/// One message from a shard's server loop to the reactor thread.
enum DownMsg {
    /// An individually-addressed downlink frame.
    One(usize, Downlink),
    /// A whole tick's decision fan-out as a single channel message: the
    /// reactor encodes the shared body once and stamps it per target
    /// connection, instead of N re-encoded `(ue, frame)` sends.
    Broadcast {
        d: FrameDecision,
        targets: Vec<(usize, usize)>,
        per_ue: bool,
    },
}

/// One shard's endpoint on the reactor: an ordinary [`ServerTransport`]
/// carrying **global** ue ids (wrap it in
/// [`crate::coordinator::shard::ShardView`] for a slice-local view).
pub struct ReactorShardTransport {
    shard: usize,
    uplink: Receiver<Uplink>,
    down_tx: SyncSender<DownMsg>,
    drops: Arc<AtomicUsize>,
}

impl ServerTransport for ReactorShardTransport {
    fn try_recv(&mut self) -> Result<Option<Uplink>, TransportError> {
        match self.uplink.try_recv() {
            Ok(u) => Ok(Some(u)),
            Err(TryRecvError::Empty) => Ok(None),
            // the reactor thread exited and dropped its senders: no UE
            // of this shard can ever speak again
            Err(TryRecvError::Disconnected) => Err(TransportError::Closed),
        }
    }

    fn send_to(&mut self, ue_id: usize, frame: Downlink) {
        match self.down_tx.try_send(DownMsg::One(ue_id, frame)) {
            Ok(()) => {}
            Err(TrySendError::Full(_)) => {
                // the reactor is behind on this shard's downlink: drop
                // and count rather than stall the server loop
                self.drops.fetch_add(1, Ordering::Relaxed);
                log::warn!("shard {} downlink queue full — frame to UE {ue_id} dropped", self.shard);
            }
            // reactor gone: the server loop will see Closed on try_recv
            Err(TrySendError::Disconnected(_)) => {}
        }
    }

    fn broadcast_decision(&mut self, d: &FrameDecision, targets: &[(usize, usize)], per_ue: bool) {
        if targets.is_empty() {
            return;
        }
        // the whole fan-out crosses the channel as ONE message — the
        // reactor side does the single-encode stamping
        let msg = DownMsg::Broadcast {
            d: d.clone(),
            targets: targets.to_vec(),
            per_ue,
        };
        match self.down_tx.try_send(msg) {
            Ok(()) => {}
            Err(TrySendError::Full(_)) => {
                // every target misses this tick's decision: count each
                self.drops.fetch_add(targets.len(), Ordering::Relaxed);
                log::warn!(
                    "shard {} downlink queue full — decision broadcast to {} UEs dropped",
                    self.shard,
                    targets.len()
                );
            }
            Err(TrySendError::Disconnected(_)) => {}
        }
    }

    fn take_drops(&mut self) -> usize {
        self.drops.swap(0, Ordering::Relaxed)
    }
}

/// Handle to the running reactor thread. Dropping it stops the sweep,
/// closes every connection and joins the thread.
pub struct TcpReactor {
    local_addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<ReactorStats>>,
}

impl TcpReactor {
    /// Bind `addr` (port 0 for ephemeral) and start the sweep thread.
    /// Returns the reactor handle plus one [`ReactorShardTransport`] per
    /// shard, in shard order.
    pub fn bind(
        addr: impl ToSocketAddrs,
        cfg: ReactorConfig,
    ) -> Result<(TcpReactor, Vec<ReactorShardTransport>)> {
        let listener = TcpListener::bind(addr).context("binding the reactor listener")?;
        let local_addr = listener.local_addr()?;
        listener.set_nonblocking(true).context("listener nonblocking mode")?;

        let map = ShardMap::new(cfg.max_ues, cfg.n_shards);
        let mut transports = Vec::with_capacity(map.n_shards());
        let mut up_txs = Vec::with_capacity(map.n_shards());
        let mut down_rxs = Vec::with_capacity(map.n_shards());
        let mut drops = Vec::with_capacity(map.n_shards());
        for shard in 0..map.n_shards() {
            let slice_len = map.slice_of(shard).map(|(_, len)| len).unwrap_or(0);
            // a full per-UE broadcast must fit without forcing drops
            let (up_tx, up_rx) = sync_channel::<Uplink>((2 * slice_len).max(4096));
            let (down_tx, down_rx) = sync_channel::<DownMsg>((2 * slice_len).max(1024));
            let ctr = Arc::new(AtomicUsize::new(0));
            transports.push(ReactorShardTransport {
                shard,
                uplink: up_rx,
                down_tx,
                drops: ctr.clone(),
            });
            up_txs.push(up_tx);
            down_rxs.push(down_rx);
            drops.push(ctr);
        }

        let stop = Arc::new(AtomicBool::new(false));
        let handle = {
            let stop = stop.clone();
            std::thread::Builder::new()
                .name("ue-reactor".into())
                .spawn(move || {
                    Reactor {
                        cfg,
                        map,
                        listener,
                        up_txs,
                        down_rxs,
                        shard_drops: drops,
                        conns: Vec::new(),
                        by_ue: vec![None; cfg.max_ues],
                        body_scratch: Vec::new(),
                        stats: ReactorStats::default(),
                        stop,
                    }
                    .run()
                })
                .context("spawning the reactor thread")?
        };

        Ok((
            TcpReactor {
                local_addr,
                stop,
                handle: Some(handle),
            },
            transports,
        ))
    }

    /// The bound address (resolves port 0 to the ephemeral port).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Stop the sweep, close every connection and collect the stats.
    /// The shard transports' uplinks report `Closed` afterwards, so
    /// server loops parked on them exit.
    pub fn stop(mut self) -> ReactorStats {
        self.stop.store(true, Ordering::SeqCst);
        self.handle
            .take()
            .map(|h| h.join().unwrap_or_default())
            .unwrap_or_default()
    }
}

impl Drop for TcpReactor {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// A flat per-connection write buffer, drained from the front without
/// shifting on every flush: `pos` marks how far the socket has consumed;
/// frames are appended in place (the wire encoders write straight into
/// [`WriteBuf::append_vec`], no intermediate `Vec` per frame). Once the
/// flushed prefix dominates, the unflushed tail is compacted down — so at
/// steady state one grown allocation is reused for the connection's
/// lifetime (asserted by `rust/tests/zero_alloc.rs`).
#[derive(Debug, Default)]
struct WriteBuf {
    buf: Vec<u8>,
    pos: usize,
}

impl WriteBuf {
    /// Unflushed byte count.
    fn len(&self) -> usize {
        self.buf.len().saturating_sub(self.pos)
    }

    fn is_empty(&self) -> bool {
        self.pos >= self.buf.len()
    }

    /// The bytes still awaiting the socket.
    fn pending(&self) -> &[u8] {
        self.buf.get(self.pos..).unwrap_or(&[])
    }

    /// The socket accepted `n` more bytes.
    fn advance(&mut self, n: usize) {
        self.pos = self.pos.saturating_add(n).min(self.buf.len());
        if self.pos == self.buf.len() {
            self.buf.clear();
            self.pos = 0;
        } else if self.pos >= 4096 && self.pos >= self.buf.len() / 2 {
            // the flushed prefix dominates: one copy_within reclaims it
            self.buf.copy_within(self.pos.., 0);
            self.buf.truncate(self.buf.len() - self.pos);
            self.pos = 0;
        }
    }

    /// Current end of the buffer — pair with [`WriteBuf::truncate_to`]
    /// to roll back a frame that overflowed the cap (encode first, then
    /// enforce: cheaper than a pre-encode size pass).
    fn mark(&self) -> usize {
        self.buf.len()
    }

    fn truncate_to(&mut self, mark: usize) {
        self.buf.truncate(mark);
    }

    /// The raw append end for the wire encoders.
    fn append_vec(&mut self) -> &mut Vec<u8> {
        &mut self.buf
    }
}

/// One live connection in the sweep.
struct Conn {
    stream: TcpStream,
    /// Undecoded inbound bytes (frames straddle reads).
    rbuf: Vec<u8>,
    /// Encoded outbound bytes awaiting socket readiness.
    wbuf: WriteBuf,
    /// Global ue ids registered on this connection.
    ues: Vec<usize>,
    /// Consecutive dropped downlink frames (slow-consumer eviction).
    drop_streak: usize,
}

/// Why a connection leaves the sweep (logging only).
enum Close {
    Eof,
    IoError,
    Poisoned,
    Rejected,
    Evicted,
}

struct Reactor {
    cfg: ReactorConfig,
    map: ShardMap,
    listener: TcpListener,
    up_txs: Vec<SyncSender<Uplink>>,
    down_rxs: Vec<Receiver<DownMsg>>,
    /// Per-shard backpressure-drop counters, shared with the shard
    /// transports so `take_drops` sees reactor-side write-buffer drops.
    shard_drops: Vec<Arc<AtomicUsize>>,
    conns: Vec<Option<Conn>>,
    /// `by_ue[global_id]` → index into `conns` of the owning connection.
    by_ue: Vec<Option<usize>>,
    /// Reused downlink-body scratch for the single-encode fan-out: a
    /// broadcast encodes the shared body here once, then stamps it into
    /// each target connection's write buffer.
    body_scratch: Vec<u8>,
    stats: ReactorStats,
    stop: Arc<AtomicBool>,
}

impl Reactor {
    fn run(mut self) -> ReactorStats {
        while !self.stop.load(Ordering::SeqCst) {
            let mut progress = false;
            progress |= self.accept_new();
            progress |= self.drain_downlinks();
            progress |= self.flush_writes();
            progress |= self.read_sockets();
            if !progress {
                std::thread::sleep(Duration::from_micros(500));
            }
        }
        // close everything on the way out (synthesized Goodbyes give the
        // shard loops a chance to mark the fleet gone before Closed)
        for slot in 0..self.conns.len() {
            if let Some(conn) = self.conns.get_mut(slot).and_then(Option::take) {
                self.close_conn(conn, Close::Eof);
            }
        }
        self.stats
    }

    /// Accept every pending connection (nonblocking).
    fn accept_new(&mut self) -> bool {
        let mut any = false;
        loop {
            match self.listener.accept() {
                Ok((stream, from)) => {
                    if stream.set_nonblocking(true).is_err() {
                        continue;
                    }
                    let _ = stream.set_nodelay(true);
                    log::debug!("reactor: connection from {from}");
                    self.stats.accepted += 1;
                    any = true;
                    let conn = Conn {
                        stream,
                        rbuf: Vec::new(),
                        wbuf: WriteBuf::default(),
                        ues: Vec::new(),
                        drop_streak: 0,
                    };
                    match self.conns.iter_mut().position(|c| c.is_none()) {
                        Some(slot) => {
                            if let Some(c) = self.conns.get_mut(slot) {
                                *c = Some(conn);
                            }
                        }
                        None => self.conns.push(Some(conn)),
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) => {
                    log::error!("reactor accept failed: {e}");
                    break;
                }
            }
        }
        any
    }

    /// Move every queued downlink from the shards into the owning
    /// connections' write buffers, as [`Frame::DownTo`] envelopes. Frames
    /// are encoded **in place** at the buffer's append end (and rolled
    /// back if they overflow the cap) — no intermediate `Vec` per frame.
    /// A [`DownMsg::Broadcast`] encodes its shared decision body once and
    /// stamps it per target: copy + outer CRC per subscriber, one encode
    /// per tick.
    fn drain_downlinks(&mut self) -> bool {
        let mut any = false;
        let mut evict: Vec<usize> = Vec::new();
        for shard in 0..self.down_rxs.len() {
            loop {
                let msg = match self.down_rxs.get(shard).map(|rx| rx.try_recv()) {
                    Some(Ok(m)) => m,
                    // Empty now, or the shard's server loop exited and
                    // dropped its sender — either way nothing to move
                    _ => break,
                };
                any = true;
                match msg {
                    DownMsg::One(ue_id, down) => {
                        let Some(&Some(slot)) = self.by_ue.get(ue_id) else {
                            // no live session for this UE: expected churn
                            // (the shard keeps sending through
                            // disconnects), not a backpressure drop
                            continue;
                        };
                        let Some(conn) = self.conns.get_mut(slot).and_then(Option::as_mut) else {
                            continue;
                        };
                        let mark = conn.wbuf.mark();
                        encode_frame_append(&Frame::DownTo { ue_id, down }, conn.wbuf.append_vec());
                        if conn.wbuf.len() > self.cfg.write_buf_cap {
                            conn.wbuf.truncate_to(mark);
                            Self::count_drop(
                                conn,
                                slot,
                                &self.cfg,
                                self.shard_drops.get(shard),
                                &mut evict,
                            );
                        } else {
                            conn.drop_streak = 0;
                        }
                    }
                    DownMsg::Broadcast { d, targets, per_ue } => {
                        let tag = if per_ue {
                            TAG_DECISION
                        } else {
                            // single-encode fan-out: the shared joint body
                            // is encoded once for the whole target set
                            self.body_scratch.clear();
                            encode_decision_body(d.frame, &d.actions, &mut self.body_scratch)
                        };
                        for &(ue_id, idx) in &targets {
                            let Some(&Some(slot)) = self.by_ue.get(ue_id) else {
                                continue;
                            };
                            if per_ue {
                                // slim per-target body straight from the
                                // shared action table (no Arc per UE; the
                                // tag is TAG_DECISION by construction)
                                let Some(act) = d.actions.get(idx) else {
                                    continue;
                                };
                                self.body_scratch.clear();
                                encode_decision_body(
                                    d.frame,
                                    std::slice::from_ref(act),
                                    &mut self.body_scratch,
                                );
                            }
                            let Some(conn) = self.conns.get_mut(slot).and_then(Option::as_mut)
                            else {
                                continue;
                            };
                            let mark = conn.wbuf.mark();
                            encode_down_to_raw(
                                ue_id,
                                tag,
                                &self.body_scratch,
                                conn.wbuf.append_vec(),
                            );
                            if conn.wbuf.len() > self.cfg.write_buf_cap {
                                conn.wbuf.truncate_to(mark);
                                Self::count_drop(
                                    conn,
                                    slot,
                                    &self.cfg,
                                    self.shard_drops.get(shard),
                                    &mut evict,
                                );
                            } else {
                                conn.drop_streak = 0;
                            }
                        }
                    }
                }
            }
        }
        for slot in evict {
            if let Some(conn) = self.conns.get_mut(slot).and_then(Option::take) {
                log::warn!("reactor: evicting slow consumer on slot {slot}");
                self.stats.evicted += 1;
                self.close_conn(conn, Close::Evicted);
            }
        }
        any
    }

    /// Bookkeeping for one backpressure-dropped downlink frame: count it
    /// against the shard and queue the connection for eviction once its
    /// drop streak is long enough.
    fn count_drop(
        conn: &mut Conn,
        slot: usize,
        cfg: &ReactorConfig,
        ctr: Option<&Arc<AtomicUsize>>,
        evict: &mut Vec<usize>,
    ) {
        conn.drop_streak += 1;
        if let Some(ctr) = ctr {
            ctr.fetch_add(1, Ordering::Relaxed);
        }
        if conn.drop_streak >= cfg.evict_after_drops.max(1) && !evict.contains(&slot) {
            evict.push(slot);
        }
    }

    /// Write as much buffered output as each socket accepts.
    fn flush_writes(&mut self) -> bool {
        let mut any = false;
        for slot in 0..self.conns.len() {
            let Some(conn) = self.conns.get_mut(slot).and_then(Option::as_mut) else {
                continue;
            };
            let mut dead = false;
            while !conn.wbuf.is_empty() {
                match conn.stream.write(conn.wbuf.pending()) {
                    Ok(0) => {
                        dead = true;
                        break;
                    }
                    Ok(n) => {
                        conn.wbuf.advance(n);
                        conn.drop_streak = 0;
                        any = true;
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                    Err(_) => {
                        dead = true;
                        break;
                    }
                }
            }
            if dead {
                if let Some(conn) = self.conns.get_mut(slot).and_then(Option::take) {
                    self.close_conn(conn, Close::IoError);
                }
            }
        }
        any
    }

    /// Read available bytes from every socket and decode/dispatch the
    /// complete frames.
    fn read_sockets(&mut self) -> bool {
        let mut any = false;
        let mut scratch = [0u8; 65536];
        for slot in 0..self.conns.len() {
            // take the connection out of the slab while handling it so
            // frame dispatch can borrow the rest of the reactor freely
            let Some(mut conn) = self.conns.get_mut(slot).and_then(Option::take) else {
                continue;
            };
            let mut close: Option<Close> = None;
            loop {
                match conn.stream.read(&mut scratch) {
                    Ok(0) => {
                        close = Some(Close::Eof);
                        break;
                    }
                    Ok(n) => {
                        any = true;
                        if let Some(got) = scratch.get(..n) {
                            conn.rbuf.extend_from_slice(got);
                        }
                        if let Some(why) = self.dispatch_frames(slot, &mut conn) {
                            close = Some(why);
                            break;
                        }
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                    Err(_) => {
                        close = Some(Close::IoError);
                        break;
                    }
                }
            }
            match close {
                Some(why) => self.close_conn(conn, why),
                None => {
                    if let Some(c) = self.conns.get_mut(slot) {
                        *c = Some(conn);
                    }
                }
            }
        }
        any
    }

    /// Decode every complete frame buffered on `conn`. Returns a close
    /// reason when the connection must go.
    fn dispatch_frames(&mut self, slot: usize, conn: &mut Conn) -> Option<Close> {
        loop {
            match decode_frame(&conn.rbuf) {
                Ok((frame, used)) => {
                    conn.rbuf.drain(..used);
                    if let Some(why) = self.handle_frame(slot, conn, frame) {
                        return Some(why);
                    }
                }
                Err(WireError::Truncated { .. }) => return None,
                Err(WireError::UnknownTag { got, skip }) => {
                    // fully framed and CRC-valid: step over it in place
                    log::debug!("reactor: unknown frame tag {got:#04x}; skipped");
                    conn.rbuf.drain(..skip.min(conn.rbuf.len()));
                }
                Err(e) => {
                    // framing is lost on this connection only: NACK
                    // best-effort and close; other connections unharmed
                    log::warn!("reactor: poisoned stream on slot {slot}: {e}");
                    self.queue_nack(conn, format!("wire error, closing connection: {e}"));
                    return Some(Close::Poisoned);
                }
            }
        }
    }

    /// One decoded frame from a peer.
    fn handle_frame(&mut self, slot: usize, conn: &mut Conn, frame: Frame) -> Option<Close> {
        match frame {
            Frame::Hello { ue_id } => {
                if ue_id >= self.cfg.max_ues {
                    self.queue_nack(
                        conn,
                        format!("ue_id {ue_id} out of range (reactor admits {} UEs)", self.cfg.max_ues),
                    );
                    return Some(Close::Rejected);
                }
                // latest wins: a reconnecting station must not race its
                // old socket's EOF — move the registration here
                if let Some(&Some(old)) = self.by_ue.get(ue_id) {
                    if old != slot {
                        log::debug!("reactor: UE {ue_id} takes over from slot {old}");
                        if let Some(old_conn) = self.conns.get_mut(old).and_then(Option::as_mut) {
                            old_conn.ues.retain(|&u| u != ue_id);
                        }
                    }
                }
                if let Some(owner) = self.by_ue.get_mut(ue_id) {
                    *owner = Some(slot);
                }
                if !conn.ues.contains(&ue_id) {
                    conn.ues.push(ue_id);
                }
                let mark = conn.wbuf.mark();
                encode_frame_append(&Frame::Welcome { ue_id }, conn.wbuf.append_vec());
                if conn.wbuf.len() > self.cfg.write_buf_cap {
                    conn.wbuf.truncate_to(mark);
                    return Some(Close::Evicted);
                }
                None
            }
            Frame::Up(up) => {
                let claimed = match &up {
                    Uplink::Report(r) => r.ue_id,
                    Uplink::Offload(o) => o.ue_id,
                    Uplink::Goodbye { ue_id } => *ue_id,
                };
                // anti-spoof: the claimed UE must be registered on THIS
                // connection (covers unknown ids and takeovers at once)
                if self.by_ue.get(claimed).copied().flatten() != Some(slot) {
                    log::warn!("reactor: slot {slot} sent a frame claiming UE {claimed}; dropped");
                    return None;
                }
                if let Uplink::Goodbye { ue_id } = up {
                    // a polite leave: deregister now so closing the
                    // socket later does not synthesize a second Goodbye
                    if let Some(owner) = self.by_ue.get_mut(ue_id) {
                        *owner = None;
                    }
                    conn.ues.retain(|&u| u != ue_id);
                }
                self.route_uplink(up);
                None
            }
            other => {
                log::warn!("reactor: peer sent an unexpected {other:?}; dropped");
                None
            }
        }
    }

    /// Hand an uplink to its owning shard (nonblocking; a full shard
    /// queue drops the frame and counts it).
    fn route_uplink(&mut self, up: Uplink) {
        let ue_id = match &up {
            Uplink::Report(r) => r.ue_id,
            Uplink::Offload(o) => o.ue_id,
            Uplink::Goodbye { ue_id } => *ue_id,
        };
        let Some(shard) = self.map.shard_of(ue_id) else {
            return;
        };
        let Some(tx) = self.up_txs.get(shard) else {
            return;
        };
        match tx.try_send(up) {
            Ok(()) => {}
            Err(TrySendError::Full(_)) => {
                self.stats.uplink_drops += 1;
                log::warn!("reactor: shard {shard} uplink queue full — frame from UE {ue_id} dropped");
            }
            // the shard's loop exited; nothing to route to
            Err(TrySendError::Disconnected(_)) => {}
        }
    }

    /// Best-effort session NACK into the connection's write buffer.
    fn queue_nack(&mut self, conn: &mut Conn, error: String) {
        let mark = conn.wbuf.mark();
        encode_frame_append(
            &Frame::Down(Downlink::Error {
                task_id: SESSION_ERROR_TASK,
                error,
            }),
            conn.wbuf.append_vec(),
        );
        if conn.wbuf.len() > self.cfg.write_buf_cap {
            conn.wbuf.truncate_to(mark);
        }
    }

    /// Flush what we can, deregister the connection's UEs (synthesizing
    /// `Goodbye`s so no shard waits on them forever) and shut the socket.
    fn close_conn(&mut self, mut conn: Conn, why: Close) {
        let label = match why {
            Close::Eof => "eof",
            Close::IoError => "io error",
            Close::Poisoned => "poisoned stream",
            Close::Rejected => "rejected",
            Close::Evicted => "evicted",
        };
        log::debug!("reactor: closing connection ({label}, {} UEs)", conn.ues.len());
        // last-gasp flush so NACKs/Welcomes already buffered get a chance
        if !conn.wbuf.is_empty() {
            let _ = conn.stream.write(conn.wbuf.pending());
        }
        let ues = std::mem::take(&mut conn.ues);
        for ue_id in ues {
            if let Some(owner) = self.by_ue.get_mut(ue_id) {
                *owner = None;
            }
            self.stats.goodbyes_synthesized += 1;
            self.route_uplink(Uplink::Goodbye { ue_id });
        }
        let _ = conn.stream.shutdown(Shutdown::Both);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::protocol::UeStateReport;
    use crate::transport::tcp::TcpClientTransport;
    use crate::transport::ClientTransport;

    fn report(ue_id: usize) -> Uplink {
        Uplink::Report(UeStateReport {
            ue_id,
            tasks_left: 2,
            compute_left_s: 0.1,
            offload_left_bits: 5.0,
            distance_m: 30.0,
        })
    }

    fn wait_uplink(t: &mut ReactorShardTransport) -> Option<Uplink> {
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        while std::time::Instant::now() < deadline {
            if let Some(u) = t.try_recv().unwrap() {
                return Some(u);
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        None
    }

    #[test]
    fn single_ue_client_roundtrips_through_the_reactor() {
        let cfg = ReactorConfig::new(4, 2);
        let (reactor, mut shards) = TcpReactor::bind("127.0.0.1:0", cfg).unwrap();
        let addr = reactor.local_addr();
        // UE 3 belongs to shard 1 of the 4-UE map
        let mut client = TcpClientTransport::connect(addr, 3).unwrap();
        client.send(report(3)).unwrap();
        assert_eq!(wait_uplink(&mut shards[1]), Some(report(3)));
        // downlink rides a DownTo envelope; the client unwraps its own
        shards[1].send_to(3, Downlink::Shutdown);
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        loop {
            match client.recv_timeout(Duration::from_millis(100)).unwrap() {
                Some(Downlink::Shutdown) => break,
                Some(other) => panic!("expected shutdown, got {other:?}"),
                None => assert!(std::time::Instant::now() < deadline, "no shutdown in time"),
            }
        }
        let stats = reactor.stop();
        assert_eq!(stats.accepted, 1);
        // after stop the shard uplink reports closure
        assert!(matches!(shards[0].try_recv(), Err(TransportError::Closed)));
    }

    #[test]
    fn hello_takeover_moves_the_registration() {
        let cfg = ReactorConfig::new(2, 1);
        let (reactor, mut shards) = TcpReactor::bind("127.0.0.1:0", cfg).unwrap();
        let addr = reactor.local_addr();
        let first = TcpClientTransport::connect(addr, 0).unwrap();
        // second session for the same UE: latest wins, no rejection
        let mut second = TcpClientTransport::connect(addr, 0).unwrap();
        second.send(report(0)).unwrap();
        assert_eq!(wait_uplink(&mut shards[0]), Some(report(0)));
        drop(first);
        drop(second);
        reactor.stop();
    }
}
