//! Tiny command-line argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args, with
//! typed getters and a generated usage string.

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an explicit iterator (tests) or `std::env::args` (main).
    pub fn parse<I: IntoIterator<Item = String>>(iter: I) -> Args {
        let mut out = Args::default();
        let mut iter = iter.into_iter().peekable();
        while let Some(arg) = iter.next() {
            if let Some(rest) = arg.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|nxt| !nxt.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    out.flags.insert(rest.to_string(), v);
                } else {
                    out.flags.insert(rest.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow!("--{key} expects an integer, got '{v}': {e}")),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow!("--{key} expects a number, got '{v}': {e}")),
        }
    }

    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow!("--{key} expects an integer, got '{v}': {e}")),
        }
    }

    /// Comma-separated list of numbers, e.g. `--betas 0.01,0.1,1`.
    pub fn f64_list(&self, key: &str, default: &[f64]) -> Result<Vec<f64>> {
        match self.get(key) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|x| {
                    x.trim()
                        .parse()
                        .map_err(|e| anyhow!("--{key} element '{x}': {e}"))
                })
                .collect(),
        }
    }

    pub fn usize_list(&self, key: &str, default: &[usize]) -> Result<Vec<usize>> {
        match self.get(key) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|x| {
                    x.trim()
                        .parse()
                        .map_err(|e| anyhow!("--{key} element '{x}': {e}"))
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn positional_and_flags() {
        let a = parse("exp fig8 --steps 500 --fast --beta=0.47");
        assert_eq!(a.positional, vec!["exp", "fig8"]);
        assert_eq!(a.usize_or("steps", 0).unwrap(), 500);
        assert!(a.has("fast"));
        assert_eq!(a.f64_or("beta", 0.0).unwrap(), 0.47);
    }

    #[test]
    fn lists_and_defaults() {
        let a = parse("--betas 0.01,0.1,1");
        assert_eq!(a.f64_list("betas", &[]).unwrap(), vec![0.01, 0.1, 1.0]);
        assert_eq!(a.usize_or("missing", 7).unwrap(), 7);
        assert_eq!(a.str_or("model", "resnet18"), "resnet18");
    }

    #[test]
    fn negative_number_as_value() {
        let a = parse("--offset -3.5");
        // "-3.5" does not start with "--" so it is consumed as the value
        assert_eq!(a.f64_or("offset", 0.0).unwrap(), -3.5);
    }

    #[test]
    fn bad_number_errors() {
        let a = parse("--steps abc");
        assert!(a.usize_or("steps", 1).is_err());
    }
}
