//! Property-testing helper (proptest is unavailable offline).
//!
//! `forall(seed, cases, gen, prop)` draws `cases` random inputs from `gen`
//! and asserts `prop`; on failure it performs a simple halving shrink over
//! the generator's size parameter and reports the smallest failing case's
//! seed so the exact input can be replayed deterministically.

use super::rng::Rng;

/// Context handed to generators: an RNG plus a "size" hint that shrinks.
pub struct Gen<'a> {
    pub rng: &'a mut Rng,
    pub size: usize,
}

impl<'a> Gen<'a> {
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        let hi = hi.min(lo + self.size.max(1));
        lo + self.rng.below((hi - lo).max(1))
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.uniform(lo, hi)
    }

    pub fn vec_f32(&mut self, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..len)
            .map(|_| self.rng.uniform(lo as f64, hi as f64) as f32)
            .collect()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }
}

/// Run a property over `cases` random inputs. Panics (with replay info) on
/// the first failure after shrinking the size parameter.
pub fn forall<T, G, P>(seed: u64, cases: usize, mut generate: G, mut prop: P)
where
    G: FnMut(&mut Gen) -> T,
    P: FnMut(&T) -> Result<(), String>,
    T: std::fmt::Debug,
{
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let case_seed = rng.next_u64();
        let mut failure: Option<(usize, String, T)> = None;
        // try full size first, then shrink the size hint on failure
        let mut size = 64usize;
        loop {
            let mut crng = Rng::new(case_seed);
            let mut g = Gen {
                rng: &mut crng,
                size,
            };
            let input = generate(&mut g);
            match prop(&input) {
                Ok(()) => {
                    if failure.is_some() {
                        break; // shrunk too far; report the last failure
                    }
                    break;
                }
                Err(msg) => {
                    failure = Some((size, msg, input));
                    if size <= 1 {
                        break;
                    }
                    size /= 2;
                }
            }
        }
        if let Some((size, msg, input)) = failure {
            panic!(
                "property failed (case {case}, replay seed {case_seed:#x}, size {size}):\n  {msg}\n  input: {input:?}"
            );
        }
    }
}

/// Convenience: assert two f32 slices are elementwise close.
pub fn assert_close(a: &[f32], b: &[f32], atol: f32, rtol: f32) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch: {} vs {}", a.len(), b.len()));
    }
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        let tol = atol + rtol * y.abs();
        if (x - y).abs() > tol {
            return Err(format!("index {i}: {x} vs {y} (tol {tol})"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        forall(
            1,
            50,
            |g| {
                let len = g.usize_in(1, 32);
                g.vec_f32(len, -1.0, 1.0)
            },
            |v| {
                if v.iter().all(|x| x.abs() <= 1.0) {
                    Ok(())
                } else {
                    Err("out of range".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics() {
        forall(
            2,
            10,
            |g| g.usize_in(0, 100),
            |&x| if x < 1000 && x % 97 != 13 { Ok(()) } else { Err("hit".into()) },
        );
        // force at least one failing draw
        forall(3, 1000, |g| g.usize_in(0, 100), |&x| {
            if x % 7 != 3 {
                Ok(())
            } else {
                Err("x % 7 == 3".into())
            }
        });
    }

    #[test]
    fn close_helper() {
        assert!(assert_close(&[1.0], &[1.0 + 1e-7], 1e-6, 0.0).is_ok());
        assert!(assert_close(&[1.0], &[1.1], 1e-6, 0.0).is_err());
        assert!(assert_close(&[1.0], &[1.0, 2.0], 1e-6, 0.0).is_err());
    }
}
