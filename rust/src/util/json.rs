//! Minimal JSON parser/serializer (RFC 8259 subset sufficient for the
//! artifact manifest, device profiles and experiment result files).
//!
//! Design notes: a single-pass recursive-descent parser over bytes; numbers
//! are always `f64` (the manifest only carries integers that fit exactly);
//! object key order is preserved for stable round-trips.

use std::collections::BTreeMap;
use std::fmt;

use anyhow::{anyhow, bail, Result};

/// A JSON value. Objects preserve insertion order via a Vec of pairs.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    // ------------------------------------------------------------ accessors
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Like `get` but returns an error naming the missing key.
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing key '{key}'"))
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(x) => Ok(*x),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let x = self.as_f64()?;
        if x < 0.0 || x.fract() != 0.0 {
            bail!("expected non-negative integer, got {x}");
        }
        Ok(x as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("expected bool, got {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("expected array, got {self:?}"),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.as_f64().ok()).unwrap_or(default)
    }

    /// Fetch `key` as f64 or error.
    pub fn f64_of(&self, key: &str) -> Result<f64> {
        self.req(key)?.as_f64()
    }

    pub fn usize_of(&self, key: &str) -> Result<usize> {
        self.req(key)?.as_usize()
    }

    pub fn str_of(&self, key: &str) -> Result<&str> {
        self.req(key)?.as_str()
    }

    // --------------------------------------------------------- construction
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    pub fn set(mut self, key: &str, val: impl Into<Json>) -> Json {
        if let Json::Obj(ref mut pairs) = self {
            pairs.push((key.to_string(), val.into()));
        }
        self
    }

    // -------------------------------------------------------------- parsing
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing characters at byte {}", p.pos);
        }
        Ok(v)
    }

    pub fn parse_file(path: impl AsRef<std::path::Path>) -> Result<Json> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("reading {}: {e}", path.display()))?;
        Json::parse(&text).map_err(|e| anyhow!("parsing {}: {e}", path.display()))
    }

    pub fn write_file(&self, path: impl AsRef<std::path::Path>) -> Result<()> {
        if let Some(dir) = path.as_ref().parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_string())?;
        Ok(())
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Json {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Json {
        Json::Str(x)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}
impl From<&[f64]> for Json {
    fn from(v: &[f64]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x)).collect())
    }
}
impl From<BTreeMap<String, f64>> for Json {
    fn from(m: BTreeMap<String, f64>) -> Json {
        Json::Obj(m.into_iter().map(|(k, v)| (k, Json::Num(v))).collect())
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len() && self.bytes[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.bytes
            .get(self.pos)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek()? != b {
            bail!("expected '{}' at byte {}", b as char, self.pos);
        }
        self.pos += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, val: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(val)
        } else {
            bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut pairs = Vec::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.pos += 1;
            return Ok(Json::Obj(pairs));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            pairs.push((key, val));
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b'}' => {
                    self.pos += 1;
                    return Ok(Json::Obj(pairs));
                }
                c => bail!("expected ',' or '}}', got '{}' at byte {}", c as char, self.pos),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b']' => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                c => bail!("expected ',' or ']', got '{}' at byte {}", c as char, self.pos),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let b = self.peek()?;
            self.pos += 1;
            match b {
                b'"' => return Ok(out),
                b'\\' => {
                    let esc = self.peek()?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let hex = self
                                .bytes
                                .get(self.pos..self.pos + 4)
                                .ok_or_else(|| anyhow!("truncated \\u escape"))?;
                            let code = u32::from_str_radix(std::str::from_utf8(hex)?, 16)?;
                            self.pos += 4;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape at byte {}", self.pos),
                    }
                }
                _ => {
                    // collect the full UTF-8 sequence
                    let start = self.pos - 1;
                    let len = utf8_len(b);
                    self.pos = start + len;
                    out.push_str(std::str::from_utf8(&self.bytes[start..self.pos])?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(s.parse::<f64>().map_err(|e| anyhow!("bad number '{s}': {e}"))?))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

// ------------------------------------------------------------- serialization
impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(items) => {
                write!(f, "[")?;
                for (i, it) in items.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{it}")?;
                }
                write!(f, "]")
            }
            Json::Obj(pairs) => {
                write!(f, "{{")?;
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-12.5e2").unwrap(), Json::Num(-1250.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(j.req("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.req("a").unwrap().as_arr().unwrap()[2].str_of("b").unwrap(),
            "x"
        );
        assert_eq!(j.get("c"), Some(&Json::Null));
        assert!(j.get("missing").is_none());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"name":"macci","n":3,"xs":[1,2.5,-4],"ok":true,"s":"a\"b"}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn unicode_string() {
        let j = Json::parse("\"caf\\u00e9 ☕\"").unwrap();
        assert_eq!(j, Json::Str("café ☕".into()));
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn builder() {
        let j = Json::obj().set("a", 1usize).set("b", "x");
        assert_eq!(j.to_string(), r#"{"a":1,"b":"x"}"#);
    }
}
