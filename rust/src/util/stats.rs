//! Small statistics helpers shared by metrics, benches and experiments.

/// Mean of a slice (0.0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population standard deviation.
pub fn std(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// p-th percentile (0..=100) via nearest-rank on a sorted copy.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (v.len() - 1) as f64).round() as usize;
    v[rank.min(v.len() - 1)]
}

/// Sliding-window average used to smooth reward curves (paper smooths with
/// the 5 nearest values; window = 5 reproduces that).
pub fn smooth(xs: &[f64], window: usize) -> Vec<f64> {
    if window <= 1 || xs.is_empty() {
        return xs.to_vec();
    }
    let half = window / 2;
    (0..xs.len())
        .map(|i| {
            let lo = i.saturating_sub(half);
            let hi = (i + half + 1).min(xs.len());
            mean(&xs[lo..hi])
        })
        .collect()
}

/// Exponential moving average.
pub fn ema(xs: &[f64], alpha: f64) -> Vec<f64> {
    let mut out = Vec::with_capacity(xs.len());
    let mut acc = None;
    for &x in xs {
        acc = Some(match acc {
            None => x,
            Some(prev) => alpha * x + (1.0 - alpha) * prev,
        });
        out.push(acc.unwrap());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_moments() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((std(&xs) - 1.118).abs() < 1e-3);
    }

    #[test]
    fn percentiles() {
        let xs: Vec<f64> = (0..101).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 0.0), 0.0);
        assert_eq!(percentile(&xs, 50.0), 50.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }

    #[test]
    fn smoothing_preserves_length_and_mean_of_constant() {
        let xs = vec![2.0; 10];
        let s = smooth(&xs, 5);
        assert_eq!(s.len(), 10);
        assert!(s.iter().all(|&x| (x - 2.0).abs() < 1e-12));
    }

    #[test]
    fn ema_converges() {
        let xs = vec![1.0; 50];
        let e = ema(&xs, 0.1);
        assert!((e[49] - 1.0).abs() < 1e-9);
    }
}
