//! In-repo substrates for the offline build: JSON, RNG, CLI parsing,
//! a micro-benchmark harness, a property-testing helper, the audited
//! home for env knobs ([`config`]) and poison-tolerant locks ([`sync`]).
//!
//! These exist because the build is fully offline (vendored crates only) —
//! serde_json / rand / clap / criterion / proptest are not available, and
//! each of these modules implements the subset this project needs, with
//! unit tests alongside.

pub mod bench;
pub mod check;
pub mod cli;
pub mod config;
pub mod json;
pub mod rng;
pub mod stats;
pub mod sync;
