//! In-repo substrates for the offline build: JSON, RNG, CLI parsing,
//! a micro-benchmark harness and a property-testing helper.
//!
//! These exist because the build is fully offline (vendored crates only) —
//! serde_json / rand / clap / criterion / proptest are not available, and
//! each of these modules implements the subset this project needs, with
//! unit tests alongside.

pub mod bench;
pub mod check;
pub mod cli;
pub mod json;
pub mod rng;
pub mod stats;
