//! The single audited home for `MACCI_*` environment knobs.
//!
//! Every knob is **latched once per process** on first read: changing the
//! environment afterwards has no effect, so a knob can never flip
//! mid-run (the scattered-latch footgun that previously forced ci.sh to
//! rerun kernel suites in fresh processes is now a structural guarantee
//! for *every* knob, not just `MACCI_FORCE_SCALAR`). The invariant that
//! raw `std::env::var` reads appear only in this module is machine-checked
//! by macci-lint rule R4 (`env-config`).
//!
//! | variable                   | accessor                 | semantics |
//! |----------------------------|--------------------------|-----------|
//! | `MACCI_FORCE_SCALAR`       | [`force_scalar`]         | non-empty, ≠ "0" pins scalar kernels |
//! | `MACCI_PRECISION`          | [`precision`]            | raw spelling; parsed by `Precision` |
//! | `MACCI_BACKEND`            | [`backend`]              | raw spelling; parsed by `default_backend` |
//! | `MACCI_N_ENVS`             | [`n_envs`]               | rollout lanes (≥ 1) |
//! | `MACCI_UPDATE_THREADS`     | [`update_threads`]       | PPO update workers (≥ 1) |
//! | `MACCI_BENCH_MS`           | [`bench_ms`]             | per-case bench budget |
//! | `MACCI_BENCH_SERVING_TASKS`| [`bench_serving_tasks`]  | serving-bench tasks per UE |
//! | `MACCI_BENCH_LOAD_UES`     | [`bench_load_ues`]       | load-bench fleet size cap |
//! | `MACCI_OFFLOAD_CACHE`      | [`offload_cache`]        | offload result cache entries (0 = off) |
//! | `MACCI_LOG`                | [`log_level`]            | raw level spelling |

use once_cell::sync::Lazy;

/// The one raw environment read in the codebase (R4's audited exception).
fn raw(name: &str) -> Option<String> {
    // lint: allow(env-config) — this module IS the audited home for env reads
    std::env::var(name).ok()
}

/// `raw`, with the common "set but empty means unset" convention applied.
fn raw_nonempty(name: &str) -> Option<String> {
    raw(name).filter(|v| !v.is_empty())
}

static FORCE_SCALAR: Lazy<bool> =
    Lazy::new(|| raw("MACCI_FORCE_SCALAR").map(|v| !v.is_empty() && v != "0").unwrap_or(false));
static PRECISION: Lazy<Option<String>> = Lazy::new(|| raw_nonempty("MACCI_PRECISION"));
static BACKEND: Lazy<Option<String>> = Lazy::new(|| raw_nonempty("MACCI_BACKEND"));
static N_ENVS: Lazy<Option<usize>> =
    Lazy::new(|| raw("MACCI_N_ENVS").and_then(|v| v.parse().ok()).filter(|&e| e >= 1));
static UPDATE_THREADS: Lazy<Option<usize>> =
    Lazy::new(|| raw("MACCI_UPDATE_THREADS").and_then(|v| v.parse().ok()).filter(|&t| t >= 1));
static BENCH_MS: Lazy<Option<u64>> =
    Lazy::new(|| raw("MACCI_BENCH_MS").and_then(|v| v.parse().ok()));
static BENCH_SERVING_TASKS: Lazy<Option<u64>> =
    Lazy::new(|| raw("MACCI_BENCH_SERVING_TASKS").and_then(|v| v.parse().ok()));
static BENCH_LOAD_UES: Lazy<Option<u64>> =
    Lazy::new(|| raw("MACCI_BENCH_LOAD_UES").and_then(|v| v.parse().ok()).filter(|&u| u >= 1));
static OFFLOAD_CACHE: Lazy<Option<usize>> =
    Lazy::new(|| raw("MACCI_OFFLOAD_CACHE").and_then(|v| v.parse().ok()));
static LOG_LEVEL: Lazy<Option<String>> = Lazy::new(|| raw("MACCI_LOG"));

/// `MACCI_FORCE_SCALAR`: pin the scalar reference kernels (any non-empty
/// value other than `"0"`). Latched before the first kernel dispatch.
pub fn force_scalar() -> bool {
    *FORCE_SCALAR
}

/// `MACCI_PRECISION`: the raw precision spelling, if set and non-empty.
/// Parsing (and the fallback-to-f32 warning) lives with
/// `crate::runtime::backend::Precision`.
pub fn precision() -> Option<&'static str> {
    PRECISION.as_deref()
}

/// `MACCI_BACKEND`: the raw backend spelling, if set and non-empty.
pub fn backend() -> Option<&'static str> {
    BACKEND.as_deref()
}

/// `MACCI_N_ENVS`: rollout lanes per trainer; values < 1 and unparsable
/// spellings fall back to `default`.
pub fn n_envs(default: usize) -> usize {
    N_ENVS.unwrap_or(default)
}

/// `MACCI_UPDATE_THREADS`: process-default PPO update worker count, used
/// when a net has no explicit `update_threads` request (values < 1 and
/// unparsable spellings count as unset). Worker count never changes the
/// trained bits — see `runtime::native::update`.
pub fn update_threads() -> Option<usize> {
    *UPDATE_THREADS
}

/// `MACCI_BENCH_MS`: per-case benchmark time budget in milliseconds.
pub fn bench_ms(default_ms: u64) -> u64 {
    BENCH_MS.unwrap_or(default_ms)
}

/// `MACCI_BENCH_SERVING_TASKS`: tasks per UE in the serving bench.
pub fn bench_serving_tasks(default: u64) -> u64 {
    BENCH_SERVING_TASKS.unwrap_or(default)
}

/// `MACCI_BENCH_LOAD_UES`: the largest fleet the load bench drives
/// (values < 1 and unparsable spellings fall back to `default`). CI sets
/// this low so the smoke step stays bounded.
pub fn bench_load_ues(default: u64) -> u64 {
    BENCH_LOAD_UES.unwrap_or(default)
}

/// `MACCI_OFFLOAD_CACHE`: capacity (entries) of the server's
/// content-addressed offload result cache. 0 (the default, and any
/// unparsable spelling) disables the cache — today's recompute-always
/// behavior. See `coordinator::offload_cache`.
pub fn offload_cache() -> usize {
    OFFLOAD_CACHE.unwrap_or(0)
}

/// `MACCI_LOG`: the raw log-level spelling ("debug", "trace", ...).
pub fn log_level() -> Option<&'static str> {
    LOG_LEVEL.as_deref()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accessors_latch_and_default() {
        // defaults must hold when the knobs are unset, and repeated reads
        // must agree (latch-once)
        if N_ENVS.is_none() {
            assert_eq!(n_envs(1), 1);
            assert_eq!(n_envs(4), 4);
        }
        if BENCH_MS.is_none() {
            assert_eq!(bench_ms(700), 700);
        }
        if BENCH_SERVING_TASKS.is_none() {
            assert_eq!(bench_serving_tasks(64), 64);
        }
        assert_eq!(force_scalar(), force_scalar());
        assert_eq!(precision(), precision());
    }
}
