//! Deterministic pseudo-random numbers: xoshiro256++ seeded via SplitMix64,
//! plus the distributions the simulator needs (uniform, normal, Poisson,
//! categorical). No external `rand` — the build is offline, and full
//! reproducibility of every experiment run matters more than generator
//! variety.

/// xoshiro256++ PRNG (Blackman & Vigna). Passes BigCrush; 2^256-1 period.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so nearby seeds give uncorrelated streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9e3779b97f4a7c15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
            z ^ (z >> 31)
        };
        Rng {
            s: [next(), next(), next(), next()],
        }
    }

    /// Derive an independent stream (for per-UE / per-seed sub-generators).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9e3779b97f4a7c15))
    }

    /// The raw xoshiro256++ state, for checkpointing. Restoring it with
    /// [`Rng::from_state`] resumes the stream at exactly this position.
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator from a captured [`Rng::state`]. The all-zero
    /// state is the generator's single fixed point (it would emit zeros
    /// forever), so it is rejected — a seeded stream can never reach it.
    pub fn from_state(s: [u64; 4]) -> Option<Rng> {
        if s == [0; 4] {
            return None;
        }
        Some(Rng { s })
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform in [lo, hi).
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection-free bound is overkill here;
        // the simple modulo bias is < 2^-53 * n for our small n.
        (self.f64() * n as f64) as usize % n
    }

    /// Standard normal via Box–Muller (one value per call; simple > fast).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-300 {
                let u2 = self.f64();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    pub fn normal_scaled(&mut self, mu: f64, sigma: f64) -> f64 {
        mu + sigma * self.normal()
    }

    /// Poisson sample. Knuth for small lambda, normal approximation above.
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        if lambda <= 0.0 {
            return 0;
        }
        if lambda < 30.0 {
            let l = (-lambda).exp();
            let mut k = 0u64;
            let mut p = 1.0;
            loop {
                p *= self.f64();
                if p <= l {
                    return k;
                }
                k += 1;
            }
        } else {
            let x = self.normal_scaled(lambda, lambda.sqrt());
            x.max(0.0).round() as u64
        }
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn categorical(&mut self, weights: &[f32]) -> usize {
        let total: f64 = weights.iter().map(|&w| w.max(0.0) as f64).sum();
        if total <= 0.0 {
            return self.below(weights.len());
        }
        let mut x = self.f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            x -= w.max(0.0) as f64;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// k distinct indices out of [0, n) (k <= n), in random order.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let mut idx = Vec::new();
        self.sample_indices_into(n, k, &mut idx);
        idx
    }

    /// [`Rng::sample_indices`] into a caller-provided buffer — reads the
    /// exact same stream positions (same shuffle of 0..n, truncated to k),
    /// so callers can swap between the two without changing any draw.
    pub fn sample_indices_into(&mut self, n: usize, k: usize, idx: &mut Vec<usize>) {
        idx.clear();
        idx.extend(0..n);
        self.shuffle(idx);
        idx.truncate(k);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn state_roundtrip_resumes_stream() {
        let mut a = Rng::new(42);
        for _ in 0..17 {
            a.next_u64();
        }
        let mut b = Rng::from_state(a.state()).unwrap();
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        assert!(Rng::from_state([0; 4]).is_none(), "all-zero state rejected");
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_moments() {
        let mut r = Rng::new(7);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn poisson_mean() {
        let mut r = Rng::new(11);
        for &lambda in &[0.5, 5.0, 200.0] {
            let n = 20_000;
            let mean = (0..n).map(|_| r.poisson(lambda) as f64).sum::<f64>() / n as f64;
            assert!(
                (mean - lambda).abs() < lambda.max(1.0) * 0.05,
                "lambda {lambda} mean {mean}"
            );
        }
    }

    #[test]
    fn categorical_distribution() {
        let mut r = Rng::new(13);
        let w = [1.0f32, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[r.categorical(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.3, "ratio {ratio}");
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(17);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }
}
