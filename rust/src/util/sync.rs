//! Poison-tolerant lock helpers for the panic-free serving path.
//!
//! `Mutex::lock().unwrap()` turns one panicked thread into a cascade:
//! every other thread touching the lock panics too. The no-panic zones
//! (macci-lint rule R1) use these accessors instead — a poisoned lock
//! yields its inner guard and the system keeps serving. That is safe
//! here because every guarded structure (peer maps, job queues, warmed
//! caches) is valid after any partial update: entries are inserted or
//! removed atomically with respect to the guard.

use std::sync::{Mutex, MutexGuard, RwLock, RwLockReadGuard, RwLockWriteGuard};

/// Lock a mutex, recovering the guard from a poisoned lock instead of
/// panicking (the poisoning thread's panic was already reported).
pub fn lock_unpoisoned<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Read-lock an `RwLock`, recovering from poison instead of panicking.
pub fn read_unpoisoned<T>(l: &RwLock<T>) -> RwLockReadGuard<'_, T> {
    l.read().unwrap_or_else(|e| e.into_inner())
}

/// Write-lock an `RwLock`, recovering from poison instead of panicking.
pub fn write_unpoisoned<T>(l: &RwLock<T>) -> RwLockWriteGuard<'_, T> {
    l.write().unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisoned_mutex_still_yields_its_guard() {
        let m = std::sync::Arc::new(Mutex::new(7));
        let m2 = m.clone();
        let _ = std::thread::Builder::new()
            .name("poisoner".into())
            .spawn(move || {
                let _g = m2.lock().unwrap();
                panic!("poison the lock");
            })
            .map(|h| h.join());
        assert!(m.is_poisoned());
        assert_eq!(*lock_unpoisoned(&m), 7);
        *lock_unpoisoned(&m) = 8;
        assert_eq!(*lock_unpoisoned(&m), 8);
    }

    #[test]
    fn rwlock_helpers_roundtrip() {
        let l = RwLock::new(1);
        *write_unpoisoned(&l) = 2;
        assert_eq!(*read_unpoisoned(&l), 2);
    }
}
