//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! Usage inside a `harness = false` bench binary:
//! ```no_run
//! use macci::util::bench::Bench;
//! let mut b = Bench::new("channel");
//! b.run("uplink_rate", || { /* work */ });
//! b.report();
//! ```
//! Each case is warmed up, then timed over adaptively-chosen batches until
//! the target wall time is reached; mean / p50 / p99 per-iteration times are
//! reported, and results are appended to `results/bench.json` so the perf
//! pass (EXPERIMENTS.md §Perf) can diff before/after.

use std::time::{Duration, Instant};

use super::json::Json;
use super::stats;

pub struct CaseResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
}

pub struct Bench {
    group: String,
    target: Duration,
    results: Vec<CaseResult>,
    /// Derived scalar figures (e.g. GFLOP/s) recorded alongside the timed
    /// cases — written to the same JSON keyed `group/name`.
    gauges: Vec<(String, f64)>,
}

impl Bench {
    pub fn new(group: &str) -> Bench {
        Bench {
            group: group.to_string(),
            target: Duration::from_millis(super::config::bench_ms(700)),
            results: Vec::new(),
            gauges: Vec::new(),
        }
    }

    /// Record a derived scalar (throughput, GFLOP/s, speedup ratio) so it
    /// lands in the merged JSON next to the timings it was computed from.
    pub fn gauge(&mut self, name: impl Into<String>, value: f64) {
        let name = name.into();
        println!("{:>34}  {value:.3}", format!("{}/{name}", self.group));
        self.gauges.push((name, value));
    }

    /// Time `f`, which should perform ONE iteration of the workload.
    pub fn run<F: FnMut()>(&mut self, name: &str, mut f: F) {
        // Warmup + estimate per-iter cost.
        let t0 = Instant::now();
        let mut warm_iters = 0u64;
        while t0.elapsed() < self.target / 10 || warm_iters < 3 {
            f();
            warm_iters += 1;
            if warm_iters > 1_000_000 {
                break;
            }
        }
        let est = t0.elapsed().as_secs_f64() / warm_iters as f64;

        // Sample batches: aim for ~60 samples over the target duration.
        let batch = ((self.target.as_secs_f64() / 60.0 / est).ceil() as u64).max(1);
        let mut samples = Vec::new();
        let mut total_iters = 0u64;
        let start = Instant::now();
        while start.elapsed() < self.target && samples.len() < 400 {
            let t = Instant::now();
            for _ in 0..batch {
                f();
            }
            samples.push(t.elapsed().as_secs_f64() / batch as f64 * 1e9);
            total_iters += batch;
        }

        let res = CaseResult {
            name: name.to_string(),
            iters: total_iters,
            mean_ns: stats::mean(&samples),
            p50_ns: stats::percentile(&samples, 50.0),
            p99_ns: stats::percentile(&samples, 99.0),
        };
        println!(
            "{:>34}  mean {:>12}  p50 {:>12}  p99 {:>12}  ({} iters)",
            format!("{}/{}", self.group, res.name),
            fmt_ns(res.mean_ns),
            fmt_ns(res.p50_ns),
            fmt_ns(res.p99_ns),
            res.iters
        );
        self.results.push(res);
    }

    /// Append results to results/bench.json (keyed by group/case).
    pub fn report(&self) {
        self.merge_into("results/bench.json");
    }

    /// Merge this run's results into a JSON file (keyed by group/case),
    /// preserving entries from other groups/runs. `report` uses the shared
    /// results/bench.json; baselines like BENCH_runtime.json pass their own
    /// path.
    pub fn merge_into(&self, path: impl AsRef<std::path::Path>) {
        let path = path.as_ref();
        let mut root = if path.exists() {
            Json::parse_file(path).unwrap_or_else(|_| Json::obj())
        } else {
            Json::obj()
        };
        for r in &self.results {
            let key = format!("{}/{}", self.group, r.name);
            let entry = Json::obj()
                .set("mean_ns", r.mean_ns)
                .set("p50_ns", r.p50_ns)
                .set("p99_ns", r.p99_ns)
                .set("iters", r.iters);
            if let Json::Obj(ref mut pairs) = root {
                pairs.retain(|(k, _)| k != &key);
                pairs.push((key, entry));
            }
        }
        for (name, value) in &self.gauges {
            let key = format!("{}/{}", self.group, name);
            let entry = Json::obj().set("value", *value);
            if let Json::Obj(ref mut pairs) = root {
                pairs.retain(|(k, _)| k != &key);
                pairs.push((key, entry));
            }
        }
        let _ = root.write_file(path);
    }

    /// The cases timed so far — for benches that derive extra figures
    /// (e.g. throughput) from the raw per-iteration times.
    pub fn results(&self) -> &[CaseResult] {
        &self.results
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.1} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Prevent the optimizer from eliding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn formats() {
        assert_eq!(fmt_ns(12.0), "12.0 ns");
        assert_eq!(fmt_ns(12_500.0), "12.50 µs");
        assert_eq!(fmt_ns(12_500_000.0), "12.50 ms");
    }

    #[test]
    fn bench_runs_fast_case() {
        std::env::set_var("MACCI_BENCH_MS", "30");
        let mut b = Bench::new("selftest");
        let mut acc = 0u64;
        b.run("add", || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert_eq!(b.results.len(), 1);
        assert!(b.results[0].mean_ns > 0.0);
        b.gauge("add_rate", 1e9 / b.results[0].mean_ns);
        assert_eq!(b.gauges.len(), 1);
    }
}
