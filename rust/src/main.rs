//! `macci` — the launcher CLI.
//!
//! ```text
//! macci exp <fig4..fig13|headline|all> [--quick] [--frames N] [--seeds K]
//! macci train  [--n-ues 5] [--frames 6000] [--beta 0.47] [--lr 1e-4] [--model resnet18]
//!              [--save policy.ckpt] [--resume policy.ckpt]
//! macci eval   [--n-ues 5] [--policy local|random|edge_raw|split<k>]
//! macci serve  [--model resnet18] [--n-ues 3] [--tasks 16]
//! macci serve  --policy policy.ckpt [--frames 200] [--online-learn] [--shards K]
//! macci info                       # artifact + profile inventory
//! ```

use std::time::Duration;

use anyhow::{bail, Result};

use macci::coordinator::decision::{ActorDecision, DecisionMaker, PolicyHandle};
use macci::coordinator::inference::CollabPipeline;
use macci::coordinator::learner::{self, LearnerConfig};
use macci::coordinator::protocol::Uplink;
use macci::coordinator::server::{drive_env_ues, EdgeServer, ServerConfig};
use macci::coordinator::state_pool::{StateNorm, StatePool};
use macci::env::mdp::MultiAgentEnv;
use macci::env::scenario::ScenarioConfig;
use macci::exp::{self, common::ExpContext};
use macci::profiles::DeviceProfile;
use macci::rl::baselines::{evaluate_policy, BaselinePolicy, PolicyKind};
use macci::rl::checkpoint;
use macci::rl::mahppo::{MahppoTrainer, TrainConfig};
use macci::runtime::artifacts::ArtifactStore;
use macci::runtime::backend::Precision;
use macci::runtime::native::NativeBackend;
use macci::util::cli::Args;

const USAGE: &str = "\
macci — Multi-Agent Collaborative Inference (MAHPPO) coordinator

USAGE:
  macci exp <fig4|fig5|fig7|fig8|fig9|fig10|fig11|fig12|fig13|headline|all>
            [--quick] [--frames N] [--seeds K] [--lambda L] [--eval-episodes E]
  macci train [--n-ues 5] [--frames 6000] [--beta 0.47] [--lr 1e-4]
              [--model resnet18] [--seed 0] [--out results/train.json]
              [--save policy.ckpt] [--resume policy.ckpt]
              [--update-threads W]
  macci eval  [--n-ues 5] [--policy local|random|edge_raw|split2] [--episodes 3]
  macci serve [--model resnet18] [--n-ues 3] [--tasks 16] [--point 2]
              [--precision f32|int8]
  macci serve --policy policy.ckpt [--frames 200] [--interval-ms 2]
              [--online-learn] [--learn-lr 1e-3] [--precision f32|int8]
              [--shards K]
  macci info

`train --save` writes a versioned, CRC-guarded checkpoint of the FULL
trainer state (resume with `train --resume` is bit-exact); `serve
--policy` deploys the checkpointed actors at the edge, and
`--online-learn` keeps refining them from serving telemetry, hot-swapping
the serving policy between decision frames (see DESIGN.md
§Policy-Lifecycle). `--shards K` runs K independent shard loops, each
serving its own N-UE group from a replica of the checkpointed actors;
policy publishes fan out to every shard (DESIGN.md §Sharded-Serving).

Artifacts are read from ./artifacts (run `make artifacts` first).";

fn main() {
    env_logger_init();
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn env_logger_init() {
    // minimal logger: MACCI_LOG=debug enables debug lines on stderr
    struct L;
    impl log::Log for L {
        fn enabled(&self, m: &log::Metadata) -> bool {
            m.level() <= log::max_level()
        }
        fn log(&self, r: &log::Record) {
            if self.enabled(r.metadata()) {
                eprintln!("[{}] {}", r.level(), r.args());
            }
        }
        fn flush(&self) {}
    }
    static LOGGER: L = L;
    let level = match macci::util::config::log_level() {
        Some("debug") => log::LevelFilter::Debug,
        Some("trace") => log::LevelFilter::Trace,
        _ => log::LevelFilter::Info,
    };
    let _ = log::set_logger(&LOGGER);
    log::set_max_level(level);
}

fn run() -> Result<()> {
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("");
    match cmd {
        "exp" => cmd_exp(&args),
        "train" => cmd_train(&args),
        "eval" => cmd_eval(&args),
        "serve" => cmd_serve(&args),
        "info" => cmd_info(),
        _ => {
            println!("{USAGE}");
            Ok(())
        }
    }
}

fn open_store() -> Result<ArtifactStore> {
    ArtifactStore::open("artifacts")
}

/// Open the store honoring `--precision f32|int8` (serve paths). f32
/// keeps the process-default backend (so `MACCI_BACKEND`/`MACCI_PRECISION`
/// still apply); int8 forces the native backend at reduced precision.
fn open_store_at(args: &Args) -> Result<ArtifactStore> {
    let precision = Precision::parse(&args.str_or("precision", "f32"))?;
    match precision {
        Precision::F32 => open_store(),
        Precision::Int8 => ArtifactStore::with_backend(
            "artifacts",
            std::sync::Arc::new(NativeBackend::with_precision(precision)),
        ),
    }
}

fn cmd_exp(args: &Args) -> Result<()> {
    let name = args
        .positional
        .get(1)
        .map(|s| s.as_str())
        .unwrap_or("all");
    let store = open_store()?;
    let mut ctx = ExpContext::new(store, args.has("quick"));
    ctx.frames = args.usize_or("frames", ctx.frames)?;
    ctx.seeds = args.usize_or("seeds", ctx.seeds)?;
    ctx.lambda_tasks = args.f64_or("lambda", ctx.lambda_tasks)?;
    ctx.eval_episodes = args.usize_or("eval-episodes", ctx.eval_episodes)?;
    exp::run(name, &ctx)
}

fn cmd_train(args: &Args) -> Result<()> {
    let store = open_store()?;
    let frames = args.usize_or("frames", 6000)?;
    let mut trainer = if let Some(resume) = args.get("resume") {
        // a checkpoint restores the FULL config; flags that would change
        // it are discarded — say so instead of silently ignoring them
        for flag in [
            "model", "n-ues", "beta", "lambda", "lr", "buffer", "batch", "reuse", "seed",
            "n-envs", "update-threads",
        ] {
            if args.has(flag) {
                eprintln!(
                    "warning: --{flag} is ignored with --resume (the checkpoint's \
                     config is restored verbatim)"
                );
            }
        }
        println!("resuming MAHPPO training from {resume} ({frames} more frames)");
        MahppoTrainer::load(&store, resume)?
    } else {
        let model = args.str_or("model", "resnet18");
        let profile = DeviceProfile::load_or_synthetic(
            store.root.join("profiles").join(format!("{model}.json")),
        )?;
        let scenario = ScenarioConfig {
            n_ues: args.usize_or("n-ues", 5)?,
            beta: args.f64_or("beta", 0.47)?,
            lambda_tasks: args.f64_or("lambda", 200.0)?,
            ..Default::default()
        };
        let cfg = TrainConfig {
            lr: args.f64_or("lr", 1e-4)? as f32,
            buffer_size: args.usize_or("buffer", 1024)?,
            minibatch: args.usize_or("batch", 256)?,
            reuse: args.usize_or("reuse", 10)?,
            seed: args.u64_or("seed", 0)?,
            n_envs: args.usize_or("n-envs", 1)?,
            update_threads: args.usize_or("update-threads", 0)?,
            ..Default::default()
        };
        println!(
            "training MAHPPO: model={model} N={} frames={frames} beta={} lr={}",
            scenario.n_ues, scenario.beta, cfg.lr
        );
        MahppoTrainer::new(&store, &profile, scenario, cfg)?
    };
    let report = trainer.train(frames)?;
    println!(
        "done: {} episodes, final reward {:.2}, {:.1}s wall",
        report.episodes,
        report.final_reward(),
        report.wall_s
    );
    let out = args.str_or("out", "results/train.json");
    let r = report.into_report("training run");
    let slug = std::path::Path::new(&out)
        .file_stem()
        .and_then(|s| s.to_str())
        .unwrap_or("train")
        .to_string();
    let dir = std::path::Path::new(&out)
        .parent()
        .map(|p| p.display().to_string())
        .unwrap_or_else(|| "results".into());
    r.write(dir, &slug)?;
    println!("wrote {out}");

    if let Some(save) = args.get("save") {
        trainer.save(save)?;
        println!("saved trainer checkpoint to {save} (resume with --resume, serve with serve --policy)");
    }

    // post-training greedy evaluation (fresh eval-seeded env)
    let stats = trainer.evaluate(args.usize_or("episodes", 2)?)?;
    println!(
        "greedy eval: avg latency {:.1} ms, avg energy {:.1} mJ, reward {:.2}",
        stats.avg_latency * 1e3,
        stats.avg_energy * 1e3,
        stats.avg_reward
    );
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let store = open_store()?;
    let model = args.str_or("model", "resnet18");
    let profile =
        DeviceProfile::load_or_synthetic(store.root.join("profiles").join(format!("{model}.json")))?;
    let scenario = ScenarioConfig {
        n_ues: args.usize_or("n-ues", 5)?,
        eval_mode: true,
        lambda_tasks: args.f64_or("lambda", 200.0)?,
        eval_tasks: args.u64_or("tasks", 200)?,
        ..Default::default()
    };
    let policy_name = args.str_or("policy", "local");
    let kind = match policy_name.as_str() {
        "local" => PolicyKind::Local,
        "random" => PolicyKind::Random,
        "edge_raw" => PolicyKind::EdgeRaw,
        s if s.starts_with("split") => PolicyKind::FixedSplit(s[5..].parse().unwrap_or(2)),
        other => bail!("unknown policy '{other}'"),
    };
    let mut env = MultiAgentEnv::new(profile, scenario, args.u64_or("seed", 0)?)?;
    let mut policy = BaselinePolicy::new(kind, 1);
    let stats = evaluate_policy(&mut policy, &mut env, args.usize_or("episodes", 3)?)?;
    println!(
        "{policy_name}: avg latency {:.1} ms, avg energy {:.1} mJ, reward {:.2} ({} episodes)",
        stats.avg_latency * 1e3,
        stats.avg_energy * 1e3,
        stats.avg_reward,
        stats.episodes
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    if args.has("policy") {
        return cmd_serve_policy(args);
    }
    // small in-process serving demo; the full threaded pipeline lives in
    // examples/collab_serving.rs
    let store = open_store_at(args)?;
    let model = args.str_or("model", "resnet18");
    let pipeline = CollabPipeline::load(&store, &model)?;
    let point = args.usize_or("point", 2)?;
    let tasks = args.usize_or("tasks", 8)?;
    let images = macci::exp::fig4::smooth_images(tasks, pipeline.meta.input_hw, 3);
    println!("serving {tasks} requests through {model} split at p{point}");
    let mut total = macci::coordinator::inference::PipelineTiming::default();
    let mut agree = 0usize;
    for img in &images {
        let (logits, t) = pipeline.infer_split(img, point)?;
        let local = pipeline.infer_local(img)?;
        let am = macci::coordinator::inference::argmax;
        if am(&logits) == am(&local) {
            agree += 1;
        }
        total.front_s += t.front_s;
        total.encode_s += t.encode_s;
        total.decode_s += t.decode_s;
        total.back_s += t.back_s;
        total.wire_bits += t.wire_bits;
    }
    let n = tasks as f64;
    println!(
        "per-request: front {:.2} ms | encode {:.2} ms | wire {:.1} kbit (R={:.0}x) | decode {:.2} ms | back {:.2} ms",
        total.front_s / n * 1e3,
        total.encode_s / n * 1e3,
        total.wire_bits as f64 / n / 1e3,
        32.0 * 3.0 * (pipeline.meta.input_hw * pipeline.meta.input_hw) as f64 / (total.wire_bits as f64 / n),
        total.decode_s / n * 1e3,
        total.back_s / n * 1e3,
    );
    println!("split-vs-local top-1 agreement: {agree}/{tasks}");
    Ok(())
}

/// Decision-serving from a checkpointed policy: the edge server broadcasts
/// greedy MAHPPO decisions to simulated UEs (driven by the analytic env),
/// optionally with the online learner refining — and hot-swapping — the
/// served policy from live telemetry.
fn cmd_serve_policy(args: &Args) -> Result<()> {
    let store = open_store_at(args)?;
    let path = args.str_or("policy", "policy.ckpt");
    let frames = args.usize_or("frames", 200)?;
    let interval = Duration::from_millis(args.u64_or("interval-ms", 2)?);
    let online = args.has("online-learn");

    let cp = checkpoint::load(&path)
        .map_err(|e| anyhow::anyhow!("loading policy from {path}: {e}"))?;
    let shards = args.usize_or("shards", 1)?.max(1);
    if shards > 1 {
        return cmd_serve_policy_sharded(args, &store, &cp, shards);
    }
    let scenario = cp.scenario.clone();
    let profile = cp.profile.clone();
    let n = scenario.n_ues;
    println!(
        "serving policy {path}: N={n}, {} net params/actor, critic step {} — {frames} decision frames{}",
        cp.actors.first().map(|a| a.params.len()).unwrap_or(0),
        cp.critic.t,
        if online { ", online learning ON" } else { "" }
    );

    let decisions = DecisionMaker::new(Box::new(ActorDecision::from_trainer_checkpoint(
        &store, &cp,
    )?));
    let policy_handle = decisions.policy_handle();
    let pool = StatePool::new(
        n,
        StateNorm {
            lambda_tasks: scenario.lambda_tasks,
            frame_s: scenario.frame_s,
            max_bits: profile.max_bits(),
            d_max: scenario.d_max,
        },
    );
    let mut server_cfg = ServerConfig::new(n, interval, frames);
    server_cfg.exec.precision = Precision::parse(&args.str_or("precision", "f32"))?;
    let mut learner_handle = None;
    if online {
        // bounded feed: a learner slower than the decision rate drops
        // frames instead of growing the queue without bound
        let (tx, rx) = std::sync::mpsc::sync_channel(1024);
        server_cfg.telemetry = Some(tx);
        let lcfg = LearnerConfig {
            lr: args.f64_or("learn-lr", 1e-3)? as f32,
            ..LearnerConfig::for_store(&store, n)?
        };
        learner_handle = Some(learner::spawn(
            &store,
            &profile,
            &scenario,
            lcfg,
            Some(&cp),
            rx,
            policy_handle,
        )?);
    }
    let (server, downlinks) = EdgeServer::spawn(server_cfg, pool, decisions, None)?;

    // drive the UEs from the analytic env: report state, await the
    // broadcast, execute the decided joint action
    let mut env = MultiAgentEnv::new(profile.clone(), scenario.clone(), args.u64_or("seed", 1)?)?;
    let received = drive_env_ues(&server.uplink, &downlinks, &mut env, frames, |_, _| {})?;
    for ue in 0..n {
        let _ = server.uplink.send(Uplink::Goodbye { ue_id: ue });
    }
    let stats = server.join();
    println!(
        "served {} decision frames ({} per UE, none missed), {} policy swaps applied",
        stats.frames,
        received.iter().min().unwrap_or(&0),
        stats.policy_swaps
    );
    if let Some(h) = learner_handle {
        let ls = h.join();
        println!(
            "online learner: {} telemetry frames -> {} PPO rounds, {} policies published (last value loss {:.4})",
            ls.frames, ls.rounds, ls.publishes, ls.last_value_loss
        );
    }
    Ok(())
}

/// `serve --policy --shards K`: the sharded deployment shape of DESIGN.md
/// §Sharded-Serving, in-process. Each shard is an independent server loop
/// serving its own N-UE group from a replica of the checkpointed actors,
/// driven by its own analytic env on a named thread; one [`PolicyHandle`]
/// fanned out over every shard carries policy publishes to the whole
/// fabric, and the online learner (fed from shard 0's telemetry) refines
/// all shards at once through it.
fn cmd_serve_policy_sharded(
    args: &Args,
    store: &ArtifactStore,
    cp: &checkpoint::TrainerCheckpoint,
    shards: usize,
) -> Result<()> {
    let frames = args.usize_or("frames", 200)?;
    let interval = Duration::from_millis(args.u64_or("interval-ms", 2)?);
    let online = args.has("online-learn");
    let scenario = cp.scenario.clone();
    let profile = cp.profile.clone();
    let n = scenario.n_ues;
    let seed = args.u64_or("seed", 1)?;
    println!(
        "serving policy across {shards} shards: N={n} UEs each ({} total), {frames} decision frames{}",
        shards * n,
        if online { ", online learning ON" } else { "" }
    );

    let (mut telemetry_tx, telemetry_rx) = if online {
        // bounded feed, as in the single-shard path: a slow learner drops
        // frames instead of growing the queue without bound
        let (tx, rx) = std::sync::mpsc::sync_channel(1024);
        (Some(tx), Some(rx))
    } else {
        (None, None)
    };

    let mut servers = Vec::with_capacity(shards);
    let mut publishers = Vec::with_capacity(shards);
    let mut drivers = Vec::with_capacity(shards);
    for s in 0..shards {
        let decisions = DecisionMaker::new(Box::new(ActorDecision::from_trainer_checkpoint(
            store, cp,
        )?));
        publishers.push(decisions.policy_handle());
        let pool = StatePool::new(
            n,
            StateNorm {
                lambda_tasks: scenario.lambda_tasks,
                frame_s: scenario.frame_s,
                max_bits: profile.max_bits(),
                d_max: scenario.d_max,
            },
        );
        let mut server_cfg = ServerConfig::new(n, interval, frames);
        server_cfg.exec.precision = Precision::parse(&args.str_or("precision", "f32"))?;
        if s == 0 {
            // the learner samples one shard's telemetry; its publishes
            // still reach every shard through the fan-out handle
            server_cfg.telemetry = telemetry_tx.take();
        }
        let (server, downlinks) = EdgeServer::spawn(server_cfg, pool, decisions, None)?;

        let mut env =
            MultiAgentEnv::new(profile.clone(), scenario.clone(), seed.wrapping_add(s as u64))?;
        let uplink = server.uplink.clone();
        let driver = std::thread::Builder::new()
            .name(format!("shard-driver-{s}"))
            .spawn(move || {
                let received = drive_env_ues(&uplink, &downlinks, &mut env, frames, |_, _| {})?;
                for ue in 0..n {
                    let _ = uplink.send(Uplink::Goodbye { ue_id: ue });
                }
                Ok::<_, anyhow::Error>(received)
            })?;
        servers.push(server);
        drivers.push(driver);
    }

    let fanout = PolicyHandle::fanout(publishers);
    println!("policy fan-out live over {} shard slots", fanout.live_slots());
    let mut learner_handle = None;
    if let Some(rx) = telemetry_rx {
        let lcfg = LearnerConfig {
            lr: args.f64_or("learn-lr", 1e-3)? as f32,
            ..LearnerConfig::for_store(store, n)?
        };
        learner_handle = Some(learner::spawn(
            store, &profile, &scenario, lcfg, Some(cp), rx, fanout,
        )?);
    }

    let mut min_received = usize::MAX;
    for (s, driver) in drivers.into_iter().enumerate() {
        let received = driver
            .join()
            .map_err(|_| anyhow::anyhow!("shard {s} driver panicked"))??;
        min_received = min_received.min(*received.iter().min().unwrap_or(&0));
    }
    let (mut total_frames, mut total_swaps) = (0usize, 0usize);
    for server in servers {
        let stats = server.join();
        total_frames += stats.frames;
        total_swaps += stats.policy_swaps;
    }
    println!(
        "served {total_frames} decision frames over {shards} shards ({min_received} per UE \
         minimum, none missed), {total_swaps} policy swaps applied",
    );
    if let Some(h) = learner_handle {
        let ls = h.join();
        println!(
            "online learner: {} telemetry frames -> {} PPO rounds, {} policies published \
             to every shard (last value loss {:.4})",
            ls.frames, ls.rounds, ls.publishes, ls.last_value_loss
        );
    }
    Ok(())
}

fn cmd_info() -> Result<()> {
    let store = open_store()?;
    println!("backend: {}", store.backend_name());
    println!("artifacts ({}):", store.names().len());
    for n in store.names() {
        println!("  {n}");
    }
    if let Ok(rl) = store.rl() {
        println!(
            "rl: N in {:?}, {} partition choices, {} channels",
            rl.n_range, rl.n_partition, rl.n_channels
        );
    }
    for m in store.model_names() {
        let meta = store.model(m)?;
        println!(
            "model {m}: {}x{} input, {} classes, base acc {:.3}, {} cut points",
            meta.input_hw,
            meta.input_hw,
            meta.num_classes,
            meta.base_acc,
            meta.points.len()
        );
    }
    Ok(())
}
