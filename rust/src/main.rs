//! `macci` — the launcher CLI.
//!
//! ```text
//! macci exp <fig4..fig13|headline|all> [--quick] [--frames N] [--seeds K]
//! macci train  [--n-ues 5] [--frames 6000] [--beta 0.47] [--lr 1e-4] [--model resnet18]
//! macci eval   [--n-ues 5] [--policy local|random|edge_raw|split<k>]
//! macci serve  [--model resnet18] [--n-ues 3] [--tasks 16]
//! macci info                       # artifact + profile inventory
//! ```

use anyhow::{bail, Result};

use macci::coordinator::inference::CollabPipeline;
use macci::env::mdp::MultiAgentEnv;
use macci::env::scenario::ScenarioConfig;
use macci::exp::{self, common::ExpContext};
use macci::profiles::DeviceProfile;
use macci::rl::baselines::{evaluate_policy, BaselinePolicy, PolicyKind};
use macci::rl::mahppo::{MahppoTrainer, TrainConfig};
use macci::runtime::artifacts::ArtifactStore;
use macci::util::cli::Args;

const USAGE: &str = "\
macci — Multi-Agent Collaborative Inference (MAHPPO) coordinator

USAGE:
  macci exp <fig4|fig5|fig7|fig8|fig9|fig10|fig11|fig12|fig13|headline|all>
            [--quick] [--frames N] [--seeds K] [--lambda L] [--eval-episodes E]
  macci train [--n-ues 5] [--frames 6000] [--beta 0.47] [--lr 1e-4]
              [--model resnet18] [--seed 0] [--out results/train.json]
  macci eval  [--n-ues 5] [--policy local|random|edge_raw|split2] [--episodes 3]
  macci serve [--model resnet18] [--n-ues 3] [--tasks 16] [--point 2]
  macci info

Artifacts are read from ./artifacts (run `make artifacts` first).";

fn main() {
    env_logger_init();
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn env_logger_init() {
    // minimal logger: MACCI_LOG=debug enables debug lines on stderr
    struct L;
    impl log::Log for L {
        fn enabled(&self, m: &log::Metadata) -> bool {
            m.level() <= log::max_level()
        }
        fn log(&self, r: &log::Record) {
            if self.enabled(r.metadata()) {
                eprintln!("[{}] {}", r.level(), r.args());
            }
        }
        fn flush(&self) {}
    }
    static LOGGER: L = L;
    let level = match std::env::var("MACCI_LOG").as_deref() {
        Ok("debug") => log::LevelFilter::Debug,
        Ok("trace") => log::LevelFilter::Trace,
        _ => log::LevelFilter::Info,
    };
    let _ = log::set_logger(&LOGGER);
    log::set_max_level(level);
}

fn run() -> Result<()> {
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("");
    match cmd {
        "exp" => cmd_exp(&args),
        "train" => cmd_train(&args),
        "eval" => cmd_eval(&args),
        "serve" => cmd_serve(&args),
        "info" => cmd_info(),
        _ => {
            println!("{USAGE}");
            Ok(())
        }
    }
}

fn open_store() -> Result<ArtifactStore> {
    ArtifactStore::open("artifacts")
}

fn cmd_exp(args: &Args) -> Result<()> {
    let name = args
        .positional
        .get(1)
        .map(|s| s.as_str())
        .unwrap_or("all");
    let store = open_store()?;
    let mut ctx = ExpContext::new(store, args.has("quick"));
    ctx.frames = args.usize_or("frames", ctx.frames)?;
    ctx.seeds = args.usize_or("seeds", ctx.seeds)?;
    ctx.lambda_tasks = args.f64_or("lambda", ctx.lambda_tasks)?;
    ctx.eval_episodes = args.usize_or("eval-episodes", ctx.eval_episodes)?;
    exp::run(name, &ctx)
}

fn cmd_train(args: &Args) -> Result<()> {
    let store = open_store()?;
    let model = args.str_or("model", "resnet18");
    let profile =
        DeviceProfile::load_or_synthetic(store.root.join("profiles").join(format!("{model}.json")))?;
    let scenario = ScenarioConfig {
        n_ues: args.usize_or("n-ues", 5)?,
        beta: args.f64_or("beta", 0.47)?,
        lambda_tasks: args.f64_or("lambda", 200.0)?,
        ..Default::default()
    };
    let cfg = TrainConfig {
        lr: args.f64_or("lr", 1e-4)? as f32,
        buffer_size: args.usize_or("buffer", 1024)?,
        minibatch: args.usize_or("batch", 256)?,
        reuse: args.usize_or("reuse", 10)?,
        seed: args.u64_or("seed", 0)?,
        n_envs: args.usize_or("n-envs", 1)?,
        ..Default::default()
    };
    let frames = args.usize_or("frames", 6000)?;
    println!(
        "training MAHPPO: model={model} N={} frames={frames} beta={} lr={}",
        scenario.n_ues, scenario.beta, cfg.lr
    );
    let mut trainer = MahppoTrainer::new(&store, &profile, scenario, cfg)?;
    let report = trainer.train(frames)?;
    println!(
        "done: {} episodes, final reward {:.2}, {:.1}s wall",
        report.episodes,
        report.final_reward(),
        report.wall_s
    );
    let out = args.str_or("out", "results/train.json");
    let r = report.into_report("training run");
    let slug = std::path::Path::new(&out)
        .file_stem()
        .and_then(|s| s.to_str())
        .unwrap_or("train")
        .to_string();
    let dir = std::path::Path::new(&out)
        .parent()
        .map(|p| p.display().to_string())
        .unwrap_or_else(|| "results".into());
    r.write(dir, &slug)?;
    println!("wrote {out}");

    // post-training greedy evaluation (fresh eval-seeded env)
    let stats = trainer.evaluate(args.usize_or("episodes", 2)?)?;
    println!(
        "greedy eval: avg latency {:.1} ms, avg energy {:.1} mJ, reward {:.2}",
        stats.avg_latency * 1e3,
        stats.avg_energy * 1e3,
        stats.avg_reward
    );
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let store = open_store()?;
    let model = args.str_or("model", "resnet18");
    let profile =
        DeviceProfile::load_or_synthetic(store.root.join("profiles").join(format!("{model}.json")))?;
    let scenario = ScenarioConfig {
        n_ues: args.usize_or("n-ues", 5)?,
        eval_mode: true,
        lambda_tasks: args.f64_or("lambda", 200.0)?,
        eval_tasks: args.u64_or("tasks", 200)?,
        ..Default::default()
    };
    let policy_name = args.str_or("policy", "local");
    let kind = match policy_name.as_str() {
        "local" => PolicyKind::Local,
        "random" => PolicyKind::Random,
        "edge_raw" => PolicyKind::EdgeRaw,
        s if s.starts_with("split") => PolicyKind::FixedSplit(s[5..].parse().unwrap_or(2)),
        other => bail!("unknown policy '{other}'"),
    };
    let mut env = MultiAgentEnv::new(profile, scenario, args.u64_or("seed", 0)?)?;
    let mut policy = BaselinePolicy::new(kind, 1);
    let stats = evaluate_policy(&mut policy, &mut env, args.usize_or("episodes", 3)?)?;
    println!(
        "{policy_name}: avg latency {:.1} ms, avg energy {:.1} mJ, reward {:.2} ({} episodes)",
        stats.avg_latency * 1e3,
        stats.avg_energy * 1e3,
        stats.avg_reward,
        stats.episodes
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    // small in-process serving demo; the full threaded pipeline lives in
    // examples/collab_serving.rs
    let store = open_store()?;
    let model = args.str_or("model", "resnet18");
    let pipeline = CollabPipeline::load(&store, &model)?;
    let point = args.usize_or("point", 2)?;
    let tasks = args.usize_or("tasks", 8)?;
    let images = macci::exp::fig4::smooth_images(tasks, pipeline.meta.input_hw, 3);
    println!("serving {tasks} requests through {model} split at p{point}");
    let mut total = macci::coordinator::inference::PipelineTiming::default();
    let mut agree = 0usize;
    for img in &images {
        let (logits, t) = pipeline.infer_split(img, point)?;
        let local = pipeline.infer_local(img)?;
        let am = macci::coordinator::inference::argmax;
        if am(&logits) == am(&local) {
            agree += 1;
        }
        total.front_s += t.front_s;
        total.encode_s += t.encode_s;
        total.decode_s += t.decode_s;
        total.back_s += t.back_s;
        total.wire_bits += t.wire_bits;
    }
    let n = tasks as f64;
    println!(
        "per-request: front {:.2} ms | encode {:.2} ms | wire {:.1} kbit (R={:.0}x) | decode {:.2} ms | back {:.2} ms",
        total.front_s / n * 1e3,
        total.encode_s / n * 1e3,
        total.wire_bits as f64 / n / 1e3,
        32.0 * 3.0 * (pipeline.meta.input_hw * pipeline.meta.input_hw) as f64 / (total.wire_bits as f64 / n),
        total.decode_s / n * 1e3,
        total.back_s / n * 1e3,
    );
    println!("split-vs-local top-1 agreement: {agree}/{tasks}");
    Ok(())
}

fn cmd_info() -> Result<()> {
    let store = open_store()?;
    println!("backend: {}", store.backend_name());
    println!("artifacts ({}):", store.names().len());
    for n in store.names() {
        println!("  {n}");
    }
    if let Ok(rl) = store.rl() {
        println!(
            "rl: N in {:?}, {} partition choices, {} channels",
            rl.n_range, rl.n_partition, rl.n_channels
        );
    }
    for m in store.model_names() {
        let meta = store.model(m)?;
        println!(
            "model {m}: {}x{} input, {} classes, base acc {:.3}, {} cut points",
            meta.input_hw,
            meta.input_hw,
            meta.num_classes,
            meta.base_acc,
            meta.points.len()
        );
    }
    Ok(())
}
