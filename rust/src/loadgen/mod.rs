//! Trace-driven massive-fleet load generation (DESIGN.md
//! §Sharded-Serving, "load harness").
//!
//! Drives a sharded serving fabric (reactor + per-shard server loops)
//! with thousands of simulated UEs over loopback, multiplexed onto a
//! handful of station connections:
//!
//! * [`hist`] — a log-bucketed latency histogram (p50/p99/p999 without
//!   storing samples).
//! * [`station`] — one connection speaking for a contiguous UE slice:
//!   open/closed-loop reports, periodic raw offloads, reconnect churn.
//! * [`run_fleet`] — partitions the fleet across stations (reusing
//!   [`ShardMap`]'s contiguous slicing), runs them on named threads and
//!   merges their stats into a [`FleetStats`].
//!
//! The `bench_load` bench and `integration_load` tests are thin wrappers
//! over [`run_fleet`] against a live reactor.

pub mod hist;
pub mod station;

use std::net::SocketAddr;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

pub use hist::LatencyHist;
pub use station::{run_station, StationConfig, StationStats};

use crate::coordinator::shard::ShardMap;

/// How a station paces its reports.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArrivalMode {
    /// Fixed per-UE report cadence, regardless of decisions received.
    Open,
    /// A UE re-reports when its decision arrives (stall-timeout backed).
    Closed,
}

/// Fleet-wide load shape.
#[derive(Debug, Clone, Copy)]
pub struct FleetConfig {
    pub addr: SocketAddr,
    /// Total simulated UEs (global ids `0..n_ues`).
    pub n_ues: usize,
    /// Station connections the fleet is multiplexed onto.
    pub n_stations: usize,
    pub mode: ArrivalMode,
    pub duration: Duration,
    pub report_interval: Duration,
    /// Raw offload with every k-th report per UE (0 = never).
    pub offload_every: usize,
    /// Reconnect period for the churning stations.
    pub churn_period: Option<Duration>,
    /// How many stations (from index 0) churn; the rest hold their
    /// connection for the whole run.
    pub churn_stations: usize,
}

/// Merged view over every station (latencies in µs inside the
/// histogram; the accessors convert to ms).
#[derive(Debug, Clone, Default)]
pub struct FleetStats {
    pub reports_sent: usize,
    pub offloads_sent: usize,
    pub decisions_received: usize,
    pub decisions_after_reconnect: usize,
    pub results_received: usize,
    pub errors_received: usize,
    pub reconnects: usize,
    pub latency: LatencyHist,
    /// Decisions per global ue id.
    pub per_ue_decisions: Vec<usize>,
    pub elapsed: Duration,
}

impl FleetStats {
    fn absorb(&mut self, lo: usize, st: &StationStats) {
        self.reports_sent += st.reports_sent;
        self.offloads_sent += st.offloads_sent;
        self.decisions_received += st.decisions_received;
        self.decisions_after_reconnect += st.decisions_after_reconnect;
        self.results_received += st.results_received;
        self.errors_received += st.errors_received;
        self.reconnects += st.reconnects;
        self.latency.merge(&st.latency);
        for (dst, &src) in self
            .per_ue_decisions
            .iter_mut()
            .skip(lo)
            .zip(st.per_ue_decisions.iter())
        {
            *dst += src;
        }
    }

    pub fn p50_ms(&self) -> f64 {
        self.latency.percentile(0.50) as f64 / 1000.0
    }

    pub fn p99_ms(&self) -> f64 {
        self.latency.percentile(0.99) as f64 / 1000.0
    }

    pub fn p999_ms(&self) -> f64 {
        self.latency.percentile(0.999) as f64 / 1000.0
    }

    pub fn decisions_per_s(&self) -> f64 {
        self.decisions_received as f64 / self.elapsed.as_secs_f64().max(1e-9)
    }

    /// Offloads *served* per second (results that came back, not
    /// requests sent).
    pub fn offloads_per_s(&self) -> f64 {
        self.results_received as f64 / self.elapsed.as_secs_f64().max(1e-9)
    }
}

/// Partition `0..n_ues` into `n_stations` contiguous slices, drive each
/// from its own named thread, and merge the results. Errors if any
/// station could not reach the server within the run budget.
pub fn run_fleet(cfg: &FleetConfig) -> Result<FleetStats> {
    anyhow::ensure!(cfg.n_ues > 0, "a fleet needs at least one UE");
    anyhow::ensure!(cfg.n_stations > 0, "a fleet needs at least one station");
    let map = ShardMap::new(cfg.n_ues, cfg.n_stations);
    let t0 = Instant::now();
    let mut joins = Vec::with_capacity(map.n_shards());
    for s in 0..map.n_shards() {
        let Some((lo, len)) = map.slice_of(s) else {
            continue;
        };
        if len == 0 {
            continue; // more stations than UEs
        }
        let scfg = StationConfig {
            addr: cfg.addr,
            lo,
            n_ues: len,
            mode: cfg.mode,
            duration: cfg.duration,
            report_interval: cfg.report_interval,
            offload_every: cfg.offload_every,
            churn_period: if s < cfg.churn_stations {
                cfg.churn_period
            } else {
                None
            },
        };
        let handle = std::thread::Builder::new()
            .name(format!("loadgen-station-{s}"))
            .spawn(move || run_station(&scfg))
            .with_context(|| format!("spawning station {s}"))?;
        joins.push((lo, handle));
    }
    let mut fleet = FleetStats {
        per_ue_decisions: vec![0; cfg.n_ues],
        ..FleetStats::default()
    };
    for (lo, handle) in joins {
        let st = handle
            .join()
            .map_err(|_| anyhow!("station at ue offset {lo} panicked"))?
            .with_context(|| format!("station at ue offset {lo}"))?;
        fleet.absorb(lo, &st);
    }
    fleet.elapsed = t0.elapsed();
    Ok(fleet)
}
