//! A small log-bucketed latency histogram (HDR-style, base-2).
//!
//! Exact counts below 16 µs, then 16 sub-buckets per power of two —
//! relative quantile error is bounded by ~1/16 (6.25%) at any magnitude,
//! with a fixed 976-bucket footprint and O(1) recording. Good enough for
//! p50/p99/p999 over millions of decision-latency samples without
//! storing them.

/// Exact buckets `0..16`, then 16 sub-buckets for each exponent 4..=63.
const N_BUCKETS: usize = 16 + 60 * 16;

/// Microsecond latency histogram; merge-able across threads.
#[derive(Debug, Clone)]
pub struct LatencyHist {
    buckets: Vec<u64>,
    count: u64,
}

impl Default for LatencyHist {
    fn default() -> LatencyHist {
        LatencyHist::new()
    }
}

impl LatencyHist {
    pub fn new() -> LatencyHist {
        LatencyHist {
            buckets: vec![0; N_BUCKETS],
            count: 0,
        }
    }

    /// Bucket index for a microsecond value.
    fn index(us: u64) -> usize {
        if us < 16 {
            return us as usize;
        }
        // us >= 16 so the leading exponent is at least 4
        let exp = 63 - us.leading_zeros() as u64;
        let sub = (us >> (exp - 4)) - 16; // 0..16
        (16 + (exp - 4) * 16 + sub) as usize
    }

    /// Representative (midpoint) microsecond value of a bucket.
    fn value_of(idx: usize) -> u64 {
        if idx < 16 {
            return idx as u64;
        }
        let g = (idx - 16) / 16; // exponent - 4
        let sub = ((idx - 16) % 16) as u64;
        let lo = (16 + sub) << g;
        lo + (1u64 << g) / 2
    }

    /// Record one latency sample, in microseconds.
    pub fn record(&mut self, us: u64) {
        let idx = Self::index(us).min(N_BUCKETS - 1);
        if let Some(b) = self.buckets.get_mut(idx) {
            *b += 1;
            self.count += 1;
        }
    }

    /// Samples recorded so far.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Fold another histogram into this one.
    pub fn merge(&mut self, other: &LatencyHist) {
        for (a, b) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *a += *b;
        }
        self.count += other.count;
    }

    /// The `p`-quantile (`0.0..=1.0`) in microseconds — the midpoint of
    /// the bucket holding the `ceil(p · count)`-th sample. 0 when empty.
    pub fn percentile(&self, p: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = ((p.clamp(0.0, 1.0) * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut cum = 0u64;
        for (idx, &b) in self.buckets.iter().enumerate() {
            cum += b;
            if cum >= target {
                return Self::value_of(idx);
            }
        }
        Self::value_of(N_BUCKETS - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_values_are_exact() {
        let mut h = LatencyHist::new();
        for us in 0..16u64 {
            h.record(us);
        }
        assert_eq!(h.count(), 16);
        assert_eq!(h.percentile(0.0), 0);
        // the 8th sample (ceil(0.5 * 16)) is value 7
        assert_eq!(h.percentile(0.5), 7);
        assert_eq!(h.percentile(1.0), 15);
    }

    #[test]
    fn quantile_error_is_bounded() {
        let mut h = LatencyHist::new();
        for &us in &[100u64, 1_000, 10_000, 250_000, 3_000_000] {
            for _ in 0..1000 {
                h.record(us);
            }
        }
        // each recorded magnitude must come back within the 1/16 bound
        for (p, want) in [(0.1, 100u64), (0.3, 1_000), (0.5, 10_000), (0.7, 250_000), (0.99, 3_000_000)] {
            let got = h.percentile(p);
            let err = (got as f64 - want as f64).abs() / want as f64;
            assert!(err <= 1.0 / 16.0 + 1e-9, "p{p}: got {got}, want ~{want}");
        }
    }

    #[test]
    fn merge_is_additive() {
        let mut a = LatencyHist::new();
        let mut b = LatencyHist::new();
        for _ in 0..10 {
            a.record(50);
            b.record(5_000);
        }
        a.merge(&b);
        assert_eq!(a.count(), 20);
        let p25 = a.percentile(0.25);
        let p75 = a.percentile(0.75);
        assert!(p25 <= 53, "low half stays low: {p25}");
        assert!((4_700..=5_400).contains(&p75), "high half stays high: {p75}");
    }

    #[test]
    fn huge_values_do_not_panic() {
        let mut h = LatencyHist::new();
        h.record(u64::MAX);
        h.record(0);
        assert_eq!(h.count(), 2);
        assert!(h.percentile(1.0) > 1u64 << 50);
    }
}
