//! One load-generator station: a single multiplexed TCP connection
//! speaking for a contiguous slice of UEs.
//!
//! Real deployments multiplex many UEs behind one base-station uplink;
//! the harness mirrors that so a 10k-UE fleet needs tens of sockets,
//! not ten thousand. The station drives its slice against a
//! [`crate::transport::reactor::TcpReactor`] endpoint: `Hello` burst for
//! the slice, then open- or closed-loop state reports with periodic raw
//! offloads, attributing downlinks via the
//! [`Frame::DownTo`] envelope and measuring report→decision latency per
//! UE. Optional churn tears the socket down mid-run and re-registers the
//! slice (session takeover on the reactor), modelling UE fleets that
//! come and go.

use std::io::Read;
use std::net::{Shutdown, SocketAddr, TcpStream};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::hist::LatencyHist;
use super::ArrivalMode;
use crate::coordinator::protocol::{Downlink, OffloadRequest, UeStateReport, Uplink};
use crate::coordinator::wire::{decode_frame, write_frame, Frame, WireError};

/// Raw-offload payload bytes: 16 f32 image elements, matching
/// `SyntheticCompute`'s expected input shape.
const OFFLOAD_PAYLOAD: usize = 4 * 16;
/// Blocking-read slice: also the station's send-loop pacing quantum.
const READ_TIMEOUT: Duration = Duration::from_millis(1);

/// One station's slice and behavior.
#[derive(Debug, Clone, Copy)]
pub struct StationConfig {
    pub addr: SocketAddr,
    /// First global ue id of the slice.
    pub lo: usize,
    /// Slice length (UEs driven by this station).
    pub n_ues: usize,
    pub mode: ArrivalMode,
    /// Wall-clock run budget.
    pub duration: Duration,
    /// Open-loop report cadence per UE; in closed-loop mode its 8×
    /// multiple is the stall timeout that re-reports an unanswered UE.
    pub report_interval: Duration,
    /// Send a raw offload with every k-th report of a UE (0 = never).
    pub offload_every: usize,
    /// Tear the connection down and re-register the slice this often.
    pub churn_period: Option<Duration>,
}

/// What one station saw (latencies in the embedded histogram, µs).
#[derive(Debug, Clone, Default)]
pub struct StationStats {
    pub reports_sent: usize,
    pub offloads_sent: usize,
    pub decisions_received: usize,
    /// Decisions received on a session after at least one reconnect —
    /// nonzero proves the fleet kept being served through churn.
    pub decisions_after_reconnect: usize,
    pub results_received: usize,
    pub errors_received: usize,
    pub reconnects: usize,
    pub latency: LatencyHist,
    /// Decisions per slice-local UE (index `i` = global `lo + i`).
    pub per_ue_decisions: Vec<usize>,
}

/// Connect and register the whole slice, retrying until `deadline`.
fn open_session(cfg: &StationConfig, deadline: Instant) -> Result<TcpStream> {
    loop {
        let attempt = (|| -> Result<TcpStream> {
            let mut stream =
                TcpStream::connect(cfg.addr).context("connecting to the reactor")?;
            let _ = stream.set_nodelay(true);
            stream
                .set_read_timeout(Some(READ_TIMEOUT))
                .context("setting the read timeout")?;
            for i in 0..cfg.n_ues {
                write_frame(&mut stream, &Frame::Hello { ue_id: cfg.lo + i })
                    .map_err(|e| anyhow::anyhow!("hello for UE {}: {e}", cfg.lo + i))?;
            }
            Ok(stream)
        })();
        match attempt {
            Ok(s) => return Ok(s),
            Err(e) if Instant::now() < deadline => {
                log::debug!("station at {}: reconnect pending: {e:#}", cfg.lo);
                std::thread::sleep(Duration::from_millis(10));
            }
            Err(e) => return Err(e),
        }
    }
}

/// Drive the slice until the duration elapses. Errors only when the
/// server is unreachable within the run budget — everything else
/// (drops, NACKs, churn) is counted, not fatal.
pub fn run_station(cfg: &StationConfig) -> Result<StationStats> {
    let deadline = Instant::now() + cfg.duration;
    let stall = cfg.report_interval * 8;
    let mut stats = StationStats {
        per_ue_decisions: vec![0; cfg.n_ues],
        ..StationStats::default()
    };
    let mut stream = open_session(cfg, deadline)?;
    let mut session_start = Instant::now();
    let mut reconnected = false;

    let mut rbuf: Vec<u8> = Vec::new();
    let mut scratch = [0u8; 65536];
    // per-UE (slice-local) send state
    let start = Instant::now();
    let mut next_report_at: Vec<Instant> = (0..cfg.n_ues)
        .map(|i| {
            // stagger first reports across one interval so a big slice
            // does not burst the uplink every period
            let offset = cfg.report_interval.as_micros() as u64 * i as u64 / cfg.n_ues.max(1) as u64;
            start + Duration::from_micros(offset)
        })
        .collect();
    let mut awaiting: Vec<Option<Instant>> = vec![None; cfg.n_ues];
    let mut pending: Vec<bool> = vec![true; cfg.n_ues];
    let mut sent_count: Vec<u64> = vec![0; cfg.n_ues];
    // station-unique task ids (disjoint ranges per slice offset)
    let mut task_ctr: u64 = (cfg.lo as u64) << 32;

    while Instant::now() < deadline {
        let mut need_reconnect = false;

        // -- send due reports (and their piggybacked offloads) --
        let now = Instant::now();
        for i in 0..cfg.n_ues {
            let due = match cfg.mode {
                ArrivalMode::Open => next_report_at.get(i).map_or(false, |&t| now >= t),
                ArrivalMode::Closed => {
                    pending.get(i).copied().unwrap_or(false)
                        || awaiting
                            .get(i)
                            .and_then(|o| *o)
                            .map_or(false, |t| now.duration_since(t) > stall)
                }
            };
            if !due {
                continue;
            }
            let gid = cfg.lo + i;
            let report = UeStateReport {
                ue_id: gid,
                tasks_left: 4,
                compute_left_s: 0.05,
                offload_left_bits: 1e5,
                distance_m: 40.0,
            };
            if write_frame(&mut stream, &Frame::Up(Uplink::Report(report))).is_err() {
                need_reconnect = true;
                break;
            }
            stats.reports_sent += 1;
            if let Some(t) = next_report_at.get_mut(i) {
                *t = now + cfg.report_interval;
            }
            if let Some(slot) = awaiting.get_mut(i) {
                *slot = Some(now);
            }
            if let Some(p) = pending.get_mut(i) {
                *p = false;
            }
            let count = sent_count.get_mut(i).map(|c| {
                *c += 1;
                *c
            });
            let offload_due =
                cfg.offload_every > 0 && count.map_or(false, |c| c % cfg.offload_every as u64 == 0);
            if offload_due {
                task_ctr += 1;
                let offload = OffloadRequest {
                    ue_id: gid,
                    task_id: task_ctr,
                    b: 0,
                    payload: vec![1u8; OFFLOAD_PAYLOAD],
                    calibration: None,
                };
                if write_frame(&mut stream, &Frame::Up(Uplink::Offload(offload))).is_err() {
                    need_reconnect = true;
                    break;
                }
                stats.offloads_sent += 1;
            }
        }

        // -- read one slice of downlink bytes, decode all full frames --
        if !need_reconnect {
            match stream.read(&mut scratch) {
                Ok(0) => need_reconnect = true, // server closed the socket
                Ok(n) => {
                    if let Some(got) = scratch.get(..n) {
                        rbuf.extend_from_slice(got);
                    }
                    loop {
                        match decode_frame(&rbuf) {
                            Ok((frame, used)) => {
                                rbuf.drain(..used);
                                let now = Instant::now();
                                match frame {
                                    Frame::DownTo { ue_id, down } => {
                                        let Some(local) = ue_id
                                            .checked_sub(cfg.lo)
                                            .filter(|&l| l < cfg.n_ues)
                                        else {
                                            continue; // not ours; misrouted
                                        };
                                        match down {
                                            Downlink::Decision(_) => {
                                                stats.decisions_received += 1;
                                                if reconnected {
                                                    stats.decisions_after_reconnect += 1;
                                                }
                                                if let Some(d) =
                                                    stats.per_ue_decisions.get_mut(local)
                                                {
                                                    *d += 1;
                                                }
                                                if let Some(slot) = awaiting.get_mut(local) {
                                                    if let Some(t0) = slot.take() {
                                                        stats.latency.record(
                                                            now.duration_since(t0).as_micros()
                                                                as u64,
                                                        );
                                                    }
                                                }
                                                if let Some(p) = pending.get_mut(local) {
                                                    *p = true;
                                                }
                                            }
                                            Downlink::Result(_) => stats.results_received += 1,
                                            Downlink::Error { .. } => stats.errors_received += 1,
                                            Downlink::Shutdown => {}
                                        }
                                    }
                                    Frame::Welcome { .. } => {}
                                    Frame::Down(Downlink::Error { .. }) => {
                                        stats.errors_received += 1;
                                    }
                                    other => {
                                        log::debug!("station: unexpected {other:?}; dropped");
                                    }
                                }
                            }
                            Err(WireError::Truncated { .. }) => break,
                            Err(WireError::UnknownTag { skip, .. }) => {
                                rbuf.drain(..skip.min(rbuf.len()));
                            }
                            Err(e) => {
                                log::warn!("station at {}: poisoned downlink: {e}", cfg.lo);
                                rbuf.clear();
                                need_reconnect = true;
                                break;
                            }
                        }
                    }
                }
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock
                        || e.kind() == std::io::ErrorKind::TimedOut => {}
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(_) => need_reconnect = true,
            }
        }

        // -- scheduled churn --
        if !need_reconnect {
            if let Some(period) = cfg.churn_period {
                if session_start.elapsed() >= period && Instant::now() < deadline {
                    log::debug!("station at {}: scheduled churn", cfg.lo);
                    need_reconnect = true;
                }
            }
        }

        if need_reconnect {
            let _ = stream.shutdown(Shutdown::Both);
            match open_session(cfg, deadline) {
                Ok(s) => {
                    stream = s;
                    rbuf.clear();
                    session_start = Instant::now();
                    stats.reconnects += 1;
                    reconnected = true;
                    for slot in awaiting.iter_mut() {
                        *slot = None;
                    }
                    for p in pending.iter_mut() {
                        *p = true;
                    }
                }
                // the run budget expired while reconnecting: wrap up
                Err(_) => break,
            }
        }
    }

    // polite leave so the shards see the slice go away
    for i in 0..cfg.n_ues {
        let _ = write_frame(&mut stream, &Frame::Up(Uplink::Goodbye { ue_id: cfg.lo + i }));
    }
    let _ = stream.shutdown(Shutdown::Both);
    Ok(stats)
}
