//! Canonical Huffman coder over bytes — the entropy-coding stage of the
//! JALAD baseline (Li et al., ICPADS'18 use 8-bit quantization + Huffman).
//!
//! Full implementation: frequency histogram → package-merge-free heap build
//! → canonical code assignment (lengths capped by construction at < 64) →
//! bit-packed stream with an embedded code-length table so the decoder is
//! self-contained. Used both to *measure* real compression rates on real
//! intermediate features (Fig. 4) and on the serving path of the JALAD
//! comparison pipeline.

use anyhow::{bail, Result};

/// Compressed container: code-length table + payload.
#[derive(Debug, Clone)]
pub struct HuffmanBlock {
    /// Code length per symbol (0 = unused), canonical order.
    pub lengths: [u8; 256],
    pub n_symbols: usize,
    pub payload: Vec<u8>,
    pub bit_len: usize,
}

impl HuffmanBlock {
    /// Wire size in bits: table (256 x 6 bits) + payload.
    pub fn wire_bits(&self) -> usize {
        256 * 6 + self.bit_len
    }
}

/// Encoder/decoder for byte streams.
#[derive(Debug, Default, Clone, Copy)]
pub struct HuffmanCoder;

impl HuffmanCoder {
    pub fn new() -> HuffmanCoder {
        HuffmanCoder
    }

    /// Build canonical code lengths from a frequency histogram.
    fn code_lengths(freq: &[u64; 256]) -> [u8; 256] {
        // heap of (weight, node-id); internal nodes appended past 256
        #[derive(PartialEq, Eq, PartialOrd, Ord)]
        struct Item(u64, usize);
        let mut heap = std::collections::BinaryHeap::new();
        let mut parent = vec![usize::MAX; 512];
        let mut next_id = 256usize;
        let mut active = 0;
        for (s, &f) in freq.iter().enumerate() {
            if f > 0 {
                heap.push(std::cmp::Reverse(Item(f, s)));
                active += 1;
            }
        }
        let mut lengths = [0u8; 256];
        match active {
            0 => return lengths,
            1 => {
                // single-symbol stream: 1-bit code
                let s = freq.iter().position(|&f| f > 0).unwrap();
                lengths[s] = 1;
                return lengths;
            }
            _ => {}
        }
        while heap.len() > 1 {
            let std::cmp::Reverse(Item(w1, a)) = heap.pop().unwrap();
            let std::cmp::Reverse(Item(w2, b)) = heap.pop().unwrap();
            let id = next_id;
            next_id += 1;
            parent[a] = id;
            parent[b] = id;
            heap.push(std::cmp::Reverse(Item(w1 + w2, id)));
        }
        for s in 0..256 {
            if freq[s] == 0 {
                continue;
            }
            let mut d = 0u8;
            let mut n = s;
            while parent[n] != usize::MAX {
                n = parent[n];
                d += 1;
            }
            lengths[s] = d.max(1);
        }
        lengths
    }

    /// Assign canonical codes from lengths (shorter codes first, then by
    /// symbol value).
    fn canonical_codes(lengths: &[u8; 256]) -> [u32; 256] {
        let mut symbols: Vec<usize> = (0..256).filter(|&s| lengths[s] > 0).collect();
        symbols.sort_by_key(|&s| (lengths[s], s));
        let mut codes = [0u32; 256];
        let mut code = 0u32;
        let mut prev_len = 0u8;
        for &s in &symbols {
            code <<= lengths[s] - prev_len;
            codes[s] = code;
            code += 1;
            prev_len = lengths[s];
        }
        codes
    }

    pub fn encode(&self, data: &[u8]) -> HuffmanBlock {
        let mut freq = [0u64; 256];
        for &b in data {
            freq[b as usize] += 1;
        }
        let lengths = Self::code_lengths(&freq);
        let codes = Self::canonical_codes(&lengths);

        let mut payload = Vec::with_capacity(data.len() / 2 + 8);
        let mut acc = 0u64;
        let mut nbits = 0u32;
        let mut bit_len = 0usize;
        for &b in data {
            let s = b as usize;
            let len = lengths[s] as u32;
            // canonical codes are MSB-first
            acc = (acc << len) | codes[s] as u64;
            nbits += len;
            bit_len += len as usize;
            while nbits >= 8 {
                nbits -= 8;
                payload.push(((acc >> nbits) & 0xff) as u8);
            }
        }
        if nbits > 0 {
            payload.push(((acc << (8 - nbits)) & 0xff) as u8);
        }
        HuffmanBlock {
            lengths,
            n_symbols: data.len(),
            payload,
            bit_len,
        }
    }

    pub fn decode(&self, block: &HuffmanBlock) -> Result<Vec<u8>> {
        // rebuild canonical codebook, then walk bits with a (len, code)
        // search table sorted by length
        let codes = Self::canonical_codes(&block.lengths);
        let mut by_len: Vec<Vec<(u32, u8)>> = vec![Vec::new(); 65];
        for s in 0..256 {
            let l = block.lengths[s];
            if l > 0 {
                by_len[l as usize].push((codes[s], s as u8));
            }
        }
        for v in by_len.iter_mut() {
            v.sort();
        }

        let mut out = Vec::with_capacity(block.n_symbols);
        let mut bitpos = 0usize;
        let read_bit = |pos: usize| -> Result<u32> {
            let byte = block
                .payload
                .get(pos / 8)
                .ok_or_else(|| anyhow::anyhow!("truncated huffman payload"))?;
            Ok(((byte >> (7 - pos % 8)) & 1) as u32)
        };
        while out.len() < block.n_symbols {
            let mut code = 0u32;
            let mut len = 0usize;
            loop {
                code = (code << 1) | read_bit(bitpos)?;
                bitpos += 1;
                len += 1;
                if len > 64 {
                    bail!("huffman code longer than 64 bits — corrupt block");
                }
                if let Ok(i) = by_len[len].binary_search_by_key(&code, |&(c, _)| c) {
                    out.push(by_len[len][i].1);
                    break;
                }
            }
        }
        Ok(out)
    }

    /// Compression ratio achieved on `data` (original bits / wire bits).
    pub fn ratio(&self, data: &[u8]) -> f64 {
        if data.is_empty() {
            return 1.0;
        }
        let block = self.encode(data);
        (data.len() * 8) as f64 / block.wire_bits() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::forall;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_random_data() {
        forall(
            41,
            100,
            |g| {
                let n = g.usize_in(0, 200);
                (0..n).map(|_| (g.rng.next_u64() & 0xff) as u8).collect::<Vec<u8>>()
            },
            |data| {
                let c = HuffmanCoder::new();
                let block = c.encode(data);
                let back = c.decode(&block).map_err(|e| e.to_string())?;
                if &back != data {
                    return Err(format!("roundtrip mismatch at len {}", data.len()));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn skewed_data_compresses_well() {
        let mut rng = Rng::new(2);
        // geometric-ish distribution like quantized sparse features
        let data: Vec<u8> = (0..50_000)
            .map(|_| {
                let u = rng.f64();
                if u < 0.7 {
                    0
                } else if u < 0.9 {
                    1 + (rng.next_u64() % 4) as u8
                } else {
                    (rng.next_u64() % 256) as u8
                }
            })
            .collect();
        let c = HuffmanCoder::new();
        let r = c.ratio(&data);
        assert!(r > 2.0, "expected >2x on skewed data, got {r:.2}");
        let block = c.encode(&data);
        assert_eq!(c.decode(&block).unwrap(), data);
    }

    #[test]
    fn uniform_data_near_1x() {
        let mut rng = Rng::new(3);
        let data: Vec<u8> = (0..50_000).map(|_| (rng.next_u64() & 0xff) as u8).collect();
        let r = HuffmanCoder::new().ratio(&data);
        assert!(r > 0.9 && r < 1.05, "uniform bytes should not compress: {r:.3}");
    }

    #[test]
    fn single_symbol_stream() {
        let data = vec![42u8; 1000];
        let c = HuffmanCoder::new();
        let block = c.encode(&data);
        assert_eq!(c.decode(&block).unwrap(), data);
        // 1-bit codes + fixed 192-byte table: 8000 bits -> ~2536 bits
        assert!(c.ratio(&data) > 3.0);
    }

    #[test]
    fn empty_stream() {
        let c = HuffmanCoder::new();
        let block = c.encode(&[]);
        assert_eq!(c.decode(&block).unwrap(), Vec::<u8>::new());
    }
}
