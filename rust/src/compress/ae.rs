//! Autoencoder compressor handle — drives the `ae_enc`/`ae_dec` artifacts
//! (Pallas conv1x1 + quant kernels, or their native Rust ports) on the
//! serving path.
//!
//! The UE-side `encode` produces integer codes + per-tensor (lo, hi); the
//! wire payload is the bit-packed codes (compress/quant.rs) plus the two
//! calibration floats. The edge-side `decode` restores the feature for the
//! back-segment of the split backbone.

use std::sync::Arc;

use anyhow::{anyhow, Result};

use super::quant::Quantizer;
use crate::runtime::artifacts::{ArtifactStore, PointMeta};
use crate::runtime::backend::Executable;
use crate::runtime::tensor::TensorView;

/// A compressed intermediate feature ready for the uplink.
#[derive(Debug, Clone)]
pub struct EncodedFeature {
    /// Quantized codes (f32 storage of integers, straight from the kernel).
    pub codes: Vec<f32>,
    pub shape: Vec<usize>,
    pub lo: f32,
    pub hi: f32,
    pub bits: u32,
}

impl EncodedFeature {
    /// Wire size in bits: packed codes + calibration floats.
    pub fn wire_bits(&self) -> usize {
        self.codes.len() * self.bits as usize + 64
    }

    /// Bit-pack into the uplink byte payload.
    pub fn to_wire(&self) -> Result<Vec<u8>> {
        let q = Quantizer::new(self.bits)?;
        let ints: Vec<u16> = self.codes.iter().map(|&c| c as u16).collect();
        Ok(q.pack(&ints))
    }

    /// Rebuild the f32 code tensor from a wire payload.
    pub fn from_wire(
        bytes: &[u8],
        shape: Vec<usize>,
        lo: f32,
        hi: f32,
        bits: u32,
    ) -> Result<EncodedFeature> {
        let n: usize = shape.iter().product();
        let q = Quantizer::new(bits)?;
        let ints = q.unpack(bytes, n)?;
        Ok(EncodedFeature {
            codes: ints.iter().map(|&c| c as f32).collect(),
            shape,
            lo,
            hi,
            bits,
        })
    }
}

/// The (model, partition-point) AE compressor: encode on the "UE", decode
/// on the "edge" — both as backend executables.
pub struct AeCompressor {
    pub meta: PointMeta,
    enc: Arc<dyn Executable>,
    dec: Arc<dyn Executable>,
    /// AE weight vector, pre-wrapped as a backend input (loop-invariant).
    weights: TensorView,
}

impl AeCompressor {
    pub fn load(store: &ArtifactStore, model: &str, point: usize) -> Result<AeCompressor> {
        let m = store.model(model)?;
        let meta = m
            .points
            .iter()
            .find(|p| p.point == point)
            .ok_or_else(|| anyhow!("model '{model}' has no partition point {point}"))?
            .clone();
        let weights = store.ae_weights(model, point)?;
        let weights = TensorView::f32(weights, vec![meta.ae_weights_size])?;
        Ok(AeCompressor {
            enc: store.load(&format!("{model}_ae_enc_p{point}"))?,
            dec: store.load(&format!("{model}_ae_dec_p{point}"))?,
            weights,
            meta,
        })
    }

    /// Compression rate R = ch·32 / (ch'·bits) (Eq. 3).
    pub fn rate(&self) -> f64 {
        self.meta.rate
    }

    /// UE side: feature (1, ch, h, w) -> codes (1, ch', h, w) + lo/hi.
    pub fn encode(&self, feature: &[f32]) -> Result<EncodedFeature> {
        let m = &self.meta;
        let feature = TensorView::f32(feature.to_vec(), vec![1, m.ch, m.h, m.w])?;
        let outs = self.enc.call_refs(&[&self.weights, &feature])?;
        Ok(EncodedFeature {
            codes: outs[0].clone().into_f32s()?,
            shape: vec![1, m.ch_r, m.h, m.w],
            lo: outs[1].scalar()?,
            hi: outs[2].scalar()?,
            bits: m.bits as u32,
        })
    }

    /// Edge side: codes -> restored feature (1, ch, h, w).
    pub fn decode(&self, enc: &EncodedFeature) -> Result<Vec<f32>> {
        let codes = TensorView::f32(enc.codes.clone(), enc.shape.clone())?;
        let lo = TensorView::from_scalar(enc.lo);
        let hi = TensorView::from_scalar(enc.hi);
        let outs = self.dec.call_refs(&[&self.weights, &codes, &lo, &hi])?;
        outs[0].clone().into_f32s()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wire_roundtrip_without_artifacts() {
        let enc = EncodedFeature {
            codes: vec![0.0, 255.0, 17.0, 128.0],
            shape: vec![1, 1, 2, 2],
            lo: -1.0,
            hi: 3.0,
            bits: 8,
        };
        let wire = enc.to_wire().unwrap();
        assert_eq!(wire.len(), 4);
        let back = EncodedFeature::from_wire(&wire, enc.shape.clone(), -1.0, 3.0, 8).unwrap();
        assert_eq!(back.codes, enc.codes);
        assert_eq!(enc.wire_bits(), 4 * 8 + 64);
    }
}
