//! Feature compression substrates (paper Sec. 2 + the JALAD baseline).
//!
//! * [`quant`] — Eq. (1)/(2) fixed-point quantization, bit-packing for the
//!   wire, mirrored against the Pallas kernels (same formulas).
//! * [`huffman`] — canonical Huffman coder over quantized bytes: the
//!   entropy-coding stage of the JALAD baseline, measured for real.
//! * [`jalad`] — the JALAD compressor model (8-bit quant + Huffman) used by
//!   both the serving path and the Fig. 4 comparison.
//! * [`ae`] — the autoencoder compressor handle driving the AOT encode/
//!   decode artifacts on the serving path.

pub mod ae;
pub mod huffman;
pub mod jalad;
pub mod quant;
