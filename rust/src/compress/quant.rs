//! Fixed-point quantization — Rust mirror of the Pallas kernels
//! (python/compile/kernels/quant.py), Eqs. (1)/(2) of the paper.
//!
//! The serving path uses the AOT kernels; this module provides the wire
//! format (bit-packing integer codes) plus a native implementation used by
//! the JALAD baseline, tests and benches. Formulas match the kernels
//! exactly so cross-validation tests can compare them elementwise.

use anyhow::{bail, Result};

use crate::runtime::native::kernels::round_ties_even;

/// A quantizer for a fixed bit-width (1..=16).
#[derive(Debug, Clone, Copy)]
pub struct Quantizer {
    pub bits: u32,
}

impl Quantizer {
    pub fn new(bits: u32) -> Result<Quantizer> {
        if bits == 0 || bits > 16 {
            bail!("bit-width {bits} out of range 1..=16");
        }
        Ok(Quantizer { bits })
    }

    pub fn levels(&self) -> u32 {
        (1u32 << self.bits) - 1
    }

    /// Eq. (1): y_i = round((2^cq − 1)(clip(x_i) − lo) / (hi − lo)).
    ///
    /// Rounds half-to-even (`jnp.round`), exactly like the native kernel
    /// and the AOT encode artifact — `.round()` (half-away-from-zero)
    /// would diverge by one code on exact half-boundary inputs and break
    /// the elementwise kernel/JALAD cross-validation.
    pub fn quantize(&self, x: &[f32], lo: f32, hi: f32) -> Vec<u16> {
        let levels = self.levels() as f32;
        let span = (hi - lo).max(1e-12);
        x.iter()
            .map(|&v| {
                let c = v.clamp(lo, hi);
                round_ties_even(levels * (c - lo) / span) as u16
            })
            .collect()
    }

    /// Eq. (2): x'_i = y_i (hi − lo) / (2^cq − 1) + lo.
    pub fn dequantize(&self, y: &[u16], lo: f32, hi: f32) -> Vec<f32> {
        let levels = self.levels() as f32;
        y.iter()
            .map(|&q| q as f32 * (hi - lo) / levels + lo)
            .collect()
    }

    /// Pack codes LSB-first into a byte stream (the uplink payload).
    pub fn pack(&self, codes: &[u16]) -> Vec<u8> {
        let total_bits = codes.len() * self.bits as usize;
        let mut out = vec![0u8; total_bits.div_ceil(8)];
        let mut bitpos = 0usize;
        for &c in codes {
            debug_assert!(c as u32 <= self.levels());
            for k in 0..self.bits as usize {
                if (c >> k) & 1 == 1 {
                    out[(bitpos + k) / 8] |= 1 << ((bitpos + k) % 8);
                }
            }
            bitpos += self.bits as usize;
        }
        out
    }

    /// Inverse of [`Quantizer::pack`]; `n` is the number of codes.
    pub fn unpack(&self, bytes: &[u8], n: usize) -> Result<Vec<u16>> {
        let need = (n * self.bits as usize).div_ceil(8);
        if bytes.len() < need {
            bail!("need {need} bytes for {n} codes, got {}", bytes.len());
        }
        let mut out = Vec::with_capacity(n);
        let mut bitpos = 0usize;
        for _ in 0..n {
            let mut c = 0u16;
            for k in 0..self.bits as usize {
                if (bytes[(bitpos + k) / 8] >> ((bitpos + k) % 8)) & 1 == 1 {
                    c |= 1 << k;
                }
            }
            out.push(c);
            bitpos += self.bits as usize;
        }
        Ok(out)
    }

    /// Max absolute reconstruction error: half a quantization step.
    pub fn max_error(&self, lo: f32, hi: f32) -> f32 {
        0.5 * (hi - lo) / self.levels() as f32
    }
}

/// min/max calibration over a sample of feature values.
pub fn calibrate(x: &[f32]) -> (f32, f32) {
    let mut lo = f32::INFINITY;
    let mut hi = f32::NEG_INFINITY;
    for &v in x {
        lo = lo.min(v);
        hi = hi.max(v);
    }
    if !lo.is_finite() || !hi.is_finite() || lo >= hi {
        (0.0, 1.0)
    } else {
        (lo, hi)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::forall;

    #[test]
    fn roundtrip_error_bounded() {
        forall(
            31,
            200,
            |g| {
                let n = g.usize_in(1, 64);
                let bits = 2 + (g.rng.next_u64() % 8) as u32;
                (g.vec_f32(n, -4.0, 4.0), bits)
            },
            |(x, bits)| {
                let q = Quantizer::new(*bits).unwrap();
                let (lo, hi) = calibrate(x);
                let codes = q.quantize(x, lo, hi);
                let x2 = q.dequantize(&codes, lo, hi);
                let tol = q.max_error(lo, hi) * 1.001 + 1e-6;
                for (a, b) in x.iter().zip(&x2) {
                    // values outside the calibration range are clipped by
                    // design (Eq. 1); the bound applies to the clipped value
                    let a = a.clamp(lo, hi);
                    if (a - b).abs() > tol {
                        return Err(format!("{a} vs {b} (tol {tol}, bits {bits})"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn pack_unpack_roundtrip() {
        forall(
            32,
            200,
            |g| {
                let bits = 1 + (g.rng.next_u64() % 12) as u32;
                let n = g.usize_in(1, 100);
                let max = (1u32 << bits) - 1;
                let codes: Vec<u16> = (0..n)
                    .map(|_| (g.rng.next_u64() % (max as u64 + 1)) as u16)
                    .collect();
                (codes, bits)
            },
            |(codes, bits)| {
                let q = Quantizer::new(*bits).unwrap();
                let packed = q.pack(codes);
                if packed.len() != (codes.len() * *bits as usize).div_ceil(8) {
                    return Err("wrong packed size".into());
                }
                let back = q.unpack(&packed, codes.len()).map_err(|e| e.to_string())?;
                if &back != codes {
                    return Err("codes mismatch".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn non_byte_aligned_widths_roundtrip_across_byte_boundaries() {
        // explicit Eq. 1/2 wire cases: widths that straddle byte edges
        for bits in [3u32, 5, 7, 11, 13] {
            let q = Quantizer::new(bits).unwrap();
            let max = (1u32 << bits) - 1;
            // all-ones, all-zeros, and a ramp exercising every bit lane
            let patterns: [Vec<u16>; 3] = [
                vec![max as u16; 17],
                vec![0u16; 17],
                (0..17u32).map(|i| (i * 37 % (max + 1)) as u16).collect(),
            ];
            for codes in &patterns {
                let packed = q.pack(codes);
                assert_eq!(packed.len(), (codes.len() * bits as usize).div_ceil(8));
                let back = q.unpack(&packed, codes.len()).unwrap();
                assert_eq!(&back, codes, "bits={bits}");
            }
            // short buffer must error, not read out of bounds
            let packed = q.pack(&patterns[0]);
            assert!(q.unpack(&packed[..packed.len() - 1], 17).is_err());
        }
    }

    #[test]
    fn matches_paper_formula_exactly() {
        // hand-computed: x = 0.5 in [0,1] at 2 bits -> round(3*0.5)=2 -> 2/3
        let q = Quantizer::new(2).unwrap();
        let codes = q.quantize(&[0.5], 0.0, 1.0);
        assert_eq!(codes, vec![2]);
        let back = q.dequantize(&codes, 0.0, 1.0);
        assert!((back[0] - 2.0 / 3.0).abs() < 1e-6);
    }

    #[test]
    fn half_boundary_ties_round_to_even_like_the_kernel() {
        // exactly-representable ties: bits=2 (levels=3), span exactly 3,
        // so t = 3·(x−0)/3 = x lands on .5 precisely. Half-away-from-zero
        // (the old `.round()`) gave [1, 2, 3] / [1] here.
        let q = Quantizer::new(2).unwrap();
        let xs = [0.5f32, 1.5, 2.5];
        let codes = q.quantize(&xs, 0.0, 3.0);
        assert_eq!(codes, vec![0, 2, 2]);
        let q1 = Quantizer::new(1).unwrap();
        assert_eq!(q1.quantize(&[0.5], 0.0, 1.0), vec![0]);
        // elementwise cross-validation against the native kernel on the
        // same tie points
        for (bits, x, lo, hi) in [
            (2usize, &xs[..], 0.0f32, 3.0f32),
            (1, &[0.5f32][..], 0.0, 1.0),
        ] {
            let wire = Quantizer::new(bits as u32).unwrap().quantize(x, lo, hi);
            let native = crate::runtime::native::kernels::quantize(x, lo, hi, bits);
            for (a, b) in wire.iter().zip(&native) {
                assert_eq!(*a as f32, *b, "bits={bits}");
            }
        }
    }

    #[test]
    fn clipping_outside_calibration() {
        let q = Quantizer::new(8).unwrap();
        let codes = q.quantize(&[-10.0, 10.0], 0.0, 1.0);
        assert_eq!(codes[0], 0);
        assert_eq!(codes[1], 255);
    }

    #[test]
    fn degenerate_calibration() {
        assert_eq!(calibrate(&[]), (0.0, 1.0));
        assert_eq!(calibrate(&[2.0, 2.0]), (0.0, 1.0));
        let (lo, hi) = calibrate(&[1.0, -1.0]);
        assert_eq!((lo, hi), (-1.0, 1.0));
    }

    #[test]
    fn invalid_bitwidths_rejected() {
        assert!(Quantizer::new(0).is_err());
        assert!(Quantizer::new(17).is_err());
    }
}
