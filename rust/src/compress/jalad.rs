//! JALAD-style compressor (Li et al., ICPADS'18): 8-bit quantization of
//! the raw intermediate feature followed by entropy coding.
//!
//! This is the paper's comparison baseline for Fig. 4 / Sec. 6: unlike the
//! autoencoder it does not shrink the channel dimension, so the quantized
//! payload is large and the entropy coder does the heavy lifting — which is
//! exactly why its latency overhead on the UE is high.

use anyhow::Result;

use super::huffman::{HuffmanBlock, HuffmanCoder};
use super::quant::{calibrate, Quantizer};

/// A compressed feature in JALAD format.
#[derive(Debug, Clone)]
pub struct JaladPacket {
    pub block: HuffmanBlock,
    pub lo: f32,
    pub hi: f32,
    pub n: usize,
}

impl JaladPacket {
    /// Uplink payload size in bits (code table + payload + calibration).
    pub fn wire_bits(&self) -> usize {
        self.block.wire_bits() + 64
    }
}

/// The 8-bit quant + Huffman pipeline.
#[derive(Debug, Clone, Copy)]
pub struct JaladCompressor {
    quant: Quantizer,
    coder: HuffmanCoder,
}

impl Default for JaladCompressor {
    fn default() -> Self {
        Self::new()
    }
}

impl JaladCompressor {
    pub fn new() -> JaladCompressor {
        JaladCompressor {
            quant: Quantizer::new(8).expect("8-bit quantizer"),
            coder: HuffmanCoder::new(),
        }
    }

    pub fn compress(&self, feature: &[f32]) -> JaladPacket {
        let (lo, hi) = calibrate(feature);
        let codes = self.quant.quantize(feature, lo, hi);
        let bytes: Vec<u8> = codes.iter().map(|&c| c as u8).collect();
        JaladPacket {
            block: self.coder.encode(&bytes),
            lo,
            hi,
            n: feature.len(),
        }
    }

    pub fn decompress(&self, packet: &JaladPacket) -> Result<Vec<f32>> {
        let bytes = self.coder.decode(&packet.block)?;
        let codes: Vec<u16> = bytes.iter().map(|&b| b as u16).collect();
        Ok(self.quant.dequantize(&codes, packet.lo, packet.hi))
    }

    /// Compression rate vs the fp32 original (Eq. 3's R for JALAD).
    pub fn rate(&self, feature: &[f32]) -> f64 {
        if feature.is_empty() {
            return 1.0;
        }
        let packet = self.compress(feature);
        (feature.len() * 32) as f64 / packet.wire_bits() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn featureish(n: usize, sparsity: f64, seed: u64) -> Vec<f32> {
        // post-ReLU conv features: mostly zeros + positive tail
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| {
                if rng.f64() < sparsity {
                    0.0
                } else {
                    rng.normal().abs() as f32
                }
            })
            .collect()
    }

    #[test]
    fn roundtrip_bounded_error() {
        let c = JaladCompressor::new();
        let x = featureish(4096, 0.5, 1);
        let p = c.compress(&x);
        let y = c.decompress(&p).unwrap();
        assert_eq!(x.len(), y.len());
        let (lo, hi) = calibrate(&x);
        let tol = (hi - lo) / 255.0;
        for (a, b) in x.iter().zip(&y) {
            assert!((a - b).abs() <= tol, "{a} vs {b}");
        }
    }

    #[test]
    fn sparser_features_compress_better() {
        let c = JaladCompressor::new();
        let dense = c.rate(&featureish(16384, 0.3, 2));
        let sparse = c.rate(&featureish(16384, 0.9, 3));
        assert!(
            sparse > dense * 1.5,
            "sparse {sparse:.1}x should beat dense {dense:.1}x"
        );
        // JALAD's reported regime: >4x over fp32 on conv features
        assert!(dense > 4.0, "even dense features give > 4x: {dense:.1}");
    }

    #[test]
    fn rate_accounts_wire_overhead() {
        let c = JaladCompressor::new();
        // tiny feature: table overhead dominates, rate must reflect that
        let tiny = c.rate(&[1.0, 2.0, 3.0]);
        assert!(tiny < 1.0, "tiny payloads pay the table: {tiny}");
    }
}
