//! Host-side tensors and `xla::Literal` conversion.
//!
//! The runtime boundary is deliberately narrow: everything crossing it is
//! an f32 or i32 dense tensor. `TensorView` owns a host copy of an output;
//! `to_literal` builds inputs with shape checks so a mismatched artifact
//! fails loudly at the call site instead of inside XLA.

use anyhow::{anyhow, bail, Result};

/// A host tensor read back from the device (always f32 or i32 here).
#[derive(Debug, Clone)]
pub struct TensorView {
    pub shape: Vec<usize>,
    pub data: Data,
}

#[derive(Debug, Clone)]
pub enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Default for TensorView {
    /// Empty f32 tensor — lets hot paths `std::mem::take` outputs out of a
    /// result vector without cloning the payload.
    fn default() -> Self {
        TensorView {
            shape: vec![0],
            data: Data::F32(Vec::new()),
        }
    }
}

impl TensorView {
    pub fn from_literal(lit: xla::Literal) -> Result<TensorView> {
        let shape = lit
            .array_shape()
            .map_err(|e| anyhow!("literal shape: {e:?}"))?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let data = match shape.ty() {
            xla::ElementType::F32 => Data::F32(
                lit.to_vec::<f32>()
                    .map_err(|e| anyhow!("reading f32 literal: {e:?}"))?,
            ),
            xla::ElementType::S32 => Data::I32(
                lit.to_vec::<i32>()
                    .map_err(|e| anyhow!("reading i32 literal: {e:?}"))?,
            ),
            other => bail!("unsupported output element type {other:?}"),
        };
        Ok(TensorView { shape: dims, data })
    }

    pub fn len(&self) -> usize {
        match &self.data {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Borrow as f32 slice (errors on dtype mismatch).
    pub fn f32s(&self) -> Result<&[f32]> {
        match &self.data {
            Data::F32(v) => Ok(v),
            Data::I32(_) => bail!("expected f32 tensor, got i32"),
        }
    }

    /// Consume into an owned f32 vec.
    pub fn into_f32s(self) -> Result<Vec<f32>> {
        match self.data {
            Data::F32(v) => Ok(v),
            Data::I32(_) => bail!("expected f32 tensor, got i32"),
        }
    }

    /// The single scalar value of a 0-d / 1-element tensor.
    pub fn scalar(&self) -> Result<f32> {
        let v = self.f32s()?;
        if v.len() != 1 {
            bail!("expected scalar, got {} elements", v.len());
        }
        Ok(v[0])
    }
}

/// Build an f32 literal of the given shape (checked).
pub fn f32_literal(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let count: usize = shape.iter().product();
    if count != data.len() {
        bail!(
            "shape {:?} needs {count} elements, got {}",
            shape,
            data.len()
        );
    }
    let lit = xla::Literal::vec1(data);
    if shape.len() == 1 {
        return Ok(lit);
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    lit.reshape(&dims)
        .map_err(|e| anyhow!("reshape to {shape:?}: {e:?}"))
}

/// Build an i32 literal of the given shape (checked).
pub fn i32_literal(data: &[i32], shape: &[usize]) -> Result<xla::Literal> {
    let count: usize = shape.iter().product();
    if count != data.len() {
        bail!(
            "shape {:?} needs {count} elements, got {}",
            shape,
            data.len()
        );
    }
    let lit = xla::Literal::vec1(data);
    if shape.len() == 1 {
        return Ok(lit);
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    lit.reshape(&dims)
        .map_err(|e| anyhow!("reshape to {shape:?}: {e:?}"))
}

/// Scalar f32 literal (0-d).
pub fn scalar_literal(x: f32) -> xla::Literal {
    xla::Literal::scalar(x)
}

/// Load a flat-f32 weight file written by the compile path (`.bin`,
/// little-endian f32, no header).
pub fn load_f32_bin(path: impl AsRef<std::path::Path>, expected: usize) -> Result<Vec<f32>> {
    let path = path.as_ref();
    let bytes = std::fs::read(path).map_err(|e| anyhow!("reading {}: {e}", path.display()))?;
    if bytes.len() % 4 != 0 {
        bail!("{}: size {} not a multiple of 4", path.display(), bytes.len());
    }
    let n = bytes.len() / 4;
    if expected != 0 && n != expected {
        bail!(
            "{}: expected {expected} f32 values, found {n}",
            path.display()
        );
    }
    let mut out = Vec::with_capacity(n);
    for chunk in bytes.chunks_exact(4) {
        out.push(f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_shape_mismatch_errors() {
        assert!(f32_literal(&[1.0, 2.0], &[3]).is_err());
        assert!(f32_literal(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).is_ok());
        assert!(i32_literal(&[1, 2, 3], &[2]).is_err());
    }

    #[test]
    fn bin_roundtrip() {
        let dir = std::env::temp_dir().join("macci_test_bin");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("w.bin");
        let vals: Vec<f32> = vec![1.5, -2.25, 0.0, 3.0e7];
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        std::fs::write(&path, bytes).unwrap();
        assert_eq!(load_f32_bin(&path, 4).unwrap(), vals);
        assert!(load_f32_bin(&path, 5).is_err());
    }
}
