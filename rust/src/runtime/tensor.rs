//! Host-side tensors crossing the backend boundary.
//!
//! The runtime boundary is deliberately narrow: everything crossing it is
//! an f32 or i32 dense tensor. [`TensorView`] owns host data for both
//! executable inputs and outputs; the checked constructors make a
//! mismatched artifact fail loudly at the call site instead of deep inside
//! a backend.

use anyhow::{bail, Result};

/// A host tensor (always f32 or i32 here). A 0-d tensor (`shape == []`)
/// holds exactly one element.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorView {
    pub shape: Vec<usize>,
    pub data: Data,
}

#[derive(Debug, Clone, PartialEq)]
pub enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Default for TensorView {
    /// Empty f32 tensor — lets hot paths `std::mem::take` outputs out of a
    /// result vector without cloning the payload.
    fn default() -> Self {
        TensorView {
            shape: vec![0],
            data: Data::F32(Vec::new()),
        }
    }
}

impl TensorView {
    /// Owned f32 tensor with a shape check.
    pub fn f32(data: Vec<f32>, shape: Vec<usize>) -> Result<TensorView> {
        let count: usize = shape.iter().product();
        if count != data.len() {
            bail!("shape {:?} needs {count} elements, got {}", shape, data.len());
        }
        Ok(TensorView {
            shape,
            data: Data::F32(data),
        })
    }

    /// Owned i32 tensor with a shape check.
    pub fn i32(data: Vec<i32>, shape: Vec<usize>) -> Result<TensorView> {
        let count: usize = shape.iter().product();
        if count != data.len() {
            bail!("shape {:?} needs {count} elements, got {}", shape, data.len());
        }
        Ok(TensorView {
            shape,
            data: Data::I32(data),
        })
    }

    /// 0-d f32 tensor.
    pub fn from_scalar(x: f32) -> TensorView {
        TensorView {
            shape: Vec::new(),
            data: Data::F32(vec![x]),
        }
    }

    pub fn len(&self) -> usize {
        match &self.data {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Borrow as f32 slice (errors on dtype mismatch).
    pub fn f32s(&self) -> Result<&[f32]> {
        match &self.data {
            Data::F32(v) => Ok(v),
            Data::I32(_) => bail!("expected f32 tensor, got i32"),
        }
    }

    /// Borrow as i32 slice (errors on dtype mismatch).
    pub fn i32s(&self) -> Result<&[i32]> {
        match &self.data {
            Data::I32(v) => Ok(v),
            Data::F32(_) => bail!("expected i32 tensor, got f32"),
        }
    }

    /// Consume into an owned f32 vec.
    pub fn into_f32s(self) -> Result<Vec<f32>> {
        match self.data {
            Data::F32(v) => Ok(v),
            Data::I32(_) => bail!("expected f32 tensor, got i32"),
        }
    }

    /// The single scalar value of a 0-d / 1-element tensor.
    pub fn scalar(&self) -> Result<f32> {
        let v = self.f32s()?;
        if v.len() != 1 {
            bail!("expected scalar, got {} elements", v.len());
        }
        Ok(v[0])
    }
}

/// Load a flat-f32 weight file written by the compile path (`.bin`,
/// little-endian f32, no header).
pub fn load_f32_bin(path: impl AsRef<std::path::Path>, expected: usize) -> Result<Vec<f32>> {
    let path = path.as_ref();
    let bytes = std::fs::read(path).map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
    if bytes.len() % 4 != 0 {
        bail!("{}: size {} not a multiple of 4", path.display(), bytes.len());
    }
    let n = bytes.len() / 4;
    if expected != 0 && n != expected {
        bail!(
            "{}: expected {expected} f32 values, found {n}",
            path.display()
        );
    }
    let mut out = Vec::with_capacity(n);
    for chunk in bytes.chunks_exact(4) {
        out.push(f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_mismatch_errors() {
        assert!(TensorView::f32(vec![1.0, 2.0], vec![3]).is_err());
        assert!(TensorView::f32(vec![1.0, 2.0, 3.0, 4.0], vec![2, 2]).is_ok());
        assert!(TensorView::i32(vec![1, 2, 3], vec![2]).is_err());
    }

    #[test]
    fn scalar_tensor() {
        let t = TensorView::from_scalar(2.5);
        assert!(t.shape.is_empty());
        assert_eq!(t.len(), 1);
        assert_eq!(t.scalar().unwrap(), 2.5);
        let v = TensorView::f32(vec![1.0, 2.0], vec![2]).unwrap();
        assert!(v.scalar().is_err());
    }

    #[test]
    fn dtype_accessors() {
        let t = TensorView::i32(vec![1, 2], vec![2]).unwrap();
        assert!(t.f32s().is_err());
        assert_eq!(t.i32s().unwrap(), &[1, 2]);
    }

    #[test]
    fn bin_roundtrip() {
        let dir = std::env::temp_dir().join("macci_test_bin");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("w.bin");
        let vals: Vec<f32> = vec![1.5, -2.25, 0.0, 3.0e7];
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        std::fs::write(&path, bytes).unwrap();
        assert_eq!(load_f32_bin(&path, 4).unwrap(), vals);
        assert!(load_f32_bin(&path, 5).is_err());
    }
}
