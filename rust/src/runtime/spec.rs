//! Flat-parameter layouts — the Rust mirror of `python/compile/common.py`
//! `ParamSpec`.
//!
//! Every network crosses the backend boundary as ONE flat f32 vector; the
//! layout (ordered name → offset/count/shape) is what gives that vector
//! meaning. Layouts arrive from `artifacts/manifest.json` (`rl.specs`) when
//! a compiled manifest exists, or are synthesized by [`actor_layout`] /
//! [`critic_layout`] for the built-in native demo manifest; both paths
//! produce byte-identical layouts for the paper architectures.

use anyhow::{anyhow, Result};

use crate::util::json::Json;

/// One entry of a network's flat-parameter layout.
#[derive(Debug, Clone, PartialEq)]
pub struct SpecEntry {
    pub name: String,
    pub offset: usize,
    pub count: usize,
    pub shape: Vec<usize>,
}

impl SpecEntry {
    /// The `(rows, cols)` of a 2-D entry, or `None` for any other rank —
    /// lets consumers destructure weight matrices without hand-rolled
    /// shape checks.
    pub fn dims2(&self) -> Option<(usize, usize)> {
        match self.shape[..] {
            [r, c] => Some((r, c)),
            _ => None,
        }
    }
}

/// Total parameter count of a layout.
pub fn spec_size(spec: &[SpecEntry]) -> usize {
    spec.iter().map(|e| e.count).sum()
}

/// Find a layout entry by name.
pub fn spec_entry<'a>(spec: &'a [SpecEntry], name: &str) -> Result<&'a SpecEntry> {
    spec.iter()
        .find(|e| e.name == name)
        .ok_or_else(|| anyhow!("parameter layout has no entry '{name}'"))
}

/// Parse a manifest `rl.specs.<N>.<actor|critic>` layout array.
pub fn parse_spec(j: &Json) -> Result<Vec<SpecEntry>> {
    j.as_arr()?
        .iter()
        .map(|e| {
            Ok(SpecEntry {
                name: e.str_of("name")?.to_string(),
                offset: e.usize_of("offset")?,
                count: e.usize_of("count")?,
                shape: e
                    .req("shape")?
                    .as_arr()?
                    .iter()
                    .map(|d| d.as_usize())
                    .collect::<Result<_>>()?,
            })
        })
        .collect()
}

// Network size constants (paper Sec. 6.3.1) — keep in sync with
// python/compile/actor_critic.py.
pub const TRUNK: [usize; 2] = [256, 128];
pub const BRANCH_HIDDEN: usize = 64;
pub const CRITIC: [usize; 3] = [256, 128, 64];

fn build(entries: &[(&str, Vec<usize>)]) -> Vec<SpecEntry> {
    let mut out = Vec::with_capacity(entries.len());
    let mut offset = 0usize;
    for (name, shape) in entries {
        let count: usize = shape.iter().product();
        out.push(SpecEntry {
            name: name.to_string(),
            offset,
            count,
            shape: shape.clone(),
        });
        offset += count;
    }
    out
}

/// The actor layout for N UEs — mirror of `actor_spec` in
/// python/compile/actor_critic.py (trunk 4N→256→128 tanh, three branch
/// heads with 64 hidden each, split mu/log_std bias).
pub fn actor_layout(n_ues: usize, n_partition: usize, n_channels: usize) -> Vec<SpecEntry> {
    let d = 4 * n_ues;
    let (t0, t1) = (TRUNK[0], TRUNK[1]);
    let h = BRANCH_HIDDEN;
    build(&[
        ("w_t0", vec![d, t0]),
        ("b_t0", vec![t0]),
        ("w_t1", vec![t0, t1]),
        ("b_t1", vec![t1]),
        // partition-point branch
        ("w_b0", vec![t1, h]),
        ("b_b0", vec![h]),
        ("w_b1", vec![h, n_partition]),
        ("b_b1", vec![n_partition]),
        // channel branch
        ("w_c0", vec![t1, h]),
        ("b_c0", vec![h]),
        ("w_c1", vec![h, n_channels]),
        ("b_c1", vec![n_channels]),
        // power branch: mu and a state-dependent log_std
        ("w_p0", vec![t1, h]),
        ("b_p0", vec![h]),
        ("w_p1", vec![h, 2]),
        ("b_p1_mu", vec![1]),
        ("b_p1_log_std", vec![1]),
    ])
}

/// The critic layout for N UEs — mirror of `critic_spec`
/// (FC 4N→256→128→64→1).
pub fn critic_layout(n_ues: usize) -> Vec<SpecEntry> {
    let d = 4 * n_ues;
    build(&[
        ("w_0", vec![d, CRITIC[0]]),
        ("b_0", vec![CRITIC[0]]),
        ("w_1", vec![CRITIC[0], CRITIC[1]]),
        ("b_1", vec![CRITIC[1]]),
        ("w_2", vec![CRITIC[1], CRITIC[2]]),
        ("b_2", vec![CRITIC[2]]),
        ("w_3", vec![CRITIC[2], 1]),
        ("b_3", vec![1]),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layouts_are_contiguous() {
        for spec in [actor_layout(5, 6, 2), critic_layout(5)] {
            let mut off = 0;
            for e in &spec {
                assert_eq!(e.offset, off, "{} not contiguous", e.name);
                assert_eq!(e.count, e.shape.iter().product::<usize>());
                off += e.count;
            }
            assert_eq!(off, spec_size(&spec));
        }
    }

    #[test]
    fn actor_size_matches_python_formula() {
        // sum of the actor_spec shapes for N=5, P=6, C=2 (see
        // python/compile/actor_critic.py)
        let d = 20;
        let expect = d * 256
            + 256
            + 256 * 128
            + 128
            + 3 * (128 * 64 + 64)
            + (64 * 6 + 6)
            + (64 * 2 + 2)
            + (64 * 2 + 1 + 1);
        assert_eq!(spec_size(&actor_layout(5, 6, 2)), expect);
    }

    #[test]
    fn critic_size_matches_python_formula() {
        let d = 20;
        let expect = d * 256 + 256 + 256 * 128 + 128 + 128 * 64 + 64 + 64 + 1;
        assert_eq!(spec_size(&critic_layout(5)), expect);
    }

    #[test]
    fn dims2_only_on_matrices() {
        let spec = critic_layout(5);
        assert_eq!(spec_entry(&spec, "w_0").unwrap().dims2(), Some((20, 256)));
        assert_eq!(spec_entry(&spec, "b_0").unwrap().dims2(), None);
    }

    #[test]
    fn parse_roundtrip() {
        let j = Json::parse(
            r#"[{"name":"w","offset":0,"count":6,"shape":[2,3]},
                {"name":"b","offset":6,"count":3,"shape":[3]}]"#,
        )
        .unwrap();
        let spec = parse_spec(&j).unwrap();
        assert_eq!(spec.len(), 2);
        assert_eq!(spec_size(&spec), 9);
        assert_eq!(spec_entry(&spec, "b").unwrap().offset, 6);
        assert!(spec_entry(&spec, "zzz").is_err());
    }
}
