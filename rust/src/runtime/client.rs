//! PJRT CPU client wrapper with an executable cache.
//!
//! HLO *text* is the interchange format (see DESIGN.md): jax >= 0.5 emits
//! protos with 64-bit instruction ids that xla_extension 0.5.1 rejects; the
//! text parser reassigns ids, so text round-trips cleanly.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{anyhow, Context, Result};
use once_cell::sync::Lazy;

use super::tensor::TensorView;

/// Process-wide XLA lock.
///
/// The `xla` crate's wrappers hold `Rc` refcounts and raw PJRT pointers and
/// are therefore `!Send`/`!Sync`. The underlying PJRT C API is thread-safe,
/// but the `Rc<PjRtClientInternal>` refcount is not: every client clone
/// (which happens inside `execute` when output buffers are wrapped) must be
/// serialized. All compile and execute calls take this lock, making it
/// sound to move/share [`Runtime`] and [`Executable`] across threads — see
/// the `unsafe impl`s below. On the single-core target this serialization
/// costs nothing; a multi-core port would switch to one client per thread.
static XLA_LOCK: Lazy<Mutex<()>> = Lazy::new(|| Mutex::new(()));

/// Process-wide PJRT runtime. Cheap to clone (Arc inside).
#[derive(Clone)]
pub struct Runtime {
    inner: Arc<RuntimeInner>,
}

struct RuntimeInner {
    client: xla::PjRtClient,
    cache: Mutex<HashMap<PathBuf, Arc<Executable>>>,
}

// SAFETY: every path that touches the wrapped PJRT objects (compile in
// `Runtime::load`, execute + literal readback in `Executable::call`) holds
// the process-wide XLA_LOCK, serializing all Rc refcount mutations and C
// API calls. No other method exposes the inner xla types.
unsafe impl Send for RuntimeInner {}
unsafe impl Sync for RuntimeInner {}
unsafe impl Send for Executable {}
unsafe impl Sync for Executable {}

/// A compiled HLO module ready to execute.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    /// Human-readable identity for error messages.
    name: String,
    /// Cumulative execution statistics (perf pass).
    stats: Mutex<ExecStats>,
}

#[derive(Default, Clone, Copy, Debug)]
pub struct ExecStats {
    pub calls: u64,
    pub total_ns: u64,
}

impl Runtime {
    /// Create the PJRT CPU client. One per process is plenty.
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Runtime {
            inner: Arc::new(RuntimeInner {
                client,
                cache: Mutex::new(HashMap::new()),
            }),
        })
    }

    pub fn platform(&self) -> String {
        self.inner.client.platform_name()
    }

    /// Load + compile an HLO text file, memoized on the canonical path.
    pub fn load(&self, path: impl AsRef<Path>) -> Result<Arc<Executable>> {
        let path = path.as_ref();
        let key = path
            .canonicalize()
            .unwrap_or_else(|_| path.to_path_buf());
        if let Some(exe) = self.inner.cache.lock().unwrap().get(&key) {
            return Ok(exe.clone());
        }
        let t0 = Instant::now();
        let _xla = XLA_LOCK.lock().unwrap();
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| anyhow!("parsing HLO text {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .inner
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e:?}", path.display()))?;
        log::debug!(
            "compiled {} in {:.1} ms",
            path.display(),
            t0.elapsed().as_secs_f64() * 1e3
        );
        let exe = Arc::new(Executable {
            exe,
            name: path.display().to_string(),
            stats: Mutex::new(ExecStats::default()),
        });
        self.inner
            .cache
            .lock()
            .unwrap()
            .insert(key, exe.clone());
        Ok(exe)
    }

    /// Number of distinct executables compiled so far.
    pub fn cache_len(&self) -> usize {
        self.inner.cache.lock().unwrap().len()
    }
}

impl Executable {
    /// Execute with f32/i32 tensor inputs; returns all outputs of the
    /// module's result tuple as [`TensorView`]s (host copies).
    ///
    /// Every artifact is lowered with `return_tuple=True`, so the single
    /// output buffer is always a tuple literal — including 1-output
    /// modules.
    pub fn call(&self, inputs: &[xla::Literal]) -> Result<Vec<TensorView>> {
        self.call_impl(|exe| exe.execute::<xla::Literal>(inputs))
    }

    /// Like [`Executable::call`] but borrowing the input literals — lets
    /// hot paths keep device-format copies of loop-invariant inputs (e.g.
    /// network parameters between PPO updates) instead of re-copying them
    /// every call (§Perf).
    pub fn call_refs(&self, inputs: &[&xla::Literal]) -> Result<Vec<TensorView>> {
        self.call_impl(|exe| exe.execute::<&xla::Literal>(inputs))
    }

    fn call_impl<F>(&self, run: F) -> Result<Vec<TensorView>>
    where
        F: FnOnce(
            &xla::PjRtLoadedExecutable,
        ) -> std::result::Result<Vec<Vec<xla::PjRtBuffer>>, xla::Error>,
    {
        let t0 = Instant::now();
        let _xla = XLA_LOCK.lock().unwrap();
        let result = run(&self.exe).map_err(|e| anyhow!("executing {}: {e:?}", self.name))?;
        let buf = result
            .first()
            .and_then(|r| r.first())
            .ok_or_else(|| anyhow!("{}: empty execution result", self.name))?;
        let lit = buf
            .to_literal_sync()
            .map_err(|e| anyhow!("{}: reading result: {e:?}", self.name))?;
        let parts = lit
            .to_tuple()
            .map_err(|e| anyhow!("{}: decomposing result tuple: {e:?}", self.name))?;
        let views = parts
            .into_iter()
            .enumerate()
            .map(|(i, l)| {
                TensorView::from_literal(l)
                    .with_context(|| format!("{}: output {i}", self.name))
            })
            .collect::<Result<Vec<_>>>()?;
        let dt = t0.elapsed().as_nanos() as u64;
        let mut s = self.stats.lock().unwrap();
        s.calls += 1;
        s.total_ns += dt;
        Ok(views)
    }

    pub fn stats(&self) -> ExecStats {
        *self.stats.lock().unwrap()
    }

    pub fn name(&self) -> &str {
        &self.name
    }
}
