//! PJRT CPU backend (cargo feature `xla-pjrt`) — compiles and executes the
//! AOT HLO-text artifacts through the PJRT C API, with an executable cache.
//!
//! HLO *text* is the interchange format (see DESIGN.md §Substitutions):
//! jax >= 0.5 emits protos with 64-bit instruction ids that xla_extension
//! 0.5.1 rejects; the text parser reassigns ids, so text round-trips
//! cleanly. In the offline tree the `xla` dependency resolves to an
//! API-compatible stub (rust/vendor/xla-stub) so this path stays
//! compilable; point it at the real crate to execute.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{anyhow, Context, Result};
use once_cell::sync::Lazy;

use super::artifacts::ArtifactMeta;
use super::backend::{Backend, ExecStats, Executable};
use super::tensor::{Data, TensorView};

/// Process-wide XLA lock.
///
/// The `xla` crate's wrappers hold `Rc` refcounts and raw PJRT pointers and
/// are therefore `!Send`/`!Sync`. The underlying PJRT C API is thread-safe,
/// but the `Rc<PjRtClientInternal>` refcount is not: every client clone
/// (which happens inside `execute` when output buffers are wrapped) must be
/// serialized. All compile and execute calls take this lock, making it
/// sound to move/share [`Runtime`] and [`PjrtExecutable`] across threads —
/// see the `unsafe impl`s below. On the single-core target this
/// serialization costs nothing; a multi-core port would switch to one
/// client per thread.
static XLA_LOCK: Lazy<Mutex<()>> = Lazy::new(|| Mutex::new(()));

/// [`Backend`] over the process-wide PJRT runtime.
pub struct PjrtBackend {
    runtime: Runtime,
}

impl PjrtBackend {
    /// Create a backend over a fresh process-wide PJRT CPU client.
    pub fn new() -> Result<PjrtBackend> {
        Ok(PjrtBackend {
            runtime: Runtime::cpu()?,
        })
    }

    /// The underlying runtime (shared executable cache + client).
    pub fn runtime(&self) -> &Runtime {
        &self.runtime
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &str {
        "xla-pjrt"
    }

    fn load(&self, meta: &ArtifactMeta) -> Result<Arc<dyn Executable>> {
        let exe: Arc<dyn Executable> = self.runtime.load(&meta.path)?;
        Ok(exe)
    }
}

/// Process-wide PJRT runtime. Cheap to clone (Arc inside).
#[derive(Clone)]
pub struct Runtime {
    inner: Arc<RuntimeInner>,
}

struct RuntimeInner {
    client: xla::PjRtClient,
    cache: Mutex<HashMap<PathBuf, Arc<PjrtExecutable>>>,
}

// SAFETY: every path that touches the wrapped PJRT objects (compile in
// `Runtime::load`, execute + literal readback in `PjrtExecutable::call_refs`)
// holds the process-wide XLA_LOCK, serializing all Rc refcount mutations and
// C API calls. No other method exposes the inner xla types.
unsafe impl Send for RuntimeInner {}
unsafe impl Sync for RuntimeInner {}
unsafe impl Send for PjrtExecutable {}
unsafe impl Sync for PjrtExecutable {}

/// A compiled HLO module ready to execute.
pub struct PjrtExecutable {
    exe: xla::PjRtLoadedExecutable,
    /// Human-readable identity for error messages.
    name: String,
    /// Cumulative execution statistics (perf pass).
    stats: Mutex<ExecStats>,
}

impl Runtime {
    /// Create the PJRT CPU client. One per process is plenty.
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Runtime {
            inner: Arc::new(RuntimeInner {
                client,
                cache: Mutex::new(HashMap::new()),
            }),
        })
    }

    /// Name of the PJRT platform backing the client (e.g. "cpu").
    pub fn platform(&self) -> String {
        self.inner.client.platform_name()
    }

    /// Load + compile an HLO text file, memoized on the canonical path.
    pub fn load(&self, path: impl AsRef<Path>) -> Result<Arc<PjrtExecutable>> {
        let path = path.as_ref();
        let key = path.canonicalize().unwrap_or_else(|_| path.to_path_buf());
        if let Some(exe) = self.inner.cache.lock().unwrap().get(&key) {
            return Ok(exe.clone());
        }
        let t0 = Instant::now();
        let _xla = XLA_LOCK.lock().unwrap();
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| anyhow!("parsing HLO text {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .inner
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e:?}", path.display()))?;
        log::debug!(
            "compiled {} in {:.1} ms",
            path.display(),
            t0.elapsed().as_secs_f64() * 1e3
        );
        let exe = Arc::new(PjrtExecutable {
            exe,
            name: path.display().to_string(),
            stats: Mutex::new(ExecStats::default()),
        });
        self.inner.cache.lock().unwrap().insert(key, exe.clone());
        Ok(exe)
    }

    /// Number of distinct executables compiled so far.
    pub fn cache_len(&self) -> usize {
        self.inner.cache.lock().unwrap().len()
    }
}

/// Build a device literal from a host tensor.
fn to_literal(t: &TensorView) -> Result<xla::Literal> {
    let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
    let lit = match &t.data {
        Data::F32(v) => {
            if t.shape.is_empty() {
                return Ok(xla::Literal::scalar(v[0]));
            }
            xla::Literal::vec1(v)
        }
        Data::I32(v) => xla::Literal::vec1(v),
    };
    if dims.len() == 1 {
        return Ok(lit);
    }
    lit.reshape(&dims)
        .map_err(|e| anyhow!("reshape to {:?}: {e:?}", t.shape))
}

/// Read a device literal back into a host tensor.
fn from_literal(lit: xla::Literal) -> Result<TensorView> {
    let shape = lit
        .array_shape()
        .map_err(|e| anyhow!("literal shape: {e:?}"))?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let data = match shape.ty() {
        xla::ElementType::F32 => Data::F32(
            lit.to_vec::<f32>()
                .map_err(|e| anyhow!("reading f32 literal: {e:?}"))?,
        ),
        xla::ElementType::S32 => Data::I32(
            lit.to_vec::<i32>()
                .map_err(|e| anyhow!("reading i32 literal: {e:?}"))?,
        ),
        other => anyhow::bail!("unsupported output element type {other:?}"),
    };
    Ok(TensorView { shape: dims, data })
}

impl Executable for PjrtExecutable {
    fn name(&self) -> &str {
        &self.name
    }

    /// Execute with f32/i32 tensor inputs; returns all outputs of the
    /// module's result tuple as host tensors.
    ///
    /// Every artifact is lowered with `return_tuple=True`, so the single
    /// output buffer is always a tuple literal — including 1-output
    /// modules.
    fn call_refs(&self, inputs: &[&TensorView]) -> Result<Vec<TensorView>> {
        let lits: Vec<xla::Literal> = inputs
            .iter()
            .enumerate()
            .map(|(i, t)| to_literal(t).with_context(|| format!("{}: input {i}", self.name)))
            .collect::<Result<_>>()?;
        let refs: Vec<&xla::Literal> = lits.iter().collect();

        let t0 = Instant::now();
        let _xla = XLA_LOCK.lock().unwrap();
        let result = self
            .exe
            .execute::<&xla::Literal>(&refs)
            .map_err(|e| anyhow!("executing {}: {e:?}", self.name))?;
        let buf = result
            .first()
            .and_then(|r| r.first())
            .ok_or_else(|| anyhow!("{}: empty execution result", self.name))?;
        let lit = buf
            .to_literal_sync()
            .map_err(|e| anyhow!("{}: reading result: {e:?}", self.name))?;
        let parts = lit
            .to_tuple()
            .map_err(|e| anyhow!("{}: decomposing result tuple: {e:?}", self.name))?;
        let views = parts
            .into_iter()
            .enumerate()
            .map(|(i, l)| from_literal(l).with_context(|| format!("{}: output {i}", self.name)))
            .collect::<Result<Vec<_>>>()?;
        let dt = t0.elapsed().as_nanos() as u64;
        let mut s = self.stats.lock().unwrap();
        s.calls += 1;
        s.total_ns += dt;
        Ok(views)
    }

    fn stats(&self) -> ExecStats {
        *self.stats.lock().unwrap()
    }
}
