//! The artifact store: `artifacts/manifest.json` index over everything the
//! compile path produced — HLO modules, their I/O signatures, network
//! parameter layouts, trained weight files and model metadata.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use super::client::{Executable, Runtime};
use super::tensor::load_f32_bin;
use crate::util::json::Json;

/// One tensor in an artifact signature.
#[derive(Debug, Clone)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String, // "f32" | "i32"
}

/// One AOT-compiled HLO module.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub name: String,
    pub path: PathBuf,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

/// Per-N RL metadata (parameter vector sizes).
#[derive(Debug, Clone)]
pub struct RlMeta {
    pub n_range: Vec<usize>,
    pub n_partition: usize,
    pub n_channels: usize,
    pub actor_size: HashMap<usize, usize>,
    pub critic_size: HashMap<usize, usize>,
    pub update_batches: HashMap<usize, Vec<usize>>,
    pub default_update_batch: usize,
}

/// One partition point of a trained backbone.
#[derive(Debug, Clone)]
pub struct PointMeta {
    pub point: usize,
    pub ch: usize,
    pub h: usize,
    pub w: usize,
    pub ch_r: usize,
    pub bits: usize,
    pub rate: f64,
    pub ae_weights: PathBuf,
    pub ae_weights_size: usize,
}

/// A trained demo-scale backbone with its AE compressors.
#[derive(Debug, Clone)]
pub struct ModelMeta {
    pub name: String,
    pub weights: PathBuf,
    pub weights_size: usize,
    pub input_hw: usize,
    pub num_classes: usize,
    pub base_acc: f64,
    pub points: Vec<PointMeta>,
}

pub struct ArtifactStore {
    pub root: PathBuf,
    runtime: Runtime,
    by_name: HashMap<String, ArtifactMeta>,
    rl: Option<RlMeta>,
    models: HashMap<String, ModelMeta>,
}

impl ArtifactStore {
    /// Open `root/manifest.json` and create the PJRT runtime.
    pub fn open(root: impl AsRef<Path>) -> Result<ArtifactStore> {
        Self::with_runtime(root, Runtime::cpu()?)
    }

    pub fn with_runtime(root: impl AsRef<Path>, runtime: Runtime) -> Result<ArtifactStore> {
        let root = root.as_ref().to_path_buf();
        let manifest_path = root.join("manifest.json");
        if !manifest_path.exists() {
            bail!(
                "no manifest at {} — run `make artifacts` first",
                manifest_path.display()
            );
        }
        let man = Json::parse_file(&manifest_path)?;

        let mut by_name = HashMap::new();
        for e in man.req("artifacts")?.as_arr()? {
            let meta = ArtifactMeta {
                name: e.str_of("name")?.to_string(),
                path: root.join(e.str_of("path")?),
                inputs: parse_ios(e.req("inputs")?)?,
                outputs: parse_ios(e.req("outputs")?)?,
            };
            by_name.insert(meta.name.clone(), meta);
        }

        let rl = match man.get("rl") {
            Some(rl) => Some(parse_rl(rl)?),
            None => None,
        };

        let mut models = HashMap::new();
        if let Some(Json::Obj(pairs)) = man.get("models") {
            for (name, m) in pairs {
                models.insert(name.clone(), parse_model(name, m, &root)?);
            }
        }

        Ok(ArtifactStore {
            root,
            runtime,
            by_name,
            rl,
            models,
        })
    }

    pub fn runtime(&self) -> &Runtime {
        &self.runtime
    }

    pub fn names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.by_name.keys().map(|s| s.as_str()).collect();
        v.sort();
        v
    }

    pub fn meta(&self, name: &str) -> Result<&ArtifactMeta> {
        self.by_name
            .get(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not in manifest (have {})", self.by_name.len()))
    }

    pub fn has(&self, name: &str) -> bool {
        self.by_name.contains_key(name)
    }

    /// Load + compile (memoized) an artifact by manifest name.
    pub fn load(&self, name: &str) -> Result<Arc<Executable>> {
        let meta = self.meta(name)?;
        self.runtime.load(&meta.path)
    }

    pub fn rl(&self) -> Result<&RlMeta> {
        self.rl
            .as_ref()
            .ok_or_else(|| anyhow!("manifest has no RL metadata — run `make artifacts-rl`"))
    }

    pub fn model(&self, name: &str) -> Result<&ModelMeta> {
        self.models.get(name).ok_or_else(|| {
            anyhow!("model '{name}' not in manifest — run `make artifacts-models`")
        })
    }

    pub fn model_names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.models.keys().map(|s| s.as_str()).collect();
        v.sort();
        v
    }

    /// Load a model's flat weight vector.
    pub fn model_weights(&self, name: &str) -> Result<Vec<f32>> {
        let m = self.model(name)?;
        load_f32_bin(&m.weights, m.weights_size)
    }

    /// Load the AE weights for (model, point).
    pub fn ae_weights(&self, model: &str, point: usize) -> Result<Vec<f32>> {
        let m = self.model(model)?;
        let p = m
            .points
            .iter()
            .find(|p| p.point == point)
            .ok_or_else(|| anyhow!("model '{model}' has no point {point}"))?;
        load_f32_bin(&p.ae_weights, p.ae_weights_size)
    }

    /// The update minibatch sizes available for a given N.
    pub fn update_batches(&self, n_ues: usize) -> Result<Vec<usize>> {
        let rl = self.rl()?;
        Ok(rl
            .update_batches
            .get(&n_ues)
            .cloned()
            .unwrap_or_else(|| vec![rl.default_update_batch]))
    }
}

fn parse_ios(j: &Json) -> Result<Vec<IoSpec>> {
    j.as_arr()?
        .iter()
        .map(|io| {
            Ok(IoSpec {
                name: io.str_of("name")?.to_string(),
                shape: io
                    .req("shape")?
                    .as_arr()?
                    .iter()
                    .map(|d| d.as_usize())
                    .collect::<Result<_>>()?,
                dtype: io
                    .get("dtype")
                    .and_then(|d| d.as_str().ok())
                    .unwrap_or("f32")
                    .to_string(),
            })
        })
        .collect()
}

fn parse_rl(j: &Json) -> Result<RlMeta> {
    let n_range = j
        .req("n_range")?
        .as_arr()?
        .iter()
        .map(|x| x.as_usize())
        .collect::<Result<Vec<_>>>()?;
    let mut actor_size = HashMap::new();
    let mut critic_size = HashMap::new();
    if let Json::Obj(pairs) = j.req("specs")? {
        for (k, v) in pairs {
            let n: usize = k.parse()?;
            actor_size.insert(n, v.usize_of("actor_size")?);
            critic_size.insert(n, v.usize_of("critic_size")?);
        }
    }
    let mut update_batches = HashMap::new();
    let mut default_update_batch = 256;
    if let Some(Json::Obj(pairs)) = j.get("update_batches") {
        for (k, v) in pairs {
            if k == "default" {
                default_update_batch = v.as_arr()?[0].as_usize()?;
            } else {
                update_batches.insert(
                    k.parse()?,
                    v.as_arr()?
                        .iter()
                        .map(|x| x.as_usize())
                        .collect::<Result<Vec<_>>>()?,
                );
            }
        }
    }
    Ok(RlMeta {
        n_range,
        n_partition: j.usize_of("n_partition")?,
        n_channels: j.usize_of("n_channels")?,
        actor_size,
        critic_size,
        update_batches,
        default_update_batch,
    })
}

fn parse_model(name: &str, m: &Json, root: &Path) -> Result<ModelMeta> {
    let points = m
        .req("points")?
        .as_arr()?
        .iter()
        .map(|p| {
            Ok(PointMeta {
                point: p.usize_of("point")?,
                ch: p.usize_of("ch")?,
                h: p.usize_of("h")?,
                w: p.usize_of("w")?,
                ch_r: p.usize_of("ch_r")?,
                bits: p.usize_of("bits")?,
                rate: p.f64_of("rate")?,
                ae_weights: root.join(p.str_of("ae_weights")?),
                ae_weights_size: p.usize_of("ae_weights_size")?,
            })
        })
        .collect::<Result<Vec<_>>>()?;
    Ok(ModelMeta {
        name: name.to_string(),
        weights: root.join(m.str_of("weights")?),
        weights_size: m.usize_of("weights_size")?,
        input_hw: m.usize_of("input_hw")?,
        num_classes: m.usize_of("num_classes")?,
        base_acc: m.f64_of("base_acc")?,
        points,
    })
}
