//! The artifact store: the `artifacts/manifest.json` index over everything
//! the compile path produced — artifact I/O signatures, network parameter
//! layouts, trained weight files and model metadata — plus the executable
//! cache over the selected [`Backend`].
//!
//! Offline-first: when no manifest exists and the native backend is
//! selected, the store synthesizes the built-in RL demo manifest (the same
//! layouts `python/compile/aot.py` would emit, computed by
//! [`crate::runtime::spec`]), so training and the quickstart run with zero
//! generated files.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, bail, Result};

use super::backend::{default_backend, Backend, Executable};
use super::native::NativeBackend;
use super::spec::{actor_layout, critic_layout, parse_spec, spec_size, SpecEntry};
use super::tensor::load_f32_bin;
use crate::util::json::Json;

// Paper-scale RL artifact matrix — keep in sync with python/compile/aot.py.
const N_RANGE: std::ops::RangeInclusive<usize> = 3..=10;
const N_FULL: usize = 5;
const UPDATE_BATCHES_FULL: [usize; 3] = [128, 256, 512];
const UPDATE_BATCH: usize = 256;
/// Forward (serving/rollout) batch sizes compiled per network. B = 1 is
/// the classic serving artifact; the larger rows serve the vectorized
/// rollout engine (`rl::rollout`), which stacks one state per env lane.
const FWD_BATCHES: [usize; 6] = [1, 2, 4, 8, 16, 32];
const N_PARTITION: usize = 6;
const N_CHANNELS: usize = 2;

/// One tensor in an artifact signature.
#[derive(Debug, Clone)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String, // "f32" | "i32"
}

impl IoSpec {
    fn f32(name: &str, shape: &[usize]) -> IoSpec {
        IoSpec {
            name: name.to_string(),
            shape: shape.to_vec(),
            dtype: "f32".into(),
        }
    }

    fn i32(name: &str, shape: &[usize]) -> IoSpec {
        IoSpec {
            name: name.to_string(),
            shape: shape.to_vec(),
            dtype: "i32".into(),
        }
    }
}

/// One AOT-compiled artifact (HLO module on the PJRT backend, interpreted
/// program on the native backend).
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub name: String,
    pub path: PathBuf,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
    /// Flat-parameter layout for network artifacts (from `rl.specs`); the
    /// native backend executes from it.
    pub spec: Option<Arc<Vec<SpecEntry>>>,
    /// Quantization bit-width for AE encode/decode artifacts (from the
    /// `models` section).
    pub bits: Option<usize>,
}

/// Per-N RL metadata (parameter layouts and vector sizes).
#[derive(Debug, Clone)]
pub struct RlMeta {
    pub n_range: Vec<usize>,
    pub n_partition: usize,
    pub n_channels: usize,
    pub actor_size: HashMap<usize, usize>,
    pub critic_size: HashMap<usize, usize>,
    pub actor_spec: HashMap<usize, Arc<Vec<SpecEntry>>>,
    pub critic_spec: HashMap<usize, Arc<Vec<SpecEntry>>>,
    pub update_batches: HashMap<usize, Vec<usize>>,
    pub default_update_batch: usize,
    /// Batch sizes the forward artifacts were compiled for (always
    /// contains 1). Shared across all N.
    pub fwd_batches: Vec<usize>,
}

/// One partition point of a trained backbone.
#[derive(Debug, Clone)]
pub struct PointMeta {
    pub point: usize,
    pub ch: usize,
    pub h: usize,
    pub w: usize,
    pub ch_r: usize,
    pub bits: usize,
    pub rate: f64,
    pub ae_weights: PathBuf,
    pub ae_weights_size: usize,
}

/// A trained demo-scale backbone with its AE compressors.
#[derive(Debug, Clone)]
pub struct ModelMeta {
    pub name: String,
    pub weights: PathBuf,
    pub weights_size: usize,
    pub input_hw: usize,
    pub num_classes: usize,
    pub base_acc: f64,
    pub points: Vec<PointMeta>,
}

pub struct ArtifactStore {
    pub root: PathBuf,
    backend: Arc<dyn Backend>,
    by_name: HashMap<String, ArtifactMeta>,
    exe_cache: Mutex<HashMap<String, Arc<dyn Executable>>>,
    rl: Option<RlMeta>,
    models: HashMap<String, ModelMeta>,
}

impl ArtifactStore {
    /// Open `root/manifest.json` on the process-default backend
    /// (`MACCI_BACKEND`, native unless overridden). Without a manifest the
    /// native backend falls back to the built-in RL demo manifest.
    pub fn open(root: impl AsRef<Path>) -> Result<ArtifactStore> {
        Self::with_backend(root, default_backend()?)
    }

    /// Open on an explicit backend.
    pub fn with_backend(root: impl AsRef<Path>, backend: Arc<dyn Backend>) -> Result<ArtifactStore> {
        let root = root.as_ref().to_path_buf();
        let manifest_path = root.join("manifest.json");
        if !manifest_path.exists() {
            if backend.name() == "native" {
                log::info!(
                    "no manifest at {} — using the built-in native RL demo manifest",
                    manifest_path.display()
                );
                return Ok(Self::native_manifest(root, backend));
            }
            bail!(
                "no manifest at {} — run `make artifacts` first (the native backend \
                 synthesizes a demo manifest automatically)",
                manifest_path.display()
            );
        }
        let man = Json::parse_file(&manifest_path)?;

        let mut by_name = HashMap::new();
        for e in man.req("artifacts")?.as_arr()? {
            let meta = ArtifactMeta {
                name: e.str_of("name")?.to_string(),
                path: root.join(e.str_of("path")?),
                inputs: parse_ios(e.req("inputs")?)?,
                outputs: parse_ios(e.req("outputs")?)?,
                spec: None,
                // AE entries carry their quantization width directly
                // (aot.py stamps it); older manifests get it backfilled
                // from the models section below
                bits: e.get("bits").and_then(|b| b.as_usize().ok()),
            };
            by_name.insert(meta.name.clone(), meta);
        }

        let rl = match man.get("rl") {
            Some(rl) => Some(parse_rl(rl)?),
            None => None,
        };

        let mut models = HashMap::new();
        if let Some(Json::Obj(pairs)) = man.get("models") {
            for (name, m) in pairs {
                models.insert(name.clone(), parse_model(name, m, &root)?);
            }
        }

        // Attach parameter layouts to the RL artifacts and bit-widths to
        // the AE artifacts so a backend can execute them without re-reading
        // the manifest.
        if let Some(rl) = &rl {
            for (name, meta) in by_name.iter_mut() {
                let specs = if name.starts_with("actor_") {
                    &rl.actor_spec
                } else if name.starts_with("critic_") {
                    &rl.critic_spec
                } else {
                    continue;
                };
                if let Some(n) = parse_n_ues(name) {
                    meta.spec = specs.get(&n).cloned();
                }
            }
        }
        for m in models.values() {
            for p in &m.points {
                for kind in ["enc", "dec"] {
                    let key = format!("{}_ae_{kind}_p{}", m.name, p.point);
                    if let Some(meta) = by_name.get_mut(&key) {
                        meta.bits.get_or_insert(p.bits);
                    }
                }
            }
        }

        Ok(ArtifactStore {
            root,
            backend,
            by_name,
            exe_cache: Mutex::new(HashMap::new()),
            rl,
            models,
        })
    }

    /// The built-in RL-only store on the native backend: the same artifact
    /// matrix `python/compile/aot.py --rl-only` emits, with layouts
    /// synthesized by [`crate::runtime::spec`]. Needs no files on disk.
    pub fn native_demo() -> ArtifactStore {
        Self::native_manifest(PathBuf::from("artifacts"), Arc::new(NativeBackend::new()))
    }

    fn native_manifest(root: PathBuf, backend: Arc<dyn Backend>) -> ArtifactStore {
        let mut by_name = HashMap::new();
        let mut rl = RlMeta {
            n_range: N_RANGE.collect(),
            n_partition: N_PARTITION,
            n_channels: N_CHANNELS,
            actor_size: HashMap::new(),
            critic_size: HashMap::new(),
            actor_spec: HashMap::new(),
            critic_spec: HashMap::new(),
            update_batches: HashMap::new(),
            default_update_batch: UPDATE_BATCH,
            fwd_batches: FWD_BATCHES.to_vec(),
        };
        rl.update_batches
            .insert(N_FULL, UPDATE_BATCHES_FULL.to_vec());

        for n in N_RANGE {
            let aspec = Arc::new(actor_layout(n, N_PARTITION, N_CHANNELS));
            let cspec = Arc::new(critic_layout(n));
            let (ap, cp) = (spec_size(&aspec), spec_size(&cspec));
            let d = 4 * n;
            rl.actor_size.insert(n, ap);
            rl.critic_size.insert(n, cp);
            rl.actor_spec.insert(n, aspec.clone());
            rl.critic_spec.insert(n, cspec.clone());

            let mut add = |name: String, inputs: Vec<IoSpec>, outputs: Vec<IoSpec>, spec: &Arc<Vec<SpecEntry>>| {
                by_name.insert(
                    name.clone(),
                    ArtifactMeta {
                        path: PathBuf::from(format!("native:{name}")),
                        name,
                        inputs,
                        outputs,
                        spec: Some(spec.clone()),
                        bits: None,
                    },
                );
            };

            for &b in &FWD_BATCHES {
                add(
                    format!("actor_fwd_n{n}_b{b}"),
                    vec![IoSpec::f32("params", &[ap]), IoSpec::f32("state", &[b, d])],
                    vec![
                        IoSpec::f32("probs_b", &[b, N_PARTITION]),
                        IoSpec::f32("probs_c", &[b, N_CHANNELS]),
                        IoSpec::f32("mu", &[b, 1]),
                        IoSpec::f32("log_std", &[b, 1]),
                    ],
                    &aspec,
                );
                add(
                    format!("critic_fwd_n{n}_b{b}"),
                    vec![IoSpec::f32("params", &[cp]), IoSpec::f32("state", &[b, d])],
                    vec![IoSpec::f32("value", &[b, 1])],
                    &cspec,
                );
            }

            let batches: &[usize] = if n == N_FULL {
                &UPDATE_BATCHES_FULL
            } else {
                &[UPDATE_BATCH]
            };
            for &b in batches {
                add(
                    format!("actor_update_n{n}_b{b}"),
                    vec![
                        IoSpec::f32("params", &[ap]),
                        IoSpec::f32("m", &[ap]),
                        IoSpec::f32("v", &[ap]),
                        IoSpec::f32("t", &[]),
                        IoSpec::f32("lr", &[]),
                        IoSpec::f32("state", &[b, d]),
                        IoSpec::i32("a_b", &[b]),
                        IoSpec::i32("a_c", &[b]),
                        IoSpec::f32("a_p", &[b]),
                        IoSpec::f32("old_logp", &[b]),
                        IoSpec::f32("adv", &[b]),
                    ],
                    vec![
                        IoSpec::f32("params", &[ap]),
                        IoSpec::f32("m", &[ap]),
                        IoSpec::f32("v", &[ap]),
                        IoSpec::f32("loss", &[]),
                        IoSpec::f32("entropy", &[]),
                        IoSpec::f32("clip_frac", &[]),
                    ],
                    &aspec,
                );
                add(
                    format!("critic_update_n{n}_b{b}"),
                    vec![
                        IoSpec::f32("params", &[cp]),
                        IoSpec::f32("m", &[cp]),
                        IoSpec::f32("v", &[cp]),
                        IoSpec::f32("t", &[]),
                        IoSpec::f32("lr", &[]),
                        IoSpec::f32("state", &[b, d]),
                        IoSpec::f32("returns", &[b]),
                    ],
                    vec![
                        IoSpec::f32("params", &[cp]),
                        IoSpec::f32("m", &[cp]),
                        IoSpec::f32("v", &[cp]),
                        IoSpec::f32("loss", &[]),
                    ],
                    &cspec,
                );
            }
        }

        ArtifactStore {
            root,
            backend,
            by_name,
            exe_cache: Mutex::new(HashMap::new()),
            rl: Some(rl),
            models: HashMap::new(),
        }
    }

    /// The backend this store executes on.
    pub fn backend(&self) -> &dyn Backend {
        self.backend.as_ref()
    }

    /// Short backend identifier ("native", "xla-pjrt", ...).
    pub fn backend_name(&self) -> &str {
        self.backend.name()
    }

    pub fn names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.by_name.keys().map(|s| s.as_str()).collect();
        v.sort();
        v
    }

    pub fn meta(&self, name: &str) -> Result<&ArtifactMeta> {
        self.by_name
            .get(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not in manifest (have {})", self.by_name.len()))
    }

    pub fn has(&self, name: &str) -> bool {
        self.by_name.contains_key(name)
    }

    /// Load (memoized) an artifact by manifest name on this store's backend.
    pub fn load(&self, name: &str) -> Result<Arc<dyn Executable>> {
        if let Some(exe) = self.exe_cache.lock().unwrap().get(name) {
            return Ok(exe.clone());
        }
        let meta = self.meta(name)?;
        let exe = self.backend.load(meta)?;
        self.exe_cache
            .lock()
            .unwrap()
            .insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Number of distinct executables loaded so far.
    pub fn loaded_len(&self) -> usize {
        self.exe_cache.lock().unwrap().len()
    }

    pub fn rl(&self) -> Result<&RlMeta> {
        self.rl
            .as_ref()
            .ok_or_else(|| anyhow!("manifest has no RL metadata — run `make artifacts-rl`"))
    }

    pub fn model(&self, name: &str) -> Result<&ModelMeta> {
        self.models.get(name).ok_or_else(|| {
            anyhow!("model '{name}' not in manifest — run `make artifacts-models`")
        })
    }

    pub fn model_names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.models.keys().map(|s| s.as_str()).collect();
        v.sort();
        v
    }

    /// Load a model's flat weight vector.
    pub fn model_weights(&self, name: &str) -> Result<Vec<f32>> {
        let m = self.model(name)?;
        load_f32_bin(&m.weights, m.weights_size)
    }

    /// Load the AE weights for (model, point).
    pub fn ae_weights(&self, model: &str, point: usize) -> Result<Vec<f32>> {
        let m = self.model(model)?;
        let p = m
            .points
            .iter()
            .find(|p| p.point == point)
            .ok_or_else(|| anyhow!("model '{model}' has no point {point}"))?;
        load_f32_bin(&p.ae_weights, p.ae_weights_size)
    }

    /// The update minibatch sizes available for a given N.
    pub fn update_batches(&self, n_ues: usize) -> Result<Vec<usize>> {
        let rl = self.rl()?;
        Ok(rl
            .update_batches
            .get(&n_ues)
            .cloned()
            .unwrap_or_else(|| vec![rl.default_update_batch]))
    }

    /// The forward (serving/rollout) batch sizes compiled for a given N —
    /// only batches whose actor AND critic forward artifacts both exist in
    /// this manifest (a partially-pruned manifest degrades to the per-row
    /// fallback instead of failing net construction). Old manifests
    /// without batched forwards yield [1].
    pub fn fwd_batches(&self, n_ues: usize) -> Result<Vec<usize>> {
        let rl = self.rl()?;
        Ok(rl
            .fwd_batches
            .iter()
            .copied()
            .filter(|b| {
                self.has(&format!("actor_fwd_n{n_ues}_b{b}"))
                    && self.has(&format!("critic_fwd_n{n_ues}_b{b}"))
            })
            .collect())
    }
}

/// Extract N from artifact names shaped `..._n{N}_b{B}` / `..._n{N}_...`.
fn parse_n_ues(name: &str) -> Option<usize> {
    for part in name.split('_') {
        if let Some(digits) = part.strip_prefix('n') {
            if !digits.is_empty() && digits.bytes().all(|b| b.is_ascii_digit()) {
                return digits.parse().ok();
            }
        }
    }
    None
}

fn parse_ios(j: &Json) -> Result<Vec<IoSpec>> {
    j.as_arr()?
        .iter()
        .map(|io| {
            Ok(IoSpec {
                name: io.str_of("name")?.to_string(),
                shape: io
                    .req("shape")?
                    .as_arr()?
                    .iter()
                    .map(|d| d.as_usize())
                    .collect::<Result<_>>()?,
                dtype: io
                    .get("dtype")
                    .and_then(|d| d.as_str().ok())
                    .unwrap_or("f32")
                    .to_string(),
            })
        })
        .collect()
}

fn parse_rl(j: &Json) -> Result<RlMeta> {
    let n_range = j
        .req("n_range")?
        .as_arr()?
        .iter()
        .map(|x| x.as_usize())
        .collect::<Result<Vec<_>>>()?;
    let mut actor_size = HashMap::new();
    let mut critic_size = HashMap::new();
    let mut actor_spec = HashMap::new();
    let mut critic_spec = HashMap::new();
    if let Json::Obj(pairs) = j.req("specs")? {
        for (k, v) in pairs {
            let n: usize = k.parse()?;
            actor_size.insert(n, v.usize_of("actor_size")?);
            critic_size.insert(n, v.usize_of("critic_size")?);
            if let Some(a) = v.get("actor") {
                actor_spec.insert(n, Arc::new(parse_spec(a)?));
            }
            if let Some(c) = v.get("critic") {
                critic_spec.insert(n, Arc::new(parse_spec(c)?));
            }
        }
    }
    let fwd_batches = match j.get("fwd_batches") {
        Some(v) => v
            .as_arr()?
            .iter()
            .map(|x| x.as_usize())
            .collect::<Result<Vec<_>>>()?,
        // pre-rollout-engine manifests only compiled the B = 1 forwards
        None => vec![1],
    };
    let mut update_batches = HashMap::new();
    let mut default_update_batch = 256;
    if let Some(Json::Obj(pairs)) = j.get("update_batches") {
        for (k, v) in pairs {
            if k == "default" {
                default_update_batch = v.as_arr()?[0].as_usize()?;
            } else {
                update_batches.insert(
                    k.parse()?,
                    v.as_arr()?
                        .iter()
                        .map(|x| x.as_usize())
                        .collect::<Result<Vec<_>>>()?,
                );
            }
        }
    }
    Ok(RlMeta {
        n_range,
        n_partition: j.usize_of("n_partition")?,
        n_channels: j.usize_of("n_channels")?,
        actor_size,
        critic_size,
        actor_spec,
        critic_spec,
        update_batches,
        default_update_batch,
        fwd_batches,
    })
}

fn parse_model(name: &str, m: &Json, root: &Path) -> Result<ModelMeta> {
    let points = m
        .req("points")?
        .as_arr()?
        .iter()
        .map(|p| {
            Ok(PointMeta {
                point: p.usize_of("point")?,
                ch: p.usize_of("ch")?,
                h: p.usize_of("h")?,
                w: p.usize_of("w")?,
                ch_r: p.usize_of("ch_r")?,
                bits: p.usize_of("bits")?,
                rate: p.f64_of("rate")?,
                ae_weights: root.join(p.str_of("ae_weights")?),
                ae_weights_size: p.usize_of("ae_weights_size")?,
            })
        })
        .collect::<Result<Vec<_>>>()?;
    Ok(ModelMeta {
        name: name.to_string(),
        weights: root.join(m.str_of("weights")?),
        weights_size: m.usize_of("weights_size")?,
        input_hw: m.usize_of("input_hw")?,
        num_classes: m.usize_of("num_classes")?,
        base_acc: m.f64_of("base_acc")?,
        points,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_demo_manifest_covers_paper_range() {
        let store = ArtifactStore::native_demo();
        assert_eq!(store.backend_name(), "native");
        let rl = store.rl().unwrap();
        assert_eq!(rl.n_range, (3..=10).collect::<Vec<_>>());
        assert_eq!(rl.n_partition, 6);
        assert_eq!(rl.n_channels, 2);
        for n in 3..=10usize {
            assert!(store.has(&format!("actor_fwd_n{n}_b1")));
            assert!(store.has(&format!("critic_update_n{n}_b256")));
        }
        assert!(store.has("actor_update_n5_b512"));
        assert!(!store.has("actor_update_n3_b512"));
        let batches = store.update_batches(5).unwrap();
        assert_eq!(batches, vec![128, 256, 512]);
        assert_eq!(store.update_batches(7).unwrap(), vec![256]);
    }

    #[test]
    fn native_demo_manifest_has_batched_forwards() {
        let store = ArtifactStore::native_demo();
        assert_eq!(store.fwd_batches(5).unwrap(), vec![1, 2, 4, 8, 16, 32]);
        for n in [3usize, 5, 10] {
            for b in [1usize, 4, 32] {
                let name = format!("critic_fwd_n{n}_b{b}");
                let meta = store.meta(&name).unwrap();
                assert_eq!(meta.inputs[1].shape, vec![b, 4 * n]);
                assert!(store.has(&format!("actor_fwd_n{n}_b{b}")));
            }
        }
        assert!(!store.has("actor_fwd_n5_b3"));
    }

    #[test]
    fn native_demo_artifacts_load_and_cache() {
        let store = ArtifactStore::native_demo();
        assert_eq!(store.loaded_len(), 0);
        let a = store.load("actor_fwd_n3_b1").unwrap();
        let b = store.load("actor_fwd_n3_b1").unwrap();
        assert_eq!(store.loaded_len(), 1);
        assert!(Arc::ptr_eq(&a, &b));
        assert!(store.load("nope").is_err());
    }

    #[test]
    fn n_ues_name_parsing() {
        assert_eq!(parse_n_ues("actor_fwd_n5_b1"), Some(5));
        assert_eq!(parse_n_ues("critic_update_n10_b256"), Some(10));
        assert_eq!(parse_n_ues("resnet18_front_p2"), None);
    }
}
