//! Typed handles over the actor / critic network artifacts.
//!
//! Parameters live in Rust as flat `Vec<f32>` (the artifacts unflatten
//! internally via the manifest layout — see python/compile/common.py). Each
//! handle owns its Adam state and counts update steps; `forward` runs the
//! B=1 serving artifact, `forward_batch` / `value_batch` stack one state
//! per rollout lane through the batch-keyed forward artifacts
//! (`*_fwd_n{N}_b{B}`), and `update` runs the fwd+bwd+Adam artifact for one
//! PPO minibatch. All run on whatever [`crate::runtime::backend::Backend`]
//! the store was opened with.
//!
//! The batched forwards take `&self`: between PPO updates the parameters
//! are frozen, so the rollout engine warms the cached input tensor once
//! ([`ActorNet::warm_cache`]) and then shares the nets read-only across its
//! worker threads.

use std::borrow::Cow;
use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use super::artifacts::ArtifactStore;
use super::backend::Executable;
use super::spec::SpecEntry;
use super::tensor::TensorView;
use crate::util::rng::Rng;

/// Initialize a flat parameter vector from the manifest's layout entries:
/// `w*` weights get fan-in-scaled gaussians, biases zero, `log_std` -0.5 —
/// the same convention as python/compile/common.py `ParamSpec.init`.
pub fn init_params(spec: &[SpecEntry], rng: &mut Rng) -> Vec<f32> {
    let total: usize = spec.iter().map(|e| e.count).sum();
    let mut out = vec![0.0f32; total];
    for e in spec {
        let seg = &mut out[e.offset..e.offset + e.count];
        if e.name.starts_with('w') {
            let fan_in = if e.shape.len() > 1 { e.shape[0] } else { e.count };
            let scale = (1.0 / fan_in.max(1) as f64).sqrt();
            for x in seg.iter_mut() {
                *x = rng.normal_scaled(0.0, scale) as f32;
            }
        } else if e.name.contains("log_std") {
            seg.fill(-0.5);
        }
    }
    out
}

/// Complete learnable state of one network handle: flat parameters, both
/// Adam moment vectors and the step counter. This is the unit the
/// [`crate::rl::checkpoint`] format serializes; restoring it reproduces
/// the net bit-for-bit (`m`/`v` always share `params`' length).
#[derive(Debug, Clone, PartialEq)]
pub struct NetState {
    pub params: Vec<f32>,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    pub t: u64,
}

impl NetState {
    /// Structural sanity: Adam moments must mirror the parameter vector.
    pub fn validate(&self) -> Result<()> {
        if self.m.len() != self.params.len() || self.v.len() != self.params.len() {
            bail!(
                "net state: params {} vs adam moments {}/{}",
                self.params.len(),
                self.m.len(),
                self.v.len()
            );
        }
        Ok(())
    }
}

/// The lazily-built backend-input copy of a net's flat parameter vector.
///
/// Rollouts call the forwards thousands of times between updates; without
/// this cache every call re-copies the ~64 k-float parameter vector into a
/// fresh input tensor (§Perf: −26 % on actor_fwd_b1, measured on the PJRT
/// path; the native backend borrows the cached tensor zero-copy, while the
/// current PJRT `call_refs` re-marshals inputs per call — see DESIGN.md
/// §Perf). Updates invalidate it; `&self` paths fall back to a temporary
/// copy when cold.
#[derive(Default)]
struct ParamCache {
    view: Option<Arc<TensorView>>,
}

impl ParamCache {
    /// Build the cached copy now (no-op when already warm) and hand it
    /// back — callers pass it to [`Executable::warm`] so backends can key
    /// precomputed per-params state (packed GEMM panels / int8 weights) on
    /// the shared buffer.
    fn warm(&mut self, params: &[f32]) -> Result<Arc<TensorView>> {
        if self.view.is_none() {
            self.view = Some(Arc::new(TensorView::f32(
                params.to_vec(),
                vec![params.len()],
            )?));
        }
        Ok(Arc::clone(self.view.as_ref().unwrap()))
    }

    /// Drop the cached copy (the parameters changed). Releasing the `Arc`
    /// also lets backends garbage-collect warmed state keyed on it.
    fn invalidate(&mut self) {
        self.view = None;
    }

    /// Borrow the cached tensor, or marshal a temporary one when cold.
    fn arg<'a>(&'a self, params: &[f32]) -> Result<Cow<'a, TensorView>> {
        Ok(match &self.view {
            Some(v) => Cow::Borrowed(v.as_ref()),
            None => Cow::Owned(TensorView::f32(params.to_vec(), vec![params.len()])?),
        })
    }
}

/// Output of one actor forward (B = 1).
#[derive(Debug, Clone)]
pub struct ActorOutput {
    pub probs_b: Vec<f32>,
    pub probs_c: Vec<f32>,
    pub mu: f32,
    pub log_std: f32,
}

/// Losses/diagnostics from one PPO minibatch step.
#[derive(Debug, Clone, Copy, Default)]
pub struct UpdateStats {
    pub loss: f32,
    pub entropy: f32,
    pub clip_frac: f32,
}

/// Actor network handle: flat params + Adam state + loaded artifacts.
pub struct ActorNet {
    pub n_ues: usize,
    pub params: Vec<f32>,
    m: Vec<f32>,
    v: Vec<f32>,
    t: u64,
    fwd: Arc<dyn Executable>,
    /// Batched forward artifacts by row count (B > 1); rollout lanes stack
    /// one state per row. Missing row counts fall back to B=1 calls.
    fwd_batch: HashMap<usize, Arc<dyn Executable>>,
    updates: HashMap<usize, Arc<dyn Executable>>, // by minibatch size
    state_dim: usize,
    cache: ParamCache,
    /// Requested PPO update worker count (0 = auto). Scoped around the
    /// update executable call; never changes the trained bits — see
    /// `runtime::native::update`.
    update_threads: usize,
}

impl ActorNet {
    pub fn new(store: &ArtifactStore, n_ues: usize, seed: u64) -> Result<ActorNet> {
        let rl = store.rl()?;
        let size = *rl
            .actor_size
            .get(&n_ues)
            .ok_or_else(|| anyhow!("no actor artifacts for N={n_ues}"))?;
        let fwd = store.load(&format!("actor_fwd_n{n_ues}_b1"))?;
        let mut fwd_batch = HashMap::new();
        for b in store.fwd_batches(n_ues)? {
            if b > 1 {
                fwd_batch.insert(b, store.load(&format!("actor_fwd_n{n_ues}_b{b}"))?);
            }
        }
        let mut updates = HashMap::new();
        for b in store.update_batches(n_ues)? {
            updates.insert(b, store.load(&format!("actor_update_n{n_ues}_b{b}"))?);
        }
        let spec = rl
            .actor_spec
            .get(&n_ues)
            .ok_or_else(|| anyhow!("manifest has no actor layout for N={n_ues}"))?;
        let mut rng = Rng::new(seed);
        let params = init_params(spec, &mut rng);
        debug_assert_eq!(params.len(), size);
        Ok(ActorNet {
            n_ues,
            params,
            m: vec![0.0; size],
            v: vec![0.0; size],
            t: 0,
            fwd,
            fwd_batch,
            updates,
            state_dim: 4 * n_ues,
            cache: ParamCache::default(),
            update_threads: 0,
        })
    }

    /// Request a PPO update worker count (0 = auto: `MACCI_UPDATE_THREADS`,
    /// else the machine's parallelism). Purely a scheduling knob — the
    /// sharded update engine produces bit-identical parameters for any
    /// worker count (`runtime::native::update`).
    pub fn set_update_threads(&mut self, threads: usize) {
        self.update_threads = threads;
    }

    /// Build the cached backend-input copy of `params` now (it is
    /// invalidated by every `update`) and let the forward executables
    /// precompute per-params state for it (packed GEMM panels / int8
    /// weights — see `Executable::warm`). Rollout workers call the `&self`
    /// batched forwards; warming first keeps them from re-marshalling the
    /// parameter vector on every call.
    pub fn warm_cache(&mut self) -> Result<()> {
        let view = self.cache.warm(&self.params)?;
        self.fwd.warm(0, &view)?;
        for exe in self.fwd_batch.values() {
            exe.warm(0, &view)?;
        }
        Ok(())
    }

    fn params_arg(&self) -> Result<Cow<'_, TensorView>> {
        self.cache.arg(&self.params)
    }

    /// Capture the complete learnable state (params + Adam moments + step
    /// counter) for checkpointing.
    pub fn snapshot(&self) -> NetState {
        NetState {
            params: self.params.clone(),
            m: self.m.clone(),
            v: self.v.clone(),
            t: self.t,
        }
    }

    /// Restore a [`NetState`] captured by [`ActorNet::snapshot`] — the net
    /// resumes bit-for-bit (the params cache is invalidated). Rejects
    /// states whose vector lengths do not match this net's layout.
    pub fn restore(&mut self, state: &NetState) -> Result<()> {
        state.validate()?;
        if state.params.len() != self.params.len() {
            bail!(
                "actor state has {} params, net expects {}",
                state.params.len(),
                self.params.len()
            );
        }
        self.params = state.params.clone();
        self.m = state.m.clone();
        self.v = state.v.clone();
        self.t = state.t;
        self.cache.invalidate();
        Ok(())
    }

    /// Overwrite the parameter vector only (hot policy swap at serving
    /// time: Adam state stays untouched, the cache is invalidated).
    pub fn set_params(&mut self, params: &[f32]) -> Result<()> {
        if params.len() != self.params.len() {
            bail!(
                "policy swap has {} params, net expects {}",
                params.len(),
                self.params.len()
            );
        }
        self.params.copy_from_slice(params);
        self.cache.invalidate();
        Ok(())
    }

    fn parse_output(mut outs: Vec<TensorView>) -> Result<ActorOutput> {
        let log_std = outs[3].scalar()?;
        let mu = outs[2].scalar()?;
        let probs_c = std::mem::take(&mut outs[1]).into_f32s()?;
        let probs_b = std::mem::take(&mut outs[0]).into_f32s()?;
        Ok(ActorOutput {
            probs_b,
            probs_c,
            mu,
            log_std,
        })
    }

    /// Policy forward for a single state (B = 1).
    pub fn forward(&mut self, state: &[f32]) -> Result<ActorOutput> {
        let view = self.cache.warm(&self.params)?;
        self.fwd.warm(0, &view)?;
        let state_view = TensorView::f32(state.to_vec(), vec![1, self.state_dim])?;
        let params = self.params_arg()?;
        let outs = self.fwd.call_refs(&[&*params, &state_view])?;
        Self::parse_output(outs)
    }

    /// Uncached forward (perf-pass baseline; rebuilds the params tensor
    /// every call exactly as the pre-optimization hot path did).
    pub fn forward_uncached(&self, state: &[f32]) -> Result<ActorOutput> {
        let outs = self.fwd.call(&[
            TensorView::f32(self.params.clone(), vec![self.params.len()])?,
            TensorView::f32(state.to_vec(), vec![1, self.state_dim])?,
        ])?;
        Self::parse_output(outs)
    }

    /// Policy forward over `rows = states.len() / state_dim` stacked
    /// states — one output per row. Uses the compiled `b{rows}` artifact
    /// when one exists, else falls back to per-row B=1 calls, so any lane
    /// count works on any backend. Per-row results are bit-identical
    /// across batch sizes (the native dense kernel preserves accumulation
    /// order; see `runtime::native::kernels`).
    pub fn forward_batch(&self, states: &[f32]) -> Result<Vec<ActorOutput>> {
        if states.is_empty() || states.len() % self.state_dim != 0 {
            bail!(
                "forward_batch: state length {} not a positive multiple of {}",
                states.len(),
                self.state_dim
            );
        }
        let rows = states.len() / self.state_dim;
        let params = self.params_arg()?;
        if rows == 1 {
            let sv = TensorView::f32(states.to_vec(), vec![1, self.state_dim])?;
            let outs = self.fwd.call_refs(&[&*params, &sv])?;
            return Ok(vec![Self::parse_output(outs)?]);
        }
        if let Some(exe) = self.fwd_batch.get(&rows) {
            let sv = TensorView::f32(states.to_vec(), vec![rows, self.state_dim])?;
            let outs = exe.call_refs(&[&*params, &sv])?;
            return Self::parse_batch(outs, rows);
        }
        (0..rows)
            .map(|r| {
                let row = &states[r * self.state_dim..(r + 1) * self.state_dim];
                let sv = TensorView::f32(row.to_vec(), vec![1, self.state_dim])?;
                let outs = self.fwd.call_refs(&[&*params, &sv])?;
                Self::parse_output(outs)
            })
            .collect()
    }

    fn parse_batch(mut outs: Vec<TensorView>, rows: usize) -> Result<Vec<ActorOutput>> {
        let log_std = std::mem::take(&mut outs[3]).into_f32s()?;
        let mu = std::mem::take(&mut outs[2]).into_f32s()?;
        let pc = std::mem::take(&mut outs[1]).into_f32s()?;
        let pb = std::mem::take(&mut outs[0]).into_f32s()?;
        if mu.len() != rows
            || log_std.len() != rows
            || pb.len() % rows != 0
            || pc.len() % rows != 0
        {
            bail!("actor_fwd batch output shape mismatch for {rows} rows");
        }
        let p = pb.len() / rows;
        let c = pc.len() / rows;
        Ok((0..rows)
            .map(|r| ActorOutput {
                probs_b: pb[r * p..(r + 1) * p].to_vec(),
                probs_c: pc[r * c..(r + 1) * c].to_vec(),
                mu: mu[r],
                log_std: log_std[r],
            })
            .collect())
    }

    /// One PPO-clip + Adam step over a minibatch of size `b`.
    #[allow(clippy::too_many_arguments)]
    pub fn update(
        &mut self,
        lr: f32,
        states: &[f32],
        a_b: &[i32],
        a_c: &[i32],
        a_p: &[f32],
        old_logp: &[f32],
        adv: &[f32],
    ) -> Result<UpdateStats> {
        let b = a_b.len();
        let exe = self
            .updates
            .get(&b)
            .ok_or_else(|| anyhow!("no actor_update artifact for batch {b} (have {:?})", self.updates.keys()))?;
        self.t += 1;
        let n = self.params.len();
        let mut outs = crate::runtime::native::update::with_threads(self.update_threads, || {
            exe.call(&[
                TensorView::f32(self.params.clone(), vec![n])?,
                TensorView::f32(self.m.clone(), vec![n])?,
                TensorView::f32(self.v.clone(), vec![n])?,
                TensorView::from_scalar(self.t as f32),
                TensorView::from_scalar(lr),
                TensorView::f32(states.to_vec(), vec![b, self.state_dim])?,
                TensorView::i32(a_b.to_vec(), vec![b])?,
                TensorView::i32(a_c.to_vec(), vec![b])?,
                TensorView::f32(a_p.to_vec(), vec![b])?,
                TensorView::f32(old_logp.to_vec(), vec![b])?,
                TensorView::f32(adv.to_vec(), vec![b])?,
            ])
        })?;
        self.params = std::mem::take(&mut outs[0]).into_f32s()?;
        self.m = std::mem::take(&mut outs[1]).into_f32s()?;
        self.v = std::mem::take(&mut outs[2]).into_f32s()?;
        self.cache.invalidate(); // cached input copy is stale now
        Ok(UpdateStats {
            loss: outs[3].scalar()?,
            entropy: outs[4].scalar()?,
            clip_frac: outs[5].scalar()?,
        })
    }

    pub fn steps(&self) -> u64 {
        self.t
    }
}

/// Critic network handle.
pub struct CriticNet {
    pub n_ues: usize,
    pub params: Vec<f32>,
    m: Vec<f32>,
    v: Vec<f32>,
    t: u64,
    fwd: Arc<dyn Executable>,
    fwd_batch: HashMap<usize, Arc<dyn Executable>>,
    updates: HashMap<usize, Arc<dyn Executable>>,
    state_dim: usize,
    cache: ParamCache,
    /// See [`ActorNet`]: requested update worker count (0 = auto).
    update_threads: usize,
}

impl CriticNet {
    pub fn new(store: &ArtifactStore, n_ues: usize, seed: u64) -> Result<CriticNet> {
        let rl = store.rl()?;
        let size = *rl
            .critic_size
            .get(&n_ues)
            .ok_or_else(|| anyhow!("no critic artifacts for N={n_ues}"))?;
        let fwd = store.load(&format!("critic_fwd_n{n_ues}_b1"))?;
        let mut fwd_batch = HashMap::new();
        for b in store.fwd_batches(n_ues)? {
            if b > 1 {
                fwd_batch.insert(b, store.load(&format!("critic_fwd_n{n_ues}_b{b}"))?);
            }
        }
        let mut updates = HashMap::new();
        for b in store.update_batches(n_ues)? {
            updates.insert(b, store.load(&format!("critic_update_n{n_ues}_b{b}"))?);
        }
        let spec = rl
            .critic_spec
            .get(&n_ues)
            .ok_or_else(|| anyhow!("manifest has no critic layout for N={n_ues}"))?;
        let mut rng = Rng::new(seed);
        let params = init_params(spec, &mut rng);
        debug_assert_eq!(params.len(), size);
        Ok(CriticNet {
            n_ues,
            params,
            m: vec![0.0; size],
            v: vec![0.0; size],
            t: 0,
            fwd,
            fwd_batch,
            updates,
            state_dim: 4 * n_ues,
            cache: ParamCache::default(),
            update_threads: 0,
        })
    }

    /// See [`ActorNet::set_update_threads`].
    pub fn set_update_threads(&mut self, threads: usize) {
        self.update_threads = threads;
    }

    /// See [`ActorNet::warm_cache`].
    pub fn warm_cache(&mut self) -> Result<()> {
        let view = self.cache.warm(&self.params)?;
        self.fwd.warm(0, &view)?;
        for exe in self.fwd_batch.values() {
            exe.warm(0, &view)?;
        }
        Ok(())
    }

    fn params_arg(&self) -> Result<Cow<'_, TensorView>> {
        self.cache.arg(&self.params)
    }

    /// See [`ActorNet::snapshot`].
    pub fn snapshot(&self) -> NetState {
        NetState {
            params: self.params.clone(),
            m: self.m.clone(),
            v: self.v.clone(),
            t: self.t,
        }
    }

    /// See [`ActorNet::restore`].
    pub fn restore(&mut self, state: &NetState) -> Result<()> {
        state.validate()?;
        if state.params.len() != self.params.len() {
            bail!(
                "critic state has {} params, net expects {}",
                state.params.len(),
                self.params.len()
            );
        }
        self.params = state.params.clone();
        self.m = state.m.clone();
        self.v = state.v.clone();
        self.t = state.t;
        self.cache.invalidate();
        Ok(())
    }

    pub fn steps(&self) -> u64 {
        self.t
    }

    /// V(s) over stacked states — one value per row (see
    /// [`ActorNet::forward_batch`] for artifact selection and fallback).
    pub fn value_batch(&self, states: &[f32]) -> Result<Vec<f32>> {
        if states.is_empty() || states.len() % self.state_dim != 0 {
            bail!(
                "value_batch: state length {} not a positive multiple of {}",
                states.len(),
                self.state_dim
            );
        }
        let rows = states.len() / self.state_dim;
        let params = self.params_arg()?;
        let exe = if rows == 1 {
            &self.fwd
        } else if let Some(exe) = self.fwd_batch.get(&rows) {
            exe
        } else {
            return (0..rows)
                .map(|r| {
                    let row = &states[r * self.state_dim..(r + 1) * self.state_dim];
                    let sv = TensorView::f32(row.to_vec(), vec![1, self.state_dim])?;
                    let outs = self.fwd.call_refs(&[&*params, &sv])?;
                    outs[0].scalar()
                })
                .collect();
        };
        let sv = TensorView::f32(states.to_vec(), vec![rows, self.state_dim])?;
        let mut outs = exe.call_refs(&[&*params, &sv])?;
        let values = std::mem::take(&mut outs[0]).into_f32s()?;
        if values.len() != rows {
            bail!("critic_fwd returned {} values for {rows} rows", values.len());
        }
        Ok(values)
    }

    /// V(s) for a single state.
    pub fn value(&mut self, state: &[f32]) -> Result<f32> {
        let view = self.cache.warm(&self.params)?;
        self.fwd.warm(0, &view)?;
        let state_view = TensorView::f32(state.to_vec(), vec![1, self.state_dim])?;
        let params = self.params_arg()?;
        let outs = self.fwd.call_refs(&[&*params, &state_view])?;
        outs[0].scalar()
    }

    /// One MSE + Adam step toward the sampled returns (Eq. 16).
    pub fn update(&mut self, lr: f32, states: &[f32], returns: &[f32]) -> Result<f32> {
        let b = returns.len();
        let exe = self
            .updates
            .get(&b)
            .ok_or_else(|| anyhow!("no critic_update artifact for batch {b}"))?;
        self.t += 1;
        let n = self.params.len();
        let mut outs = crate::runtime::native::update::with_threads(self.update_threads, || {
            exe.call(&[
                TensorView::f32(self.params.clone(), vec![n])?,
                TensorView::f32(self.m.clone(), vec![n])?,
                TensorView::f32(self.v.clone(), vec![n])?,
                TensorView::from_scalar(self.t as f32),
                TensorView::from_scalar(lr),
                TensorView::f32(states.to_vec(), vec![b, self.state_dim])?,
                TensorView::f32(returns.to_vec(), vec![b])?,
            ])
        })?;
        self.params = std::mem::take(&mut outs[0]).into_f32s()?;
        self.m = std::mem::take(&mut outs[1]).into_f32s()?;
        self.v = std::mem::take(&mut outs[2]).into_f32s()?;
        self.cache.invalidate();
        outs[3].scalar()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_respects_layout_conventions() {
        let spec = crate::runtime::spec::actor_layout(3, 6, 2);
        let mut rng = Rng::new(9);
        let params = init_params(&spec, &mut rng);
        let ls = crate::runtime::spec::spec_entry(&spec, "b_p1_log_std").unwrap();
        assert_eq!(params[ls.offset], -0.5);
        let b_t0 = crate::runtime::spec::spec_entry(&spec, "b_t0").unwrap();
        assert!(params[b_t0.offset..b_t0.offset + b_t0.count]
            .iter()
            .all(|&x| x == 0.0));
        let w_t0 = crate::runtime::spec::spec_entry(&spec, "w_t0").unwrap();
        assert!(params[w_t0.offset..w_t0.offset + w_t0.count]
            .iter()
            .any(|&x| x != 0.0));
    }

    #[test]
    fn batched_forwards_match_single_rows_bitwise() {
        let store = crate::runtime::artifacts::ArtifactStore::native_demo();
        let n = 3;
        let d = 4 * n;
        let mut actor = ActorNet::new(&store, n, 11).unwrap();
        let mut critic = CriticNet::new(&store, n, 12).unwrap();
        actor.warm_cache().unwrap();
        critic.warm_cache().unwrap();
        let mut rng = Rng::new(5);
        // 4 has a compiled artifact, 3 exercises the per-row fallback
        for rows in [1usize, 3, 4] {
            let states: Vec<f32> = (0..rows * d).map(|_| rng.f32()).collect();
            let batch = actor.forward_batch(&states).unwrap();
            let values = critic.value_batch(&states).unwrap();
            assert_eq!(batch.len(), rows);
            assert_eq!(values.len(), rows);
            for r in 0..rows {
                let row = &states[r * d..(r + 1) * d];
                let single = actor.forward(row).unwrap();
                assert_eq!(batch[r].probs_b, single.probs_b, "rows={rows} r={r}");
                assert_eq!(batch[r].probs_c, single.probs_c);
                assert_eq!(batch[r].mu, single.mu);
                assert_eq!(batch[r].log_std, single.log_std);
                assert_eq!(values[r], critic.value(row).unwrap());
            }
        }
        // stale-cache path: after an invalidation the &self forwards still
        // produce the same results via a temporary params tensor
        actor.cache.invalidate();
        let states: Vec<f32> = (0..4 * d).map(|_| rng.f32()).collect();
        let cold = actor.forward_batch(&states).unwrap();
        actor.warm_cache().unwrap();
        let warm = actor.forward_batch(&states).unwrap();
        assert_eq!(cold[2].probs_b, warm[2].probs_b);
    }

    #[test]
    fn snapshot_restore_roundtrips_bitwise() {
        let store = crate::runtime::artifacts::ArtifactStore::native_demo();
        let mut a = ActorNet::new(&store, 3, 11).unwrap();
        let mut b = ActorNet::new(&store, 3, 99).unwrap();
        // push `a` off its init point so Adam moments are non-trivial
        let batch = 256;
        let mut rng = Rng::new(4);
        let states: Vec<f32> = (0..batch * 12).map(|_| rng.f32()).collect();
        let ab: Vec<i32> = (0..batch).map(|_| (rng.below(6)) as i32).collect();
        let ac: Vec<i32> = (0..batch).map(|_| (rng.below(2)) as i32).collect();
        let ap: Vec<f32> = (0..batch).map(|_| rng.f32()).collect();
        let lp: Vec<f32> = (0..batch).map(|_| -rng.f32()).collect();
        let adv: Vec<f32> = (0..batch).map(|_| rng.f32() - 0.5).collect();
        a.update(1e-3, &states, &ab, &ac, &ap, &lp, &adv).unwrap();
        let snap = a.snapshot();
        b.restore(&snap).unwrap();
        assert_eq!(b.snapshot(), snap, "restore must be bit-exact");
        assert_eq!(b.steps(), a.steps());
        let s = &states[..12];
        let (fa, fb) = (a.forward(s).unwrap(), b.forward(s).unwrap());
        assert_eq!(fa.probs_b, fb.probs_b);
        assert_eq!(fa.mu, fb.mu);
        // params-only swap keeps Adam state but changes the policy
        let mut c = ActorNet::new(&store, 3, 5).unwrap();
        c.set_params(&snap.params).unwrap();
        assert_eq!(c.forward(s).unwrap().probs_b, fa.probs_b);
        assert!(c.set_params(&[0.0; 3]).is_err(), "length mismatch rejected");
        let mut bad = snap.clone();
        bad.m.pop();
        assert!(b.restore(&bad).is_err(), "inconsistent adam state rejected");
    }
}
