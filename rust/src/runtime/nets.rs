//! Typed handles over the actor / critic network artifacts.
//!
//! Parameters live in Rust as flat `Vec<f32>` (the artifacts unflatten
//! internally — see python/compile/common.py). Each handle owns its Adam
//! state and counts update steps; `forward` runs the B=1 serving artifact,
//! `update` runs the fwd+bwd+Adam artifact for one PPO minibatch.

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{anyhow, Result};

use super::artifacts::ArtifactStore;
use super::client::Executable;
use super::tensor::{f32_literal, i32_literal, scalar_literal};
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Initialize a flat parameter vector from the manifest's layout entries:
/// `w*` weights get fan-in-scaled gaussians, biases zero, `log_std` -0.5 —
/// the same convention as python/compile/common.py `ParamSpec.init`.
pub fn init_params(spec: &[SpecEntry], rng: &mut Rng) -> Vec<f32> {
    let total: usize = spec.iter().map(|e| e.count).sum();
    let mut out = vec![0.0f32; total];
    for e in spec {
        let seg = &mut out[e.offset..e.offset + e.count];
        if e.name.starts_with('w') {
            let fan_in = if e.shape.len() > 1 { e.shape[0] } else { e.count };
            let scale = (1.0 / fan_in.max(1) as f64).sqrt();
            for x in seg.iter_mut() {
                *x = rng.normal_scaled(0.0, scale) as f32;
            }
        } else if e.name.contains("log_std") {
            seg.fill(-0.5);
        }
    }
    out
}

/// One entry of a network's flat-parameter layout.
#[derive(Debug, Clone)]
pub struct SpecEntry {
    pub name: String,
    pub offset: usize,
    pub count: usize,
    pub shape: Vec<usize>,
}

pub fn parse_spec(j: &Json) -> Result<Vec<SpecEntry>> {
    j.as_arr()?
        .iter()
        .map(|e| {
            Ok(SpecEntry {
                name: e.str_of("name")?.to_string(),
                offset: e.usize_of("offset")?,
                count: e.usize_of("count")?,
                shape: e
                    .req("shape")?
                    .as_arr()?
                    .iter()
                    .map(|d| d.as_usize())
                    .collect::<Result<_>>()?,
            })
        })
        .collect()
}

/// Output of one actor forward (B = 1).
#[derive(Debug, Clone)]
pub struct ActorOutput {
    pub probs_b: Vec<f32>,
    pub probs_c: Vec<f32>,
    pub mu: f32,
    pub log_std: f32,
}

/// Losses/diagnostics from one PPO minibatch step.
#[derive(Debug, Clone, Copy, Default)]
pub struct UpdateStats {
    pub loss: f32,
    pub entropy: f32,
    pub clip_frac: f32,
}

/// Actor network handle: flat params + Adam state + compiled artifacts.
pub struct ActorNet {
    pub n_ues: usize,
    pub params: Vec<f32>,
    m: Vec<f32>,
    v: Vec<f32>,
    t: u64,
    fwd: Arc<Executable>,
    updates: HashMap<usize, Arc<Executable>>, // by minibatch size
    state_dim: usize,
    /// Device-format copy of `params`, rebuilt lazily after updates.
    /// Rollouts call `forward` thousands of times between updates; without
    /// this cache every call re-copies the ~64 k-float parameter vector
    /// into a fresh literal (§Perf: −26 % on actor_fwd_b1).
    params_lit: Option<xla::Literal>,
}

// SAFETY: the cached `params_lit` is a standalone host literal (no shared
// Rc state; the raw pointer is uniquely owned by this handle) and every C
// API call that touches it happens inside `Executable::call_refs`, which
// holds the process-wide XLA lock. Moving the handle across threads is
// therefore sound; concurrent &mut access is prevented by the borrow
// checker as usual.
unsafe impl Send for ActorNet {}
unsafe impl Send for CriticNet {}

impl ActorNet {
    pub fn new(store: &ArtifactStore, n_ues: usize, seed: u64) -> Result<ActorNet> {
        let rl = store.rl()?;
        let size = *rl
            .actor_size
            .get(&n_ues)
            .ok_or_else(|| anyhow!("no actor artifacts for N={n_ues}"))?;
        let fwd = store.load(&format!("actor_fwd_n{n_ues}_b1"))?;
        let mut updates = HashMap::new();
        for b in store.update_batches(n_ues)? {
            updates.insert(b, store.load(&format!("actor_update_n{n_ues}_b{b}"))?);
        }
        // layout entries for init come from the manifest (specs.N.actor)
        let man = Json::parse_file(store.root.join("manifest.json"))?;
        let spec = parse_spec(man.req("rl")?.req("specs")?.req(&n_ues.to_string())?.req("actor")?)?;
        let mut rng = Rng::new(seed);
        let params = init_params(&spec, &mut rng);
        debug_assert_eq!(params.len(), size);
        Ok(ActorNet {
            n_ues,
            params,
            m: vec![0.0; size],
            v: vec![0.0; size],
            t: 0,
            fwd,
            updates,
            state_dim: 4 * n_ues,
            params_lit: None,
        })
    }

    /// Policy forward for a single state (B = 1).
    pub fn forward(&mut self, state: &[f32]) -> Result<ActorOutput> {
        if self.params_lit.is_none() {
            self.params_lit = Some(f32_literal(&self.params, &[self.params.len()])?);
        }
        let state_lit = f32_literal(state, &[1, self.state_dim])?;
        let args = [self.params_lit.as_ref().unwrap(), &state_lit];
        let mut outs = self.fwd.call_refs(&args)?;
        let log_std = outs[3].scalar()?;
        let mu = outs[2].scalar()?;
        let probs_c = std::mem::take(&mut outs[1]).into_f32s()?;
        let probs_b = std::mem::take(&mut outs[0]).into_f32s()?;
        Ok(ActorOutput {
            probs_b,
            probs_c,
            mu,
            log_std,
        })
    }

    /// Uncached forward (perf-pass baseline; rebuilds the params literal
    /// every call exactly as the pre-optimization hot path did).
    pub fn forward_uncached(&self, state: &[f32]) -> Result<ActorOutput> {
        let outs = self.fwd.call(&[
            f32_literal(&self.params, &[self.params.len()])?,
            f32_literal(state, &[1, self.state_dim])?,
        ])?;
        Ok(ActorOutput {
            probs_b: outs[0].clone().into_f32s()?,
            probs_c: outs[1].clone().into_f32s()?,
            mu: outs[2].scalar()?,
            log_std: outs[3].scalar()?,
        })
    }

    /// One PPO-clip + Adam step over a minibatch of size `b`.
    #[allow(clippy::too_many_arguments)]
    pub fn update(
        &mut self,
        lr: f32,
        states: &[f32],
        a_b: &[i32],
        a_c: &[i32],
        a_p: &[f32],
        old_logp: &[f32],
        adv: &[f32],
    ) -> Result<UpdateStats> {
        let b = a_b.len();
        let exe = self
            .updates
            .get(&b)
            .ok_or_else(|| anyhow!("no actor_update artifact for batch {b} (have {:?})", self.updates.keys()))?;
        self.t += 1;
        let n = self.params.len();
        let outs = exe.call(&[
            f32_literal(&self.params, &[n])?,
            f32_literal(&self.m, &[n])?,
            f32_literal(&self.v, &[n])?,
            scalar_literal(self.t as f32),
            scalar_literal(lr),
            f32_literal(states, &[b, self.state_dim])?,
            i32_literal(a_b, &[b])?,
            i32_literal(a_c, &[b])?,
            f32_literal(a_p, &[b])?,
            f32_literal(old_logp, &[b])?,
            f32_literal(adv, &[b])?,
        ])?;
        let mut outs = outs;
        self.params = std::mem::take(&mut outs[0]).into_f32s()?;
        self.m = std::mem::take(&mut outs[1]).into_f32s()?;
        self.v = std::mem::take(&mut outs[2]).into_f32s()?;
        self.params_lit = None; // device copy is stale now
        Ok(UpdateStats {
            loss: outs[3].scalar()?,
            entropy: outs[4].scalar()?,
            clip_frac: outs[5].scalar()?,
        })
    }

    pub fn steps(&self) -> u64 {
        self.t
    }
}

/// Critic network handle.
pub struct CriticNet {
    pub n_ues: usize,
    pub params: Vec<f32>,
    m: Vec<f32>,
    v: Vec<f32>,
    t: u64,
    fwd: Arc<Executable>,
    updates: HashMap<usize, Arc<Executable>>,
    state_dim: usize,
    params_lit: Option<xla::Literal>,
}

impl CriticNet {
    pub fn new(store: &ArtifactStore, n_ues: usize, seed: u64) -> Result<CriticNet> {
        let rl = store.rl()?;
        let size = *rl
            .critic_size
            .get(&n_ues)
            .ok_or_else(|| anyhow!("no critic artifacts for N={n_ues}"))?;
        let fwd = store.load(&format!("critic_fwd_n{n_ues}_b1"))?;
        let mut updates = HashMap::new();
        for b in store.update_batches(n_ues)? {
            updates.insert(b, store.load(&format!("critic_update_n{n_ues}_b{b}"))?);
        }
        let man = Json::parse_file(store.root.join("manifest.json"))?;
        let spec = parse_spec(man.req("rl")?.req("specs")?.req(&n_ues.to_string())?.req("critic")?)?;
        let mut rng = Rng::new(seed);
        let params = init_params(&spec, &mut rng);
        debug_assert_eq!(params.len(), size);
        Ok(CriticNet {
            n_ues,
            params,
            m: vec![0.0; size],
            v: vec![0.0; size],
            t: 0,
            fwd,
            updates,
            state_dim: 4 * n_ues,
            params_lit: None,
        })
    }

    /// V(s) for a single state.
    pub fn value(&mut self, state: &[f32]) -> Result<f32> {
        if self.params_lit.is_none() {
            self.params_lit = Some(f32_literal(&self.params, &[self.params.len()])?);
        }
        let state_lit = f32_literal(state, &[1, self.state_dim])?;
        let args = [self.params_lit.as_ref().unwrap(), &state_lit];
        let outs = self.fwd.call_refs(&args)?;
        outs[0].scalar()
    }

    /// One MSE + Adam step toward the sampled returns (Eq. 16).
    pub fn update(&mut self, lr: f32, states: &[f32], returns: &[f32]) -> Result<f32> {
        let b = returns.len();
        let exe = self
            .updates
            .get(&b)
            .ok_or_else(|| anyhow!("no critic_update artifact for batch {b}"))?;
        self.t += 1;
        let n = self.params.len();
        let outs = exe.call(&[
            f32_literal(&self.params, &[n])?,
            f32_literal(&self.m, &[n])?,
            f32_literal(&self.v, &[n])?,
            scalar_literal(self.t as f32),
            scalar_literal(lr),
            f32_literal(states, &[b, self.state_dim])?,
            f32_literal(returns, &[b])?,
        ])?;
        let mut outs = outs;
        self.params = std::mem::take(&mut outs[0]).into_f32s()?;
        self.m = std::mem::take(&mut outs[1]).into_f32s()?;
        self.v = std::mem::take(&mut outs[2]).into_f32s()?;
        self.params_lit = None;
        outs[3].scalar()
    }
}
