//! The execution-substrate seam.
//!
//! Every compiled artifact is driven through the [`Backend`] /
//! [`Executable`] trait pair, so the serving and training layers are
//! agnostic to *how* an artifact runs:
//!
//! * [`crate::runtime::native::NativeBackend`] — the default: a pure-Rust
//!   interpreter that executes the actor/critic/autoencoder artifacts from
//!   their flat-f32 weights and manifest layouts (no external runtime,
//!   fully offline).
//! * `runtime::client::PjrtBackend` (cargo feature `xla-pjrt`) — compiles
//!   the AOT HLO-text artifacts through the PJRT C API; required for the
//!   CNN backbone segments.
//!
//! Future backends (GPU, remote execution, sharded serving) plug into the
//! same seam — see ROADMAP.md.

use std::sync::Arc;

use anyhow::Result;

use super::artifacts::ArtifactMeta;
use super::tensor::TensorView;

/// Cumulative execution statistics of one executable (perf pass).
#[derive(Default, Clone, Copy, Debug)]
pub struct ExecStats {
    pub calls: u64,
    pub total_ns: u64,
}

/// A loaded artifact ready to execute.
pub trait Executable: Send + Sync {
    /// Human-readable identity for error messages.
    fn name(&self) -> &str;

    /// Execute with borrowed inputs; returns all outputs of the artifact's
    /// result tuple as host tensors. Borrowing lets hot paths keep
    /// loop-invariant inputs (e.g. network parameters between PPO updates)
    /// alive across thousands of calls; the native backend reads them
    /// zero-copy. (The PJRT backend currently re-marshals inputs to device
    /// literals per call — a device-side input cache is future work, see
    /// DESIGN.md §Perf.)
    fn call_refs(&self, inputs: &[&TensorView]) -> Result<Vec<TensorView>>;

    /// Cumulative execution statistics.
    fn stats(&self) -> ExecStats;
}

impl dyn Executable {
    /// Convenience wrapper over [`Executable::call_refs`] for owned inputs.
    pub fn call(&self, inputs: &[TensorView]) -> Result<Vec<TensorView>> {
        let refs: Vec<&TensorView> = inputs.iter().collect();
        self.call_refs(&refs)
    }
}

/// An execution substrate: turns artifact metadata into executables.
pub trait Backend: Send + Sync {
    /// Short backend identifier ("native", "xla-pjrt", ...).
    fn name(&self) -> &str;

    /// Load/compile one artifact into an executable.
    fn load(&self, meta: &ArtifactMeta) -> Result<Arc<dyn Executable>>;
}

/// The process-default backend. `MACCI_BACKEND=native|xla` overrides;
/// native is the default (and the only choice without the `xla-pjrt`
/// cargo feature).
pub fn default_backend() -> Result<Arc<dyn Backend>> {
    let choice = std::env::var("MACCI_BACKEND").unwrap_or_default();
    match choice.as_str() {
        "" | "native" => Ok(Arc::new(super::native::NativeBackend::new())),
        "xla" | "pjrt" | "xla-pjrt" => pjrt_backend(),
        other => anyhow::bail!("unknown MACCI_BACKEND '{other}' (expected native or xla)"),
    }
}

#[cfg(feature = "xla-pjrt")]
fn pjrt_backend() -> Result<Arc<dyn Backend>> {
    Ok(Arc::new(super::client::PjrtBackend::new()?))
}

#[cfg(not(feature = "xla-pjrt"))]
fn pjrt_backend() -> Result<Arc<dyn Backend>> {
    anyhow::bail!("MACCI_BACKEND=xla requires building with `--features xla-pjrt`")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_native_without_env() {
        // MACCI_BACKEND is not set under `cargo test`; the default resolves
        // to the native interpreter.
        if std::env::var("MACCI_BACKEND").is_err() {
            assert_eq!(default_backend().unwrap().name(), "native");
        }
    }
}
