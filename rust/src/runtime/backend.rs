//! The execution-substrate seam.
//!
//! Every compiled artifact is driven through the [`Backend`] /
//! [`Executable`] trait pair, so the serving and training layers are
//! agnostic to *how* an artifact runs:
//!
//! * [`crate::runtime::native::NativeBackend`] — the default: a pure-Rust
//!   interpreter that executes the actor/critic/autoencoder artifacts from
//!   their flat-f32 weights and manifest layouts (no external runtime,
//!   fully offline).
//! * `runtime::client::PjrtBackend` (cargo feature `xla-pjrt`) — compiles
//!   the AOT HLO-text artifacts through the PJRT C API; required for the
//!   CNN backbone segments.
//!
//! Future backends (GPU, remote execution, sharded serving) plug into the
//! same seam — see ROADMAP.md.

use std::sync::Arc;

use anyhow::Result;

use super::artifacts::ArtifactMeta;
use super::tensor::TensorView;

/// Cumulative execution statistics of one executable (perf pass).
#[derive(Default, Clone, Copy, Debug)]
pub struct ExecStats {
    pub calls: u64,
    pub total_ns: u64,
}

/// Numeric precision an inference executable runs at. Selected
/// per-backend ([`crate::runtime::native::NativeBackend::with_precision`])
/// and plumbed through the executor config; training programs always run
/// f32 regardless.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Precision {
    /// Full f32 — bit-identical to the scalar reference kernels.
    #[default]
    F32,
    /// Int8 forward path (u8 activations × i8 weights, i32 accumulate,
    /// f32 requantize) — bounded-error, not bit-identical.
    Int8,
}

impl Precision {
    /// Parse a CLI/env spelling: `f32`/`fp32` or `int8`/`q8`.
    pub fn parse(s: &str) -> Result<Precision> {
        match s.to_ascii_lowercase().as_str() {
            "f32" | "fp32" | "float32" => Ok(Precision::F32),
            "int8" | "i8" | "q8" => Ok(Precision::Int8),
            other => anyhow::bail!("unknown precision '{other}' (expected f32 or int8)"),
        }
    }

    /// Process-wide default from `MACCI_PRECISION` (unset → f32). The
    /// spelling is latched once via [`crate::util::config::precision`].
    pub fn from_env() -> Precision {
        match crate::util::config::precision() {
            Some(v) => Precision::parse(v).unwrap_or_else(|e| {
                eprintln!("warning: {e}; falling back to f32");
                Precision::F32
            }),
            None => Precision::F32,
        }
    }
}

/// A loaded artifact ready to execute.
pub trait Executable: Send + Sync {
    /// Human-readable identity for error messages.
    fn name(&self) -> &str;

    /// Execute with borrowed inputs; returns all outputs of the artifact's
    /// result tuple as host tensors. Borrowing lets hot paths keep
    /// loop-invariant inputs (e.g. network parameters between PPO updates)
    /// alive across thousands of calls; the native backend reads them
    /// zero-copy. (The PJRT backend currently re-marshals inputs to device
    /// literals per call — a device-side input cache is future work, see
    /// DESIGN.md §Perf.)
    fn call_refs(&self, inputs: &[&TensorView]) -> Result<Vec<TensorView>>;

    /// Cumulative execution statistics.
    fn stats(&self) -> ExecStats;

    /// Hint that `input` will be passed as input `input_idx` on many
    /// upcoming calls — backends may precompute per-input state (the
    /// native backend packs GEMM panels / int8 weights keyed on the
    /// buffer). Purely an optimization: executables may ignore it, and
    /// calling with other inputs afterwards stays correct.
    fn warm(&self, input_idx: usize, input: &Arc<TensorView>) -> Result<()> {
        let _ = (input_idx, input);
        Ok(())
    }
}

impl dyn Executable {
    /// Convenience wrapper over [`Executable::call_refs`] for owned inputs.
    pub fn call(&self, inputs: &[TensorView]) -> Result<Vec<TensorView>> {
        let refs: Vec<&TensorView> = inputs.iter().collect();
        self.call_refs(&refs)
    }
}

/// An execution substrate: turns artifact metadata into executables.
pub trait Backend: Send + Sync {
    /// Short backend identifier ("native", "xla-pjrt", ...).
    fn name(&self) -> &str;

    /// Load/compile one artifact into an executable.
    fn load(&self, meta: &ArtifactMeta) -> Result<Arc<dyn Executable>>;
}

/// The process-default backend. `MACCI_BACKEND=native|xla` overrides;
/// native is the default (and the only choice without the `xla-pjrt`
/// cargo feature).
pub fn default_backend() -> Result<Arc<dyn Backend>> {
    let choice = crate::util::config::backend().unwrap_or_default();
    match choice {
        "" | "native" => Ok(Arc::new(super::native::NativeBackend::with_precision(
            Precision::from_env(),
        ))),
        "xla" | "pjrt" | "xla-pjrt" => pjrt_backend(),
        other => anyhow::bail!("unknown MACCI_BACKEND '{other}' (expected native or xla)"),
    }
}

#[cfg(feature = "xla-pjrt")]
fn pjrt_backend() -> Result<Arc<dyn Backend>> {
    Ok(Arc::new(super::client::PjrtBackend::new()?))
}

#[cfg(not(feature = "xla-pjrt"))]
fn pjrt_backend() -> Result<Arc<dyn Backend>> {
    anyhow::bail!("MACCI_BACKEND=xla requires building with `--features xla-pjrt`")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn precision_parses_spellings() {
        assert_eq!(Precision::parse("f32").unwrap(), Precision::F32);
        assert_eq!(Precision::parse("FP32").unwrap(), Precision::F32);
        assert_eq!(Precision::parse("int8").unwrap(), Precision::Int8);
        assert_eq!(Precision::parse("q8").unwrap(), Precision::Int8);
        assert!(Precision::parse("fp16").is_err());
        assert_eq!(Precision::default(), Precision::F32);
    }

    #[test]
    fn default_is_native_without_env() {
        // MACCI_BACKEND is not set under `cargo test`; the default resolves
        // to the native interpreter.
        if crate::util::config::backend().is_none() {
            assert_eq!(default_backend().unwrap().name(), "native");
        }
    }
}
