//! Pure-Rust ports of the three Pallas kernels (L1): fused dense
//! (matmul + bias + activation), 1x1-conv channel mix, and fixed-point
//! quantize/dequantize.
//!
//! Semantics match `python/compile/kernels/ref.py` — the correctness
//! oracles the Pallas kernels themselves are tested against — including
//! round-half-to-even in [`quantize`] (jnp.round) and the `1e-12` span
//! floor of Eq. (1). The golden fixtures in the tests below were generated
//! from ref.py, so any drift between the Rust and Pallas kernels fails
//! loudly here.
//!
//! Each kernel has a `*_with(isa, ..)` variant that routes its inner loop
//! through [`super::simd`]; the plain names dispatch on the detected ISA
//! ([`super::simd::active`]). Every ISA is bit-identical to the scalar
//! reference — see the contract in `simd.rs` — so the goldens and the
//! rollout chunking/thread-count invariance hold on all paths.

use super::simd::{self, Isa};

/// Activation fused into the dense epilogue.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Act {
    Linear,
    Tanh,
    Relu,
}

/// Apply the fused activation in place. Kept scalar on every ISA: `tanh`
/// is libm either way, and vectorized `max` has a −0.0 ambiguity the
/// bit-identity contract won't buy.
pub(crate) fn apply_act(y: &mut [f32], act: Act) {
    match act {
        Act::Linear => {}
        Act::Tanh => {
            for v in y.iter_mut() {
                *v = v.tanh();
            }
        }
        Act::Relu => {
            for v in y.iter_mut() {
                *v = v.max(0.0);
            }
        }
    }
}

/// `y = act(x @ w + b)` — x: (rows, in_dim) row-major, w: (in_dim,
/// out_dim), b: (out_dim,). Mirrors `dense_ref`. Dispatches on the
/// detected ISA.
pub fn dense(
    x: &[f32],
    rows: usize,
    in_dim: usize,
    w: &[f32],
    b: &[f32],
    out_dim: usize,
    act: Act,
) -> Vec<f32> {
    dense_with(simd::active(), x, rows, in_dim, w, b, out_dim, act)
}

/// [`dense`] on an explicit ISA — bit-identical across all of them.
#[allow(clippy::too_many_arguments)]
pub fn dense_with(
    isa: Isa,
    x: &[f32],
    rows: usize,
    in_dim: usize,
    w: &[f32],
    b: &[f32],
    out_dim: usize,
    act: Act,
) -> Vec<f32> {
    let mut out = Vec::new();
    dense_into_with(isa, x, rows, in_dim, w, b, out_dim, act, &mut out);
    out
}

/// [`dense`] into a caller-owned buffer (cleared and resized; capacity is
/// reused) — the update engine's workspace path. Bit-identical to
/// [`dense_with`], which is a thin wrapper over this.
#[allow(clippy::too_many_arguments)]
pub fn dense_into(
    x: &[f32],
    rows: usize,
    in_dim: usize,
    w: &[f32],
    b: &[f32],
    out_dim: usize,
    act: Act,
    out: &mut Vec<f32>,
) {
    dense_into_with(simd::active(), x, rows, in_dim, w, b, out_dim, act, out)
}

/// [`dense_into`] on an explicit ISA.
#[allow(clippy::too_many_arguments)]
pub fn dense_into_with(
    isa: Isa,
    x: &[f32],
    rows: usize,
    in_dim: usize,
    w: &[f32],
    b: &[f32],
    out_dim: usize,
    act: Act,
    out: &mut Vec<f32>,
) {
    debug_assert_eq!(x.len(), rows * in_dim);
    debug_assert_eq!(w.len(), in_dim * out_dim);
    debug_assert_eq!(b.len(), out_dim);
    out.clear();
    out.resize(rows * out_dim, 0.0);
    for r in 0..rows {
        out[r * out_dim..(r + 1) * out_dim].copy_from_slice(b);
    }
    if rows == 1 {
        // matrix–vector: stream W once against the single row
        let yr = &mut out[..out_dim];
        for (k, &xv) in x.iter().enumerate() {
            let wr = &w[k * out_dim..(k + 1) * out_dim];
            simd::axpy(isa, yr, xv, wr);
        }
    } else {
        // batched: k-outer so each W row is streamed ONCE for the whole
        // batch (the out block stays cache-hot) instead of once per row.
        // Per-element accumulation order is k-ascending either way, so the
        // two paths are bit-identical — rollout lanes may be chunked onto
        // worker threads in any batch split without changing a single f32.
        for k in 0..in_dim {
            let wr = &w[k * out_dim..(k + 1) * out_dim];
            for r in 0..rows {
                let xv = x[r * in_dim + k];
                let yr = &mut out[r * out_dim..(r + 1) * out_dim];
                simd::axpy(isa, yr, xv, wr);
            }
        }
    }
    apply_act(out, act);
}

/// `dX = dY @ Wᵀ` — dy: (rows, out_dim), w: (in_dim, out_dim) →
/// (rows, in_dim). The backward-data matmul of the dense kernel.
/// Dispatches on the detected ISA.
pub fn matmul_bt(dy: &[f32], rows: usize, out_dim: usize, w: &[f32], in_dim: usize) -> Vec<f32> {
    matmul_bt_with(simd::active(), dy, rows, out_dim, w, in_dim)
}

/// [`matmul_bt`] on an explicit ISA. `Isa::Scalar` keeps the original
/// per-element dot (the reference semantics); every other ISA transposes
/// W once and runs a blocked o-outer pass — the per-element contraction
/// stays o-ascending from 0.0, so the output is bit-identical while W is
/// walked contiguously instead of column-major per output element.
pub fn matmul_bt_with(
    isa: Isa,
    dy: &[f32],
    rows: usize,
    out_dim: usize,
    w: &[f32],
    in_dim: usize,
) -> Vec<f32> {
    let mut dx = Vec::new();
    let mut wt = Vec::new();
    matmul_bt_into_with(isa, dy, rows, out_dim, w, in_dim, &mut dx, &mut wt);
    dx
}

/// [`matmul_bt`] into caller-owned output and transpose-scratch buffers
/// (both cleared and resized; the scalar arm leaves `wt` untouched).
/// Bit-identical to [`matmul_bt_with`], which wraps this.
#[allow(clippy::too_many_arguments)]
pub fn matmul_bt_into(
    dy: &[f32],
    rows: usize,
    out_dim: usize,
    w: &[f32],
    in_dim: usize,
    dx: &mut Vec<f32>,
    wt: &mut Vec<f32>,
) {
    matmul_bt_into_with(simd::active(), dy, rows, out_dim, w, in_dim, dx, wt)
}

/// [`matmul_bt_into`] on an explicit ISA.
#[allow(clippy::too_many_arguments)]
pub fn matmul_bt_into_with(
    isa: Isa,
    dy: &[f32],
    rows: usize,
    out_dim: usize,
    w: &[f32],
    in_dim: usize,
    dx: &mut Vec<f32>,
    wt: &mut Vec<f32>,
) {
    debug_assert_eq!(dy.len(), rows * out_dim);
    debug_assert_eq!(w.len(), in_dim * out_dim);
    dx.clear();
    dx.resize(rows * in_dim, 0.0);
    if isa == Isa::Scalar {
        for r in 0..rows {
            let dyr = &dy[r * out_dim..(r + 1) * out_dim];
            let dxr = &mut dx[r * in_dim..(r + 1) * in_dim];
            for (k, slot) in dxr.iter_mut().enumerate() {
                let wr = &w[k * out_dim..(k + 1) * out_dim];
                let mut acc = 0.0f32;
                for (&d, &wv) in dyr.iter().zip(wr) {
                    acc += d * wv;
                }
                *slot = acc;
            }
        }
        return;
    }
    // one transposed copy of W: wt[o][k] = w[k][o], row-contiguous in k
    wt.clear();
    wt.resize(out_dim * in_dim, 0.0);
    for k in 0..in_dim {
        let wr = &w[k * out_dim..(k + 1) * out_dim];
        for (o, &wv) in wr.iter().enumerate() {
            wt[o * in_dim + k] = wv;
        }
    }
    // row blocks keep the dx slab cache-hot while each wt row streams once
    const RB: usize = 32;
    let mut r0 = 0usize;
    while r0 < rows {
        let r1 = (r0 + RB).min(rows);
        for o in 0..out_dim {
            let wrow = &wt[o * in_dim..(o + 1) * in_dim];
            for r in r0..r1 {
                let d = dy[r * out_dim + o];
                simd::axpy(isa, &mut dx[r * in_dim..(r + 1) * in_dim], d, wrow);
            }
        }
        r0 = r1;
    }
}

/// Row-wise softmax in place (max-subtracted, exactly `_softmax` in
/// python/compile/actor_critic.py). Dispatches on the detected ISA.
pub fn softmax_rows(z: &mut [f32], rows: usize, cols: usize) {
    softmax_rows_with(simd::active(), z, rows, cols)
}

/// [`softmax_rows`] on an explicit ISA — the max/exp/sum sweep stays
/// scalar (libm exp), only the normalizing division vectorizes (one IEEE
/// division per lane, bit-identical).
pub fn softmax_rows_with(isa: Isa, z: &mut [f32], rows: usize, cols: usize) {
    debug_assert_eq!(z.len(), rows * cols);
    for r in 0..rows {
        let row = &mut z[r * cols..(r + 1) * cols];
        let max = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f32;
        for v in row.iter_mut() {
            *v = (*v - max).exp();
            sum += *v;
        }
        simd::div_scalar(isa, row, sum);
    }
}

/// 1x1 convolution == per-pixel channel mix (conv1x1_ref): x (N, C, H, W),
/// w (C, C'), b (C',) → (N, C', H, W). The paper's Sec. 2.2
/// channel-reduction encoder/decoder. Dispatches on the detected ISA.
#[allow(clippy::too_many_arguments)]
pub fn conv1x1(
    x: &[f32],
    n: usize,
    c_in: usize,
    h: usize,
    w: usize,
    wmat: &[f32],
    b: &[f32],
    c_out: usize,
) -> Vec<f32> {
    conv1x1_with(simd::active(), x, n, c_in, h, w, wmat, b, c_out)
}

/// [`conv1x1`] on an explicit ISA — bit-identical across all of them.
#[allow(clippy::too_many_arguments)]
pub fn conv1x1_with(
    isa: Isa,
    x: &[f32],
    n: usize,
    c_in: usize,
    h: usize,
    w: usize,
    wmat: &[f32],
    b: &[f32],
    c_out: usize,
) -> Vec<f32> {
    debug_assert_eq!(x.len(), n * c_in * h * w);
    debug_assert_eq!(wmat.len(), c_in * c_out);
    debug_assert_eq!(b.len(), c_out);
    let hw = h * w;
    let mut out = vec![0.0f32; n * c_out * hw];
    for im in 0..n {
        for co in 0..c_out {
            let dst = &mut out[(im * c_out + co) * hw..(im * c_out + co + 1) * hw];
            dst.fill(b[co]);
            for ci in 0..c_in {
                let wv = wmat[ci * c_out + co];
                let src = &x[(im * c_in + ci) * hw..(im * c_in + ci + 1) * hw];
                simd::axpy(isa, dst, wv, src);
            }
        }
    }
    out
}

/// Round half to even, matching `jnp.round` (IEEE 754 roundTiesToEven)
/// rather than Rust's round-half-away-from-zero. Shared by [`quantize`],
/// the wire-format `compress::quant::Quantizer`, and the int8 packers.
pub fn round_ties_even(v: f32) -> f32 {
    let r = v.round();
    if (r - v).abs() == 0.5 {
        let t = v.trunc();
        if (t as i64) % 2 == 0 {
            t
        } else {
            t + v.signum()
        }
    } else {
        r
    }
}

/// Paper Eq. (1), `quantize_ref`: `y_i = round((2^cq − 1)(clip(x_i) − lo)
/// / max(hi − lo, 1e-12))`. Codes are returned as f32 integers, exactly as
/// the AOT encode artifact emits them.
pub fn quantize(x: &[f32], lo: f32, hi: f32, bits: usize) -> Vec<f32> {
    let levels = ((1u32 << bits) - 1) as f32;
    let span = (hi - lo).max(1e-12);
    x.iter()
        .map(|&v| round_ties_even(levels * (v.clamp(lo, hi) - lo) / span))
        .collect()
}

/// Paper Eq. (2), `dequantize_ref`: `x'_i = y_i (hi − lo) / (2^cq − 1) + lo`.
pub fn dequantize(y: &[f32], lo: f32, hi: f32, bits: usize) -> Vec<f32> {
    let levels = ((1u32 << bits) - 1) as f32;
    y.iter().map(|&q| q * (hi - lo) / levels + lo).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::assert_close;

    // Golden fixtures generated from python/compile/kernels/ref.py
    // (dense_ref / conv1x1_ref / quantize_ref / dequantize_ref) with
    // numpy default_rng(7) inputs — see DESIGN.md §Kernel-Parity.
    const X: &[f32] = &[
        0.001230153371579945,
        0.2987455427646637,
        -0.27413785457611084,
        -0.8905918598175049,
        -0.454670786857605,
        -0.9916465282440186,
    ];
    const W: &[f32] = &[
        0.0601436011493206,
        1.3402152061462402,
        -0.49220651388168335,
        -0.6204748749732971,
        0.4898420572280884,
        0.35688701272010803,
        0.1054142490029335,
        -0.9304680228233337,
        -0.02925182320177555,
        0.695303201675415,
        -1.3442145586013794,
        -0.45761576294898987,
    ];
    const B: &[f32] = &[
        -1.9012227058410645,
        -1.289537787437439,
        -1.8417350053787231,
        -0.23509113490581512,
    ];
    const Y_LINEAR: &[f32] = &[
        -1.7467916011810303,
        -1.3718795776367188,
        -1.4423483610153198,
        -0.3883777856826782,
        -2.1484954357147217,
        -3.334883689880371,
        -0.11832296848297119,
        1.1943484544754028,
    ];
    const Y_TANH: &[f32] = &[
        -0.9410092234611511,
        -0.879119873046875,
        -0.8941695094108582,
        -0.3699609041213989,
        -0.9731465578079224,
        -0.9974657893180847,
        -0.11777384579181671,
        0.8319226503372192,
    ];
    const Y_RELU: &[f32] = &[0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 1.1943484544754028];

    const XC: &[f32] = &[
        -1.267446517944336,
        0.27126434445381165,
        0.15675108134746552,
        -0.18693093955516815,
        -2.5167596340179443,
        -0.5386928915977478,
        -0.048500943928956985,
        0.11330898851156235,
        -1.5301357507705688,
        -0.47775328159332275,
        -0.978519082069397,
        -0.8088372349739075,
    ];
    const WC: &[f32] = &[
        1.0608986616134644,
        -0.8075346946716309,
        -0.03252170607447624,
        0.8843898773193359,
        -0.5836004614830017,
        -0.11170195043087006,
    ];
    const BC: &[f32] = &[0.11046414077281952, 0.06378177553415298];
    const YC: &[f32] = &[
        -0.2593308687210083,
        0.6945843696594238,
        0.849402666091919,
        0.3805021643638611,
        -0.9675887227058411,
        -0.578322172164917,
        0.003608591854572296,
        0.40529298782348633,
    ];

    const XQ: &[f32] = &[-1.5, -0.20000000298023224, 0.0, 0.30000001192092896, 0.7699999809265137, 1.2000000476837158, 2.0, 5.0];
    const Q3: &[f32] = &[0.0, 2.0, 2.0, 3.0, 4.0, 5.0, 7.0, 7.0];
    const D3: &[f32] = &[
        -1.0,
        -0.1428571343421936,
        -0.1428571343421936,
        0.2857142686843872,
        0.7142857313156128,
        1.1428570747375488,
        2.0,
        2.0,
    ];
    const Q8: &[f32] = &[0.0, 68.0, 85.0, 110.0, 150.0, 187.0, 255.0, 255.0];
    const D8: &[f32] = &[
        -1.0,
        -0.19999998807907104,
        0.0,
        0.29411768913269043,
        0.7647058963775635,
        1.2000000476837158,
        2.0,
        2.0,
    ];

    #[test]
    fn dense_matches_ref_goldens() {
        for (act, golden) in [
            (Act::Linear, Y_LINEAR),
            (Act::Tanh, Y_TANH),
            (Act::Relu, Y_RELU),
        ] {
            let y = dense(X, 2, 3, W, B, 4, act);
            assert_close(&y, golden, 1e-5, 1e-5).unwrap();
        }
    }

    #[test]
    fn dense_goldens_hold_on_every_isa() {
        for isa in simd::available() {
            for (act, golden) in [
                (Act::Linear, Y_LINEAR),
                (Act::Tanh, Y_TANH),
                (Act::Relu, Y_RELU),
            ] {
                let y = dense_with(isa, X, 2, 3, W, B, 4, act);
                assert_close(&y, golden, 1e-5, 1e-5).unwrap();
                // and bitwise against the scalar reference path
                let scalar = dense_with(Isa::Scalar, X, 2, 3, W, B, 4, act);
                assert_eq!(y, scalar, "{isa:?} {act:?}");
            }
        }
    }

    #[test]
    fn dense_batched_path_is_bit_identical_to_rowwise() {
        // the k-outer batched path must agree bitwise with per-row
        // matrix–vector calls (rollout correctness depends on this)
        let in_dim = 7;
        let out_dim = 5;
        let rows = 4;
        let x: Vec<f32> = (0..rows * in_dim)
            .map(|i| ((i * 37 % 19) as f32 - 9.0) * 0.13)
            .collect();
        let w: Vec<f32> = (0..in_dim * out_dim)
            .map(|i| ((i * 11 % 23) as f32 - 11.0) * 0.07)
            .collect();
        let b: Vec<f32> = (0..out_dim).map(|i| i as f32 * 0.31 - 0.5).collect();
        for isa in simd::available() {
            for act in [Act::Linear, Act::Tanh, Act::Relu] {
                let batched = dense_with(isa, &x, rows, in_dim, &w, &b, out_dim, act);
                for r in 0..rows {
                    let row = &x[r * in_dim..(r + 1) * in_dim];
                    let single = dense_with(isa, row, 1, in_dim, &w, &b, out_dim, act);
                    assert_eq!(
                        &batched[r * out_dim..(r + 1) * out_dim],
                        &single[..],
                        "{isa:?} row {r}"
                    );
                }
            }
        }
    }

    #[test]
    fn conv1x1_matches_ref_golden() {
        let y = conv1x1(XC, 1, 3, 2, 2, WC, BC, 2);
        assert_close(&y, YC, 1e-5, 1e-5).unwrap();
        for isa in simd::available() {
            let yi = conv1x1_with(isa, XC, 1, 3, 2, 2, WC, BC, 2);
            assert_eq!(yi, y, "{isa:?}");
        }
    }

    #[test]
    fn quantize_matches_ref_goldens() {
        for (bits, q_golden, d_golden) in [(3usize, Q3, D3), (8, Q8, D8)] {
            let q = quantize(XQ, -1.0, 2.0, bits);
            assert_close(&q, q_golden, 0.0, 0.0).unwrap();
            let d = dequantize(&q, -1.0, 2.0, bits);
            assert_close(&d, d_golden, 1e-6, 1e-6).unwrap();
        }
    }

    #[test]
    fn quantize_matches_wire_quantizer() {
        // the native kernel and the wire-format Quantizer (compress/quant)
        // implement the same Eq. (1)/(2) and must agree elementwise —
        // including on exact half-boundary ties now that both round
        // ties-to-even
        let mut xs: Vec<f32> = (0..64).map(|i| (i as f32 * 0.37).sin() * 2.0).collect();
        xs.extend_from_slice(&[-0.5, 0.5, 1.5, -1.7, 1.9]);
        let (lo, hi) = (-1.7f32, 1.9f32);
        for bits in [3usize, 5, 8, 11] {
            let q = crate::compress::quant::Quantizer::new(bits as u32).unwrap();
            let wire = q.quantize(&xs, lo, hi);
            let native = quantize(&xs, lo, hi, bits);
            for (a, b) in wire.iter().zip(&native) {
                assert_eq!(*a as f32, *b);
            }
            let back_wire = q.dequantize(&wire, lo, hi);
            let back_native = dequantize(&native, lo, hi, bits);
            assert_close(&back_native, &back_wire, 1e-6, 0.0).unwrap();
        }
    }

    #[test]
    fn round_half_even_cases() {
        assert_eq!(round_ties_even(2.5), 2.0);
        assert_eq!(round_ties_even(3.5), 4.0);
        assert_eq!(round_ties_even(0.5), 0.0);
        assert_eq!(round_ties_even(1.5), 2.0);
        assert_eq!(round_ties_even(2.4), 2.0);
        assert_eq!(round_ties_even(2.6), 3.0);
        assert_eq!(round_ties_even(-0.5), 0.0);
        assert_eq!(round_ties_even(-1.5), -2.0);
        assert_eq!(round_ties_even(-2.5), -2.0);
    }

    #[test]
    fn matmul_bt_is_transpose_contraction() {
        // dy (1,2) @ wᵀ where w (3,2): dx_k = Σ_o dy_o w[k,o]
        let w = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let dy = [10.0f32, 100.0];
        let dx = matmul_bt(&dy, 1, 2, &w, 3);
        assert_eq!(dx, vec![210.0, 430.0, 650.0]);
        for isa in simd::available() {
            assert_eq!(
                matmul_bt_with(isa, &dy, 1, 2, &w, 3),
                vec![210.0, 430.0, 650.0],
                "{isa:?}"
            );
        }
    }

    #[test]
    fn blocked_matmul_bt_bit_identical_to_scalar() {
        // the blocked o-outer pass must reproduce the per-element dot
        // bitwise on shapes straddling the row-block edge
        for (rows, out_dim, in_dim) in [(1usize, 4usize, 7usize), (5, 9, 3), (70, 13, 17)] {
            let dy: Vec<f32> = (0..rows * out_dim)
                .map(|i| ((i * 29 % 31) as f32 - 15.0) * 0.11)
                .collect();
            let w: Vec<f32> = (0..in_dim * out_dim)
                .map(|i| ((i * 17 % 41) as f32 - 20.0) * 0.05)
                .collect();
            let want = matmul_bt_with(Isa::Scalar, &dy, rows, out_dim, &w, in_dim);
            for isa in simd::available() {
                let got = matmul_bt_with(isa, &dy, rows, out_dim, &w, in_dim);
                assert_eq!(got, want, "{isa:?} {rows}x{out_dim}x{in_dim}");
            }
        }
    }

    #[test]
    fn softmax_rows_normalizes() {
        let mut z = vec![1.0f32, 2.0, 3.0, -1.0, 0.0, 1.0];
        softmax_rows(&mut z, 2, 3);
        for r in 0..2 {
            let s: f32 = z[r * 3..(r + 1) * 3].iter().sum();
            assert!((s - 1.0).abs() < 1e-6);
        }
        assert!(z[2] > z[1] && z[1] > z[0]);
        // dispatched paths bit-identical to scalar
        for isa in simd::available() {
            let mut zi = vec![1.0f32, 2.0, 3.0, -1.0, 0.0, 1.0];
            softmax_rows_with(isa, &mut zi, 2, 3);
            assert_eq!(zi, z, "{isa:?}");
        }
    }
}
