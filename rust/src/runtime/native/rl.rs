//! Native execution of the actor / critic artifacts: the same networks,
//! losses, gradients and Adam updates `python/compile/actor_critic.py`
//! lowers to HLO, re-derived in Rust from the manifest's flat-parameter
//! layout.
//!
//! The hand-written backward pass was validated elementwise against
//! `jax.grad` of the Python losses (forward probabilities, one full Adam
//! step of both networks agree to f32 precision — DESIGN.md
//! §Kernel-Parity), so the native and PJRT backends train identically up
//! to float rounding.
//!
//! Updates run on the data-parallel engine in [`super::update`]: the
//! minibatch is cut into fixed `SHARD_ROWS`-row shards, each shard's
//! gradient partial lands in its own pooled workspace, and the partials
//! fold together in ascending shard order — so the trained bits depend
//! on the batch size but never on the worker count, and steady-state
//! updates reuse their scratch instead of reallocating it (DESIGN.md
//! §Update-Engine).

use std::collections::BTreeMap;
use std::ops::Range;
use std::sync::{Arc, RwLock};

use anyhow::{anyhow, bail, Result};

use super::gemm::{dense_packed_into, PackedW};
use super::kernels::{dense_into, matmul_bt_into, softmax_rows, Act};
use super::quant8::QuantDense;
use super::simd::{self, Isa};
use super::update::{self, Arena};
use super::{expect_inputs, f32_in, i32_in, same_f32_buffer, scalar_in};
use crate::runtime::artifacts::ArtifactMeta;
use crate::runtime::backend::Precision;
use crate::runtime::spec::{spec_entry, spec_size, SpecEntry};
use crate::runtime::tensor::TensorView;

// PPO / entropy constants — defaults of `actor_update` in
// python/compile/actor_critic.py.
const CLIP_EPS: f32 = 0.2;
const ENTROPY_COEF: f32 = 0.001;
const PROB_FLOOR: f32 = 1e-8;
const LOG_STD_MIN: f32 = -4.0;
const LOG_STD_MAX: f32 = 1.0;
const LOG_2PI: f32 = 1.837_877_1;

// Adam constants — python/compile/common.py `adam_step`.
const ADAM_B1: f32 = 0.9;
const ADAM_B2: f32 = 0.999;
const ADAM_EPS: f32 = 1e-8;

/// One named segment of the flat parameter vector.
#[derive(Debug, Clone, Copy)]
struct Slot {
    off: usize,
    len: usize,
}

fn slot(spec: &[SpecEntry], name: &str) -> Result<(Slot, Vec<usize>)> {
    let e = spec_entry(spec, name)?;
    Ok((
        Slot {
            off: e.offset,
            len: e.count,
        },
        e.shape.clone(),
    ))
}

/// A weight slot that must be a 2-D matrix — resolves via
/// [`SpecEntry::dims2`] so layout-shape validation lives with the spec.
fn slot2(spec: &[SpecEntry], name: &str) -> Result<(Slot, (usize, usize))> {
    let e = spec_entry(spec, name)?;
    let dims = e
        .dims2()
        .ok_or_else(|| anyhow!("parameter '{name}' is not a 2-D matrix (shape {:?})", e.shape))?;
    Ok((
        Slot {
            off: e.offset,
            len: e.count,
        },
        dims,
    ))
}

// ------------------------------------------------- warmed per-params prep

/// One dense layer's precomputed forward state: packed GEMM panels (f32)
/// or quantized int8 weights, per the executable's [`Precision`].
enum PrepDense {
    F32(PackedW),
    Q8(QuantDense),
}

impl PrepDense {
    fn build(precision: Precision, w: &[f32], b: &[f32], in_dim: usize, out_dim: usize) -> Self {
        match precision {
            Precision::F32 => PrepDense::F32(PackedW::pack(w, b, in_dim, out_dim)),
            Precision::Int8 => PrepDense::Q8(QuantDense::pack(w, b, in_dim, out_dim)),
        }
    }
}

/// Run one dense layer into a workspace buffer: through the warmed prep
/// when present, else the plain dispatched kernel. The f32 prep path is
/// bit-identical to the kernel; the int8 path is bounded-error (DESIGN.md
/// §Native-Kernels). `xq` is the int8 path's activation-code scratch.
#[allow(clippy::too_many_arguments)]
fn run_layer_into(
    prep: Option<&PrepDense>,
    x: &[f32],
    rows: usize,
    in_dim: usize,
    w: &[f32],
    b: &[f32],
    out_dim: usize,
    act: Act,
    out: &mut Vec<f32>,
    xq: &mut Vec<u8>,
) {
    match prep {
        Some(PrepDense::F32(pw)) => dense_packed_into(simd::active(), x, rows, pw, act, out),
        Some(PrepDense::Q8(q)) => q.forward_into(simd::active(), x, rows, act, out, xq),
        None => dense_into(x, rows, in_dim, w, b, out_dim, act, out),
    }
}

/// Per-parameter-version precomputed state, keyed by the params buffer
/// address. `ArtifactStore` memoizes executables, so several nets (one per
/// UE lane) share one program — each keeps its own cached params tensor
/// alive, which makes the buffer pointer a stable, ABA-safe key as long as
/// the entry holds the tensor's `Arc` (it does).
struct Warmed<P> {
    params: Arc<TensorView>,
    prep: P,
}

// BTreeMap, not HashMap: the warmed cache sits on the bit-exactness hot
// path and macci-lint rule R2 (`determinism`) bans hash-order iteration
// there — `insert_warmed`'s GC retain() walks the map.
type WarmedMap<P> = RwLock<BTreeMap<usize, Arc<Warmed<P>>>>;

fn lookup_warmed<P>(map: &WarmedMap<P>, params_in: &TensorView) -> Option<Arc<Warmed<P>>> {
    let key = params_in.f32s().ok()?.as_ptr() as usize;
    let g = map.read().unwrap();
    let w = g.get(&key)?;
    if same_f32_buffer(&w.params, params_in) {
        Some(w.clone())
    } else {
        None
    }
}

fn insert_warmed<P>(map: &WarmedMap<P>, key: usize, entry: Warmed<P>) {
    let mut g = map.write().unwrap();
    // drop entries whose params tensor nobody else holds anymore (the net
    // invalidated its cache after an update) so the map never grows past
    // the live parameter versions
    g.retain(|_, w| Arc::strong_count(&w.params) > 1);
    g.insert(key, Arc::new(entry));
}

fn seg<'a>(params: &'a [f32], s: Slot) -> &'a [f32] {
    &params[s.off..s.off + s.len]
}

/// `dh *= 1 - h²` — tanh backward, elementwise.
fn tanh_backward(dh: &mut [f32], h: &[f32]) {
    for (d, &hv) in dh.iter_mut().zip(h) {
        *d *= 1.0 - hv * hv;
    }
}

/// Accumulate `dW += Xᵀ dY` and `db += colsum(dY)` straight into the flat
/// gradient vector (slots may live anywhere in the layout, so index math
/// instead of slice splitting). The inner column sweep routes through
/// [`simd::axpy`], which is elementwise mul+add in r-then-k ascending
/// order — bit-identical to the scalar loops it replaced.
#[allow(clippy::too_many_arguments)]
fn acc_into(
    g: &mut [f32],
    w: Slot,
    b: Slot,
    x: &[f32],
    rows: usize,
    in_dim: usize,
    dy: &[f32],
    out_dim: usize,
) {
    let isa = simd::active();
    for r in 0..rows {
        let xr = &x[r * in_dim..(r + 1) * in_dim];
        let dyr = &dy[r * out_dim..(r + 1) * out_dim];
        for (k, &xv) in xr.iter().enumerate() {
            let base = w.off + k * out_dim;
            simd::axpy(isa, &mut g[base..base + out_dim], xv, dyr);
        }
        simd::axpy(isa, &mut g[b.off..b.off + out_dim], 1.0, dyr);
    }
}

/// One Adam step on flat vectors (`t` is the 1-based step count as f32).
fn adam_step(
    p: &[f32],
    g: &[f32],
    m: &[f32],
    v: &[f32],
    t: f32,
    lr: f32,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let bc1 = 1.0 - ADAM_B1.powf(t);
    let bc2 = 1.0 - ADAM_B2.powf(t);
    let n = p.len();
    let mut p2 = vec![0.0f32; n];
    let mut m2 = vec![0.0f32; n];
    let mut v2 = vec![0.0f32; n];
    for i in 0..n {
        let mi = ADAM_B1 * m[i] + (1.0 - ADAM_B1) * g[i];
        let vi = ADAM_B2 * v[i] + (1.0 - ADAM_B2) * g[i] * g[i];
        m2[i] = mi;
        v2[i] = vi;
        let mhat = mi / bc1;
        let vhat = vi / bc2;
        p2[i] = p[i] - lr * mhat / (vhat.sqrt() + ADAM_EPS);
    }
    (p2, m2, v2)
}

// ===================================================================== actor

/// Layout-resolved actor network (trunk 4N→t0→t1 tanh, three branch heads).
pub(super) struct ActorProgram {
    size: usize,
    d: usize,
    t0: usize,
    t1: usize,
    h: usize,
    p: usize,
    c: usize,
    precision: Precision,
    warmed: WarmedMap<ActorPrep>,
    w_t0: Slot,
    b_t0: Slot,
    w_t1: Slot,
    b_t1: Slot,
    w_b0: Slot,
    b_b0: Slot,
    w_b1: Slot,
    b_b1: Slot,
    w_c0: Slot,
    b_c0: Slot,
    w_c1: Slot,
    b_c1: Slot,
    w_p0: Slot,
    b_p0: Slot,
    w_p1: Slot,
    b_p1_mu: Slot,
    b_p1_ls: Slot,
    ws: Arena<ActorWs>,
}

/// Precomputed per-params state for every dense layer of the actor.
struct ActorPrep {
    t0: PrepDense,
    t1: PrepDense,
    b0: PrepDense,
    b1: PrepDense,
    c0: PrepDense,
    c1: PrepDense,
    p0: PrepDense,
    p1: PrepDense,
}

/// One shard's pooled `UpdateWorkspace` for the actor: forward
/// activations kept for the backward pass, loss/backward scratch, and
/// the shard's flat gradient partial with its loss-scalar partials. All
/// buffers warm up to their steady-state capacity on first use and are
/// then recycled through the program's [`Arena`].
#[derive(Default)]
struct ActorWs {
    // forward activations
    h0: Vec<f32>,
    h1: Vec<f32>,
    hb: Vec<f32>,
    hc: Vec<f32>,
    hp: Vec<f32>,
    probs_b: Vec<f32>,
    probs_c: Vec<f32>,
    mu_std: Vec<f32>,
    mu: Vec<f32>,
    ls_raw: Vec<f32>,
    log_std: Vec<f32>,
    /// int8 activation codes (only the warmed Q8 forward path uses it)
    xq: Vec<u8>,
    // loss pass
    d_logp: Vec<f32>,
    z: Vec<f32>,
    std: Vec<f32>,
    // backward scratch
    d_logits_b: Vec<f32>,
    d_logits_c: Vec<f32>,
    dhdp: Vec<f32>,
    d_mu_std: Vec<f32>,
    d_hp: Vec<f32>,
    d_hb: Vec<f32>,
    d_hc: Vec<f32>,
    d_h1_p: Vec<f32>,
    d_h1_b: Vec<f32>,
    d_h1_c: Vec<f32>,
    d_h1: Vec<f32>,
    d_h0: Vec<f32>,
    /// transpose scratch for [`matmul_bt_into`]
    wt: Vec<f32>,
    // shard partials, folded shard-ascending by `run_update`
    g: Vec<f32>,
    l_clip_sum: f32,
    ent_sum: f32,
    clip_count: usize,
}

impl ActorProgram {
    pub(super) fn from_meta(meta: &ArtifactMeta, precision: Precision) -> Result<ActorProgram> {
        let spec = meta.spec.as_ref().ok_or_else(|| {
            anyhow!("no parameter layout attached (manifest rl.specs entry missing?)")
        })?;
        let (w_t0, (d, t0)) = slot2(spec, "w_t0")?;
        let (w_t1, (_, t1)) = slot2(spec, "w_t1")?;
        let (w_b0, (_, h)) = slot2(spec, "w_b0")?;
        let (w_b1, (_, p)) = slot2(spec, "w_b1")?;
        let (w_c1, (_, c)) = slot2(spec, "w_c1")?;
        let prog = ActorProgram {
            size: spec_size(spec),
            d,
            t0,
            t1,
            h,
            p,
            c,
            precision,
            warmed: RwLock::new(BTreeMap::new()),
            w_t0,
            b_t0: slot(spec, "b_t0")?.0,
            w_t1,
            b_t1: slot(spec, "b_t1")?.0,
            w_b0,
            b_b0: slot(spec, "b_b0")?.0,
            w_b1,
            b_b1: slot(spec, "b_b1")?.0,
            w_c0: slot(spec, "w_c0")?.0,
            b_c0: slot(spec, "b_c0")?.0,
            w_c1,
            b_c1: slot(spec, "b_c1")?.0,
            w_p0: slot(spec, "w_p0")?.0,
            b_p0: slot(spec, "b_p0")?.0,
            w_p1: slot(spec, "w_p1")?.0,
            b_p1_mu: slot(spec, "b_p1_mu")?.0,
            b_p1_ls: slot(spec, "b_p1_log_std")?.0,
            ws: Arena::new(),
        };
        Ok(prog)
    }

    /// Build the per-layer prep for one params version at this program's
    /// precision (packed GEMM panels for f32, quantized weights for int8).
    fn build_prep(&self, params: &[f32]) -> ActorPrep {
        let pr = self.precision;
        let bias_p = [params[self.b_p1_mu.off], params[self.b_p1_ls.off]];
        ActorPrep {
            t0: PrepDense::build(pr, seg(params, self.w_t0), seg(params, self.b_t0), self.d, self.t0),
            t1: PrepDense::build(pr, seg(params, self.w_t1), seg(params, self.b_t1), self.t0, self.t1),
            b0: PrepDense::build(pr, seg(params, self.w_b0), seg(params, self.b_b0), self.t1, self.h),
            b1: PrepDense::build(pr, seg(params, self.w_b1), seg(params, self.b_b1), self.h, self.p),
            c0: PrepDense::build(pr, seg(params, self.w_c0), seg(params, self.b_c0), self.t1, self.h),
            c1: PrepDense::build(pr, seg(params, self.w_c1), seg(params, self.b_c1), self.h, self.c),
            p0: PrepDense::build(pr, seg(params, self.w_p0), seg(params, self.b_p0), self.t1, self.h),
            p1: PrepDense::build(pr, seg(params, self.w_p1), &bias_p, self.h, 2),
        }
    }

    /// Precompute and cache per-params forward state — see
    /// [`super::NativeBackend`] and `Executable::warm`.
    pub(super) fn warm(&self, input: &Arc<TensorView>) -> Result<()> {
        let params = input.f32s()?;
        if params.len() != self.size {
            bail!("actor warm: expected {} parameters, got {}", self.size, params.len());
        }
        // under MACCI_FORCE_SCALAR at f32 the un-prepped kernels are the
        // reference path — keep it exactly the seed behavior, no packing
        if self.precision == Precision::F32 && simd::active() == Isa::Scalar {
            return Ok(());
        }
        let key = params.as_ptr() as usize;
        if lookup_warmed(&self.warmed, input).is_some() {
            return Ok(());
        }
        let prep = self.build_prep(params);
        insert_warmed(
            &self.warmed,
            key,
            Warmed {
                params: input.clone(),
                prep,
            },
        );
        Ok(())
    }

    /// Forward `b` rows into `ws`'s activation buffers. Per row this is
    /// bit-identical for any batch split (the dense kernels accumulate
    /// k-ascending per row), which is what lets `run_update` shard the
    /// minibatch without perturbing any shard's forward bits.
    fn forward_into(
        &self,
        params: &[f32],
        state: &[f32],
        b: usize,
        prep: Option<&ActorPrep>,
        ws: &mut ActorWs,
    ) {
        run_layer_into(
            prep.map(|p| &p.t0),
            state,
            b,
            self.d,
            seg(params, self.w_t0),
            seg(params, self.b_t0),
            self.t0,
            Act::Tanh,
            &mut ws.h0,
            &mut ws.xq,
        );
        run_layer_into(
            prep.map(|p| &p.t1),
            &ws.h0,
            b,
            self.t0,
            seg(params, self.w_t1),
            seg(params, self.b_t1),
            self.t1,
            Act::Tanh,
            &mut ws.h1,
            &mut ws.xq,
        );

        run_layer_into(
            prep.map(|p| &p.b0),
            &ws.h1,
            b,
            self.t1,
            seg(params, self.w_b0),
            seg(params, self.b_b0),
            self.h,
            Act::Tanh,
            &mut ws.hb,
            &mut ws.xq,
        );
        run_layer_into(
            prep.map(|p| &p.b1),
            &ws.hb,
            b,
            self.h,
            seg(params, self.w_b1),
            seg(params, self.b_b1),
            self.p,
            Act::Linear,
            &mut ws.probs_b,
            &mut ws.xq,
        );
        softmax_rows(&mut ws.probs_b, b, self.p);

        run_layer_into(
            prep.map(|p| &p.c0),
            &ws.h1,
            b,
            self.t1,
            seg(params, self.w_c0),
            seg(params, self.b_c0),
            self.h,
            Act::Tanh,
            &mut ws.hc,
            &mut ws.xq,
        );
        run_layer_into(
            prep.map(|p| &p.c1),
            &ws.hc,
            b,
            self.h,
            seg(params, self.w_c1),
            seg(params, self.b_c1),
            self.c,
            Act::Linear,
            &mut ws.probs_c,
            &mut ws.xq,
        );
        softmax_rows(&mut ws.probs_c, b, self.c);

        run_layer_into(
            prep.map(|p| &p.p0),
            &ws.h1,
            b,
            self.t1,
            seg(params, self.w_p0),
            seg(params, self.b_p0),
            self.h,
            Act::Tanh,
            &mut ws.hp,
            &mut ws.xq,
        );
        let bias_p = [params[self.b_p1_mu.off], params[self.b_p1_ls.off]];
        run_layer_into(
            prep.map(|p| &p.p1),
            &ws.hp,
            b,
            self.h,
            seg(params, self.w_p1),
            &bias_p,
            2,
            Act::Linear,
            &mut ws.mu_std,
            &mut ws.xq,
        );
        update::zeroed(&mut ws.mu, b);
        update::zeroed(&mut ws.ls_raw, b);
        update::zeroed(&mut ws.log_std, b);
        for i in 0..b {
            ws.mu[i] = ws.mu_std[2 * i];
            ws.ls_raw[i] = ws.mu_std[2 * i + 1];
            ws.log_std[i] = ws.ls_raw[i].clamp(LOG_STD_MIN, LOG_STD_MAX);
        }
    }

    fn check_params<'a>(&self, inputs: &'a [&TensorView], what: &str) -> Result<&'a [f32]> {
        let params = f32_in(inputs, 0, what)?;
        if params.len() != self.size {
            bail!("{what}: expected {} parameters, got {}", self.size, params.len());
        }
        Ok(params)
    }

    /// `(params, state) -> (probs_b, probs_c, mu, log_std)`.
    pub(super) fn run_forward(&self, inputs: &[&TensorView]) -> Result<Vec<TensorView>> {
        expect_inputs(inputs, 2, "actor_fwd")?;
        let params = self.check_params(inputs, "actor_fwd")?;
        let state = f32_in(inputs, 1, "actor_fwd")?;
        if state.is_empty() || state.len() % self.d != 0 {
            bail!("actor_fwd: state length {} not a multiple of {}", state.len(), self.d);
        }
        let b = state.len() / self.d;
        // warmed prep keyed on the params buffer; int8 must quantize even
        // when cold (correctness of the precision knob beats the one-off
        // packing cost), f32 cold calls use the plain dispatched kernels
        let warmed = lookup_warmed(&self.warmed, inputs[0]);
        let ephemeral;
        let prep = match (&warmed, self.precision) {
            (Some(w), _) => Some(&w.prep),
            (None, Precision::Int8) => {
                ephemeral = self.build_prep(params);
                Some(&ephemeral)
            }
            (None, Precision::F32) => None,
        };
        let mut wss = self.ws.take(1);
        self.forward_into(params, state, b, prep, &mut wss[0]);
        let out = vec![
            TensorView::f32(wss[0].probs_b.clone(), vec![b, self.p])?,
            TensorView::f32(wss[0].probs_c.clone(), vec![b, self.c])?,
            TensorView::f32(wss[0].mu.clone(), vec![b, 1])?,
            TensorView::f32(wss[0].log_std.clone(), vec![b, 1])?,
        ];
        self.ws.put(wss);
        Ok(out)
    }

    /// One PPO-clip + entropy-bonus + Adam minibatch step:
    /// `(params, m, v, t, lr, state, a_b, a_c, a_p, old_logp, adv)
    ///  -> (params', m', v', loss, entropy, clip_frac)`.
    pub(super) fn run_update(&self, inputs: &[&TensorView]) -> Result<Vec<TensorView>> {
        let what = "actor_update";
        expect_inputs(inputs, 11, what)?;
        let params = self.check_params(inputs, what)?;
        let m = f32_in(inputs, 1, what)?;
        let v = f32_in(inputs, 2, what)?;
        let t = scalar_in(inputs, 3, what)?;
        let lr = scalar_in(inputs, 4, what)?;
        let state = f32_in(inputs, 5, what)?;
        let a_b = i32_in(inputs, 6, what)?;
        let a_c = i32_in(inputs, 7, what)?;
        let a_p = f32_in(inputs, 8, what)?;
        let old_logp = f32_in(inputs, 9, what)?;
        let adv = f32_in(inputs, 10, what)?;
        let b = a_b.len();
        if b == 0 || state.len() != b * self.d {
            bail!("{what}: state length {} vs batch {b} x dim {}", state.len(), self.d);
        }
        if m.len() != self.size || v.len() != self.size {
            bail!("{what}: Adam state size mismatch");
        }
        if a_c.len() != b || a_p.len() != b || old_logp.len() != b || adv.len() != b {
            bail!("{what}: ragged minibatch inputs");
        }
        // validate up front — the sharded workers are infallible
        for i in 0..b {
            let jb = a_b[i] as usize;
            let jc = a_c[i] as usize;
            if jb >= self.p || jc >= self.c {
                bail!("{what}: action ({jb},{jc}) out of range ({},{})", self.p, self.c);
            }
        }

        let inv_b = 1.0 / b as f32;
        let shards = update::shard_count(b);
        let threads = update::effective_threads(shards);
        let mut wss = self.ws.take(shards);
        update::run_sharded(&mut wss, threads, |ws, s| {
            self.update_shard(
                params,
                state,
                a_b,
                a_c,
                a_p,
                old_logp,
                adv,
                inv_b,
                update::shard_range(s, b),
                ws,
            )
        })?;

        // deterministic reduction: fold partials in ascending shard order
        // (1.0-scaled axpy is an exact elementwise add), so the result
        // depends on the fixed partition, never on the worker count
        let isa = simd::active();
        let (acc, rest) = wss.split_first_mut().expect("at least one shard");
        for ws in rest.iter() {
            simd::axpy(isa, &mut acc.g, 1.0, &ws.g);
            acc.l_clip_sum += ws.l_clip_sum;
            acc.ent_sum += ws.ent_sum;
            acc.clip_count += ws.clip_count;
        }
        let loss = -(acc.l_clip_sum * inv_b + ENTROPY_COEF * acc.ent_sum * inv_b);
        let entropy = acc.ent_sum * inv_b;
        let clip_frac = acc.clip_count as f32 * inv_b;

        // ---- Adam ----
        let (p2, m2, v2) = adam_step(params, &acc.g, m, v, t, lr);
        self.ws.put(wss);
        Ok(vec![
            TensorView::f32(p2, vec![self.size])?,
            TensorView::f32(m2, vec![self.size])?,
            TensorView::f32(v2, vec![self.size])?,
            TensorView::from_scalar(loss),
            TensorView::from_scalar(entropy),
            TensorView::from_scalar(clip_frac),
        ])
    }

    /// Forward + loss + backward for one shard's rows, writing the flat
    /// gradient partial and loss scalars into `ws`. Inputs are indexed by
    /// the global row `i`, workspace buffers by the shard-local `li`.
    #[allow(clippy::too_many_arguments)]
    fn update_shard(
        &self,
        params: &[f32],
        state: &[f32],
        a_b: &[i32],
        a_c: &[i32],
        a_p: &[f32],
        old_logp: &[f32],
        adv: &[f32],
        inv_b: f32,
        range: Range<usize>,
        ws: &mut ActorWs,
    ) {
        let rows = range.len();
        let shard_state = &state[range.start * self.d..range.end * self.d];
        // updates always run the un-prepped f32 kernels: the training and
        // bit-exact-resume contracts are defined on them
        self.forward_into(params, shard_state, rows, None, ws);
        let ent_coef_b = ENTROPY_COEF * inv_b;

        let ActorWs {
            h0,
            h1,
            hb,
            hc,
            hp,
            probs_b,
            probs_c,
            ls_raw,
            log_std,
            mu,
            d_logp,
            z,
            std,
            d_logits_b,
            d_logits_c,
            dhdp,
            d_mu_std,
            d_hp,
            d_hb,
            d_hc,
            d_h1_p,
            d_h1_b,
            d_h1_c,
            d_h1,
            d_h0,
            wt,
            g,
            l_clip_sum,
            ent_sum,
            clip_count,
            ..
        } = ws;

        // ---- hybrid log-prob, PPO ratio, loss scalars ----
        update::zeroed(d_logp, rows);
        update::zeroed(z, rows);
        update::zeroed(std, rows);
        *l_clip_sum = 0.0;
        *ent_sum = 0.0;
        *clip_count = 0;
        for li in 0..rows {
            let i = range.start + li;
            let jb = a_b[i] as usize;
            let jc = a_c[i] as usize;
            let pb = &probs_b[li * self.p..(li + 1) * self.p];
            let pc = &probs_c[li * self.c..(li + 1) * self.c];
            std[li] = log_std[li].exp();
            z[li] = (a_p[i] - mu[li]) / std[li];
            let lp = pb[jb].clamp(PROB_FLOOR, 1.0).ln()
                + pc[jc].clamp(PROB_FLOOR, 1.0).ln()
                + (-0.5 * z[li] * z[li] - log_std[li] - 0.5 * LOG_2PI);
            let ratio = (lp - old_logp[i]).exp();
            let surr1 = ratio * adv[i];
            let surr2 = ratio.clamp(1.0 - CLIP_EPS, 1.0 + CLIP_EPS) * adv[i];
            *l_clip_sum += surr1.min(surr2);
            if (ratio - 1.0).abs() > CLIP_EPS {
                *clip_count += 1;
            }
            // d l_clip / d ratio: 1·adv on the unclipped branch
            // (jnp.minimum picks the first arg on ties), 1{in clip range}·adv
            // on the clipped one
            let in_range = (1.0 - CLIP_EPS..=1.0 + CLIP_EPS).contains(&ratio);
            let d_ratio = if surr1 <= surr2 || in_range {
                adv[i] * inv_b
            } else {
                0.0
            };
            // loss = -(l_clip + coef * entropy)
            d_logp[li] = -d_ratio * ratio;

            // entropy (for the reported scalar)
            let mut ent = 0.5 * (1.0 + LOG_2PI) + log_std[li];
            for &q in pb.iter().chain(pc.iter()) {
                let qc = q.clamp(PROB_FLOOR, 1.0);
                ent -= qc * qc.ln();
            }
            *ent_sum += ent;
        }

        // ---- gradients on the branch outputs ----
        update::zeroed(d_logits_b, rows * self.p);
        update::zeroed(d_logits_c, rows * self.c);
        update::zeroed(dhdp, self.p.max(self.c));
        for li in 0..rows {
            let i = range.start + li;
            for (probs, d_logits, cols, act) in [
                (&*probs_b, &mut *d_logits_b, self.p, a_b[i] as usize),
                (&*probs_c, &mut *d_logits_c, self.c, a_c[i] as usize),
            ] {
                let pr = &probs[li * cols..(li + 1) * cols];
                let row = &mut d_logits[li * cols..(li + 1) * cols];
                // log-prob term: d_logp * (onehot − p)
                for (slot, &q) in row.iter_mut().zip(pr) {
                    *slot = -q * d_logp[li];
                }
                row[act] += d_logp[li];
                // entropy bonus term: −coef/B · p ⊙ (dH/dp − Σ p dH/dp)
                let mut s = 0.0f32;
                for (tmp, &q) in dhdp.iter_mut().zip(pr) {
                    *tmp = -(q.clamp(PROB_FLOOR, 1.0).ln() + 1.0);
                    s += *tmp * q;
                }
                for ((slot, &q), &dh) in row.iter_mut().zip(pr).zip(dhdp.iter()) {
                    *slot += -ent_coef_b * q * (dh - s);
                }
            }
        }

        // gaussian head: interleaved (mu, log_std) gradient rows
        update::zeroed(d_mu_std, rows * 2);
        for li in 0..rows {
            d_mu_std[2 * li] = d_logp[li] * z[li] / std[li];
            let mut dls = d_logp[li] * (z[li] * z[li] - 1.0) - ent_coef_b;
            if !(LOG_STD_MIN..=LOG_STD_MAX).contains(&ls_raw[li]) {
                dls = 0.0; // clip gate
            }
            d_mu_std[2 * li + 1] = dls;
        }

        // ---- backprop through the dense stack, into the shard partial ----
        update::zeroed(g, self.size);

        // power branch — the mu/log_std biases live in two 1-wide slots, so
        // accumulate its dW/db by hand instead of through `acc_into`
        for li in 0..rows {
            g[self.b_p1_mu.off] += d_mu_std[2 * li];
            g[self.b_p1_ls.off] += d_mu_std[2 * li + 1];
            let xr = &hp[li * self.h..(li + 1) * self.h];
            for (k, &xv) in xr.iter().enumerate() {
                let base = self.w_p1.off + k * 2;
                g[base] += xv * d_mu_std[2 * li];
                g[base + 1] += xv * d_mu_std[2 * li + 1];
            }
        }
        matmul_bt_into(d_mu_std, rows, 2, seg(params, self.w_p1), self.h, d_hp, wt);
        tanh_backward(d_hp, hp);
        acc_into(g, self.w_p0, self.b_p0, h1, rows, self.t1, d_hp, self.h);
        matmul_bt_into(d_hp, rows, self.h, seg(params, self.w_p0), self.t1, d_h1_p, wt);

        // partition branch
        acc_into(g, self.w_b1, self.b_b1, hb, rows, self.h, d_logits_b, self.p);
        matmul_bt_into(d_logits_b, rows, self.p, seg(params, self.w_b1), self.h, d_hb, wt);
        tanh_backward(d_hb, hb);
        acc_into(g, self.w_b0, self.b_b0, h1, rows, self.t1, d_hb, self.h);
        matmul_bt_into(d_hb, rows, self.h, seg(params, self.w_b0), self.t1, d_h1_b, wt);

        // channel branch
        acc_into(g, self.w_c1, self.b_c1, hc, rows, self.h, d_logits_c, self.c);
        matmul_bt_into(d_logits_c, rows, self.c, seg(params, self.w_c1), self.h, d_hc, wt);
        tanh_backward(d_hc, hc);
        acc_into(g, self.w_c0, self.b_c0, h1, rows, self.t1, d_hc, self.h);
        matmul_bt_into(d_hc, rows, self.h, seg(params, self.w_c0), self.t1, d_h1_c, wt);

        // trunk
        update::zeroed(d_h1, rows * self.t1);
        for (j, slot) in d_h1.iter_mut().enumerate() {
            *slot = d_h1_p[j] + d_h1_b[j] + d_h1_c[j];
        }
        tanh_backward(d_h1, h1);
        acc_into(g, self.w_t1, self.b_t1, h0, rows, self.t0, d_h1, self.t1);
        matmul_bt_into(d_h1, rows, self.t1, seg(params, self.w_t1), self.t0, d_h0, wt);
        tanh_backward(d_h0, h0);
        acc_into(g, self.w_t0, self.b_t0, shard_state, rows, self.d, d_h0, self.t0);
    }
}

// ==================================================================== critic

/// Layout-resolved critic network (FC 4N→c0→c1→c2→1, tanh hidden).
pub(super) struct CriticProgram {
    size: usize,
    d: usize,
    c0: usize,
    c1: usize,
    c2: usize,
    precision: Precision,
    warmed: WarmedMap<CriticPrep>,
    w_0: Slot,
    b_0: Slot,
    w_1: Slot,
    b_1: Slot,
    w_2: Slot,
    b_2: Slot,
    w_3: Slot,
    b_3: Slot,
    ws: Arena<CriticWs>,
}

/// One shard's pooled `UpdateWorkspace` for the critic — same ownership
/// story as [`ActorWs`].
#[derive(Default)]
struct CriticWs {
    // forward activations
    h0: Vec<f32>,
    h1: Vec<f32>,
    h2: Vec<f32>,
    value: Vec<f32>,
    /// int8 activation codes (only the warmed Q8 forward path uses it)
    xq: Vec<u8>,
    // backward scratch
    dv: Vec<f32>,
    d_h2: Vec<f32>,
    d_h1: Vec<f32>,
    d_h0: Vec<f32>,
    /// transpose scratch for [`matmul_bt_into`]
    wt: Vec<f32>,
    // shard partials, folded shard-ascending by `run_update`
    g: Vec<f32>,
    loss_sum: f32,
}

/// Prepared per-layer forward state for one critic parameter vector.
struct CriticPrep {
    l0: PrepDense,
    l1: PrepDense,
    l2: PrepDense,
    l3: PrepDense,
}

impl CriticProgram {
    pub(super) fn from_meta(meta: &ArtifactMeta, precision: Precision) -> Result<CriticProgram> {
        let spec = meta.spec.as_ref().ok_or_else(|| {
            anyhow!("no parameter layout attached (manifest rl.specs entry missing?)")
        })?;
        let (w_0, (d, c0)) = slot2(spec, "w_0")?;
        let (w_1, (_, c1)) = slot2(spec, "w_1")?;
        let (w_2, (_, c2)) = slot2(spec, "w_2")?;
        Ok(CriticProgram {
            size: spec_size(spec),
            d,
            c0,
            c1,
            c2,
            precision,
            warmed: RwLock::new(BTreeMap::new()),
            w_0,
            b_0: slot(spec, "b_0")?.0,
            w_1,
            b_1: slot(spec, "b_1")?.0,
            w_2,
            b_2: slot(spec, "b_2")?.0,
            w_3: slot(spec, "w_3")?.0,
            b_3: slot(spec, "b_3")?.0,
            ws: Arena::new(),
        })
    }

    fn build_prep(&self, params: &[f32]) -> CriticPrep {
        let p = self.precision;
        CriticPrep {
            l0: PrepDense::build(p, seg(params, self.w_0), seg(params, self.b_0), self.d, self.c0),
            l1: PrepDense::build(p, seg(params, self.w_1), seg(params, self.b_1), self.c0, self.c1),
            l2: PrepDense::build(p, seg(params, self.w_2), seg(params, self.b_2), self.c1, self.c2),
            l3: PrepDense::build(p, seg(params, self.w_3), seg(params, self.b_3), self.c2, 1),
        }
    }

    pub(super) fn warm(&self, input: &Arc<TensorView>) -> Result<()> {
        let params = input.f32s()?;
        if params.len() != self.size {
            bail!("critic warm: expected {} parameters, got {}", self.size, params.len());
        }
        // forced-scalar f32 has nothing to precompute — the un-prepped
        // kernels are already the exact seed behavior
        if self.precision == Precision::F32 && simd::active() == Isa::Scalar {
            return Ok(());
        }
        let key = params.as_ptr() as usize;
        if lookup_warmed(&self.warmed, input).is_some() {
            return Ok(());
        }
        let prep = self.build_prep(params);
        insert_warmed(
            &self.warmed,
            key,
            Warmed {
                params: input.clone(),
                prep,
            },
        );
        Ok(())
    }

    /// Forward `b` rows into `ws` — per-row bit-identical for any batch
    /// split, same contract as [`ActorProgram::forward_into`].
    fn forward_into(
        &self,
        params: &[f32],
        state: &[f32],
        b: usize,
        prep: Option<&CriticPrep>,
        ws: &mut CriticWs,
    ) {
        run_layer_into(
            prep.map(|p| &p.l0),
            state,
            b,
            self.d,
            seg(params, self.w_0),
            seg(params, self.b_0),
            self.c0,
            Act::Tanh,
            &mut ws.h0,
            &mut ws.xq,
        );
        run_layer_into(
            prep.map(|p| &p.l1),
            &ws.h0,
            b,
            self.c0,
            seg(params, self.w_1),
            seg(params, self.b_1),
            self.c1,
            Act::Tanh,
            &mut ws.h1,
            &mut ws.xq,
        );
        run_layer_into(
            prep.map(|p| &p.l2),
            &ws.h1,
            b,
            self.c1,
            seg(params, self.w_2),
            seg(params, self.b_2),
            self.c2,
            Act::Tanh,
            &mut ws.h2,
            &mut ws.xq,
        );
        run_layer_into(
            prep.map(|p| &p.l3),
            &ws.h2,
            b,
            self.c2,
            seg(params, self.w_3),
            seg(params, self.b_3),
            1,
            Act::Linear,
            &mut ws.value,
            &mut ws.xq,
        );
    }

    /// `(params, state) -> (value,)`.
    pub(super) fn run_forward(&self, inputs: &[&TensorView]) -> Result<Vec<TensorView>> {
        expect_inputs(inputs, 2, "critic_fwd")?;
        let params = f32_in(inputs, 0, "critic_fwd")?;
        if params.len() != self.size {
            bail!("critic_fwd: expected {} parameters, got {}", self.size, params.len());
        }
        let state = f32_in(inputs, 1, "critic_fwd")?;
        if state.is_empty() || state.len() % self.d != 0 {
            bail!("critic_fwd: state length {} not a multiple of {}", state.len(), self.d);
        }
        let b = state.len() / self.d;
        let warmed = lookup_warmed(&self.warmed, inputs[0]);
        let ephemeral;
        let prep = match (&warmed, self.precision) {
            (Some(w), _) => Some(&w.prep),
            (None, Precision::Int8) => {
                ephemeral = self.build_prep(params);
                Some(&ephemeral)
            }
            (None, Precision::F32) => None,
        };
        let mut wss = self.ws.take(1);
        self.forward_into(params, state, b, prep, &mut wss[0]);
        let out = vec![TensorView::f32(wss[0].value.clone(), vec![b, 1])?];
        self.ws.put(wss);
        Ok(out)
    }

    /// One MSE + Adam step toward the sampled returns (Eq. 16):
    /// `(params, m, v, t, lr, state, returns) -> (params', m', v', loss)`.
    pub(super) fn run_update(&self, inputs: &[&TensorView]) -> Result<Vec<TensorView>> {
        let what = "critic_update";
        expect_inputs(inputs, 7, what)?;
        let params = f32_in(inputs, 0, what)?;
        let m = f32_in(inputs, 1, what)?;
        let v = f32_in(inputs, 2, what)?;
        let t = scalar_in(inputs, 3, what)?;
        let lr = scalar_in(inputs, 4, what)?;
        let state = f32_in(inputs, 5, what)?;
        let returns = f32_in(inputs, 6, what)?;
        let b = returns.len();
        if b == 0 || state.len() != b * self.d {
            bail!("{what}: state length {} vs batch {b} x dim {}", state.len(), self.d);
        }
        if params.len() != self.size || m.len() != self.size || v.len() != self.size {
            bail!("{what}: parameter/Adam state size mismatch");
        }

        let inv_b = 1.0 / b as f32;
        let shards = update::shard_count(b);
        let threads = update::effective_threads(shards);
        let mut wss = self.ws.take(shards);
        update::run_sharded(&mut wss, threads, |ws, s| {
            self.update_shard(params, state, returns, inv_b, update::shard_range(s, b), ws)
        })?;

        // deterministic shard-ascending reduction (see the actor's)
        let isa = simd::active();
        let (acc, rest) = wss.split_first_mut().expect("at least one shard");
        for ws in rest.iter() {
            simd::axpy(isa, &mut acc.g, 1.0, &ws.g);
            acc.loss_sum += ws.loss_sum;
        }
        let loss = acc.loss_sum;

        let (p2, m2, v2) = adam_step(params, &acc.g, m, v, t, lr);
        self.ws.put(wss);
        Ok(vec![
            TensorView::f32(p2, vec![self.size])?,
            TensorView::f32(m2, vec![self.size])?,
            TensorView::f32(v2, vec![self.size])?,
            TensorView::from_scalar(loss),
        ])
    }

    /// Forward + MSE loss + backward for one shard's rows — the critic
    /// half of the update engine's per-shard work.
    fn update_shard(
        &self,
        params: &[f32],
        state: &[f32],
        returns: &[f32],
        inv_b: f32,
        range: Range<usize>,
        ws: &mut CriticWs,
    ) {
        let rows = range.len();
        let shard_state = &state[range.start * self.d..range.end * self.d];
        // updates always run the un-prepped f32 kernels: the training and
        // bit-exact-resume contracts are defined on them
        self.forward_into(params, shard_state, rows, None, ws);
        let CriticWs {
            h0,
            h1,
            h2,
            value,
            dv,
            d_h2,
            d_h1,
            d_h0,
            wt,
            g,
            loss_sum,
            ..
        } = ws;

        update::zeroed(dv, rows);
        *loss_sum = 0.0;
        for li in 0..rows {
            let err = value[li] - returns[range.start + li];
            *loss_sum += err * err * inv_b;
            dv[li] = 2.0 * err * inv_b;
        }

        update::zeroed(g, self.size);
        acc_into(g, self.w_3, self.b_3, h2, rows, self.c2, dv, 1);
        matmul_bt_into(dv, rows, 1, seg(params, self.w_3), self.c2, d_h2, wt);
        tanh_backward(d_h2, h2);
        acc_into(g, self.w_2, self.b_2, h1, rows, self.c1, d_h2, self.c2);
        matmul_bt_into(d_h2, rows, self.c2, seg(params, self.w_2), self.c1, d_h1, wt);
        tanh_backward(d_h1, h1);
        acc_into(g, self.w_1, self.b_1, h0, rows, self.c0, d_h1, self.c1);
        matmul_bt_into(d_h1, rows, self.c1, seg(params, self.w_1), self.c0, d_h0, wt);
        tanh_backward(d_h0, h0);
        acc_into(g, self.w_0, self.b_0, shard_state, rows, self.d, d_h0, self.c0);
    }
}
