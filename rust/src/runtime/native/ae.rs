//! Native execution of the autoencoder compressor artifacts
//! (`{model}_ae_enc_p{i}` / `{model}_ae_dec_p{i}`): 1x1-conv channel
//! reduce/restore + Eq. (1)/(2) quantization, mirroring
//! `python/compile/autoencoder.py` `encode`/`decode` over the flat weight
//! layout (`w_enc, b_enc, w_dec, b_dec`).

use anyhow::{anyhow, bail, Result};

use super::kernels::{conv1x1, dequantize, quantize};
use super::quant8::QuantConv;
use super::simd;
use super::{expect_inputs, f32_in, scalar_in};
use crate::runtime::artifacts::ArtifactMeta;
use crate::runtime::backend::Precision;
use crate::runtime::tensor::TensorView;

/// A (model, partition-point) AE compressor resolved from the manifest:
/// feature geometry, reduced channels and quantization bit-width.
pub(super) struct AeProgram {
    ch: usize,
    ch_r: usize,
    h: usize,
    w: usize,
    bits: usize,
    weights_len: usize,
    precision: Precision,
}

impl AeProgram {
    pub(super) fn from_meta(meta: &ArtifactMeta, precision: Precision) -> Result<AeProgram> {
        let bits = meta.bits.ok_or_else(|| {
            anyhow!("no quantization bit-width attached (manifest models section missing?)")
        })?;
        if bits == 0 || bits > 16 {
            bail!("bit-width {bits} out of range 1..=16");
        }
        // enc: inputs [ae_weights, feature(1,ch,h,w)], outputs [codes(1,ch_r,h,w), lo, hi]
        // dec: inputs [ae_weights, codes(1,ch_r,h,w), lo, hi], outputs [feature(1,ch,h,w)]
        let is_enc = meta.name.contains("_ae_enc_p");
        let weights_len: usize = meta
            .inputs
            .first()
            .ok_or_else(|| anyhow!("missing ae_weights input spec"))?
            .shape
            .iter()
            .product();
        let (feat_shape, codes_shape) = if is_enc {
            (
                meta.inputs.get(1).map(|io| io.shape.clone()),
                meta.outputs.first().map(|io| io.shape.clone()),
            )
        } else {
            (
                meta.outputs.first().map(|io| io.shape.clone()),
                meta.inputs.get(1).map(|io| io.shape.clone()),
            )
        };
        let feat = feat_shape.ok_or_else(|| anyhow!("missing feature I/O spec"))?;
        let codes = codes_shape.ok_or_else(|| anyhow!("missing codes I/O spec"))?;
        if feat.len() != 4 || codes.len() != 4 || feat[2] != codes[2] || feat[3] != codes[3] {
            bail!("unexpected AE I/O shapes (feature {feat:?}, codes {codes:?})");
        }
        let prog = AeProgram {
            ch: feat[1],
            ch_r: codes[1],
            h: feat[2],
            w: feat[3],
            bits,
            weights_len,
            precision,
        };
        let expect = prog.ch * prog.ch_r + prog.ch_r + prog.ch_r * prog.ch + prog.ch;
        if weights_len != expect {
            bail!(
                "ae weight vector has {weights_len} values, layout needs {expect} \
                 (ch={}, ch'={})",
                prog.ch,
                prog.ch_r
            );
        }
        Ok(prog)
    }

    /// Offsets of (w_enc, b_enc, w_dec, b_dec) in the flat weight vector —
    /// the `ae_flatten` order of python/compile/autoencoder.py.
    fn split<'a>(&self, weights: &'a [f32]) -> (&'a [f32], &'a [f32], &'a [f32], &'a [f32]) {
        let (c, cr) = (self.ch, self.ch_r);
        let w_enc = &weights[0..c * cr];
        let b_enc = &weights[c * cr..c * cr + cr];
        let o = c * cr + cr;
        let w_dec = &weights[o..o + cr * c];
        let b_dec = &weights[o + cr * c..o + cr * c + c];
        (w_enc, b_enc, w_dec, b_dec)
    }

    /// One 1x1-conv at this program's precision. AE weights arrive as a
    /// per-call input (they are trained online and change between calls),
    /// so the int8 path packs per call — cheap at these channel counts.
    #[allow(clippy::too_many_arguments)]
    fn conv(&self, x: &[f32], c_in: usize, c_out: usize, w: &[f32], b: &[f32]) -> Vec<f32> {
        match self.precision {
            Precision::F32 => conv1x1(x, 1, c_in, self.h, self.w, w, b, c_out),
            Precision::Int8 => {
                QuantConv::pack(w, b, c_in, c_out).forward(simd::active(), x, 1, self.h, self.w)
            }
        }
    }

    fn check_weights<'a>(&self, inputs: &'a [&TensorView], what: &str) -> Result<&'a [f32]> {
        let weights = f32_in(inputs, 0, what)?;
        if weights.len() != self.weights_len {
            bail!(
                "{what}: expected {} AE weights, got {}",
                self.weights_len,
                weights.len()
            );
        }
        Ok(weights)
    }

    /// UE side: `(ae_weights, feature) -> (codes, lo, hi)`.
    pub(super) fn run_encode(&self, inputs: &[&TensorView]) -> Result<Vec<TensorView>> {
        let what = "ae_enc";
        expect_inputs(inputs, 2, what)?;
        let weights = self.check_weights(inputs, what)?;
        let feat = f32_in(inputs, 1, what)?;
        let hw = self.h * self.w;
        if feat.len() != self.ch * hw {
            bail!("{what}: feature has {} values, expected {}", feat.len(), self.ch * hw);
        }
        let (w_enc, b_enc, _, _) = self.split(weights);
        let z = self.conv(feat, self.ch, self.ch_r, w_enc, b_enc);
        let lo = z.iter().copied().fold(f32::INFINITY, f32::min);
        let hi = z.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let codes = quantize(&z, lo, hi, self.bits);
        Ok(vec![
            TensorView::f32(codes, vec![1, self.ch_r, self.h, self.w])?,
            TensorView::from_scalar(lo),
            TensorView::from_scalar(hi),
        ])
    }

    /// Edge side: `(ae_weights, codes, lo, hi) -> (feature',)`.
    pub(super) fn run_decode(&self, inputs: &[&TensorView]) -> Result<Vec<TensorView>> {
        let what = "ae_dec";
        expect_inputs(inputs, 4, what)?;
        let weights = self.check_weights(inputs, what)?;
        let codes = f32_in(inputs, 1, what)?;
        let lo = scalar_in(inputs, 2, what)?;
        let hi = scalar_in(inputs, 3, what)?;
        let hw = self.h * self.w;
        if codes.len() != self.ch_r * hw {
            bail!("{what}: codes have {} values, expected {}", codes.len(), self.ch_r * hw);
        }
        let (_, _, w_dec, b_dec) = self.split(weights);
        let z = dequantize(codes, lo, hi, self.bits);
        let feat = self.conv(&z, self.ch_r, self.ch, w_dec, b_dec);
        Ok(vec![TensorView::f32(feat, vec![1, self.ch, self.h, self.w])?])
    }
}
