//! Cache-blocked GEMM over a packed weight matrix.
//!
//! [`PackedW`] repacks a `(in_dim, out_dim)` dense weight matrix into
//! column panels of [`NR`] = 8 outputs, each panel k-contiguous, so the
//! micro-kernel streams weights linearly. [`dense_packed`] then computes
//! `act(x @ w + b)` with Mc/Kc blocking (MR = 4 rows × NR = 8 columns
//! register tile, Kc = 256 k-slab, Mc = 64 row block).
//!
//! **Bit-identity:** per output element the accumulation is exactly the
//! scalar [`super::kernels::dense`] sequence — bias prefill, then
//! k-ascending `y += x_k * w_kj` with separate mul/add (no FMA, no tree
//! reduction). Kc blocking stores and reloads the f32 partials, which is
//! exact; Mc/panel blocking only reorders independent elements. The
//! batched-forward test in `runtime/nets.rs` and the `kernel_` proptests
//! pin this bitwise against the scalar kernel.

use super::kernels::{apply_act, Act};
use super::simd::Isa;

/// Panel width (output columns per packed panel / micro-kernel tile).
pub const NR: usize = 8;
/// Micro-kernel row count.
const MR: usize = 4;
/// k-dimension slab per blocking pass.
const KC: usize = 256;
/// Row block kept hot across panels.
const MC: usize = 64;

/// A dense layer's weights repacked for the blocked GEMM, plus its bias.
/// Built once per parameter version and cached (see `ParamCache`).
#[derive(Debug, Clone)]
pub struct PackedW {
    pub in_dim: usize,
    pub out_dim: usize,
    bias: Vec<f32>,
    /// `out_dim.div_ceil(NR)` panels, each `in_dim × NR` and zero-padded
    /// in the final partial panel.
    panels: Vec<f32>,
}

impl PackedW {
    /// Pack `w` (`(in_dim, out_dim)` row-major, same layout as
    /// [`super::kernels::dense`]) and its bias.
    pub fn pack(w: &[f32], bias: &[f32], in_dim: usize, out_dim: usize) -> PackedW {
        debug_assert_eq!(w.len(), in_dim * out_dim);
        debug_assert_eq!(bias.len(), out_dim);
        let np = out_dim.div_ceil(NR);
        let mut panels = vec![0.0f32; np * in_dim * NR];
        for jp in 0..np {
            let j0 = jp * NR;
            let width = NR.min(out_dim - j0);
            let panel = &mut panels[jp * in_dim * NR..(jp + 1) * in_dim * NR];
            for k in 0..in_dim {
                let wrow = &w[k * out_dim + j0..k * out_dim + j0 + width];
                panel[k * NR..k * NR + width].copy_from_slice(wrow);
            }
        }
        PackedW {
            in_dim,
            out_dim,
            bias: bias.to_vec(),
            panels,
        }
    }
}

/// `y = act(x @ w + b)` over the packed weights — drop-in for
/// [`super::kernels::dense`] with identical f32 output.
pub fn dense_packed(isa: Isa, x: &[f32], rows: usize, pw: &PackedW, act: Act) -> Vec<f32> {
    let mut out = Vec::new();
    dense_packed_into(isa, x, rows, pw, act, &mut out);
    out
}

/// [`dense_packed`] into a caller-owned buffer (cleared and resized, so a
/// warm workspace makes the GEMM epilogue allocation-free). Bit-identical
/// to [`dense_packed`], which wraps this.
pub fn dense_packed_into(
    isa: Isa,
    x: &[f32],
    rows: usize,
    pw: &PackedW,
    act: Act,
    out: &mut Vec<f32>,
) {
    let (in_dim, out_dim) = (pw.in_dim, pw.out_dim);
    debug_assert_eq!(x.len(), rows * in_dim);
    out.clear();
    out.resize(rows * out_dim, 0.0);
    for r in 0..rows {
        out[r * out_dim..(r + 1) * out_dim].copy_from_slice(&pw.bias);
    }
    let np = out_dim.div_ceil(NR);
    let mut rc = 0usize;
    while rc < rows {
        let rend = (rc + MC).min(rows);
        let mut k0 = 0usize;
        while k0 < in_dim {
            let k1 = (k0 + KC).min(in_dim);
            for jp in 0..np {
                let j0 = jp * NR;
                let width = NR.min(out_dim - j0);
                let panel = &pw.panels[jp * in_dim * NR..(jp + 1) * in_dim * NR];
                let mut r = rc;
                while r + MR <= rend {
                    block4(isa, x, in_dim, panel, k0, k1, &mut out[..], out_dim, r, j0, width);
                    r += MR;
                }
                while r < rend {
                    block1(isa, x, in_dim, panel, k0, k1, &mut out[..], out_dim, r, j0, width);
                    r += 1;
                }
            }
            k0 = k1;
        }
        rc = rend;
    }
    apply_act(out, act);
}

// The x86 micro-kernels store full NR-wide vectors, so they are only
// entered when the panel is full width (`width == NR`) — a partial final
// panel would store past the row end. Partial panels and non-x86 ISAs
// take the portable register tile below, which handles any width.

#[allow(clippy::too_many_arguments)]
fn block4(
    isa: Isa,
    x: &[f32],
    in_dim: usize,
    panel: &[f32],
    k0: usize,
    k1: usize,
    out: &mut [f32],
    out_dim: usize,
    r: usize,
    j0: usize,
    width: usize,
) {
    #[cfg(not(target_arch = "x86_64"))]
    let _ = isa;
    #[cfg(target_arch = "x86_64")]
    if width == NR {
        match isa {
            // SAFETY: AVX2 was runtime-detected for this arm; width == NR so
            // the full-vector stores stay inside row `r + 3`'s panel columns
            Isa::Avx2 => unsafe {
                micro4_avx2(
                    x.as_ptr().add(r * in_dim),
                    in_dim,
                    panel.as_ptr(),
                    k0,
                    k1,
                    out.as_mut_ptr().add(r * out_dim + j0),
                    out_dim,
                );
                return;
            },
            // SAFETY: SSE4.1 was runtime-detected; same full-width bound
            Isa::Sse41 => unsafe {
                micro4_sse(
                    x.as_ptr().add(r * in_dim),
                    in_dim,
                    panel.as_ptr(),
                    k0,
                    k1,
                    out.as_mut_ptr().add(r * out_dim + j0),
                    out_dim,
                );
                return;
            },
            _ => {}
        }
    }
    micro_portable::<MR>(x, in_dim, panel, k0, k1, out, out_dim, r, j0, width);
}

#[allow(clippy::too_many_arguments)]
fn block1(
    isa: Isa,
    x: &[f32],
    in_dim: usize,
    panel: &[f32],
    k0: usize,
    k1: usize,
    out: &mut [f32],
    out_dim: usize,
    r: usize,
    j0: usize,
    width: usize,
) {
    #[cfg(not(target_arch = "x86_64"))]
    let _ = isa;
    #[cfg(target_arch = "x86_64")]
    if width == NR {
        match isa {
            // SAFETY: AVX2 was runtime-detected for this arm; width == NR so
            // the full-vector stores stay inside row `r`'s panel columns
            Isa::Avx2 => unsafe {
                micro1_avx2(
                    x.as_ptr().add(r * in_dim),
                    panel.as_ptr(),
                    k0,
                    k1,
                    out.as_mut_ptr().add(r * out_dim + j0),
                );
                return;
            },
            // SAFETY: SSE4.1 was runtime-detected; same full-width bound
            Isa::Sse41 => unsafe {
                micro1_sse(
                    x.as_ptr().add(r * in_dim),
                    panel.as_ptr(),
                    k0,
                    k1,
                    out.as_mut_ptr().add(r * out_dim + j0),
                );
                return;
            },
            _ => {}
        }
    }
    micro_portable::<1>(x, in_dim, panel, k0, k1, out, out_dim, r, j0, width);
}

/// Register-tile micro-kernel for any width ≤ NR — also the reference
/// semantics the x86 micros replicate lane for lane.
#[allow(clippy::too_many_arguments)]
fn micro_portable<const M: usize>(
    x: &[f32],
    in_dim: usize,
    panel: &[f32],
    k0: usize,
    k1: usize,
    out: &mut [f32],
    out_dim: usize,
    r: usize,
    j0: usize,
    width: usize,
) {
    let mut acc = [[0.0f32; NR]; M];
    for (i, a) in acc.iter_mut().enumerate() {
        let base = (r + i) * out_dim + j0;
        a[..width].copy_from_slice(&out[base..base + width]);
    }
    for k in k0..k1 {
        let wrow = &panel[k * NR..(k + 1) * NR];
        for (i, a) in acc.iter_mut().enumerate() {
            let xv = x[(r + i) * in_dim + k];
            for (slot, &wv) in a.iter_mut().zip(wrow) {
                *slot += xv * wv;
            }
        }
    }
    for (i, a) in acc.iter().enumerate() {
        let base = (r + i) * out_dim + j0;
        out[base..base + width].copy_from_slice(&a[..width]);
    }
}

// SAFETY: caller must ensure AVX2 is available and that `x` covers 4
// rows of `in_dim` through index `k1 - 1`, `panel` covers `k1 * NR`
// floats, and `out` covers 4 rows of `out_dim` with NR valid columns
// (block4 only enters at width == NR). All access is unaligned.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn micro4_avx2(
    x: *const f32,
    in_dim: usize,
    panel: *const f32,
    k0: usize,
    k1: usize,
    out: *mut f32,
    out_dim: usize,
) {
    use std::arch::x86_64::*;
    let mut acc0 = _mm256_loadu_ps(out);
    let mut acc1 = _mm256_loadu_ps(out.add(out_dim));
    let mut acc2 = _mm256_loadu_ps(out.add(2 * out_dim));
    let mut acc3 = _mm256_loadu_ps(out.add(3 * out_dim));
    for k in k0..k1 {
        let wv = _mm256_loadu_ps(panel.add(k * NR));
        acc0 = _mm256_add_ps(acc0, _mm256_mul_ps(_mm256_set1_ps(*x.add(k)), wv));
        acc1 = _mm256_add_ps(acc1, _mm256_mul_ps(_mm256_set1_ps(*x.add(in_dim + k)), wv));
        acc2 = _mm256_add_ps(acc2, _mm256_mul_ps(_mm256_set1_ps(*x.add(2 * in_dim + k)), wv));
        acc3 = _mm256_add_ps(acc3, _mm256_mul_ps(_mm256_set1_ps(*x.add(3 * in_dim + k)), wv));
    }
    _mm256_storeu_ps(out, acc0);
    _mm256_storeu_ps(out.add(out_dim), acc1);
    _mm256_storeu_ps(out.add(2 * out_dim), acc2);
    _mm256_storeu_ps(out.add(3 * out_dim), acc3);
}

// SAFETY: caller must ensure AVX2 is available, `x` valid through
// `k1 - 1`, `panel` through `k1 * NR`, and NR columns writable at `out`.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn micro1_avx2(x: *const f32, panel: *const f32, k0: usize, k1: usize, out: *mut f32) {
    use std::arch::x86_64::*;
    let mut acc = _mm256_loadu_ps(out);
    for k in k0..k1 {
        let wv = _mm256_loadu_ps(panel.add(k * NR));
        acc = _mm256_add_ps(acc, _mm256_mul_ps(_mm256_set1_ps(*x.add(k)), wv));
    }
    _mm256_storeu_ps(out, acc);
}

// SAFETY: caller must ensure SSE4.1 is available, with the same 4-row /
// `k1 * NR`-panel / NR-column bounds as `micro4_avx2`.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "sse4.1")]
unsafe fn micro4_sse(
    x: *const f32,
    in_dim: usize,
    panel: *const f32,
    k0: usize,
    k1: usize,
    out: *mut f32,
    out_dim: usize,
) {
    use std::arch::x86_64::*;
    let mut lo0 = _mm_loadu_ps(out);
    let mut hi0 = _mm_loadu_ps(out.add(4));
    let mut lo1 = _mm_loadu_ps(out.add(out_dim));
    let mut hi1 = _mm_loadu_ps(out.add(out_dim + 4));
    let mut lo2 = _mm_loadu_ps(out.add(2 * out_dim));
    let mut hi2 = _mm_loadu_ps(out.add(2 * out_dim + 4));
    let mut lo3 = _mm_loadu_ps(out.add(3 * out_dim));
    let mut hi3 = _mm_loadu_ps(out.add(3 * out_dim + 4));
    for k in k0..k1 {
        let wlo = _mm_loadu_ps(panel.add(k * NR));
        let whi = _mm_loadu_ps(panel.add(k * NR + 4));
        let x0 = _mm_set1_ps(*x.add(k));
        let x1 = _mm_set1_ps(*x.add(in_dim + k));
        let x2 = _mm_set1_ps(*x.add(2 * in_dim + k));
        let x3 = _mm_set1_ps(*x.add(3 * in_dim + k));
        lo0 = _mm_add_ps(lo0, _mm_mul_ps(x0, wlo));
        hi0 = _mm_add_ps(hi0, _mm_mul_ps(x0, whi));
        lo1 = _mm_add_ps(lo1, _mm_mul_ps(x1, wlo));
        hi1 = _mm_add_ps(hi1, _mm_mul_ps(x1, whi));
        lo2 = _mm_add_ps(lo2, _mm_mul_ps(x2, wlo));
        hi2 = _mm_add_ps(hi2, _mm_mul_ps(x2, whi));
        lo3 = _mm_add_ps(lo3, _mm_mul_ps(x3, wlo));
        hi3 = _mm_add_ps(hi3, _mm_mul_ps(x3, whi));
    }
    _mm_storeu_ps(out, lo0);
    _mm_storeu_ps(out.add(4), hi0);
    _mm_storeu_ps(out.add(out_dim), lo1);
    _mm_storeu_ps(out.add(out_dim + 4), hi1);
    _mm_storeu_ps(out.add(2 * out_dim), lo2);
    _mm_storeu_ps(out.add(2 * out_dim + 4), hi2);
    _mm_storeu_ps(out.add(3 * out_dim), lo3);
    _mm_storeu_ps(out.add(3 * out_dim + 4), hi3);
}

// SAFETY: caller must ensure SSE4.1 is available, `x` valid through
// `k1 - 1`, `panel` through `k1 * NR`, and NR columns writable at `out`.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "sse4.1")]
unsafe fn micro1_sse(x: *const f32, panel: *const f32, k0: usize, k1: usize, out: *mut f32) {
    use std::arch::x86_64::*;
    let mut lo = _mm_loadu_ps(out);
    let mut hi = _mm_loadu_ps(out.add(4));
    for k in k0..k1 {
        let xv = _mm_set1_ps(*x.add(k));
        lo = _mm_add_ps(lo, _mm_mul_ps(xv, _mm_loadu_ps(panel.add(k * NR))));
        hi = _mm_add_ps(hi, _mm_mul_ps(xv, _mm_loadu_ps(panel.add(k * NR + 4))));
    }
    _mm_storeu_ps(out, lo);
    _mm_storeu_ps(out.add(4), hi);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::native::kernels::dense;
    use crate::runtime::native::simd;

    fn fill(n: usize, mul: usize, md: usize, scale: f32) -> Vec<f32> {
        (0..n)
            .map(|i| ((i * mul % md) as f32 - md as f32 / 2.0) * scale)
            .collect()
    }

    #[test]
    fn packed_gemm_bit_identical_to_scalar_dense() {
        // shapes straddling every blocking edge: partial panels, partial
        // MR blocks, k larger than KC, empty batch
        for (rows, in_dim, out_dim) in [
            (1usize, 3usize, 4usize),
            (2, 3, 4),
            (5, 7, 9),
            (4, 300, 8),
            (70, 13, 17),
            (0, 5, 6),
            (3, 1, 1),
        ] {
            let x = fill(rows * in_dim, 37, 19, 0.13);
            let w = fill(in_dim * out_dim, 11, 23, 0.07);
            let b = fill(out_dim, 7, 13, 0.31);
            for act in [Act::Linear, Act::Tanh, Act::Relu] {
                let want = dense(&x, rows, in_dim, &w, &b, out_dim, act);
                let pw = PackedW::pack(&w, &b, in_dim, out_dim);
                for isa in simd::available() {
                    let got = dense_packed(isa, &x, rows, &pw, act);
                    assert_eq!(got, want, "{isa:?} {rows}x{in_dim}x{out_dim} {act:?}");
                }
            }
        }
    }

    #[test]
    fn packed_gemm_matches_goldens_via_dense_equivalence() {
        // the actor trunk shape the rollout engine actually runs
        let (rows, in_dim, out_dim) = (32usize, 20usize, 256usize);
        let x = fill(rows * in_dim, 29, 31, 0.11);
        let w = fill(in_dim * out_dim, 17, 41, 0.05);
        let b = fill(out_dim, 5, 11, 0.2);
        let want = dense(&x, rows, in_dim, &w, &b, out_dim, Act::Tanh);
        let pw = PackedW::pack(&w, &b, in_dim, out_dim);
        for isa in simd::available() {
            assert_eq!(dense_packed(isa, &x, rows, &pw, Act::Tanh), want, "{isa:?}");
        }
    }
}
