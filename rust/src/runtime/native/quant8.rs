//! Int8 inference kernels: `dense_q8` / `conv1x1_q8`.
//!
//! Scheme (per DESIGN.md §Native-Kernels):
//!
//! * **Weights** — per-output-channel symmetric: `s_w = amax/127`, `w_q =
//!   round_ties_even(w / s_w) ∈ [−127, 127]` (i8), packed k-contiguous
//!   per output column and zero-padded to a multiple of 16 so the SIMD
//!   dot never needs a tail mask.
//! * **Activations** — per-row (dense) / per-image (conv) asymmetric u8
//!   with the same affine map as the paper's Eq. (1) at 8 bits: `lo =
//!   min(x)`, `s_a = span/255`, `x_q = round((x − lo)·255/span)`. The
//!   calibration here is the raw min/max of the tensor being quantized
//!   (not `compress::quant::calibrate`'s (0,1) degenerate remap — a
//!   constant activation row must reconstruct exactly, so the degenerate
//!   span collapses to the 1e-12 floor instead).
//! * **Accumulate** — i32 over `u8 × i8` products ([`super::simd::dot_q8`]),
//!   exact on every ISA.
//! * **Requantize** — f32 epilogue from the algebraic identity
//!   `Σ w x ≈ Σ (s_w w_q)(lo + s_a x_q) = s_w s_a·acc + s_w lo·Σw_q + b`,
//!   using the precomputed per-column code sum `Σw_q`.
//!
//! There is no bit-identity contract for int8; instead
//! [`dense_q8_error_bound`] / [`conv1x1_q8_error_bound`] give an analytic
//! per-element bound on `|y_q8 − y_f32|` from the calibration spans, and
//! proptests hold the kernels to it over randomized ranges.

use super::kernels::{apply_act, round_ties_even, Act};
use super::simd::{self, Isa};

/// Span floor for degenerate (constant) activation tensors — mirrors the
/// Eq. (1) 1e-12 floor in `kernels::quantize`.
const SPAN_FLOOR: f32 = 1e-12;

/// Raw min/max of a tensor, skipping NaN; non-finite collapses to (0, 0).
pub fn calib_range(x: &[f32]) -> (f32, f32) {
    let mut lo = f32::INFINITY;
    let mut hi = f32::NEG_INFINITY;
    for &v in x {
        if v < lo {
            lo = v;
        }
        if v > hi {
            hi = v;
        }
    }
    if !lo.is_finite() || !hi.is_finite() {
        return (0.0, 0.0);
    }
    (lo, hi)
}

/// A dense layer quantized for int8 inference — built once per parameter
/// version and cached alongside the f32 [`super::gemm::PackedW`].
#[derive(Debug, Clone)]
pub struct QuantDense {
    pub in_dim: usize,
    pub out_dim: usize,
    k_pad: usize,
    /// `(out_dim, k_pad)` — transposed, k-contiguous per output column.
    wq_t: Vec<i8>,
    /// Per-output-channel weight scale `s_w`.
    w_scale: Vec<f32>,
    /// Per-column `Σ_k w_q` for the asymmetric-activation epilogue term.
    col_sum: Vec<i32>,
    bias: Vec<f32>,
}

impl QuantDense {
    /// Quantize `w` (`(in_dim, out_dim)` row-major, the
    /// [`super::kernels::dense`] layout).
    pub fn pack(w: &[f32], bias: &[f32], in_dim: usize, out_dim: usize) -> QuantDense {
        debug_assert_eq!(w.len(), in_dim * out_dim);
        debug_assert_eq!(bias.len(), out_dim);
        let k_pad = in_dim.div_ceil(16) * 16;
        let mut wq_t = vec![0i8; out_dim * k_pad];
        let mut w_scale = vec![1.0f32; out_dim];
        let mut col_sum = vec![0i32; out_dim];
        for j in 0..out_dim {
            let mut amax = 0.0f32;
            for k in 0..in_dim {
                let a = w[k * out_dim + j].abs();
                if a > amax {
                    amax = a;
                }
            }
            let sw = if amax > 0.0 { amax / 127.0 } else { 1.0 };
            w_scale[j] = sw;
            let col = &mut wq_t[j * k_pad..j * k_pad + in_dim];
            let mut sum = 0i32;
            for (k, q) in col.iter_mut().enumerate() {
                let code = round_ties_even(w[k * out_dim + j] / sw).clamp(-127.0, 127.0) as i8;
                *q = code;
                sum += code as i32;
            }
            col_sum[j] = sum;
        }
        QuantDense {
            in_dim,
            out_dim,
            k_pad,
            wq_t,
            w_scale,
            col_sum,
            bias: bias.to_vec(),
        }
    }

    /// `y ≈ act(x @ w + b)` with u8 activations and i32 accumulation.
    pub fn forward(&self, isa: Isa, x: &[f32], rows: usize, act: Act) -> Vec<f32> {
        let mut out = Vec::new();
        let mut xq = Vec::new();
        self.forward_into(isa, x, rows, act, &mut out, &mut xq);
        out
    }

    /// [`Self::forward`] into caller-owned output and activation-code
    /// buffers (cleared and resized) — the update engine's workspace path.
    pub fn forward_into(
        &self,
        isa: Isa,
        x: &[f32],
        rows: usize,
        act: Act,
        out: &mut Vec<f32>,
        xq: &mut Vec<u8>,
    ) {
        debug_assert_eq!(x.len(), rows * self.in_dim);
        out.clear();
        out.resize(rows * self.out_dim, 0.0);
        xq.clear();
        xq.resize(self.k_pad, 0); // tail stays zero (pads match)
        for r in 0..rows {
            let xr = &x[r * self.in_dim..(r + 1) * self.in_dim];
            let (lo, hi) = calib_range(xr);
            let span = (hi - lo).max(SPAN_FLOOR);
            let s_a = span / 255.0;
            let inv_step = 255.0 / span;
            simd::quantize_row(isa, xr, lo, inv_step, &mut xq[..self.in_dim]);
            let yr = &mut out[r * self.out_dim..(r + 1) * self.out_dim];
            for (j, y) in yr.iter_mut().enumerate() {
                let col = &self.wq_t[j * self.k_pad..(j + 1) * self.k_pad];
                let acc = simd::dot_q8(isa, xq, col);
                let sw = self.w_scale[j];
                *y = sw * s_a * acc as f32 + sw * lo * self.col_sum[j] as f32 + self.bias[j];
            }
        }
        apply_act(out, act);
    }
}

/// One-shot int8 dense — packs then forwards on the active ISA. The hot
/// paths keep a [`QuantDense`] cached instead.
pub fn dense_q8(
    x: &[f32],
    rows: usize,
    in_dim: usize,
    w: &[f32],
    b: &[f32],
    out_dim: usize,
    act: Act,
) -> Vec<f32> {
    QuantDense::pack(w, b, in_dim, out_dim).forward(simd::active(), x, rows, act)
}

/// A 1×1 convolution quantized for int8 inference.
#[derive(Debug, Clone)]
pub struct QuantConv {
    pub c_in: usize,
    pub c_out: usize,
    /// `(c_in, c_out)` i8 codes — same ci-major layout as the f32 `wmat`.
    wq: Vec<i8>,
    w_scale: Vec<f32>,
    col_sum: Vec<i32>,
    bias: Vec<f32>,
}

impl QuantConv {
    /// Quantize `wmat` (`(c_in, c_out)`, the [`super::kernels::conv1x1`]
    /// layout) per output channel.
    pub fn pack(wmat: &[f32], bias: &[f32], c_in: usize, c_out: usize) -> QuantConv {
        debug_assert_eq!(wmat.len(), c_in * c_out);
        debug_assert_eq!(bias.len(), c_out);
        let mut wq = vec![0i8; c_in * c_out];
        let mut w_scale = vec![1.0f32; c_out];
        let mut col_sum = vec![0i32; c_out];
        for co in 0..c_out {
            let mut amax = 0.0f32;
            for ci in 0..c_in {
                let a = wmat[ci * c_out + co].abs();
                if a > amax {
                    amax = a;
                }
            }
            let sw = if amax > 0.0 { amax / 127.0 } else { 1.0 };
            w_scale[co] = sw;
            let mut sum = 0i32;
            for ci in 0..c_in {
                let code =
                    round_ties_even(wmat[ci * c_out + co] / sw).clamp(-127.0, 127.0) as i8;
                wq[ci * c_out + co] = code;
                sum += code as i32;
            }
            col_sum[co] = sum;
        }
        QuantConv {
            c_in,
            c_out,
            wq,
            w_scale,
            col_sum,
            bias: bias.to_vec(),
        }
    }

    /// `y ≈ conv1x1(x, w, b)` — activations calibrated per image over the
    /// whole feature map (matching the per-tensor AE calibration).
    pub fn forward(&self, isa: Isa, x: &[f32], n: usize, h: usize, w: usize) -> Vec<f32> {
        let hw = h * w;
        debug_assert_eq!(x.len(), n * self.c_in * hw);
        let mut out = vec![0.0f32; n * self.c_out * hw];
        let mut xq = vec![0u8; self.c_in * hw];
        let mut acc = vec![0i32; hw];
        for im in 0..n {
            let img = &x[im * self.c_in * hw..(im + 1) * self.c_in * hw];
            let (lo, hi) = calib_range(img);
            let span = (hi - lo).max(SPAN_FLOOR);
            let s_a = span / 255.0;
            let inv_step = 255.0 / span;
            simd::quantize_row(isa, img, lo, inv_step, &mut xq);
            for co in 0..self.c_out {
                acc.fill(0);
                for ci in 0..self.c_in {
                    let wv = self.wq[ci * self.c_out + co] as i32;
                    if wv == 0 {
                        continue;
                    }
                    simd::accum_u8(isa, &mut acc, wv, &xq[ci * hw..(ci + 1) * hw]);
                }
                let sw = self.w_scale[co];
                let base = sw * lo * self.col_sum[co] as f32 + self.bias[co];
                let dst = &mut out[(im * self.c_out + co) * hw..(im * self.c_out + co + 1) * hw];
                for (d, &a) in dst.iter_mut().zip(&acc) {
                    *d = sw * s_a * a as f32 + base;
                }
            }
        }
        out
    }
}

/// One-shot int8 conv1x1 on the active ISA.
pub fn conv1x1_q8(
    x: &[f32],
    n: usize,
    c_in: usize,
    h: usize,
    w: usize,
    wmat: &[f32],
    b: &[f32],
    c_out: usize,
) -> Vec<f32> {
    QuantConv::pack(wmat, b, c_in, c_out).forward(simd::active(), x, n, h, w)
}

// ------------------------------------------------------- error bounds

/// Analytic per-element bound on `|dense_q8 − dense_f32|` (pre- or
/// post-activation — tanh and relu are 1-Lipschitz, so the bound
/// survives the epilogue).
///
/// Derivation: with weight step `ε_w = s_w/2` and activation step
/// `ε_x = s_a/2` (both half-ULP of their grids, activation inflated
/// slightly for the f32 rounding of the quantize map itself),
/// `|ŵ x̂ − w x| ≤ (|w| + ε_w)·ε_x + |x|·ε_w` per product; summing over k
/// and adding a relative-slack term for the f32 rounding of both the
/// reference dot and the requantize epilogue gives the bound.
pub fn dense_q8_error_bound(
    x: &[f32],
    rows: usize,
    in_dim: usize,
    w: &[f32],
    out_dim: usize,
) -> Vec<f32> {
    let mut bound = vec![0.0f32; rows * out_dim];
    // per-column weight scales, as QuantDense::pack derives them
    let mut eps_w = vec![0.0f32; out_dim];
    for j in 0..out_dim {
        let mut amax = 0.0f32;
        for k in 0..in_dim {
            let a = w[k * out_dim + j].abs();
            if a > amax {
                amax = a;
            }
        }
        let sw = if amax > 0.0 { amax / 127.0 } else { 1.0 };
        eps_w[j] = 0.5 * sw;
    }
    for r in 0..rows {
        let xr = &x[r * in_dim..(r + 1) * in_dim];
        let (lo, hi) = calib_range(xr);
        let span = (hi - lo).max(SPAN_FLOOR);
        let eps_x = 0.5 * span / 255.0 * 1.001 + 1e-7;
        for j in 0..out_dim {
            let mut s = 0.0f32;
            let mut sabs = 0.0f32;
            for (k, &xv) in xr.iter().enumerate() {
                let wv = w[k * out_dim + j];
                s += (wv.abs() + eps_w[j]) * eps_x + xv.abs() * eps_w[j];
                sabs += (wv * xv).abs();
            }
            bound[r * out_dim + j] = s * 1.001 + 1e-4 * (1.0 + sabs);
        }
    }
    bound
}

/// Analytic per-element bound on `|conv1x1_q8 − conv1x1_f32|` — same
/// derivation with per-image calibration.
pub fn conv1x1_q8_error_bound(
    x: &[f32],
    n: usize,
    c_in: usize,
    h: usize,
    w_dim: usize,
    wmat: &[f32],
    c_out: usize,
) -> Vec<f32> {
    let hw = h * w_dim;
    let mut bound = vec![0.0f32; n * c_out * hw];
    let mut eps_w = vec![0.0f32; c_out];
    for co in 0..c_out {
        let mut amax = 0.0f32;
        for ci in 0..c_in {
            let a = wmat[ci * c_out + co].abs();
            if a > amax {
                amax = a;
            }
        }
        let sw = if amax > 0.0 { amax / 127.0 } else { 1.0 };
        eps_w[co] = 0.5 * sw;
    }
    for im in 0..n {
        let img = &x[im * c_in * hw..(im + 1) * c_in * hw];
        let (lo, hi) = calib_range(img);
        let span = (hi - lo).max(SPAN_FLOOR);
        let eps_x = 0.5 * span / 255.0 * 1.001 + 1e-7;
        for co in 0..c_out {
            for p in 0..hw {
                let mut s = 0.0f32;
                let mut sabs = 0.0f32;
                for ci in 0..c_in {
                    let wv = wmat[ci * c_out + co];
                    let xv = img[ci * hw + p];
                    s += (wv.abs() + eps_w[co]) * eps_x + xv.abs() * eps_w[co];
                    sabs += (wv * xv).abs();
                }
                bound[(im * c_out + co) * hw + p] = s * 1.001 + 1e-4 * (1.0 + sabs);
            }
        }
    }
    bound
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::native::kernels::{conv1x1, dense};

    fn fill(n: usize, mul: usize, md: usize, scale: f32, off: f32) -> Vec<f32> {
        (0..n)
            .map(|i| ((i * mul % md) as f32 - md as f32 / 2.0) * scale + off)
            .collect()
    }

    #[test]
    fn dense_q8_within_analytic_bound_on_every_isa() {
        for (rows, in_dim, out_dim) in [(1usize, 3usize, 4usize), (4, 20, 13), (8, 256, 128)] {
            let x = fill(rows * in_dim, 37, 61, 0.21, 0.4);
            let w = fill(in_dim * out_dim, 11, 47, 0.06, 0.0);
            let b = fill(out_dim, 7, 13, 0.31, 0.0);
            let bound = dense_q8_error_bound(&x, rows, in_dim, &w, out_dim);
            for act in [Act::Linear, Act::Tanh] {
                let want = dense(&x, rows, in_dim, &w, &b, out_dim, act);
                let qd = QuantDense::pack(&w, &b, in_dim, out_dim);
                for isa in simd::available() {
                    let got = qd.forward(isa, &x, rows, act);
                    for (i, ((&g, &f), &eps)) in
                        got.iter().zip(&want).zip(&bound).enumerate()
                    {
                        assert!(
                            (g - f).abs() <= eps,
                            "{isa:?} {act:?} idx {i}: |{g} - {f}| > {eps}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn dense_q8_constant_row_reconstructs_exactly_enough() {
        // a constant activation row has zero-span calibration; the span
        // floor must keep it near-exact (weight quantization error only)
        let (rows, in_dim, out_dim) = (2usize, 6usize, 3usize);
        let x = vec![0.75f32; rows * in_dim];
        let w = fill(in_dim * out_dim, 13, 29, 0.1, 0.0);
        let b = vec![0.0f32; out_dim];
        let want = dense(&x, rows, in_dim, &w, &b, out_dim, Act::Linear);
        let bound = dense_q8_error_bound(&x, rows, in_dim, &w, out_dim);
        let got = dense_q8(&x, rows, in_dim, &w, &b, out_dim, Act::Linear);
        for ((&g, &f), &eps) in got.iter().zip(&want).zip(&bound) {
            assert!((g - f).abs() <= eps, "|{g} - {f}| > {eps}");
        }
    }

    #[test]
    fn conv1x1_q8_within_analytic_bound_on_every_isa() {
        let (n, c_in, h, wd, c_out) = (2usize, 3usize, 4usize, 5usize, 2usize);
        let x = fill(n * c_in * h * wd, 23, 53, 0.17, -0.2);
        let wmat = fill(c_in * c_out, 9, 17, 0.2, 0.0);
        let b = fill(c_out, 3, 7, 0.25, 0.0);
        let want = conv1x1(&x, n, c_in, h, wd, &wmat, &b, c_out);
        let bound = conv1x1_q8_error_bound(&x, n, c_in, h, wd, &wmat, c_out);
        let qc = QuantConv::pack(&wmat, &b, c_in, c_out);
        for isa in simd::available() {
            let got = qc.forward(isa, &x, n, h, wd);
            for (i, ((&g, &f), &eps)) in got.iter().zip(&want).zip(&bound).enumerate() {
                assert!((g - f).abs() <= eps, "{isa:?} idx {i}: |{g} - {f}| > {eps}");
            }
        }
    }

    #[test]
    fn quantized_weights_round_trip_within_half_step() {
        let (in_dim, out_dim) = (10usize, 6usize);
        let w = fill(in_dim * out_dim, 19, 37, 0.11, 0.0);
        let b = vec![0.0f32; out_dim];
        let qd = QuantDense::pack(&w, &b, in_dim, out_dim);
        for j in 0..out_dim {
            let sw = qd.w_scale[j];
            for k in 0..in_dim {
                let back = qd.wq_t[j * qd.k_pad + k] as f32 * sw;
                assert!((back - w[k * out_dim + j]).abs() <= 0.5 * sw + 1e-6);
            }
        }
    }
}
