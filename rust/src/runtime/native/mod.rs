//! The pure-Rust inference backend (default).
//!
//! Executes the actor / critic / autoencoder artifacts directly from their
//! flat-f32 weights and manifest layouts — no PJRT, no HLO files, fully
//! offline. The three Pallas kernels every artifact lowers through
//! ([`kernels::dense`], [`kernels::conv1x1`], [`kernels::quantize`] /
//! [`kernels::dequantize`]) are ported 1:1 from
//! `python/compile/kernels/ref.py`, and the RL forward/backward/Adam math
//! mirrors `python/compile/actor_critic.py` (validated against `jax.grad`
//! — see DESIGN.md §Kernel-Parity).
//!
//! CNN backbone segments (`*_full_*`, `*_front_*`, `*_back_*`) are not
//! interpreted natively; they require the PJRT backend (`--features
//! xla-pjrt` plus the real `xla` crate).

pub mod gemm;
pub mod kernels;
pub mod quant8;
pub mod simd;
pub mod update;

mod ae;
mod rl;

use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use super::artifacts::ArtifactMeta;
use super::backend::{Backend, ExecStats, Executable, Precision};
use super::tensor::TensorView;

use ae::AeProgram;
use rl::{ActorProgram, CriticProgram};

/// The pure-Rust interpreter backend.
#[derive(Debug, Default)]
pub struct NativeBackend {
    precision: Precision,
}

impl NativeBackend {
    pub fn new() -> NativeBackend {
        NativeBackend::default()
    }

    /// A backend whose *inference* executables (actor/critic forward, AE
    /// encode/decode) run at the given precision. Training programs
    /// (`*_update_*`) always execute f32 — the PPO/Adam math and the
    /// bit-exact checkpoint resume depend on it.
    pub fn with_precision(precision: Precision) -> NativeBackend {
        NativeBackend { precision }
    }

    pub fn precision(&self) -> Precision {
        self.precision
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &str {
        "native"
    }

    fn load(&self, meta: &ArtifactMeta) -> Result<Arc<dyn Executable>> {
        let program = Program::from_meta(meta, self.precision)
            .with_context(|| format!("building native program for '{}'", meta.name))?;
        Ok(Arc::new(NativeExecutable {
            name: meta.name.clone(),
            program,
            stats: Mutex::new(ExecStats::default()),
        }))
    }
}

/// What a given artifact computes, decided from its manifest entry.
enum Program {
    ActorFwd(ActorProgram),
    ActorUpdate(ActorProgram),
    CriticFwd(CriticProgram),
    CriticUpdate(CriticProgram),
    AeEncode(AeProgram),
    AeDecode(AeProgram),
}

impl Program {
    fn from_meta(meta: &ArtifactMeta, precision: Precision) -> Result<Program> {
        let name = meta.name.as_str();
        if name.starts_with("actor_fwd_") {
            return Ok(Program::ActorFwd(ActorProgram::from_meta(meta, precision)?));
        }
        if name.starts_with("actor_update_") {
            // updates always run f32 (bit-exact training/resume contract)
            return Ok(Program::ActorUpdate(ActorProgram::from_meta(
                meta,
                Precision::F32,
            )?));
        }
        if name.starts_with("critic_fwd_") {
            return Ok(Program::CriticFwd(CriticProgram::from_meta(meta, precision)?));
        }
        if name.starts_with("critic_update_") {
            return Ok(Program::CriticUpdate(CriticProgram::from_meta(
                meta,
                Precision::F32,
            )?));
        }
        if name.contains("_ae_enc_p") {
            return Ok(Program::AeEncode(AeProgram::from_meta(meta, precision)?));
        }
        if name.contains("_ae_dec_p") {
            return Ok(Program::AeDecode(AeProgram::from_meta(meta, precision)?));
        }
        bail!(
            "artifact '{name}' has no native program (CNN backbone segments need the PJRT \
             backend: build with --features xla-pjrt and MACCI_BACKEND=xla)"
        )
    }

    fn run(&self, inputs: &[&TensorView]) -> Result<Vec<TensorView>> {
        match self {
            Program::ActorFwd(p) => p.run_forward(inputs),
            Program::ActorUpdate(p) => p.run_update(inputs),
            Program::CriticFwd(p) => p.run_forward(inputs),
            Program::CriticUpdate(p) => p.run_update(inputs),
            Program::AeEncode(p) => p.run_encode(inputs),
            Program::AeDecode(p) => p.run_decode(inputs),
        }
    }
}

struct NativeExecutable {
    name: String,
    program: Program,
    stats: Mutex<ExecStats>,
}

impl Executable for NativeExecutable {
    fn name(&self) -> &str {
        &self.name
    }

    fn call_refs(&self, inputs: &[&TensorView]) -> Result<Vec<TensorView>> {
        let t0 = Instant::now();
        let out = self
            .program
            .run(inputs)
            .with_context(|| format!("executing {} (native)", self.name))?;
        let dt = t0.elapsed().as_nanos() as u64;
        let mut s = self.stats.lock().unwrap();
        s.calls += 1;
        s.total_ns += dt;
        Ok(out)
    }

    fn stats(&self) -> ExecStats {
        *self.stats.lock().unwrap()
    }

    fn warm(&self, input_idx: usize, input: &Arc<TensorView>) -> Result<()> {
        // only the forward programs keep warmed per-params state (packed
        // GEMM panels / int8 weights); everything else ignores the hint
        if input_idx != 0 {
            return Ok(());
        }
        match &self.program {
            Program::ActorFwd(p) => p.warm(input),
            Program::CriticFwd(p) => p.warm(input),
            _ => Ok(()),
        }
    }
}

/// Do two tensor handles share the same f32 buffer? Used to key warmed
/// per-parameter state: `ArtifactStore` memoizes loads, so one executable
/// can serve several nets — each keeps its own cached params tensor alive,
/// making the buffer address a stable identity.
pub(crate) fn same_f32_buffer(a: &TensorView, b: &TensorView) -> bool {
    match (a.f32s(), b.f32s()) {
        (Ok(x), Ok(y)) => x.as_ptr() == y.as_ptr() && x.len() == y.len(),
        _ => false,
    }
}

// ------------------------------------------------------- input helpers
pub(crate) fn expect_inputs(inputs: &[&TensorView], n: usize, what: &str) -> Result<()> {
    if inputs.len() != n {
        bail!("{what}: expected {n} inputs, got {}", inputs.len());
    }
    Ok(())
}

pub(crate) fn f32_in<'a>(inputs: &'a [&TensorView], idx: usize, what: &str) -> Result<&'a [f32]> {
    inputs
        .get(idx)
        .ok_or_else(|| anyhow!("{what}: missing input {idx}"))?
        .f32s()
        .with_context(|| format!("{what}: input {idx}"))
}

pub(crate) fn i32_in<'a>(inputs: &'a [&TensorView], idx: usize, what: &str) -> Result<&'a [i32]> {
    inputs
        .get(idx)
        .ok_or_else(|| anyhow!("{what}: missing input {idx}"))?
        .i32s()
        .with_context(|| format!("{what}: input {idx}"))
}

pub(crate) fn scalar_in(inputs: &[&TensorView], idx: usize, what: &str) -> Result<f32> {
    inputs
        .get(idx)
        .ok_or_else(|| anyhow!("{what}: missing input {idx}"))?
        .scalar()
        .with_context(|| format!("{what}: input {idx}"))
}
