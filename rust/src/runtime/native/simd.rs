//! Runtime SIMD dispatch for the native kernels.
//!
//! One ISA is detected once per process ([`active`]): AVX2, then SSE4.1
//! (via `is_x86_feature_detected!`), then a portable 8-wide manually
//! unrolled fallback. `MACCI_FORCE_SCALAR=1` pins the plain scalar loops —
//! the exact pre-SIMD reference paths — for CI and debugging.
//!
//! **Bit-identity contract (f32):** every f32 primitive here vectorizes
//! across *independent output elements only*; each element still sees the
//! scalar operation sequence — separate multiply then add (never FMA),
//! k-ascending accumulation, no tree reductions. `_mm256_add_ps(acc,
//! _mm256_mul_ps(a, x))` per lane is the same rounding as `acc + a * x`,
//! so every ISA produces bit-identical f32 output (proptested in
//! `tests/proptests.rs`). The int8 primitives accumulate in i32, where
//! addition is associative — all ISAs agree exactly there too; only the
//! f32→u8 activation quantization step ([`quantize_row`]) may differ by
//! ±1 code across ISAs, which the analytic int8 error bound absorbs.

use once_cell::sync::Lazy;

use super::kernels::round_ties_even;

/// Instruction set the kernels dispatch on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Isa {
    /// Plain scalar loops — the original reference kernels, selected by
    /// `MACCI_FORCE_SCALAR=1`.
    Scalar,
    /// Portable 8-wide manually-unrolled loops (any architecture).
    Portable,
    /// x86-64 SSE4.1 (4-wide f32, 8-wide int8 dot).
    Sse41,
    /// x86-64 AVX2 (8-wide f32, 16-wide int8 dot).
    Avx2,
}

static ACTIVE: Lazy<Isa> = Lazy::new(detect);

fn detect() -> Isa {
    if forced_scalar() {
        return Isa::Scalar;
    }
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") {
            return Isa::Avx2;
        }
        if is_x86_feature_detected!("sse4.1") {
            return Isa::Sse41;
        }
    }
    Isa::Portable
}

fn forced_scalar() -> bool {
    crate::util::config::force_scalar()
}

/// The ISA every dispatching kernel wrapper uses (detected once).
pub fn active() -> Isa {
    *ACTIVE
}

/// Every ISA that can run on this machine — lets tests exercise all
/// runnable paths regardless of which one [`active`] picked.
pub fn available() -> Vec<Isa> {
    let mut isas = vec![Isa::Scalar, Isa::Portable];
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("sse4.1") {
            isas.push(Isa::Sse41);
        }
        if is_x86_feature_detected!("avx2") {
            isas.push(Isa::Avx2);
        }
    }
    isas
}

// ------------------------------------------------------------- f32 axpy

/// `dst[i] += a * x[i]` — the inner step of the k-outer dense/matmul
/// loops. Bit-identical across ISAs (see module docs).
pub fn axpy(isa: Isa, dst: &mut [f32], a: f32, x: &[f32]) {
    debug_assert_eq!(dst.len(), x.len());
    match isa {
        Isa::Scalar => {
            for (d, &v) in dst.iter_mut().zip(x) {
                *d += a * v;
            }
        }
        Isa::Portable => axpy_portable(dst, a, x),
        // SAFETY: this arm is reachable only when detect()/available() saw
        // SSE4.1 at runtime — the one precondition of the target_feature fn
        #[cfg(target_arch = "x86_64")]
        Isa::Sse41 => unsafe { axpy_sse(dst, a, x) },
        // SAFETY: reachable only when AVX2 was detected at runtime
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => unsafe { axpy_avx2(dst, a, x) },
        #[cfg(not(target_arch = "x86_64"))]
        _ => axpy_portable(dst, a, x),
    }
}

fn axpy_portable(dst: &mut [f32], a: f32, x: &[f32]) {
    let n = dst.len();
    let head = n - n % 8;
    let (dh, dt) = dst.split_at_mut(head);
    let (xh, xt) = x.split_at(head);
    for (d, v) in dh.chunks_exact_mut(8).zip(xh.chunks_exact(8)) {
        d[0] += a * v[0];
        d[1] += a * v[1];
        d[2] += a * v[2];
        d[3] += a * v[3];
        d[4] += a * v[4];
        d[5] += a * v[5];
        d[6] += a * v[6];
        d[7] += a * v[7];
    }
    for (d, &v) in dt.iter_mut().zip(xt) {
        *d += a * v;
    }
}

// SAFETY: caller must ensure SSE4.1 is available (the dispatchers do).
// All vector access is unaligned loadu/storeu at `i`, and every loop
// guard keeps `i + 4 <= dst.len()` with `x.len() == dst.len()`.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "sse4.1")]
unsafe fn axpy_sse(dst: &mut [f32], a: f32, x: &[f32]) {
    use std::arch::x86_64::*;
    let n = dst.len();
    let va = _mm_set1_ps(a);
    let mut i = 0usize;
    while i + 4 <= n {
        let d = _mm_loadu_ps(dst.as_ptr().add(i));
        let v = _mm_loadu_ps(x.as_ptr().add(i));
        _mm_storeu_ps(dst.as_mut_ptr().add(i), _mm_add_ps(d, _mm_mul_ps(va, v)));
        i += 4;
    }
    while i < n {
        dst[i] += a * x[i];
        i += 1;
    }
}

// SAFETY: caller must ensure AVX2 is available; unaligned loadu/storeu
// only, with `i + 8 <= dst.len()` and `x.len() == dst.len()`.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn axpy_avx2(dst: &mut [f32], a: f32, x: &[f32]) {
    use std::arch::x86_64::*;
    let n = dst.len();
    let va = _mm256_set1_ps(a);
    let mut i = 0usize;
    while i + 8 <= n {
        let d = _mm256_loadu_ps(dst.as_ptr().add(i));
        let v = _mm256_loadu_ps(x.as_ptr().add(i));
        _mm256_storeu_ps(
            dst.as_mut_ptr().add(i),
            _mm256_add_ps(d, _mm256_mul_ps(va, v)),
        );
        i += 8;
    }
    while i < n {
        dst[i] += a * x[i];
        i += 1;
    }
}

// ----------------------------------------------------- f32 div-by-scalar

/// `dst[i] /= s` — the softmax normalization epilogue. One IEEE division
/// per lane, bit-identical across ISAs.
pub fn div_scalar(isa: Isa, dst: &mut [f32], s: f32) {
    match isa {
        Isa::Scalar => {
            for v in dst.iter_mut() {
                *v /= s;
            }
        }
        Isa::Portable => div_scalar_portable(dst, s),
        // SAFETY: reachable only when SSE4.1 was detected at runtime
        #[cfg(target_arch = "x86_64")]
        Isa::Sse41 => unsafe { div_scalar_sse(dst, s) },
        // SAFETY: reachable only when AVX2 was detected at runtime
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => unsafe { div_scalar_avx2(dst, s) },
        #[cfg(not(target_arch = "x86_64"))]
        _ => div_scalar_portable(dst, s),
    }
}

fn div_scalar_portable(dst: &mut [f32], s: f32) {
    let n = dst.len();
    let head = n - n % 8;
    let (dh, dt) = dst.split_at_mut(head);
    for d in dh.chunks_exact_mut(8) {
        d[0] /= s;
        d[1] /= s;
        d[2] /= s;
        d[3] /= s;
        d[4] /= s;
        d[5] /= s;
        d[6] /= s;
        d[7] /= s;
    }
    for d in dt.iter_mut() {
        *d /= s;
    }
}

// SAFETY: caller must ensure SSE4.1 is available; unaligned loadu/storeu
// only, with the loop guard keeping `i + 4 <= dst.len()`.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "sse4.1")]
unsafe fn div_scalar_sse(dst: &mut [f32], s: f32) {
    use std::arch::x86_64::*;
    let n = dst.len();
    let vs = _mm_set1_ps(s);
    let mut i = 0usize;
    while i + 4 <= n {
        let d = _mm_loadu_ps(dst.as_ptr().add(i));
        _mm_storeu_ps(dst.as_mut_ptr().add(i), _mm_div_ps(d, vs));
        i += 4;
    }
    while i < n {
        dst[i] /= s;
        i += 1;
    }
}

// SAFETY: caller must ensure AVX2 is available; unaligned loadu/storeu
// only, with the loop guard keeping `i + 8 <= dst.len()`.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn div_scalar_avx2(dst: &mut [f32], s: f32) {
    use std::arch::x86_64::*;
    let n = dst.len();
    let vs = _mm256_set1_ps(s);
    let mut i = 0usize;
    while i + 8 <= n {
        let d = _mm256_loadu_ps(dst.as_ptr().add(i));
        _mm256_storeu_ps(dst.as_mut_ptr().add(i), _mm256_div_ps(d, vs));
        i += 8;
    }
    while i < n {
        dst[i] /= s;
        i += 1;
    }
}

// ---------------------------------------------------------- int8 dot

/// `Σ_i x[i] * w[i]` over u8 activations × i8 weights, i32 accumulate.
/// Exactly the same integer result on every ISA (i32 addition is
/// associative; per-pair products fit i32: 255·127·pair ≤ 64770 per madd
/// lane, and the k-dimension here is ≤ a few hundred).
pub fn dot_q8(isa: Isa, x: &[u8], w: &[i8]) -> i32 {
    debug_assert_eq!(x.len(), w.len());
    match isa {
        Isa::Scalar | Isa::Portable => dot_q8_portable(x, w),
        // SAFETY: reachable only when SSE4.1 was detected at runtime
        #[cfg(target_arch = "x86_64")]
        Isa::Sse41 => unsafe { dot_q8_sse(x, w) },
        // SAFETY: reachable only when AVX2 was detected at runtime
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => unsafe { dot_q8_avx2(x, w) },
        #[cfg(not(target_arch = "x86_64"))]
        _ => dot_q8_portable(x, w),
    }
}

fn dot_q8_portable(x: &[u8], w: &[i8]) -> i32 {
    x.iter().zip(w).map(|(&a, &b)| a as i32 * b as i32).sum()
}

// SAFETY: caller must ensure SSE4.1 is available; 64-bit unaligned loads
// at `i` with the guard keeping `i + 8 <= x.len()` and equal lengths.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "sse4.1")]
unsafe fn dot_q8_sse(x: &[u8], w: &[i8]) -> i32 {
    use std::arch::x86_64::*;
    let n = x.len();
    let mut acc = _mm_setzero_si128();
    let mut i = 0usize;
    while i + 8 <= n {
        let xv = _mm_loadl_epi64(x.as_ptr().add(i) as *const __m128i);
        let wv = _mm_loadl_epi64(w.as_ptr().add(i) as *const __m128i);
        let x16 = _mm_cvtepu8_epi16(xv);
        let w16 = _mm_cvtepi8_epi16(wv);
        acc = _mm_add_epi32(acc, _mm_madd_epi16(x16, w16));
        i += 8;
    }
    let mut sum = hsum_epi32_sse(acc);
    while i < n {
        sum += x[i] as i32 * w[i] as i32;
        i += 1;
    }
    sum
}

// SAFETY: register-only lane arithmetic — no memory access; caller must
// ensure SSE4.1 is available.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "sse4.1")]
unsafe fn hsum_epi32_sse(v: std::arch::x86_64::__m128i) -> i32 {
    use std::arch::x86_64::*;
    let s = _mm_add_epi32(v, _mm_shuffle_epi32::<0b00_00_11_10>(v));
    let s = _mm_add_epi32(s, _mm_shuffle_epi32::<0b00_00_00_01>(s));
    _mm_cvtsi128_si32(s)
}

// SAFETY: caller must ensure AVX2 is available; 128-bit unaligned loads
// at `i` with the guard keeping `i + 16 <= x.len()` and equal lengths.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn dot_q8_avx2(x: &[u8], w: &[i8]) -> i32 {
    use std::arch::x86_64::*;
    let n = x.len();
    let mut acc = _mm256_setzero_si256();
    let mut i = 0usize;
    while i + 16 <= n {
        // widen to i16 before madd — _mm_maddubs_epi16 saturates and is
        // deliberately avoided
        let xv = _mm_loadu_si128(x.as_ptr().add(i) as *const __m128i);
        let wv = _mm_loadu_si128(w.as_ptr().add(i) as *const __m128i);
        let x16 = _mm256_cvtepu8_epi16(xv);
        let w16 = _mm256_cvtepi8_epi16(wv);
        acc = _mm256_add_epi32(acc, _mm256_madd_epi16(x16, w16));
        i += 16;
    }
    let lo = _mm256_castsi256_si128(acc);
    let hi = _mm256_extracti128_si256::<1>(acc);
    let mut sum = hsum_epi32_sse(_mm_add_epi32(lo, hi));
    while i < n {
        sum += x[i] as i32 * w[i] as i32;
        i += 1;
    }
    sum
}

// ------------------------------------------------------- int8 accumulate

/// `acc[i] += wv * x[i]` over u8 activations — the conv1x1 int8 inner
/// loop (channel-broadcast weight against a pixel row). Exact i32 math on
/// every ISA.
pub fn accum_u8(isa: Isa, acc: &mut [i32], wv: i32, x: &[u8]) {
    debug_assert_eq!(acc.len(), x.len());
    match isa {
        // SAFETY: reachable only when AVX2 was detected at runtime
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => unsafe { accum_u8_avx2(acc, wv, x) },
        _ => {
            for (a, &v) in acc.iter_mut().zip(x) {
                *a += wv * v as i32;
            }
        }
    }
}

// SAFETY: caller must ensure AVX2 is available; unaligned loads/stores
// at `i` with the guard keeping `i + 8 <= acc.len()` and equal lengths.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn accum_u8_avx2(acc: &mut [i32], wv: i32, x: &[u8]) {
    use std::arch::x86_64::*;
    let n = acc.len();
    let vw = _mm256_set1_epi32(wv);
    let mut i = 0usize;
    while i + 8 <= n {
        let xv = _mm_loadl_epi64(x.as_ptr().add(i) as *const __m128i);
        let x32 = _mm256_cvtepu8_epi32(xv);
        let a = _mm256_loadu_si256(acc.as_ptr().add(i) as *const __m256i);
        _mm256_storeu_si256(
            acc.as_mut_ptr().add(i) as *mut __m256i,
            _mm256_add_epi32(a, _mm256_mullo_epi32(x32, vw)),
        );
        i += 8;
    }
    while i < n {
        acc[i] += wv * x[i] as i32;
        i += 1;
    }
}

// -------------------------------------------------- activation quantize

/// Quantize one f32 row to u8 codes: `q = round((x - lo) * inv_step)`
/// clamped to [0, 255], round-ties-even (AVX2 uses `_mm256_cvtps_epi32`,
/// which rounds ties-even under the default MXCSR mode). This is the one
/// int8 step where ISAs may differ by ±1 ulp of the scaled input landing
/// on the far side of a tie — covered by the analytic error bound, not a
/// bit-identity contract.
pub fn quantize_row(isa: Isa, x: &[f32], lo: f32, inv_step: f32, out: &mut [u8]) {
    debug_assert_eq!(x.len(), out.len());
    match isa {
        // SAFETY: reachable only when AVX2 was detected at runtime
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => unsafe { quantize_row_avx2(x, lo, inv_step, out) },
        _ => {
            for (o, &v) in out.iter_mut().zip(x) {
                *o = quantize_one(v, lo, inv_step);
            }
        }
    }
}

#[inline]
fn quantize_one(v: f32, lo: f32, inv_step: f32) -> u8 {
    round_ties_even(((v - lo) * inv_step).clamp(0.0, 255.0)) as u8
}

// SAFETY: caller must ensure AVX2 is available; unaligned loads at `i`
// bounded by `i + 8 <= x.len()`, stores into a local stack buffer, and
// `out` writes go through the bounds-checked slice index.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn quantize_row_avx2(x: &[f32], lo: f32, inv_step: f32, out: &mut [u8]) {
    use std::arch::x86_64::*;
    let n = x.len();
    let vlo = _mm256_set1_ps(lo);
    let vs = _mm256_set1_ps(inv_step);
    let zero = _mm256_setzero_ps();
    let top = _mm256_set1_ps(255.0);
    let mut tmp = [0i32; 8];
    let mut i = 0usize;
    while i + 8 <= n {
        let v = _mm256_loadu_ps(x.as_ptr().add(i));
        let t = _mm256_mul_ps(_mm256_sub_ps(v, vlo), vs);
        // max/min with the clamp bound second: NaN inputs collapse to the
        // bound, matching scalar clamp-then-cast saturation closely enough
        // for the error-bound contract (calibration never emits NaN)
        let t = _mm256_min_ps(_mm256_max_ps(t, zero), top);
        let q = _mm256_cvtps_epi32(t);
        _mm256_storeu_si256(tmp.as_mut_ptr() as *mut __m256i, q);
        for (j, &code) in tmp.iter().enumerate() {
            out[i + j] = code as u8;
        }
        i += 8;
    }
    while i < n {
        out[i] = quantize_one(x[i], lo, inv_step);
        i += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn available_includes_scalar_and_portable() {
        let isas = available();
        assert!(isas.contains(&Isa::Scalar));
        assert!(isas.contains(&Isa::Portable));
        assert!(isas.contains(&active()) || active() == Isa::Scalar);
    }

    #[test]
    fn axpy_matches_scalar_on_every_isa() {
        let x: Vec<f32> = (0..37).map(|i| (i as f32 * 0.7).sin()).collect();
        let base: Vec<f32> = (0..37).map(|i| (i as f32 * 0.3).cos()).collect();
        let mut want = base.clone();
        axpy(Isa::Scalar, &mut want, 1.37, &x);
        for isa in available() {
            let mut got = base.clone();
            axpy(isa, &mut got, 1.37, &x);
            assert_eq!(got, want, "{isa:?}");
        }
    }

    #[test]
    fn div_scalar_matches_scalar_on_every_isa() {
        let base: Vec<f32> = (0..29).map(|i| (i as f32 * 0.9).sin() + 2.0).collect();
        let mut want = base.clone();
        div_scalar(Isa::Scalar, &mut want, 3.7);
        for isa in available() {
            let mut got = base.clone();
            div_scalar(isa, &mut got, 3.7);
            assert_eq!(got, want, "{isa:?}");
        }
    }

    #[test]
    fn dot_q8_exact_on_every_isa() {
        let x: Vec<u8> = (0..45).map(|i| (i * 37 % 256) as u8).collect();
        let w: Vec<i8> = (0..45).map(|i| ((i * 53 % 255) as i32 - 127) as i8).collect();
        let want = dot_q8_portable(&x, &w);
        for isa in available() {
            assert_eq!(dot_q8(isa, &x, &w), want, "{isa:?}");
        }
    }

    #[test]
    fn accum_u8_exact_on_every_isa() {
        let x: Vec<u8> = (0..21).map(|i| (i * 91 % 256) as u8).collect();
        let base: Vec<i32> = (0..21).map(|i| i as i32 * 1000 - 9000).collect();
        for wv in [-127i32, -3, 0, 5, 127] {
            let mut want = base.clone();
            accum_u8(Isa::Scalar, &mut want, wv, &x);
            for isa in available() {
                let mut got = base.clone();
                accum_u8(isa, &mut got, wv, &x);
                assert_eq!(got, want, "{isa:?} wv={wv}");
            }
        }
    }

    #[test]
    fn quantize_row_within_one_code_of_scalar() {
        let x: Vec<f32> = (0..33).map(|i| (i as f32 * 0.41).sin() * 3.0).collect();
        let (lo, span) = (-3.0f32, 6.0f32);
        let inv_step = 255.0 / span;
        let mut want = vec![0u8; x.len()];
        quantize_row(Isa::Scalar, &x, lo, inv_step, &mut want);
        for isa in available() {
            let mut got = vec![0u8; x.len()];
            quantize_row(isa, &x, lo, inv_step, &mut got);
            for (i, (&g, &w)) in got.iter().zip(&want).enumerate() {
                assert!(
                    (g as i32 - w as i32).abs() <= 1,
                    "{isa:?} idx {i}: {g} vs {w}"
                );
            }
        }
    }
}
