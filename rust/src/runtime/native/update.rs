//! The data-parallel PPO update engine: fixed row sharding, a named
//! worker pool, and a deterministic shard-ascending gradient reduction.
//!
//! **Thread-count invariance.** A minibatch of `b` rows is cut into
//! `shard_count(b)` contiguous shards of [`SHARD_ROWS`] rows each — a
//! partition that depends only on `b`, never on the worker count. Each
//! shard produces its own gradient partial in its own pooled workspace,
//! and the caller folds the partials
//! together in ascending shard order. Workers only decide *when* a
//! shard's partial gets computed, never *what* is summed with what, so
//! the update is bit-identical for 1 vs N workers — the same contract
//! PR 4's rollout engine established for lane chunking (DESIGN.md
//! §Update-Engine). For `b ≤ SHARD_ROWS` there is a single shard and the
//! engine reproduces the original serial accumulation exactly.
//!
//! **Workspace arena.** [`Arena`] keeps per-shard scratch alive across
//! update calls (gradient partials, forward activations, backward
//! temporaries), so steady-state training allocates nothing beyond the
//! output tensors the executable ABI returns.
//!
//! The requested worker count travels as a thread-local scoped by
//! [`with_threads`] — `ActorNet`/`CriticNet` set it around their
//! executable calls from `TrainConfig::update_threads`, so the shared,
//! memoized update programs need no per-caller state.

use std::cell::Cell;
use std::ops::Range;
use std::sync::Mutex;

use anyhow::{anyhow, Result};

/// Fixed shard width in minibatch rows. Part of the numeric contract:
/// changing it regroups the gradient reduction and thus changes training
/// bit-streams (like editing the loss), so it is a constant, not a knob.
pub const SHARD_ROWS: usize = 32;

/// Number of shards a `b`-row minibatch is cut into.
pub fn shard_count(b: usize) -> usize {
    b.div_ceil(SHARD_ROWS)
}

/// Row range of shard `s` (the final shard may be short).
pub fn shard_range(s: usize, b: usize) -> Range<usize> {
    s * SHARD_ROWS..((s + 1) * SHARD_ROWS).min(b)
}

thread_local! {
    /// Worker count requested by the calling net for the current update
    /// executable call; 0 means "not set, use the process default".
    static REQUESTED: Cell<usize> = const { Cell::new(0) };
}

/// Scope a requested update worker count around `f` (0 = auto). Restores
/// the previous request on exit so nested calls compose.
pub fn with_threads<R>(threads: usize, f: impl FnOnce() -> R) -> R {
    struct Restore(usize);
    impl Drop for Restore {
        fn drop(&mut self) {
            REQUESTED.with(|c| c.set(self.0));
        }
    }
    let _restore = Restore(REQUESTED.with(|c| c.replace(threads)));
    f()
}

/// Resolve the worker count for a `shards`-shard update: the scoped
/// request when one is set, else `MACCI_UPDATE_THREADS`, else the
/// machine's parallelism — always clamped to `1..=shards`. Mirrors the
/// `rollout_threads` resolution in `rl::rollout`.
pub fn effective_threads(shards: usize) -> usize {
    let req = REQUESTED.with(|c| c.get());
    let t = if req == 0 {
        crate::util::config::update_threads().unwrap_or_else(|| {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        })
    } else {
        req
    };
    t.clamp(1, shards.max(1))
}

/// Run `f(workspace, shard_index)` once per shard on up to `threads`
/// named `update-{i}` workers. Shards are assigned to workers in fixed
/// contiguous chunks (the rollout engine's `chunks_mut` idiom); with one
/// worker everything runs inline on the caller. `f` must be infallible —
/// validate inputs before sharding.
pub fn run_sharded<W, F>(workspaces: &mut [W], threads: usize, f: F) -> Result<()>
where
    W: Send,
    F: Fn(&mut W, usize) + Sync,
{
    let shards = workspaces.len();
    let threads = threads.clamp(1, shards.max(1));
    if threads == 1 {
        for (s, ws) in workspaces.iter_mut().enumerate() {
            f(ws, s);
        }
        return Ok(());
    }
    let chunk = shards.div_ceil(threads);
    std::thread::scope(|scope| -> Result<()> {
        let mut handles = Vec::with_capacity(threads);
        for (i, slab) in workspaces.chunks_mut(chunk).enumerate() {
            let f = &f;
            let h = std::thread::Builder::new()
                .name(format!("update-{i}"))
                .spawn_scoped(scope, move || {
                    for (j, ws) in slab.iter_mut().enumerate() {
                        f(ws, i * chunk + j);
                    }
                })?;
            handles.push(h);
        }
        for h in handles {
            h.join().map_err(|_| anyhow!("update worker panicked"))?;
        }
        Ok(())
    })
}

/// A pool of reusable per-shard workspaces. `take` hands out `n`
/// (recycled first, `Default` for the shortfall), `put` returns them;
/// the pool never shrinks below the high-water shard count, which keeps
/// steady-state updates allocation-free.
pub struct Arena<W> {
    pool: Mutex<Vec<W>>,
}

impl<W: Default> Arena<W> {
    pub fn new() -> Arena<W> {
        Arena {
            pool: Mutex::new(Vec::new()),
        }
    }

    pub fn take(&self, n: usize) -> Vec<W> {
        let mut pool = self.pool.lock().unwrap();
        let have = pool.len().min(n);
        let mut out: Vec<W> = pool.drain(pool.len() - have..).collect();
        drop(pool);
        out.resize_with(n, W::default);
        out
    }

    pub fn put(&self, workspaces: Vec<W>) {
        self.pool.lock().unwrap().extend(workspaces);
    }
}

impl<W: Default> Default for Arena<W> {
    fn default() -> Self {
        Arena::new()
    }
}

/// Reset `buf` to `n` zeros, keeping its capacity (the arena's buffers
/// warm up once and then never reallocate).
pub fn zeroed(buf: &mut Vec<f32>, n: usize) {
    buf.clear();
    buf.resize(n, 0.0);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_partition_covers_batch_exactly() {
        for b in [1usize, 31, 32, 33, 64, 100, 256, 511] {
            let s = shard_count(b);
            assert_eq!(shard_range(0, b).start, 0);
            assert_eq!(shard_range(s - 1, b).end, b);
            let mut covered = 0usize;
            for i in 0..s {
                let r = shard_range(i, b);
                assert_eq!(r.start, covered, "b={b} shard {i} contiguous");
                assert!(!r.is_empty());
                assert!(r.len() <= SHARD_ROWS);
                covered = r.end;
            }
            assert_eq!(covered, b);
        }
    }

    #[test]
    fn small_batches_are_single_shard() {
        // the serial-equivalence guarantee: b ≤ SHARD_ROWS never shards
        for b in 1..=SHARD_ROWS {
            assert_eq!(shard_count(b), 1);
        }
        assert_eq!(shard_count(SHARD_ROWS + 1), 2);
    }

    #[test]
    fn with_threads_scopes_and_restores() {
        assert_eq!(REQUESTED.with(|c| c.get()), 0);
        let seen = with_threads(3, || {
            let inner = with_threads(7, || REQUESTED.with(|c| c.get()));
            assert_eq!(inner, 7);
            REQUESTED.with(|c| c.get())
        });
        assert_eq!(seen, 3);
        assert_eq!(REQUESTED.with(|c| c.get()), 0);
    }

    #[test]
    fn effective_threads_clamps_to_shards() {
        with_threads(8, || {
            assert_eq!(effective_threads(1), 1);
            assert_eq!(effective_threads(3), 3);
            assert_eq!(effective_threads(100), 8);
        });
        with_threads(1, || assert_eq!(effective_threads(64), 1));
    }

    #[test]
    fn run_sharded_is_worker_count_invariant() {
        // every worker count must produce the same per-shard results in
        // the same slots; only scheduling may differ
        let shards = 11;
        for threads in [1usize, 2, 4, 8, 16] {
            let mut ws: Vec<(usize, String)> = vec![(0, String::new()); shards];
            run_sharded(&mut ws, threads, |slot, s| {
                slot.0 = s * s + 1;
                slot.1 = std::thread::current().name().unwrap_or("main").to_string();
            })
            .unwrap();
            for (s, slot) in ws.iter().enumerate() {
                assert_eq!(slot.0, s * s + 1, "threads={threads} shard {s}");
                if threads > 1 {
                    assert!(slot.1.starts_with("update-"), "unnamed worker: {}", slot.1);
                }
            }
        }
    }

    #[test]
    fn arena_recycles_workspaces() {
        let arena: Arena<Vec<f32>> = Arena::new();
        let mut first = arena.take(3);
        for w in &mut first {
            w.resize(64, 1.0);
        }
        let caps: Vec<usize> = first.iter().map(|w| w.capacity()).collect();
        arena.put(first);
        let again = arena.take(3);
        let caps2: Vec<usize> = again.iter().map(|w| w.capacity()).collect();
        assert_eq!(caps, caps2, "recycled buffers keep their capacity");
        // asking for more than pooled tops up with defaults
        arena.put(again);
        assert_eq!(arena.take(5).len(), 5);
    }

    #[test]
    fn zeroed_keeps_capacity() {
        let mut v = Vec::with_capacity(128);
        v.resize(100, 7.0f32);
        zeroed(&mut v, 64);
        assert_eq!(v.len(), 64);
        assert!(v.iter().all(|&x| x == 0.0));
        assert!(v.capacity() >= 128);
    }
}
