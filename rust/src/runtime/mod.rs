//! The runtime layer: pluggable execution of the AOT artifacts produced by
//! `python/compile/aot.py`.
//!
//! * [`backend`] — the [`backend::Backend`] / [`backend::Executable`] seam
//!   every execution substrate implements.
//! * [`native`] — the default pure-Rust interpreter: executes the
//!   actor/critic/autoencoder artifacts from flat-f32 weights and manifest
//!   layouts, fully offline.
//! * `client` (cargo feature `xla-pjrt`) — the PJRT CPU client plus an
//!   executable cache (each HLO module is parsed + compiled exactly once
//!   per process); required for the CNN backbone segments.
//! * [`artifacts`] — the `artifacts/manifest.json` index: artifact names,
//!   I/O signatures, network parameter layouts, model/weight metadata,
//!   plus the built-in native demo manifest.
//! * [`spec`] — flat-parameter layouts (the Rust `ParamSpec` mirror).
//! * [`tensor`] — the host tensors crossing the backend boundary, with
//!   shape checks at the edge.
//! * [`nets`] — typed handles over the actor/critic artifacts (forward and
//!   PPO-update calls).

pub mod artifacts;
pub mod backend;
#[cfg(feature = "xla-pjrt")]
pub mod client;
pub mod native;
pub mod nets;
pub mod spec;
pub mod tensor;
