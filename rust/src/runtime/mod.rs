//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the request/training path.
//!
//! * [`client`] — the PJRT CPU client plus an executable cache (each HLO
//!   module is parsed + compiled exactly once per process).
//! * [`artifacts`] — the `artifacts/manifest.json` index: artifact names,
//!   I/O signatures, network parameter layouts, model/weight metadata.
//! * [`tensor`] — `Vec<f32>` ⇄ `xla::Literal` conversion helpers with shape
//!   checks at the boundary.
//! * [`nets`] — typed handles over the actor/critic artifacts (forward and
//!   PPO-update calls) and backbone/AE segment executables.

pub mod artifacts;
pub mod client;
pub mod nets;
pub mod tensor;
