//! Baseline policies and the shared [`Policy`] trait used for evaluation.
//!
//! Paper baselines (Sec. 6.3.1): **Local** (everything on-device, no edge)
//! and **JALAD** (same MAHPPO agent, JALAD compressor profile — built via
//! [`crate::profiles::DeviceProfile::jalad_variant`], not here). The extra
//! Random / FixedSplit / EdgeRaw policies serve as sanity anchors and for
//! ablations.

use anyhow::Result;

use super::mahppo::EvalStats;
use crate::env::mdp::MultiAgentEnv;
use crate::env::{Action, HybridAction};
use crate::util::rng::Rng;

/// Anything that can drive the joint environment.
pub trait Policy {
    fn act(&mut self, state: &[f32], env: &MultiAgentEnv) -> Result<Action>;
    fn name(&self) -> &str;
}

/// Which built-in baseline to construct.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PolicyKind {
    /// Execute every task fully on the UE (paper's "Local").
    Local,
    /// Uniform-random partition/channel, random power.
    Random,
    /// Offload the raw input (b = 0) at full power.
    EdgeRaw,
    /// Always split at a fixed partition point.
    FixedSplit(usize),
}

/// A stateless/heuristic baseline policy.
pub struct BaselinePolicy {
    kind: PolicyKind,
    rng: Rng,
    label: String,
}

impl BaselinePolicy {
    pub fn new(kind: PolicyKind, seed: u64) -> BaselinePolicy {
        let label = match kind {
            PolicyKind::Local => "local".to_string(),
            PolicyKind::Random => "random".to_string(),
            PolicyKind::EdgeRaw => "edge_raw".to_string(),
            PolicyKind::FixedSplit(b) => format!("fixed_split_{b}"),
        };
        BaselinePolicy {
            kind,
            rng: Rng::new(seed),
            label,
        }
    }
}

impl Policy for BaselinePolicy {
    fn act(&mut self, _state: &[f32], env: &MultiAgentEnv) -> Result<Action> {
        let n = env.n_ues();
        let n_choices = env.profile.n_choices;
        let n_channels = env.cfg.n_channels;
        let p_max = env.cfg.p_max;
        let action = (0..n)
            .map(|i| match self.kind {
                PolicyKind::Local => {
                    HybridAction::new(env.profile.local_choice(), 0, 0.0, p_max)
                }
                PolicyKind::Random => HybridAction::new(
                    self.rng.below(n_choices),
                    self.rng.below(n_channels),
                    self.rng.normal() as f32,
                    p_max,
                ),
                PolicyKind::EdgeRaw => HybridAction::new(0, i % n_channels, 10.0, p_max),
                PolicyKind::FixedSplit(b) => {
                    HybridAction::new(b.min(n_choices - 1), i % n_channels, 2.0, p_max)
                }
            })
            .collect();
        Ok(action)
    }

    fn name(&self) -> &str {
        &self.label
    }
}

/// Roll a policy through `episodes` full episodes; aggregates per-task
/// latency/energy (Fig. 11 metrics) and episode rewards (Fig. 8 scale).
pub fn evaluate_policy(
    policy: &mut dyn Policy,
    env: &mut MultiAgentEnv,
    episodes: usize,
) -> Result<EvalStats> {
    let mut stats = EvalStats::default();
    for _ in 0..episodes {
        let mut state = env.reset();
        let mut ep_reward = 0.0;
        loop {
            let action = policy.act(&state, env)?;
            let r = env.step(&action);
            ep_reward += r.reward;
            if r.done {
                break;
            }
            state = r.state;
        }
        let t = env.totals();
        stats.avg_latency += t.avg_latency();
        stats.avg_energy += t.avg_energy();
        stats.avg_reward += ep_reward;
        stats.episodes += 1;
    }
    let e = stats.episodes.max(1) as f64;
    stats.avg_latency /= e;
    stats.avg_energy /= e;
    stats.avg_reward /= e;
    Ok(stats)
}

/// Cumulative-reward trace of a policy (baseline curves on Fig. 8).
pub fn reward_trace(
    policy: &mut dyn Policy,
    env: &mut MultiAgentEnv,
    episodes: usize,
) -> Result<Vec<f64>> {
    let mut out = Vec::with_capacity(episodes);
    for _ in 0..episodes {
        let mut state = env.reset();
        let mut ep_reward = 0.0;
        loop {
            let action = policy.act(&state, env)?;
            let r = env.step(&action);
            ep_reward += r.reward;
            if r.done {
                break;
            }
            state = r.state;
        }
        out.push(ep_reward);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::scenario::ScenarioConfig;
    use crate::profiles::DeviceProfile;

    fn env(n: usize) -> MultiAgentEnv {
        let cfg = ScenarioConfig {
            n_ues: n,
            ..Default::default()
        }
        .quick(4.0);
        MultiAgentEnv::new(DeviceProfile::synthetic(), cfg, 5).unwrap()
    }

    #[test]
    fn local_policy_matches_profile_costs() {
        let mut e = env(3);
        let mut p = BaselinePolicy::new(PolicyKind::Local, 0);
        let stats = evaluate_policy(&mut p, &mut e, 2).unwrap();
        assert!((stats.avg_latency - 0.05).abs() < 1e-9);
        assert!((stats.avg_energy - 0.107).abs() < 1e-9);
        assert!(stats.avg_reward < 0.0);
    }

    #[test]
    fn random_policy_obeys_action_space() {
        let mut e = env(4);
        let mut p = BaselinePolicy::new(PolicyKind::Random, 1);
        for _ in 0..50 {
            let s = e.state();
            let a = p.act(&s, &e).unwrap();
            for h in &a {
                assert!(h.b < e.profile.n_choices);
                assert!(h.c < e.cfg.n_channels);
                assert!(h.p_watts > 0.0 && h.p_watts <= e.cfg.p_max);
            }
        }
    }

    #[test]
    fn fixed_split_beats_local_at_close_range() {
        // at eval distance 50 m with few UEs, splitting at a deep cut
        // should cost less energy than full local on the synthetic profile
        let cfg = ScenarioConfig {
            n_ues: 2,
            eval_mode: true,
            eval_tasks: 10,
            ..Default::default()
        };
        let mut e = MultiAgentEnv::new(DeviceProfile::synthetic(), cfg, 9).unwrap();
        let mut local_p = BaselinePolicy::new(PolicyKind::Local, 0);
        let l = evaluate_policy(&mut local_p, &mut e, 1).unwrap();
        let mut split = BaselinePolicy::new(PolicyKind::FixedSplit(2), 0);
        let s = evaluate_policy(&mut split, &mut e, 1).unwrap();
        assert!(
            s.avg_energy < l.avg_energy,
            "split {} vs local {}",
            s.avg_energy,
            l.avg_energy
        );
    }
}
