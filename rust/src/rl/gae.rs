//! Returns (Eq. 15) and generalized advantage estimation (Eq. 18).
//!
//! The buffer may span several episodes and may end mid-episode; `done`
//! flags delimit episodes, and a `bootstrap` value V(s_T) continues the
//! tail when the last transition is not terminal (the paper sets
//! V(s_{t+1}) = 0 past the horizon; mid-buffer truncation bootstraps with
//! the critic as is standard for PPO).

/// Discounted sampled returns V'(s_t) = Σ_{t'≥t} γ^{t'-t} r_{t'} (Eq. 15).
pub fn discounted_returns(rewards: &[f64], dones: &[bool], gamma: f64, bootstrap: f64) -> Vec<f32> {
    let n = rewards.len();
    let mut out = vec![0.0f32; n];
    let mut acc = bootstrap;
    for t in (0..n).rev() {
        if dones[t] {
            acc = 0.0;
        }
        acc = rewards[t] + gamma * acc;
        out[t] = acc as f32;
    }
    out
}

/// GAE(γ, λ) advantages (Eq. 18): Â_t = Σ (γλ)^k δ_{t+k},
/// δ_t = r_t + γ V(s_{t+1}) − V(s_t), episode-delimited.
pub fn gae_advantages(
    rewards: &[f64],
    values: &[f32],
    dones: &[bool],
    gamma: f64,
    lam: f64,
    bootstrap: f64,
) -> Vec<f32> {
    let n = rewards.len();
    assert_eq!(values.len(), n);
    assert_eq!(dones.len(), n);
    let mut adv = vec![0.0f32; n];
    let mut gae = 0.0f64;
    for t in (0..n).rev() {
        let (next_v, next_nonterminal) = if dones[t] {
            (0.0, 0.0)
        } else if t + 1 < n {
            (values[t + 1] as f64, 1.0)
        } else {
            (bootstrap, 1.0)
        };
        let delta = rewards[t] + gamma * next_v - values[t] as f64;
        gae = delta + gamma * lam * next_nonterminal * gae;
        if dones[t] {
            gae = delta;
        }
        adv[t] = gae as f32;
    }
    adv
}

/// Normalize advantages to zero mean / unit std (standard PPO practice;
/// stabilizes the shared-trajectory multi-actor updates).
pub fn normalize(adv: &mut [f32]) {
    if adv.len() < 2 {
        return;
    }
    let n = adv.len() as f64;
    let mean = adv.iter().map(|&x| x as f64).sum::<f64>() / n;
    let var = adv.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / n;
    let std = var.sqrt().max(1e-8);
    for x in adv.iter_mut() {
        *x = ((*x as f64 - mean) / std) as f32;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::forall;

    #[test]
    fn returns_single_episode() {
        let r = [1.0, 1.0, 1.0];
        let d = [false, false, true];
        let v = discounted_returns(&r, &d, 0.5, 99.0);
        // episode ends at t=2 so bootstrap is ignored
        assert!((v[2] - 1.0).abs() < 1e-6);
        assert!((v[1] - 1.5).abs() < 1e-6);
        assert!((v[0] - 1.75).abs() < 1e-6);
    }

    #[test]
    fn returns_bootstrap_on_truncation() {
        let r = [1.0];
        let d = [false];
        let v = discounted_returns(&r, &d, 0.9, 10.0);
        assert!((v[0] - 10.0).abs() < 1e-5); // 1 + 0.9 * 10 = 10
    }

    #[test]
    fn episode_boundary_blocks_flow() {
        let r = [5.0, 1.0];
        let d = [true, true];
        let v = discounted_returns(&r, &d, 0.9, 0.0);
        assert!((v[0] - 5.0).abs() < 1e-6, "no leakage across done");
        assert!((v[1] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn gae_with_lambda_one_matches_returns_minus_values() {
        // λ = 1 ⇒ Â_t = V'(s_t) − V(s_t) (telescoping), per episode
        forall(
            21,
            100,
            |g| {
                let n = g.usize_in(2, 20);
                let rewards: Vec<f64> = (0..n).map(|_| g.f64_in(-2.0, 2.0)).collect();
                let values: Vec<f32> = (0..n).map(|_| g.f64_in(-1.0, 1.0) as f32).collect();
                let mut dones = vec![false; n];
                dones[n - 1] = true;
                (rewards, values, dones)
            },
            |(rewards, values, dones)| {
                let gamma = 0.95;
                let adv = gae_advantages(rewards, values, dones, gamma, 1.0, 0.0);
                let ret = discounted_returns(rewards, dones, gamma, 0.0);
                for t in 0..rewards.len() {
                    let expect = ret[t] - values[t];
                    if (adv[t] - expect).abs() > 1e-3 {
                        return Err(format!("t={t}: {} vs {expect}", adv[t]));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn gae_lambda_zero_is_td_error() {
        let rewards = [1.0, -1.0, 0.5];
        let values = [0.2f32, 0.1, -0.3];
        let dones = [false, false, true];
        let adv = gae_advantages(&rewards, &values, &dones, 0.9, 0.0, 0.0);
        assert!((adv[0] - (1.0 + 0.9 * 0.1 - 0.2) as f32).abs() < 1e-6);
        assert!((adv[2] - (0.5 - (-0.3)) as f32).abs() < 1e-6);
    }

    #[test]
    fn multi_episode_buffer_equals_per_episode_computation() {
        // a buffer holding several done-delimited episodes must yield
        // exactly the returns/advantages of computing each episode alone
        let rewards = [1.0, -0.5, 2.0, 0.3, -1.0, 0.7, 0.2];
        let values = [0.1f32, -0.2, 0.4, 0.0, 0.3, -0.1, 0.2];
        let dones = [false, false, true, false, true, false, true];
        let (gamma, lam) = (0.93, 0.9);
        let ret = discounted_returns(&rewards, &dones, gamma, 123.0);
        let adv = gae_advantages(&rewards, &values, &dones, gamma, lam, 123.0);
        // episodes: [0..3), [3..5), [5..7) — all terminal, bootstrap unused
        let mut off = 0;
        for ep in [3usize, 2, 2] {
            let r = &rewards[off..off + ep];
            let v = &values[off..off + ep];
            let mut d = vec![false; ep];
            d[ep - 1] = true;
            let ret_ep = discounted_returns(r, &d, gamma, 0.0);
            let adv_ep = gae_advantages(r, v, &d, gamma, lam, 0.0);
            assert_eq!(&ret[off..off + ep], &ret_ep[..], "returns, episode at {off}");
            assert_eq!(&adv[off..off + ep], &adv_ep[..], "advantages, episode at {off}");
            off += ep;
        }
    }

    #[test]
    fn truncated_tail_bootstraps_and_head_is_unaffected() {
        // buffer = [full episode][truncated tail]: the tail continues
        // through the bootstrap, the completed head must be blind to it
        let rewards = [1.0, 2.0, 0.5, 0.5];
        let values = [0.0f32, 0.0, 0.1, 0.2];
        let dones = [false, true, false, false];
        let (gamma, lam) = (0.9, 0.95);
        let with_b = gae_advantages(&rewards, &values, &dones, gamma, lam, 10.0);
        let without_b = gae_advantages(&rewards, &values, &dones, gamma, lam, 0.0);
        assert_eq!(&with_b[..2], &without_b[..2], "head blind to tail bootstrap");
        assert!(with_b[3] > without_b[3], "tail must use the bootstrap");
        let ret = discounted_returns(&rewards, &dones, gamma, 10.0);
        // tail return: 0.5 + 0.9*(0.5 + 0.9*10) = 9.05
        assert!((ret[2] - 9.05).abs() < 1e-5, "got {}", ret[2]);
        // head return unaffected: 1 + 0.9*2 = 2.8
        assert!((ret[0] - 2.8).abs() < 1e-6);
    }

    #[test]
    fn normalization_is_standard() {
        let mut adv = vec![1.0f32, 2.0, 3.0, 4.0];
        normalize(&mut adv);
        let mean: f32 = adv.iter().sum::<f32>() / 4.0;
        assert!(mean.abs() < 1e-6);
        let var: f32 = adv.iter().map(|x| x * x).sum::<f32>() / 4.0;
        assert!((var - 1.0).abs() < 1e-4);
    }
}
