//! The trajectory buffer **M** of Algorithm 1.
//!
//! Stores joint transitions (state, per-UE hybrid actions + log-probs,
//! reward, critic value, done). Once full, [`TrajectoryBuffer::finish`]
//! computes returns (Eq. 15) and GAE advantages (Eq. 18), after which
//! minibatches can be drawn for the PPO epochs; `clear` empties it for the
//! next collection round ("Clear memories in M").

use super::gae;
use crate::util::rng::Rng;

/// One joint environment transition.
#[derive(Debug, Clone)]
pub struct Transition {
    pub state: Vec<f32>,
    /// Per-UE discrete partition choices.
    pub a_b: Vec<i32>,
    /// Per-UE discrete channel choices.
    pub a_c: Vec<i32>,
    /// Per-UE raw (pre-squash) power actions.
    pub a_p: Vec<f32>,
    /// Per-UE hybrid log π_old(a|s).
    pub log_prob: Vec<f32>,
    pub reward: f64,
    pub value: f32,
    pub done: bool,
}

/// A minibatch view, columnar per actor.
#[derive(Debug, Clone, Default)]
pub struct Minibatch {
    /// Flattened states (batch × state_dim).
    pub states: Vec<f32>,
    /// `returns[i]` — critic regression targets.
    pub returns: Vec<f32>,
    /// Per-actor columns, each `batch` long: indexed `[ue][i]`.
    pub a_b: Vec<Vec<i32>>,
    pub a_c: Vec<Vec<i32>>,
    pub a_p: Vec<Vec<f32>>,
    pub old_logp: Vec<Vec<f32>>,
    pub adv: Vec<f32>,
}

pub struct TrajectoryBuffer {
    pub capacity: usize,
    pub n_ues: usize,
    pub state_dim: usize,
    transitions: Vec<Transition>,
    returns: Vec<f32>,
    advantages: Vec<f32>,
    finished: bool,
}

impl TrajectoryBuffer {
    pub fn new(capacity: usize, n_ues: usize) -> TrajectoryBuffer {
        TrajectoryBuffer {
            capacity,
            n_ues,
            state_dim: 4 * n_ues,
            transitions: Vec::with_capacity(capacity),
            returns: Vec::new(),
            advantages: Vec::new(),
            finished: false,
        }
    }

    pub fn len(&self) -> usize {
        self.transitions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.transitions.is_empty()
    }

    pub fn is_full(&self) -> bool {
        self.transitions.len() >= self.capacity
    }

    pub fn push(&mut self, t: Transition) {
        debug_assert_eq!(t.state.len(), self.state_dim);
        debug_assert_eq!(t.a_b.len(), self.n_ues);
        debug_assert!(!self.is_full(), "buffer overflow — check is_full() first");
        self.transitions.push(t);
        self.finished = false;
    }

    /// Compute returns + advantages. `bootstrap` is V(s_T) of the state
    /// following the last stored transition (0.0 if it was terminal).
    pub fn finish(&mut self, gamma: f64, lam: f64, bootstrap: f64, normalize_adv: bool) {
        let rewards: Vec<f64> = self.transitions.iter().map(|t| t.reward).collect();
        let values: Vec<f32> = self.transitions.iter().map(|t| t.value).collect();
        let dones: Vec<bool> = self.transitions.iter().map(|t| t.done).collect();
        self.returns = gae::discounted_returns(&rewards, &dones, gamma, bootstrap);
        self.advantages = gae::gae_advantages(&rewards, &values, &dones, gamma, lam, bootstrap);
        if normalize_adv {
            gae::normalize(&mut self.advantages);
        }
        self.finished = true;
    }

    /// Draw a uniform minibatch of `batch` transitions (Algorithm 1's
    /// "Sample B samples from M"). Requires `finish` first.
    pub fn sample_minibatch(&self, batch: usize, rng: &mut Rng) -> Minibatch {
        assert!(self.finished, "call finish() before sampling");
        assert!(batch <= self.len(), "batch {batch} > buffer {}", self.len());
        let idx = rng.sample_indices(self.len(), batch);
        self.gather(&idx)
    }

    fn gather(&self, idx: &[usize]) -> Minibatch {
        let n = self.n_ues;
        let mut mb = Minibatch {
            states: Vec::with_capacity(idx.len() * self.state_dim),
            returns: Vec::with_capacity(idx.len()),
            a_b: vec![Vec::with_capacity(idx.len()); n],
            a_c: vec![Vec::with_capacity(idx.len()); n],
            a_p: vec![Vec::with_capacity(idx.len()); n],
            old_logp: vec![Vec::with_capacity(idx.len()); n],
            adv: Vec::with_capacity(idx.len()),
        };
        for &i in idx {
            let t = &self.transitions[i];
            mb.states.extend_from_slice(&t.state);
            mb.returns.push(self.returns[i]);
            mb.adv.push(self.advantages[i]);
            for u in 0..n {
                mb.a_b[u].push(t.a_b[u]);
                mb.a_c[u].push(t.a_c[u]);
                mb.a_p[u].push(t.a_p[u]);
                mb.old_logp[u].push(t.log_prob[u]);
            }
        }
        mb
    }

    /// "Clear memories in M."
    pub fn clear(&mut self) {
        self.transitions.clear();
        self.returns.clear();
        self.advantages.clear();
        self.finished = false;
    }

    pub fn mean_value(&self) -> f64 {
        if self.is_empty() {
            return 0.0;
        }
        self.transitions.iter().map(|t| t.value as f64).sum::<f64>() / self.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn transition(n: usize, reward: f64, done: bool) -> Transition {
        Transition {
            state: vec![0.5; 4 * n],
            a_b: vec![1; n],
            a_c: vec![0; n],
            a_p: vec![0.1; n],
            log_prob: vec![-1.0; n],
            reward,
            value: 0.0,
            done,
        }
    }

    #[test]
    fn fill_finish_sample_clear() {
        let mut buf = TrajectoryBuffer::new(8, 3);
        for i in 0..8 {
            buf.push(transition(3, -(i as f64), i == 7));
        }
        assert!(buf.is_full());
        buf.finish(0.95, 0.95, 0.0, true);
        let mut rng = Rng::new(1);
        let mb = buf.sample_minibatch(4, &mut rng);
        assert_eq!(mb.states.len(), 4 * 12);
        assert_eq!(mb.a_b.len(), 3);
        assert_eq!(mb.a_b[0].len(), 4);
        assert_eq!(mb.adv.len(), 4);
        buf.clear();
        assert!(buf.is_empty());
    }

    #[test]
    #[should_panic(expected = "finish")]
    fn sampling_unfinished_panics() {
        let mut buf = TrajectoryBuffer::new(4, 2);
        buf.push(transition(2, 0.0, false));
        let mut rng = Rng::new(1);
        let _ = buf.sample_minibatch(1, &mut rng);
    }

    #[test]
    fn minibatch_columns_align() {
        let mut buf = TrajectoryBuffer::new(4, 2);
        for i in 0..4 {
            let mut t = transition(2, i as f64, i == 3);
            t.a_b = vec![i as i32, (i + 10) as i32];
            buf.push(t);
        }
        buf.finish(0.9, 0.9, 0.0, false);
        let mut rng = Rng::new(2);
        let mb = buf.sample_minibatch(4, &mut rng);
        for k in 0..4 {
            // actor 1's b action is always actor 0's + 10
            assert_eq!(mb.a_b[1][k], mb.a_b[0][k] + 10);
        }
    }
}
