//! The trajectory buffer **M** of Algorithm 1, laid out in *lanes*.
//!
//! Stores joint transitions (state, per-UE hybrid actions + log-probs,
//! reward, critic value, done). Each lane is the time-ordered trajectory of
//! one [`crate::rl::rollout::RolloutEngine`] env; returns (Eq. 15) and GAE
//! advantages (Eq. 18) are computed **per lane** with a per-lane bootstrap
//! — credit never flows across lane boundaries, only along each lane's own
//! timeline. After [`TrajectoryBuffer::finish_lanes`] the lanes are
//! flattened (lane-major) and minibatches can be drawn for the PPO epochs;
//! `clear` empties it for the next collection round ("Clear memories in
//! M"). A 1-lane buffer is exactly the classic serial buffer.

use super::gae;
use crate::util::rng::Rng;

/// One joint environment transition.
#[derive(Debug, Clone)]
pub struct Transition {
    pub state: Vec<f32>,
    /// Per-UE discrete partition choices.
    pub a_b: Vec<i32>,
    /// Per-UE discrete channel choices.
    pub a_c: Vec<i32>,
    /// Per-UE raw (pre-squash) power actions.
    pub a_p: Vec<f32>,
    /// Per-UE hybrid log π_old(a|s).
    pub log_prob: Vec<f32>,
    pub reward: f64,
    pub value: f32,
    pub done: bool,
}

/// A minibatch view, columnar per actor.
#[derive(Debug, Clone, Default)]
pub struct Minibatch {
    /// Flattened states (batch × state_dim).
    pub states: Vec<f32>,
    /// `returns[i]` — critic regression targets.
    pub returns: Vec<f32>,
    /// Per-actor columns, each `batch` long: indexed `[ue][i]`.
    pub a_b: Vec<Vec<i32>>,
    pub a_c: Vec<Vec<i32>>,
    pub a_p: Vec<Vec<f32>>,
    pub old_logp: Vec<Vec<f32>>,
    pub adv: Vec<f32>,
}

pub struct TrajectoryBuffer {
    pub capacity: usize,
    pub n_ues: usize,
    pub state_dim: usize,
    /// Per-lane staging, time-ordered within each lane.
    lanes: Vec<Vec<Transition>>,
    /// Lane-major flattened transitions, built by `finish_lanes`.
    flat: Vec<Transition>,
    returns: Vec<f32>,
    advantages: Vec<f32>,
    finished: bool,
    /// Reused index buffer for `sample_minibatch_into` — the PPO epoch
    /// loop draws hundreds of minibatches per collection, so the draw
    /// itself should not allocate.
    idx_scratch: Vec<usize>,
}

impl TrajectoryBuffer {
    /// The classic single-lane (serial) buffer.
    pub fn new(capacity: usize, n_ues: usize) -> TrajectoryBuffer {
        Self::with_lanes(capacity, n_ues, 1)
    }

    /// A buffer fed by `n_lanes` independent rollout lanes.
    pub fn with_lanes(capacity: usize, n_ues: usize, n_lanes: usize) -> TrajectoryBuffer {
        assert!(n_lanes >= 1, "need at least one lane");
        TrajectoryBuffer {
            capacity,
            n_ues,
            state_dim: 4 * n_ues,
            lanes: vec![Vec::new(); n_lanes],
            flat: Vec::with_capacity(capacity),
            returns: Vec::new(),
            advantages: Vec::new(),
            finished: false,
            idx_scratch: Vec::new(),
        }
    }

    pub fn n_lanes(&self) -> usize {
        self.lanes.len()
    }

    pub fn len(&self) -> usize {
        self.flat.len() + self.lanes.iter().map(Vec::len).sum::<usize>()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn is_full(&self) -> bool {
        self.len() >= self.capacity
    }

    pub fn push(&mut self, t: Transition) {
        self.push_to(0, t);
    }

    /// Append one transition to `lane`'s timeline.
    pub fn push_to(&mut self, lane: usize, t: Transition) {
        debug_assert_eq!(t.state.len(), self.state_dim);
        debug_assert_eq!(t.a_b.len(), self.n_ues);
        debug_assert!(!self.is_full(), "buffer overflow — check is_full() first");
        assert!(!self.finished, "clear() a finished buffer before refilling");
        self.lanes[lane].push(t);
    }

    /// Bulk-append one lane's collected transitions (rollout workers hand
    /// over whole per-lane trajectories at the end of a collection).
    pub fn extend_lane(&mut self, lane: usize, ts: Vec<Transition>) {
        assert!(!self.finished, "clear() a finished buffer before refilling");
        if let Some(t) = ts.first() {
            debug_assert_eq!(t.state.len(), self.state_dim);
        }
        self.lanes[lane].extend(ts);
    }

    /// Compute returns + advantages for a single-lane buffer. `bootstrap`
    /// is V(s_T) of the state following the last stored transition (0.0 if
    /// it was terminal).
    pub fn finish(&mut self, gamma: f64, lam: f64, bootstrap: f64, normalize_adv: bool) {
        assert_eq!(self.lanes.len(), 1, "multi-lane buffers need finish_lanes");
        self.finish_lanes(gamma, lam, &[bootstrap], normalize_adv);
    }

    /// Compute returns + advantages **per lane** (one bootstrap per lane),
    /// then flatten lane-major for minibatch sampling. Advantage
    /// normalization, when enabled, is global over the whole buffer —
    /// exactly the serial behavior for one lane.
    pub fn finish_lanes(&mut self, gamma: f64, lam: f64, bootstraps: &[f64], normalize_adv: bool) {
        assert_eq!(bootstraps.len(), self.lanes.len(), "one bootstrap per lane");
        assert!(!self.finished, "buffer already finished — clear() first");
        for (lane, &bootstrap) in self.lanes.iter_mut().zip(bootstraps) {
            let rewards: Vec<f64> = lane.iter().map(|t| t.reward).collect();
            let values: Vec<f32> = lane.iter().map(|t| t.value).collect();
            let dones: Vec<bool> = lane.iter().map(|t| t.done).collect();
            self.returns
                .extend(gae::discounted_returns(&rewards, &dones, gamma, bootstrap));
            self.advantages.extend(gae::gae_advantages(
                &rewards, &values, &dones, gamma, lam, bootstrap,
            ));
            self.flat.append(lane);
        }
        if normalize_adv {
            gae::normalize(&mut self.advantages);
        }
        self.finished = true;
    }

    /// Draw a uniform minibatch of `batch` transitions (Algorithm 1's
    /// "Sample B samples from M"). Requires `finish` first.
    pub fn sample_minibatch(&self, batch: usize, rng: &mut Rng) -> Minibatch {
        assert!(self.finished, "call finish() before sampling");
        assert!(batch <= self.len(), "batch {batch} > buffer {}", self.len());
        let idx = rng.sample_indices(self.len(), batch);
        let mut mb = Minibatch::default();
        self.gather_into(&idx, &mut mb);
        mb
    }

    /// [`TrajectoryBuffer::sample_minibatch`] into caller-owned buffers:
    /// the draw reads the exact same RNG stream positions, but the index
    /// scratch and every minibatch column reuse their capacity, so the
    /// PPO epoch loop samples allocation-free after the first round.
    pub fn sample_minibatch_into(&mut self, batch: usize, rng: &mut Rng, mb: &mut Minibatch) {
        assert!(self.finished, "call finish() before sampling");
        assert!(batch <= self.len(), "batch {batch} > buffer {}", self.len());
        let len = self.len();
        let mut idx = std::mem::take(&mut self.idx_scratch);
        rng.sample_indices_into(len, batch, &mut idx);
        self.gather_into(&idx, mb);
        self.idx_scratch = idx;
    }

    fn gather_into(&self, idx: &[usize], mb: &mut Minibatch) {
        let n = self.n_ues;
        mb.states.clear();
        mb.returns.clear();
        mb.adv.clear();
        mb.a_b.resize_with(n, Vec::new);
        mb.a_c.resize_with(n, Vec::new);
        mb.a_p.resize_with(n, Vec::new);
        mb.old_logp.resize_with(n, Vec::new);
        for u in 0..n {
            mb.a_b[u].clear();
            mb.a_c[u].clear();
            mb.a_p[u].clear();
            mb.old_logp[u].clear();
        }
        for &i in idx {
            let t = &self.flat[i];
            mb.states.extend_from_slice(&t.state);
            mb.returns.push(self.returns[i]);
            mb.adv.push(self.advantages[i]);
            for u in 0..n {
                mb.a_b[u].push(t.a_b[u]);
                mb.a_c[u].push(t.a_c[u]);
                mb.a_p[u].push(t.a_p[u]);
                mb.old_logp[u].push(t.log_prob[u]);
            }
        }
    }

    /// The advantages in flattened (lane-major) order; requires `finish`.
    pub fn advantages(&self) -> &[f32] {
        assert!(self.finished, "call finish() before reading advantages");
        &self.advantages
    }

    /// The returns in flattened (lane-major) order; requires `finish`.
    pub fn returns(&self) -> &[f32] {
        assert!(self.finished, "call finish() before reading returns");
        &self.returns
    }

    /// "Clear memories in M."
    pub fn clear(&mut self) {
        for lane in &mut self.lanes {
            lane.clear();
        }
        self.flat.clear();
        self.returns.clear();
        self.advantages.clear();
        self.finished = false;
    }

    pub fn mean_value(&self) -> f64 {
        if self.is_empty() {
            return 0.0;
        }
        let staged: f64 = self
            .lanes
            .iter()
            .flatten()
            .chain(self.flat.iter())
            .map(|t| t.value as f64)
            .sum();
        staged / self.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn transition(n: usize, reward: f64, done: bool) -> Transition {
        Transition {
            state: vec![0.5; 4 * n],
            a_b: vec![1; n],
            a_c: vec![0; n],
            a_p: vec![0.1; n],
            log_prob: vec![-1.0; n],
            reward,
            value: 0.0,
            done,
        }
    }

    #[test]
    fn fill_finish_sample_clear() {
        let mut buf = TrajectoryBuffer::new(8, 3);
        for i in 0..8 {
            buf.push(transition(3, -(i as f64), i == 7));
        }
        assert!(buf.is_full());
        buf.finish(0.95, 0.95, 0.0, true);
        let mut rng = Rng::new(1);
        let mb = buf.sample_minibatch(4, &mut rng);
        assert_eq!(mb.states.len(), 4 * 12);
        assert_eq!(mb.a_b.len(), 3);
        assert_eq!(mb.a_b[0].len(), 4);
        assert_eq!(mb.adv.len(), 4);
        buf.clear();
        assert!(buf.is_empty());
    }

    #[test]
    #[should_panic(expected = "finish")]
    fn sampling_unfinished_panics() {
        let mut buf = TrajectoryBuffer::new(4, 2);
        buf.push(transition(2, 0.0, false));
        let mut rng = Rng::new(1);
        let _ = buf.sample_minibatch(1, &mut rng);
    }

    fn rewarded(n: usize, reward: f64, value: f32, done: bool) -> Transition {
        Transition {
            value,
            ..transition(n, reward, done)
        }
    }

    #[test]
    fn lane_advantages_match_independent_single_lane_buffers() {
        // two lanes finished together must produce exactly the advantages
        // and returns of two independent single-lane buffers — credit
        // assignment never crosses a lane boundary
        let lane_a: Vec<(f64, f32, bool)> =
            vec![(1.0, 0.2, false), (-2.0, 0.5, false), (3.0, -0.1, true)];
        let lane_b: Vec<(f64, f32, bool)> = vec![(100.0, 1.0, false), (50.0, -2.0, false)];
        let (ba, bb) = (0.0, 7.5); // lane B truncates mid-episode

        let mut multi = TrajectoryBuffer::with_lanes(8, 2, 2);
        for &(r, v, d) in &lane_a {
            multi.push_to(0, rewarded(2, r, v, d));
        }
        for &(r, v, d) in &lane_b {
            multi.push_to(1, rewarded(2, r, v, d));
        }
        multi.finish_lanes(0.9, 0.8, &[ba, bb], false);

        let mut solo_a = TrajectoryBuffer::new(4, 2);
        for &(r, v, d) in &lane_a {
            solo_a.push(rewarded(2, r, v, d));
        }
        solo_a.finish(0.9, 0.8, ba, false);
        let mut solo_b = TrajectoryBuffer::new(4, 2);
        for &(r, v, d) in &lane_b {
            solo_b.push(rewarded(2, r, v, d));
        }
        solo_b.finish(0.9, 0.8, bb, false);

        let expect_adv: Vec<f32> = solo_a
            .advantages()
            .iter()
            .chain(solo_b.advantages())
            .copied()
            .collect();
        let expect_ret: Vec<f32> = solo_a
            .returns()
            .iter()
            .chain(solo_b.returns())
            .copied()
            .collect();
        assert_eq!(multi.advantages(), &expect_adv[..]);
        assert_eq!(multi.returns(), &expect_ret[..]);
    }

    #[test]
    fn lane_boundary_blocks_credit_even_without_done() {
        // lane A ends truncated (done = false); a huge lane-B reward placed
        // right after it in the flat layout must not bleed into lane A
        let mk = |b_reward: f64| {
            let mut buf = TrajectoryBuffer::with_lanes(4, 1, 2);
            buf.push_to(0, rewarded(1, 1.0, 0.0, false));
            buf.push_to(0, rewarded(1, 1.0, 0.0, false));
            buf.push_to(1, rewarded(1, b_reward, 0.0, false));
            buf.push_to(1, rewarded(1, b_reward, 0.0, false));
            buf.finish_lanes(0.99, 0.95, &[0.0, 0.0], false);
            (buf.advantages()[..2].to_vec(), buf.returns()[..2].to_vec())
        };
        assert_eq!(mk(1e6), mk(-1e6), "lane A must be blind to lane B");
    }

    #[test]
    fn one_lane_buffer_is_the_serial_buffer() {
        let mut a = TrajectoryBuffer::new(4, 2);
        let mut b = TrajectoryBuffer::with_lanes(4, 2, 1);
        for i in 0..4 {
            a.push(rewarded(2, -(i as f64), 0.3, i == 2));
            b.push_to(0, rewarded(2, -(i as f64), 0.3, i == 2));
        }
        a.finish(0.95, 0.9, 2.0, true);
        b.finish_lanes(0.95, 0.9, &[2.0], true);
        assert_eq!(a.advantages(), b.advantages());
        assert_eq!(a.returns(), b.returns());
    }

    #[test]
    #[should_panic(expected = "one bootstrap per lane")]
    fn finish_lanes_requires_matching_bootstraps() {
        let mut buf = TrajectoryBuffer::with_lanes(4, 1, 2);
        buf.push_to(0, transition(1, 0.0, false));
        buf.finish_lanes(0.9, 0.9, &[0.0], false);
    }

    #[test]
    fn reused_minibatch_matches_allocating_draws_epoch_after_epoch() {
        // regression: the into- variant must read the same RNG stream and
        // produce the same samples as the allocating draw on EVERY epoch —
        // stale contents from the previous round must never leak through
        // the reused columns
        let mut buf = TrajectoryBuffer::new(8, 2);
        for i in 0..8 {
            let mut t = transition(2, i as f64, i == 7);
            t.state = (0..8).map(|j| (i * 8 + j) as f32).collect();
            t.a_b = vec![i as i32, i as i32 + 10];
            t.log_prob = vec![-(i as f32), -2.0 * i as f32];
            buf.push(t);
        }
        buf.finish(0.9, 0.9, 0.0, true);
        let mut fresh_rng = Rng::new(33);
        let mut reuse_rng = Rng::new(33);
        let mut mb = Minibatch::default();
        let mut warm_cap = 0usize;
        for epoch in 0..4 {
            let fresh = buf.sample_minibatch(5, &mut fresh_rng);
            buf.sample_minibatch_into(5, &mut reuse_rng, &mut mb);
            assert_eq!(fresh.states, mb.states, "epoch {epoch}");
            assert_eq!(fresh.returns, mb.returns);
            assert_eq!(fresh.a_b, mb.a_b);
            assert_eq!(fresh.a_c, mb.a_c);
            assert_eq!(fresh.a_p, mb.a_p);
            assert_eq!(fresh.old_logp, mb.old_logp);
            assert_eq!(fresh.adv, mb.adv);
            if epoch == 0 {
                warm_cap = mb.states.capacity();
            } else {
                assert_eq!(mb.states.capacity(), warm_cap, "reuse must not regrow");
            }
        }
    }

    #[test]
    fn minibatch_columns_align() {
        let mut buf = TrajectoryBuffer::new(4, 2);
        for i in 0..4 {
            let mut t = transition(2, i as f64, i == 3);
            t.a_b = vec![i as i32, (i + 10) as i32];
            buf.push(t);
        }
        buf.finish(0.9, 0.9, 0.0, false);
        let mut rng = Rng::new(2);
        let mb = buf.sample_minibatch(4, &mut rng);
        for k in 0..4 {
            // actor 1's b action is always actor 0's + 10
            assert_eq!(mb.a_b[1][k], mb.a_b[0][k] + 10);
        }
    }
}
