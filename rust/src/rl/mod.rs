//! MAHPPO — multi-agent hybrid-action PPO (paper Sec. 5).
//!
//! * [`sampling`] — hybrid action sampling and log-probabilities matching
//!   the jax formulas bit-for-formula (categorical over partition/channel,
//!   Gaussian over power; Eqs. 13/14).
//! * [`buffer`] — the trajectory buffer **M** of Algorithm 1, laid out in
//!   per-env lanes.
//! * [`checkpoint`] — versioned, CRC-guarded binary trainer checkpoints:
//!   the complete state seam (nets + Adam + every RNG stream + env
//!   mid-episode state) that makes training resumable bit-for-bit across
//!   process boundaries, and the [`checkpoint::PolicySnapshot`] unit the
//!   serving stack hot-swaps.
//! * [`gae`] — sampled returns (Eq. 15) and generalized advantage
//!   estimation (Eq. 18).
//! * [`rollout`] — the vectorized rollout engine: E environment lanes,
//!   batched actor/critic forwards, a worker-thread pool, per-lane episode
//!   bookkeeping and optional scenario randomization.
//! * [`mahppo`] — the trainer: N actor networks + one central critic,
//!   composed of the rollout engine plus PPO-clip minibatch updates
//!   through the AOT artifacts (Algorithm 1).
//! * [`baselines`] — Local / Random / FixedSplit / EdgeRaw policies and the
//!   shared [`baselines::Policy`] trait used by evaluation.

pub mod baselines;
pub mod buffer;
pub mod checkpoint;
pub mod gae;
pub mod mahppo;
pub mod rollout;
pub mod sampling;
