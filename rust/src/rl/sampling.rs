//! Hybrid action sampling + log-probabilities (Eqs. 13/14).
//!
//! The Rust side samples actions during rollout and records `old_logp`; the
//! update artifacts recompute `logp` under the new parameters in jax. The
//! two implementations must agree *formula-for-formula* (not bitwise):
//!
//!   log π(a|s) = log p_b[a_b] + log p_c[a_c] + log N(a_p; μ, σ)
//!   log N(a; μ, σ) = -0.5 z² − log σ − 0.5 ln(2π),  z = (a − μ)/σ
//!
//! with probabilities clamped to ≥ 1e-8 exactly as in
//! python/compile/actor_critic.py::hybrid_log_prob.

use crate::env::HybridAction;
use crate::runtime::nets::ActorOutput;
use crate::util::rng::Rng;

const LOG_2PI: f32 = 1.837_877_1;
/// Matches the jnp.clip in actor_forward / hybrid_log_prob.
const PROB_FLOOR: f32 = 1e-8;

/// Gaussian log-density with the same parameterization as the jax side.
pub fn gaussian_log_prob(a: f32, mu: f32, log_std: f32) -> f32 {
    let std = log_std.exp();
    let z = (a - mu) / std;
    -0.5 * z * z - log_std - 0.5 * LOG_2PI
}

pub fn categorical_log_prob(probs: &[f32], idx: usize) -> f32 {
    probs[idx].max(PROB_FLOOR).ln()
}

/// A sampled hybrid action plus everything PPO needs to learn from it.
#[derive(Debug, Clone, Copy)]
pub struct SampledAction {
    pub b: usize,
    pub c: usize,
    pub p_raw: f32,
    pub log_prob: f32,
}

/// Sample from one actor's output distributions (Eqs. 13/14).
pub fn sample_hybrid(out: &ActorOutput, rng: &mut Rng) -> SampledAction {
    let b = rng.categorical(&out.probs_b);
    let c = rng.categorical(&out.probs_c);
    let std = out.log_std.exp();
    let p_raw = out.mu + std * rng.normal() as f32;
    let log_prob = categorical_log_prob(&out.probs_b, b)
        + categorical_log_prob(&out.probs_c, c)
        + gaussian_log_prob(p_raw, out.mu, out.log_std);
    SampledAction {
        b,
        c,
        p_raw,
        log_prob,
    }
}

/// Deterministic (evaluation) action: argmax categories, mean power.
pub fn greedy_hybrid(out: &ActorOutput) -> SampledAction {
    let b = argmax(&out.probs_b);
    let c = argmax(&out.probs_c);
    let p_raw = out.mu;
    let log_prob = categorical_log_prob(&out.probs_b, b)
        + categorical_log_prob(&out.probs_c, c)
        + gaussian_log_prob(p_raw, out.mu, out.log_std);
    SampledAction {
        b,
        c,
        p_raw,
        log_prob,
    }
}

pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

impl SampledAction {
    pub fn to_hybrid(self, p_max: f64) -> HybridAction {
        HybridAction::new(self.b, self.c, self.p_raw, p_max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::forall;

    fn out(probs_b: Vec<f32>, probs_c: Vec<f32>, mu: f32, log_std: f32) -> ActorOutput {
        ActorOutput {
            probs_b,
            probs_c,
            mu,
            log_std,
        }
    }

    #[test]
    fn gaussian_logp_matches_closed_form() {
        // N(0,1) at 0: -0.5 ln(2π) ≈ -0.9189
        assert!((gaussian_log_prob(0.0, 0.0, 0.0) + 0.918_938_5).abs() < 1e-5);
        // symmetric
        assert!(
            (gaussian_log_prob(1.0, 0.0, 0.0) - gaussian_log_prob(-1.0, 0.0, 0.0)).abs() < 1e-6
        );
    }

    #[test]
    fn sampled_actions_follow_distribution() {
        let o = out(vec![0.7, 0.3], vec![1.0, 0.0], 0.5, -1.0);
        let mut rng = Rng::new(3);
        let mut count_b0 = 0;
        let mut p_sum = 0.0f64;
        let n = 20_000;
        for _ in 0..n {
            let s = sample_hybrid(&o, &mut rng);
            if s.b == 0 {
                count_b0 += 1;
            }
            assert_eq!(s.c, 0, "zero-prob channel never sampled");
            p_sum += s.p_raw as f64;
        }
        let frac = count_b0 as f64 / n as f64;
        assert!((frac - 0.7).abs() < 0.02, "b=0 frequency {frac}");
        assert!((p_sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn greedy_takes_mode() {
        let o = out(vec![0.1, 0.2, 0.7], vec![0.6, 0.4], -0.3, 0.0);
        let g = greedy_hybrid(&o);
        assert_eq!((g.b, g.c), (2, 0));
        assert_eq!(g.p_raw, -0.3);
    }

    #[test]
    fn log_prob_is_consistent_with_parts() {
        forall(
            11,
            300,
            |g| {
                let pb = g.f64_in(0.05, 0.95) as f32;
                let pc = g.f64_in(0.05, 0.95) as f32;
                (
                    out(vec![pb, 1.0 - pb], vec![pc, 1.0 - pc], g.f64_in(-2.0, 2.0) as f32, g.f64_in(-2.0, 0.5) as f32),
                    g.rng.next_u64(),
                )
            },
            |(o, seed)| {
                let mut rng = Rng::new(*seed);
                let s = sample_hybrid(o, &mut rng);
                let expect = categorical_log_prob(&o.probs_b, s.b)
                    + categorical_log_prob(&o.probs_c, s.c)
                    + gaussian_log_prob(s.p_raw, o.mu, o.log_std);
                if (s.log_prob - expect).abs() > 1e-6 {
                    return Err(format!("{} vs {expect}", s.log_prob));
                }
                if !s.log_prob.is_finite() {
                    return Err("non-finite log prob".into());
                }
                Ok(())
            },
        );
    }
}
