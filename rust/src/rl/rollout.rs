//! The vectorized rollout engine: `E` independent environment lanes
//! feeding one trajectory buffer.
//!
//! Each lane owns a [`MultiAgentEnv`] (its own seed, optionally its own
//! scenario drawn from a [`ScenarioDistribution`] for domain-randomized
//! training), an action-sampling RNG and its episode bookkeeping. Lanes
//! advance in *waves*: the per-lane states are stacked and pushed through
//! the batch-keyed forward artifacts (`ActorNet::forward_batch` /
//! `CriticNet::value_batch`) so one network call serves every lane, then
//! each lane samples its joint action and steps its env.
//!
//! Lanes are partitioned into contiguous chunks over a small worker-thread
//! pool; a chunk never synchronizes with another chunk, so workers run
//! their lanes' full collection — forwards, sampling, env stepping, resets
//! — independently. Determinism is preserved by construction:
//!
//! * every lane has its own RNG streams, so scheduling cannot reorder
//!   draws;
//! * the native dense kernel produces bit-identical rows for any batch
//!   split, so the chunking (and hence the thread count) never changes a
//!   single f32 — a backend without that guarantee (e.g. real PJRT) needs
//!   a pinned `rollout_threads` for cross-machine reproducibility;
//! * transitions land in per-lane buffer segments and GAE runs per lane
//!   ([`TrajectoryBuffer::finish_lanes`]), episodes are merged in
//!   (wave, lane) order.
//!
//! With `n_envs = 1` and no scenario distribution, the engine runs inline
//! on the caller's RNG and reproduces the classic serial MAHPPO collection
//! loop bit-for-bit (regression-tested in `tests/integration_train.rs`).

use anyhow::{anyhow, ensure, Result};

use super::buffer::{TrajectoryBuffer, Transition};
use super::mahppo::TrainConfig;
use super::sampling;
use crate::env::mdp::{EnvSnapshot, MultiAgentEnv};
use crate::env::scenario::{ScenarioConfig, ScenarioDistribution};
use crate::env::{Action, HybridAction};
use crate::profiles::DeviceProfile;
use crate::runtime::nets::{ActorNet, ActorOutput, CriticNet};
use crate::util::rng::Rng;

/// One rollout lane: env + RNG streams + in-flight episode state.
struct Lane {
    id: usize,
    env: MultiAgentEnv,
    /// Action-sampling stream. Unused for a 1-env engine, which samples
    /// from the trainer's RNG to stay bit-compatible with the serial loop.
    rng: Rng,
    /// Stream for drawing per-episode scenarios (only consumed when a
    /// distribution is configured).
    scenario_rng: Rng,
    state: Vec<f32>,
    ep_reward: f64,
    /// Transitions collected since the last drain, time-ordered.
    trans: Vec<Transition>,
    /// Completed episodes since the last drain: (wave index, reward).
    episodes: Vec<(usize, f64)>,
    /// V(s_T) of the lane's post-collection state.
    bootstrap: f64,
}

/// What one `collect` call produced, in deterministic order.
#[derive(Debug, Clone, Default)]
pub struct RolloutStats {
    /// Environment frames consumed (waves × lanes).
    pub frames: usize,
    /// Rewards of episodes completed during the collection, ordered by
    /// (wave, lane) — identical to the serial episode order for one lane.
    pub episode_rewards: Vec<f64>,
    /// Per-lane critic bootstrap V(s_T) for truncated-tail GAE.
    pub bootstraps: Vec<f64>,
}

/// Complete mid-collection state of one rollout lane (checkpointing):
/// the env snapshot plus both RNG stream positions and the running
/// episode reward. Transitions/episodes are always drained at collection
/// boundaries, so they never appear here; `Lane::state` is recomputed
/// from the restored env.
#[derive(Debug, Clone, PartialEq)]
pub struct LaneSnapshot {
    pub env: EnvSnapshot,
    pub rng: [u64; 4],
    pub scenario_rng: [u64; 4],
    pub ep_reward: f64,
}

/// Complete engine state between `collect` calls.
#[derive(Debug, Clone, PartialEq)]
pub struct EngineSnapshot {
    /// Whether the engine has started its episode streams (see
    /// [`RolloutEngine::ensure_started`]).
    pub started: bool,
    pub lanes: Vec<LaneSnapshot>,
}

/// `E` environment lanes stepped in waves over a worker-thread pool.
pub struct RolloutEngine {
    lanes: Vec<Lane>,
    threads: usize,
    n_ues: usize,
    dist: Option<ScenarioDistribution>,
    /// Set by the first `reset`/`ensure_started`; `train` calls continue
    /// the episode streams instead of re-resetting, so training is one
    /// uninterrupted stream across any number of `train` calls (and hence
    /// across a save → load boundary).
    started: bool,
}

impl RolloutEngine {
    /// Build `cfg.n_envs` lanes around `scenario`. Lane 0 reuses
    /// `cfg.seed` as its env seed, so a 1-env engine drives exactly the
    /// env the serial trainer would. Lanes start on the base scenario;
    /// with a scenario distribution, every [`RolloutEngine::reset`] and
    /// per-lane episode reset draws a fresh one (UE count pinned to the
    /// training N).
    pub fn new(
        profile: &DeviceProfile,
        scenario: &ScenarioConfig,
        cfg: &TrainConfig,
    ) -> Result<RolloutEngine> {
        ensure!(cfg.n_envs >= 1, "n_envs must be >= 1");
        if let Some(d) = &cfg.scenario_dist {
            d.validate()?;
        }
        let n_ues = scenario.n_ues;
        let lanes = (0..cfg.n_envs)
            .map(|id| {
                let env = MultiAgentEnv::new(profile.clone(), scenario.clone(), cfg.env_seed(id))?;
                let state = env.state();
                Ok(Lane {
                    id,
                    env,
                    rng: Rng::new(cfg.lane_seed(id)),
                    scenario_rng: Rng::new(cfg.scenario_seed(id)),
                    state,
                    ep_reward: 0.0,
                    trans: Vec::new(),
                    episodes: Vec::new(),
                    bootstrap: 0.0,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let auto = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        let threads = if cfg.rollout_threads == 0 {
            auto.min(cfg.n_envs)
        } else {
            cfg.rollout_threads.min(cfg.n_envs)
        }
        .max(1);
        Ok(RolloutEngine {
            lanes,
            threads,
            n_ues,
            dist: cfg.scenario_dist.clone(),
            started: false,
        })
    }

    pub fn n_lanes(&self) -> usize {
        self.lanes.len()
    }

    /// The worker-thread count collections will use.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// The scenario a lane is currently running (lanes re-draw on episode
    /// resets when a distribution is configured).
    pub fn lane_scenario(&self, lane: usize) -> &ScenarioConfig {
        &self.lanes[lane].env.cfg
    }

    /// A lane-matched trajectory buffer holding at least `target`
    /// transitions, rounded up to a whole number of waves.
    pub fn make_buffer(&self, target: usize) -> TrajectoryBuffer {
        let e = self.lanes.len();
        let waves = target.max(1).div_ceil(e);
        TrajectoryBuffer::with_lanes(waves * e, self.n_ues, e)
    }

    /// Start fresh episodes on every lane (the serial trainer's
    /// `env.reset()` at the top of `train`), re-drawing scenarios when a
    /// distribution is configured. Lane RNG streams continue.
    pub fn reset(&mut self) -> Result<()> {
        let n_ues = self.n_ues;
        for lane in &mut self.lanes {
            lane.state = match &self.dist {
                Some(d) => {
                    let sc = d.sample_for(n_ues, &mut lane.scenario_rng);
                    lane.env.reconfigure(sc)?
                }
                None => lane.env.reset(),
            };
            lane.ep_reward = 0.0;
            lane.trans.clear();
            lane.episodes.clear();
        }
        self.started = true;
        Ok(())
    }

    /// Reset once, the first time — later calls are no-ops, so episode
    /// streams run uninterrupted across `train` calls. This is what makes
    /// `train(a); train(b)` equal one `train(a + b)` (and resumable across
    /// a checkpoint save → load).
    pub fn ensure_started(&mut self) -> Result<()> {
        if self.started {
            return Ok(());
        }
        self.reset()
    }

    /// Capture the complete engine state (between collections).
    pub fn snapshot(&self) -> EngineSnapshot {
        EngineSnapshot {
            started: self.started,
            lanes: self
                .lanes
                .iter()
                .map(|l| LaneSnapshot {
                    env: l.env.snapshot(),
                    rng: l.rng.state(),
                    scenario_rng: l.scenario_rng.state(),
                    ep_reward: l.ep_reward,
                })
                .collect(),
        }
    }

    /// Restore an [`EngineSnapshot`] into this engine (built from the same
    /// config): the next `collect` produces exactly the waves the captured
    /// engine would have produced.
    pub fn restore(&mut self, snap: EngineSnapshot) -> Result<()> {
        ensure!(
            snap.lanes.len() == self.lanes.len(),
            "snapshot has {} lanes, engine {}",
            snap.lanes.len(),
            self.lanes.len()
        );
        for (lane, s) in self.lanes.iter_mut().zip(snap.lanes) {
            ensure!(
                s.env.cfg.n_ues == self.n_ues,
                "lane {} snapshot is N={}, engine is N={}",
                lane.id,
                s.env.cfg.n_ues,
                self.n_ues
            );
            lane.env = MultiAgentEnv::from_snapshot(lane.env.profile.clone(), s.env)?;
            lane.rng = Rng::from_state(s.rng)
                .ok_or_else(|| anyhow!("lane {} rng state is all zeros", lane.id))?;
            lane.scenario_rng = Rng::from_state(s.scenario_rng)
                .ok_or_else(|| anyhow!("lane {} scenario rng state is all zeros", lane.id))?;
            lane.state = lane.env.state();
            lane.ep_reward = s.ep_reward;
            lane.trans.clear();
            lane.episodes.clear();
            lane.bootstrap = 0.0;
        }
        self.started = snap.started;
        Ok(())
    }

    /// Fill `buf` to capacity: every lane collects the same number of
    /// waves, transitions land in per-lane segments, and the per-lane
    /// critic bootstraps are returned for [`TrajectoryBuffer::finish_lanes`].
    ///
    /// `rng` is only consumed by a 1-env engine (the serial sampling
    /// stream); multi-env engines sample from their per-lane streams so
    /// results are independent of thread count and scheduling.
    pub fn collect(
        &mut self,
        actors: &mut [ActorNet],
        critic: &mut CriticNet,
        buf: &mut TrajectoryBuffer,
        rng: &mut Rng,
    ) -> Result<RolloutStats> {
        let e = self.lanes.len();
        ensure!(buf.n_lanes() == e, "buffer has {} lanes, engine {e}", buf.n_lanes());
        let remaining = buf.capacity.saturating_sub(buf.len());
        let waves = remaining.div_ceil(e).max(1);
        // Parameters are frozen for the whole collection: warm the cached
        // input tensors once, then share the nets read-only with workers.
        for a in actors.iter_mut() {
            a.warm_cache()?;
        }
        critic.warm_cache()?;

        if e == 1 {
            run_chunk(&mut self.lanes, Some(rng), actors, critic, waves, &self.dist)?;
        } else {
            let chunk = e.div_ceil(self.threads);
            let dist = &self.dist;
            let actors: &[ActorNet] = actors;
            let critic: &CriticNet = critic;
            std::thread::scope(|s| -> Result<()> {
                let mut handles = Vec::new();
                for (i, lanes) in self.lanes.chunks_mut(chunk).enumerate() {
                    let worker = std::thread::Builder::new()
                        .name(format!("rollout-{i}"))
                        .spawn_scoped(s, move || {
                            run_chunk(lanes, None, actors, critic, waves, dist)
                        })?;
                    handles.push(worker);
                }
                for h in handles {
                    h.join().map_err(|_| anyhow!("rollout worker panicked"))??;
                }
                Ok(())
            })?;
        }

        // Deterministic merge: per-lane segments into the buffer, episodes
        // ordered by (wave, lane).
        let mut stats = RolloutStats {
            frames: waves * e,
            episode_rewards: Vec::new(),
            bootstraps: Vec::with_capacity(e),
        };
        let mut eps: Vec<(usize, usize, f64)> = Vec::new();
        for lane in &mut self.lanes {
            buf.extend_lane(lane.id, std::mem::take(&mut lane.trans));
            eps.extend(lane.episodes.drain(..).map(|(w, r)| (w, lane.id, r)));
            stats.bootstraps.push(lane.bootstrap);
        }
        eps.sort_unstable_by_key(|&(w, id, _)| (w, id));
        stats.episode_rewards = eps.into_iter().map(|(_, _, r)| r).collect();
        Ok(stats)
    }
}

/// Run one contiguous chunk of lanes for `waves` steps — the whole rollout
/// inner loop, lockstep across the chunk's lanes. `rng_override` is the
/// serial trainer's RNG (1-env engines only).
fn run_chunk(
    lanes: &mut [Lane],
    mut rng_override: Option<&mut Rng>,
    actors: &[ActorNet],
    critic: &CriticNet,
    waves: usize,
    dist: &Option<ScenarioDistribution>,
) -> Result<()> {
    let rows = lanes.len();
    debug_assert!(rng_override.is_none() || rows == 1);
    let state_dim = lanes[0].state.len();
    let mut stacked = vec![0.0f32; rows * state_dim];
    let mut outs: Vec<Vec<ActorOutput>> = Vec::with_capacity(actors.len());
    for w in 0..waves {
        for (r, lane) in lanes.iter().enumerate() {
            stacked[r * state_dim..(r + 1) * state_dim].copy_from_slice(&lane.state);
        }
        outs.clear();
        for actor in actors {
            outs.push(actor.forward_batch(&stacked)?);
        }
        let values = critic.value_batch(&stacked)?;

        for (r, lane) in lanes.iter_mut().enumerate() {
            let n_choices = lane.env.profile.n_choices;
            let p_max = lane.env.cfg.p_max;
            let n = actors.len();
            let mut action: Action = Vec::with_capacity(n);
            let (mut a_b, mut a_c, mut a_p, mut log_prob) = (
                Vec::with_capacity(n),
                Vec::with_capacity(n),
                Vec::with_capacity(n),
                Vec::with_capacity(n),
            );
            {
                let rng: &mut Rng = match rng_override.as_deref_mut() {
                    Some(shared) => shared,
                    None => &mut lane.rng,
                };
                for out in outs.iter() {
                    let s = sampling::sample_hybrid(&out[r], rng);
                    let b = s.b.min(n_choices - 1);
                    action.push(HybridAction::new(b, s.c, s.p_raw, p_max));
                    a_b.push(s.b as i32);
                    a_c.push(s.c as i32);
                    a_p.push(s.p_raw);
                    log_prob.push(s.log_prob);
                }
            }
            let step = lane.env.step(&action);
            lane.ep_reward += step.reward;
            lane.trans.push(Transition {
                state: std::mem::take(&mut lane.state),
                a_b,
                a_c,
                a_p,
                log_prob,
                reward: step.reward,
                value: values[r],
                done: step.done,
            });
            if step.done {
                lane.episodes.push((w, lane.ep_reward));
                lane.ep_reward = 0.0;
                lane.state = match dist {
                    Some(d) => {
                        let n_ues = lane.env.n_ues();
                        let sc = d.sample_for(n_ues, &mut lane.scenario_rng);
                        lane.env.reconfigure(sc)?
                    }
                    None => lane.env.reset(),
                };
            } else {
                lane.state = step.state;
            }
        }
    }

    // Per-lane truncated-tail bootstraps: V(s_T) under the frozen critic.
    for (r, lane) in lanes.iter().enumerate() {
        stacked[r * state_dim..(r + 1) * state_dim].copy_from_slice(&lane.state);
    }
    let values = critic.value_batch(&stacked)?;
    for (r, lane) in lanes.iter_mut().enumerate() {
        lane.bootstrap = values[r] as f64;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifacts::ArtifactStore;

    type Setup = (RolloutEngine, Vec<ActorNet>, CriticNet, TrainConfig);

    fn setup(n_envs: usize, threads: usize) -> Setup {
        let store = ArtifactStore::native_demo();
        let scenario = ScenarioConfig {
            n_ues: 3,
            lambda_tasks: 8.0,
            ..Default::default()
        };
        let cfg = TrainConfig {
            buffer_size: 64,
            minibatch: 32,
            n_envs,
            rollout_threads: threads,
            seed: 9,
            ..Default::default()
        };
        let actors = (0..3)
            .map(|i| ActorNet::new(&store, 3, cfg.actor_seed(i)).unwrap())
            .collect();
        let critic = CriticNet::new(&store, 3, cfg.critic_seed()).unwrap();
        let engine = RolloutEngine::new(&DeviceProfile::synthetic(), &scenario, &cfg).unwrap();
        (engine, actors, critic, cfg)
    }

    fn collect_once(n_envs: usize, threads: usize) -> (Vec<f32>, Vec<f64>, RolloutStats) {
        let (mut engine, mut actors, mut critic, cfg) = setup(n_envs, threads);
        let mut buf = engine.make_buffer(cfg.buffer_size);
        let mut rng = Rng::new(cfg.sampler_seed());
        engine.reset().unwrap();
        let stats = engine.collect(&mut actors, &mut critic, &mut buf, &mut rng).unwrap();
        buf.finish_lanes(0.95, 0.95, &stats.bootstraps, true);
        let eps = stats.episode_rewards.clone();
        (buf.advantages().to_vec(), eps, stats)
    }

    #[test]
    fn collect_fills_buffer_and_counts_frames() {
        let (adv, _eps, stats) = collect_once(4, 2);
        assert_eq!(stats.frames, 64);
        assert_eq!(stats.bootstraps.len(), 4);
        assert_eq!(adv.len(), 64);
        assert!(adv.iter().all(|a| a.is_finite()));
    }

    #[test]
    fn rollouts_are_thread_count_invariant() {
        // the same engine config must produce bit-identical trajectories
        // whether its lanes run on 1, 2 or 4 workers (chunked batching and
        // scheduling must not change a single f32)
        let (a1, e1, s1) = collect_once(4, 1);
        let (a2, e2, s2) = collect_once(4, 2);
        let (a4, e4, s4) = collect_once(4, 4);
        assert_eq!(a1, a2);
        assert_eq!(a1, a4);
        assert_eq!(e1, e2);
        assert_eq!(e1, e4);
        assert_eq!(s1.bootstraps, s2.bootstraps);
        assert_eq!(s1.bootstraps, s4.bootstraps);
    }

    #[test]
    fn lanes_see_distinct_env_seeds() {
        let (mut engine, mut actors, mut critic, cfg) = setup(4, 2);
        let mut buf = engine.make_buffer(cfg.buffer_size);
        let mut rng = Rng::new(cfg.sampler_seed());
        engine.reset().unwrap();
        engine.collect(&mut actors, &mut critic, &mut buf, &mut rng).unwrap();
        // lanes explore independently: their bootstrap states must differ
        let states: Vec<Vec<f32>> = (0..4).map(|l| engine.lanes[l].state.clone()).collect();
        assert!(
            states.windows(2).any(|w| w[0] != w[1]),
            "all lanes evolved identically — seeds not independent"
        );
    }

    #[test]
    fn snapshot_restore_resumes_collection_bitwise() {
        // collect once, snapshot, restore into a FRESH engine from the
        // same config — the next collection must match the original's
        // bit-for-bit (env streams, lane RNGs, mid-episode state)
        let (mut engine, mut actors, mut critic, cfg) = setup(4, 2);
        let mut rng = Rng::new(cfg.sampler_seed());
        let mut buf = engine.make_buffer(cfg.buffer_size);
        engine.ensure_started().unwrap();
        engine
            .collect(&mut actors, &mut critic, &mut buf, &mut rng)
            .unwrap();
        buf.clear();
        let snap = engine.snapshot();
        assert!(snap.started);

        let (mut twin, mut actors2, mut critic2, _) = setup(4, 2);
        twin.restore(snap.clone()).unwrap();
        // ensure_started must NOT re-reset a restored (started) engine
        twin.ensure_started().unwrap();
        assert_eq!(twin.snapshot(), snap);

        let mut buf2 = twin.make_buffer(cfg.buffer_size);
        let mut rng2 = Rng::new(cfg.sampler_seed());
        let s1 = engine
            .collect(&mut actors, &mut critic, &mut buf, &mut rng)
            .unwrap();
        let s2 = twin
            .collect(&mut actors2, &mut critic2, &mut buf2, &mut rng2)
            .unwrap();
        buf.finish_lanes(0.95, 0.95, &s1.bootstraps, true);
        buf2.finish_lanes(0.95, 0.95, &s2.bootstraps, true);
        assert_eq!(s1.episode_rewards, s2.episode_rewards);
        assert_eq!(s1.bootstraps, s2.bootstraps);
        assert_eq!(buf.advantages(), buf2.advantages());

        // lane-count mismatch is rejected
        let (mut wrong, ..) = setup(2, 1);
        assert!(wrong.restore(snap).is_err());
    }

    #[test]
    fn scenario_distribution_randomizes_lanes() {
        let base = ScenarioConfig {
            n_ues: 3,
            lambda_tasks: 10.0,
            ..Default::default()
        };
        let cfg = TrainConfig {
            n_envs: 4,
            scenario_dist: Some(ScenarioDistribution::around(base.clone())),
            seed: 3,
            ..Default::default()
        };
        let mut engine = RolloutEngine::new(&DeviceProfile::synthetic(), &base, &cfg).unwrap();
        engine.reset().unwrap();
        let lambdas: Vec<f64> = (0..4).map(|l| engine.lane_scenario(l).lambda_tasks).collect();
        assert!(
            lambdas.windows(2).any(|w| w[0] != w[1]),
            "scenario distribution must vary across lanes: {lambdas:?}"
        );
        for l in 0..4 {
            assert_eq!(engine.lane_scenario(l).n_ues, 3, "training N stays pinned");
        }
    }
}
