//! The MAHPPO trainer — Algorithm 1 of the paper.
//!
//! N actor networks (one per UE) and one central critic, executing through
//! the artifact backends; the environment, sampling, GAE and the minibatch
//! loop live here in Rust. Python is never invoked. The trainer is a thin
//! composition of the [`RolloutEngine`] (vectorized experience collection
//! over `n_envs` lanes — see `rl::rollout`) and the PPO update phase.
//!
//! One `train(steps)` call runs:
//! ```text
//! loop until `steps` environment frames consumed:
//!   collect transitions until M is full (E lanes, sampling from π_old)
//!   compute returns (Eq. 15) + GAE (Eq. 18) per lane
//!   for e in 1 ..= K·(|M|/B):
//!     draw minibatch B
//!     critic Adam step on Eq. (16)
//!     per-actor Adam step on Eq. (20)   [PPO-clip + entropy bonus]
//!   clear M
//! ```
//!
//! With `n_envs = 1` and no scenario distribution this reproduces the
//! original serial trainer bit-for-bit under the same seed.

use std::fmt;
use std::time::Instant;

use anyhow::Result;

use super::buffer::Minibatch;
use super::checkpoint::{self, PolicySnapshot, TrainerCheckpoint};
use super::rollout::RolloutEngine;
use super::sampling;
use crate::env::mdp::MultiAgentEnv;
use crate::env::scenario::{ScenarioConfig, ScenarioDistribution};
use crate::env::{Action, HybridAction};
use crate::metrics::{Report, Series};
use crate::profiles::DeviceProfile;
use crate::runtime::artifacts::ArtifactStore;
use crate::runtime::nets::{ActorNet, CriticNet};
use crate::util::rng::Rng;

/// Training hyperparameters (paper Sec. 6.3.1 "Agent" defaults).
#[derive(Debug, Clone, PartialEq)]
pub struct TrainConfig {
    /// Memory buffer size ‖M‖.
    pub buffer_size: usize,
    /// Minibatch size B (paper: ‖M‖/4).
    pub minibatch: usize,
    /// Sample reuse time K.
    pub reuse: usize,
    /// Discount γ.
    pub gamma: f64,
    /// GAE λ.
    pub lam: f64,
    /// Adam learning rate α (same for critic and actors).
    pub lr: f32,
    /// Normalize advantages per buffer (standard PPO practice).
    pub normalize_adv: bool,
    pub seed: u64,
    /// Parallel environment lanes E in the rollout engine. 1 = the classic
    /// serial collection loop (bit-for-bit).
    pub n_envs: usize,
    /// Rollout worker threads; 0 = min(n_envs, available cores). On the
    /// native backend the thread count never changes results, only wall
    /// time (its kernels are bit-identical across batch splits); on other
    /// backends pin this for cross-machine reproducibility.
    pub rollout_threads: usize,
    /// PPO update worker threads; 0 = auto (`MACCI_UPDATE_THREADS`, else
    /// available cores). Like `rollout_threads` this is purely a wall-time
    /// knob: the sharded update engine reduces per-shard gradients in a
    /// fixed order, so trained parameters are bit-identical for any worker
    /// count (`runtime::native::update`).
    pub update_threads: usize,
    /// Domain randomization: when set, every lane draws its episode
    /// scenarios (λ, distances, p_max; UE count pinned to the training N)
    /// from this distribution instead of the fixed training scenario.
    pub scenario_dist: Option<ScenarioDistribution>,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            buffer_size: 1024,
            minibatch: 256,
            reuse: 10,
            gamma: 0.95,
            lam: 0.95,
            lr: 1e-4,
            normalize_adv: true,
            seed: 0,
            n_envs: 1,
            rollout_threads: 0,
            update_threads: 0,
            scenario_dist: None,
        }
    }
}

/// Configuration errors caught up front at [`MahppoTrainer::new`] instead
/// of silently rounding down or panicking mid-training.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TrainConfigError {
    /// `minibatch == 0` — the PPO epoch loop would divide by zero.
    MinibatchZero,
    /// `minibatch > buffer_size` — `sample_minibatch` would panic after
    /// the first (wasted) collection.
    MinibatchExceedsBuffer { minibatch: usize, buffer_size: usize },
    /// `buffer_size % minibatch != 0` — the epoch count `K·(‖M‖/B)` would
    /// silently round down and under-train on part of the buffer.
    MinibatchNotDivisor { minibatch: usize, buffer_size: usize },
    /// `n_envs == 0` — no rollout lanes to collect from.
    NoEnvs,
    /// `buffer_size % n_envs != 0` — lanes collect whole waves, so the
    /// buffer would silently overshoot ‖M‖ and drift from the configured
    /// buffer/minibatch accounting.
    EnvsNotDivisor { n_envs: usize, buffer_size: usize },
}

impl fmt::Display for TrainConfigError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match *self {
            TrainConfigError::MinibatchZero => write!(f, "minibatch size must be > 0"),
            TrainConfigError::MinibatchExceedsBuffer { minibatch, buffer_size } => write!(
                f,
                "minibatch {minibatch} exceeds buffer size {buffer_size}"
            ),
            TrainConfigError::MinibatchNotDivisor { minibatch, buffer_size } => write!(
                f,
                "buffer size {buffer_size} is not a multiple of minibatch {minibatch}"
            ),
            TrainConfigError::NoEnvs => write!(f, "n_envs must be >= 1"),
            TrainConfigError::EnvsNotDivisor { n_envs, buffer_size } => write!(
                f,
                "buffer size {buffer_size} is not a multiple of n_envs {n_envs}"
            ),
        }
    }
}

impl std::error::Error for TrainConfigError {}

impl TrainConfig {
    /// Check the knobs that would otherwise fail late (or silently) inside
    /// the training loop.
    pub fn validate(&self) -> Result<(), TrainConfigError> {
        if self.minibatch == 0 {
            return Err(TrainConfigError::MinibatchZero);
        }
        if self.minibatch > self.buffer_size {
            return Err(TrainConfigError::MinibatchExceedsBuffer {
                minibatch: self.minibatch,
                buffer_size: self.buffer_size,
            });
        }
        if self.buffer_size % self.minibatch != 0 {
            return Err(TrainConfigError::MinibatchNotDivisor {
                minibatch: self.minibatch,
                buffer_size: self.buffer_size,
            });
        }
        if self.n_envs == 0 {
            return Err(TrainConfigError::NoEnvs);
        }
        if self.buffer_size % self.n_envs != 0 {
            return Err(TrainConfigError::EnvsNotDivisor {
                n_envs: self.n_envs,
                buffer_size: self.buffer_size,
            });
        }
        Ok(())
    }

    // Seed-stream derivations. Public so reference implementations and
    // tests can reproduce the trainer's exact streams.

    /// Init stream of actor `i`'s parameters.
    pub fn actor_seed(&self, i: usize) -> u64 {
        self.seed.wrapping_add(1000 + i as u64)
    }

    /// Init stream of the critic's parameters.
    pub fn critic_seed(&self) -> u64 {
        self.seed.wrapping_add(7777)
    }

    /// The trainer RNG: action sampling (1-env engines) + minibatch draws.
    pub fn sampler_seed(&self) -> u64 {
        self.seed.wrapping_add(42)
    }

    /// Env stream of rollout lane `lane`; lane 0 is the serial env seed.
    pub fn env_seed(&self, lane: usize) -> u64 {
        self.seed
            .wrapping_add((lane as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15))
    }

    /// Action-sampling stream of lane `lane` (multi-env engines).
    pub fn lane_seed(&self, lane: usize) -> u64 {
        self.sampler_seed()
            .wrapping_add((lane as u64 + 1).wrapping_mul(0xd1b5_4a32_d192_ed03))
    }

    /// Scenario-draw stream of lane `lane` (domain randomization).
    pub fn scenario_seed(&self, lane: usize) -> u64 {
        (self.seed ^ 0x5cea_0d15_7a9b_3e71)
            .wrapping_add((lane as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15))
    }

    /// Env stream of evaluation runs — disjoint from every training
    /// stream, so evaluation never perturbs training.
    pub fn eval_seed(&self) -> u64 {
        self.seed ^ 0xe7a1_5eed_c0ff_ee00
    }
}

/// Everything the experiment harness needs from one training run.
#[derive(Debug, Clone, Default)]
pub struct TrainReport {
    /// Cumulative reward per completed episode (paper Fig. 8/10 curves).
    pub episode_rewards: Series,
    /// Critic loss per update round (paper Fig. 9d).
    pub value_losses: Series,
    /// Mean actor entropy per update round.
    pub entropies: Series,
    /// Mean actor clip fraction per update round.
    pub clip_fracs: Series,
    pub frames: usize,
    pub episodes: usize,
    pub wall_s: f64,
}

impl TrainReport {
    /// Convergent value: mean cumulative reward over the last 10 episodes.
    pub fn final_reward(&self) -> f64 {
        self.episode_rewards.tail_mean(10)
    }

    pub fn into_report(self, title: &str) -> Report {
        let mut r = Report::new(title);
        r.fact("frames", self.frames as f64);
        r.fact("episodes", self.episodes as f64);
        r.fact("final_reward", self.final_reward());
        r.fact("wall_s", self.wall_s);
        r.add_series(self.episode_rewards);
        r.add_series(self.value_losses);
        r.add_series(self.entropies);
        r.add_series(self.clip_fracs);
        r
    }
}

/// The MAHPPO agent: N actors + central critic + the rollout engine.
pub struct MahppoTrainer {
    pub actors: Vec<ActorNet>,
    pub critic: CriticNet,
    pub cfg: TrainConfig,
    /// The fixed training scenario (and the base the scenario distribution
    /// randomizes around).
    pub scenario: ScenarioConfig,
    pub profile: DeviceProfile,
    engine: RolloutEngine,
    rng: Rng,
}

impl MahppoTrainer {
    pub fn new(
        store: &ArtifactStore,
        profile: &DeviceProfile,
        scenario: ScenarioConfig,
        cfg: TrainConfig,
    ) -> Result<MahppoTrainer> {
        cfg.validate()?;
        let n = scenario.n_ues;
        let mut actors = (0..n)
            .map(|i| ActorNet::new(store, n, cfg.actor_seed(i)))
            .collect::<Result<Vec<_>>>()?;
        let mut critic = CriticNet::new(store, n, cfg.critic_seed())?;
        for a in actors.iter_mut() {
            a.set_update_threads(cfg.update_threads);
        }
        critic.set_update_threads(cfg.update_threads);
        let engine = RolloutEngine::new(profile, &scenario, &cfg)?;
        Ok(MahppoTrainer {
            actors,
            critic,
            rng: Rng::new(cfg.sampler_seed()),
            cfg,
            scenario,
            profile: profile.clone(),
            engine,
        })
    }

    /// The rollout lane count (E).
    pub fn n_envs(&self) -> usize {
        self.engine.n_lanes()
    }

    /// Capture the complete trainer state — nets (params + Adam + step
    /// counters), config, scenario, profile and every RNG stream / env
    /// mid-episode state — as a [`TrainerCheckpoint`]. A trainer rebuilt
    /// from it ([`MahppoTrainer::resume`]) continues training bit-for-bit.
    pub fn checkpoint(&self) -> TrainerCheckpoint {
        TrainerCheckpoint {
            config: self.cfg.clone(),
            scenario: self.scenario.clone(),
            profile: self.profile.clone(),
            actors: self.actors.iter().map(|a| a.snapshot()).collect(),
            critic: self.critic.snapshot(),
            sampler_rng: self.rng.state(),
            engine: self.engine.snapshot(),
        }
    }

    /// Persist the trainer to `path` in the versioned, CRC-guarded
    /// [`checkpoint`] format.
    pub fn save(&self, path: impl AsRef<std::path::Path>) -> Result<()> {
        let path = path.as_ref();
        checkpoint::save(&self.checkpoint(), path)
            .map_err(|e| anyhow::anyhow!("saving checkpoint to {}: {e}", path.display()))
    }

    /// Rebuild a live trainer from a decoded checkpoint. The artifact
    /// `store` supplies the compiled executables (they are not part of the
    /// checkpoint); everything learnable/stochastic is restored from `cp`.
    pub fn resume(store: &ArtifactStore, cp: TrainerCheckpoint) -> Result<MahppoTrainer> {
        cp.config.validate()?;
        let n = cp.scenario.n_ues;
        anyhow::ensure!(
            cp.actors.len() == n,
            "checkpoint has {} actors for an N={n} scenario",
            cp.actors.len()
        );
        let mut actors = (0..n)
            .map(|i| ActorNet::new(store, n, cp.config.actor_seed(i)))
            .collect::<Result<Vec<_>>>()?;
        for (a, st) in actors.iter_mut().zip(&cp.actors) {
            a.restore(st)?;
            a.set_update_threads(cp.config.update_threads);
        }
        let mut critic = CriticNet::new(store, n, cp.config.critic_seed())?;
        critic.restore(&cp.critic)?;
        critic.set_update_threads(cp.config.update_threads);
        let mut engine = RolloutEngine::new(&cp.profile, &cp.scenario, &cp.config)?;
        engine.restore(cp.engine)?;
        let rng = Rng::from_state(cp.sampler_rng)
            .ok_or_else(|| anyhow::anyhow!("checkpoint sampler rng state is all zeros"))?;
        Ok(MahppoTrainer {
            actors,
            critic,
            rng,
            cfg: cp.config,
            scenario: cp.scenario,
            profile: cp.profile,
            engine,
        })
    }

    /// [`MahppoTrainer::resume`] from a checkpoint file.
    pub fn load(store: &ArtifactStore, path: impl AsRef<std::path::Path>) -> Result<MahppoTrainer> {
        let path = path.as_ref();
        let cp = checkpoint::load(path)
            .map_err(|e| anyhow::anyhow!("loading checkpoint from {}: {e}", path.display()))?;
        Self::resume(store, cp)
    }

    /// The deployable policy right now: actor parameter vectors plus the
    /// critic step counter as a monotonic version. This is the unit the
    /// serving stack hot-swaps
    /// ([`crate::coordinator::decision::PolicyHandle::publish`]).
    pub fn policy_snapshot(&self) -> PolicySnapshot {
        PolicySnapshot {
            version: self.critic.steps(),
            actors: self.actors.iter().map(|a| a.params.clone()).collect(),
        }
    }

    /// Run Algorithm 1 for (at least) `total_frames` environment frames.
    pub fn train(&mut self, total_frames: usize) -> Result<TrainReport> {
        let t0 = Instant::now();
        let mut buf = self.engine.make_buffer(self.cfg.buffer_size);
        // one minibatch's gather buffers, reused across every PPO round
        // (the draw itself reads the same RNG stream as the allocating
        // `sample_minibatch`, so this is purely an allocation change)
        let mut mb = Minibatch::default();
        let mut report = TrainReport::default();
        report.episode_rewards = Series::new("episode_reward");
        report.value_losses = Series::new("value_loss");
        report.entropies = Series::new("entropy");
        report.clip_fracs = Series::new("clip_frac");

        // first `train` on this trainer resets the lanes; later calls (and
        // checkpoint-resumed trainers) continue the same episode streams,
        // so train(a) → train(b) ≡ train(a + b) bit-for-bit
        self.engine.ensure_started()?;
        let mut frames = 0usize;

        while frames < total_frames {
            // ---- collect one buffer of experience (E lanes) ----
            let stats = self
                .engine
                .collect(&mut self.actors, &mut self.critic, &mut buf, &mut self.rng)?;
            frames += stats.frames;
            for reward in stats.episode_rewards {
                report.episode_rewards.push(report.episodes as f64, reward);
                report.episodes += 1;
            }

            // ---- returns + advantages, per lane ----
            buf.finish_lanes(
                self.cfg.gamma,
                self.cfg.lam,
                &stats.bootstraps,
                self.cfg.normalize_adv,
            );

            // ---- PPO epochs: K * (|M| / B) minibatches ----
            let rounds = self.cfg.reuse * (self.cfg.buffer_size / self.cfg.minibatch).max(1);
            let mut vloss_acc = 0.0f64;
            let mut ent_acc = 0.0f64;
            let mut clip_acc = 0.0f64;
            for _ in 0..rounds {
                buf.sample_minibatch_into(self.cfg.minibatch, &mut self.rng, &mut mb);
                vloss_acc += self.update_critic(&mb)? as f64;
                let (ent, clip) = self.update_actors(&mb)?;
                ent_acc += ent as f64;
                clip_acc += clip as f64;
            }
            let r = rounds as f64;
            report
                .value_losses
                .push(frames as f64, vloss_acc / r);
            report.entropies.push(frames as f64, ent_acc / r);
            report.clip_fracs.push(frames as f64, clip_acc / r);
            buf.clear();
        }

        report.frames = frames;
        report.wall_s = t0.elapsed().as_secs_f64();
        Ok(report)
    }

    fn update_critic(&mut self, mb: &Minibatch) -> Result<f32> {
        self.critic.update(self.cfg.lr, &mb.states, &mb.returns)
    }

    fn update_actors(&mut self, mb: &Minibatch) -> Result<(f32, f32)> {
        let mut ent = 0.0f32;
        let mut clip = 0.0f32;
        let n = self.actors.len();
        for (u, actor) in self.actors.iter_mut().enumerate() {
            let stats = actor.update(
                self.cfg.lr,
                &mb.states,
                &mb.a_b[u],
                &mb.a_c[u],
                &mb.a_p[u],
                &mb.old_logp[u],
                &mb.adv,
            )?;
            ent += stats.entropy;
            clip += stats.clip_frac;
        }
        Ok((ent / n as f32, clip / n as f32))
    }

    /// Greedy evaluation over `episodes` episodes of the training scenario
    /// in eval mode (fixed d = 50 m, K tasks); returns (avg per-task
    /// latency, avg per-task energy, avg episode reward).
    pub fn evaluate(&mut self, episodes: usize) -> Result<EvalStats> {
        let mut sc = self.scenario.clone();
        sc.eval_mode = true;
        self.evaluate_on(sc, episodes)
    }

    /// Greedy evaluation on an explicit scenario. Runs on a **fresh**
    /// eval-seeded env with its own RNG, so evaluation never touches the
    /// training streams: train → eval → train equals train → train.
    pub fn evaluate_on(&mut self, scenario: ScenarioConfig, episodes: usize) -> Result<EvalStats> {
        let mut env = MultiAgentEnv::new(self.profile.clone(), scenario, self.cfg.eval_seed())?;
        let mut stats = EvalStats::default();
        for _ in 0..episodes {
            let mut state = env.reset();
            let mut ep_reward = 0.0;
            loop {
                let mut action: Action = Vec::with_capacity(self.actors.len());
                for actor in self.actors.iter_mut() {
                    let out = actor.forward(&state)?;
                    let g = sampling::greedy_hybrid(&out);
                    action.push(HybridAction::new(
                        g.b.min(env.profile.n_choices - 1),
                        g.c,
                        g.p_raw,
                        env.cfg.p_max,
                    ));
                }
                let r = env.step(&action);
                ep_reward += r.reward;
                if r.done {
                    break;
                }
                state = r.state;
            }
            let t = env.totals();
            stats.avg_latency += t.avg_latency();
            stats.avg_energy += t.avg_energy();
            stats.avg_reward += ep_reward;
            stats.episodes += 1;
        }
        let e = stats.episodes.max(1) as f64;
        stats.avg_latency /= e;
        stats.avg_energy /= e;
        stats.avg_reward /= e;
        Ok(stats)
    }
}

/// Greedy-policy evaluation summary.
#[derive(Debug, Clone, Copy, Default)]
pub struct EvalStats {
    pub avg_latency: f64,
    pub avg_energy: f64,
    pub avg_reward: f64,
    pub episodes: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_is_valid() {
        assert_eq!(TrainConfig::default().validate(), Ok(()));
    }

    #[test]
    fn validate_rejects_zero_minibatch() {
        let cfg = TrainConfig {
            minibatch: 0,
            ..Default::default()
        };
        assert_eq!(cfg.validate(), Err(TrainConfigError::MinibatchZero));
    }

    #[test]
    fn validate_rejects_oversized_minibatch() {
        let cfg = TrainConfig {
            buffer_size: 128,
            minibatch: 256,
            ..Default::default()
        };
        assert_eq!(
            cfg.validate(),
            Err(TrainConfigError::MinibatchExceedsBuffer {
                minibatch: 256,
                buffer_size: 128
            })
        );
    }

    #[test]
    fn validate_rejects_non_dividing_minibatch() {
        let cfg = TrainConfig {
            buffer_size: 1000,
            minibatch: 256,
            ..Default::default()
        };
        assert_eq!(
            cfg.validate(),
            Err(TrainConfigError::MinibatchNotDivisor {
                minibatch: 256,
                buffer_size: 1000
            })
        );
    }

    #[test]
    fn validate_rejects_zero_envs() {
        let cfg = TrainConfig {
            n_envs: 0,
            ..Default::default()
        };
        assert_eq!(cfg.validate(), Err(TrainConfigError::NoEnvs));
    }

    #[test]
    fn validate_rejects_non_dividing_envs() {
        let cfg = TrainConfig {
            buffer_size: 1024,
            n_envs: 3,
            ..Default::default()
        };
        assert_eq!(
            cfg.validate(),
            Err(TrainConfigError::EnvsNotDivisor {
                n_envs: 3,
                buffer_size: 1024
            })
        );
        let cfg = TrainConfig {
            n_envs: 8,
            ..Default::default()
        };
        assert_eq!(cfg.validate(), Ok(()));
    }

    #[test]
    fn trainer_new_surfaces_config_errors() {
        let store = ArtifactStore::native_demo();
        let cfg = TrainConfig {
            buffer_size: 100,
            minibatch: 256,
            ..Default::default()
        };
        let err = MahppoTrainer::new(
            &store,
            &DeviceProfile::synthetic(),
            ScenarioConfig {
                n_ues: 3,
                ..Default::default()
            },
            cfg,
        )
        .unwrap_err();
        assert!(err.to_string().contains("exceeds buffer size"), "{err:#}");
    }

    #[test]
    fn seed_streams_are_distinct() {
        let cfg = TrainConfig::default();
        let seeds = [
            cfg.actor_seed(0),
            cfg.actor_seed(1),
            cfg.critic_seed(),
            cfg.sampler_seed(),
            cfg.env_seed(0),
            cfg.env_seed(1),
            cfg.lane_seed(0),
            cfg.lane_seed(1),
            cfg.scenario_seed(0),
            cfg.eval_seed(),
        ];
        for i in 0..seeds.len() {
            for j in i + 1..seeds.len() {
                assert_ne!(seeds[i], seeds[j], "seed {i} collides with {j}");
            }
        }
        // lane 0's env stream IS the serial env stream
        assert_eq!(cfg.env_seed(0), cfg.seed);
    }
}
