//! The MAHPPO trainer — Algorithm 1 of the paper.
//!
//! N actor networks (one per UE) and one central critic, all executing as
//! AOT-compiled XLA artifacts via PJRT; the environment, sampling, GAE and
//! the minibatch loop live here in Rust. Python is never invoked.
//!
//! One `train(steps)` call runs:
//! ```text
//! loop until `steps` environment frames consumed:
//!   collect transitions until M is full (sampling from π_old)
//!   compute returns (Eq. 15) + GAE (Eq. 18)
//!   for e in 1 ..= K·(|M|/B):
//!     draw minibatch B
//!     critic Adam step on Eq. (16)
//!     per-actor Adam step on Eq. (20)   [PPO-clip + entropy bonus]
//!   clear M
//! ```

use std::time::Instant;

use anyhow::Result;

use super::buffer::{Minibatch, TrajectoryBuffer, Transition};
use super::sampling;
use crate::env::mdp::MultiAgentEnv;
use crate::env::scenario::ScenarioConfig;
use crate::env::{Action, HybridAction};
use crate::metrics::{Report, Series};
use crate::profiles::DeviceProfile;
use crate::runtime::artifacts::ArtifactStore;
use crate::runtime::nets::{ActorNet, CriticNet};
use crate::util::rng::Rng;

/// Training hyperparameters (paper Sec. 6.3.1 "Agent" defaults).
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Memory buffer size ‖M‖.
    pub buffer_size: usize,
    /// Minibatch size B (paper: ‖M‖/4).
    pub minibatch: usize,
    /// Sample reuse time K.
    pub reuse: usize,
    /// Discount γ.
    pub gamma: f64,
    /// GAE λ.
    pub lam: f64,
    /// Adam learning rate α (same for critic and actors).
    pub lr: f32,
    /// Normalize advantages per buffer (standard PPO practice).
    pub normalize_adv: bool,
    pub seed: u64,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            buffer_size: 1024,
            minibatch: 256,
            reuse: 10,
            gamma: 0.95,
            lam: 0.95,
            lr: 1e-4,
            normalize_adv: true,
            seed: 0,
        }
    }
}

/// Everything the experiment harness needs from one training run.
#[derive(Debug, Clone, Default)]
pub struct TrainReport {
    /// Cumulative reward per completed episode (paper Fig. 8/10 curves).
    pub episode_rewards: Series,
    /// Critic loss per update round (paper Fig. 9d).
    pub value_losses: Series,
    /// Mean actor entropy per update round.
    pub entropies: Series,
    /// Mean actor clip fraction per update round.
    pub clip_fracs: Series,
    pub frames: usize,
    pub episodes: usize,
    pub wall_s: f64,
}

impl TrainReport {
    /// Convergent value: mean cumulative reward over the last 10 episodes.
    pub fn final_reward(&self) -> f64 {
        self.episode_rewards.tail_mean(10)
    }

    pub fn into_report(self, title: &str) -> Report {
        let mut r = Report::new(title);
        r.fact("frames", self.frames as f64);
        r.fact("episodes", self.episodes as f64);
        r.fact("final_reward", self.final_reward());
        r.fact("wall_s", self.wall_s);
        r.add_series(self.episode_rewards);
        r.add_series(self.value_losses);
        r.add_series(self.entropies);
        r.add_series(self.clip_fracs);
        r
    }
}

/// The MAHPPO agent: N actors + central critic + environment.
pub struct MahppoTrainer {
    pub env: MultiAgentEnv,
    pub actors: Vec<ActorNet>,
    pub critic: CriticNet,
    pub cfg: TrainConfig,
    rng: Rng,
}

impl MahppoTrainer {
    pub fn new(
        store: &ArtifactStore,
        profile: &DeviceProfile,
        scenario: ScenarioConfig,
        cfg: TrainConfig,
    ) -> Result<MahppoTrainer> {
        let n = scenario.n_ues;
        let env = MultiAgentEnv::new(profile.clone(), scenario, cfg.seed)?;
        let actors = (0..n)
            .map(|i| ActorNet::new(store, n, cfg.seed.wrapping_add(1000 + i as u64)))
            .collect::<Result<Vec<_>>>()?;
        let critic = CriticNet::new(store, n, cfg.seed.wrapping_add(7777))?;
        Ok(MahppoTrainer {
            env,
            actors,
            critic,
            cfg: cfg.clone(),
            rng: Rng::new(cfg.seed.wrapping_add(42)),
        })
    }

    /// Sample the joint action from the current policies.
    fn act(&mut self, state: &[f32]) -> Result<(Action, Vec<i32>, Vec<i32>, Vec<f32>, Vec<f32>)> {
        let n = self.env.n_ues();
        let p_max = self.env.cfg.p_max;
        let n_choices = self.env.profile.n_choices;
        let mut action: Action = Vec::with_capacity(n);
        let (mut ab, mut ac, mut ap, mut lp) = (
            Vec::with_capacity(n),
            Vec::with_capacity(n),
            Vec::with_capacity(n),
            Vec::with_capacity(n),
        );
        for actor in self.actors.iter_mut() {
            let out = actor.forward(state)?;
            let s = sampling::sample_hybrid(&out, &mut self.rng);
            let b = s.b.min(n_choices - 1);
            action.push(HybridAction::new(b, s.c, s.p_raw, p_max));
            ab.push(s.b as i32);
            ac.push(s.c as i32);
            ap.push(s.p_raw);
            lp.push(s.log_prob);
        }
        Ok((action, ab, ac, ap, lp))
    }

    /// Run Algorithm 1 for (at least) `total_frames` environment frames.
    pub fn train(&mut self, total_frames: usize) -> Result<TrainReport> {
        let t0 = Instant::now();
        let n = self.env.n_ues();
        let mut buf = TrajectoryBuffer::new(self.cfg.buffer_size, n);
        let mut report = TrainReport::default();
        report.episode_rewards = Series::new("episode_reward");
        report.value_losses = Series::new("value_loss");
        report.entropies = Series::new("entropy");
        report.clip_fracs = Series::new("clip_frac");

        let mut state = self.env.reset();
        let mut ep_reward = 0.0f64;
        let mut frames = 0usize;

        while frames < total_frames {
            // ---- collect one buffer of experience ----
            while !buf.is_full() {
                let (action, a_b, a_c, a_p, log_prob) = self.act(&state)?;
                let value = self.critic.value(&state)?;
                let r = self.env.step(&action);
                ep_reward += r.reward;
                frames += 1;
                buf.push(Transition {
                    state: std::mem::take(&mut state),
                    a_b,
                    a_c,
                    a_p,
                    log_prob,
                    reward: r.reward,
                    value,
                    done: r.done,
                });
                if r.done {
                    report
                        .episode_rewards
                        .push(report.episodes as f64, ep_reward);
                    report.episodes += 1;
                    ep_reward = 0.0;
                    state = self.env.reset();
                } else {
                    state = r.state;
                }
            }

            // ---- returns + advantages ----
            let bootstrap = if buf.is_empty() {
                0.0
            } else {
                self.critic.value(&state)? as f64
            };
            buf.finish(self.cfg.gamma, self.cfg.lam, bootstrap, self.cfg.normalize_adv);

            // ---- PPO epochs: K * (|M| / B) minibatches ----
            let rounds = self.cfg.reuse * (self.cfg.buffer_size / self.cfg.minibatch).max(1);
            let mut vloss_acc = 0.0f64;
            let mut ent_acc = 0.0f64;
            let mut clip_acc = 0.0f64;
            for _ in 0..rounds {
                let mb = buf.sample_minibatch(self.cfg.minibatch, &mut self.rng);
                vloss_acc += self.update_critic(&mb)? as f64;
                let (ent, clip) = self.update_actors(&mb)?;
                ent_acc += ent as f64;
                clip_acc += clip as f64;
            }
            let r = rounds as f64;
            report
                .value_losses
                .push(frames as f64, vloss_acc / r);
            report.entropies.push(frames as f64, ent_acc / r);
            report.clip_fracs.push(frames as f64, clip_acc / r);
            buf.clear();
        }

        report.frames = frames;
        report.wall_s = t0.elapsed().as_secs_f64();
        Ok(report)
    }

    fn update_critic(&mut self, mb: &Minibatch) -> Result<f32> {
        self.critic.update(self.cfg.lr, &mb.states, &mb.returns)
    }

    fn update_actors(&mut self, mb: &Minibatch) -> Result<(f32, f32)> {
        let mut ent = 0.0f32;
        let mut clip = 0.0f32;
        let n = self.actors.len();
        for (u, actor) in self.actors.iter_mut().enumerate() {
            let stats = actor.update(
                self.cfg.lr,
                &mb.states,
                &mb.a_b[u],
                &mb.a_c[u],
                &mb.a_p[u],
                &mb.old_logp[u],
                &mb.adv,
            )?;
            ent += stats.entropy;
            clip += stats.clip_frac;
        }
        Ok((ent / n as f32, clip / n as f32))
    }

    /// Greedy evaluation over `episodes` episodes in eval mode; returns
    /// (avg per-task latency, avg per-task energy, avg episode reward).
    pub fn evaluate(&mut self, episodes: usize) -> Result<EvalStats> {
        let mut stats = EvalStats::default();
        for _ in 0..episodes {
            let mut state = self.env.reset();
            let mut ep_reward = 0.0;
            loop {
                let mut action: Action = Vec::with_capacity(self.actors.len());
                for actor in self.actors.iter_mut() {
                    let out = actor.forward(&state)?;
                    let g = sampling::greedy_hybrid(&out);
                    action.push(HybridAction::new(
                        g.b.min(self.env.profile.n_choices - 1),
                        g.c,
                        g.p_raw,
                        self.env.cfg.p_max,
                    ));
                }
                let r = self.env.step(&action);
                ep_reward += r.reward;
                if r.done {
                    break;
                }
                state = r.state;
            }
            let t = self.env.totals();
            stats.avg_latency += t.avg_latency();
            stats.avg_energy += t.avg_energy();
            stats.avg_reward += ep_reward;
            stats.episodes += 1;
        }
        let e = stats.episodes.max(1) as f64;
        stats.avg_latency /= e;
        stats.avg_energy /= e;
        stats.avg_reward /= e;
        Ok(stats)
    }
}

/// Greedy-policy evaluation summary.
#[derive(Debug, Clone, Copy, Default)]
pub struct EvalStats {
    pub avg_latency: f64,
    pub avg_energy: f64,
    pub avg_reward: f64,
    pub episodes: usize,
}
