//! Versioned, CRC-guarded binary checkpoints for the policy lifecycle
//! (train → save → deploy → keep learning).
//!
//! A checkpoint captures the **complete** trainer state — every net's
//! parameters *and* Adam moments and step counters, the full
//! [`TrainConfig`] (scenario distribution included), the training
//! scenario, the device profile, and the position of **every** RNG stream
//! (sampler, per-lane action/scenario streams, per-lane env streams, plus
//! each env's in-flight UE task machines). Restoring one therefore resumes
//! training *bit-exactly*: `train(a + b)` ≡ `train(a)` → save → load →
//! `train(b)` under the same seed (regression-tested in
//! `rust/tests/integration_train.rs`).
//!
//! ## File layout
//!
//! The format reuses the [`crate::coordinator::wire`] header discipline —
//! magic, version byte, type tag, u32 LE body length, CRC-32 over header
//! prefix + body — so a damaged or truncated file is always detected and
//! decoding is *total*: hostile bytes produce a typed
//! [`CheckpointError`], never a panic (property-tested in
//! `rust/tests/proptests.rs`). Full byte tables live in DESIGN.md
//! §Policy-Lifecycle; this header is the normative summary.
//!
//! ```text
//! offset  size  field
//!      0     2  magic        0x4D 0x4B ("MK")
//!      2     1  version      currently 2
//!      3     1  type tag     0x01 = trainer checkpoint
//!      4     4  body length  u32 LE, <= MAX_BODY
//!      8     4  crc32        u32 LE, IEEE CRC-32 over bytes [0..8) + body
//!     12     n  body         sections in fixed order, all little-endian
//! ```
//!
//! Body sections, in order: train config · scenario · device profile ·
//! actor nets (count-prefixed) · critic net · sampler RNG · engine
//! (per-lane env snapshots + RNG streams). Floats are stored as raw LE
//! bit patterns, so round-trips are bit-exact by construction.
//!
//! ## Versioning rules
//!
//! * A decoder rejects versions it does not know ([`CheckpointError::Version`]);
//!   section layouts never change within a version.
//! * New checkpoint kinds get new type tags; an unknown tag is
//!   [`CheckpointError::UnknownTag`], not a parse attempt.

use std::path::Path;

use crate::coordinator::wire::crc32_parts;
use crate::env::mdp::EnvSnapshot;
use crate::env::scenario::{ScenarioConfig, ScenarioDistribution};
use crate::env::ue::{Phase, TaskTotals, UeSnapshot};
use crate::env::HybridAction;
use crate::profiles::{DeviceProfile, JaladEntry, OverheadEntry};
use crate::rl::mahppo::TrainConfig;
use crate::rl::rollout::{EngineSnapshot, LaneSnapshot};
use crate::runtime::nets::NetState;

/// First two bytes of every checkpoint: "MK".
pub const MAGIC: [u8; 2] = [0x4D, 0x4B];
/// Checkpoint-format version this build speaks. v2 added the config's
/// `update_threads` word (after `rollout_threads`); v1 files are no longer
/// readable — the format rejects unknown versions rather than guessing.
pub const VERSION: u8 = 2;
/// Fixed header size (magic + version + tag + length + crc).
pub const HEADER_LEN: usize = 12;
/// Upper bound on a checkpoint body — a corrupt length prefix must not be
/// able to trigger a multi-gigabyte allocation.
pub const MAX_BODY: usize = 1 << 30; // 1 GiB
/// Type tag: full trainer state (the only kind in v1).
pub const TAG_TRAINER: u8 = 0x01;

/// The complete persisted trainer state. See the module docs for what
/// "complete" means; [`crate::rl::mahppo::MahppoTrainer::checkpoint`]
/// captures one and [`crate::rl::mahppo::MahppoTrainer::resume`] rebuilds
/// a live trainer from one.
#[derive(Debug, Clone, PartialEq)]
pub struct TrainerCheckpoint {
    pub config: TrainConfig,
    pub scenario: ScenarioConfig,
    pub profile: DeviceProfile,
    /// One [`NetState`] per UE actor, in UE order.
    pub actors: Vec<NetState>,
    pub critic: NetState,
    /// The trainer's sampler/minibatch RNG stream position.
    pub sampler_rng: [u64; 4],
    pub engine: EngineSnapshot,
}

/// The serving-side view of a policy: actor parameter vectors only, plus a
/// monotonic version for observability. This is what crosses the
/// hot-swap channel ([`crate::coordinator::decision::PolicyHandle`]).
#[derive(Debug, Clone, PartialEq)]
pub struct PolicySnapshot {
    /// Publisher-defined monotonic version (the trainer uses the critic's
    /// Adam step counter).
    pub version: u64,
    /// One flat parameter vector per UE actor, in UE order.
    pub actors: Vec<Vec<f32>>,
}

impl TrainerCheckpoint {
    /// Extract the deployable policy (actor params only).
    pub fn policy_snapshot(&self) -> PolicySnapshot {
        PolicySnapshot {
            version: self.critic.t,
            actors: self.actors.iter().map(|a| a.params.clone()).collect(),
        }
    }
}

/// Why a buffer failed to decode as a checkpoint. Decoding is total:
/// hostile bytes produce one of these, never a panic.
#[derive(Debug)]
pub enum CheckpointError {
    /// More bytes are needed to complete the frame.
    Truncated { have: usize, need: usize },
    /// The first two bytes are not [`MAGIC`].
    BadMagic { got: [u8; 2] },
    /// The file speaks a format version this build does not know.
    Version { got: u8 },
    /// Unknown checkpoint kind.
    UnknownTag { got: u8 },
    /// The length prefix exceeds [`MAX_BODY`].
    TooLarge { len: usize },
    /// CRC mismatch: the file was damaged.
    Corrupt { expect: u32, got: u32 },
    /// The body parsed structurally wrong (bad flag, bad utf-8, length
    /// fields disagreeing with the byte count, trailing bytes, all-zero
    /// RNG state, invalid scenario).
    Malformed(String),
    /// Underlying filesystem error.
    Io(std::io::Error),
}

impl std::fmt::Display for CheckpointError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CheckpointError::Truncated { have, need } => {
                write!(f, "truncated checkpoint: have {have} bytes, need {need}")
            }
            CheckpointError::BadMagic { got } => {
                write!(f, "bad checkpoint magic {:#04x} {:#04x}", got[0], got[1])
            }
            CheckpointError::Version { got } => write!(
                f,
                "unsupported checkpoint version {got} (this build speaks {VERSION})"
            ),
            CheckpointError::UnknownTag { got } => {
                write!(f, "unknown checkpoint kind {got:#04x}")
            }
            CheckpointError::TooLarge { len } => {
                write!(f, "checkpoint body of {len} bytes exceeds the {MAX_BODY}-byte cap")
            }
            CheckpointError::Corrupt { expect, got } => {
                write!(f, "crc mismatch: file says {expect:#010x}, computed {got:#010x}")
            }
            CheckpointError::Malformed(why) => write!(f, "malformed checkpoint body: {why}"),
            CheckpointError::Io(e) => write!(f, "checkpoint i/o: {e}"),
        }
    }
}

impl std::error::Error for CheckpointError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            CheckpointError::Io(e) => Some(e),
            _ => None,
        }
    }
}

// ---------------------------------------------------------------- encoding

struct Enc(Vec<u8>);

impl Enc {
    fn u8(&mut self, v: u8) {
        self.0.push(v);
    }
    fn bool(&mut self, v: bool) {
        self.0.push(v as u8);
    }
    fn u32(&mut self, v: u32) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }
    fn f32(&mut self, v: f32) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }
    fn f64(&mut self, v: f64) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }
    fn str(&mut self, v: &str) {
        self.u32(v.len() as u32);
        self.0.extend_from_slice(v.as_bytes());
    }
    /// Raw f32 payload without a length prefix (caller encodes the count).
    fn f32s_raw(&mut self, v: &[f32]) {
        self.0.reserve(v.len() * 4);
        for &x in v {
            self.0.extend_from_slice(&x.to_le_bytes());
        }
    }
    fn rng(&mut self, s: [u64; 4]) {
        for w in s {
            self.u64(w);
        }
    }
}

fn put_scenario(e: &mut Enc, sc: &ScenarioConfig) {
    e.u64(sc.n_ues as u64);
    e.u64(sc.n_channels as u64);
    e.f64(sc.bandwidth_hz);
    e.f64(sc.noise_w);
    e.f64(sc.path_loss_exp);
    e.f64(sc.p_max);
    e.f64(sc.frame_s);
    e.f64(sc.beta);
    e.f64(sc.lambda_tasks);
    e.f64(sc.d_min);
    e.f64(sc.d_max);
    e.bool(sc.eval_mode);
    e.f64(sc.eval_distance);
    e.u64(sc.eval_tasks);
    e.u64(sc.max_frames as u64);
}

fn put_dist(e: &mut Enc, d: &ScenarioDistribution) {
    put_scenario(e, &d.base);
    e.u32(d.ue_buckets.len() as u32);
    for &n in &d.ue_buckets {
        e.u64(n as u64);
    }
    for (lo, hi) in [d.lambda_range, d.d_max_range, d.p_max_range] {
        e.f64(lo);
        e.f64(hi);
    }
}

fn put_config(e: &mut Enc, c: &TrainConfig) {
    e.u64(c.buffer_size as u64);
    e.u64(c.minibatch as u64);
    e.u64(c.reuse as u64);
    e.f64(c.gamma);
    e.f64(c.lam);
    e.f32(c.lr);
    e.bool(c.normalize_adv);
    e.u64(c.seed);
    e.u64(c.n_envs as u64);
    e.u64(c.rollout_threads as u64);
    e.u64(c.update_threads as u64);
    match &c.scenario_dist {
        Some(d) => {
            e.u8(1);
            put_dist(e, d);
        }
        None => e.u8(0),
    }
}

fn put_profile(e: &mut Enc, p: &DeviceProfile) {
    e.str(&p.model);
    e.u64(p.n_choices as u64);
    e.u32(p.entries.len() as u32);
    for en in &p.entries {
        e.u64(en.b as u64);
        e.f64(en.t_f);
        e.f64(en.e_f);
        e.f64(en.t_c);
        e.f64(en.e_c);
        e.f64(en.bits);
    }
    e.u32(p.jalad.len() as u32);
    for j in &p.jalad {
        e.u64(j.b as u64);
        e.f64(j.t_c);
        e.f64(j.e_c);
        e.f64(j.bits);
        e.f64(j.rate);
    }
    e.f64(p.full_local_t);
    e.f64(p.full_local_e);
    e.f64(p.input_bits);
}

fn put_net(e: &mut Enc, n: &NetState) {
    // one count serves params/m/v: the three always share a length
    e.u32(n.params.len() as u32);
    e.f32s_raw(&n.params);
    e.f32s_raw(&n.m);
    e.f32s_raw(&n.v);
    e.u64(n.t);
}

fn put_action(e: &mut Enc, a: &HybridAction) {
    e.u64(a.b as u64);
    e.u64(a.c as u64);
    e.f32(a.p_raw);
    e.f64(a.p_watts);
}

fn put_ue(e: &mut Enc, u: &UeSnapshot) {
    e.u64(u.id as u64);
    e.f64(u.distance);
    e.f64(u.gain);
    e.u64(u.tasks_left);
    match u.phase {
        Phase::Idle => e.u8(0),
        Phase::Compute {
            remaining_s,
            total_s,
            total_energy,
        } => {
            e.u8(1);
            e.f64(remaining_s);
            e.f64(total_s);
            e.f64(total_energy);
        }
        Phase::Offload { remaining_bits } => {
            e.u8(2);
            e.f64(remaining_bits);
        }
    }
    put_action(e, &u.decision);
    put_action(e, &u.pending);
    e.f64(u.cur_latency);
    e.f64(u.cur_energy);
    e.f64(u.frame_energy);
    e.u64(u.totals.completed);
    e.f64(u.totals.latency_sum);
    e.f64(u.totals.energy_sum);
}

fn put_env(e: &mut Enc, s: &EnvSnapshot) {
    put_scenario(e, &s.cfg);
    e.rng(s.rng);
    e.u64(s.frame_idx);
    e.u32(s.ues.len() as u32);
    for u in &s.ues {
        put_ue(e, u);
    }
}

fn put_engine(e: &mut Enc, s: &EngineSnapshot) {
    e.bool(s.started);
    e.u32(s.lanes.len() as u32);
    for l in &s.lanes {
        put_env(e, &l.env);
        e.rng(l.rng);
        e.rng(l.scenario_rng);
        e.f64(l.ep_reward);
    }
}

/// Encode a checkpoint into a fresh buffer (header + body).
pub fn encode(cp: &TrainerCheckpoint) -> Result<Vec<u8>, CheckpointError> {
    let mut e = Enc(Vec::with_capacity(4096));
    put_config(&mut e, &cp.config);
    put_scenario(&mut e, &cp.scenario);
    put_profile(&mut e, &cp.profile);
    e.u32(cp.actors.len() as u32);
    for a in &cp.actors {
        put_net(&mut e, a);
    }
    put_net(&mut e, &cp.critic);
    e.rng(cp.sampler_rng);
    put_engine(&mut e, &cp.engine);
    let body = e.0;
    if body.len() > MAX_BODY {
        return Err(CheckpointError::TooLarge { len: body.len() });
    }
    let mut out = Vec::with_capacity(HEADER_LEN + body.len());
    out.extend_from_slice(&MAGIC);
    out.push(VERSION);
    out.push(TAG_TRAINER);
    out.extend_from_slice(&(body.len() as u32).to_le_bytes());
    let crc = crc32_parts(&[&out[..8], &body]);
    out.extend_from_slice(&crc.to_le_bytes());
    out.extend_from_slice(&body);
    Ok(out)
}

// ---------------------------------------------------------------- decoding

struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }
    fn take(&mut self, n: usize) -> Result<&'a [u8], CheckpointError> {
        if self.remaining() < n {
            return Err(CheckpointError::Malformed(format!(
                "body needs {n} more bytes at offset {}, only {} left",
                self.pos,
                self.remaining()
            )));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
    fn u8(&mut self) -> Result<u8, CheckpointError> {
        Ok(self.take(1)?[0])
    }
    fn bool(&mut self) -> Result<bool, CheckpointError> {
        match self.u8()? {
            0 => Ok(false),
            1 => Ok(true),
            flag => Err(CheckpointError::Malformed(format!(
                "bool flag must be 0 or 1, got {flag}"
            ))),
        }
    }
    fn u32(&mut self) -> Result<u32, CheckpointError> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }
    fn u64(&mut self) -> Result<u64, CheckpointError> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([
            b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
        ]))
    }
    fn usize(&mut self) -> Result<usize, CheckpointError> {
        let v = self.u64()?;
        usize::try_from(v)
            .map_err(|_| CheckpointError::Malformed(format!("{v} does not fit a usize")))
    }
    fn f32(&mut self) -> Result<f32, CheckpointError> {
        let b = self.take(4)?;
        Ok(f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }
    fn f64(&mut self) -> Result<f64, CheckpointError> {
        let b = self.take(8)?;
        Ok(f64::from_le_bytes([
            b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
        ]))
    }
    fn str(&mut self) -> Result<String, CheckpointError> {
        let n = self.u32()? as usize;
        let bytes = self.take(n)?;
        String::from_utf8(bytes.to_vec())
            .map_err(|_| CheckpointError::Malformed("string is not utf-8".into()))
    }
    /// `n` raw f32s (the caller already validated `n` against a count
    /// field; the byte-level bound is enforced here).
    fn f32s_raw(&mut self, n: usize) -> Result<Vec<f32>, CheckpointError> {
        let bytes = self.take(n.checked_mul(4).ok_or_else(|| {
            CheckpointError::Malformed(format!("f32 count {n} overflows"))
        })?)?;
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
    fn rng(&mut self) -> Result<[u64; 4], CheckpointError> {
        let s = [self.u64()?, self.u64()?, self.u64()?, self.u64()?];
        if s == [0; 4] {
            return Err(CheckpointError::Malformed(
                "rng state is all zeros (unreachable from any seed)".into(),
            ));
        }
        Ok(s)
    }
    fn finish(self) -> Result<(), CheckpointError> {
        if self.pos != self.buf.len() {
            return Err(CheckpointError::Malformed(format!(
                "{} trailing bytes after the last section",
                self.buf.len() - self.pos
            )));
        }
        Ok(())
    }
}

fn get_scenario(d: &mut Dec) -> Result<ScenarioConfig, CheckpointError> {
    let sc = ScenarioConfig {
        n_ues: d.usize()?,
        n_channels: d.usize()?,
        bandwidth_hz: d.f64()?,
        noise_w: d.f64()?,
        path_loss_exp: d.f64()?,
        p_max: d.f64()?,
        frame_s: d.f64()?,
        beta: d.f64()?,
        lambda_tasks: d.f64()?,
        d_min: d.f64()?,
        d_max: d.f64()?,
        eval_mode: d.bool()?,
        eval_distance: d.f64()?,
        eval_tasks: d.u64()?,
        max_frames: d.usize()?,
    };
    sc.validate()
        .map_err(|e| CheckpointError::Malformed(format!("invalid scenario: {e}")))?;
    Ok(sc)
}

fn get_dist(d: &mut Dec) -> Result<ScenarioDistribution, CheckpointError> {
    let base = get_scenario(d)?;
    let n = d.u32()? as usize;
    let mut ue_buckets = Vec::new();
    for _ in 0..n {
        ue_buckets.push(d.usize()?);
    }
    let mut ranges = [(0.0, 0.0); 3];
    for r in &mut ranges {
        *r = (d.f64()?, d.f64()?);
    }
    let dist = ScenarioDistribution {
        base,
        ue_buckets,
        lambda_range: ranges[0],
        d_max_range: ranges[1],
        p_max_range: ranges[2],
    };
    dist.validate()
        .map_err(|e| CheckpointError::Malformed(format!("invalid scenario distribution: {e}")))?;
    Ok(dist)
}

fn get_config(d: &mut Dec) -> Result<TrainConfig, CheckpointError> {
    let cfg = TrainConfig {
        buffer_size: d.usize()?,
        minibatch: d.usize()?,
        reuse: d.usize()?,
        gamma: d.f64()?,
        lam: d.f64()?,
        lr: d.f32()?,
        normalize_adv: d.bool()?,
        seed: d.u64()?,
        n_envs: d.usize()?,
        rollout_threads: d.usize()?,
        update_threads: d.usize()?,
        scenario_dist: match d.u8()? {
            0 => None,
            1 => Some(get_dist(d)?),
            flag => {
                return Err(CheckpointError::Malformed(format!(
                    "scenario_dist flag must be 0 or 1, got {flag}"
                )))
            }
        },
    };
    cfg.validate()
        .map_err(|e| CheckpointError::Malformed(format!("invalid train config: {e}")))?;
    Ok(cfg)
}

fn get_profile(d: &mut Dec) -> Result<DeviceProfile, CheckpointError> {
    let model = d.str()?;
    let n_choices = d.usize()?;
    let n = d.u32()? as usize;
    let mut entries = Vec::new();
    for _ in 0..n {
        entries.push(OverheadEntry {
            b: d.usize()?,
            t_f: d.f64()?,
            e_f: d.f64()?,
            t_c: d.f64()?,
            e_c: d.f64()?,
            bits: d.f64()?,
        });
    }
    if n_choices == 0 {
        // every consumer computes `n_choices - 1` (the full-local choice);
        // a zero-choice profile must be a decode error, not a later panic
        return Err(CheckpointError::Malformed(
            "profile has zero partition choices".into(),
        ));
    }
    if entries.len() != n_choices {
        return Err(CheckpointError::Malformed(format!(
            "profile has {} entries but claims {n_choices} partition choices",
            entries.len()
        )));
    }
    let nj = d.u32()? as usize;
    let mut jalad = Vec::new();
    for _ in 0..nj {
        jalad.push(JaladEntry {
            b: d.usize()?,
            t_c: d.f64()?,
            e_c: d.f64()?,
            bits: d.f64()?,
            rate: d.f64()?,
        });
    }
    Ok(DeviceProfile {
        model,
        n_choices,
        entries,
        jalad,
        full_local_t: d.f64()?,
        full_local_e: d.f64()?,
        input_bits: d.f64()?,
    })
}

fn get_net(d: &mut Dec) -> Result<NetState, CheckpointError> {
    let n = d.u32()? as usize;
    // params + m + v at 4 bytes each, then the step counter
    if n > d.remaining() / 12 {
        return Err(CheckpointError::Malformed(format!(
            "net claims {n} params in a {}-byte remainder",
            d.remaining()
        )));
    }
    Ok(NetState {
        params: d.f32s_raw(n)?,
        m: d.f32s_raw(n)?,
        v: d.f32s_raw(n)?,
        t: d.u64()?,
    })
}

fn get_action(d: &mut Dec) -> Result<HybridAction, CheckpointError> {
    Ok(HybridAction {
        b: d.usize()?,
        c: d.usize()?,
        p_raw: d.f32()?,
        p_watts: d.f64()?,
    })
}

fn get_ue(d: &mut Dec) -> Result<UeSnapshot, CheckpointError> {
    let id = d.usize()?;
    let distance = d.f64()?;
    let gain = d.f64()?;
    let tasks_left = d.u64()?;
    let phase = match d.u8()? {
        0 => Phase::Idle,
        1 => Phase::Compute {
            remaining_s: d.f64()?,
            total_s: d.f64()?,
            total_energy: d.f64()?,
        },
        2 => Phase::Offload {
            remaining_bits: d.f64()?,
        },
        tag => {
            return Err(CheckpointError::Malformed(format!(
                "unknown UE phase tag {tag}"
            )))
        }
    };
    Ok(UeSnapshot {
        id,
        distance,
        gain,
        tasks_left,
        phase,
        decision: get_action(d)?,
        pending: get_action(d)?,
        cur_latency: d.f64()?,
        cur_energy: d.f64()?,
        frame_energy: d.f64()?,
        totals: TaskTotals {
            completed: d.u64()?,
            latency_sum: d.f64()?,
            energy_sum: d.f64()?,
        },
    })
}

fn get_env(d: &mut Dec) -> Result<EnvSnapshot, CheckpointError> {
    let cfg = get_scenario(d)?;
    let rng = d.rng()?;
    let frame_idx = d.u64()?;
    let n = d.u32()? as usize;
    let mut ues = Vec::new();
    for _ in 0..n {
        ues.push(get_ue(d)?);
    }
    if ues.len() != cfg.n_ues {
        return Err(CheckpointError::Malformed(format!(
            "env snapshot has {} UEs for an N={} scenario",
            ues.len(),
            cfg.n_ues
        )));
    }
    Ok(EnvSnapshot {
        cfg,
        rng,
        frame_idx,
        ues,
    })
}

fn get_engine(d: &mut Dec) -> Result<EngineSnapshot, CheckpointError> {
    let started = d.bool()?;
    let n = d.u32()? as usize;
    let mut lanes = Vec::new();
    for _ in 0..n {
        lanes.push(LaneSnapshot {
            env: get_env(d)?,
            rng: d.rng()?,
            scenario_rng: d.rng()?,
            ep_reward: d.f64()?,
        });
    }
    Ok(EngineSnapshot { started, lanes })
}

/// Decode one checkpoint from a complete buffer. Total: every failure
/// path is a typed [`CheckpointError`].
pub fn decode(buf: &[u8]) -> Result<TrainerCheckpoint, CheckpointError> {
    if buf.len() < HEADER_LEN {
        return Err(CheckpointError::Truncated {
            have: buf.len(),
            need: HEADER_LEN,
        });
    }
    if buf[0..2] != MAGIC {
        return Err(CheckpointError::BadMagic {
            got: [buf[0], buf[1]],
        });
    }
    if buf[2] != VERSION {
        return Err(CheckpointError::Version { got: buf[2] });
    }
    let tag = buf[3];
    if tag != TAG_TRAINER {
        return Err(CheckpointError::UnknownTag { got: tag });
    }
    let len = u32::from_le_bytes([buf[4], buf[5], buf[6], buf[7]]) as usize;
    if len > MAX_BODY {
        return Err(CheckpointError::TooLarge { len });
    }
    let need = HEADER_LEN + len;
    if buf.len() < need {
        return Err(CheckpointError::Truncated {
            have: buf.len(),
            need,
        });
    }
    if buf.len() > need {
        return Err(CheckpointError::Malformed(format!(
            "{} bytes after the frame end",
            buf.len() - need
        )));
    }
    let expect = u32::from_le_bytes([buf[8], buf[9], buf[10], buf[11]]);
    let body = &buf[HEADER_LEN..];
    let got = crc32_parts(&[&buf[..8], body]);
    if expect != got {
        return Err(CheckpointError::Corrupt { expect, got });
    }

    let mut d = Dec { buf: body, pos: 0 };
    let config = get_config(&mut d)?;
    let scenario = get_scenario(&mut d)?;
    let profile = get_profile(&mut d)?;
    let na = d.u32()? as usize;
    let mut actors = Vec::new();
    for _ in 0..na {
        actors.push(get_net(&mut d)?);
    }
    let critic = get_net(&mut d)?;
    let sampler_rng = d.rng()?;
    let engine = get_engine(&mut d)?;
    d.finish()?;

    // cross-section consistency the per-section parsers cannot see
    if actors.len() != scenario.n_ues {
        return Err(CheckpointError::Malformed(format!(
            "{} actor nets for an N={} scenario",
            actors.len(),
            scenario.n_ues
        )));
    }
    if engine.lanes.len() != config.n_envs {
        return Err(CheckpointError::Malformed(format!(
            "{} engine lanes for an n_envs={} config",
            engine.lanes.len(),
            config.n_envs
        )));
    }
    for st in actors.iter().chain(std::iter::once(&critic)) {
        st.validate()
            .map_err(|e| CheckpointError::Malformed(format!("{e:#}")))?;
    }
    Ok(TrainerCheckpoint {
        config,
        scenario,
        profile,
        actors,
        critic,
        sampler_rng,
        engine,
    })
}

/// Write a checkpoint to disk (atomically: temp file + rename, so a crash
/// mid-save never leaves a torn checkpoint at `path`).
pub fn save(cp: &TrainerCheckpoint, path: impl AsRef<Path>) -> Result<(), CheckpointError> {
    let path = path.as_ref();
    let bytes = encode(cp)?;
    let tmp = path.with_extension("ckpt.tmp");
    std::fs::write(&tmp, &bytes).map_err(CheckpointError::Io)?;
    std::fs::rename(&tmp, path).map_err(CheckpointError::Io)
}

/// Read and decode a checkpoint from disk.
pub fn load(path: impl AsRef<Path>) -> Result<TrainerCheckpoint, CheckpointError> {
    let bytes = std::fs::read(path).map_err(CheckpointError::Io)?;
    decode(&bytes)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A small hand-built checkpoint (no artifact store needed).
    pub(crate) fn sample_checkpoint() -> TrainerCheckpoint {
        let scenario = ScenarioConfig {
            n_ues: 2,
            lambda_tasks: 8.0,
            ..Default::default()
        };
        let config = TrainConfig {
            buffer_size: 8,
            minibatch: 4,
            n_envs: 2,
            seed: 9,
            scenario_dist: Some(ScenarioDistribution::around(scenario.clone())),
            ..Default::default()
        };
        let net = |k: f32, t: u64| NetState {
            params: vec![k, -k, 0.25 * k, f32::MIN_POSITIVE],
            m: vec![0.0, 1e-9, -2.0, 3.0],
            v: vec![0.5; 4],
            t,
        };
        let ue = |id: usize, phase: Phase| UeSnapshot {
            id,
            distance: 40.0 + id as f64,
            gain: 1e-5,
            tasks_left: 3,
            phase,
            decision: HybridAction::new(2, 1, 0.3, 1.0),
            pending: HybridAction::new(0, 0, -0.7, 1.0),
            cur_latency: 0.01,
            cur_energy: 0.002,
            frame_energy: 0.001,
            totals: TaskTotals {
                completed: 5,
                latency_sum: 0.4,
                energy_sum: 0.9,
            },
        };
        let lane = |seed: u64| LaneSnapshot {
            env: EnvSnapshot {
                cfg: scenario.clone(),
                rng: crate::util::rng::Rng::new(seed).state(),
                frame_idx: 17,
                ues: vec![
                    ue(
                        0,
                        Phase::Compute {
                            remaining_s: 0.01,
                            total_s: 0.05,
                            total_energy: 0.1,
                        },
                    ),
                    ue(1, Phase::Offload { remaining_bits: 900.0 }),
                ],
            },
            rng: crate::util::rng::Rng::new(seed ^ 1).state(),
            scenario_rng: crate::util::rng::Rng::new(seed ^ 2).state(),
            ep_reward: -3.25,
        };
        TrainerCheckpoint {
            config,
            scenario,
            profile: crate::profiles::DeviceProfile::synthetic(),
            actors: vec![net(1.5, 7), net(-0.25, 7)],
            critic: net(9.0, 7),
            sampler_rng: crate::util::rng::Rng::new(3).state(),
            engine: EngineSnapshot {
                started: true,
                lanes: vec![lane(10), lane(11)],
            },
        }
    }

    #[test]
    fn roundtrip_is_bit_exact() {
        let cp = sample_checkpoint();
        let bytes = encode(&cp).unwrap();
        let back = decode(&bytes).unwrap();
        assert_eq!(back, cp);
        // and re-encoding is byte-identical (canonical encoding)
        assert_eq!(encode(&back).unwrap(), bytes);
    }

    #[test]
    fn save_load_roundtrips_through_disk() {
        let cp = sample_checkpoint();
        let dir = std::env::temp_dir().join(format!("macci_ckpt_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trainer.ckpt");
        save(&cp, &path).unwrap();
        assert_eq!(load(&path).unwrap(), cp);
        assert!(
            !path.with_extension("ckpt.tmp").exists(),
            "temp file renamed away"
        );
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn header_errors_are_typed() {
        let cp = sample_checkpoint();
        let good = encode(&cp).unwrap();

        assert!(matches!(
            decode(&good[..5]),
            Err(CheckpointError::Truncated { .. })
        ));

        let mut bad = good.clone();
        bad[0] = b'X';
        assert!(matches!(decode(&bad), Err(CheckpointError::BadMagic { .. })));

        let mut bad = good.clone();
        bad[2] = 99;
        assert!(matches!(
            decode(&bad),
            Err(CheckpointError::Version { got: 99 })
        ));

        let mut bad = good.clone();
        bad[3] = 0x7F;
        assert!(matches!(
            decode(&bad),
            Err(CheckpointError::UnknownTag { got: 0x7F })
        ));

        let mut bad = good.clone();
        bad[4..8].copy_from_slice(&(MAX_BODY as u32 + 1).to_le_bytes());
        assert!(matches!(decode(&bad), Err(CheckpointError::TooLarge { .. })));

        let mut bad = good.clone();
        *bad.last_mut().unwrap() ^= 0x10;
        assert!(matches!(decode(&bad), Err(CheckpointError::Corrupt { .. })));

        let mut bad = good.clone();
        bad.push(0);
        assert!(matches!(decode(&bad), Err(CheckpointError::Malformed(_))));

        assert!(decode(&good).is_ok(), "the pristine buffer still decodes");
    }

    #[test]
    fn semantic_validation_runs_after_crc() {
        // flip a semantic field (engine lane count) and re-seal the CRC:
        // the decoder must still reject it, with a Malformed error
        let mut cp = sample_checkpoint();
        cp.engine.lanes.pop();
        let err = match encode(&cp) {
            // encode is structural only; decode must catch it
            Ok(bytes) => decode(&bytes).unwrap_err(),
            Err(e) => e,
        };
        assert!(
            matches!(err, CheckpointError::Malformed(_)),
            "got {err:?}"
        );

        let mut cp = sample_checkpoint();
        cp.actors[0].m.pop();
        let bytes = encode(&cp).unwrap();
        // m shares params' count on the wire, so the tail mis-parses into
        // some typed error — never a panic, never an Ok
        assert!(decode(&bytes).is_err());

        // a zero-partition-choice profile would make every consumer's
        // `n_choices - 1` underflow — decode must reject it up front
        let mut cp = sample_checkpoint();
        cp.profile.n_choices = 0;
        cp.profile.entries.clear();
        let bytes = encode(&cp).unwrap();
        let err = decode(&bytes).unwrap_err();
        assert!(
            matches!(err, CheckpointError::Malformed(_)),
            "zero-choice profile must be Malformed, got {err:?}"
        );
    }
}
