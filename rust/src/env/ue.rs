//! Per-UE task state machine.
//!
//! A task executes in (up to) two phases, per the partition decision `b`
//! latched at task start (Sec. 4.3 — `b`/`c` take effect when a new task
//! starts, transmit power immediately):
//!
//! * **Compute** — local inference of the front segment plus feature
//!   compression: duration `t_f(b) + t_c(b)`, energy `e_f(b) + e_c(b)`
//!   accrued proportionally over the phase.
//! * **Offload** — transmitting `bits(b)` over the shared uplink at the
//!   instantaneous rate from the channel model; energy `p · dt` (Eq. 9).
//!
//! `b = 0` skips Compute (raw-input offload); `b = B+1` skips Offload
//! (full-local). Per-task latency/energy are accumulated so the experiment
//! harness can report the paper's "averaged inference overhead" (Fig. 11).

use super::HybridAction;
use crate::profiles::DeviceProfile;

/// Execution phase of the UE's current task.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Phase {
    /// No task in flight (between tasks, or all done).
    Idle,
    /// Local compute (+compression): `remaining_s` of `total_s` left.
    Compute {
        remaining_s: f64,
        total_s: f64,
        /// Total energy of the whole compute phase (accrued pro rata).
        total_energy: f64,
    },
    /// Uplink transmission: `remaining_bits` still to send.
    Offload { remaining_bits: f64 },
}

/// Aggregate per-episode task accounting.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct TaskTotals {
    pub completed: u64,
    pub latency_sum: f64,
    pub energy_sum: f64,
}

impl TaskTotals {
    pub fn avg_latency(&self) -> f64 {
        if self.completed == 0 {
            0.0
        } else {
            self.latency_sum / self.completed as f64
        }
    }

    pub fn avg_energy(&self) -> f64 {
        if self.completed == 0 {
            0.0
        } else {
            self.energy_sum / self.completed as f64
        }
    }
}

/// One user equipment.
#[derive(Debug, Clone)]
pub struct Ue {
    pub id: usize,
    pub distance: f64,
    pub gain: f64,
    pub tasks_left: u64,
    pub phase: Phase,
    /// Decision latched for the task currently in flight.
    pub decision: HybridAction,
    /// Decision that will latch at the next task start (updated per frame).
    pub pending: HybridAction,
    /// Per-task accumulators for the task in flight.
    cur_latency: f64,
    cur_energy: f64,
    /// Energy spent in the current frame (reward Eq. 12 input).
    pub frame_energy: f64,
    pub totals: TaskTotals,
}

/// Complete mid-episode state of one UE, with every private accumulator
/// exposed — the unit [`crate::rl::checkpoint`] serializes so a restored
/// environment resumes the episode bit-for-bit.
#[derive(Debug, Clone, PartialEq)]
pub struct UeSnapshot {
    pub id: usize,
    pub distance: f64,
    pub gain: f64,
    pub tasks_left: u64,
    pub phase: Phase,
    pub decision: HybridAction,
    pub pending: HybridAction,
    pub cur_latency: f64,
    pub cur_energy: f64,
    pub frame_energy: f64,
    pub totals: TaskTotals,
}

impl Ue {
    pub fn new(id: usize, distance: f64, gain: f64, tasks: u64, default_action: HybridAction) -> Ue {
        Ue {
            id,
            distance,
            gain,
            tasks_left: tasks,
            phase: Phase::Idle,
            decision: default_action,
            pending: default_action,
            cur_latency: 0.0,
            cur_energy: 0.0,
            frame_energy: 0.0,
            totals: TaskTotals::default(),
        }
    }

    /// All tasks done and nothing in flight?
    pub fn finished(&self) -> bool {
        self.tasks_left == 0 && self.phase == Phase::Idle
    }

    /// Capture the complete task-machine state (checkpointing).
    pub fn snapshot(&self) -> UeSnapshot {
        UeSnapshot {
            id: self.id,
            distance: self.distance,
            gain: self.gain,
            tasks_left: self.tasks_left,
            phase: self.phase,
            decision: self.decision,
            pending: self.pending,
            cur_latency: self.cur_latency,
            cur_energy: self.cur_energy,
            frame_energy: self.frame_energy,
            totals: self.totals,
        }
    }

    /// Rebuild a UE from a [`Ue::snapshot`] — resumes mid-phase exactly.
    pub fn from_snapshot(s: UeSnapshot) -> Ue {
        Ue {
            id: s.id,
            distance: s.distance,
            gain: s.gain,
            tasks_left: s.tasks_left,
            phase: s.phase,
            decision: s.decision,
            pending: s.pending,
            cur_latency: s.cur_latency,
            cur_energy: s.cur_energy,
            frame_energy: s.frame_energy,
            totals: s.totals,
        }
    }

    /// Transmit power takes effect immediately (Sec. 4.3); `b`/`c` latch at
    /// the next task start.
    pub fn apply_action(&mut self, a: HybridAction) {
        self.pending = a;
        self.decision.p_raw = a.p_raw;
        self.decision.p_watts = a.p_watts;
    }

    /// Pop the next task and enter its first phase. No-op unless Idle with
    /// tasks remaining.
    pub fn maybe_start_task(&mut self, profile: &DeviceProfile) {
        if self.phase != Phase::Idle || self.tasks_left == 0 {
            return;
        }
        self.tasks_left -= 1;
        self.decision = self.pending;
        self.cur_latency = 0.0;
        self.cur_energy = 0.0;
        let e = profile.entry(self.decision.b.min(profile.n_choices - 1));
        let compute_s = e.t_f + e.t_c;
        let compute_j = e.e_f + e.e_c;
        self.phase = if compute_s > 0.0 {
            Phase::Compute {
                remaining_s: compute_s,
                total_s: compute_s,
                total_energy: compute_j,
            }
        } else if e.bits > 0.0 {
            Phase::Offload {
                remaining_bits: e.bits,
            }
        } else {
            // degenerate zero-cost task: complete instantly
            self.complete_task();
            Phase::Idle
        };
    }

    /// Currently transmitting?
    pub fn offloading(&self) -> bool {
        matches!(self.phase, Phase::Offload { .. })
    }

    /// Time until the current phase completes at the given uplink rate
    /// (f64::INFINITY when not active or rate is zero).
    pub fn time_to_completion(&self, rate_bps: f64) -> f64 {
        match self.phase {
            Phase::Idle => f64::INFINITY,
            Phase::Compute { remaining_s, .. } => remaining_s,
            Phase::Offload { remaining_bits } => {
                if rate_bps > 0.0 {
                    remaining_bits / rate_bps
                } else {
                    f64::INFINITY
                }
            }
        }
    }

    /// Advance the in-flight phase by `dt` seconds; returns `true` if the
    /// task completed during this interval. Transitions Compute → Offload
    /// when the compute phase drains and a payload exists.
    pub fn advance(&mut self, dt: f64, rate_bps: f64, profile: &DeviceProfile) -> bool {
        match self.phase {
            Phase::Idle => false,
            Phase::Compute {
                remaining_s,
                total_s,
                total_energy,
            } => {
                let used = dt.min(remaining_s);
                let de = if total_s > 0.0 {
                    total_energy * used / total_s
                } else {
                    0.0
                };
                self.cur_latency += used;
                self.cur_energy += de;
                self.frame_energy += de;
                let left = remaining_s - used;
                if left > 1e-12 {
                    self.phase = Phase::Compute {
                        remaining_s: left,
                        total_s,
                        total_energy,
                    };
                    false
                } else {
                    let bits = profile.entry(self.decision.b.min(profile.n_choices - 1)).bits;
                    if bits > 0.0 {
                        self.phase = Phase::Offload {
                            remaining_bits: bits,
                        };
                        false
                    } else {
                        self.complete_task();
                        true
                    }
                }
            }
            Phase::Offload { remaining_bits } => {
                let sent = rate_bps * dt;
                let de = self.decision.p_watts * dt;
                self.cur_latency += dt;
                self.cur_energy += de;
                self.frame_energy += de;
                let left = remaining_bits - sent;
                if left > 1e-6 {
                    self.phase = Phase::Offload {
                        remaining_bits: left,
                    };
                    false
                } else {
                    self.complete_task();
                    true
                }
            }
        }
    }

    fn complete_task(&mut self) {
        self.totals.completed += 1;
        self.totals.latency_sum += self.cur_latency;
        self.totals.energy_sum += self.cur_energy;
        self.phase = Phase::Idle;
    }

    /// Remaining local compute time of the in-flight task (state `l_t`).
    pub fn remaining_compute_s(&self) -> f64 {
        match self.phase {
            Phase::Compute { remaining_s, .. } => remaining_s,
            _ => 0.0,
        }
    }

    /// Remaining offload payload of the in-flight task (state `n_t`).
    pub fn remaining_offload_bits(&self) -> f64 {
        match self.phase {
            Phase::Offload { remaining_bits } => remaining_bits,
            _ => 0.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn action(b: usize) -> HybridAction {
        HybridAction::new(b, 0, 0.0, 1.0)
    }

    fn ue(b: usize, tasks: u64) -> Ue {
        Ue::new(0, 50.0, 8e-6, tasks, action(b))
    }

    #[test]
    fn full_local_task_lifecycle() {
        let p = DeviceProfile::synthetic();
        let mut u = ue(5, 1);
        u.maybe_start_task(&p);
        assert!(matches!(u.phase, Phase::Compute { .. }));
        // full local takes 0.05 s; advance in two halves
        assert!(!u.advance(0.025, 0.0, &p));
        assert!(u.advance(0.05, 0.0, &p));
        assert!(u.finished());
        assert_eq!(u.totals.completed, 1);
        assert!((u.totals.latency_sum - 0.05).abs() < 1e-9);
        assert!((u.totals.energy_sum - 0.107).abs() < 1e-9);
    }

    #[test]
    fn raw_offload_skips_compute() {
        let p = DeviceProfile::synthetic();
        let mut u = ue(0, 1);
        u.maybe_start_task(&p);
        assert!(u.offloading());
        // 1.2e6 bits at 1.2e7 bps -> 0.1 s, at 0.5 W (sigmoid(0) * 1W)
        assert!(u.advance(0.1, 1.2e7, &p));
        assert!((u.totals.latency_sum - 0.1).abs() < 1e-9);
        assert!((u.totals.energy_sum - 0.05).abs() < 1e-9);
    }

    #[test]
    fn split_task_two_phases() {
        let p = DeviceProfile::synthetic();
        let mut u = ue(2, 1);
        u.maybe_start_task(&p);
        let e = p.entry(2);
        let compute = e.t_f + e.t_c;
        assert!(!u.advance(compute, 0.0, &p));
        assert!(u.offloading());
        assert_eq!(u.remaining_offload_bits(), e.bits);
        assert!(u.advance(e.bits / 1e6, 1e6, &p));
        assert_eq!(u.totals.completed, 1);
    }

    #[test]
    fn decision_latches_at_task_start_power_immediate() {
        let p = DeviceProfile::synthetic();
        let mut u = ue(5, 2);
        u.maybe_start_task(&p);
        // mid-task action change: power applies now, b/c at next task
        u.apply_action(HybridAction::new(1, 1, 2.0, 1.0));
        assert_eq!(u.decision.b, 5, "b must not change mid-task");
        assert!(u.decision.p_watts > 0.8, "power applies immediately");
        // finish task 1; task 2 must use b=1
        assert!(u.advance(0.06, 0.0, &p));
        u.maybe_start_task(&p);
        assert_eq!(u.decision.b, 1);
    }

    #[test]
    fn frame_energy_accrues_and_resets_externally() {
        let p = DeviceProfile::synthetic();
        let mut u = ue(5, 1);
        u.maybe_start_task(&p);
        u.advance(0.025, 0.0, &p);
        assert!(u.frame_energy > 0.0);
        let half = u.frame_energy;
        assert!((half - 0.107 / 2.0).abs() < 1e-6);
    }
}
