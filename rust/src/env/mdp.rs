//! The frame-stepped MDP (paper Sec. 4.3).
//!
//! * **State** `s_t = {k_t, l_t, n_t, d}` — per-UE remaining task count,
//!   remaining local compute time, remaining offload payload, and distance;
//!   concatenated and normalized into a `4N` vector.
//! * **Action** — one [`HybridAction`] per UE; power effective immediately,
//!   `b`/`c` latched at the next task start.
//! * **Transition** — event-driven continuous-time simulation inside one
//!   frame of `T0` seconds: uplink rates are recomputed whenever the set of
//!   transmitting UEs changes (task/phase completions), so intra-frame
//!   interference dynamics are exact for piecewise-constant rates.
//! * **Reward** Eq. (12): `r_t = -T0/K_t − β·E_t/K_t` with `K_t` clamped to
//!   ≥ 1 (a frame that completes nothing pays the full frame penalty).

use anyhow::Result;

use super::channel::{ChannelModel, Transmitter};
use super::scenario::ScenarioConfig;
use super::ue::{TaskTotals, Ue, UeSnapshot};
use super::{Action, HybridAction};
use crate::profiles::DeviceProfile;
use crate::util::rng::Rng;

/// Result of one environment step (one decision frame).
#[derive(Debug, Clone)]
pub struct StepResult {
    pub state: Vec<f32>,
    pub reward: f64,
    pub done: bool,
    pub info: FrameInfo,
}

/// Diagnostics for the frame just simulated.
#[derive(Debug, Clone, Copy, Default)]
pub struct FrameInfo {
    /// K_t — tasks completed during the frame.
    pub completed: u64,
    /// E_t — energy consumed during the frame (J).
    pub energy: f64,
    /// Wall-clock simulated inside the frame (== T0 unless episode ended).
    pub elapsed: f64,
}

/// Complete mid-episode state of a [`MultiAgentEnv`]: scenario, RNG
/// stream position, frame counter and every UE's task machine. Restoring
/// it with [`MultiAgentEnv::from_snapshot`] resumes the episode (and the
/// env's random stream) bit-for-bit.
#[derive(Debug, Clone, PartialEq)]
pub struct EnvSnapshot {
    pub cfg: ScenarioConfig,
    pub rng: [u64; 4],
    pub frame_idx: u64,
    pub ues: Vec<UeSnapshot>,
}

/// The multi-agent environment: N UEs + shared channels + one decision
/// frame per `step`.
pub struct MultiAgentEnv {
    pub cfg: ScenarioConfig,
    pub profile: DeviceProfile,
    channel: ChannelModel,
    ues: Vec<Ue>,
    rng: Rng,
    frame_idx: usize,
    max_bits_norm: f64,
}

impl MultiAgentEnv {
    pub fn new(profile: DeviceProfile, cfg: ScenarioConfig, seed: u64) -> Result<MultiAgentEnv> {
        cfg.validate()?;
        let channel = ChannelModel::new(&cfg);
        let max_bits_norm = profile.max_bits().max(1.0);
        let mut env = MultiAgentEnv {
            cfg,
            profile,
            channel,
            ues: Vec::new(),
            rng: Rng::new(seed),
            frame_idx: 0,
            max_bits_norm,
        };
        env.reset();
        Ok(env)
    }

    /// Start a new episode: re-draw distances and task counts (Sec. 6.3.1);
    /// in eval mode both are fixed (d = 50 m, K = 200).
    pub fn reset(&mut self) -> Vec<f32> {
        self.frame_idx = 0;
        let default_action =
            HybridAction::new(self.profile.local_choice(), 0, 0.0, self.cfg.p_max);
        self.ues = (0..self.cfg.n_ues)
            .map(|id| {
                let (d, k) = if self.cfg.eval_mode {
                    (self.cfg.eval_distance, self.cfg.eval_tasks)
                } else {
                    (
                        self.rng.uniform(self.cfg.d_min, self.cfg.d_max),
                        self.rng.poisson(self.cfg.lambda_tasks).max(1),
                    )
                };
                Ue::new(id, d, self.cfg.gain(d), k, default_action)
            })
            .collect();
        self.state()
    }

    /// Swap in a new scenario (domain-randomized training draws one per
    /// episode) and start a fresh episode under it. The env's RNG stream is
    /// preserved, so `reconfigure(same_cfg)` consumes exactly the draws a
    /// plain [`MultiAgentEnv::reset`] would.
    pub fn reconfigure(&mut self, cfg: ScenarioConfig) -> Result<Vec<f32>> {
        cfg.validate()?;
        self.channel = ChannelModel::new(&cfg);
        self.cfg = cfg;
        Ok(self.reset())
    }

    /// Capture the complete environment state for checkpointing.
    pub fn snapshot(&self) -> EnvSnapshot {
        EnvSnapshot {
            cfg: self.cfg.clone(),
            rng: self.rng.state(),
            frame_idx: self.frame_idx as u64,
            ues: self.ues.iter().map(Ue::snapshot).collect(),
        }
    }

    /// Rebuild an environment from an [`EnvSnapshot`]: same scenario, same
    /// RNG stream position, same in-flight tasks — stepping it produces
    /// exactly the frames the captured env would have produced. Rejects
    /// snapshots whose scenario fails validation, whose UE count does not
    /// match the scenario, or whose RNG state is the (unreachable)
    /// all-zero fixed point.
    pub fn from_snapshot(profile: DeviceProfile, snap: EnvSnapshot) -> Result<MultiAgentEnv> {
        snap.cfg.validate()?;
        anyhow::ensure!(
            snap.ues.len() == snap.cfg.n_ues,
            "snapshot has {} UEs for an N={} scenario",
            snap.ues.len(),
            snap.cfg.n_ues
        );
        let rng = Rng::from_state(snap.rng)
            .ok_or_else(|| anyhow::anyhow!("snapshot env rng state is all zeros"))?;
        let channel = ChannelModel::new(&snap.cfg);
        let max_bits_norm = profile.max_bits().max(1.0);
        Ok(MultiAgentEnv {
            channel,
            ues: snap.ues.into_iter().map(Ue::from_snapshot).collect(),
            rng,
            frame_idx: snap.frame_idx as usize,
            max_bits_norm,
            cfg: snap.cfg,
            profile,
        })
    }

    pub fn n_ues(&self) -> usize {
        self.cfg.n_ues
    }

    pub fn ues(&self) -> &[Ue] {
        &self.ues
    }

    pub fn frame_idx(&self) -> usize {
        self.frame_idx
    }

    /// Episode finished — every UE drained its task queue.
    pub fn done(&self) -> bool {
        self.ues.iter().all(|u| u.finished()) || self.frame_idx >= self.cfg.max_frames
    }

    /// Normalized state vector `{k, l, n, d}`, length 4N (Sec. 4.3).
    pub fn state(&self) -> Vec<f32> {
        let n = self.cfg.n_ues;
        let mut s = Vec::with_capacity(4 * n);
        let k_norm = self.cfg.lambda_tasks.max(1.0);
        for u in &self.ues {
            s.push((u.tasks_left as f64 / k_norm) as f32);
        }
        for u in &self.ues {
            s.push((u.remaining_compute_s() / self.cfg.frame_s) as f32);
        }
        for u in &self.ues {
            s.push((u.remaining_offload_bits() / self.max_bits_norm) as f32);
        }
        for u in &self.ues {
            s.push((u.distance / self.cfg.d_max) as f32);
        }
        s
    }

    /// Apply the joint action and simulate one frame of `T0` seconds.
    pub fn step(&mut self, actions: &Action) -> StepResult {
        assert_eq!(actions.len(), self.cfg.n_ues, "need one action per UE");
        for (u, a) in self.ues.iter_mut().zip(actions) {
            debug_assert!(a.b < self.profile.n_choices);
            debug_assert!(a.c < self.cfg.n_channels);
            u.apply_action(*a);
            u.frame_energy = 0.0;
        }

        let info = self.simulate_frame();
        let k = info.completed.max(1) as f64;
        let reward = -(self.cfg.frame_s / k) - self.cfg.beta * info.energy / k;
        self.frame_idx += 1;

        StepResult {
            state: self.state(),
            reward,
            done: self.done(),
            info,
        }
    }

    /// Event-driven intra-frame simulation with piecewise-constant rates.
    fn simulate_frame(&mut self) -> FrameInfo {
        let t0 = self.cfg.frame_s;
        let mut t = 0.0f64;
        let mut completed = 0u64;
        // Guard against pathological zero-length event loops.
        let mut iterations = 0usize;
        let max_iterations = 64 * (self.cfg.n_ues + 1) * 64;

        while t < t0 - 1e-12 {
            iterations += 1;
            if iterations > max_iterations {
                log::warn!("frame event cap hit at t={t:.6}");
                break;
            }
            // 1) start queued tasks on idle UEs
            for u in self.ues.iter_mut() {
                u.maybe_start_task(&self.profile);
            }
            if self.ues.iter().all(|u| u.finished()) {
                break; // episode drained mid-frame
            }

            // 2) current transmitter set -> uplink rates (Eq. 5)
            let txs: Vec<Transmitter> = self
                .ues
                .iter()
                .filter(|u| u.offloading())
                .map(|u| Transmitter {
                    ue: u.id,
                    channel: u.decision.c,
                    power_w: u.decision.p_watts,
                    gain: u.gain,
                })
                .collect();
            let rates = self.channel.rates(&txs);
            let mut rate_of = vec![0.0f64; self.cfg.n_ues];
            for (tx, r) in txs.iter().zip(&rates) {
                rate_of[tx.ue] = *r;
            }

            // 3) next event: earliest phase completion, capped by frame end
            let mut dt = t0 - t;
            for u in &self.ues {
                dt = dt.min(u.time_to_completion(rate_of[u.id]));
            }
            dt = dt.max(1e-9);

            // 4) advance everyone by dt at the frozen rates
            for u in self.ues.iter_mut() {
                if u.advance(dt, rate_of[u.id], &self.profile) {
                    completed += 1;
                }
            }
            t += dt;
        }

        FrameInfo {
            completed,
            energy: self.ues.iter().map(|u| u.frame_energy).sum(),
            elapsed: t,
        }
    }

    /// Aggregate per-task totals across UEs (Fig. 11 metrics).
    pub fn totals(&self) -> TaskTotals {
        let mut agg = TaskTotals::default();
        for u in &self.ues {
            agg.completed += u.totals.completed;
            agg.latency_sum += u.totals.latency_sum;
            agg.energy_sum += u.totals.energy_sum;
        }
        agg
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_env(n: usize, seed: u64) -> MultiAgentEnv {
        let cfg = ScenarioConfig {
            n_ues: n,
            ..Default::default()
        }
        .quick(5.0);
        MultiAgentEnv::new(DeviceProfile::synthetic(), cfg, seed).unwrap()
    }

    fn local_actions(env: &MultiAgentEnv) -> Action {
        (0..env.n_ues())
            .map(|_| HybridAction::new(env.profile.local_choice(), 0, 0.0, 1.0))
            .collect()
    }

    #[test]
    fn state_layout_and_normalization() {
        let env = quick_env(4, 1);
        let s = env.state();
        assert_eq!(s.len(), 16);
        // all-normalized: k in (0, ~3], l = n = 0 at reset, d in (0, 1]
        for &x in &s {
            assert!(x.is_finite() && x >= 0.0);
        }
        assert!(s[4..12].iter().all(|&x| x == 0.0), "l,n zero at reset");
    }

    #[test]
    fn local_policy_completes_episode() {
        let mut env = quick_env(3, 2);
        let mut frames = 0;
        let mut total_completed = 0;
        while !env.done() {
            let r = env.step(&local_actions(&env));
            total_completed += r.info.completed;
            frames += 1;
            assert!(r.reward <= 0.0);
            assert!(frames < 1000, "episode must terminate");
        }
        let tot = env.totals();
        assert_eq!(tot.completed, total_completed);
        assert!(tot.completed >= 3); // >= 1 task per UE
        // full-local per-task overhead matches the profile exactly
        assert!((tot.avg_latency() - 0.05).abs() < 1e-9);
        assert!((tot.avg_energy() - 0.107).abs() < 1e-9);
    }

    #[test]
    fn offload_policy_uses_channel_and_completes() {
        let mut env = quick_env(3, 3);
        let acts: Action = (0..3).map(|i| HybridAction::new(2, i % 2, 1.0, 1.0)).collect();
        let mut frames = 0;
        while !env.done() {
            env.step(&acts);
            frames += 1;
            assert!(frames < 10_000);
        }
        let tot = env.totals();
        assert!(tot.completed >= 3);
        // offloading at close-ish range must beat... at minimum, record
        // nonzero transmission energy
        assert!(tot.energy_sum > 0.0);
    }

    #[test]
    fn reward_matches_eq12() {
        let mut env = quick_env(2, 4);
        let r = env.step(&local_actions(&env));
        let k = r.info.completed.max(1) as f64;
        let expect = -(0.5 / k) - 0.47 * r.info.energy / k;
        assert!((r.reward - expect).abs() < 1e-12);
    }

    #[test]
    fn eval_mode_is_deterministic_across_seeds() {
        let cfg = ScenarioConfig {
            n_ues: 3,
            eval_mode: true,
            eval_tasks: 5,
            ..Default::default()
        };
        let mut e1 = MultiAgentEnv::new(DeviceProfile::synthetic(), cfg.clone(), 1).unwrap();
        let mut e2 = MultiAgentEnv::new(DeviceProfile::synthetic(), cfg, 999).unwrap();
        let a1 = local_actions(&e1);
        let (r1, r2) = (e1.step(&a1), e2.step(&a1));
        assert_eq!(r1.info.completed, r2.info.completed);
        assert!((r1.reward - r2.reward).abs() < 1e-12);
    }

    #[test]
    fn interference_slows_co_channel_offloads() {
        // two UEs offloading raw input on the same channel vs different
        let mk = |same: bool| {
            let cfg = ScenarioConfig {
                n_ues: 2,
                eval_mode: true,
                eval_tasks: 3,
                ..Default::default()
            };
            let mut env = MultiAgentEnv::new(DeviceProfile::synthetic(), cfg, 7).unwrap();
            let acts: Action = (0..2)
                .map(|i| HybridAction::new(0, if same { 0 } else { i }, 3.0, 1.0))
                .collect();
            let mut frames = 0;
            while !env.done() && frames < 5000 {
                env.step(&acts);
                frames += 1;
            }
            env.totals().avg_latency()
        };
        let same = mk(true);
        let diff = mk(false);
        assert!(
            same > diff * 1.2,
            "co-channel {same} should be notably slower than split {diff}"
        );
    }

    #[test]
    fn reconfigure_swaps_scenario_and_preserves_rng_stream() {
        // two identical envs; one reconfigures with its own cfg, the other
        // plain-resets — the resulting episodes must be identical because
        // reconfigure preserves the rng stream
        let mut a = quick_env(3, 21);
        let mut b = quick_env(3, 21);
        let s1 = a.reconfigure(a.cfg.clone()).unwrap();
        let s2 = b.reset();
        assert_eq!(s1, s2);
        // a genuinely different scenario takes effect immediately
        let mut wide = a.cfg.clone();
        wide.p_max = 2.5;
        wide.lambda_tasks = 9.0;
        a.reconfigure(wide).unwrap();
        assert_eq!(a.cfg.p_max, 2.5);
        let mut bad = a.cfg.clone();
        bad.noise_w = 0.0;
        assert!(a.reconfigure(bad).is_err());
    }

    #[test]
    fn snapshot_restore_resumes_episode_bitwise() {
        // run a few frames, snapshot mid-episode, then step the original
        // and the restored env in lockstep — identical states and rewards
        let mut env = quick_env(3, 77);
        let acts = local_actions(&env);
        for _ in 0..2 {
            env.step(&acts);
        }
        let snap = env.snapshot();
        let mut twin =
            MultiAgentEnv::from_snapshot(DeviceProfile::synthetic(), snap.clone()).unwrap();
        assert_eq!(twin.state(), env.state());
        for _ in 0..30 {
            if env.done() {
                // resets draw from the (shared-position) env RNG streams
                assert!(twin.done());
                assert_eq!(env.reset(), twin.reset());
            }
            let (a, b) = (env.step(&acts), twin.step(&acts));
            assert_eq!(a.state, b.state);
            assert_eq!(a.reward.to_bits(), b.reward.to_bits());
            assert_eq!(a.done, b.done);
        }
        // hostile snapshots are rejected, never panicked on
        let mut bad = snap.clone();
        bad.rng = [0; 4];
        assert!(MultiAgentEnv::from_snapshot(DeviceProfile::synthetic(), bad).is_err());
        let mut bad = snap.clone();
        bad.ues.pop();
        assert!(MultiAgentEnv::from_snapshot(DeviceProfile::synthetic(), bad).is_err());
        let mut bad = snap;
        bad.cfg.noise_w = 0.0;
        assert!(MultiAgentEnv::from_snapshot(DeviceProfile::synthetic(), bad).is_err());
    }

    #[test]
    fn episode_counts_all_tasks() {
        let mut env = quick_env(5, 8);
        let expected: u64 = env.ues().iter().map(|u| u.tasks_left).sum();
        while !env.done() {
            env.step(&local_actions(&env));
        }
        assert_eq!(env.totals().completed, expected);
    }
}
