//! Multi-UE collaborative-inference environment (paper Secs. 3–4).
//!
//! * [`scenario`] — scenario configuration (N, C, bandwidth, β, T0, …).
//! * [`channel`] — the wireless uplink model, Eq. (5), with co-channel
//!   interference between simultaneously offloading UEs.
//! * [`ue`] — per-UE task state machine (compute → compress → offload),
//!   driven by the device overhead profile.
//! * [`mdp`] — the frame-stepped MDP: state (Sec. 4.3), event-driven
//!   intra-frame simulation, reward Eq. (12), episode bookkeeping.

pub mod channel;
pub mod mdp;
pub mod scenario;
pub mod ue;

/// One UE's hybrid action (Sec. 3.3): partition point `b`, offloading
/// channel `c` (0-based internally) and transmit power.
///
/// `p_raw` is the unsquashed Gaussian sample the actor emitted — stored so
/// PPO can recompute its log-probability; `p_watts = p_max * sigmoid(p_raw)`
/// is what the radio actually uses (constraint C3: 0 < p ≤ p_max).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HybridAction {
    pub b: usize,
    pub c: usize,
    pub p_raw: f32,
    pub p_watts: f64,
}

impl HybridAction {
    /// Map a raw Gaussian power action into (0, p_max].
    pub fn squash_power(p_raw: f32, p_max: f64) -> f64 {
        let s = 1.0 / (1.0 + (-p_raw as f64).exp());
        (p_max * s).max(p_max * 1e-4)
    }

    pub fn new(b: usize, c: usize, p_raw: f32, p_max: f64) -> HybridAction {
        HybridAction {
            b,
            c,
            p_raw,
            p_watts: Self::squash_power(p_raw, p_max),
        }
    }
}

/// Joint action: one [`HybridAction`] per UE.
pub type Action = Vec<HybridAction>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn power_squash_respects_c3() {
        for raw in [-100.0f32, -2.0, 0.0, 2.0, 100.0] {
            let p = HybridAction::squash_power(raw, 1.0);
            assert!(p > 0.0 && p <= 1.0, "raw {raw} -> {p}");
        }
        assert!((HybridAction::squash_power(0.0, 2.0) - 1.0).abs() < 1e-9);
    }
}
