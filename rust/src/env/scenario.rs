//! Scenario configuration — the environment constants of Sec. 6.3.1 — and
//! the scenario *distribution* used for domain-randomized training: each
//! rollout lane can draw its own λ, distance range, UE-count bucket and
//! p_max so the learned policy generalizes across load and geometry
//! instead of overfitting one fixed deployment.

use crate::util::rng::Rng;

/// All environment constants. Defaults are the paper's Sec. 6.3.1 settings.
#[derive(Debug, Clone, PartialEq)]
pub struct ScenarioConfig {
    /// Number of UEs (N). Paper default 5, sweeps 3..10 (Fig. 10/11).
    pub n_ues: usize,
    /// Number of offloading channels (C). Paper: 2.
    pub n_channels: usize,
    /// Per-channel bandwidth ω (Hz). Paper: 1 MHz, static channels.
    pub bandwidth_hz: f64,
    /// Background noise power σ (W). Paper: 1e-9.
    pub noise_w: f64,
    /// Path-loss exponent l in g = d^{-l}. Paper: 3 (urban cellular).
    pub path_loss_exp: f64,
    /// Maximum transmit power p_max (W) — constraint (C3). Not stated in
    /// the paper; 1 W (see DESIGN.md §Substitutions).
    pub p_max: f64,
    /// Duration of one time frame T0 (s). Paper: 0.5 (3.0 for JALAD runs).
    pub frame_s: f64,
    /// Latency/energy balance β in Eq. (10)/(12). Paper: 0.47.
    pub beta: f64,
    /// Poisson parameter λ_p for the per-UE task count. Paper: 200.
    pub lambda_tasks: f64,
    /// UE–BS distance range (m): d_n ~ U[d_min, d_max]. Paper: [1, 100].
    pub d_min: f64,
    pub d_max: f64,
    /// Evaluation mode (Sec. 6.3.1): fixed d = 50 m and K = 200 tasks for
    /// fair comparison between trained agents.
    pub eval_mode: bool,
    pub eval_distance: f64,
    pub eval_tasks: u64,
    /// Safety cap on frames per episode (no-progress guard).
    pub max_frames: usize,
}

impl Default for ScenarioConfig {
    fn default() -> Self {
        ScenarioConfig {
            n_ues: 5,
            n_channels: 2,
            bandwidth_hz: 1e6,
            noise_w: 1e-9,
            path_loss_exp: 3.0,
            p_max: 1.0,
            frame_s: 0.5,
            beta: 0.47,
            lambda_tasks: 200.0,
            d_min: 1.0,
            d_max: 100.0,
            eval_mode: false,
            eval_distance: 50.0,
            eval_tasks: 200,
            max_frames: 100_000,
        }
    }
}

impl ScenarioConfig {
    /// The paper's JALAD-baseline setting: time frame relaxed to 3 s
    /// (Sec. 6.3.1 "Baselines") to help convergence.
    pub fn jalad_frame(mut self) -> Self {
        self.frame_s = 3.0;
        self
    }

    /// Evaluation variant (d = 50 m, K = 200) of this scenario.
    pub fn eval(mut self) -> Self {
        self.eval_mode = true;
        self
    }

    /// Quick-run variant for tests: few tasks, so episodes are short.
    pub fn quick(mut self, lambda: f64) -> Self {
        self.lambda_tasks = lambda;
        self.eval_tasks = lambda.max(1.0) as u64;
        self
    }

    /// Channel gain for a UE at distance d (g = d^{-l}).
    pub fn gain(&self, d: f64) -> f64 {
        d.max(self.d_min).powf(-self.path_loss_exp)
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.n_ues >= 1, "need at least one UE");
        anyhow::ensure!(self.n_channels >= 1, "need at least one channel");
        anyhow::ensure!(self.bandwidth_hz > 0.0, "bandwidth must be positive");
        anyhow::ensure!(self.noise_w > 0.0, "noise must be positive");
        anyhow::ensure!(self.p_max > 0.0, "p_max must be positive");
        anyhow::ensure!(self.frame_s > 0.0, "frame must be positive");
        anyhow::ensure!(self.beta >= 0.0, "beta must be non-negative");
        anyhow::ensure!(self.d_min > 0.0 && self.d_max >= self.d_min, "bad distance range");
        Ok(())
    }
}

/// A distribution over [`ScenarioConfig`]s for domain-randomized training.
///
/// `sample` draws a fresh scenario around `base`: the UE count comes from
/// `ue_buckets`, and λ / d_max / p_max are uniform over their ranges. The
/// draw order (bucket, λ, d_max, p_max) is fixed, so a given RNG stream
/// always yields the same scenario sequence regardless of which knobs are
/// actually randomized.
#[derive(Debug, Clone, PartialEq)]
pub struct ScenarioDistribution {
    /// Every sampled scenario starts from this config.
    pub base: ScenarioConfig,
    /// Candidate UE counts (paper sweeps N = 3..10). Training lanes pin N
    /// via [`ScenarioDistribution::sample_for`]; the buckets drive scenario
    /// sweeps and evaluation grids.
    pub ue_buckets: Vec<usize>,
    /// Uniform range for the Poisson task parameter λ_p.
    pub lambda_range: (f64, f64),
    /// Uniform range for the cell radius d_max (d_min stays at base).
    pub d_max_range: (f64, f64),
    /// Uniform range for the transmit-power cap p_max (constraint C3).
    pub p_max_range: (f64, f64),
}

impl ScenarioDistribution {
    /// A moderate randomization band around `base`: ±50 % on λ, d_max and
    /// p_max, UE count fixed at the base value.
    pub fn around(base: ScenarioConfig) -> ScenarioDistribution {
        ScenarioDistribution {
            ue_buckets: vec![base.n_ues],
            lambda_range: (0.5 * base.lambda_tasks, 1.5 * base.lambda_tasks),
            d_max_range: ((0.5 * base.d_max).max(base.d_min), 1.5 * base.d_max),
            p_max_range: (0.5 * base.p_max, 1.5 * base.p_max),
            base,
        }
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        self.base.validate()?;
        anyhow::ensure!(!self.ue_buckets.is_empty(), "need at least one UE bucket");
        anyhow::ensure!(self.ue_buckets.iter().all(|&n| n >= 1), "UE buckets must be >= 1");
        for (name, (lo, hi)) in [
            ("lambda_range", self.lambda_range),
            ("d_max_range", self.d_max_range),
            ("p_max_range", self.p_max_range),
        ] {
            anyhow::ensure!(lo > 0.0 && hi >= lo, "bad {name}: ({lo}, {hi})");
        }
        anyhow::ensure!(
            self.d_max_range.0 >= self.base.d_min,
            "d_max_range below d_min {}",
            self.base.d_min
        );
        Ok(())
    }

    /// Draw one scenario (UE count included).
    pub fn sample(&self, rng: &mut Rng) -> ScenarioConfig {
        let n_ues = self.ue_buckets[rng.below(self.ue_buckets.len())];
        let lambda = rng.uniform(self.lambda_range.0, self.lambda_range.1);
        let d_max = rng.uniform(self.d_max_range.0, self.d_max_range.1);
        let p_max = rng.uniform(self.p_max_range.0, self.p_max_range.1);
        ScenarioConfig {
            n_ues,
            lambda_tasks: lambda,
            eval_tasks: lambda.max(1.0) as u64,
            d_max,
            p_max,
            ..self.base.clone()
        }
    }

    /// Draw one scenario with the UE count pinned to `n_ues` (training
    /// lanes must keep the actor/critic state dimension fixed). Consumes
    /// the same number of RNG draws as [`ScenarioDistribution::sample`].
    pub fn sample_for(&self, n_ues: usize, rng: &mut Rng) -> ScenarioConfig {
        let mut sc = self.sample(rng);
        sc.n_ues = n_ues;
        sc
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper() {
        let c = ScenarioConfig::default();
        assert_eq!(c.n_ues, 5);
        assert_eq!(c.n_channels, 2);
        assert_eq!(c.bandwidth_hz, 1e6);
        assert_eq!(c.noise_w, 1e-9);
        assert_eq!(c.path_loss_exp, 3.0);
        assert_eq!(c.frame_s, 0.5);
        assert_eq!(c.beta, 0.47);
        assert_eq!(c.lambda_tasks, 200.0);
        assert!(c.validate().is_ok());
    }

    #[test]
    fn gain_decays_with_distance() {
        let c = ScenarioConfig::default();
        assert!(c.gain(1.0) > c.gain(10.0));
        assert!((c.gain(10.0) - 1e-3).abs() < 1e-12);
        // distances below d_min are clamped
        assert_eq!(c.gain(0.1), c.gain(1.0));
    }

    #[test]
    fn invalid_configs_rejected() {
        let mut c = ScenarioConfig::default();
        c.n_ues = 0;
        assert!(c.validate().is_err());
        let mut c = ScenarioConfig::default();
        c.noise_w = 0.0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn distribution_samples_within_ranges() {
        let dist = ScenarioDistribution {
            ue_buckets: vec![3, 5, 8],
            ..ScenarioDistribution::around(ScenarioConfig::default())
        };
        dist.validate().unwrap();
        let mut rng = Rng::new(3);
        for _ in 0..200 {
            let sc = dist.sample(&mut rng);
            sc.validate().unwrap();
            assert!([3usize, 5, 8].contains(&sc.n_ues));
            assert!(sc.lambda_tasks >= 100.0 && sc.lambda_tasks <= 300.0);
            assert!(sc.d_max >= 50.0 && sc.d_max <= 150.0);
            assert!(sc.p_max >= 0.5 && sc.p_max <= 1.5);
            assert_eq!(sc.n_channels, 2, "non-randomized knobs keep base values");
        }
    }

    #[test]
    fn distribution_sample_is_deterministic_and_pinnable() {
        let dist = ScenarioDistribution {
            ue_buckets: vec![3, 5, 8],
            ..ScenarioDistribution::around(ScenarioConfig::default())
        };
        let a = dist.sample(&mut Rng::new(7));
        let b = dist.sample(&mut Rng::new(7));
        assert_eq!(a.n_ues, b.n_ues);
        assert_eq!(a.lambda_tasks, b.lambda_tasks);
        assert_eq!(a.d_max, b.d_max);
        assert_eq!(a.p_max, b.p_max);
        // pinning N consumes the identical rng stream
        let p = dist.sample_for(5, &mut Rng::new(7));
        assert_eq!(p.n_ues, 5);
        assert_eq!(p.lambda_tasks, a.lambda_tasks);
        assert_eq!(p.p_max, a.p_max);
    }

    #[test]
    fn distribution_rejects_bad_ranges() {
        let mut d = ScenarioDistribution::around(ScenarioConfig::default());
        d.lambda_range = (10.0, 5.0);
        assert!(d.validate().is_err());
        let mut d = ScenarioDistribution::around(ScenarioConfig::default());
        d.ue_buckets.clear();
        assert!(d.validate().is_err());
        let mut d = ScenarioDistribution::around(ScenarioConfig::default());
        d.d_max_range = (0.5, 1.0); // below d_min = 1.0
        assert!(d.validate().is_err());
    }
}
