//! Scenario configuration — the environment constants of Sec. 6.3.1.

/// All environment constants. Defaults are the paper's Sec. 6.3.1 settings.
#[derive(Debug, Clone)]
pub struct ScenarioConfig {
    /// Number of UEs (N). Paper default 5, sweeps 3..10 (Fig. 10/11).
    pub n_ues: usize,
    /// Number of offloading channels (C). Paper: 2.
    pub n_channels: usize,
    /// Per-channel bandwidth ω (Hz). Paper: 1 MHz, static channels.
    pub bandwidth_hz: f64,
    /// Background noise power σ (W). Paper: 1e-9.
    pub noise_w: f64,
    /// Path-loss exponent l in g = d^{-l}. Paper: 3 (urban cellular).
    pub path_loss_exp: f64,
    /// Maximum transmit power p_max (W) — constraint (C3). Not stated in
    /// the paper; 1 W (see DESIGN.md §Substitutions).
    pub p_max: f64,
    /// Duration of one time frame T0 (s). Paper: 0.5 (3.0 for JALAD runs).
    pub frame_s: f64,
    /// Latency/energy balance β in Eq. (10)/(12). Paper: 0.47.
    pub beta: f64,
    /// Poisson parameter λ_p for the per-UE task count. Paper: 200.
    pub lambda_tasks: f64,
    /// UE–BS distance range (m): d_n ~ U[d_min, d_max]. Paper: [1, 100].
    pub d_min: f64,
    pub d_max: f64,
    /// Evaluation mode (Sec. 6.3.1): fixed d = 50 m and K = 200 tasks for
    /// fair comparison between trained agents.
    pub eval_mode: bool,
    pub eval_distance: f64,
    pub eval_tasks: u64,
    /// Safety cap on frames per episode (no-progress guard).
    pub max_frames: usize,
}

impl Default for ScenarioConfig {
    fn default() -> Self {
        ScenarioConfig {
            n_ues: 5,
            n_channels: 2,
            bandwidth_hz: 1e6,
            noise_w: 1e-9,
            path_loss_exp: 3.0,
            p_max: 1.0,
            frame_s: 0.5,
            beta: 0.47,
            lambda_tasks: 200.0,
            d_min: 1.0,
            d_max: 100.0,
            eval_mode: false,
            eval_distance: 50.0,
            eval_tasks: 200,
            max_frames: 100_000,
        }
    }
}

impl ScenarioConfig {
    /// The paper's JALAD-baseline setting: time frame relaxed to 3 s
    /// (Sec. 6.3.1 "Baselines") to help convergence.
    pub fn jalad_frame(mut self) -> Self {
        self.frame_s = 3.0;
        self
    }

    /// Evaluation variant (d = 50 m, K = 200) of this scenario.
    pub fn eval(mut self) -> Self {
        self.eval_mode = true;
        self
    }

    /// Quick-run variant for tests: few tasks, so episodes are short.
    pub fn quick(mut self, lambda: f64) -> Self {
        self.lambda_tasks = lambda;
        self.eval_tasks = lambda.max(1.0) as u64;
        self
    }

    /// Channel gain for a UE at distance d (g = d^{-l}).
    pub fn gain(&self, d: f64) -> f64 {
        d.max(self.d_min).powf(-self.path_loss_exp)
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.n_ues >= 1, "need at least one UE");
        anyhow::ensure!(self.n_channels >= 1, "need at least one channel");
        anyhow::ensure!(self.bandwidth_hz > 0.0, "bandwidth must be positive");
        anyhow::ensure!(self.noise_w > 0.0, "noise must be positive");
        anyhow::ensure!(self.p_max > 0.0, "p_max must be positive");
        anyhow::ensure!(self.frame_s > 0.0, "frame must be positive");
        anyhow::ensure!(self.beta >= 0.0, "beta must be non-negative");
        anyhow::ensure!(self.d_min > 0.0 && self.d_max >= self.d_min, "bad distance range");
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper() {
        let c = ScenarioConfig::default();
        assert_eq!(c.n_ues, 5);
        assert_eq!(c.n_channels, 2);
        assert_eq!(c.bandwidth_hz, 1e6);
        assert_eq!(c.noise_w, 1e-9);
        assert_eq!(c.path_loss_exp, 3.0);
        assert_eq!(c.frame_s, 0.5);
        assert_eq!(c.beta, 0.47);
        assert_eq!(c.lambda_tasks, 200.0);
        assert!(c.validate().is_ok());
    }

    #[test]
    fn gain_decays_with_distance() {
        let c = ScenarioConfig::default();
        assert!(c.gain(1.0) > c.gain(10.0));
        assert!((c.gain(10.0) - 1e-3).abs() < 1e-12);
        // distances below d_min are clamped
        assert_eq!(c.gain(0.1), c.gain(1.0));
    }

    #[test]
    fn invalid_configs_rejected() {
        let mut c = ScenarioConfig::default();
        c.n_ues = 0;
        assert!(c.validate().is_err());
        let mut c = ScenarioConfig::default();
        c.noise_w = 0.0;
        assert!(c.validate().is_err());
    }
}
