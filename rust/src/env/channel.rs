//! Wireless uplink model — Eq. (5) with co-channel interference.
//!
//! r_n = ω_{c_n} log2(1 + p_n g_n / (σ_{c_n} + Σ_{i co-channel, offloading} p_i g_i))
//!
//! The paper's formula sums interference over all offloading UEs; since σ
//! is per-channel and C = 2 channels otherwise have no effect, we restrict
//! the sum to UEs transmitting on the *same* channel (see DESIGN.md
//! §Substitutions — "ambiguities resolved").

use super::scenario::ScenarioConfig;

/// A transmitting UE as seen by the channel model.
#[derive(Debug, Clone, Copy)]
pub struct Transmitter {
    pub ue: usize,
    pub channel: usize,
    pub power_w: f64,
    pub gain: f64,
}

/// Computes uplink rates for the current set of transmitters.
#[derive(Debug, Clone)]
pub struct ChannelModel {
    pub bandwidth_hz: f64,
    pub noise_w: f64,
    pub n_channels: usize,
}

impl ChannelModel {
    pub fn new(cfg: &ScenarioConfig) -> ChannelModel {
        ChannelModel {
            bandwidth_hz: cfg.bandwidth_hz,
            noise_w: cfg.noise_w,
            n_channels: cfg.n_channels,
        }
    }

    /// Uplink rate (bits/s) for every transmitter, Eq. (5).
    ///
    /// O(T) per call: received powers are accumulated per channel once,
    /// then each transmitter subtracts its own contribution.
    pub fn rates(&self, txs: &[Transmitter]) -> Vec<f64> {
        let mut per_channel = vec![0.0f64; self.n_channels];
        for t in txs {
            debug_assert!(t.channel < self.n_channels);
            per_channel[t.channel] += t.power_w * t.gain;
        }
        txs.iter()
            .map(|t| {
                let signal = t.power_w * t.gain;
                let interference = per_channel[t.channel] - signal;
                let sinr = signal / (self.noise_w + interference);
                self.bandwidth_hz * (1.0 + sinr).log2()
            })
            .collect()
    }

    /// Rate of a single transmitter given explicit interference (W).
    pub fn rate_with_interference(&self, power_w: f64, gain: f64, interference_w: f64) -> f64 {
        let sinr = power_w * gain / (self.noise_w + interference_w);
        self.bandwidth_hz * (1.0 + sinr).log2()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::forall;
    use crate::util::rng::Rng;

    fn model() -> ChannelModel {
        ChannelModel {
            bandwidth_hz: 1e6,
            noise_w: 1e-9,
            n_channels: 2,
        }
    }

    fn tx(ue: usize, channel: usize, power_w: f64, d: f64) -> Transmitter {
        Transmitter {
            ue,
            channel,
            power_w,
            gain: d.powf(-3.0),
        }
    }

    #[test]
    fn single_transmitter_no_interference() {
        let m = model();
        let r = m.rates(&[tx(0, 0, 1.0, 50.0)]);
        // SNR = 1 * 50^-3 / 1e-9 = 8000 -> rate = 1e6 * log2(8001)
        let expect = 1e6 * (1.0f64 + 8e-6 / 1e-9).log2();
        assert!((r[0] - expect).abs() / expect < 1e-9, "{} vs {expect}", r[0]);
    }

    #[test]
    fn co_channel_interference_reduces_rate() {
        let m = model();
        let solo = m.rates(&[tx(0, 0, 1.0, 50.0)])[0];
        let both_same = m.rates(&[tx(0, 0, 1.0, 50.0), tx(1, 0, 1.0, 40.0)]);
        let both_diff = m.rates(&[tx(0, 0, 1.0, 50.0), tx(1, 1, 1.0, 40.0)]);
        assert!(both_same[0] < solo);
        // different channels do not interfere
        assert!((both_diff[0] - solo).abs() / solo < 1e-12);
    }

    #[test]
    fn rates_match_direct_formula() {
        // property: per-channel accumulation == direct pairwise sum
        forall(
            42,
            200,
            |g| {
                let n = g.usize_in(1, 8);
                (0..n)
                    .map(|i| {
                        tx(
                            i,
                            g.usize_in(0, 2).min(1),
                            g.f64_in(0.01, 1.0),
                            g.f64_in(1.0, 100.0),
                        )
                    })
                    .collect::<Vec<_>>()
            },
            |txs| {
                let m = model();
                let fast = m.rates(txs);
                for (i, t) in txs.iter().enumerate() {
                    let interference: f64 = txs
                        .iter()
                        .enumerate()
                        .filter(|(j, o)| *j != i && o.channel == t.channel)
                        .map(|(_, o)| o.power_w * o.gain)
                        .sum();
                    let direct = m.rate_with_interference(t.power_w, t.gain, interference);
                    let rel = (fast[i] - direct).abs() / direct.max(1.0);
                    if rel > 1e-9 {
                        return Err(format!("ue {i}: {} vs {direct}", fast[i]));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn more_power_more_rate_monotone() {
        let m = model();
        let mut rng = Rng::new(5);
        for _ in 0..100 {
            let d = rng.uniform(1.0, 100.0);
            let p1 = rng.uniform(0.01, 0.5);
            let p2 = p1 + rng.uniform(0.01, 0.5);
            let r1 = m.rates(&[tx(0, 0, p1, d)])[0];
            let r2 = m.rates(&[tx(0, 0, p2, d)])[0];
            assert!(r2 > r1);
        }
    }
}
