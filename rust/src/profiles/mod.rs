//! Device overhead profiles — the per-partition-decision latency/energy/
//! payload tables produced by `python/compile/profile.py` (the substitute
//! for the paper's Jetson Nano measurements, Fig. 6/7).
//!
//! The MDP environment consumes [`DeviceProfile::entry`] per partition
//! decision `b`: local-inference latency/energy `t_f`/`e_f`, compression
//! latency/energy `t_c`/`e_c` and payload size `bits` (Sec. 3.4, Eqs. 6-9).
//! `jalad` entries model the JALAD baseline compressor at the same cuts.

use std::path::Path;

use anyhow::{bail, Result};

use crate::util::json::Json;

/// Overhead of one partition decision `b` ∈ {0..B+1}.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct OverheadEntry {
    pub b: usize,
    /// Local inference latency (s) — `t_n^f` in Eq. (7).
    pub t_f: f64,
    /// Local inference energy (J) — `e_n^f` in Eq. (8).
    pub e_f: f64,
    /// Feature compression latency (s) — `t_n^c`.
    pub t_c: f64,
    /// Feature compression energy (J) — `e_n^c`.
    pub e_c: f64,
    /// Payload transmitted uplink (bits) — `f_n` in Eq. (6).
    pub bits: f64,
}

/// The JALAD baseline's compression overhead at one cut.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct JaladEntry {
    pub b: usize,
    pub t_c: f64,
    pub e_c: f64,
    pub bits: f64,
    pub rate: f64,
}

/// Per-model device profile (paper-scale analytic tables).
#[derive(Debug, Clone, PartialEq)]
pub struct DeviceProfile {
    pub model: String,
    /// Number of partition choices: b in {0, 1..B, B+1}.
    pub n_choices: usize,
    pub entries: Vec<OverheadEntry>,
    pub jalad: Vec<JaladEntry>,
    pub full_local_t: f64,
    pub full_local_e: f64,
    pub input_bits: f64,
}

impl DeviceProfile {
    pub fn load(path: impl AsRef<Path>) -> Result<DeviceProfile> {
        let j = Json::parse_file(path)?;
        Self::from_json(&j)
    }

    /// Load `path`, falling back to [`DeviceProfile::synthetic`] (with a
    /// stderr note) when the profile file does not exist — keeps the
    /// offline native build usable end-to-end. A profile that exists but
    /// fails to parse is still a hard error: evaluating against a silently
    /// wrong device table would corrupt every reported number.
    pub fn load_or_synthetic(path: impl AsRef<Path>) -> Result<DeviceProfile> {
        let path = path.as_ref();
        if path.exists() {
            return Self::load(path);
        }
        eprintln!(
            "note: no device profile at {} — using the synthetic profile",
            path.display()
        );
        Ok(DeviceProfile::synthetic())
    }

    pub fn from_json(j: &Json) -> Result<DeviceProfile> {
        let entries = j
            .req("entries")?
            .as_arr()?
            .iter()
            .map(|e| {
                Ok(OverheadEntry {
                    b: e.usize_of("b")?,
                    t_f: e.f64_of("t_f")?,
                    e_f: e.f64_of("e_f")?,
                    t_c: e.f64_of("t_c")?,
                    e_c: e.f64_of("e_c")?,
                    bits: e.f64_of("bits")?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let jalad = j
            .req("jalad")?
            .as_arr()?
            .iter()
            .map(|e| {
                Ok(JaladEntry {
                    b: e.usize_of("b")?,
                    t_c: e.f64_of("t_c")?,
                    e_c: e.f64_of("e_c")?,
                    bits: e.f64_of("bits")?,
                    rate: e.f64_of("rate")?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let n_choices = j.usize_of("n_partition_choices")?;
        if entries.len() != n_choices {
            bail!(
                "profile has {} entries but claims {} partition choices",
                entries.len(),
                n_choices
            );
        }
        let full = j.req("full_local")?;
        Ok(DeviceProfile {
            model: j.str_of("model")?.to_string(),
            n_choices,
            entries,
            jalad,
            full_local_t: full.f64_of("t")?,
            full_local_e: full.f64_of("e")?,
            input_bits: j.f64_of("input_bits")?,
        })
    }

    /// Overhead for partition decision `b` (panics on out-of-range — the
    /// action space is validated upstream).
    pub fn entry(&self, b: usize) -> &OverheadEntry {
        &self.entries[b]
    }

    /// The full-local decision index (B + 1).
    pub fn local_choice(&self) -> usize {
        self.n_choices - 1
    }

    /// A variant of this profile where partition cuts use the JALAD
    /// compressor instead of the autoencoder (paper baseline; raw-input and
    /// full-local decisions are unchanged).
    pub fn jalad_variant(&self) -> DeviceProfile {
        let mut out = self.clone();
        for je in &self.jalad {
            let e = &mut out.entries[je.b];
            e.t_c = je.t_c;
            e.e_c = je.e_c;
            e.bits = je.bits;
        }
        out.model = format!("{}+jalad", self.model);
        out
    }

    /// Largest payload over all decisions (used for state normalization).
    pub fn max_bits(&self) -> f64 {
        self.entries.iter().map(|e| e.bits).fold(0.0, f64::max)
    }

    /// Synthetic profile for unit tests (no artifact files needed):
    /// monotone compute costs, geometrically shrinking payloads.
    pub fn synthetic() -> DeviceProfile {
        let full_t = 0.05;
        let full_e = 0.107;
        let n_choices = 6;
        let mut entries = Vec::new();
        for b in 0..n_choices {
            let frac = b as f64 / (n_choices - 1) as f64;
            let (t_f, e_f) = if b == 0 { (0.0, 0.0) } else { (full_t * frac, full_e * frac) };
            let bits = match b {
                0 => 1.2e6,
                5 => 0.0,
                _ => 4.0e5 / 2f64.powi(b as i32 - 1),
            };
            let (t_c, e_c) = if b == 0 || b == 5 { (0.0, 0.0) } else { (2e-4, 4e-4) };
            entries.push(OverheadEntry { b, t_f, e_f, t_c, e_c, bits });
        }
        let jalad = (1..5)
            .map(|b| JaladEntry {
                b,
                t_c: 5e-3,
                e_c: 7e-3,
                bits: 8.0e5 / 2f64.powi(b as i32 - 1),
                rate: 8.0,
            })
            .collect();
        DeviceProfile {
            model: "synthetic".into(),
            n_choices,
            entries,
            jalad,
            full_local_t: full_t,
            full_local_e: full_e,
            input_bits: 1.2e6,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_is_consistent() {
        let p = DeviceProfile::synthetic();
        assert_eq!(p.n_choices, 6);
        assert_eq!(p.local_choice(), 5);
        assert_eq!(p.entry(5).bits, 0.0);
        assert_eq!(p.entry(0).t_f, 0.0);
        assert!(p.max_bits() >= 1.2e6);
    }

    #[test]
    fn jalad_variant_swaps_cut_entries_only() {
        let p = DeviceProfile::synthetic();
        let jv = p.jalad_variant();
        assert_eq!(jv.entry(0).bits, p.entry(0).bits);
        assert_eq!(jv.entry(5).bits, p.entry(5).bits);
        assert!(jv.entry(1).bits > p.entry(1).bits);
        assert!(jv.entry(1).t_c > p.entry(1).t_c);
    }

    #[test]
    fn parses_profile_json() {
        let j = Json::parse(
            r#"{"model":"m","n_partition_choices":2,
                "entries":[{"b":0,"t_f":0,"e_f":0,"t_c":0,"e_c":0,"bits":10},
                           {"b":1,"t_f":1,"e_f":2,"t_c":0,"e_c":0,"bits":0}],
                "jalad":[],"full_local":{"t":1,"e":2},"input_bits":10}"#,
        )
        .unwrap();
        let p = DeviceProfile::from_json(&j).unwrap();
        assert_eq!(p.model, "m");
        assert_eq!(p.entry(1).e_f, 2.0);
    }

    #[test]
    fn entry_count_mismatch_rejected() {
        let j = Json::parse(
            r#"{"model":"m","n_partition_choices":3,
                "entries":[{"b":0,"t_f":0,"e_f":0,"t_c":0,"e_c":0,"bits":0}],
                "jalad":[],"full_local":{"t":1,"e":2},"input_bits":10}"#,
        )
        .unwrap();
        assert!(DeviceProfile::from_json(&j).is_err());
    }
}
