//! The edge-server coordinator (paper Sec. 3.1 workflow).
//!
//! A fixed-frequency decision loop runs at the edge: at the end of each
//! frame every UE reports its state; the decision maker (a trained MAHPPO
//! agent or a baseline) computes the next joint action; decisions are
//! broadcast back; UEs execute tasks locally and/or offload (compressed)
//! features which the edge completes through the back model segment.
//!
//! * [`protocol`] — the UE ⇄ server message types.
//! * [`wire`] — the versioned byte-level codec those messages ride when
//!   UEs are remote (length-prefixed, CRC-protected frames; layouts in
//!   DESIGN.md §Wire-Protocol). Transports live in [`crate::transport`].
//! * [`state_pool`] — "the edge server collects and stores the states of
//!   all UEs" (Sec. 3.1): assembly of the global state vector.
//! * [`decision`] — policy wrapper producing per-frame joint actions, with
//!   a hot-swap channel ([`decision::PolicyHandle`]) that installs freshly
//!   published policies atomically between decision frames.
//! * [`learner`] — the online edge learner: a background thread that turns
//!   serving telemetry into PPO updates and publishes refreshed policies
//!   through the swap channel (the paper's edge-learning loop, inside the
//!   serving stack).
//! * [`inference`] — the collaborative-inference pipeline over real AOT
//!   model segments: front → AE-encode → wire → AE-decode → back.
//! * [`batcher`] — dynamic batching of edge-side full-model executions for
//!   raw-input offloads (flush policy + batch runner).
//! * [`executor`] — the offload executor: a worker pool serving offloads
//!   off the server thread, with the batcher wired into its dispatch side.
//! * [`offload_cache`] — a bounded-LRU content-addressed result cache
//!   consulted before the executor: identical payloads under the same
//!   (partition, calibration) key are served from memory, bit-identical
//!   to a recompute (DESIGN.md §Data-Plane).
//! * [`server`] — the threaded event loop tying it together (std threads +
//!   mpsc; tokio is unavailable in the offline build).
//! * [`shard`] — fleet-scale serving: a contiguous ue-id ownership map,
//!   per-shard transports with global⇄local id rewriting, and a policy
//!   fan-out handle so the learner publishes to every shard at once
//!   (DESIGN.md §Sharded-Serving).

pub mod batcher;
pub mod decision;
pub mod executor;
pub mod inference;
pub mod learner;
pub mod offload_cache;
pub mod protocol;
pub mod server;
pub mod shard;
pub mod state_pool;
pub mod wire;
