//! UE ⇄ edge-server message types (Sec. 3.1 workflow).
//!
//! These frames cross the radio link between UEs and the edge server:
//! state reports up, per-frame decisions down, offloaded payloads up,
//! inference results down. *How* they cross is pluggable
//! ([`crate::transport`]): in-process mpsc channels for simulation and
//! tests, or real TCP sockets using the byte-level codec in
//! [`super::wire`] (frame layouts in DESIGN.md §Wire-Protocol).

use std::sync::Arc;

use crate::env::HybridAction;

/// Reserved `task_id` for session-level [`Downlink::Error`] frames
/// (handshake rejection, wire desync) — real tasks must never use it, so
/// a session NACK can never be misattributed to an in-flight offload.
pub const SESSION_ERROR_TASK: u64 = u64::MAX;

/// One UE's per-frame state report (the four Sec. 4.3 components).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct UeStateReport {
    pub ue_id: usize,
    /// Remaining (uncompleted) tasks — k_t.
    pub tasks_left: u64,
    /// Remaining local compute time of the in-flight task (s) — l_t.
    pub compute_left_s: f64,
    /// Remaining offload payload of the in-flight task (bits) — n_t.
    pub offload_left_bits: f64,
    /// Distance to the BS (m) — d.
    pub distance_m: f64,
}

/// The decision broadcast for one frame.
///
/// The joint action is shared (`Arc<[..]>`), not owned: a fleet broadcast
/// clones the decision once per transport hop for the price of a refcount
/// bump, instead of copying the full action vector per UE per tick.
#[derive(Debug, Clone, PartialEq)]
pub struct FrameDecision {
    pub frame: usize,
    /// One hybrid action per UE, indexed by ue_id.
    pub actions: Arc<[HybridAction]>,
}

/// An offloaded payload arriving at the edge.
#[derive(Debug, Clone, PartialEq)]
pub struct OffloadRequest {
    pub ue_id: usize,
    pub task_id: u64,
    /// Partition decision used by the UE: 0 = raw input, 1..=4 = AE-coded
    /// feature at that cut.
    pub b: usize,
    /// Wire payload (packed codes or raw image bytes).
    pub payload: Vec<u8>,
    /// AE calibration (lo, hi) when b >= 1.
    pub calibration: Option<(f32, f32)>,
}

/// Edge-side inference result returned to the UE.
#[derive(Debug, Clone, PartialEq)]
pub struct InferenceResult {
    pub ue_id: usize,
    pub task_id: u64,
    pub logits: Vec<f32>,
    pub argmax: usize,
    /// Server-side processing time (s).
    pub edge_latency_s: f64,
}

/// Server -> UE control messages.
#[derive(Debug, Clone, PartialEq)]
pub enum Downlink {
    Decision(FrameDecision),
    Result(InferenceResult),
    /// NACK: the offload was accepted but could not be served — the owner
    /// must hear about it rather than wait forever for a `Result`.
    Error { task_id: u64, error: String },
    Shutdown,
}

/// UE -> server messages.
#[derive(Debug, Clone, PartialEq)]
pub enum Uplink {
    Report(UeStateReport),
    Offload(OffloadRequest),
    /// UE finished all tasks and is leaving the system.
    Goodbye { ue_id: usize },
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_roundtrips_through_enum() {
        let r = UeStateReport {
            ue_id: 3,
            tasks_left: 17,
            compute_left_s: 0.02,
            offload_left_bits: 1e5,
            distance_m: 50.0,
        };
        let up = Uplink::Report(r);
        match up {
            Uplink::Report(r2) => assert_eq!(r2, r),
            _ => panic!("wrong variant"),
        }
    }
}
