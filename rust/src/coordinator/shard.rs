//! Sharded serving: partition the UE fleet across independent server
//! loops (DESIGN.md §Sharded-Serving).
//!
//! One `server_loop` routing thousands of UEs serializes every decision,
//! offload and swap through a single thread. Sharding splits the fleet
//! into contiguous ue-id slices, each owned by its own loop with its own
//! [`StatePool`], [`DecisionMaker`] and executor pool:
//!
//! ```text
//!              ┌───────────── ShardMap (closed-form) ─────────────┐
//!  global ids  │ shard 0: [0, len0)   shard 1: [len0, len0+len1) …│
//!              └──────────────────────────────────────────────────┘
//!  transport ──► ShardView (global⇄local id rewrite) ──► server_loop
//!                                   ×N shards, each its own thread
//!  learner ──► PolicyHandle::fanout ──► every shard's swap slot
//! ```
//!
//! * [`ShardMap`] — the ownership map: total, stable, collision-free
//!   assignment of `ue_id → shard` with contiguous slices (remainder
//!   spread over the first `n % k` shards).
//! * [`ShardView`] — adapts any [`ServerTransport`] carrying *global*
//!   ue ids into a shard-local transport: uplinks outside the slice are
//!   dropped (counted), ids are rewritten to slice-local space so the
//!   inner `server_loop`, `StatePool` and `DecisionMaker` are completely
//!   ignorant of sharding — cross-shard isolation by construction.
//! * [`spawn_shards`] — one named thread per shard running the unchanged
//!   [`server_loop`], returning the join handles plus a fanned-out
//!   [`PolicyHandle`] so `coordinator::learner` publishes to every shard
//!   with the same latest-wins semantics it had against one.

use std::sync::Arc;

use anyhow::{ensure, Context, Result};

use super::decision::{DecisionMaker, PolicyHandle};
use super::executor::OffloadCompute;
use super::protocol::{Downlink, Uplink};
use super::server::{server_loop, EdgeServerHandle, ServerConfig};
use super::state_pool::StatePool;
use crate::transport::{ServerTransport, TransportError};

/// Contiguous-slice ownership map over `n_ues` UEs and `n_shards`
/// shards. Pure arithmetic — no allocation, O(1) lookups — so routing
/// hot paths (the reactor, the load generator) can call it per frame.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardMap {
    n_ues: usize,
    n_shards: usize,
}

impl ShardMap {
    /// `n_shards` is clamped to at least 1; shards beyond `n_ues` end up
    /// owning empty slices.
    pub fn new(n_ues: usize, n_shards: usize) -> ShardMap {
        ShardMap {
            n_ues,
            n_shards: n_shards.max(1),
        }
    }

    pub fn n_ues(&self) -> usize {
        self.n_ues
    }

    pub fn n_shards(&self) -> usize {
        self.n_shards
    }

    /// The shard owning `ue_id`, or `None` if the id is out of range.
    /// The first `n_ues % n_shards` shards own `base + 1` UEs, the rest
    /// `base = n_ues / n_shards`.
    pub fn shard_of(&self, ue_id: usize) -> Option<usize> {
        if ue_id >= self.n_ues {
            return None;
        }
        let base = self.n_ues / self.n_shards;
        let rem = self.n_ues % self.n_shards;
        let split = rem * (base + 1);
        if ue_id < split {
            Some(ue_id / (base + 1))
        } else {
            // base == 0 implies rem == n_ues, so split == n_ues and no
            // in-range id reaches this branch: the division is safe
            Some(rem + (ue_id - split) / base)
        }
    }

    /// `(lo, len)` of the contiguous global-id slice `shard` owns, or
    /// `None` for an out-of-range shard index. `len` may be 0 when there
    /// are more shards than UEs.
    pub fn slice_of(&self, shard: usize) -> Option<(usize, usize)> {
        if shard >= self.n_shards {
            return None;
        }
        let base = self.n_ues / self.n_shards;
        let rem = self.n_ues % self.n_shards;
        let split = rem * (base + 1);
        if shard < rem {
            Some((shard * (base + 1), base + 1))
        } else {
            Some((split + (shard - rem) * base, base))
        }
    }
}

/// A shard's window onto a fleet-wide transport: rewrites global ue ids
/// into `[0, len)` slice-local space on the uplink and back on the
/// downlink, and refuses to pass frames outside its slice. The inner
/// `server_loop` sees an ordinary `len`-UE transport, so a frame for
/// shard A can never reach — let alone mutate — shard B's `StatePool`.
pub struct ShardView<T: ServerTransport> {
    inner: T,
    lo: usize,
    len: usize,
    misrouted: usize,
    /// Reused global-id target scratch for `broadcast_decision` — the
    /// per-tick translation allocates nothing at steady state.
    bcast_scratch: Vec<(usize, usize)>,
}

impl<T: ServerTransport> ShardView<T> {
    pub fn new(inner: T, lo: usize, len: usize) -> ShardView<T> {
        ShardView {
            inner,
            lo,
            len,
            misrouted: 0,
            bcast_scratch: Vec::new(),
        }
    }

    /// Uplink frames dropped because their global ue id fell outside
    /// this shard's slice.
    pub fn misrouted(&self) -> usize {
        self.misrouted
    }

    fn to_local(&mut self, global: usize) -> Option<usize> {
        match global.checked_sub(self.lo) {
            Some(local) if local < self.len => Some(local),
            _ => {
                self.misrouted += 1;
                log::warn!(
                    "uplink for UE {global} outside shard slice [{}, {}) dropped",
                    self.lo,
                    self.lo + self.len
                );
                None
            }
        }
    }
}

impl<T: ServerTransport> ServerTransport for ShardView<T> {
    fn try_recv(&mut self) -> Result<Option<Uplink>, TransportError> {
        loop {
            match self.inner.try_recv()? {
                Some(Uplink::Report(mut r)) => {
                    let Some(local) = self.to_local(r.ue_id) else {
                        continue;
                    };
                    r.ue_id = local;
                    return Ok(Some(Uplink::Report(r)));
                }
                Some(Uplink::Offload(mut o)) => {
                    let Some(local) = self.to_local(o.ue_id) else {
                        continue;
                    };
                    o.ue_id = local;
                    return Ok(Some(Uplink::Offload(o)));
                }
                Some(Uplink::Goodbye { ue_id }) => {
                    let Some(local) = self.to_local(ue_id) else {
                        continue;
                    };
                    return Ok(Some(Uplink::Goodbye { ue_id: local }));
                }
                None => return Ok(None),
            }
        }
    }

    fn send_to(&mut self, ue_id: usize, frame: Downlink) {
        // out-of-slice downlinks cannot happen from a correct loop (its
        // cfg.n_ues == len), but guard anyway: never touch another slice
        if ue_id >= self.len {
            log::warn!("downlink to local UE {ue_id} outside shard of {} dropped", self.len);
            return;
        }
        let global = self.lo + ue_id;
        // results embed the ue id; restore global addressing for the UE
        let frame = match frame {
            Downlink::Result(mut r) => {
                r.ue_id = global;
                Downlink::Result(r)
            }
            other => other,
        };
        self.inner.send_to(global, frame);
    }

    fn broadcast_decision(
        &mut self,
        d: &super::protocol::FrameDecision,
        targets: &[(usize, usize)],
        per_ue: bool,
    ) {
        // translate slice-local targets to the fleet-wide ids the inner
        // transport speaks; action indices stay local (the decision's
        // action table is the shard's own). The scratch is reused, so a
        // tick's translation is alloc-free at steady state.
        self.bcast_scratch.clear();
        self.bcast_scratch.extend(
            targets
                .iter()
                .filter(|&&(ue, _)| ue < self.len)
                .map(|&(ue, idx)| (self.lo + ue, idx)),
        );
        self.inner.broadcast_decision(d, &self.bcast_scratch, per_ue);
    }

    fn take_drops(&mut self) -> usize {
        self.inner.take_drops()
    }
}

/// Spawn one named server thread per shard over `map`, each running the
/// unchanged [`server_loop`] behind a [`ShardView`] of its transport.
///
/// `shards[i]` supplies shard `i`'s transport (carrying **global** ue
/// ids), `StatePool` (sized to the slice) and `DecisionMaker`;
/// `mk_cfg(shard, len)` builds its config (`n_ues` is overwritten with
/// the slice length). Returns the join handles plus one [`PolicyHandle`]
/// fanned out over every shard's swap slot, so a learner publishes to
/// the whole fabric exactly as it published to a single server.
pub fn spawn_shards<T: ServerTransport + 'static>(
    map: &ShardMap,
    mut mk_cfg: impl FnMut(usize, usize) -> ServerConfig,
    shards: Vec<(T, StatePool, DecisionMaker)>,
    compute: Option<Arc<dyn OffloadCompute>>,
) -> Result<(Vec<EdgeServerHandle>, PolicyHandle)> {
    ensure!(
        shards.len() == map.n_shards(),
        "{} shard bundles for a {}-shard map",
        shards.len(),
        map.n_shards()
    );
    let mut handles = Vec::with_capacity(shards.len());
    let mut publishers = Vec::with_capacity(shards.len());
    for (shard, (transport, mut pool, mut decisions)) in shards.into_iter().enumerate() {
        let (lo, len) = map
            .slice_of(shard)
            .with_context(|| format!("shard {shard} has no slice"))?;
        let mut cfg = mk_cfg(shard, len);
        cfg.n_ues = len;
        publishers.push(decisions.policy_handle());
        let mut view = ShardView::new(transport, lo, len);
        let compute = compute.clone();
        let handle = std::thread::Builder::new()
            .name(format!("edge-shard-{shard}"))
            .spawn(move || server_loop(cfg, &mut view, &mut pool, &mut decisions, compute))
            .with_context(|| format!("spawning shard {shard}"))?;
        handles.push(EdgeServerHandle::from_join(handle));
    }
    Ok((handles, PolicyHandle::fanout(publishers)))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_partitions_exactly() {
        for &(n, k) in &[(10, 3), (1, 1), (7, 7), (3, 5), (0, 4), (1000, 16)] {
            let map = ShardMap::new(n, k);
            // slices tile [0, n) in order with no gaps or overlaps
            let mut next = 0usize;
            for shard in 0..map.n_shards() {
                let (lo, len) = map.slice_of(shard).unwrap();
                assert_eq!(lo, next, "n={n} k={k} shard={shard}");
                for ue in lo..lo + len {
                    assert_eq!(map.shard_of(ue), Some(shard), "n={n} k={k} ue={ue}");
                }
                next = lo + len;
            }
            assert_eq!(next, n, "slices cover the fleet exactly");
            assert_eq!(map.shard_of(n), None, "out of range is not owned");
            // balanced: slice lengths differ by at most one
            let lens: Vec<usize> = (0..map.n_shards())
                .map(|s| map.slice_of(s).unwrap().1)
                .collect();
            let min = lens.iter().min().unwrap();
            let max = lens.iter().max().unwrap();
            assert!(max - min <= 1, "n={n} k={k} lens={lens:?}");
        }
    }

    #[test]
    fn zero_shards_clamps_to_one() {
        let map = ShardMap::new(5, 0);
        assert_eq!(map.n_shards(), 1);
        assert_eq!(map.slice_of(0), Some((0, 5)));
        assert_eq!(map.shard_of(4), Some(0));
    }
}
