//! The state pool (Sec. 3.1): "the edge server collects and stores the
//! states of all UEs. We term the collection of all UE states the state
//! pool." Assembles the normalized 4N state vector the decision maker
//! consumes, tolerating missing/stale reports (last value is held).

use super::protocol::UeStateReport;

/// Normalization constants — must match `env::mdp::MultiAgentEnv::state`.
#[derive(Debug, Clone, Copy)]
pub struct StateNorm {
    pub lambda_tasks: f64,
    pub frame_s: f64,
    pub max_bits: f64,
    pub d_max: f64,
}

pub struct StatePool {
    n_ues: usize,
    norm: StateNorm,
    reports: Vec<Option<UeStateReport>>,
    /// Per-slot freshness: set on ingest, cleared by assemble(). Held
    /// reports stay `Some` forever, so freshness cannot be derived from
    /// the slot itself — a re-report after an assemble must count again.
    fresh: Vec<bool>,
}

impl StatePool {
    pub fn new(n_ues: usize, norm: StateNorm) -> StatePool {
        StatePool {
            n_ues,
            norm,
            reports: vec![None; n_ues],
            fresh: vec![false; n_ues],
        }
    }

    pub fn ingest(&mut self, r: UeStateReport) {
        if r.ue_id < self.n_ues {
            self.fresh[r.ue_id] = true;
            self.reports[r.ue_id] = Some(r);
        }
    }

    /// All UEs have reported at least once since the last drain?
    pub fn complete(&self) -> bool {
        self.reports.iter().all(|r| r.is_some())
    }

    /// Number of UEs with a fresh (not-yet-assembled) report.
    pub fn fresh_count(&self) -> usize {
        self.fresh.iter().filter(|&&f| f).count()
    }

    /// Assemble the normalized `{k, l, n, d}` state vector. Missing reports
    /// contribute zeros (a UE that never reported looks "done").
    pub fn assemble(&mut self) -> Vec<f32> {
        let n = self.n_ues;
        let mut s = Vec::with_capacity(4 * n);
        let k_norm = self.norm.lambda_tasks.max(1.0);
        for i in 0..n {
            s.push(
                self.reports[i]
                    .map(|r| (r.tasks_left as f64 / k_norm) as f32)
                    .unwrap_or(0.0),
            );
        }
        for i in 0..n {
            s.push(
                self.reports[i]
                    .map(|r| (r.compute_left_s / self.norm.frame_s) as f32)
                    .unwrap_or(0.0),
            );
        }
        for i in 0..n {
            s.push(
                self.reports[i]
                    .map(|r| (r.offload_left_bits / self.norm.max_bits.max(1.0)) as f32)
                    .unwrap_or(0.0),
            );
        }
        for i in 0..n {
            s.push(
                self.reports[i]
                    .map(|r| (r.distance_m / self.norm.d_max) as f32)
                    .unwrap_or(0.0),
            );
        }
        self.fresh.fill(false);
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn norm() -> StateNorm {
        StateNorm {
            lambda_tasks: 200.0,
            frame_s: 0.5,
            max_bits: 1.2e6,
            d_max: 100.0,
        }
    }

    fn report(ue: usize, k: u64) -> UeStateReport {
        UeStateReport {
            ue_id: ue,
            tasks_left: k,
            compute_left_s: 0.25,
            offload_left_bits: 6e5,
            distance_m: 50.0,
        }
    }

    #[test]
    fn assembles_in_block_layout() {
        let mut pool = StatePool::new(2, norm());
        pool.ingest(report(0, 100));
        pool.ingest(report(1, 200));
        assert!(pool.complete());
        let s = pool.assemble();
        assert_eq!(s.len(), 8);
        assert!((s[0] - 0.5).abs() < 1e-6); // k0 = 100/200
        assert!((s[1] - 1.0).abs() < 1e-6); // k1
        assert!((s[2] - 0.5).abs() < 1e-6); // l0 = .25/.5
        assert!((s[4] - 0.5).abs() < 1e-6); // n0 = 6e5/1.2e6
        assert!((s[6] - 0.5).abs() < 1e-6); // d0
    }

    #[test]
    fn missing_reports_are_zero() {
        let mut pool = StatePool::new(3, norm());
        pool.ingest(report(1, 100));
        assert!(!pool.complete());
        let s = pool.assemble();
        assert_eq!(s[0], 0.0);
        assert!(s[1] > 0.0);
        assert_eq!(s[2], 0.0);
    }

    #[test]
    fn stale_reports_held_and_fresh_counter() {
        let mut pool = StatePool::new(2, norm());
        pool.ingest(report(0, 10));
        assert_eq!(pool.fresh_count(), 1);
        let _ = pool.assemble();
        assert_eq!(pool.fresh_count(), 0);
        // after drain, the old report is still held
        let s = pool.assemble();
        assert!(s[0] > 0.0);
    }

    #[test]
    fn re_reports_count_as_fresh_after_assemble() {
        // regression: the counter used to increment only on None -> Some,
        // so every report after the first assemble() was invisible
        let mut pool = StatePool::new(3, norm());
        pool.ingest(report(0, 10));
        pool.ingest(report(1, 10));
        assert_eq!(pool.fresh_count(), 2);
        let _ = pool.assemble();
        assert_eq!(pool.fresh_count(), 0);
        pool.ingest(report(0, 9));
        assert_eq!(pool.fresh_count(), 1, "re-report must count as fresh");
        // double-report of the same UE counts once
        pool.ingest(report(0, 8));
        assert_eq!(pool.fresh_count(), 1);
        let _ = pool.assemble();
        assert_eq!(pool.fresh_count(), 0);
    }

    #[test]
    fn out_of_range_ue_ignored() {
        let mut pool = StatePool::new(2, norm());
        pool.ingest(report(7, 10));
        assert!(!pool.complete());
    }
}
