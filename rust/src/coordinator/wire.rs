//! Byte-level wire codec for the UE ⇄ edge-server protocol (v1).
//!
//! [`super::protocol`] defines *what* crosses the radio; this module
//! defines *how*: a versioned, length-prefixed, CRC-protected binary
//! framing with explicit little-endian field layouts, so real remote UEs
//! can speak to the server over any byte stream (see [`crate::transport`]).
//! The full frame tables live in DESIGN.md §Wire-Protocol — this header is
//! the normative summary.
//!
//! ## Frame layout
//!
//! ```text
//! offset  size  field
//!      0     2  magic        0x4D 0x43 ("MC")
//!      2     1  version      currently 1
//!      3     1  type tag     see the TAG_* constants
//!      4     4  body length  u32 LE, <= MAX_BODY
//!      8     4  crc32        u32 LE, IEEE CRC-32 over bytes [0..8) + body
//!     12     n  body         per-tag field layout, all little-endian
//! ```
//!
//! The CRC covers the header prefix *and* the body, so any single
//! bit-flip anywhere in a frame is detected (property-tested in
//! `rust/tests/proptests.rs`).
//!
//! ## Versioning & compatibility
//!
//! * A decoder rejects frames whose `version` it does not know
//!   ([`WireError::Version`]); field layouts never change within a
//!   version.
//! * New frame types get new tags. A decoder that validates the CRC but
//!   does not know the tag returns [`WireError::UnknownTag`] carrying the
//!   full frame length, so a same-version peer may skip the frame and
//!   stay in sync instead of dropping the connection.
//! * Truncated or corrupt frames are unrecoverable on a stream (framing
//!   is lost): transports NACK and close the connection.
//!
//! Decoding never panics on hostile input: every error path returns a
//! [`WireError`].

use std::io::{Read, Write};

use super::protocol::{
    Downlink, FrameDecision, InferenceResult, OffloadRequest, UeStateReport, Uplink,
};
use crate::env::HybridAction;

/// First two bytes of every frame: "MC".
pub const MAGIC: [u8; 2] = [0x4D, 0x43];
/// Wire-protocol version this build speaks.
pub const VERSION: u8 = 1;
/// Fixed frame header size (magic + version + tag + length + crc).
pub const HEADER_LEN: usize = 12;
/// Upper bound on a frame body — a corrupt length prefix must not be able
/// to trigger a multi-gigabyte allocation.
pub const MAX_BODY: usize = 1 << 26; // 64 MiB

/// UE → server: session handshake (first frame on every connection).
pub const TAG_HELLO: u8 = 0x01;
/// UE → server: per-frame state report.
pub const TAG_REPORT: u8 = 0x02;
/// UE → server: offloaded payload (raw input or AE-coded feature).
pub const TAG_OFFLOAD: u8 = 0x03;
/// UE → server: the UE finished all tasks and is leaving.
pub const TAG_GOODBYE: u8 = 0x04;
/// Server → UE: handshake accepted.
pub const TAG_WELCOME: u8 = 0x81;
/// Server → UE: joint decision broadcast.
pub const TAG_DECISION: u8 = 0x82;
/// Server → UE: edge-side inference result.
pub const TAG_RESULT: u8 = 0x83;
/// Server → UE: NACK — an accepted request could not be served.
pub const TAG_ERROR: u8 = 0x84;
/// Server → UE: orderly end of session.
pub const TAG_SHUTDOWN: u8 = 0x85;
/// Server → UE: an explicitly-addressed downlink — `u32` ue_id, inner
/// downlink tag, then the inner body as a length-prefixed byte field.
/// Used on multiplexed connections (one socket carrying many UEs, see
/// [`crate::transport::reactor`]) where the session id alone cannot
/// attribute a frame. Nesting is forbidden: a `DownTo` wrapping a
/// `DownTo` is malformed.
pub const TAG_DOWN_TO: u8 = 0x86;

/// Everything that can cross the wire: the [`Uplink`]/[`Downlink`]
/// application frames plus the transport-level handshake pair.
#[derive(Debug, Clone, PartialEq)]
pub enum Frame {
    /// First frame a UE sends on a fresh connection.
    Hello { ue_id: usize },
    /// The server's handshake accept, echoing the registered id.
    Welcome { ue_id: usize },
    /// Application frame, UE → server.
    Up(Uplink),
    /// Application frame, server → UE.
    Down(Downlink),
    /// Application frame, server → UE, explicitly addressed to one UE of
    /// a multiplexed connection (a reactor socket carrying many UEs).
    /// Single-UE transports keep sending plain [`Frame::Down`].
    DownTo { ue_id: usize, down: Downlink },
}

impl From<Uplink> for Frame {
    fn from(u: Uplink) -> Frame {
        Frame::Up(u)
    }
}

impl From<Downlink> for Frame {
    fn from(d: Downlink) -> Frame {
        Frame::Down(d)
    }
}

/// Why a buffer failed to decode (or a stream failed to frame). Decoding
/// is total: hostile bytes produce one of these, never a panic.
#[derive(Debug)]
pub enum WireError {
    /// The peer closed the stream cleanly at a frame boundary.
    Closed,
    /// More bytes are needed to complete the frame.
    Truncated { have: usize, need: usize },
    /// The first two bytes are not [`MAGIC`].
    BadMagic { got: [u8; 2] },
    /// The frame speaks a protocol version this build does not know.
    Version { got: u8 },
    /// Unknown type tag; `skip` is the full frame length (header + body),
    /// so a same-version peer may step over the frame and stay in sync.
    UnknownTag { got: u8, skip: usize },
    /// The length prefix exceeds [`MAX_BODY`].
    TooLarge { len: usize },
    /// CRC mismatch: the frame was damaged in flight.
    Corrupt { expect: u32, got: u32 },
    /// The body parsed structurally wrong (bad flag, bad utf-8, length
    /// field disagreeing with the actual byte count, trailing bytes).
    Malformed(String),
    /// Underlying stream error.
    Io(std::io::Error),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Closed => write!(f, "stream closed at a frame boundary"),
            WireError::Truncated { have, need } => {
                write!(f, "truncated frame: have {have} bytes, need {need}")
            }
            WireError::BadMagic { got: [a, b] } => {
                write!(f, "bad magic {a:#04x} {b:#04x}")
            }
            WireError::Version { got } => {
                write!(f, "unsupported wire version {got} (this build speaks {VERSION})")
            }
            WireError::UnknownTag { got, skip } => {
                write!(f, "unknown frame tag {got:#04x} ({skip}-byte frame)")
            }
            WireError::TooLarge { len } => {
                write!(f, "frame body of {len} bytes exceeds the {MAX_BODY}-byte cap")
            }
            WireError::Corrupt { expect, got } => {
                write!(f, "crc mismatch: frame says {expect:#010x}, computed {got:#010x}")
            }
            WireError::Malformed(why) => write!(f, "malformed frame body: {why}"),
            WireError::Io(e) => write!(f, "wire i/o: {e}"),
        }
    }
}

impl std::error::Error for WireError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            WireError::Io(e) => Some(e),
            _ => None,
        }
    }
}

const fn crc32_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        // lint: allow(no-panic) — compile-time const-eval table build; i < 256 by the loop bound
        table[i] = c;
        i += 1;
    }
    table
}

const CRC_TABLE: [u32; 256] = crc32_table();

/// IEEE CRC-32 (the zlib/Ethernet polynomial).
pub fn crc32(data: &[u8]) -> u32 {
    crc32_update(0xFFFF_FFFF, data) ^ 0xFFFF_FFFF
}

/// IEEE CRC-32 over a logical concatenation of byte slices, one pass and
/// zero copies. Both this codec and the [`crate::rl::checkpoint`] format
/// (which reuses the wire header discipline) checksum header-prefix +
/// body without materializing them contiguously.
pub fn crc32_parts(parts: &[&[u8]]) -> u32 {
    let mut c = 0xFFFF_FFFF;
    for p in parts {
        c = crc32_update(c, p);
    }
    c ^ 0xFFFF_FFFF
}

fn crc32_update(mut c: u32, data: &[u8]) -> u32 {
    for &b in data {
        // index through `get`: (x as u8) as usize ≤ 255, so the branch is
        // provably dead and this stays panic-free without a pragma
        let idx = (c ^ b as u32) as u8;
        c = CRC_TABLE.get(idx as usize).copied().unwrap_or(0) ^ (c >> 8);
    }
    c
}

// ---------------------------------------------------------------- encoding

/// Little-endian field writer appending to a caller buffer — every encode
/// path borrows the destination, so a reused buffer means zero
/// allocations at steady state (the PR-9 `_into` idiom).
struct Enc<'a>(&'a mut Vec<u8>);

impl Enc<'_> {
    fn u8(&mut self, v: u8) {
        self.0.push(v);
    }
    fn u32(&mut self, v: u32) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }
    fn f32(&mut self, v: f32) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }
    fn f64(&mut self, v: f64) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }
    fn bytes(&mut self, v: &[u8]) {
        self.u32(v.len() as u32);
        self.0.extend_from_slice(v);
    }
}

/// Append one frame's body to `out` and return its tag (ids are encoded
/// as u32 — the protocol caps a deployment at 2^32 UEs/classes, far
/// beyond the state vector).
fn encode_body_append(frame: &Frame, out: &mut Vec<u8>) -> u8 {
    let mut e = Enc(out);
    match frame {
        Frame::Hello { ue_id } => {
            e.u32(*ue_id as u32);
            TAG_HELLO
        }
        Frame::Welcome { ue_id } => {
            e.u32(*ue_id as u32);
            TAG_WELCOME
        }
        Frame::Up(Uplink::Report(r)) => {
            e.u32(r.ue_id as u32);
            e.u64(r.tasks_left);
            e.f64(r.compute_left_s);
            e.f64(r.offload_left_bits);
            e.f64(r.distance_m);
            TAG_REPORT
        }
        Frame::Up(Uplink::Offload(o)) => {
            e.u32(o.ue_id as u32);
            e.u64(o.task_id);
            e.u32(o.b as u32);
            match o.calibration {
                Some((lo, hi)) => {
                    e.u8(1);
                    e.f32(lo);
                    e.f32(hi);
                }
                None => e.u8(0),
            }
            e.bytes(&o.payload);
            TAG_OFFLOAD
        }
        Frame::Up(Uplink::Goodbye { ue_id }) => {
            e.u32(*ue_id as u32);
            TAG_GOODBYE
        }
        Frame::Down(d) => encode_down(&mut e, d),
        Frame::DownTo { ue_id, down } => {
            e.u32(*ue_id as u32);
            // inner downlink: tag byte + length-prefixed body, encoded in
            // place — the placeholders are patched once the body size is
            // known, so no intermediate buffer is ever materialized
            let slot_at = e.0.len();
            e.u8(0); // inner-tag placeholder
            e.u32(0); // inner-length placeholder
            let body_at = e.0.len();
            let inner_tag = encode_down(&mut e, down);
            let inner_len = (e.0.len() - body_at) as u32;
            if let Some(t) = e.0.get_mut(slot_at) {
                *t = inner_tag;
            }
            if let Some(slot) = e.0.get_mut(slot_at + 1..body_at) {
                slot.copy_from_slice(&inner_len.to_le_bytes());
            }
            TAG_DOWN_TO
        }
    }
}

/// Body of one downlink frame, shared by the plain [`Frame::Down`]
/// encoding and the addressed [`Frame::DownTo`] envelope.
fn encode_down(e: &mut Enc, down: &Downlink) -> u8 {
    match down {
        Downlink::Decision(d) => encode_decision_body(d.frame, &d.actions, e.0),
        Downlink::Result(r) => {
            e.u32(r.ue_id as u32);
            e.u64(r.task_id);
            e.u32(r.argmax as u32);
            e.f64(r.edge_latency_s);
            e.u32(r.logits.len() as u32);
            for &l in &r.logits {
                e.f32(l);
            }
            TAG_RESULT
        }
        Downlink::Error { task_id, error } => {
            e.u64(*task_id);
            e.bytes(error.as_bytes());
            TAG_ERROR
        }
        Downlink::Shutdown => TAG_SHUTDOWN,
    }
}

/// The 8 checksummed header bytes (magic + version + tag + length) — the
/// "header prefix" the CRC covers alongside the body.
fn header_prefix(tag: u8, body_len: usize) -> [u8; 8] {
    let [m0, m1] = MAGIC;
    let [l0, l1, l2, l3] = (body_len as u32).to_le_bytes();
    [m0, m1, VERSION, tag, l0, l1, l2, l3]
}

/// Patch the placeholder header of the frame starting at `start`:
/// `out[start..]` must hold `HEADER_LEN` reserved bytes followed by the
/// body. Writes the prefix and the CRC over prefix + body.
fn finish_frame(out: &mut Vec<u8>, start: usize, tag: u8) {
    let body_len = out.len().saturating_sub(start + HEADER_LEN);
    let prefix = header_prefix(tag, body_len);
    let body = out.get(start + HEADER_LEN..).unwrap_or(&[]);
    let crc = crc32_parts(&[&prefix, body]);
    if let Some(slot) = out.get_mut(start..start + 8) {
        slot.copy_from_slice(&prefix);
    }
    if let Some(slot) = out.get_mut(start + 8..start + HEADER_LEN) {
        slot.copy_from_slice(&crc.to_le_bytes());
    }
}

/// Encode one frame (header + body), **appending** to `out` — the
/// write-buffer form: a transport encodes straight into its per-connection
/// buffer with no intermediate `Vec`. Returns the frame's byte length.
pub fn encode_frame_append(frame: &Frame, out: &mut Vec<u8>) -> usize {
    let start = out.len();
    out.extend_from_slice(&[0u8; HEADER_LEN]);
    let tag = encode_body_append(frame, out);
    finish_frame(out, start, tag);
    out.len() - start
}

/// Encode one frame into a caller buffer, replacing its contents. A
/// buffer reused across frames makes the encode path allocation-free at
/// steady state (asserted by `rust/tests/zero_alloc.rs`).
pub fn encode_frame_into(frame: &Frame, out: &mut Vec<u8>) {
    out.clear();
    encode_frame_append(frame, out);
}

/// Encode one frame into a fresh buffer (header + body) — thin wrapper
/// over [`encode_frame_into`] for callers that don't reuse buffers.
pub fn encode_frame(frame: &Frame) -> Vec<u8> {
    let mut out = Vec::new();
    encode_frame_append(frame, &mut out);
    out
}

// ----------------------------------------------- single-encode fan-out

/// Append the **body bytes** of one downlink (no header) to `out` and
/// return its tag. This is the single-encode half of a fan-out: encode
/// the shared `Decision` body once, then stamp it into per-connection
/// frames with [`encode_down_to_raw`] / [`encode_down_raw`] — a byte copy
/// per subscriber instead of a re-encode per subscriber.
pub fn encode_down_body(down: &Downlink, out: &mut Vec<u8>) -> u8 {
    let mut e = Enc(out);
    encode_down(&mut e, down)
}

/// Append the body bytes of a `Decision` downlink built from a frame
/// number and an action slice, returning [`TAG_DECISION`]. Lets a per-UE
/// fan-out stamp slim one-action decisions straight from the shared
/// action table without materializing a `FrameDecision` (and its `Arc`
/// allocation) per target.
pub fn encode_decision_body(frame: usize, actions: &[HybridAction], out: &mut Vec<u8>) -> u8 {
    let mut e = Enc(out);
    e.u32(frame as u32);
    e.u32(actions.len() as u32);
    for a in actions {
        e.u32(a.b as u32);
        e.u32(a.c as u32);
        e.f32(a.p_raw);
        e.f64(a.p_watts);
    }
    TAG_DECISION
}

/// Append a complete [`Frame::Down`] frame built around a pre-encoded
/// downlink body (from [`encode_down_body`]). Byte-identical to
/// `encode_frame_append(&Frame::Down(d), out)` for the same downlink.
/// Returns the frame's byte length.
pub fn encode_down_raw(tag: u8, body: &[u8], out: &mut Vec<u8>) -> usize {
    let start = out.len();
    out.extend_from_slice(&[0u8; HEADER_LEN]);
    out.extend_from_slice(body);
    finish_frame(out, start, tag);
    out.len() - start
}

/// Append a complete [`Frame::DownTo`] envelope around a pre-encoded
/// downlink body (from [`encode_down_body`]). Byte-identical to
/// `encode_frame_append(&Frame::DownTo { ue_id, down }, out)` for the
/// same downlink — only the outer CRC differs per `ue_id`, so a fleet
/// broadcast encodes the body once and pays a copy + CRC per connection.
/// Returns the frame's byte length.
pub fn encode_down_to_raw(ue_id: usize, tag: u8, body: &[u8], out: &mut Vec<u8>) -> usize {
    let start = out.len();
    out.extend_from_slice(&[0u8; HEADER_LEN]);
    let mut e = Enc(out);
    e.u32(ue_id as u32);
    e.u8(tag);
    e.bytes(body);
    finish_frame(out, start, TAG_DOWN_TO);
    out.len() - start
}

// ---------------------------------------------------------------- decoding

struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        let end = self.pos.checked_add(n).ok_or_else(|| {
            WireError::Malformed(format!("field length {n} overflows at offset {}", self.pos))
        })?;
        let s = self.buf.get(self.pos..end).ok_or_else(|| {
            WireError::Malformed(format!(
                "body needs {n} more bytes at offset {}, only {} left",
                self.pos,
                self.buf.len().saturating_sub(self.pos)
            ))
        })?;
        self.pos = end;
        Ok(s)
    }
    /// `take(N)` as a fixed array — every fixed-width field reads through
    /// this, so the decode path never indexes a slice.
    fn arr<const N: usize>(&mut self) -> Result<[u8; N], WireError> {
        self.take(N)?
            .try_into()
            .map_err(|_| WireError::Malformed(format!("internal: take({N}) mis-sized")))
    }
    fn u8(&mut self) -> Result<u8, WireError> {
        let [b] = self.arr::<1>()?;
        Ok(b)
    }
    fn u32(&mut self) -> Result<u32, WireError> {
        Ok(u32::from_le_bytes(self.arr()?))
    }
    fn u64(&mut self) -> Result<u64, WireError> {
        Ok(u64::from_le_bytes(self.arr()?))
    }
    fn f32(&mut self) -> Result<f32, WireError> {
        Ok(f32::from_le_bytes(self.arr()?))
    }
    fn f64(&mut self) -> Result<f64, WireError> {
        Ok(f64::from_le_bytes(self.arr()?))
    }
    fn bytes(&mut self) -> Result<&'a [u8], WireError> {
        let n = self.u32()? as usize;
        self.take(n)
    }
    fn finish(self) -> Result<(), WireError> {
        if self.pos != self.buf.len() {
            return Err(WireError::Malformed(format!(
                "{} trailing bytes after the last field",
                self.buf.len() - self.pos
            )));
        }
        Ok(())
    }
}

fn decode_body(tag: u8, body: &[u8]) -> Result<Frame, WireError> {
    let mut d = Dec { buf: body, pos: 0 };
    let frame = match tag {
        TAG_HELLO => Frame::Hello {
            ue_id: d.u32()? as usize,
        },
        TAG_WELCOME => Frame::Welcome {
            ue_id: d.u32()? as usize,
        },
        TAG_REPORT => Frame::Up(Uplink::Report(UeStateReport {
            ue_id: d.u32()? as usize,
            tasks_left: d.u64()?,
            compute_left_s: d.f64()?,
            offload_left_bits: d.f64()?,
            distance_m: d.f64()?,
        })),
        TAG_OFFLOAD => {
            let ue_id = d.u32()? as usize;
            let task_id = d.u64()?;
            let b = d.u32()? as usize;
            let calibration = match d.u8()? {
                0 => None,
                1 => Some((d.f32()?, d.f32()?)),
                flag => {
                    return Err(WireError::Malformed(format!(
                        "calibration flag must be 0 or 1, got {flag}"
                    )))
                }
            };
            let payload = d.bytes()?.to_vec();
            Frame::Up(Uplink::Offload(OffloadRequest {
                ue_id,
                task_id,
                b,
                payload,
                calibration,
            }))
        }
        TAG_GOODBYE => Frame::Up(Uplink::Goodbye {
            ue_id: d.u32()? as usize,
        }),
        TAG_DECISION => {
            let frame_no = d.u32()? as usize;
            let n = d.u32()? as usize;
            // 20 bytes per action: cap before allocating
            if n > body.len() / 20 {
                return Err(WireError::Malformed(format!(
                    "decision claims {n} actions in a {}-byte body",
                    body.len()
                )));
            }
            let mut actions = Vec::with_capacity(n);
            for _ in 0..n {
                actions.push(HybridAction {
                    b: d.u32()? as usize,
                    c: d.u32()? as usize,
                    p_raw: d.f32()?,
                    p_watts: d.f64()?,
                });
            }
            Frame::Down(Downlink::Decision(FrameDecision {
                frame: frame_no,
                actions: actions.into(),
            }))
        }
        TAG_RESULT => {
            let ue_id = d.u32()? as usize;
            let task_id = d.u64()?;
            let argmax = d.u32()? as usize;
            let edge_latency_s = d.f64()?;
            let n = d.u32()? as usize;
            if n > body.len() / 4 {
                return Err(WireError::Malformed(format!(
                    "result claims {n} logits in a {}-byte body",
                    body.len()
                )));
            }
            let mut logits = Vec::with_capacity(n);
            for _ in 0..n {
                logits.push(d.f32()?);
            }
            Frame::Down(Downlink::Result(InferenceResult {
                ue_id,
                task_id,
                logits,
                argmax,
                edge_latency_s,
            }))
        }
        TAG_ERROR => {
            let task_id = d.u64()?;
            // lossy on purpose: the error text is diagnostic, and a
            // hostile or corrupt string must not kill an otherwise-valid
            // NACK frame — replacement characters beat a dead session
            let error = String::from_utf8_lossy(d.bytes()?).into_owned();
            Frame::Down(Downlink::Error { task_id, error })
        }
        TAG_SHUTDOWN => Frame::Down(Downlink::Shutdown),
        TAG_DOWN_TO => {
            let ue_id = d.u32()? as usize;
            let inner_tag = d.u8()?;
            let inner_body = d.bytes()?;
            // reject nesting before recursing: decode depth stays 1 even
            // on hostile bytes
            if inner_tag == TAG_DOWN_TO {
                return Err(WireError::Malformed(
                    "nested DownTo envelopes are not allowed".into(),
                ));
            }
            match decode_body(inner_tag, inner_body) {
                Ok(Frame::Down(down)) => Frame::DownTo { ue_id, down },
                Ok(other) => {
                    return Err(WireError::Malformed(format!(
                        "DownTo envelope wraps a non-downlink frame {other:?}"
                    )))
                }
                Err(WireError::UnknownTag { got, .. }) => {
                    // inner frames are same-version downlinks by
                    // construction; an unknown inner tag is damage, not
                    // forward compatibility (the outer frame is the unit
                    // of skipping)
                    return Err(WireError::Malformed(format!(
                        "DownTo envelope wraps unknown tag {got:#04x}"
                    )));
                }
                Err(e) => return Err(e),
            }
        }
        got => {
            return Err(WireError::UnknownTag {
                got,
                skip: HEADER_LEN + body.len(),
            })
        }
    };
    d.finish()?;
    Ok(frame)
}

/// A validated 12-byte header: the frame tag, body length, expected CRC,
/// and the 8 checksummed prefix bytes (for [`crc32_parts`] verification).
struct Header {
    tag: u8,
    body_len: usize,
    crc: u32,
    prefix: [u8; 8],
}

/// Validate a 12-byte header. A slice pattern destructures the bytes, so
/// the decode path never indexes (a mis-sized slice is a typed error).
fn parse_header(h: &[u8]) -> Result<Header, WireError> {
    let &[m0, m1, version, tag, l0, l1, l2, l3, c0, c1, c2, c3] = h else {
        return Err(WireError::Truncated {
            have: h.len(),
            need: HEADER_LEN,
        });
    };
    if [m0, m1] != MAGIC {
        return Err(WireError::BadMagic { got: [m0, m1] });
    }
    if version != VERSION {
        return Err(WireError::Version { got: version });
    }
    let body_len = u32::from_le_bytes([l0, l1, l2, l3]) as usize;
    if body_len > MAX_BODY {
        return Err(WireError::TooLarge { len: body_len });
    }
    let crc = u32::from_le_bytes([c0, c1, c2, c3]);
    Ok(Header {
        tag,
        body_len,
        crc,
        prefix: [m0, m1, version, tag, l0, l1, l2, l3],
    })
}

/// Decode the first frame in `buf`; returns the frame and the number of
/// bytes it occupied. [`WireError::Truncated`] means "feed me more bytes" —
/// callers accumulating a stream buffer retry once more arrive.
pub fn decode_frame(buf: &[u8]) -> Result<(Frame, usize), WireError> {
    let header = buf.get(..HEADER_LEN).ok_or(WireError::Truncated {
        have: buf.len(),
        need: HEADER_LEN,
    })?;
    let h = parse_header(header)?;
    let total = HEADER_LEN + h.body_len;
    let body = buf.get(HEADER_LEN..total).ok_or(WireError::Truncated {
        have: buf.len(),
        need: total,
    })?;
    let got = crc32_parts(&[&h.prefix, body]);
    if got != h.crc {
        return Err(WireError::Corrupt { expect: h.crc, got });
    }
    Ok((decode_body(h.tag, body)?, total))
}

/// Write one frame to a byte sink (one `write_all` — transports decide
/// buffering). Rejects frames whose body exceeds [`MAX_BODY`] *before*
/// any bytes hit the wire: an oversized frame would be unreadable by
/// every compliant peer, so failing at the sender is the only useful
/// place (and bodies past u32 range would corrupt the length prefix).
pub fn write_frame<W: Write>(w: &mut W, frame: &Frame) -> Result<(), WireError> {
    let buf = encode_frame(frame);
    if buf.len() - HEADER_LEN > MAX_BODY {
        return Err(WireError::TooLarge {
            len: buf.len() - HEADER_LEN,
        });
    }
    w.write_all(&buf).map_err(WireError::Io)
}

/// Read exactly one frame from a blocking byte stream — thin wrapper
/// over [`read_frame_into`] for callers that don't reuse buffers.
///
/// A clean EOF *between* frames is [`WireError::Closed`] (the peer hung
/// up); an EOF *inside* a frame is [`WireError::Truncated`].
pub fn read_frame<R: Read>(r: &mut R) -> Result<Frame, WireError> {
    let mut body = Vec::new();
    read_frame_into(r, &mut body)
}

/// [`read_frame`] with a caller-owned body scratch buffer: `body` is
/// cleared and refilled with the frame body, so a buffer reused across
/// frames makes the read path allocation-free once it has grown to the
/// session's largest body (asserted by `rust/tests/zero_alloc.rs`).
pub fn read_frame_into<R: Read>(r: &mut R, body: &mut Vec<u8>) -> Result<Frame, WireError> {
    let mut header = [0u8; HEADER_LEN];
    let mut have = 0usize;
    while have < HEADER_LEN {
        // `get_mut` instead of `header[have..]`: `have` is below
        // HEADER_LEN by the loop condition, but the decode path indexes
        // nothing, ever
        let Some(dst) = header.get_mut(have..) else { break };
        match r.read(dst) {
            Ok(0) if have == 0 => return Err(WireError::Closed),
            Ok(0) => {
                return Err(WireError::Truncated {
                    have,
                    need: HEADER_LEN,
                })
            }
            Ok(n) => have += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(WireError::Io(e)),
        }
    }
    let h = parse_header(&header)?;
    body.clear();
    body.resize(h.body_len, 0);
    r.read_exact(body).map_err(|e| {
        if e.kind() == std::io::ErrorKind::UnexpectedEof {
            WireError::Truncated {
                have: HEADER_LEN,
                need: HEADER_LEN + h.body_len,
            }
        } else {
            WireError::Io(e)
        }
    })?;
    let got = crc32_parts(&[&h.prefix, body]);
    if got != h.crc {
        return Err(WireError::Corrupt { expect: h.crc, got });
    }
    decode_body(h.tag, body)
}

// ---------------------------------------------------------------- pooling

/// How many recycled buffers one size class retains — enough to cover a
/// handful of in-flight bodies per size without hoarding memory.
const POOL_PER_CLASS: usize = 8;
/// Size classes: powers of two from 2^0 up to 2^POOL_CLASSES-1 bytes
/// (1 MiB). Larger buffers are allocated and dropped normally — at that
/// size the allocation is noise next to the copy.
const POOL_CLASSES: usize = 21;

/// A small size-keyed recycler for frame/payload byte buffers.
///
/// Buffers are binned by power-of-two capacity class; [`FramePool::get`]
/// pops a cleared buffer of at least the requested capacity (allocating
/// one on miss), [`FramePool::put`] returns a spent buffer to its class.
/// Each class keeps at most [`POOL_PER_CLASS`] buffers, so the pool's
/// footprint is bounded by construction. Single-threaded by design —
/// every user owns its pool (reactor sweep loop, offload cache); there is
/// no lock on the hot path.
#[derive(Debug)]
pub struct FramePool {
    classes: Vec<Vec<Vec<u8>>>,
    hits: u64,
    misses: u64,
}

/// Power-of-two size class of a capacity (0 → class 0).
fn pool_class(capacity: usize) -> usize {
    capacity.next_power_of_two().trailing_zeros() as usize
}

impl Default for FramePool {
    fn default() -> FramePool {
        FramePool::new()
    }
}

impl FramePool {
    pub fn new() -> FramePool {
        FramePool {
            classes: (0..POOL_CLASSES).map(|_| Vec::new()).collect(),
            hits: 0,
            misses: 0,
        }
    }

    /// An empty buffer with at least `min_capacity` bytes of capacity —
    /// recycled when the class has one, freshly allocated otherwise.
    pub fn get(&mut self, min_capacity: usize) -> Vec<u8> {
        let class = pool_class(min_capacity);
        if let Some(buf) = self.classes.get_mut(class).and_then(|c| c.pop()) {
            self.hits += 1;
            return buf;
        }
        self.misses += 1;
        // allocate the full class size so the buffer re-bins to the same
        // class on return, whatever length it ends up holding
        Vec::with_capacity(min_capacity.max(1).next_power_of_two())
    }

    /// Return a spent buffer to the pool (cleared). Buffers above the
    /// largest class, and overflow beyond the per-class cap, are dropped.
    pub fn put(&mut self, mut buf: Vec<u8>) {
        let class = pool_class(buf.capacity());
        let Some(bin) = self.classes.get_mut(class) else { return };
        if bin.len() < POOL_PER_CLASS {
            buf.clear();
            bin.push(buf);
        }
    }

    /// (recycled, freshly-allocated) counts since construction.
    pub fn stats(&self) -> (u64, u64) {
        (self.hits, self.misses)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn offload_frame() -> Frame {
        Frame::Up(Uplink::Offload(OffloadRequest {
            ue_id: 3,
            task_id: 42,
            b: 2,
            payload: vec![1, 2, 3, 4, 5],
            calibration: Some((-1.5, 2.5)),
        }))
    }

    #[test]
    fn crc32_matches_reference_vector() {
        // the classic IEEE test vector
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn every_frame_type_roundtrips() {
        let frames = vec![
            Frame::Hello { ue_id: 7 },
            Frame::Welcome { ue_id: 7 },
            Frame::Up(Uplink::Report(UeStateReport {
                ue_id: 1,
                tasks_left: 9,
                compute_left_s: 0.25,
                offload_left_bits: 1.5e5,
                distance_m: 42.0,
            })),
            offload_frame(),
            Frame::Up(Uplink::Offload(OffloadRequest {
                ue_id: 0,
                task_id: 1,
                b: 0,
                payload: vec![0u8; 64],
                calibration: None,
            })),
            Frame::Up(Uplink::Goodbye { ue_id: 2 }),
            Frame::Down(Downlink::Decision(FrameDecision {
                frame: 11,
                actions: vec![HybridAction::new(3, 1, 0.5, 1.0); 4].into(),
            })),
            Frame::Down(Downlink::Result(InferenceResult {
                ue_id: 5,
                task_id: 77,
                logits: vec![0.1, -0.2, 0.9],
                argmax: 2,
                edge_latency_s: 0.003,
            })),
            Frame::Down(Downlink::Error {
                task_id: 13,
                error: "no calibration".into(),
            }),
            Frame::Down(Downlink::Shutdown),
            Frame::DownTo {
                ue_id: 9_001,
                down: Downlink::Decision(FrameDecision {
                    frame: 4,
                    actions: vec![HybridAction::new(1, 0, -0.25, 1.0)].into(),
                }),
            },
            Frame::DownTo {
                ue_id: 0,
                down: Downlink::Error {
                    task_id: 5,
                    error: "addressed NACK".into(),
                },
            },
            Frame::DownTo {
                ue_id: 123,
                down: Downlink::Shutdown,
            },
        ];
        for f in frames {
            let buf = encode_frame(&f);
            let (back, used) = decode_frame(&buf).expect("roundtrip");
            assert_eq!(back, f);
            assert_eq!(used, buf.len());
        }
    }

    #[test]
    fn stream_io_roundtrips() {
        let mut buf = Vec::new();
        write_frame(&mut buf, &offload_frame()).unwrap();
        write_frame(&mut buf, &Frame::Down(Downlink::Shutdown)).unwrap();
        let mut r = &buf[..];
        assert_eq!(read_frame(&mut r).unwrap(), offload_frame());
        assert_eq!(read_frame(&mut r).unwrap(), Frame::Down(Downlink::Shutdown));
        assert!(matches!(read_frame(&mut r), Err(WireError::Closed)));
    }

    #[test]
    fn truncation_is_rejected_at_every_length() {
        let buf = encode_frame(&offload_frame());
        for n in 0..buf.len() {
            match decode_frame(&buf[..n]) {
                Err(WireError::Truncated { .. }) => {}
                other => panic!("prefix of {n} bytes must be Truncated, got {other:?}"),
            }
        }
    }

    #[test]
    fn corruption_and_unknown_tags_are_rejected() {
        let good = encode_frame(&offload_frame());
        // flip one bit in the payload: crc must catch it
        let mut bad = good.clone();
        *bad.last_mut().unwrap() ^= 0x01;
        assert!(matches!(decode_frame(&bad), Err(WireError::Corrupt { .. })));
        // wrong magic
        let mut bad = good.clone();
        bad[0] = 0x00;
        assert!(matches!(decode_frame(&bad), Err(WireError::BadMagic { .. })));
        // future version
        let mut bad = good.clone();
        bad[2] = VERSION + 1;
        assert!(matches!(decode_frame(&bad), Err(WireError::Version { got }) if got == VERSION + 1));
        // unknown tag with a valid crc: skippable
        let mut bad = good;
        bad[3] = 0x7F;
        let crc = crc32_update(0xFFFF_FFFF, &bad[..8]);
        let crc = crc32_update(crc, &bad[HEADER_LEN..]) ^ 0xFFFF_FFFF;
        bad[8..12].copy_from_slice(&crc.to_le_bytes());
        match decode_frame(&bad) {
            Err(WireError::UnknownTag { got: 0x7F, skip }) => assert_eq!(skip, bad.len()),
            other => panic!("expected UnknownTag, got {other:?}"),
        }
    }

    #[test]
    fn nested_down_to_is_rejected() {
        // hand-build a DownTo whose inner tag is TAG_DOWN_TO: the decoder
        // must reject it as malformed instead of recursing
        let inner = Frame::DownTo {
            ue_id: 1,
            down: Downlink::Shutdown,
        };
        let inner_buf = encode_frame(&inner);
        let inner_body = &inner_buf[HEADER_LEN..];
        let mut body = Vec::new();
        body.extend_from_slice(&7u32.to_le_bytes()); // outer ue_id
        body.push(TAG_DOWN_TO);
        body.extend_from_slice(&(inner_body.len() as u32).to_le_bytes());
        body.extend_from_slice(inner_body);
        let prefix = [
            MAGIC[0],
            MAGIC[1],
            VERSION,
            TAG_DOWN_TO,
            body.len() as u8,
            0,
            0,
            0,
        ];
        let crc = crc32_parts(&[&prefix, &body]);
        let mut buf = Vec::new();
        buf.extend_from_slice(&prefix);
        buf.extend_from_slice(&crc.to_le_bytes());
        buf.extend_from_slice(&body);
        match decode_frame(&buf) {
            Err(WireError::Malformed(why)) => assert!(why.contains("nested"), "got: {why}"),
            other => panic!("expected Malformed, got {other:?}"),
        }
    }

    #[test]
    fn oversized_frames_are_rejected_at_the_sender() {
        let huge = Frame::Up(Uplink::Offload(OffloadRequest {
            ue_id: 0,
            task_id: 1,
            b: 0,
            payload: vec![0u8; MAX_BODY + 1],
            calibration: None,
        }));
        let mut sink = Vec::new();
        match write_frame(&mut sink, &huge) {
            Err(WireError::TooLarge { len }) => assert!(len > MAX_BODY),
            other => panic!("expected TooLarge, got {other:?}"),
        }
        assert!(sink.is_empty(), "no bytes may reach the wire");
    }

    #[test]
    fn absurd_length_prefix_cannot_allocate() {
        let mut buf = encode_frame(&Frame::Down(Downlink::Shutdown));
        buf[4..8].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(matches!(decode_frame(&buf), Err(WireError::TooLarge { .. })));
    }

    #[test]
    fn non_utf8_error_text_is_decoded_lossily_not_rejected() {
        // regression: a NACK whose error string is invalid UTF-8 must
        // still decode (lossily) — it used to kill the whole frame
        let mut body = Vec::new();
        body.extend_from_slice(&13u64.to_le_bytes()); // task_id
        let text = [b'b', b'a', b'd', 0xFF, 0xFE, b'!'];
        body.extend_from_slice(&(text.len() as u32).to_le_bytes());
        body.extend_from_slice(&text);
        let prefix = header_prefix(TAG_ERROR, body.len());
        let crc = crc32_parts(&[&prefix, &body]);
        let mut buf = Vec::new();
        buf.extend_from_slice(&prefix);
        buf.extend_from_slice(&crc.to_le_bytes());
        buf.extend_from_slice(&body);
        match decode_frame(&buf) {
            Ok((Frame::Down(Downlink::Error { task_id, error }), used)) => {
                assert_eq!(task_id, 13);
                assert_eq!(used, buf.len());
                assert!(error.starts_with("bad"), "got: {error:?}");
                assert!(error.contains('\u{FFFD}'), "lossy replacement expected: {error:?}");
            }
            other => panic!("expected a decoded Error frame, got {other:?}"),
        }
        // same bytes inside a DownTo envelope must survive too
        let mut outer_body = Vec::new();
        outer_body.extend_from_slice(&7u32.to_le_bytes());
        outer_body.push(TAG_ERROR);
        outer_body.extend_from_slice(&(body.len() as u32).to_le_bytes());
        outer_body.extend_from_slice(&body);
        let prefix = header_prefix(TAG_DOWN_TO, outer_body.len());
        let crc = crc32_parts(&[&prefix, &outer_body]);
        let mut buf = Vec::new();
        buf.extend_from_slice(&prefix);
        buf.extend_from_slice(&crc.to_le_bytes());
        buf.extend_from_slice(&outer_body);
        match decode_frame(&buf) {
            Ok((Frame::DownTo { ue_id: 7, down: Downlink::Error { .. } }, _)) => {}
            other => panic!("expected a decoded DownTo NACK, got {other:?}"),
        }
    }

    fn all_frames() -> Vec<Frame> {
        vec![
            Frame::Hello { ue_id: 7 },
            Frame::Welcome { ue_id: 7 },
            offload_frame(),
            Frame::Down(Downlink::Decision(FrameDecision {
                frame: 11,
                actions: vec![HybridAction::new(3, 1, 0.5, 1.0); 4].into(),
            })),
            Frame::Down(Downlink::Result(InferenceResult {
                ue_id: 5,
                task_id: 77,
                logits: vec![0.1, -0.2, 0.9],
                argmax: 2,
                edge_latency_s: 0.003,
            })),
            Frame::Down(Downlink::Error {
                task_id: 13,
                error: "no calibration".into(),
            }),
            Frame::DownTo {
                ue_id: 9_001,
                down: Downlink::Decision(FrameDecision {
                    frame: 4,
                    actions: vec![HybridAction::new(1, 0, -0.25, 1.0)].into(),
                }),
            },
            Frame::DownTo {
                ue_id: 123,
                down: Downlink::Shutdown,
            },
        ]
    }

    #[test]
    fn into_and_append_variants_match_the_allocating_encoder() {
        let mut reused = Vec::new();
        let mut appended = Vec::new();
        let mut expect_cat = Vec::new();
        for f in all_frames() {
            let fresh = encode_frame(&f);
            encode_frame_into(&f, &mut reused);
            assert_eq!(reused, fresh, "encode_frame_into diverged on {f:?}");
            let n = encode_frame_append(&f, &mut appended);
            assert_eq!(n, fresh.len());
            expect_cat.extend_from_slice(&fresh);
        }
        assert_eq!(appended, expect_cat, "appended frames must concatenate cleanly");
        // and the concatenation decodes back frame by frame
        let mut rest = &appended[..];
        for f in all_frames() {
            let (back, used) = decode_frame(rest).expect("decode appended");
            assert_eq!(back, f);
            rest = &rest[used..];
        }
        assert!(rest.is_empty());
    }

    #[test]
    fn raw_fanout_frames_are_byte_identical_to_reencoding() {
        let downs = vec![
            Downlink::Decision(FrameDecision {
                frame: 3,
                actions: vec![HybridAction::new(2, 1, 0.25, 1.0); 6].into(),
            }),
            Downlink::Result(InferenceResult {
                ue_id: 1,
                task_id: 5,
                logits: vec![1.0, 2.0],
                argmax: 1,
                edge_latency_s: 0.01,
            }),
            Downlink::Error {
                task_id: 9,
                error: "nope".into(),
            },
            Downlink::Shutdown,
        ];
        let mut body = Vec::new();
        for down in downs {
            body.clear();
            let tag = encode_down_body(&down, &mut body);
            // plain Down frame from the shared body
            let mut raw = Vec::new();
            let n = encode_down_raw(tag, &body, &mut raw);
            assert_eq!(n, raw.len());
            assert_eq!(raw, encode_frame(&Frame::Down(down.clone())));
            // DownTo envelopes for several UEs from the SAME body bytes
            for ue_id in [0usize, 7, 41_000] {
                let mut raw = Vec::new();
                let n = encode_down_to_raw(ue_id, tag, &body, &mut raw);
                assert_eq!(n, raw.len());
                assert_eq!(
                    raw,
                    encode_frame(&Frame::DownTo {
                        ue_id,
                        down: down.clone()
                    }),
                    "fan-out frame for UE {ue_id} diverged on {down:?}"
                );
            }
        }
    }

    #[test]
    fn read_frame_into_reuses_the_body_buffer() {
        let mut wire = Vec::new();
        write_frame(&mut wire, &offload_frame()).unwrap();
        write_frame(&mut wire, &Frame::Down(Downlink::Shutdown)).unwrap();
        let mut r = &wire[..];
        let mut body = Vec::new();
        assert_eq!(read_frame_into(&mut r, &mut body).unwrap(), offload_frame());
        let cap = body.capacity();
        assert_eq!(
            read_frame_into(&mut r, &mut body).unwrap(),
            Frame::Down(Downlink::Shutdown)
        );
        assert_eq!(body.capacity(), cap, "smaller frame must reuse the grown buffer");
        assert!(matches!(read_frame_into(&mut r, &mut body), Err(WireError::Closed)));
    }

    #[test]
    fn frame_pool_recycles_by_size_class() {
        let mut pool = FramePool::new();
        let mut a = pool.get(100); // class 7 (128)
        assert!(a.capacity() >= 100);
        a.extend_from_slice(&[1; 90]);
        let a_ptr = a.as_ptr();
        pool.put(a);
        // same class: the exact buffer comes back, cleared
        let b = pool.get(128);
        assert_eq!(b.as_ptr(), a_ptr, "same-class get must recycle");
        assert!(b.is_empty() && b.capacity() >= 128);
        // different class: a fresh allocation
        let c = pool.get(4096);
        assert!(c.capacity() >= 4096);
        let (hits, misses) = pool.stats();
        assert_eq!((hits, misses), (1, 2));
        // the per-class cap bounds retention
        for _ in 0..(POOL_PER_CLASS + 5) {
            pool.put(Vec::with_capacity(64));
        }
        let mut served = 0;
        for _ in 0..(POOL_PER_CLASS + 5) {
            let before = pool.stats().0;
            let _ = pool.get(64);
            if pool.stats().0 > before {
                served += 1;
            }
        }
        assert_eq!(served, POOL_PER_CLASS, "retention must stop at the cap");
        // oversized buffers are dropped, never binned
        pool.put(Vec::with_capacity(4 << 20));
        let huge = pool.get(4 << 20);
        assert!(huge.capacity() >= 4 << 20);
    }
}
