//! The collaborative-inference pipeline over real AOT model segments
//! (paper Fig. 1): UE-side front segment → AE encode (conv1x1 + quant
//! kernels) → wire → edge-side AE decode → back segment.
//!
//! Every stage is a backend executable (PJRT-compiled XLA for the CNN
//! segments; the AE stages also run on the native interpreter); this module
//! wires them together per partition decision and reports per-stage timings
//! so the serving example can print real latency/throughput numbers.

use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, Result};

use super::protocol::{InferenceResult, OffloadRequest};
use crate::compress::ae::{AeCompressor, EncodedFeature};
use crate::runtime::artifacts::{ArtifactStore, ModelMeta};
use crate::runtime::backend::Executable;
use crate::runtime::tensor::TensorView;

/// NaN-safe argmax over logits. `partial_cmp(..).unwrap()` panics the
/// serving thread on any NaN logit; here NaN entries simply never win
/// (every comparison against NaN is false) and an empty or all-NaN slice
/// yields 0 instead of panicking.
pub fn argmax(logits: &[f32]) -> usize {
    let mut best = 0usize;
    let mut best_v = f32::NEG_INFINITY;
    for (i, &v) in logits.iter().enumerate() {
        if v > best_v {
            best = i;
            best_v = v;
        }
    }
    best
}

/// O(1) length validation of a raw-offload payload — cheap enough for the
/// server routing thread, so malformed requests NACK immediately and
/// never enter a batch.
pub fn check_raw_payload(payload: &[u8], expect_elems: usize) -> Result<()> {
    if payload.len() != 4 * expect_elems {
        return Err(anyhow!(
            "raw offload payload is {} bytes; expected {} (= 4 bytes x {} f32 image elements)",
            payload.len(),
            4 * expect_elems,
            expect_elems
        ));
    }
    Ok(())
}

/// Decode a raw-offload payload (little-endian f32 pixels) after
/// validating its length up front. Without the check, `chunks_exact(4)`
/// silently drops trailing bytes and the mismatch only surfaces (if at
/// all) deep inside tensor construction.
pub fn decode_raw_payload(payload: &[u8], expect_elems: usize) -> Result<Vec<f32>> {
    check_raw_payload(payload, expect_elems)?;
    Ok(payload
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Per-stage timing of one collaborative inference (seconds).
#[derive(Debug, Clone, Copy, Default)]
pub struct PipelineTiming {
    pub front_s: f64,
    pub encode_s: f64,
    pub wire_bits: usize,
    pub decode_s: f64,
    pub back_s: f64,
}

impl PipelineTiming {
    pub fn ue_side_s(&self) -> f64 {
        self.front_s + self.encode_s
    }

    pub fn edge_side_s(&self) -> f64 {
        self.decode_s + self.back_s
    }
}

/// The full collaborative pipeline for one model: all four cuts plus the
/// full-model path, selected per request.
pub struct CollabPipeline {
    pub meta: ModelMeta,
    /// Model weight vector, pre-wrapped as a backend input (loop-invariant).
    weights: TensorView,
    full: Arc<dyn Executable>,
    fronts: Vec<Arc<dyn Executable>>,
    backs: Vec<Arc<dyn Executable>>,
    compressors: Vec<AeCompressor>,
}

impl CollabPipeline {
    pub fn load(store: &ArtifactStore, model: &str) -> Result<CollabPipeline> {
        let meta = store.model(model)?.clone();
        let weights = store.model_weights(model)?;
        let weights = TensorView::f32(weights, vec![meta.weights_size])?;
        let full = store.load(&format!("{model}_full_b1"))?;
        let mut fronts = Vec::new();
        let mut backs = Vec::new();
        let mut compressors = Vec::new();
        for p in 1..=meta.points.len() {
            fronts.push(store.load(&format!("{model}_front_p{p}"))?);
            backs.push(store.load(&format!("{model}_back_p{p}"))?);
            compressors.push(AeCompressor::load(store, model, p)?);
        }
        Ok(CollabPipeline {
            meta,
            weights,
            full,
            fronts,
            backs,
            compressors,
        })
    }

    pub fn num_points(&self) -> usize {
        self.fronts.len()
    }

    fn image_shape(&self) -> Vec<usize> {
        vec![1, 3, self.meta.input_hw, self.meta.input_hw]
    }

    /// Full on-device inference (the b = B+1 decision).
    pub fn infer_local(&self, image: &[f32]) -> Result<Vec<f32>> {
        let image = TensorView::f32(image.to_vec(), self.image_shape())?;
        let outs = self.full.call_refs(&[&self.weights, &image])?;
        outs[0].clone().into_f32s()
    }

    /// Raw intermediate feature at point `p` (no compression) — used by
    /// the JALAD measurement path and numerics tests.
    pub fn front_feature(&self, image: &[f32], p: usize) -> Result<Vec<f32>> {
        let idx = p
            .checked_sub(1)
            .filter(|&i| i < self.fronts.len())
            .ok_or_else(|| anyhow!("partition point {p} out of range"))?;
        let image = TensorView::f32(image.to_vec(), self.image_shape())?;
        let outs = self.fronts[idx].call_refs(&[&self.weights, &image])?;
        outs[0].clone().into_f32s()
    }

    /// UE half for partition point `p` (1-based): front segment + encode.
    pub fn ue_half(&self, image: &[f32], p: usize) -> Result<(EncodedFeature, PipelineTiming)> {
        let idx = p
            .checked_sub(1)
            .filter(|&i| i < self.fronts.len())
            .ok_or_else(|| anyhow!("partition point {p} out of range"))?;
        let mut timing = PipelineTiming::default();

        let t = Instant::now();
        let image = TensorView::f32(image.to_vec(), self.image_shape())?;
        let outs = self.fronts[idx].call_refs(&[&self.weights, &image])?;
        let feature = outs[0].clone().into_f32s()?;
        timing.front_s = t.elapsed().as_secs_f64();

        let t = Instant::now();
        let encoded = self.compressors[idx].encode(&feature)?;
        timing.encode_s = t.elapsed().as_secs_f64();
        timing.wire_bits = encoded.wire_bits();
        Ok((encoded, timing))
    }

    /// Decode a compressed feature back to (1, ch, h, w) without running
    /// the back segment (reconstruction-error measurement).
    pub fn decode_feature(&self, encoded: &EncodedFeature, p: usize) -> Result<Vec<f32>> {
        let idx = p
            .checked_sub(1)
            .filter(|&i| i < self.compressors.len())
            .ok_or_else(|| anyhow!("partition point {p} out of range"))?;
        self.compressors[idx].decode(encoded)
    }

    /// Edge half for partition point `p`: decode + back segment.
    pub fn edge_half(
        &self,
        encoded: &EncodedFeature,
        p: usize,
        timing: &mut PipelineTiming,
    ) -> Result<Vec<f32>> {
        let idx = p
            .checked_sub(1)
            .filter(|&i| i < self.backs.len())
            .ok_or_else(|| anyhow!("partition point {p} out of range"))?;
        let t = Instant::now();
        let feature = self.compressors[idx].decode(encoded)?;
        timing.decode_s = t.elapsed().as_secs_f64();

        let t = Instant::now();
        let pm = &self.compressors[idx].meta;
        let feature = TensorView::f32(feature, vec![1, pm.ch, pm.h, pm.w])?;
        let outs = self.backs[idx].call_refs(&[&self.weights, &feature])?;
        let logits = outs[0].clone().into_f32s()?;
        timing.back_s = t.elapsed().as_secs_f64();
        Ok(logits)
    }

    /// Whole split inference at point `p` (UE + edge halves in-process).
    pub fn infer_split(&self, image: &[f32], p: usize) -> Result<(Vec<f32>, PipelineTiming)> {
        let (encoded, mut timing) = self.ue_half(image, p)?;
        let logits = self.edge_half(&encoded, p, &mut timing)?;
        Ok((logits, timing))
    }

    /// Serve an [`OffloadRequest`] arriving at the edge over the wire
    /// format (used by the threaded server).
    pub fn serve_offload(&self, req: &OffloadRequest) -> Result<InferenceResult> {
        let t0 = Instant::now();
        let logits = if req.b == 0 {
            // raw input: payload is the f32 image bytes (validated up front)
            let image =
                decode_raw_payload(&req.payload, 3 * self.meta.input_hw * self.meta.input_hw)?;
            // the edge runs the whole model
            self.infer_local(&image)?
        } else {
            let idx = req
                .b
                .checked_sub(1)
                .filter(|&i| i < self.compressors.len())
                .ok_or_else(|| anyhow!("offload partition point {} out of range", req.b))?;
            let pm = &self.compressors[idx].meta;
            let (lo, hi) = req
                .calibration
                .ok_or_else(|| anyhow!("feature offload without calibration"))?;
            let encoded = EncodedFeature::from_wire(
                &req.payload,
                vec![1, pm.ch_r, pm.h, pm.w],
                lo,
                hi,
                pm.bits as u32,
            )?;
            let mut timing = PipelineTiming::default();
            self.edge_half(&encoded, req.b, &mut timing)?
        };
        Ok(InferenceResult {
            ue_id: req.ue_id,
            task_id: req.task_id,
            argmax: argmax(&logits),
            logits,
            edge_latency_s: t0.elapsed().as_secs_f64(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_is_nan_safe() {
        assert_eq!(argmax(&[0.1, 0.7, 0.3]), 1);
        // NaN logits must never win — and must not panic the server
        assert_eq!(argmax(&[f32::NAN, 1.0, 2.0]), 2);
        assert_eq!(argmax(&[1.0, f32::NAN, 0.5]), 0);
        assert_eq!(argmax(&[f32::NAN, f32::NAN]), 0);
        assert_eq!(argmax(&[]), 0);
        assert_eq!(argmax(&[f32::NEG_INFINITY, -1.0]), 1);
    }

    #[test]
    fn raw_payload_length_is_validated_up_front() {
        let ok = decode_raw_payload(&1.0f32.to_le_bytes(), 1).unwrap();
        assert_eq!(ok, vec![1.0]);
        // trailing bytes used to be silently dropped by chunks_exact(4)
        let err = decode_raw_payload(&[0u8; 6], 1).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("6 bytes"), "unexpected error: {msg}");
        assert!(msg.contains("expected 4"), "unexpected error: {msg}");
        // truncated payloads are rejected too
        assert!(decode_raw_payload(&[0u8; 8], 3).is_err());
        assert!(decode_raw_payload(&[], 1).is_err());
    }
}
