//! The offload executor: a worker pool that serves offloaded inferences
//! *off* the server thread, so `server_loop` only routes — it never runs
//! model math (one slow back-segment must not stall decision broadcasts
//! for every UE).
//!
//! Shape (mirrors the dispatcher/worker split of serving systems):
//!
//! ```text
//!              submit()                 jobs (mpsc)
//! server loop ──────────► dispatcher ═══════════════► N workers
//!                          │  raw b=0 → DynamicBatcher   │ serve() /
//!                          │  (flush on max_batch or     │ serve_batch()
//!                          │   max_wait via pump())      │
//!              ◄──────────────────────────────────────────┘
//!                try_completions()  (completion mpsc)
//! ```
//!
//! * Feature offloads (b ≥ 1) dispatch to per-worker `edge_half`
//!   execution immediately.
//! * Raw-input offloads (b = 0) accumulate in the [`DynamicBatcher`] and
//!   flush as one job through the batch-capable compute (the
//!   `{model}_full_b8` artifact when it exists).
//! * [`OffloadExecutor::drain_shutdown`] flushes everything still queued
//!   and joins the workers — no accepted offload is ever dropped.
//!
//! The model math behind the pool is the [`OffloadCompute`] trait:
//! [`CollabPipeline`] (serial), [`PipelineCompute`] (pipeline + b8 batch
//! runner), or [`SyntheticCompute`] (artifact-free, for tests/benches).

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use super::batcher::{BatchItem, BatchOutput, BatchRunner, DynamicBatcher, Stamped};
use super::inference::{argmax, check_raw_payload, decode_raw_payload, CollabPipeline};
use super::protocol::{InferenceResult, OffloadRequest};
use crate::runtime::artifacts::ArtifactStore;
use crate::runtime::backend::Precision;
use crate::util::sync::lock_unpoisoned;

/// The compute side of offload serving — what the workers actually run,
/// independent of where the model math comes from.
pub trait OffloadCompute: Send + Sync {
    /// Serve one offload: a feature (b ≥ 1) or a single raw input (b = 0).
    fn serve(&self, req: &OffloadRequest) -> Result<InferenceResult>;

    /// Serve raw-input items as one batch (all b = 0). Item order is
    /// preserved in the outputs.
    fn serve_batch(&self, items: Vec<BatchItem>) -> Result<Vec<BatchOutput>>;

    /// Elements of one raw image payload — used to validate and decode
    /// raw payloads before they enter the batch queue.
    fn image_elems(&self) -> usize;

    /// The batch size worth accumulating to (1 = batching buys nothing,
    /// raw offloads dispatch individually).
    fn preferred_batch(&self) -> usize {
        1
    }
}

/// The plain pipeline: serial full-model execution for raw batches.
impl OffloadCompute for CollabPipeline {
    fn serve(&self, req: &OffloadRequest) -> Result<InferenceResult> {
        self.serve_offload(req)
    }

    fn serve_batch(&self, items: Vec<BatchItem>) -> Result<Vec<BatchOutput>> {
        let now = Instant::now();
        items
            .into_iter()
            .map(|it| {
                Ok(BatchOutput {
                    logits: self.infer_local(&it.image)?,
                    ue_id: it.ue_id,
                    task_id: it.task_id,
                    queue_wait: now.duration_since(it.enqueued),
                })
            })
            .collect()
    }

    fn image_elems(&self) -> usize {
        3 * self.meta.input_hw * self.meta.input_hw
    }
}

/// The production compute: a shared [`CollabPipeline`] plus — when the
/// `{model}_full_b8` artifact exists — a [`BatchRunner`] so raw offloads
/// ride the batched artifact.
pub struct PipelineCompute {
    pipeline: CollabPipeline,
    runner: Option<BatchRunner>,
}

impl PipelineCompute {
    pub fn load(store: &ArtifactStore, model: &str) -> Result<PipelineCompute> {
        let pipeline = CollabPipeline::load(store, model)?;
        let runner = match BatchRunner::from_store(store, model) {
            Ok(r) => Some(r),
            Err(e) => {
                // no b8 artifact: serve raw offloads serially instead of
                // refusing to start
                log::warn!("raw-offload batching disabled: {e:#}");
                None
            }
        };
        Ok(PipelineCompute { pipeline, runner })
    }

    pub fn pipeline(&self) -> &CollabPipeline {
        &self.pipeline
    }
}

impl OffloadCompute for PipelineCompute {
    fn serve(&self, req: &OffloadRequest) -> Result<InferenceResult> {
        self.pipeline.serve_offload(req)
    }

    fn serve_batch(&self, items: Vec<BatchItem>) -> Result<Vec<BatchOutput>> {
        match &self.runner {
            Some(r) => r.run(items),
            None => self.pipeline.serve_batch(items),
        }
    }

    fn image_elems(&self) -> usize {
        self.pipeline.image_elems()
    }

    fn preferred_batch(&self) -> usize {
        self.runner.as_ref().map_or(1, |r| r.wire_batch())
    }
}

/// A model-free compute for executor tests and the serving bench: spins
/// the CPU for a configurable per-item cost and emits deterministic
/// logits `logit[c] = checksum + c`, where the checksum is the decoded
/// f32 image sum for raw inputs (identical on the single and batch
/// paths) and the payload byte sum for features. `serve_batch` costs
/// `cost · (1 + (n-1)/batch_speedup)` — the first item at full price,
/// the rest amortized — modeling what the `_full_b8` artifact buys
/// batched raw offloads. (The CNN artifacts themselves need the PJRT
/// backend, so the offline serving bench runs on this stand-in;
/// BENCH_runtime.json carries real artifact timings.)
pub struct SyntheticCompute {
    pub image_elems: usize,
    pub num_classes: usize,
    pub cost: Duration,
    pub batch_speedup: f64,
}

impl SyntheticCompute {
    pub fn new(cost: Duration) -> SyntheticCompute {
        SyntheticCompute {
            image_elems: 16,
            num_classes: 8,
            cost,
            batch_speedup: 3.0,
        }
    }

    fn spin(d: Duration) {
        let t = Instant::now();
        while t.elapsed() < d {
            std::hint::spin_loop();
        }
    }

    fn logits_for(&self, checksum: f32) -> Vec<f32> {
        (0..self.num_classes).map(|c| checksum + c as f32).collect()
    }
}

impl OffloadCompute for SyntheticCompute {
    fn serve(&self, req: &OffloadRequest) -> Result<InferenceResult> {
        // same checksum rule as the batch path: raw inputs sum the
        // decoded image, so single vs batched results are identical
        let checksum: f32 = if req.b == 0 {
            decode_raw_payload(&req.payload, self.image_elems)?.iter().sum()
        } else {
            req.payload.iter().map(|&b| b as f32).sum()
        };
        Self::spin(self.cost);
        let logits = self.logits_for(checksum);
        Ok(InferenceResult {
            ue_id: req.ue_id,
            task_id: req.task_id,
            argmax: argmax(&logits),
            logits,
            edge_latency_s: self.cost.as_secs_f64(),
        })
    }

    fn serve_batch(&self, items: Vec<BatchItem>) -> Result<Vec<BatchOutput>> {
        // stamp waits before executing — queue wait must not include
        // execution time
        let now = Instant::now();
        let n = items.len();
        if n > 0 {
            let amortized = 1.0 + (n - 1) as f64 / self.batch_speedup.max(1.0);
            Self::spin(Duration::from_secs_f64(self.cost.as_secs_f64() * amortized));
        }
        Ok(items
            .into_iter()
            .map(|it| BatchOutput {
                logits: self.logits_for(it.image.iter().sum()),
                ue_id: it.ue_id,
                task_id: it.task_id,
                queue_wait: now.duration_since(it.enqueued),
            })
            .collect())
    }

    fn image_elems(&self) -> usize {
        self.image_elems
    }

    fn preferred_batch(&self) -> usize {
        8
    }
}

/// Executor knobs (threaded through [`super::server::ServerConfig`]).
#[derive(Debug, Clone, Copy)]
pub struct ExecutorConfig {
    /// Worker threads. 0 = no pool: the server serves offloads inline on
    /// its own thread (the serial baseline).
    pub workers: usize,
    /// Accumulation target for raw-offload batches.
    pub max_batch: usize,
    /// Max age of a queued raw offload before a partial batch flushes.
    pub max_wait: Duration,
    /// Numeric precision the serving stack's inference executables run at.
    /// The executor itself is precision-agnostic — the serve entry points
    /// open their [`crate::runtime::artifacts::ArtifactStore`] with a
    /// backend at this precision (see `macci serve --precision`); it rides
    /// here so one config travels the whole serving path.
    pub precision: Precision,
}

impl Default for ExecutorConfig {
    fn default() -> ExecutorConfig {
        ExecutorConfig {
            workers: 4,
            max_batch: 8,
            max_wait: Duration::from_millis(2),
            precision: Precision::F32,
        }
    }
}

/// One finished offload coming back from the pool.
#[derive(Debug)]
pub struct Completion {
    pub ue_id: usize,
    pub task_id: u64,
    pub outcome: Result<InferenceResult>,
    /// Submit → execution-start wait.
    pub queue_wait: Duration,
    /// Size of the batch this item rode (1 = individual dispatch).
    pub batch_size: usize,
}

/// Executor counters, merged into `ServerStats` at shutdown.
#[derive(Debug, Clone, Copy, Default)]
pub struct ExecutorStats {
    pub submitted: usize,
    pub completed: usize,
    pub errors: usize,
    /// Raw batches dispatched, and the items that rode them.
    pub batches: usize,
    pub batched_items: usize,
    /// High-water mark of accepted-but-unfinished offloads.
    pub max_queue_depth: usize,
    /// Cumulative submit → execution-start wait.
    pub queue_wait_s: f64,
}

impl ExecutorStats {
    /// Mean fill of dispatched batches relative to the accumulation target.
    pub fn batch_occupancy(&self, max_batch: usize) -> f64 {
        if self.batches == 0 || max_batch == 0 {
            return 0.0;
        }
        self.batched_items as f64 / (self.batches * max_batch) as f64
    }

    pub fn mean_queue_wait_s(&self) -> f64 {
        if self.completed == 0 {
            return 0.0;
        }
        self.queue_wait_s / self.completed as f64
    }
}

/// A raw-input offload waiting in the batch queue. The payload stays
/// undecoded: submit() only length-checks (O(1)); the byte → f32 decode
/// runs on the worker, keeping the server routing thread compute-free.
struct PendingRaw {
    req: OffloadRequest,
    enqueued: Instant,
}

impl Stamped for PendingRaw {
    fn enqueued(&self) -> Instant {
        self.enqueued
    }
}

enum Job {
    /// A feature offload (or a raw one when batching is off), stamped
    /// with its submit time.
    Single(OffloadRequest, Instant),
    Batch(Vec<PendingRaw>),
}

/// Handle owned by the server loop: submission in, completions out.
pub struct OffloadExecutor {
    compute: Arc<dyn OffloadCompute>,
    jobs_tx: Option<Sender<Job>>,
    /// Kept so the dispatcher can inject rejects (bad payloads) as
    /// ordinary completions.
    done_tx: Sender<Completion>,
    done_rx: Receiver<Completion>,
    workers: Vec<JoinHandle<()>>,
    batch: Option<DynamicBatcher<PendingRaw>>,
    inflight: usize,
    stats: ExecutorStats,
}

impl OffloadExecutor {
    /// Spawn the worker pool (`cfg.workers` ≥ 1 — a zero-worker setup
    /// means "serve inline", in which case don't start an executor).
    pub fn start(compute: Arc<dyn OffloadCompute>, cfg: ExecutorConfig) -> Result<OffloadExecutor> {
        // lint: allow(bounded-channels) — depth ≤ inflight, which the server loop
        // bounds via drain_limit admission; a sync_channel would deadlock
        // drain_shutdown (workers join before the final completion drain).
        // SLO-driven admission control replaces this in the ops-plane item.
        let (jobs_tx, jobs_rx) = channel::<Job>();
        let jobs_rx = Arc::new(Mutex::new(jobs_rx));
        // lint: allow(bounded-channels) — completions: same inflight bound as jobs;
        // blocking workers here would wedge the graceful drain
        let (done_tx, done_rx) = channel::<Completion>();
        let mut workers = Vec::new();
        for i in 0..cfg.workers.max(1) {
            let rx = jobs_rx.clone();
            let tx = done_tx.clone();
            let compute = compute.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("offload-worker-{i}"))
                    .spawn(move || worker_loop(rx, tx, compute))?,
            );
        }
        let batch = (cfg.max_batch > 1 && compute.preferred_batch() > 1)
            .then(|| DynamicBatcher::new(cfg.max_batch, cfg.max_wait));
        Ok(OffloadExecutor {
            compute,
            jobs_tx: Some(jobs_tx),
            done_tx,
            done_rx,
            workers,
            batch,
            inflight: 0,
            stats: ExecutorStats::default(),
        })
    }

    /// Accepted-but-unfinished offloads (including queued raw items).
    pub fn queue_depth(&self) -> usize {
        self.inflight
    }

    pub fn stats(&self) -> ExecutorStats {
        self.stats
    }

    /// Route one accepted offload: raw inputs enter the batch queue,
    /// everything else dispatches to the pool immediately. Never blocks
    /// and never does per-byte work on the caller's thread.
    pub fn submit(&mut self, req: OffloadRequest) {
        self.inflight += 1;
        self.stats.submitted += 1;
        self.stats.max_queue_depth = self.stats.max_queue_depth.max(self.inflight);
        if req.b == 0 && self.batch.is_some() {
            // reject malformed payloads before the queue (O(1) length
            // check only — the decode itself happens on the worker)
            if let Err(e) = check_raw_payload(&req.payload, self.compute.image_elems()) {
                let _ = self.done_tx.send(Completion {
                    ue_id: req.ue_id,
                    task_id: req.task_id,
                    outcome: Err(e),
                    queue_wait: Duration::ZERO,
                    batch_size: 1,
                });
                return;
            }
            if let Some(q) = self.batch.as_mut() {
                q.push(PendingRaw {
                    req,
                    enqueued: Instant::now(),
                });
                return;
            }
        }
        self.dispatch(Job::Single(req, Instant::now()));
    }

    /// Flush the batch queue per policy — call once per server tick.
    pub fn pump(&mut self, now: Instant) {
        while self.batch.as_ref().map_or(false, |q| q.should_flush(now)) {
            self.flush_one_batch();
        }
    }

    /// Take one batch off the queue and dispatch it (shared by the
    /// per-tick pump and the shutdown drain so the accounting cannot
    /// diverge). Returns false once the queue is empty or absent.
    fn flush_one_batch(&mut self) -> bool {
        let items = match self.batch.as_mut() {
            Some(q) if q.pending() > 0 => q.take_batch(),
            _ => return false,
        };
        self.stats.batches += 1;
        self.stats.batched_items += items.len();
        self.dispatch(Job::Batch(items));
        true
    }

    /// Non-blocking drain of finished offloads.
    pub fn try_completions(&mut self) -> Vec<Completion> {
        let mut out = Vec::new();
        while let Ok(c) = self.done_rx.try_recv() {
            self.note(&c);
            out.push(c);
        }
        out
    }

    /// Graceful shutdown: flush everything still queued, stop the
    /// workers, and hand back every outstanding completion — no accepted
    /// offload is dropped.
    pub fn drain_shutdown(mut self) -> (Vec<Completion>, ExecutorStats) {
        while self.flush_one_batch() {}
        // dropping the sender ends every worker's recv loop
        drop(self.jobs_tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        // workers are joined: all completions are already in the channel
        let mut out = Vec::new();
        while let Ok(c) = self.done_rx.try_recv() {
            self.note(&c);
            out.push(c);
        }
        (out, self.stats)
    }

    fn dispatch(&mut self, job: Job) {
        // `jobs_tx` is Some until `drain_shutdown` consumes self, so this
        // arm is unreachable — but the dispatch path must not panic
        match self.jobs_tx.as_ref() {
            Some(tx) => {
                let _ = tx.send(job);
            }
            None => log::error!("offload dispatched after executor shutdown — dropped"),
        }
    }

    fn note(&mut self, c: &Completion) {
        self.inflight = self.inflight.saturating_sub(1);
        self.stats.completed += 1;
        self.stats.queue_wait_s += c.queue_wait.as_secs_f64();
        if c.outcome.is_err() {
            self.stats.errors += 1;
        }
    }
}

/// Run one compute call, converting a panic into an error so the worker
/// survives and the owner still gets a NACK — the "no accepted offload
/// is dropped" guarantee must hold even against a buggy backend.
fn run_guarded<T>(f: impl FnOnce() -> Result<T>) -> Result<T> {
    match std::panic::catch_unwind(std::panic::AssertUnwindSafe(f)) {
        Ok(r) => r,
        Err(p) => {
            let msg = p
                .downcast_ref::<&str>()
                .map(|s| s.to_string())
                .or_else(|| p.downcast_ref::<String>().cloned())
                .unwrap_or_else(|| "unknown panic".into());
            Err(anyhow!("offload compute panicked: {msg}"))
        }
    }
}

fn worker_loop(
    jobs: Arc<Mutex<Receiver<Job>>>,
    done: Sender<Completion>,
    compute: Arc<dyn OffloadCompute>,
) {
    loop {
        // hold the lock only for the blocking recv, not the execution
        // (poison-tolerant: a panicked sibling must not take the pool down)
        let job = match lock_unpoisoned(&jobs).recv() {
            Ok(j) => j,
            Err(_) => return, // dispatcher gone: drain complete
        };
        match job {
            Job::Single(req, submitted) => {
                let queue_wait = submitted.elapsed();
                let outcome = run_guarded(|| compute.serve(&req));
                let _ = done.send(Completion {
                    ue_id: req.ue_id,
                    task_id: req.task_id,
                    outcome,
                    queue_wait,
                    batch_size: 1,
                });
            }
            Job::Batch(pend) => {
                // decode payloads here, off the server thread; lengths
                // were validated at submit, so failures are exceptional
                // and fail only their own item
                let elems = compute.image_elems();
                let mut items = Vec::with_capacity(pend.len());
                for p in pend {
                    match decode_raw_payload(&p.req.payload, elems) {
                        Ok(image) => items.push(BatchItem {
                            ue_id: p.req.ue_id,
                            task_id: p.req.task_id,
                            image,
                            enqueued: p.enqueued,
                        }),
                        Err(e) => {
                            let _ = done.send(Completion {
                                ue_id: p.req.ue_id,
                                task_id: p.req.task_id,
                                outcome: Err(e),
                                queue_wait: p.enqueued.elapsed(),
                                batch_size: 1,
                            });
                        }
                    }
                }
                if items.is_empty() {
                    continue;
                }
                let n = items.len();
                let meta: Vec<(usize, u64, Instant)> = items
                    .iter()
                    .map(|it| (it.ue_id, it.task_id, it.enqueued))
                    .collect();
                let t = Instant::now();
                match run_guarded(|| compute.serve_batch(items)) {
                    Ok(outs) => {
                        // amortized per-item edge cost of the batch
                        let per_item_s = t.elapsed().as_secs_f64() / n.max(1) as f64;
                        for o in outs {
                            let result = InferenceResult {
                                ue_id: o.ue_id,
                                task_id: o.task_id,
                                argmax: argmax(&o.logits),
                                logits: o.logits,
                                edge_latency_s: per_item_s,
                            };
                            let _ = done.send(Completion {
                                ue_id: result.ue_id,
                                task_id: result.task_id,
                                queue_wait: o.queue_wait,
                                batch_size: n,
                                outcome: Ok(result),
                            });
                        }
                    }
                    // fail every item of the batch individually so each
                    // owner hears about it
                    Err(e) => {
                        for (ue_id, task_id, enqueued) in meta {
                            let _ = done.send(Completion {
                                ue_id,
                                task_id,
                                outcome: Err(anyhow!("batch of {n} failed: {e:#}")),
                                // wait ends where execution began — same
                                // accounting as the success path
                                queue_wait: t.duration_since(enqueued),
                                batch_size: n,
                            });
                        }
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Records how work reached the compute (batch sizes, single serves).
    struct Recorder {
        batches: Mutex<Vec<usize>>,
        singles: Mutex<Vec<u64>>,
    }

    struct TestCompute {
        rec: Arc<Recorder>,
        elems: usize,
        fail_task: Option<u64>,
    }

    impl TestCompute {
        fn new(elems: usize, fail_task: Option<u64>) -> (Arc<TestCompute>, Arc<Recorder>) {
            let rec = Arc::new(Recorder {
                batches: Mutex::new(Vec::new()),
                singles: Mutex::new(Vec::new()),
            });
            (
                Arc::new(TestCompute {
                    rec: rec.clone(),
                    elems,
                    fail_task,
                }),
                rec,
            )
        }
    }

    impl OffloadCompute for TestCompute {
        fn serve(&self, req: &OffloadRequest) -> Result<InferenceResult> {
            self.rec.singles.lock().unwrap().push(req.task_id);
            if self.fail_task == Some(req.task_id) {
                anyhow::bail!("injected failure for task {}", req.task_id);
            }
            Ok(InferenceResult {
                ue_id: req.ue_id,
                task_id: req.task_id,
                logits: vec![1.0, 0.0],
                argmax: 0,
                edge_latency_s: 0.0,
            })
        }

        fn serve_batch(&self, items: Vec<BatchItem>) -> Result<Vec<BatchOutput>> {
            self.rec.batches.lock().unwrap().push(items.len());
            let now = Instant::now();
            Ok(items
                .into_iter()
                .map(|it| BatchOutput {
                    ue_id: it.ue_id,
                    task_id: it.task_id,
                    logits: vec![0.0, 1.0],
                    queue_wait: now.duration_since(it.enqueued),
                })
                .collect())
        }

        fn image_elems(&self) -> usize {
            self.elems
        }

        fn preferred_batch(&self) -> usize {
            8
        }
    }

    fn raw_req(task_id: u64, elems: usize) -> OffloadRequest {
        OffloadRequest {
            ue_id: task_id as usize % 2,
            task_id,
            b: 0,
            payload: vec![0u8; 4 * elems],
            calibration: None,
        }
    }

    fn feature_req(task_id: u64) -> OffloadRequest {
        OffloadRequest {
            ue_id: 0,
            task_id,
            b: 2,
            payload: vec![1, 2, 3],
            calibration: Some((0.0, 1.0)),
        }
    }

    /// Pump + drain until `n` completions arrive (or 5 s pass).
    fn drain_until(ex: &mut OffloadExecutor, n: usize) -> Vec<Completion> {
        let mut got = Vec::new();
        let t0 = Instant::now();
        while got.len() < n && t0.elapsed() < Duration::from_secs(5) {
            ex.pump(Instant::now());
            got.extend(ex.try_completions());
            std::thread::sleep(Duration::from_micros(200));
        }
        got
    }

    #[test]
    fn raw_offloads_flow_through_the_batcher() {
        let (compute, rec) = TestCompute::new(4, None);
        let cfg = ExecutorConfig {
            workers: 2,
            max_batch: 4,
            max_wait: Duration::from_secs(60), // size-triggered flush only
            ..ExecutorConfig::default()
        };
        let mut ex = OffloadExecutor::start(compute, cfg).unwrap();
        for t in 0..4 {
            ex.submit(raw_req(t, 4));
        }
        assert_eq!(ex.queue_depth(), 4);
        let got = drain_until(&mut ex, 4);
        assert_eq!(got.len(), 4);
        assert!(got.iter().all(|c| c.batch_size == 4));
        assert!(got.iter().all(|c| c.outcome.is_ok()));
        assert_eq!(*rec.batches.lock().unwrap(), vec![4]);
        assert!(rec.singles.lock().unwrap().is_empty());
        assert_eq!(ex.queue_depth(), 0);
        let (_, stats) = ex.drain_shutdown();
        assert_eq!((stats.batches, stats.batched_items), (1, 4));
        assert!((stats.batch_occupancy(4) - 1.0).abs() < 1e-9);
        assert_eq!(stats.max_queue_depth, 4);
    }

    #[test]
    fn partial_batch_flushes_on_max_wait() {
        let (compute, rec) = TestCompute::new(4, None);
        let cfg = ExecutorConfig {
            workers: 1,
            max_batch: 8,
            max_wait: Duration::from_millis(40),
            ..ExecutorConfig::default()
        };
        let mut ex = OffloadExecutor::start(compute, cfg).unwrap();
        let t0 = Instant::now();
        ex.submit(raw_req(0, 4));
        ex.pump(Instant::now());
        if t0.elapsed() < Duration::from_millis(40) {
            assert!(
                ex.try_completions().is_empty(),
                "fresh item must not flush before max_wait"
            );
        }
        std::thread::sleep(Duration::from_millis(45));
        let got = drain_until(&mut ex, 1);
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].batch_size, 1);
        assert!(got[0].queue_wait >= Duration::from_millis(40));
        assert_eq!(*rec.batches.lock().unwrap(), vec![1]);
        ex.drain_shutdown();
    }

    #[test]
    fn feature_offloads_dispatch_individually() {
        let (compute, rec) = TestCompute::new(4, None);
        let mut ex = OffloadExecutor::start(compute, ExecutorConfig::default()).unwrap();
        ex.submit(feature_req(7));
        ex.submit(feature_req(8));
        let got = drain_until(&mut ex, 2);
        assert_eq!(got.len(), 2);
        assert!(got.iter().all(|c| c.batch_size == 1));
        let mut singles = rec.singles.lock().unwrap().clone();
        singles.sort_unstable();
        assert_eq!(singles, vec![7, 8]);
        assert!(rec.batches.lock().unwrap().is_empty());
        ex.drain_shutdown();
    }

    #[test]
    fn malformed_raw_payload_is_rejected_before_the_queue() {
        let (compute, rec) = TestCompute::new(4, None);
        let mut ex = OffloadExecutor::start(compute, ExecutorConfig::default()).unwrap();
        ex.submit(OffloadRequest {
            payload: vec![0u8; 7], // not 4 * elems
            ..raw_req(3, 4)
        });
        let got = drain_until(&mut ex, 1);
        assert_eq!(got.len(), 1);
        assert!(got[0].outcome.is_err());
        assert_eq!(got[0].task_id, 3);
        assert!(rec.batches.lock().unwrap().is_empty());
        assert!(rec.singles.lock().unwrap().is_empty());
        let (_, stats) = ex.drain_shutdown();
        assert_eq!(stats.errors, 1);
        assert_eq!(stats.completed, 1);
    }

    #[test]
    fn serve_errors_become_error_completions() {
        let (compute, _rec) = TestCompute::new(4, Some(9));
        let mut ex = OffloadExecutor::start(compute, ExecutorConfig::default()).unwrap();
        ex.submit(feature_req(9));
        let got = drain_until(&mut ex, 1);
        let err = got[0].outcome.as_ref().unwrap_err();
        assert!(format!("{err:#}").contains("injected failure"));
        let (_, stats) = ex.drain_shutdown();
        assert_eq!(stats.errors, 1);
    }

    #[test]
    fn compute_panics_become_error_completions() {
        struct PanicCompute;
        impl OffloadCompute for PanicCompute {
            fn serve(&self, _req: &OffloadRequest) -> Result<InferenceResult> {
                panic!("boom");
            }
            fn serve_batch(&self, _items: Vec<BatchItem>) -> Result<Vec<BatchOutput>> {
                panic!("batch boom");
            }
            fn image_elems(&self) -> usize {
                4
            }
            fn preferred_batch(&self) -> usize {
                8
            }
        }
        let cfg = ExecutorConfig {
            workers: 1,
            max_batch: 4,
            max_wait: Duration::from_micros(100),
            ..ExecutorConfig::default()
        };
        let mut ex = OffloadExecutor::start(Arc::new(PanicCompute), cfg).unwrap();
        ex.submit(feature_req(1)); // panics in serve
        ex.submit(raw_req(2, 4)); // panics in serve_batch once flushed
        let got = drain_until(&mut ex, 2);
        assert_eq!(got.len(), 2, "panics must still produce completions");
        for c in &got {
            let err = format!("{:#}", c.outcome.as_ref().unwrap_err());
            assert!(err.contains("panicked"), "unexpected error: {err}");
        }
        let (_, stats) = ex.drain_shutdown();
        assert_eq!(stats.errors, 2);
        assert_eq!(stats.completed, 2);
    }

    #[test]
    fn drain_shutdown_flushes_everything_still_queued() {
        let (compute, rec) = TestCompute::new(4, None);
        let cfg = ExecutorConfig {
            workers: 2,
            max_batch: 4,
            max_wait: Duration::from_secs(60), // nothing flushes on its own
            ..ExecutorConfig::default()
        };
        let mut ex = OffloadExecutor::start(compute, cfg).unwrap();
        for t in 0..6 {
            ex.submit(raw_req(t, 4)); // 4 flush by size via pump; 2 linger
        }
        ex.submit(feature_req(100));
        ex.pump(Instant::now());
        let mut got = drain_until(&mut ex, 5); // full batch + the feature
        let (rest, stats) = ex.drain_shutdown();
        got.extend(rest);
        assert_eq!(got.len(), 7, "no accepted offload may be dropped");
        assert!(got.iter().all(|c| c.outcome.is_ok()));
        assert_eq!(stats.submitted, 7);
        assert_eq!(stats.completed, 7);
        assert_eq!(stats.errors, 0);
        assert_eq!(*rec.batches.lock().unwrap(), vec![4, 2]);
    }
}
