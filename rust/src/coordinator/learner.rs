//! The online edge learner — the paper's *edge learning* loop running
//! inside the serving stack.
//!
//! A background thread consumes the serving telemetry the
//! [`super::server`] loop exports (one [`TelemetryFrame`] per decision
//! broadcast: the assembled state-pool vector plus the issued joint
//! [`HybridAction`]s), scores each frame with the env-model reward derived
//! from the device profile (Eq. 12, via a shadow [`MultiAgentEnv`]
//! replaying the issued actions), accumulates lane-0 trajectories into the
//! existing [`TrajectoryBuffer`], runs PPO update rounds **off** the
//! serving thread, and publishes refreshed actor parameters through the
//! [`PolicyHandle`] swap channel. The serving loop never blocks on any of
//! this: telemetry rides a **bounded** channel whose `try_send` drops
//! frames when the learner falls behind (serving never stalls and never
//! grows memory on telemetry), and swaps apply between decision frames.
//!
//! ```text
//! server loop ──TelemetryFrame──▶ learner thread
//!      ▲                            │ shadow-env reward (device profile)
//!      │                            │ TrajectoryBuffer (lane 0)
//!      │                            │ PPO rounds (actor+critic Adam)
//!      └──PolicyHandle::publish◀────┘ every `publish_every` rounds
//! ```

use std::sync::mpsc::Receiver;
use std::thread::JoinHandle;

use anyhow::{anyhow, Result};

use super::decision::PolicyHandle;
use crate::env::mdp::MultiAgentEnv;
use crate::env::scenario::ScenarioConfig;
use crate::env::{Action, HybridAction};
use crate::profiles::DeviceProfile;
use crate::rl::buffer::{Minibatch, TrajectoryBuffer, Transition};
use crate::rl::checkpoint::{PolicySnapshot, TrainerCheckpoint};
use crate::rl::sampling;
use crate::runtime::artifacts::ArtifactStore;
use crate::runtime::nets::{ActorNet, CriticNet};
use crate::util::rng::Rng;

/// One decision frame's worth of serving telemetry, exported by the
/// server loop right after the broadcast.
#[derive(Debug, Clone)]
pub struct TelemetryFrame {
    /// Decision frame number ([`super::protocol::FrameDecision::frame`]).
    pub frame: usize,
    /// The assembled state-pool vector the decision was computed from.
    pub state: Vec<f32>,
    /// The joint action that was broadcast — the same shared slice the
    /// decision maker produced (exporting telemetry clones an `Arc`, not
    /// the action vector).
    pub actions: std::sync::Arc<[HybridAction]>,
}

/// Online-learning knobs. Defaults are sized for a serving loop: small
/// buffer, one PPO round per fill, publish after every round.
#[derive(Debug, Clone)]
pub struct LearnerConfig {
    /// Frames accumulated before each PPO round (the buffer ‖M‖). Must be
    /// a multiple of `minibatch`.
    pub buffer_size: usize,
    /// PPO minibatch B — must match a compiled update artifact
    /// (see `ArtifactStore::update_batches`).
    pub minibatch: usize,
    /// Sample reuse K per buffer fill.
    pub reuse: usize,
    pub gamma: f64,
    pub lam: f64,
    pub lr: f32,
    pub normalize_adv: bool,
    /// Publish a policy snapshot every this many update rounds.
    pub publish_every: usize,
    pub seed: u64,
    /// PPO update workers (0 = auto) — forwarded to the nets'
    /// `set_update_threads`. The sharded update engine is worker-count
    /// invariant, so this only changes how long the learner stalls its
    /// telemetry feed per round, never what it learns.
    pub update_threads: usize,
}

impl LearnerConfig {
    /// Defaults against a store: the smallest compiled update batch as
    /// both minibatch and buffer (one round per fill, fastest feedback).
    pub fn for_store(store: &ArtifactStore, n_ues: usize) -> Result<LearnerConfig> {
        let batches = store.update_batches(n_ues)?;
        let minibatch = batches
            .iter()
            .copied()
            .min()
            .ok_or_else(|| anyhow!("no update artifacts for N={n_ues}"))?;
        Ok(LearnerConfig {
            buffer_size: minibatch,
            minibatch,
            reuse: 4,
            gamma: 0.95,
            lam: 0.95,
            lr: 1e-3,
            normalize_adv: true,
            publish_every: 1,
            seed: 0,
            update_threads: 0,
        })
    }

    fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.minibatch >= 1, "minibatch must be >= 1");
        anyhow::ensure!(
            self.buffer_size >= self.minibatch && self.buffer_size % self.minibatch == 0,
            "buffer {} must be a positive multiple of minibatch {}",
            self.buffer_size,
            self.minibatch
        );
        anyhow::ensure!(self.publish_every >= 1, "publish_every must be >= 1");
        Ok(())
    }
}

/// What the learner did before its telemetry feed closed.
#[derive(Debug, Clone, Copy, Default)]
pub struct LearnerStats {
    /// Telemetry frames consumed into trajectories.
    pub frames: usize,
    /// PPO update rounds completed.
    pub rounds: usize,
    /// Policy snapshots published through the swap channel.
    pub publishes: usize,
    /// Mean critic loss of the final update round.
    pub last_value_loss: f64,
    /// Total wall time spent inside PPO update rounds — the stall during
    /// which the telemetry feed backs up (frames shed by a full feed are
    /// counted in `ServerStats::telemetry_drops`).
    pub stall_ms_total: f64,
    /// Longest single update-round stall.
    pub stall_ms_max: f64,
}

/// Join handle over the learner thread.
pub struct LearnerHandle {
    handle: Option<JoinHandle<LearnerStats>>,
}

impl LearnerHandle {
    /// Wait for the learner to drain its telemetry feed (the feed closes
    /// when the server loop exits) and collect its stats.
    pub fn join(mut self) -> LearnerStats {
        self.handle
            .take()
            .map(|h| h.join().unwrap_or_default())
            .unwrap_or_default()
    }
}

/// The learner state living on the background thread.
struct Learner {
    actors: Vec<ActorNet>,
    critic: CriticNet,
    cfg: LearnerConfig,
    buf: TrajectoryBuffer,
    /// Reused minibatch gather buffers (`sample_minibatch_into`) — the
    /// update rounds run allocation-free at steady state.
    mb: Minibatch,
    shadow: MultiAgentEnv,
    rng: Rng,
    publisher: PolicyHandle,
    version: u64,
    stats: LearnerStats,
}

/// Spawn the online learner. `init` seeds the nets from a checkpoint (the
/// policy being served) so learning *continues*; `None` starts from fresh
/// nets (matching an [`super::decision::ActorDecision::untrained`]
/// deployment). The thread exits when `telemetry`'s sender side —
/// held by the server loop — is dropped.
pub fn spawn(
    store: &ArtifactStore,
    profile: &DeviceProfile,
    scenario: &ScenarioConfig,
    cfg: LearnerConfig,
    init: Option<&TrainerCheckpoint>,
    telemetry: Receiver<TelemetryFrame>,
    publisher: PolicyHandle,
) -> Result<LearnerHandle> {
    cfg.validate()?;
    let n = scenario.n_ues;
    anyhow::ensure!(
        store.update_batches(n)?.contains(&cfg.minibatch),
        "no update artifact for minibatch {} at N={n}",
        cfg.minibatch
    );
    let mut actors = (0..n)
        .map(|i| ActorNet::new(store, n, cfg.seed.wrapping_add(5000 + i as u64)))
        .collect::<Result<Vec<_>>>()?;
    let mut critic = CriticNet::new(store, n, cfg.seed.wrapping_add(6000))?;
    for a in actors.iter_mut() {
        a.set_update_threads(cfg.update_threads);
    }
    critic.set_update_threads(cfg.update_threads);
    if let Some(cp) = init {
        anyhow::ensure!(
            cp.actors.len() == n,
            "init checkpoint has {} actors for an N={n} scenario",
            cp.actors.len()
        );
        for (a, st) in actors.iter_mut().zip(&cp.actors) {
            a.restore(st)?;
        }
        critic.restore(&cp.critic)?;
    }
    // the shadow env replays issued actions to score them with the
    // paper's Eq. 12 reward under the device profile ("env-model reward")
    let shadow = MultiAgentEnv::new(profile.clone(), scenario.clone(), cfg.seed ^ 0x1ea4_ed9e)?;
    let buf = TrajectoryBuffer::new(cfg.buffer_size, n);
    let mut learner = Learner {
        actors,
        critic,
        rng: Rng::new(cfg.seed.wrapping_add(7000)),
        cfg,
        buf,
        mb: Minibatch::default(),
        shadow,
        publisher,
        version: 0,
        stats: LearnerStats::default(),
    };
    let handle = std::thread::Builder::new()
        .name("edge-learner".into())
        .spawn(move || {
            while let Ok(frame) = telemetry.recv() {
                if let Err(e) = learner.consume(frame) {
                    log::error!("online learner: {e:#}");
                }
            }
            learner.stats
        })?;
    Ok(LearnerHandle {
        handle: Some(handle),
    })
}

impl Learner {
    /// Fold one telemetry frame into the trajectory buffer; run a PPO
    /// round (and maybe publish) whenever the buffer fills.
    fn consume(&mut self, f: TelemetryFrame) -> Result<()> {
        let n = self.actors.len();
        if f.actions.len() != n || f.state.len() != 4 * n {
            anyhow::bail!(
                "telemetry frame {} has {} actions / {}-dim state for N={n}",
                f.frame,
                f.actions.len(),
                f.state.len()
            );
        }
        // log π_old of the *issued* action under the current nets (the
        // serving policy and the learner's copy are kept in sync by the
        // publish channel, modulo in-flight rounds)
        let (mut a_b, mut a_c, mut a_p, mut log_prob) = (
            Vec::with_capacity(n),
            Vec::with_capacity(n),
            Vec::with_capacity(n),
            Vec::with_capacity(n),
        );
        for (actor, a) in self.actors.iter_mut().zip(f.actions.iter()) {
            let out = actor.forward(&f.state)?;
            let b = a.b.min(out.probs_b.len() - 1);
            let c = a.c.min(out.probs_c.len() - 1);
            let lp = sampling::categorical_log_prob(&out.probs_b, b)
                + sampling::categorical_log_prob(&out.probs_c, c)
                + sampling::gaussian_log_prob(a.p_raw, out.mu, out.log_std);
            a_b.push(b as i32);
            a_c.push(c as i32);
            a_p.push(a.p_raw);
            log_prob.push(lp);
        }
        let value = self.critic.value(&f.state)?;

        // env-model reward: replay the issued joint action on the shadow
        // env (clamping decisions into its action space)
        let replay: Action = f
            .actions
            .iter()
            .map(|a| {
                HybridAction::new(
                    a.b.min(self.shadow.profile.n_choices - 1),
                    a.c.min(self.shadow.cfg.n_channels - 1),
                    a.p_raw,
                    self.shadow.cfg.p_max,
                )
            })
            .collect();
        let step = self.shadow.step(&replay);
        if step.done {
            self.shadow.reset();
        }

        self.buf.push(Transition {
            state: f.state,
            a_b,
            a_c,
            a_p,
            log_prob,
            reward: step.reward,
            value,
            done: step.done,
        });
        self.stats.frames += 1;

        if self.buf.is_full() {
            self.update_round()?;
        }
        Ok(())
    }

    /// One buffer's worth of PPO: finish returns/GAE, K·(‖M‖/B) minibatch
    /// steps, clear — then publish the refreshed policy on schedule.
    ///
    /// This runs inline on the telemetry-consuming thread, so its wall
    /// time is exactly the stall during which the bounded telemetry feed
    /// backs up (and the server sheds frames, counted in
    /// `ServerStats::telemetry_drops`). The stall is tracked in
    /// [`LearnerStats`]; `update_threads` shortens it on multicore hosts.
    fn update_round(&mut self) -> Result<()> {
        let t0 = std::time::Instant::now();
        let bootstrap = self.critic.value(&self.shadow.state())? as f64;
        self.buf.finish(
            self.cfg.gamma,
            self.cfg.lam,
            bootstrap,
            self.cfg.normalize_adv,
        );
        let rounds = self.cfg.reuse * (self.cfg.buffer_size / self.cfg.minibatch).max(1);
        let mut vloss = 0.0f64;
        for _ in 0..rounds {
            self.buf
                .sample_minibatch_into(self.cfg.minibatch, &mut self.rng, &mut self.mb);
            vloss += self
                .critic
                .update(self.cfg.lr, &self.mb.states, &self.mb.returns)? as f64;
            for (u, actor) in self.actors.iter_mut().enumerate() {
                actor.update(
                    self.cfg.lr,
                    &self.mb.states,
                    &self.mb.a_b[u],
                    &self.mb.a_c[u],
                    &self.mb.a_p[u],
                    &self.mb.old_logp[u],
                    &self.mb.adv,
                )?;
            }
        }
        self.buf.clear();
        self.stats.rounds += 1;
        self.stats.last_value_loss = vloss / rounds as f64;
        let stall = t0.elapsed().as_secs_f64() * 1e3;
        self.stats.stall_ms_total += stall;
        if stall > self.stats.stall_ms_max {
            self.stats.stall_ms_max = stall;
        }

        if self.stats.rounds % self.cfg.publish_every == 0 {
            self.version += 1;
            let snap = PolicySnapshot {
                version: self.version,
                actors: self.actors.iter().map(|a| a.params.clone()).collect(),
            };
            if self.publisher.publish(snap) {
                self.stats.publishes += 1;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    use crate::coordinator::decision::{DecisionMaker, StaticDecision};

    fn scenario(n: usize) -> ScenarioConfig {
        ScenarioConfig {
            n_ues: n,
            lambda_tasks: 10.0,
            ..Default::default()
        }
    }

    #[test]
    fn learner_trains_and_publishes_from_telemetry() {
        let store = ArtifactStore::native_demo();
        let n = 3;
        let sc = scenario(n);
        let profile = DeviceProfile::synthetic();
        let cfg = LearnerConfig {
            reuse: 1,
            ..LearnerConfig::for_store(&store, n).unwrap()
        };
        let buffer = cfg.buffer_size;

        // a throwaway maker supplies the swap channel end to observe
        let dm = DecisionMaker::new(Box::new(StaticDecision::new(vec![
            HybridAction::new(5, 0, 0.0, 1.0);
            n
        ])));
        let handle = dm.policy_handle();

        let (tx, rx) = channel();
        let learner = spawn(&store, &profile, &sc, cfg, None, rx, handle).unwrap();
        // feed exactly two buffers of synthetic telemetry
        let mut rng = Rng::new(5);
        for frame in 0..2 * buffer {
            let state: Vec<f32> = (0..4 * n).map(|_| rng.f32()).collect();
            let actions: std::sync::Arc<[HybridAction]> = (0..n)
                .map(|_| HybridAction::new(rng.below(6), rng.below(2), rng.normal() as f32, 1.0))
                .collect();
            tx.send(TelemetryFrame {
                frame,
                state,
                actions,
            })
            .unwrap();
        }
        drop(tx); // feed closes -> learner drains and exits
        let stats = learner.join();
        assert_eq!(stats.frames, 2 * buffer);
        assert_eq!(stats.rounds, 2);
        assert_eq!(stats.publishes, 2);
        assert!(stats.last_value_loss.is_finite());
        assert!(stats.stall_ms_total > 0.0, "update stall is measured");
        assert!(stats.stall_ms_max <= stats.stall_ms_total);
    }

    #[test]
    fn bad_config_rejected_up_front() {
        let store = ArtifactStore::native_demo();
        let profile = DeviceProfile::synthetic();
        let sc = scenario(3);
        let mut cfg = LearnerConfig::for_store(&store, 3).unwrap();
        cfg.buffer_size = cfg.minibatch + 1; // not a multiple
        let (_tx, rx) = channel();
        let dm = DecisionMaker::new(Box::new(StaticDecision::new(vec![
            HybridAction::new(5, 0, 0.0, 1.0);
            3
        ])));
        assert!(spawn(&store, &profile, &sc, cfg, None, rx, dm.policy_handle()).is_err());

        let mut cfg = LearnerConfig::for_store(&store, 3).unwrap();
        cfg.minibatch = 7; // no compiled update artifact
        cfg.buffer_size = 7;
        let (_tx, rx) = channel();
        assert!(spawn(&store, &profile, &sc, cfg, None, rx, dm.policy_handle()).is_err());
    }
}
