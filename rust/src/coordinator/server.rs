//! The threaded edge-server event loop (Sec. 3.1 workflow, Fig. 2a).
//!
//! One server thread owns the state pool, the decision maker and the
//! offload executor; each UE is a client holding an `mpsc::Sender<Uplink>`
//! and its own downlink receiver. Per tick the server:
//!
//! 1. drains uplink messages (state reports, offloaded payloads, goodbyes);
//! 2. if a decision interval elapsed, assembles the state pool and
//!    broadcasts the next [`FrameDecision`];
//! 3. serves offloaded inferences (through the collaborative pipeline) and
//!    returns results on the owning UE's downlink.
//!
//! std threads + mpsc stand in for tokio (offline build — see DESIGN.md);
//! the loop structure is identical to an async reactor with a timer.

use std::collections::HashMap;
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use super::decision::DecisionMaker;
use super::inference::CollabPipeline;
use super::protocol::{Downlink, Uplink};
use super::state_pool::StatePool;

/// Server-side counters (exposed after shutdown).
#[derive(Debug, Clone, Copy, Default)]
pub struct ServerStats {
    pub frames: usize,
    pub reports: usize,
    pub offloads_served: usize,
    pub raw_offloads: usize,
    pub feature_offloads: usize,
    pub edge_compute_s: f64,
}

/// Handle to a running edge server.
pub struct EdgeServer {
    pub uplink: Sender<Uplink>,
    handle: Option<JoinHandle<ServerStats>>,
}

/// Everything the server thread needs.
pub struct ServerConfig {
    pub n_ues: usize,
    /// Real-time decision interval (scaled-down T0 for the demo loop).
    pub decision_interval: Duration,
    /// Stop after this many decision frames even if UEs linger.
    pub max_frames: usize,
}

impl EdgeServer {
    /// Spawn the server thread. `downlinks[ue_id]` receives that UE's
    /// decisions and inference results. `pipeline` may be `None` for a
    /// decision-only server (pure scheduling, no model serving).
    pub fn spawn(
        cfg: ServerConfig,
        mut pool: StatePool,
        mut decisions: DecisionMaker,
        pipeline: Option<CollabPipeline>,
    ) -> Result<(EdgeServer, Vec<Receiver<Downlink>>)> {
        let (uplink_tx, uplink_rx) = channel::<Uplink>();
        let mut downlink_txs: Vec<Sender<Downlink>> = Vec::with_capacity(cfg.n_ues);
        let mut downlink_rxs: Vec<Receiver<Downlink>> = Vec::with_capacity(cfg.n_ues);
        for _ in 0..cfg.n_ues {
            let (tx, rx) = channel();
            downlink_txs.push(tx);
            downlink_rxs.push(rx);
        }

        let handle = std::thread::Builder::new()
            .name("edge-server".into())
            .spawn(move || {
                server_loop(cfg, uplink_rx, downlink_txs, &mut pool, &mut decisions, pipeline)
            })?;

        Ok((
            EdgeServer {
                uplink: uplink_tx,
                handle: Some(handle),
            },
            downlink_rxs,
        ))
    }

    /// Wait for the server loop to exit and collect its stats.
    pub fn join(mut self) -> ServerStats {
        self.handle
            .take()
            .map(|h| h.join().unwrap_or_default())
            .unwrap_or_default()
    }
}

fn server_loop(
    cfg: ServerConfig,
    uplink: Receiver<Uplink>,
    downlinks: Vec<Sender<Downlink>>,
    pool: &mut StatePool,
    decisions: &mut DecisionMaker,
    pipeline: Option<CollabPipeline>,
) -> ServerStats {
    let mut stats = ServerStats::default();
    let mut alive: HashMap<usize, bool> = (0..downlinks.len()).map(|i| (i, true)).collect();
    let mut last_decision = Instant::now();
    // issue an initial decision as soon as the first full pool assembles
    let mut first_decision_done = false;
    // set when every uplink sender is gone: no client can ever speak again
    let mut uplink_disconnected = false;

    loop {
        // -- drain the uplink --
        loop {
            match uplink.try_recv() {
                Ok(Uplink::Report(r)) => {
                    stats.reports += 1;
                    pool.ingest(r);
                }
                Ok(Uplink::Offload(req)) => {
                    if let Some(pipe) = pipeline.as_ref() {
                        if req.b == 0 {
                            stats.raw_offloads += 1;
                        } else {
                            stats.feature_offloads += 1;
                        }
                        match pipe.serve_offload(&req) {
                            Ok(result) => {
                                stats.offloads_served += 1;
                                stats.edge_compute_s += result.edge_latency_s;
                                if let Some(tx) = downlinks.get(req.ue_id) {
                                    let _ = tx.send(Downlink::Result(result));
                                }
                            }
                            Err(e) => log::error!("offload from UE {}: {e:#}", req.ue_id),
                        }
                    }
                }
                Ok(Uplink::Goodbye { ue_id }) => {
                    alive.insert(ue_id, false);
                }
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    // every sender clone dropped: treat full disconnection
                    // as shutdown instead of busy-spinning to max_frames
                    uplink_disconnected = true;
                    break;
                }
            }
        }

        // -- all UEs done or gone? --
        if uplink_disconnected {
            log::debug!("uplink fully disconnected — shutting down");
            break;
        }
        if alive.values().all(|&a| !a) {
            break;
        }
        if stats.frames >= cfg.max_frames {
            break;
        }

        // -- decision tick --
        let due = last_decision.elapsed() >= cfg.decision_interval;
        let ready = pool.complete() || first_decision_done;
        if (due && ready) || (!first_decision_done && pool.complete()) {
            let state = pool.assemble();
            match decisions.next_decision(&state) {
                Ok(d) => {
                    stats.frames += 1;
                    first_decision_done = true;
                    for (i, tx) in downlinks.iter().enumerate() {
                        if alive.get(&i).copied().unwrap_or(false) {
                            let _ = tx.send(Downlink::Decision(d.clone()));
                        }
                    }
                }
                Err(e) => log::error!("decision failed: {e:#}"),
            }
            last_decision = Instant::now();
        }

        std::thread::sleep(Duration::from_micros(200));
    }

    for tx in &downlinks {
        let _ = tx.send(Downlink::Shutdown);
    }
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::decision::StaticDecision;
    use crate::coordinator::protocol::UeStateReport;
    use crate::coordinator::state_pool::StateNorm;
    use crate::env::HybridAction;

    #[test]
    fn decision_only_server_round() {
        let n = 3;
        let pool = StatePool::new(
            n,
            StateNorm {
                lambda_tasks: 10.0,
                frame_s: 0.5,
                max_bits: 1e6,
                d_max: 100.0,
            },
        );
        let dm = DecisionMaker::new(Box::new(StaticDecision {
            actions: vec![HybridAction::new(5, 0, 0.0, 1.0); n],
        }));
        let cfg = ServerConfig {
            n_ues: n,
            decision_interval: Duration::from_millis(5),
            max_frames: 3,
        };
        let (server, downlinks) = EdgeServer::spawn(cfg, pool, dm, None).unwrap();

        // all UEs report, then await decisions
        for ue in 0..n {
            server
                .uplink
                .send(Uplink::Report(UeStateReport {
                    ue_id: ue,
                    tasks_left: 5,
                    compute_left_s: 0.0,
                    offload_left_bits: 0.0,
                    distance_m: 40.0,
                }))
                .unwrap();
        }
        let mut got = 0;
        for rx in &downlinks {
            if let Ok(Downlink::Decision(d)) = rx.recv_timeout(Duration::from_secs(2)) {
                assert_eq!(d.actions.len(), n);
                got += 1;
            }
        }
        assert_eq!(got, n, "every UE receives the broadcast");
        for ue in 0..n {
            server.uplink.send(Uplink::Goodbye { ue_id: ue }).unwrap();
        }
        let stats = server.join();
        assert!(stats.frames >= 1);
        assert_eq!(stats.reports, n);
    }

    #[test]
    fn dropped_uplink_without_goodbye_shuts_down() {
        let n = 2;
        let pool = StatePool::new(
            n,
            StateNorm {
                lambda_tasks: 10.0,
                frame_s: 0.5,
                max_bits: 1e6,
                d_max: 100.0,
            },
        );
        let dm = DecisionMaker::new(Box::new(StaticDecision {
            actions: vec![HybridAction::new(5, 0, 0.0, 1.0); n],
        }));
        let cfg = ServerConfig {
            n_ues: n,
            decision_interval: Duration::from_millis(5),
            // huge frame budget: only disconnection can end the loop quickly
            max_frames: usize::MAX,
        };
        let (server, _downlinks) = EdgeServer::spawn(cfg, pool, dm, None).unwrap();
        server
            .uplink
            .send(Uplink::Report(UeStateReport {
                ue_id: 0,
                tasks_left: 1,
                compute_left_s: 0.0,
                offload_left_bits: 0.0,
                distance_m: 40.0,
            }))
            .unwrap();
        // UEs vanish without a Goodbye: dropping the only sender must shut
        // the server down promptly instead of spinning to max_frames
        drop(server.uplink.clone()); // exercise clone-then-drop too
        let EdgeServer { uplink, handle } = server;
        drop(uplink);
        let t0 = std::time::Instant::now();
        let stats = handle.unwrap().join().unwrap();
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "server must exit promptly on full disconnection"
        );
        assert_eq!(stats.reports, 1);
    }
}
